"""Layer-1 Pallas kernels: the fused dense-layer hot path of SSP-DNN.

The paper's per-layer compute (Eq. 6/7) decomposes into three kernels:

* ``dense_sigmoid``   — forward  ``z = h(x W + b)``
* ``delta_backward``  — backflow ``delta_i = h'(a_i) * (delta W^T)_i``
* ``grad_w``          — gradient ``dW = z_lower^T delta / B``

Each is a tiled Pallas kernel with an explicit BlockSpec schedule.  The
tiling is MXU-shaped (multiples of 128x128 blocks, fp32 accumulate) so the
same kernels lower to Mosaic on a real TPU; in this repo they are lowered
with ``interpret=True`` so the resulting HLO runs on the CPU PJRT plugin
(see DESIGN.md §Hardware-Adaptation).

Inputs of arbitrary shape are zero-padded up to block multiples inside the
wrappers and the result is sliced back, so the kernels are total functions
over the hypothesis sweep in ``python/tests/test_kernels.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 128
# interpret=True everywhere: real-TPU lowering emits a Mosaic custom-call
# that the CPU PJRT plugin cannot execute (see /opt/xla-example/README.md).
INTERPRET = True


def _pad2(x, bm, bn):
    """Zero-pad a 2-D array up to multiples of (bm, bn)."""
    m, n = x.shape
    pm = (-m) % bm
    pn = (-n) % bn
    if pm == 0 and pn == 0:
        return x
    return jnp.pad(x, ((0, pm), (0, pn)))


def _blocks(dim, blk):
    return (dim + blk - 1) // blk


def _sigmoid(a):
    return jnp.where(
        a >= 0, 1.0 / (1.0 + jnp.exp(-a)), jnp.exp(a) / (1.0 + jnp.exp(a))
    )


# ---------------------------------------------------------------------------
# forward: z = sigmoid(x @ w + b)
# ---------------------------------------------------------------------------


def _dense_kernel(x_ref, w_ref, b_ref, o_ref, *, nk: int, activation: str):
    """Grid (M/bm, N/bn, K/bk); K is innermost so o_ref accumulates."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _finish():
        acc = o_ref[...] + b_ref[...]
        if activation == "sigmoid":
            o_ref[...] = _sigmoid(acc)
        else:
            o_ref[...] = acc


def _dense(x, w, b, activation, bm, bn, bk):
    m, kdim = x.shape
    _, n = w.shape
    bm = min(bm, max(m, 1))
    bn = min(bn, max(n, 1))
    bk = min(bk, max(kdim, 1))
    xp = _pad2(x, bm, bk)
    wp = _pad2(w, bk, bn)
    bp = _pad2(b[None, :], 1, bn)
    nk = _blocks(kdim, bk)
    out = pl.pallas_call(
        functools.partial(_dense_kernel, nk=nk, activation=activation),
        grid=(_blocks(m, bm), _blocks(n, bn), nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct(
            (_blocks(m, bm) * bm, _blocks(n, bn) * bn), jnp.float32
        ),
        interpret=INTERPRET,
    )(xp, wp, bp)
    return out[:m, :n]


def dense_sigmoid(x, w, b, *, bm=DEFAULT_BLOCK, bn=DEFAULT_BLOCK, bk=DEFAULT_BLOCK):
    """Fused forward layer ``sigmoid(x @ w + b)`` (paper: z_j = h(a_j))."""
    return _dense(x, w, b, "sigmoid", bm, bn, bk)


def dense_linear(x, w, b, *, bm=DEFAULT_BLOCK, bn=DEFAULT_BLOCK, bk=DEFAULT_BLOCK):
    """Fused forward layer without activation (pre-softmax output layer)."""
    return _dense(x, w, b, "linear", bm, bn, bk)


# ---------------------------------------------------------------------------
# backward error flow: delta_i = h'(a_i) * sum_j delta_j w_{j,i}
# ---------------------------------------------------------------------------


def _delta_kernel(d_ref, w_ref, z_ref, o_ref, *, nk: int):
    """Grid (B/bm, I/bn, O/bk).  d (bm,bk) @ w(bn,bk)^T, fused h'."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        d_ref[...], w_ref[...].T, preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _finish():
        z = z_ref[...]
        o_ref[...] = o_ref[...] * z * (1.0 - z)


def delta_backward(
    delta, w, z_lower, *, bm=DEFAULT_BLOCK, bn=DEFAULT_BLOCK, bk=DEFAULT_BLOCK
):
    """Backpropagate errors one layer (paper chain rule, fused with h').

    delta: (B, O); w: (I, O); z_lower: (B, I) -> (B, I).
    """
    m, o = delta.shape
    i, _ = w.shape
    bm = min(bm, max(m, 1))
    bn = min(bn, max(i, 1))
    bk = min(bk, max(o, 1))
    dp = _pad2(delta, bm, bk)
    wp = _pad2(w, bn, bk)
    zp = _pad2(z_lower, bm, bn)
    nk = _blocks(o, bk)
    out = pl.pallas_call(
        functools.partial(_delta_kernel, nk=nk),
        grid=(_blocks(m, bm), _blocks(i, bn), nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda a, b_, k: (a, k)),
            pl.BlockSpec((bn, bk), lambda a, b_, k: (b_, k)),
            pl.BlockSpec((bm, bn), lambda a, b_, k: (a, b_)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda a, b_, k: (a, b_)),
        out_shape=jax.ShapeDtypeStruct(
            (_blocks(m, bm) * bm, _blocks(i, bn) * bn), jnp.float32
        ),
        interpret=INTERPRET,
    )(dp, wp, zp)
    return out[:m, :i]


# ---------------------------------------------------------------------------
# weight gradient: dW = z_lower^T @ delta / B
# ---------------------------------------------------------------------------


def _gradw_kernel(z_ref, d_ref, o_ref, *, nk: int, inv_batch: float):
    """Grid (I/bm, O/bn, B/bk).  z(bk,bm)^T @ d(bk,bn), scaled by 1/B."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        z_ref[...].T, d_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _finish():
        o_ref[...] = o_ref[...] * inv_batch


def grad_w(delta, z_lower, *, bm=DEFAULT_BLOCK, bn=DEFAULT_BLOCK, bk=DEFAULT_BLOCK):
    """Batch-mean weight gradient ``z_lower^T @ delta / B`` -> (I, O)."""
    batch, o = delta.shape
    _, i = z_lower.shape
    bm = min(bm, max(i, 1))
    bn = min(bn, max(o, 1))
    bk = min(bk, max(batch, 1))
    zp = _pad2(z_lower, bk, bm)
    dp = _pad2(delta, bk, bn)
    nk = _blocks(batch, bk)
    out = pl.pallas_call(
        functools.partial(_gradw_kernel, nk=nk, inv_batch=1.0 / batch),
        grid=(_blocks(i, bm), _blocks(o, bn), nk),
        in_specs=[
            pl.BlockSpec((bk, bm), lambda a, b_, k: (k, a)),
            pl.BlockSpec((bk, bn), lambda a, b_, k: (k, b_)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda a, b_, k: (a, b_)),
        out_shape=jax.ShapeDtypeStruct(
            (_blocks(i, bm) * bm, _blocks(o, bn) * bn), jnp.float32
        ),
        interpret=INTERPRET,
    )(zp, dp)
    return out[:i, :o]


def sgd_apply(w, delta, z_lower, eta, **blocks):
    """Fused SGD step on one layer: ``w - eta * grad_w`` (paper Eq. 6)."""
    return w - eta * grad_w(delta, z_lower, **blocks)


# ---------------------------------------------------------------------------
# output-layer error: delta_M = softmax(logits) - onehot(y)   (Eq. 7 top)
# ---------------------------------------------------------------------------


def _softmax_delta_kernel(l_ref, y_ref, o_ref):
    """One batch-row block, full class width: stable softmax - onehot."""
    logits = l_ref[...]
    mx = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - mx)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    cols = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    onehot = (cols == y_ref[...][:, None]).astype(jnp.float32)
    o_ref[...] = p - onehot


def softmax_delta(logits, y, *, bm=DEFAULT_BLOCK):
    """The paper's output-layer error term ``delta_M`` for cross-entropy.

    logits: (B, C) f32; y: (B,) int32 class ids. Returns (B, C).
    Grid over batch rows only — the row-wise softmax needs the whole class
    axis resident (class counts here: <= 2001 → <=8 KB/row, VMEM-trivial).
    """
    b, c = logits.shape
    bm = min(bm, max(b, 1))
    pb = (-b) % bm
    lp = jnp.pad(logits, ((0, pb), (0, 0)))
    yp = jnp.pad(y, (0, pb), constant_values=0)
    out = pl.pallas_call(
        _softmax_delta_kernel,
        grid=(_blocks(b, bm),),
        in_specs=[
            pl.BlockSpec((bm, c), lambda i: (i, 0)),
            pl.BlockSpec((bm,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bm, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((_blocks(b, bm) * bm, c), jnp.float32),
        interpret=INTERPRET,
    )(lp, yp)
    return out[:b, :]
