"""Pure-jnp reference oracles for the Layer-1 Pallas kernels.

These are the ground truth the Pallas kernels in ``fused_layer.py`` are
checked against (``python/tests/test_kernels.py``, hypothesis sweeps over
shapes/dtypes).  They implement exactly the layerwise quantities of the
paper's Eq. (6)/(7):

* ``dense_sigmoid``     — forward:  ``z_j = h(a_j)``, ``a_j = sum_i w_ji z_i + b_j``
* ``delta_backward``    — backflow: ``delta_i = h'(a_i) * sum_j delta_j w_ji``
* ``sgd_apply``         — update:   ``w_ji <- w_ji - eta * delta_j z_i`` (batched)

Shape conventions (row-major, batch-first):
  x      : (B, I)   activations entering the layer (``z_i`` in the paper)
  w      : (I, O)   weight matrix ``w^{(m+1, m)}`` stored input-major
  b      : (O,)     bias
  delta  : (B, O)   error terms ``delta_j`` of the upper layer
  z_lower: (B, I)   activation outputs of the *lower* layer (for h')
"""

from __future__ import annotations

import jax.numpy as jnp


def sigmoid(a):
    """Numerically-stable logistic unit h(a) (paper Assumption 3)."""
    return jnp.where(
        a >= 0, 1.0 / (1.0 + jnp.exp(-a)), jnp.exp(a) / (1.0 + jnp.exp(a))
    )


def sigmoid_grad_from_output(z):
    """h'(a) expressed through the activation output: h'(a) = z (1 - z)."""
    return z * (1.0 - z)


def dense_sigmoid(x, w, b):
    """Forward fused dense layer: sigmoid(x @ w + b)."""
    return sigmoid(jnp.dot(x, w, preferred_element_type=jnp.float32) + b)


def dense_linear(x, w, b):
    """Forward dense layer without activation (output layer pre-softmax)."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32) + b


def delta_backward(delta, w, z_lower):
    """Backpropagate error terms one layer down (paper chain rule).

    delta_i = h'(a_i) * sum_j delta_j w_{j,i}
    with h'(a_i) = z_i (1 - z_i) for sigmoid units.

    delta: (B, O) errors at the upper layer; w: (I, O); z_lower: (B, I)
    activations of the lower layer.  Returns (B, I).
    """
    back = jnp.dot(delta, w.T, preferred_element_type=jnp.float32)
    return back * sigmoid_grad_from_output(z_lower)


def grad_w(delta, z_lower):
    """Weight gradient dL/dW = z_lower^T @ delta, averaged over the batch.

    delta: (B, O); z_lower: (B, I) -> (I, O).
    """
    batch = delta.shape[0]
    return jnp.dot(z_lower.T, delta, preferred_element_type=jnp.float32) / batch


def sgd_apply(w, delta, z_lower, eta):
    """Fused SGD step on one layer: w - eta * grad_w(delta, z_lower)."""
    return w - eta * grad_w(delta, z_lower)


def softmax_xent(logits, labels):
    """Mean softmax cross-entropy; labels are int32 class ids (B,)."""
    logits = logits - jnp.max(logits, axis=-1, keepdims=True)
    logz = jnp.log(jnp.sum(jnp.exp(logits), axis=-1, keepdims=True))
    logp = logits - logz
    picked = jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return -jnp.mean(picked)


def mse(pred, target):
    """Mean squared error (paper's l2 loss option), 0.5 ||y - f||^2 mean."""
    return 0.5 * jnp.mean(jnp.sum((pred - target) ** 2, axis=-1))
