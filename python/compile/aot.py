"""AOT pipeline: lower the Layer-2 model to HLO *text* artifacts.

Build-time only (``make artifacts``).  Python never runs on the training
path: the Rust runtime loads ``artifacts/<name>.hlo.txt`` through
``HloModuleProto::from_text_file`` and executes via PJRT.

HLO **text** is the interchange format, NOT ``.serialize()``: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Each artifact is a flat-signature step function
``(w0, b0, ..., x, y) -> (loss, g_w0, g_b0, ...)`` plus a JSON manifest
describing shapes/dtypes/argument order for the Rust side.

Usage:  cd python && python -m compile.aot --out ../artifacts
        (optionally ``--only tiny,timit_scaled`` / ``--list``)
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from compile import model

# ---------------------------------------------------------------------------
# Artifact registry.
#
# `dims` are layer sizes [input, hidden..., output]; `impl` selects the
# gradient path: "jnp" = autodiff (production), "pallas" = the paper's
# layerwise Eq.(6)/(7) backprop through the Layer-1 Pallas kernels.
#
# Paper-scale configs (Section 6.1):
#   TIMIT:       360 -> 2048 x6 -> 2001   (~24M params), minibatch 100
#   ImageNet-63K 21504 -> 5000,3000,2000 -> 1000 (~132M), minibatch 1000
# The *_scaled variants keep the architecture shape but shrink widths so
# the full bench suite runs on one CPU core; `e2e_100m` is the ~100M-param
# end-to-end training artifact used by examples/e2e_train_100m.rs.
# ---------------------------------------------------------------------------

CONFIGS = {
    # correctness-sized artifacts (integration tests, quickstart)
    "tiny": dict(dims=[16, 32, 10], batch=8, loss="xent", impl="jnp"),
    "tiny_pallas": dict(dims=[16, 32, 10], batch=8, loss="xent", impl="pallas"),
    "tiny_mse": dict(dims=[16, 32, 10], batch=8, loss="mse", impl="jnp"),
    # scaled workloads driving the paper's figures
    "timit_scaled": dict(
        dims=[360, 256, 256, 256, 256, 256, 256, 2001],
        batch=100,
        loss="xent",
        impl="jnp",
    ),
    "imagenet_scaled": dict(
        dims=[2150, 500, 300, 200, 1000], batch=100, loss="xent", impl="jnp"
    ),
    # the end-to-end ~100M-parameter driver (examples/e2e_train_100m.rs)
    "e2e_100m": dict(
        dims=[4096, 8192, 4096, 4096, 2048, 1024],
        batch=16,
        loss="xent",
        impl="jnp",
    ),
}

FORWARD_CONFIGS = {
    "tiny_fwd": dict(dims=[16, 32, 10], batch=8, loss="xent"),
}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple for rust)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_json(spec, name):
    return {
        "name": name,
        "shape": list(spec.shape),
        "dtype": str(spec.dtype),
    }


def build_one(name, cfg, out_dir):
    dims, batch, loss = cfg["dims"], cfg["batch"], cfg["loss"]
    impl = cfg.get("impl")
    if impl is None:  # forward-only artifact
        fn = model.make_forward_fn(dims, loss)
        specs, names = model.arg_specs(dims, batch, loss, with_y=False)
        outputs = [{"name": "out", "shape": [batch, dims[-1]], "dtype": "float32"}]
        kind = "forward"
    else:
        fn = model.make_step_fn(dims, loss, impl)
        specs, names = model.arg_specs(dims, batch, loss, with_y=True)
        outputs = [{"name": "loss", "shape": [], "dtype": "float32"}]
        for m in range(len(dims) - 1):
            outputs.append(
                {"name": f"g_w{m}", "shape": [dims[m], dims[m + 1]], "dtype": "float32"}
            )
            outputs.append(
                {"name": f"g_b{m}", "shape": [dims[m + 1]], "dtype": "float32"}
            )
        kind = "step"

    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    path = os.path.join(out_dir, fname)
    with open(path, "w") as f:
        f.write(text)
    digest = hashlib.sha256(text.encode()).hexdigest()[:16]
    entry = {
        "file": fname,
        "kind": kind,
        "layer_dims": dims,
        "batch": batch,
        "loss": loss,
        "impl": impl or "jnp",
        "inputs": [_spec_json(s, n) for s, n in zip(specs, names)],
        "outputs": outputs,
        "sha256_16": digest,
    }
    n_params = sum(dims[m] * dims[m + 1] + dims[m + 1] for m in range(len(dims) - 1))
    print(f"  {name:18s} {len(text):>10d} chars  {n_params:>12d} params")
    return entry


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--only", default="", help="comma-separated artifact names")
    ap.add_argument("--skip-large", action="store_true",
                    help="skip the e2e_100m artifact (CI-speed builds)")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    all_cfgs = {**CONFIGS, **FORWARD_CONFIGS}
    if args.list:
        for k, v in all_cfgs.items():
            print(k, v)
        return 0

    names = [n for n in args.only.split(",") if n] or list(all_cfgs)
    if args.skip_large:
        names = [n for n in names if n != "e2e_100m"]

    os.makedirs(args.out, exist_ok=True)
    manifest = {"format": 1, "artifacts": {}}
    manifest_path = os.path.join(args.out, "manifest.json")
    # merge with an existing manifest so --only builds are incremental
    if os.path.exists(manifest_path):
        try:
            with open(manifest_path) as f:
                manifest = json.load(f)
        except Exception:
            pass

    print(f"lowering {len(names)} artifacts -> {args.out}")
    for n in names:
        if n not in all_cfgs:
            print(f"unknown artifact {n!r}", file=sys.stderr)
            return 1
        manifest["artifacts"][n] = build_one(n, all_cfgs[n], args.out)

    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {manifest_path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
