"""Layer-2: the SSP-DNN model — JAX forward/backward for a sigmoid MLP.

This is the compute graph the paper trains (Section 4): a feed-forward DNN
with logistic hidden units and either a softmax cross-entropy output
(classification on TIMIT / ImageNet-63K) or an MSE output (paper's l2
option).  Two gradient implementations are provided:

* ``loss_and_grads_autodiff`` — plain jnp forward + ``jax.value_and_grad``.
  This is the production path for large configurations: XLA fuses it and
  the artifact runs fast on the CPU PJRT plugin.

* ``loss_and_grads_manual``  — the paper's *layerwise* backpropagation,
  Eq. (6)/(7), written explicitly with the Layer-1 Pallas kernels
  (``kernels.fused_layer``): forward through ``dense_sigmoid``, the error
  terms ``delta`` flowing down through ``delta_backward``, and per-layer
  gradients from ``grad_w``.  pytest asserts it matches autodiff exactly.

Both lower to HLO via ``aot.py``; the Rust coordinator treats them
identically (same manifest signature).

Parameter convention: ``params = [w0, b0, w1, b1, ...]`` with
``w_m : (dims[m], dims[m+1])`` — i.e. ``w^{(m+1,m)}`` of the paper stored
input-major — and ``b_m : (dims[m+1],)``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels import fused_layer as fk
from compile.kernels import ref


def init_params(key, dims):
    """Glorot-uniform weights, zero biases, for layer dims [d0, ..., dM]."""
    params = []
    for m in range(len(dims) - 1):
        key, sub = jax.random.split(key)
        fan_in, fan_out = dims[m], dims[m + 1]
        limit = jnp.sqrt(6.0 / (fan_in + fan_out))
        w = jax.random.uniform(
            sub, (fan_in, fan_out), jnp.float32, -limit, limit
        )
        params.append(w)
        params.append(jnp.zeros((fan_out,), jnp.float32))
    return params


def _split(params):
    """[w0, b0, w1, b1, ...] -> ([w...], [b...])."""
    return params[0::2], params[1::2]


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def forward_jnp(params, x, loss: str):
    """Pure-jnp forward; returns output-layer values (logits or sigmoids)."""
    ws, bs = _split(params)
    z = x
    for m in range(len(ws) - 1):
        z = ref.dense_sigmoid(z, ws[m], bs[m])
    out = ref.dense_linear(z, ws[-1], bs[-1])
    if loss == "mse":
        out = ref.sigmoid(out)
    return out


def forward_pallas(params, x, loss: str):
    """Forward through the Layer-1 Pallas kernels; returns (out, activations).

    activations[m] is the input z entering layer m (activations[0] == x),
    needed by the layerwise backward pass.
    """
    ws, bs = _split(params)
    acts = [x]
    z = x
    for m in range(len(ws) - 1):
        z = fk.dense_sigmoid(z, ws[m], bs[m])
        acts.append(z)
    out = fk.dense_linear(z, ws[-1], bs[-1])
    if loss == "mse":
        out = ref.sigmoid(out)
    return out, acts


def objective(params, x, y, loss: str):
    """The paper's Eq. (3) objective E for one minibatch."""
    out = forward_jnp(params, x, loss)
    if loss == "xent":
        return ref.softmax_xent(out, y)
    return ref.mse(out, y)


# ---------------------------------------------------------------------------
# gradients
# ---------------------------------------------------------------------------


def loss_and_grads_autodiff(params, x, y, loss: str):
    """(E, [dE/dw0, dE/db0, ...]) via jax.value_and_grad."""
    val, grads = jax.value_and_grad(lambda p: objective(p, x, y, loss))(params)
    return val, grads


def loss_and_grads_manual(params, x, y, loss: str):
    """The paper's layerwise backprop (Eq. 6/7) with Pallas kernels.

    delta_M at the output layer, then recursively
    ``delta_m = h'(a_m) * (delta_{m+1} W^T)`` via ``delta_backward``; each
    layer's gradient is ``grad_w(delta, z_lower)`` — computed independently
    per layer, exactly the structure SSP synchronizes independently.
    """
    ws, bs = _split(params)
    out, acts = forward_pallas(params, x, loss)

    if loss == "xent":
        loss_val = ref.softmax_xent(out, y)
        # delta_M = softmax(out) - onehot(y), via the L1 kernel (Eq. 7 top)
        delta = fk.softmax_delta(out, y)
    else:
        loss_val = ref.mse(out, y)
        # out = sigmoid(a); dE/da = (out - y) * out (1 - out)
        delta = (out - y) * ref.sigmoid_grad_from_output(out)

    grads = [None] * len(params)
    # top layer M
    m = len(ws) - 1
    grads[2 * m] = fk.grad_w(delta, acts[m])
    grads[2 * m + 1] = jnp.mean(delta, axis=0)
    # recurse down: delta_i = h'(a_i) sum_j delta_j w_ji
    for m in range(len(ws) - 2, -1, -1):
        delta = fk.delta_backward(delta, ws[m + 1], acts[m + 1])
        grads[2 * m] = fk.grad_w(delta, acts[m])
        grads[2 * m + 1] = jnp.mean(delta, axis=0)
    return loss_val, grads


def make_step_fn(dims, loss: str, impl: str):
    """Flat-signature function for AOT lowering.

    fn(w0, b0, ..., wM, bM, x, y) -> (loss, g_w0, g_b0, ..., g_wM, g_bM)

    The flat positional signature is what the Rust runtime marshals
    (manifest lists the argument order explicitly).
    """
    nparams = 2 * (len(dims) - 1)
    grad_fn = (
        loss_and_grads_manual if impl == "pallas" else loss_and_grads_autodiff
    )

    def fn(*args):
        params = list(args[:nparams])
        x, y = args[nparams], args[nparams + 1]
        val, grads = grad_fn(params, x, y, loss)
        return (val, *grads)

    return fn


def make_forward_fn(dims, loss: str):
    """fn(w0, b0, ..., x) -> (out,) — inference-only artifact."""
    nparams = 2 * (len(dims) - 1)

    def fn(*args):
        params = list(args[:nparams])
        x = args[nparams]
        return (forward_jnp(params, x, loss),)

    return fn


def arg_specs(dims, batch, loss: str, with_y=True):
    """ShapeDtypeStructs matching make_step_fn's flat signature."""
    specs = []
    names = []
    for m in range(len(dims) - 1):
        specs.append(jax.ShapeDtypeStruct((dims[m], dims[m + 1]), jnp.float32))
        names.append(f"w{m}")
        specs.append(jax.ShapeDtypeStruct((dims[m + 1],), jnp.float32))
        names.append(f"b{m}")
    specs.append(jax.ShapeDtypeStruct((batch, dims[0]), jnp.float32))
    names.append("x")
    if with_y:
        if loss == "xent":
            specs.append(jax.ShapeDtypeStruct((batch,), jnp.int32))
        else:
            specs.append(jax.ShapeDtypeStruct((batch, dims[-1]), jnp.float32))
        names.append("y")
    return specs, names
