"""Layer-1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes (including non-block-multiple, degenerate dims)
and block sizes; every kernel must match ref.* to float32 tolerance.
This is the CORE correctness signal for the kernel layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fused_layer as fk
from compile.kernels import ref

DIM = st.integers(min_value=1, max_value=70)
BLK = st.sampled_from([1, 2, 3, 8, 16, 128])
SEED = st.integers(min_value=0, max_value=2**31 - 1)


def _rand(key, *shape, scale=1.0):
    return scale * jax.random.normal(key, shape, jnp.float32)


def _keys(seed, n):
    return jax.random.split(jax.random.PRNGKey(seed), n)


@settings(max_examples=25, deadline=None)
@given(b=DIM, i=DIM, o=DIM, bm=BLK, bn=BLK, bk=BLK, seed=SEED)
def test_dense_sigmoid_matches_ref(b, i, o, bm, bn, bk, seed):
    kx, kw, kb = _keys(seed, 3)
    x, w, bias = _rand(kx, b, i), _rand(kw, i, o), _rand(kb, o)
    got = fk.dense_sigmoid(x, w, bias, bm=bm, bn=bn, bk=bk)
    want = ref.dense_sigmoid(x, w, bias)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(b=DIM, i=DIM, o=DIM, bm=BLK, bn=BLK, bk=BLK, seed=SEED)
def test_dense_linear_matches_ref(b, i, o, bm, bn, bk, seed):
    kx, kw, kb = _keys(seed, 3)
    x, w, bias = _rand(kx, b, i), _rand(kw, i, o), _rand(kb, o)
    got = fk.dense_linear(x, w, bias, bm=bm, bn=bn, bk=bk)
    want = ref.dense_linear(x, w, bias)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(b=DIM, i=DIM, o=DIM, bm=BLK, bn=BLK, bk=BLK, seed=SEED)
def test_delta_backward_matches_ref(b, i, o, bm, bn, bk, seed):
    kd, kw, kz = _keys(seed, 3)
    delta, w = _rand(kd, b, o), _rand(kw, i, o)
    z = jax.nn.sigmoid(_rand(kz, b, i))  # activations live in (0,1)
    got = fk.delta_backward(delta, w, z, bm=bm, bn=bn, bk=bk)
    want = ref.delta_backward(delta, w, z)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(b=DIM, i=DIM, o=DIM, bm=BLK, bn=BLK, bk=BLK, seed=SEED)
def test_grad_w_matches_ref(b, i, o, bm, bn, bk, seed):
    kd, kz = _keys(seed, 2)
    delta, z = _rand(kd, b, o), _rand(kz, b, i)
    got = fk.grad_w(delta, z, bm=bm, bn=bn, bk=bk)
    want = ref.grad_w(delta, z)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(b=DIM, i=DIM, o=DIM, seed=SEED,
       eta=st.floats(min_value=1e-4, max_value=2.0))
def test_sgd_apply_matches_ref(b, i, o, seed, eta):
    kd, kz, kw = _keys(seed, 3)
    delta, z, w = _rand(kd, b, o), _rand(kz, b, i), _rand(kw, i, o)
    got = fk.sgd_apply(w, delta, z, eta)
    want = ref.sgd_apply(w, delta, z, eta)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_blocks_larger_than_dims():
    """Default 128-blocks on tiny inputs must still be exact."""
    k = jax.random.PRNGKey(7)
    x, w, b = _rand(k, 2, 3), _rand(k, 3, 4), _rand(k, 4)
    np.testing.assert_allclose(
        fk.dense_sigmoid(x, w, b), ref.dense_sigmoid(x, w, b),
        rtol=1e-5, atol=1e-6,
    )


def test_sigmoid_extreme_preactivations_stable():
    """Assumption 3 units must not overflow for large |a|."""
    a = jnp.array([[-120.0, -30.0, 0.0, 30.0, 120.0]], jnp.float32)
    x = jnp.ones((1, 1), jnp.float32)
    w = a  # 1x5, so x @ w = a
    b = jnp.zeros((5,), jnp.float32)
    z = fk.dense_sigmoid(x, w, b)
    assert np.all(np.isfinite(np.asarray(z)))
    np.testing.assert_allclose(
        np.asarray(z)[0, [0, 2, 4]], [0.0, 0.5, 1.0], atol=1e-6
    )


def test_grad_w_is_batch_mean():
    """grad_w must divide by the batch size (Eq. 3 is a mean objective)."""
    b, i, o = 6, 3, 2
    delta = jnp.ones((b, o), jnp.float32)
    z = jnp.ones((b, i), jnp.float32)
    got = fk.grad_w(delta, z)
    np.testing.assert_allclose(got, np.ones((i, o)), rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(b=DIM, c=st.integers(min_value=2, max_value=50), bm=BLK, seed=SEED)
def test_softmax_delta_matches_ref(b, c, bm, seed):
    kl, ky = _keys(seed, 2)
    logits = _rand(kl, b, c, scale=3.0)
    y = jax.random.randint(ky, (b,), 0, c)
    got = fk.softmax_delta(logits, y, bm=bm)
    want = jax.nn.softmax(logits) - jax.nn.one_hot(y, c, dtype=jnp.float32)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_softmax_delta_rows_sum_to_zero_and_stable():
    logits = jnp.array([[1e4, 0.0, -1e4], [0.0, 0.0, 0.0]], jnp.float32)
    y = jnp.array([0, 2], jnp.int32)
    d = np.asarray(fk.softmax_delta(logits, y))
    assert np.all(np.isfinite(d))
    np.testing.assert_allclose(d.sum(axis=1), 0.0, atol=1e-6)
    # saturated row: softmax ≈ onehot(0), true class 0 → delta ≈ 0
    np.testing.assert_allclose(d[0], 0.0, atol=1e-6)


def test_delta_backward_zero_activation_kills_flow():
    """h'(a)=z(1-z): saturated units (z=0 or 1) must pass no error."""
    delta = jnp.ones((4, 5), jnp.float32)
    w = jnp.ones((3, 5), jnp.float32)
    z = jnp.concatenate(
        [jnp.zeros((4, 1)), jnp.ones((4, 1)), 0.5 * jnp.ones((4, 1))], axis=1
    ).astype(jnp.float32)
    out = np.asarray(fk.delta_backward(delta, w, z))
    np.testing.assert_allclose(out[:, 0], 0.0, atol=1e-7)
    np.testing.assert_allclose(out[:, 1], 0.0, atol=1e-7)
    np.testing.assert_allclose(out[:, 2], 5 * 0.25, rtol=1e-6)
