"""AOT pipeline tests: HLO-text artifacts + manifest integrity.

Builds the tiny artifacts into a tmp dir and checks the interchange
contract the Rust runtime depends on: parseable HLO text (ENTRY present,
no serialized-proto path), manifest shapes matching model.arg_specs, and
numeric equivalence of the lowered computation to the eager model.
"""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model

PY_DIR = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    entries = {}
    for name in ["tiny", "tiny_pallas", "tiny_mse", "tiny_fwd"]:
        cfg = {**aot.CONFIGS, **aot.FORWARD_CONFIGS}[name]
        entries[name] = aot.build_one(name, cfg, str(out))
    with open(out / "manifest.json", "w") as f:
        json.dump({"format": 1, "artifacts": entries}, f)
    return out, entries


def test_hlo_text_format(built):
    out, entries = built
    for name, e in entries.items():
        text = (out / e["file"]).read_text()
        assert "ENTRY" in text, f"{name}: not HLO text"
        assert "HloModule" in text
        # return_tuple contract: the root is a tuple the rust side unpacks
        assert "tuple(" in text or ") tuple" in text


def test_manifest_shapes_match_arg_specs(built):
    _, entries = built
    e = entries["tiny"]
    specs, names = model.arg_specs(e["layer_dims"], e["batch"], e["loss"])
    assert [i["name"] for i in e["inputs"]] == names
    for i, s in zip(e["inputs"], specs):
        assert tuple(i["shape"]) == s.shape
    assert e["outputs"][0]["name"] == "loss"
    assert len(e["outputs"]) == 1 + 2 * (len(e["layer_dims"]) - 1)


def test_manifest_grad_shapes_mirror_params(built):
    _, entries = built
    e = entries["tiny"]
    dims = e["layer_dims"]
    outs = {o["name"]: o["shape"] for o in e["outputs"]}
    for m in range(len(dims) - 1):
        assert outs[f"g_w{m}"] == [dims[m], dims[m + 1]]
        assert outs[f"g_b{m}"] == [dims[m + 1]]


def test_lowered_computation_matches_eager(built):
    """Execute the lowered tiny step through jax and compare to eager."""
    e = {**aot.CONFIGS}["tiny"]
    fn = model.make_step_fn(e["dims"], e["loss"], e["impl"])
    specs, _ = model.arg_specs(e["dims"], e["batch"], e["loss"])
    compiled = jax.jit(fn).lower(*specs).compile()

    key = jax.random.PRNGKey(0)
    params = model.init_params(key, e["dims"])
    x = jax.random.normal(key, (e["batch"], e["dims"][0]), jnp.float32)
    y = jax.random.randint(key, (e["batch"],), 0, e["dims"][-1])
    got = compiled(*params, x, y)
    want = fn(*params, x, y)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=1e-5, atol=1e-6)


def test_pallas_and_jnp_artifacts_agree(built):
    """tiny and tiny_pallas lower different impls of the same math."""
    cfg = aot.CONFIGS["tiny"]
    fn_jnp = model.make_step_fn(cfg["dims"], cfg["loss"], "jnp")
    fn_pl = model.make_step_fn(cfg["dims"], cfg["loss"], "pallas")
    key = jax.random.PRNGKey(1)
    params = model.init_params(key, cfg["dims"])
    x = jax.random.normal(key, (cfg["batch"], cfg["dims"][0]), jnp.float32)
    y = jax.random.randint(key, (cfg["batch"],), 0, cfg["dims"][-1])
    a = fn_jnp(*params, x, y)
    b = fn_pl(*params, x, y)
    for u, v in zip(a, b):
        np.testing.assert_allclose(u, v, rtol=1e-4, atol=1e-6)


def test_cli_only_and_manifest_merge(tmp_path):
    """--only builds are incremental: the manifest merges, not replaces."""
    env = {**os.environ, "PYTHONPATH": PY_DIR}
    for only in ["tiny", "tiny_fwd"]:
        r = subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out", str(tmp_path),
             "--only", only],
            cwd=PY_DIR, env=env, capture_output=True, text=True,
        )
        assert r.returncode == 0, r.stderr
    with open(tmp_path / "manifest.json") as f:
        man = json.load(f)
    assert set(man["artifacts"]) == {"tiny", "tiny_fwd"}
    assert man["format"] == 1


def test_cli_rejects_unknown_artifact(tmp_path):
    env = {**os.environ, "PYTHONPATH": PY_DIR}
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(tmp_path),
         "--only", "nope"],
        cwd=PY_DIR, env=env, capture_output=True, text=True,
    )
    assert r.returncode == 1


def test_registry_paper_configs_present():
    """The registry must cover the paper's two workloads + the e2e driver."""
    assert "timit_scaled" in aot.CONFIGS
    assert "imagenet_scaled" in aot.CONFIGS
    assert "e2e_100m" in aot.CONFIGS
    t = aot.CONFIGS["timit_scaled"]
    assert len(t["dims"]) == 8, "TIMIT: 6 hidden layers (paper §6.1)"
    assert t["dims"][0] == 360 and t["dims"][-1] == 2001
    i = aot.CONFIGS["imagenet_scaled"]
    assert len(i["dims"]) == 5, "ImageNet: 3 hidden layers (paper §6.1)"
    assert i["dims"][-1] == 1000
    e = aot.CONFIGS["e2e_100m"]
    n = sum(e["dims"][m] * e["dims"][m + 1] + e["dims"][m + 1]
            for m in range(len(e["dims"]) - 1))
    assert 80e6 < n < 120e6, f"e2e artifact must be ~100M params, got {n}"
