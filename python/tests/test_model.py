"""Layer-2 correctness: the JAX model (model.py).

* manual layerwise backprop (Eq. 6/7, Pallas kernels) == jax autodiff
* gradients == finite differences on a tiny network
* SGD on the objective actually descends
* flat-signature step fn matches the pytree API
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

DIMS = [9, 12, 7, 5]
BATCH = 6


def _problem(seed=0, loss="xent", dims=DIMS, batch=BATCH):
    key = jax.random.PRNGKey(seed)
    kp, kx, ky = jax.random.split(key, 3)
    params = model.init_params(kp, dims)
    x = jax.random.normal(kx, (batch, dims[0]), jnp.float32)
    if loss == "xent":
        y = jax.random.randint(ky, (batch,), 0, dims[-1])
    else:
        y = jax.nn.one_hot(
            jax.random.randint(ky, (batch,), 0, dims[-1]), dims[-1]
        ).astype(jnp.float32)
    return params, x, y


@pytest.mark.parametrize("loss", ["xent", "mse"])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_manual_matches_autodiff(loss, seed):
    params, x, y = _problem(seed, loss)
    l_a, g_a = model.loss_and_grads_autodiff(params, x, y, loss)
    l_m, g_m = model.loss_and_grads_manual(params, x, y, loss)
    np.testing.assert_allclose(l_a, l_m, rtol=1e-5)
    assert len(g_a) == len(g_m) == 2 * (len(DIMS) - 1)
    for a, m in zip(g_a, g_m):
        np.testing.assert_allclose(a, m, rtol=1e-4, atol=1e-6)


@pytest.mark.parametrize("loss", ["xent", "mse"])
def test_autodiff_matches_finite_differences(loss):
    dims = [4, 5, 3]
    params, x, y = _problem(3, loss, dims=dims, batch=4)
    _, grads = model.loss_and_grads_autodiff(params, x, y, loss)
    eps = 1e-3
    # spot-check a handful of coordinates in every parameter tensor
    for pi, p in enumerate(params):
        flat = np.asarray(p).ravel()
        for ci in range(0, flat.size, max(1, flat.size // 3)):
            bump = np.zeros_like(flat)
            bump[ci] = eps
            pp = [q if qi != pi else (q + bump.reshape(q.shape))
                  for qi, q in enumerate(params)]
            pm = [q if qi != pi else (q - bump.reshape(q.shape))
                  for qi, q in enumerate(params)]
            fd = (model.objective(pp, x, y, loss)
                  - model.objective(pm, x, y, loss)) / (2 * eps)
            got = np.asarray(grads[pi]).ravel()[ci]
            np.testing.assert_allclose(got, fd, rtol=2e-2, atol=2e-4)


@pytest.mark.parametrize("loss", ["xent", "mse"])
def test_sgd_descends(loss):
    params, x, y = _problem(5, loss)
    losses = []
    for _ in range(30):
        l, g = model.loss_and_grads_autodiff(params, x, y, loss)
        losses.append(float(l))
        params = [p - 0.5 * gi for p, gi in zip(params, g)]
    assert losses[-1] < losses[0] * 0.8, losses


def test_step_fn_flat_signature():
    params, x, y = _problem(0, "xent")
    fn = model.make_step_fn(DIMS, "xent", "jnp")
    out = fn(*params, x, y)
    l_ref, g_ref = model.loss_and_grads_autodiff(params, x, y, "xent")
    assert len(out) == 1 + len(params)
    np.testing.assert_allclose(out[0], l_ref, rtol=1e-6)
    for o, g in zip(out[1:], g_ref):
        np.testing.assert_allclose(o, g, rtol=1e-6)


def test_step_fn_pallas_impl():
    params, x, y = _problem(1, "xent")
    fn = model.make_step_fn(DIMS, "xent", "pallas")
    out = fn(*params, x, y)
    l_ref, g_ref = model.loss_and_grads_autodiff(params, x, y, "xent")
    np.testing.assert_allclose(out[0], l_ref, rtol=1e-5)
    for o, g in zip(out[1:], g_ref):
        np.testing.assert_allclose(o, g, rtol=1e-4, atol=1e-6)


def test_forward_fn():
    params, x, _ = _problem(2, "xent")
    fn = model.make_forward_fn(DIMS, "xent")
    (out,) = fn(*params, x)
    want = model.forward_jnp(params, x, "xent")
    np.testing.assert_allclose(out, want, rtol=1e-6)
    assert out.shape == (BATCH, DIMS[-1])


def test_arg_specs_order_and_shapes():
    specs, names = model.arg_specs(DIMS, BATCH, "xent")
    assert names == ["w0", "b0", "w1", "b1", "w2", "b2", "x", "y"]
    assert specs[0].shape == (9, 12) and specs[1].shape == (12,)
    assert specs[-2].shape == (BATCH, 9)
    assert specs[-1].shape == (BATCH,) and specs[-1].dtype == jnp.int32
    specs_mse, _ = model.arg_specs(DIMS, BATCH, "mse")
    assert specs_mse[-1].shape == (BATCH, DIMS[-1])


def test_init_params_glorot_scale():
    params = model.init_params(jax.random.PRNGKey(0), [100, 50, 10])
    w0 = np.asarray(params[0])
    limit = np.sqrt(6.0 / 150)
    assert np.abs(w0).max() <= limit + 1e-6
    assert w0.std() > 0.3 * limit  # actually spread out, not degenerate
    assert np.all(np.asarray(params[1]) == 0)


@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    hidden=st.lists(st.integers(2, 12), min_size=1, max_size=4),
)
def test_manual_matches_autodiff_random_architectures(seed, hidden):
    dims = [7] + hidden + [4]
    params, x, y = _problem(seed, "xent", dims=dims, batch=3)
    l_a, g_a = model.loss_and_grads_autodiff(params, x, y, "xent")
    l_m, g_m = model.loss_and_grads_manual(params, x, y, "xent")
    np.testing.assert_allclose(l_a, l_m, rtol=1e-5)
    for a, m in zip(g_a, g_m):
        np.testing.assert_allclose(a, m, rtol=1e-4, atol=1e-6)


def test_objective_matches_ref_composition():
    """Layer-2 objective is exactly ref-kernel composition (Eq. 3)."""
    params, x, y = _problem(4, "xent")
    ws, bs = params[0::2], params[1::2]
    z = x
    for m in range(len(ws) - 1):
        z = ref.dense_sigmoid(z, ws[m], bs[m])
    logits = ref.dense_linear(z, ws[-1], bs[-1])
    np.testing.assert_allclose(
        model.objective(params, x, y, "xent"),
        ref.softmax_xent(logits, y),
        rtol=1e-6,
    )
