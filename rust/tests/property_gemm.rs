//! Property suite for the packed GEMM backend (§Perf pass 5): all three
//! kernel orientations and every fused epilogue are driven against a
//! naive f32 oracle over adversarial shapes — empty dims, single
//! elements, non-multiples of the MR/NR/KC blocking, k below the
//! microkernel's unroll width, shapes crossing every cache-block
//! boundary — and the intra-op thread split is pinned to be bitwise
//! invariant (1 thread vs T threads must agree to the last bit).
//!
//! §Perf pass 7 extends the suite across the microkernel dispatch seam:
//! the full grid re-runs under **every** path the host supports (forced
//! via the scoped `dispatch::with_selection` override, in both f32 and
//! bf16 pack modes), each SIMD path is compared to the forced-scalar
//! result under the documented FMA tolerance, and the thread split is
//! pinned bitwise per path. The scalar path itself is textually the
//! pass-5 kernel; CI additionally runs this whole suite (and the
//! driver/transport equivalence stacks) with `SSPDNN_GEMM_KERNEL=scalar`
//! so the scalar leg stays pinned to the pre-dispatch engine.

use sspdnn::tensor::dispatch::{self, KernelPath, Selection};
use sspdnn::tensor::{
    gemm_ep, gemm_nt_ep, gemm_tn_ep, Epilogue, GemmPool, Matrix, Unary,
};
use sspdnn::util::Pcg64;

/// Naive row-major oracle: C[i,j] = Σ_p A[i,p]·B[p,j], f32 accumulation
/// in ascending p — the same per-element order the packed kernels use.
fn naive(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.rows(), b.cols());
    for i in 0..a.rows() {
        for j in 0..b.cols() {
            let mut s = 0.0f32;
            for p in 0..a.cols() {
                s += a.at(i, p) * b.at(p, j);
            }
            *c.at_mut(i, j) = s;
        }
    }
    c
}

fn assert_close(got: &Matrix, want: &Matrix, tol: f32, what: &str) {
    assert_eq!(got.rows(), want.rows(), "{what} rows");
    assert_eq!(got.cols(), want.cols(), "{what} cols");
    let d = got.max_abs_diff(want);
    assert!(d <= tol, "{what}: max diff {d} > {tol}");
}

/// Adversarial shape grid: zeros, ones, unroll-width edges (k < 4),
/// MR/NR (8) edges, KC (256) / NC (256) / MC (64) crossings.
const SHAPES: &[(usize, usize, usize)] = &[
    (0, 0, 0),
    (0, 5, 3),
    (5, 0, 3),
    (5, 3, 0),
    (1, 1, 1),
    (1, 3, 1),
    (3, 1, 9),
    (7, 2, 5),
    (8, 8, 8),
    (9, 7, 17),
    (16, 33, 8),
    (63, 64, 65),
    (64, 256, 64),
    (65, 257, 31),
    (13, 513, 19),
    (3, 5, 258),
    (70, 300, 130),
    (129, 5, 7),
];

#[test]
fn gemm_all_shapes_match_oracle() {
    let mut rng = Pcg64::new(100);
    for &(m, k, n) in SHAPES {
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let b = Matrix::randn(k, n, 1.0, &mut rng);
        let mut c = Matrix::zeros(m, n);
        c.fill(f32::NAN); // Overwrite must not read stale C
        gemm_ep(&a, &b, &mut c, Epilogue::Overwrite);
        let tol = 1e-4 * (k as f32).max(1.0).sqrt() * 4.0;
        assert_close(&c, &naive(&a, &b), tol, &format!("gemm {m}x{k}x{n}"));
    }
}

#[test]
fn gemm_nt_all_shapes_match_oracle() {
    let mut rng = Pcg64::new(101);
    for &(m, k, n) in SHAPES {
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let b = Matrix::randn(n, k, 1.0, &mut rng); // B is n×k, used as Bᵀ
        let mut c = Matrix::zeros(m, n);
        c.fill(f32::NAN);
        gemm_nt_ep(&a, &b, &mut c, Epilogue::Overwrite);
        let mut bt = Matrix::zeros(k, n);
        b.transpose_into(&mut bt);
        let tol = 1e-4 * (k as f32).max(1.0).sqrt() * 4.0;
        assert_close(&c, &naive(&a, &bt), tol, &format!("gemm_nt {m}x{k}x{n}"));
    }
}

#[test]
fn gemm_tn_all_shapes_match_oracle() {
    let mut rng = Pcg64::new(102);
    for &(m, k, n) in SHAPES {
        let a = Matrix::randn(k, m, 1.0, &mut rng); // A is k×m, used as Aᵀ
        let b = Matrix::randn(k, n, 1.0, &mut rng);
        let mut c = Matrix::zeros(m, n);
        c.fill(f32::NAN);
        gemm_tn_ep(&a, &b, &mut c, Epilogue::Overwrite);
        let mut at = Matrix::zeros(m, k);
        a.transpose_into(&mut at);
        let tol = 1e-4 * (k as f32).max(1.0).sqrt() * 4.0;
        assert_close(&c, &naive(&at, &b), tol, &format!("gemm_tn {m}x{k}x{n}"));
    }
}

#[test]
fn accumulate_epilogue_adds_to_existing() {
    let mut rng = Pcg64::new(103);
    for &(m, k, n) in &[(5, 3, 7), (17, 65, 9), (64, 256, 33)] {
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let b = Matrix::randn(k, n, 1.0, &mut rng);
        let mut c = Matrix::from_fn(m, n, |r, s| (r + s) as f32 * 0.25);
        let before = c.clone();
        gemm_ep(&a, &b, &mut c, Epilogue::Accumulate);
        // exact contract: C = before + (overwrite result), elementwise
        let mut prod = Matrix::zeros(m, n);
        gemm_ep(&a, &b, &mut prod, Epilogue::Overwrite);
        for i in 0..m * n {
            let want = before.data()[i] + prod.data()[i];
            assert_eq!(c.data()[i], want, "accumulate at flat index {i}");
        }
    }
}

#[test]
fn fused_epilogues_bitwise_match_unfused_all_orientations() {
    let mut rng = Pcg64::new(104);
    for &(m, k, n) in &[(1, 1, 1), (9, 7, 17), (63, 300, 65), (13, 513, 19)] {
        // --- BiasUnary on gemm ---
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let b = Matrix::randn(k, n, 1.0, &mut rng);
        let bias: Vec<f32> = (0..n).map(|i| (i as f32) * 0.1 - 0.3).collect();
        for f in [Unary::Identity, Unary::Sigmoid, Unary::Tanh, Unary::Relu] {
            let mut fused = Matrix::zeros(m, n);
            gemm_ep(&a, &b, &mut fused, Epilogue::BiasUnary { bias: &bias, f });
            let mut want = Matrix::zeros(m, n);
            gemm_ep(&a, &b, &mut want, Epilogue::Overwrite);
            for r in 0..m {
                for (v, bv) in want.row_mut(r).iter_mut().zip(&bias) {
                    *v = f.apply(*v + bv);
                }
            }
            assert_eq!(fused, want, "bias+{f:?} {m}x{k}x{n}");
        }

        // --- MaskDeriv on gemm_nt ---
        let bt = Matrix::randn(n, k, 1.0, &mut rng);
        let z = Matrix::from_fn(m, n, |r, c| {
            Unary::Sigmoid.apply(((r * 31 + c * 7) % 13) as f32 * 0.1 - 0.6)
        });
        let mut fused = Matrix::zeros(m, n);
        let ep = Epilogue::MaskDeriv {
            z: &z,
            f: Unary::Sigmoid,
        };
        gemm_nt_ep(&a, &bt, &mut fused, ep);
        let mut want = Matrix::zeros(m, n);
        gemm_nt_ep(&a, &bt, &mut want, Epilogue::Overwrite);
        for (v, zv) in want.data_mut().iter_mut().zip(z.data()) {
            *v *= Unary::Sigmoid.deriv_from_output(*zv);
        }
        assert_eq!(fused, want, "mask {m}x{k}x{n}");

        // --- Scale on gemm_tn ---
        let at = Matrix::randn(k, m, 1.0, &mut rng);
        let bb = Matrix::randn(k, n, 1.0, &mut rng);
        let mut fused = Matrix::zeros(m, n);
        gemm_tn_ep(&at, &bb, &mut fused, Epilogue::Scale(1.0 / 50.0));
        let mut want = Matrix::zeros(m, n);
        gemm_tn_ep(&at, &bb, &mut want, Epilogue::Overwrite);
        want.scale(1.0 / 50.0);
        assert_eq!(fused, want, "scale {m}x{k}x{n}");
    }
}

#[test]
fn thread_split_is_bitwise_invariant() {
    // the pool splits rows into micro-panel bands; a C element's
    // k-accumulation is never subdivided, so every thread count must
    // produce identical bits — including at shapes that don't divide
    // evenly and shapes big enough to actually engage the parallel path
    let mut rng = Pcg64::new(105);
    for &(m, k, n) in &[
        (97, 200, 128),  // above PAR_MIN_FLOPS, m % MR != 0
        (256, 256, 256), // the bench shape
        (64, 300, 130),  // barely above the flops floor
        (9, 7, 17),      // tiny (serial fallback; must still match)
    ] {
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let b = Matrix::randn(k, n, 1.0, &mut rng);
        let bias: Vec<f32> = (0..n).map(|i| (i as f32) * 0.01).collect();
        let mut reference: Option<Matrix> = None;
        for threads in [1usize, 2, 3, 4, 7] {
            let mut pool = GemmPool::new(threads);
            let mut c = Matrix::zeros(m, n);
            let ep = Epilogue::BiasUnary {
                bias: &bias,
                f: Unary::Sigmoid,
            };
            pool.gemm(&a, &b, &mut c, ep);
            match &reference {
                None => reference = Some(c),
                Some(r) => {
                    assert_eq!(&c, r, "threads={threads} diverged {m}x{k}x{n}")
                }
            }
        }
    }
}

#[test]
fn thread_split_invariant_for_transposed_orientations() {
    let mut rng = Pcg64::new(106);
    let (m, k, n) = (128usize, 200usize, 96usize);
    let a = Matrix::randn(m, k, 1.0, &mut rng);
    let bt = Matrix::randn(n, k, 1.0, &mut rng);
    let mut c1 = Matrix::zeros(m, n);
    let mut c4 = Matrix::zeros(m, n);
    GemmPool::new(1).gemm_nt(&a, &bt, &mut c1, Epilogue::Overwrite);
    GemmPool::new(4).gemm_nt(&a, &bt, &mut c4, Epilogue::Overwrite);
    assert_eq!(c1, c4, "gemm_nt thread split");

    let at = Matrix::randn(k, m, 1.0, &mut rng);
    let b = Matrix::randn(k, n, 1.0, &mut rng);
    let mut d1 = Matrix::zeros(m, n);
    let mut d4 = Matrix::zeros(m, n);
    GemmPool::new(1).gemm_tn(&at, &b, &mut d1, Epilogue::Scale(0.02));
    GemmPool::new(4).gemm_tn(&at, &b, &mut d4, Epilogue::Scale(0.02));
    assert_eq!(d1, d4, "gemm_tn thread split");
}

#[test]
fn sparse_input_panels_match_dense_oracle() {
    // column-sparse A (whole features zero across the batch — the
    // sparse-LLC first-layer pattern the packing-time filter targets):
    // results must match the oracle and the thread split must hold
    let mut rng = Pcg64::new(107);
    let (m, k, n) = (64usize, 360usize, 128usize);
    let mut a = Matrix::from_fn(m, k, |_, _| rng.uniform_f32(0.05, 1.0));
    for r in 0..m {
        for p in 0..k {
            if p % 7 != 0 {
                *a.at_mut(r, p) = 0.0;
            }
        }
    }
    let b = Matrix::from_fn(k, n, |_, _| rng.uniform_f32(0.05, 1.0));
    let mut c = Matrix::zeros(m, n);
    gemm_ep(&a, &b, &mut c, Epilogue::Overwrite);
    assert_close(&c, &naive(&a, &b), 1e-3, "sparse gemm");
    let mut c4 = Matrix::zeros(m, n);
    GemmPool::new(4).gemm(&a, &b, &mut c4, Epilogue::Overwrite);
    assert_eq!(c, c4, "sparse thread split");
}

fn max_abs(m: &Matrix) -> f32 {
    m.data().iter().fold(0.0f32, |acc, v| acc.max(v.abs()))
}

/// Tolerance for f32 SIMD paths vs scalar: the only numeric difference
/// is FMA keeping the product unrounded before the add, bounded by
/// `|Δ| ≤ 16·k·ε·‖A‖∞·‖B‖∞` (a loose form of the standard γ_k bound;
/// observed differences sit orders of magnitude below it). Documented
/// in `rust/EXPERIMENTS.md` §Perf pass 7.
fn fma_tol(k: usize, amax: f32, bmax: f32) -> f32 {
    (k as f32).max(1.0) * f32::EPSILON * amax.max(1.0) * bmax.max(1.0) * 16.0
}

/// Tolerance vs the f32 oracle when operand panels are stored as bf16:
/// each pack rounds to 8 mantissa bits (≤2⁻⁸ relative per operand), so
/// per-element error random-walks as ~2⁻⁷·√k on unit-variance data.
fn bf16_tol(k: usize) -> f32 {
    0.05 * (k as f32).max(1.0).sqrt() + 0.2
}

#[test]
fn every_path_full_grid_matches_oracle_all_orientations() {
    // the full adversarial grid, all three orientations, every dispatch
    // path this host supports, in both pack storage modes
    let mut rng = Pcg64::new(110);
    for &(m, k, n) in SHAPES {
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let b = Matrix::randn(k, n, 1.0, &mut rng);
        let bt = {
            let mut t = Matrix::zeros(n, k);
            b.transpose_into(&mut t);
            t
        };
        let at = {
            let mut t = Matrix::zeros(k, m);
            a.transpose_into(&mut t);
            t
        };
        let want = naive(&a, &b);
        for &path in dispatch::available() {
            for bf16 in [false, true] {
                let sel = Selection::new(path, bf16);
                let tol = if bf16 {
                    bf16_tol(k)
                } else {
                    1e-4 * (k as f32).max(1.0).sqrt() * 4.0
                };
                let mut c = Matrix::zeros(m, n);
                c.fill(f32::NAN);
                dispatch::with_selection(sel, || {
                    gemm_ep(&a, &b, &mut c, Epilogue::Overwrite);
                });
                assert_close(&c, &want, tol, &format!("gemm[{sel}] {m}x{k}x{n}"));
                let mut c = Matrix::zeros(m, n);
                c.fill(f32::NAN);
                dispatch::with_selection(sel, || {
                    gemm_nt_ep(&a, &bt, &mut c, Epilogue::Overwrite);
                });
                assert_close(&c, &want, tol, &format!("gemm_nt[{sel}] {m}x{k}x{n}"));
                let mut c = Matrix::zeros(m, n);
                c.fill(f32::NAN);
                dispatch::with_selection(sel, || {
                    gemm_tn_ep(&at, &b, &mut c, Epilogue::Overwrite);
                });
                assert_close(&c, &want, tol, &format!("gemm_tn[{sel}] {m}x{k}x{n}"));
            }
        }
    }
}

#[test]
fn simd_paths_match_forced_scalar_within_fma_tolerance() {
    // direct scalar-vs-SIMD comparison, tighter than the oracle check:
    // the packed pipeline is shared, so only FMA contraction may differ
    let scalar = Selection::new(KernelPath::Scalar, false);
    for &path in dispatch::available() {
        if path == KernelPath::Scalar {
            continue;
        }
        let sel = Selection::new(path, false);
        let mut rng = Pcg64::new(111);
        for &(m, k, n) in
            &[(9, 7, 17), (63, 64, 65), (64, 256, 64), (13, 513, 19), (70, 300, 130)]
        {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            let mut cs = Matrix::zeros(m, n);
            dispatch::with_selection(scalar, || {
                gemm_ep(&a, &b, &mut cs, Epilogue::Overwrite);
            });
            let mut cv = Matrix::zeros(m, n);
            dispatch::with_selection(sel, || {
                gemm_ep(&a, &b, &mut cv, Epilogue::Overwrite);
            });
            let tol = fma_tol(k, max_abs(&a), max_abs(&b));
            assert_close(
                &cv,
                &cs,
                tol,
                &format!("{} vs scalar {m}x{k}x{n}", path.as_str()),
            );
        }
    }
}

#[test]
fn every_path_thread_split_bitwise_invariant() {
    // the bitwise 1-vs-T pin must hold per dispatch path and pack mode:
    // bands share packed B panels and never subdivide a k-accumulation
    let mut rng = Pcg64::new(112);
    let (m, k, n) = (97usize, 200usize, 128usize);
    let a = Matrix::randn(m, k, 1.0, &mut rng);
    let b = Matrix::randn(k, n, 1.0, &mut rng);
    for &path in dispatch::available() {
        for bf16 in [false, true] {
            let sel = Selection::new(path, bf16);
            let mut reference: Option<Matrix> = None;
            for threads in [1usize, 4, 7] {
                let mut pool = GemmPool::new(threads)
                    .with_kernel(Some(sel))
                    .with_par_min_flops(Some(0));
                let mut c = Matrix::zeros(m, n);
                pool.gemm(&a, &b, &mut c, Epilogue::Overwrite);
                match &reference {
                    None => reference = Some(c),
                    Some(r) => assert_eq!(
                        &c, r,
                        "threads={threads} diverged on {sel} {m}x{k}x{n}"
                    ),
                }
            }
        }
    }
}

#[test]
fn fused_epilogues_bitwise_match_unfused_on_every_path() {
    // the SIMD epilogue helpers (row fold/copy/scale) are elementwise
    // IEEE ops, so fused == unfused must stay *bitwise* per path
    let mut rng = Pcg64::new(113);
    let (m, k, n) = (63usize, 300usize, 65usize);
    let a = Matrix::randn(m, k, 1.0, &mut rng);
    let b = Matrix::randn(k, n, 1.0, &mut rng);
    let bias: Vec<f32> = (0..n).map(|i| (i as f32) * 0.1 - 0.3).collect();
    for &path in dispatch::available() {
        for bf16 in [false, true] {
            let sel = Selection::new(path, bf16);
            dispatch::with_selection(sel, || {
                let mut fused = Matrix::zeros(m, n);
                let ep = Epilogue::BiasUnary {
                    bias: &bias,
                    f: Unary::Sigmoid,
                };
                gemm_ep(&a, &b, &mut fused, ep);
                let mut want = Matrix::zeros(m, n);
                gemm_ep(&a, &b, &mut want, Epilogue::Overwrite);
                for r in 0..m {
                    for (v, bv) in want.row_mut(r).iter_mut().zip(&bias) {
                        *v = Unary::Sigmoid.apply(*v + bv);
                    }
                }
                assert_eq!(fused, want, "bias+sigmoid fused on {sel}");

                let mut acc = Matrix::from_fn(m, n, |r, s| (r + s) as f32 * 0.25);
                let before = acc.clone();
                gemm_ep(&a, &b, &mut acc, Epilogue::Accumulate);
                let mut prod = Matrix::zeros(m, n);
                gemm_ep(&a, &b, &mut prod, Epilogue::Overwrite);
                for i in 0..m * n {
                    assert_eq!(
                        acc.data()[i],
                        before.data()[i] + prod.data()[i],
                        "accumulate on {sel} at flat index {i}"
                    );
                }
            });
        }
    }
}

#[test]
fn k_zero_with_epilogues() {
    // k = 0: the product is all-zero, and epilogues still apply
    let a = Matrix::zeros(4, 0);
    let b = Matrix::zeros(0, 6);
    let bias: Vec<f32> = (0..6).map(|i| i as f32 - 2.0).collect();
    let mut c = Matrix::zeros(4, 6);
    c.fill(99.0);
    let ep = Epilogue::BiasUnary {
        bias: &bias,
        f: Unary::Relu,
    };
    gemm_ep(&a, &b, &mut c, ep);
    for r in 0..4 {
        for j in 0..6 {
            assert_eq!(c.at(r, j), bias[j].max(0.0), "relu(0 + bias)");
        }
    }
}
