//! Integration tests across the AOT boundary: the PJRT engine running
//! JAX-lowered HLO artifacts must agree with the native Rust engine.
//!
//! These run only when `make artifacts` has produced artifacts/; they are
//! skipped (with a notice) otherwise so `cargo test` works pre-build.

use sspdnn::config::ExperimentConfig;
use sspdnn::coordinator::{
    build_dataset, run_experiment_on, DriverOptions, EngineKind, GradEngine,
    NativeEngine,
};
use sspdnn::nn::{Activation, Labels, Loss, Mlp, ParamSet};
use sspdnn::runtime::{Manifest, PjrtEngine};
use sspdnn::tensor::Matrix;
use sspdnn::util::Pcg64;

fn manifest() -> Option<Manifest> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(Manifest::load(&dir).expect("manifest parses"))
    } else {
        eprintln!("artifacts/ missing; run `make artifacts` — skipping");
        None
    }
}

fn problem(dims: &[usize], batch: usize, seed: u64) -> (ParamSet, Matrix, Labels) {
    let mut rng = Pcg64::new(seed);
    let p = ParamSet::glorot(dims, &mut rng);
    let x = Matrix::randn(batch, dims[0], 1.0, &mut rng);
    let y = Labels::Class(
        (0..batch)
            .map(|_| rng.below(*dims.last().unwrap()) as u32)
            .collect(),
    );
    (p, x, y)
}

#[test]
fn pjrt_tiny_matches_native_engine() {
    let Some(man) = manifest() else { return };
    let spec = man.get("tiny").expect("tiny artifact");
    let mut pjrt = PjrtEngine::load(spec).expect("compile tiny");
    let mlp = Mlp::new(spec.layer_dims.clone(), Activation::Sigmoid, Loss::Xent);
    let mut native = NativeEngine::new(mlp);

    for seed in 0..3 {
        let (p, x, y) = problem(&spec.layer_dims, spec.batch, seed);
        let (l_p, g_p) = pjrt.loss_and_grads(&p, &x, &y);
        let (l_n, g_n) = native.loss_and_grads(&p, &x, &y);
        assert!(
            (l_p - l_n).abs() < 1e-4 * (1.0 + l_n.abs()),
            "loss mismatch: pjrt {l_p} native {l_n}"
        );
        for (m, (a, b)) in g_p.layers.iter().zip(&g_n.layers).enumerate() {
            let d = a.w.max_abs_diff(&b.w);
            assert!(d < 1e-4, "layer {m} grad diff {d}");
            for (x1, x2) in a.b.iter().zip(&b.b) {
                assert!((x1 - x2).abs() < 1e-4, "layer {m} bias grads");
            }
        }
    }
}

#[test]
fn pjrt_pallas_artifact_matches_jnp_artifact() {
    // the layerwise manual-backprop (pallas) artifact and the autodiff
    // (jnp) artifact must be numerically interchangeable — the Layer-1
    // kernels really implement Eq. (6)/(7)
    let Some(man) = manifest() else { return };
    let jnp = man.get("tiny").expect("tiny");
    let pallas = man.get("tiny_pallas").expect("tiny_pallas");
    assert_eq!(jnp.layer_dims, pallas.layer_dims);
    let mut e_jnp = PjrtEngine::load(jnp).unwrap();
    let mut e_pal = PjrtEngine::load(pallas).unwrap();
    let (p, x, y) = problem(&jnp.layer_dims, jnp.batch, 7);
    let (l1, g1) = e_jnp.loss_and_grads(&p, &x, &y);
    let (l2, g2) = e_pal.loss_and_grads(&p, &x, &y);
    assert!((l1 - l2).abs() < 1e-4, "{l1} vs {l2}");
    for (a, b) in g1.layers.iter().zip(&g2.layers) {
        assert!(a.w.max_abs_diff(&b.w) < 1e-4);
    }
}

#[test]
fn pjrt_mse_artifact_runs() {
    let Some(man) = manifest() else { return };
    let spec = man.get("tiny_mse").expect("tiny_mse");
    let mut engine = PjrtEngine::load(spec).unwrap();
    let mut rng = Pcg64::new(9);
    let p = ParamSet::glorot(&spec.layer_dims, &mut rng);
    let x = Matrix::randn(spec.batch, spec.layer_dims[0], 1.0, &mut rng);
    let out_dim = *spec.layer_dims.last().unwrap();
    let t = Matrix::from_fn(spec.batch, out_dim, |r, c| {
        if c == r % out_dim {
            1.0
        } else {
            0.0
        }
    });
    let y = Labels::Dense(t);
    let (loss, grads) = engine.loss_and_grads(&p, &x, &y);
    assert!(loss.is_finite() && loss > 0.0);
    assert!(grads.norm() > 0.0);

    // cross-check vs native MSE engine
    let mlp = Mlp::new(spec.layer_dims.clone(), Activation::Sigmoid, Loss::Mse);
    let mut native = NativeEngine::new(mlp);
    let (l_n, g_n) = native.loss_and_grads(&p, &x, &y);
    assert!((loss - l_n).abs() < 1e-4, "pjrt {loss} vs native {l_n}");
    for (a, b) in grads.layers.iter().zip(&g_n.layers) {
        assert!(a.w.max_abs_diff(&b.w) < 1e-4);
    }
}

#[test]
fn full_ssp_run_with_pjrt_engine_matches_native_run() {
    // determinism end-to-end: the same experiment driven by the PJRT
    // engine and the native engine must produce near-identical
    // trajectories (both compute the same math in f32).
    let Some(man) = manifest() else { return };
    let spec = man.get("tiny").unwrap();
    let mut cfg = ExperimentConfig::tiny();
    cfg.train.clocks = 8;
    cfg.train.batches_per_clock = 2;
    assert_eq!(cfg.model.dims, spec.layer_dims);
    assert_eq!(cfg.train.batch, spec.batch);
    let ds = build_dataset(&cfg);

    let native = run_experiment_on(
        &cfg,
        DriverOptions {
            per_batch_s: Some(0.02),
            ..DriverOptions::default()
        },
        &ds,
    );
    let pjrt_engine = PjrtEngine::load(spec).unwrap();
    let pjrt = run_experiment_on(
        &cfg,
        DriverOptions {
            per_batch_s: Some(0.02),
            engine: Some(EngineKind::Boxed(Box::new(pjrt_engine))),
            ..DriverOptions::default()
        },
        &ds,
    );
    assert_eq!(native.steps, pjrt.steps);
    let rel = (native.final_objective - pjrt.final_objective).abs()
        / native.final_objective.max(1e-9);
    assert!(
        rel < 5e-3,
        "final objectives diverged: native {} pjrt {}",
        native.final_objective,
        pjrt.final_objective
    );
    let d = native.final_params.dist_sq(&pjrt.final_params).sqrt()
        / native.final_params.norm();
    assert!(d < 5e-3, "final params diverged: rel dist {d}");
}
