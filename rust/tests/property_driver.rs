//! Driver equivalence + sweep determinism properties.
//!
//! D1. The zero-copy driver loop reproduces the pre-refactor allocating
//!     oracle (`run_experiment_alloc_*`) value-for-value — on the
//!     single-lock reference `Server` AND the sharded per-layer
//!     `ShardedServer`, across every consistency policy, with and
//!     without tracing. (The only bit divergence permitted anywhere is
//!     the sign of zero, which no comparison below distinguishes.)
//! D2. The zero-copy loop performs zero steady-state allocations: the
//!     audit armed after warmup observes no pool growth.
//! D3. A sweep's statistical content is bitwise identical at any thread
//!     budget, and each cell is exactly the driver run its derived seed
//!     describes.

use sspdnn::config::{ExperimentConfig, SweepConfig};
use sspdnn::coordinator::{
    build_dataset, run_experiment_alloc_on, run_experiment_alloc_with,
    run_experiment_on, run_experiment_with, run_sweep, DriverOptions,
    RunResult, SweepOptions,
};
use sspdnn::metrics;
use sspdnn::ssp::{Policy, ShardedServer};

fn tiny_cfg() -> ExperimentConfig {
    let mut c = ExperimentConfig::tiny();
    c.train.clocks = 10;
    c.train.batches_per_clock = 2;
    c
}

fn fast_opts() -> DriverOptions {
    DriverOptions {
        per_batch_s: Some(0.01),
        eval_samples: 128,
        ..DriverOptions::default()
    }
}

/// Value-equality over every deterministic field of two runs.
fn assert_runs_equal(a: &RunResult, b: &RunResult) {
    assert_eq!(a.final_params, b.final_params, "final params diverged");
    assert_eq!(a.final_objective, b.final_objective);
    assert_eq!(a.total_vtime, b.total_vtime);
    assert_eq!(a.steps, b.steps);
    assert_eq!(a.reads, b.reads);
    assert_eq!(a.messages, b.messages);
    assert_eq!(a.bytes, b.bytes);
    assert_eq!(a.congestion_events, b.congestion_events);
    assert_eq!(a.epsilon_rate, b.epsilon_rate);
    assert_eq!(a.barrier_wait_s, b.barrier_wait_s);
    assert_eq!(a.read_wait_s, b.read_wait_s);
    assert_eq!(a.compute_s, b.compute_s);
    assert_eq!(a.evals.len(), b.evals.len(), "eval curve length");
    for (x, y) in a.evals.iter().zip(&b.evals) {
        assert_eq!(x.vtime, y.vtime);
        assert_eq!(x.clock, y.clock);
        assert_eq!(x.objective, y.objective);
        assert_eq!(x.param_msd, y.param_msd);
        assert_eq!(x.layer_msd, y.layer_msd);
    }
    assert_eq!(a.clock_loss.len(), b.clock_loss.len());
    for (x, y) in a.clock_loss.iter().zip(&b.clock_loss) {
        // bit comparison: NaN (an index no worker reached) must match NaN
        assert_eq!(x.to_bits(), y.to_bits(), "clock loss diverged");
    }
}

#[test]
fn d1_zero_copy_matches_oracle_on_reference_server() {
    let cfg = tiny_cfg();
    let ds = build_dataset(&cfg);
    let zc = run_experiment_on(&cfg, fast_opts(), &ds);
    let oracle = run_experiment_alloc_on(&cfg, fast_opts(), &ds);
    assert_runs_equal(&zc, &oracle);
}

#[test]
fn d1_zero_copy_matches_oracle_on_sharded_server() {
    let cfg = tiny_cfg();
    let ds = build_dataset(&cfg);
    // strongest cross pairing: zero-copy loop on the sharded server vs
    // the allocating oracle on the reference server
    let zc = run_experiment_with(&cfg, fast_opts(), &ds, ShardedServer::new);
    let oracle = run_experiment_alloc_on(&cfg, fast_opts(), &ds);
    assert_runs_equal(&zc, &oracle);
    // ... and the sharded oracle agrees too
    let oracle_sharded =
        run_experiment_alloc_with(&cfg, fast_opts(), &ds, ShardedServer::new);
    assert_runs_equal(&zc, &oracle_sharded);
}

#[test]
fn d1_equivalence_holds_across_policies() {
    for policy in [
        Policy::Bsp,
        Policy::Ssp { staleness: 0 },
        Policy::Ssp { staleness: 8 },
        Policy::Async,
    ] {
        let mut cfg = tiny_cfg();
        cfg.train.clocks = 6;
        cfg.ssp.policy = policy;
        let ds = build_dataset(&cfg);
        let zc = run_experiment_on(&cfg, fast_opts(), &ds);
        let oracle = run_experiment_alloc_on(&cfg, fast_opts(), &ds);
        assert_runs_equal(&zc, &oracle);
    }
}

#[test]
fn d1_protocol_traces_are_identical() {
    let cfg = tiny_cfg();
    let ds = build_dataset(&cfg);
    let trace_opts = || DriverOptions {
        trace: true,
        ..fast_opts()
    };
    let zc = run_experiment_on(&cfg, trace_opts(), &ds);
    let oracle = run_experiment_alloc_on(&cfg, trace_opts(), &ds);
    let a = zc.trace.expect("zc trace").to_csv();
    let b = oracle.trace.expect("oracle trace").to_csv();
    assert_eq!(a, b, "event-for-event protocol trace must match");
}

#[test]
fn d2_steady_state_allocation_free_on_both_servers() {
    let mut cfg = tiny_cfg();
    cfg.train.clocks = 24;
    // keep the in-flight message population flat after warmup
    cfg.cluster.drop_prob = 0.0;
    cfg.cluster.straggler_prob = 0.0;
    let opts = || DriverOptions {
        warmup_clocks: 8,
        ..fast_opts()
    };
    let ds = build_dataset(&cfg);
    let reference = run_experiment_on(&cfg, opts(), &ds);
    assert_eq!(reference.steady_reallocs, 0, "reference server path");
    let sharded = run_experiment_with(&cfg, opts(), &ds, ShardedServer::new);
    assert_eq!(sharded.steady_reallocs, 0, "sharded server path");
}

fn sweep_grid() -> SweepConfig {
    SweepConfig {
        machines: vec![1, 2],
        staleness: vec![0, 4],
        policies: vec!["ssp".into(), "bsp".into()],
        etas: vec![],
        threads: 1,
    }
}

fn sweep_cfg() -> ExperimentConfig {
    let mut c = ExperimentConfig::tiny();
    c.train.clocks = 6;
    c.train.batches_per_clock = 1;
    c
}

fn sweep_opts(threads: usize) -> SweepOptions {
    SweepOptions {
        threads,
        per_batch_s: Some(0.01),
        eval_samples: 64,
        ..SweepOptions::default()
    }
}

#[test]
fn d3_sweep_bitwise_identical_across_thread_budgets() {
    let cfg = sweep_cfg();
    let grid = sweep_grid();
    // 2 machines x (ssp s=0, ssp s=4, bsp) = 6 cells
    let baseline = run_sweep(&cfg, &grid, &sweep_opts(1)).unwrap();
    let baseline_json = metrics::sweep_json(&baseline, false).to_string();
    assert_eq!(baseline.cells.len(), 6);
    for budget in [2usize, 4, 7] {
        let report = run_sweep(&cfg, &grid, &sweep_opts(budget)).unwrap();
        assert_eq!(report.outer_workers, budget.min(6));
        let json = metrics::sweep_json(&report, false).to_string();
        assert_eq!(
            json, baseline_json,
            "budget {budget} changed the sweep's statistical content"
        );
    }
}

#[test]
fn d3_sweep_cell_is_exactly_its_derived_driver_run() {
    let cfg = sweep_cfg();
    let grid = sweep_grid();
    let report = run_sweep(&cfg, &grid, &sweep_opts(4)).unwrap();
    let cell = &report.cells[3]; // machines=2, ssp(s=0)
    assert_eq!(cell.machines, 2);
    // cells share the root seed: axes compare protocol, not seed noise
    assert_eq!(cell.seed, cfg.train.seed);

    let mut direct = cfg.clone();
    direct.cluster.machines = cell.machines;
    direct.ssp.policy = Policy::Ssp { staleness: 0 };
    direct.train.eta = cell.eta;
    direct.train.seed = cell.seed;
    let ds = build_dataset(&direct);
    let run = run_experiment_on(
        &direct,
        DriverOptions {
            machines: Some(cell.machines),
            per_batch_s: Some(0.01),
            eval_samples: 64,
            ..DriverOptions::default()
        },
        &ds,
    );
    assert_eq!(cell.final_objective, run.final_objective);
    assert_eq!(cell.total_vtime, run.total_vtime);
    assert_eq!(cell.steps, run.steps);
    assert_eq!(cell.evals.len(), run.evals.len());
    for (&(vtime, clock, objective), e) in cell.evals.iter().zip(&run.evals) {
        assert_eq!(vtime, e.vtime);
        assert_eq!(clock, e.clock);
        assert_eq!(objective, e.objective);
    }
}

#[test]
fn d3_sweep_cells_are_allocation_free_too() {
    let mut cfg = sweep_cfg();
    cfg.train.clocks = 16;
    cfg.cluster.drop_prob = 0.0;
    cfg.cluster.straggler_prob = 0.0;
    let grid = SweepConfig {
        machines: vec![1, 3],
        staleness: vec![2],
        policies: vec!["ssp".into()],
        etas: vec![],
        threads: 2,
    };
    let report = run_sweep(
        &cfg,
        &grid,
        &SweepOptions {
            warmup_clocks: 6,
            ..sweep_opts(2)
        },
    )
    .unwrap();
    for cell in &report.cells {
        assert_eq!(
            cell.steady_reallocs, 0,
            "cell {} allocated at steady state",
            cell.index
        );
    }
}
