//! End-to-end tests of the multi-process SSP transport: the remote
//! backing must be *observation-equivalent* to the in-process servers —
//! bitwise-equal final weights on simulated figures, identical sweep
//! reports, identical threaded runs at one machine — and the version
//! gate must provably skip unchanged layers **on the wire** (byte
//! counts, not just FetchStats). Both serving tiers are pinned — the
//! shared single-process endpoints and the exclusive one-process-per-
//! group split — with commits synchronous and pipelined, plus the
//! protocol edge cases: reconnects with stale revision vectors, typed
//! ERR replies that leave the in-flight window aligned, wildcard-bind
//! shutdown, and frame reassembly across 1-byte server writes.

use std::sync::Arc;

use sspdnn::config::{ExperimentConfig, SweepConfig};
use sspdnn::coordinator::{
    self, build_dataset, native_factory, run_experiment_with, run_sweep_with,
    run_threaded, run_threaded_on, DriverOptions, EtaSchedule, SweepOptions,
    ThreadedOptions,
};
use sspdnn::metrics;
use sspdnn::nn::{LayerParams, ParamSet};
use sspdnn::ssp::transport::{
    self, RemoteClient, ShardService, TransportErrorKind,
};
use sspdnn::ssp::{ParamServer, Policy, ShardedServer, UpdateMsg, WorkerCache};
use sspdnn::tensor::Matrix;

fn tiny_cfg() -> ExperimentConfig {
    let mut c = ExperimentConfig::tiny();
    c.train.clocks = 10;
    c.train.batches_per_clock = 2;
    c
}

fn fast_opts() -> DriverOptions {
    DriverOptions {
        per_batch_s: Some(0.01),
        eval_samples: 128,
        ..DriverOptions::default()
    }
}

fn dims() -> Vec<usize> {
    vec![3, 4, 2]
}

fn msg(from: usize, clock: u64, layer: usize, v: f32) -> UpdateMsg {
    let d = dims();
    UpdateMsg::new(
        from,
        clock,
        layer,
        LayerParams {
            w: Matrix::from_fn(d[layer], d[layer + 1], |_, _| v),
            b: vec![v; d[layer + 1]],
        },
    )
}

/// The acceptance pin: one simulated figure run with the discrete-event
/// driver backed by a `RemoteClient` over loopback TCP must reproduce
/// the in-process `ShardedServer` run **bitwise** — final weights,
/// objective curve, virtual time, step and read counts.
#[test]
fn remote_driver_matches_sharded_bitwise_on_a_simulated_figure() {
    let cfg = tiny_cfg();
    let ds = build_dataset(&cfg);
    let a = run_experiment_with(&cfg, fast_opts(), &ds, ShardedServer::new);
    let b = run_experiment_with(&cfg, fast_opts(), &ds, |init, workers, policy| {
        transport::loopback(init, workers, policy, 2)
    });
    assert_eq!(a.final_params, b.final_params, "final weights diverged");
    assert_eq!(a.final_objective, b.final_objective);
    assert_eq!(a.total_vtime, b.total_vtime);
    assert_eq!(a.steps, b.steps);
    assert_eq!(a.reads, b.reads);
    let a_curve: Vec<(u64, f64)> =
        a.evals.iter().map(|e| (e.clock, e.objective)).collect();
    let b_curve: Vec<(u64, f64)> =
        b.evals.iter().map(|e| (e.clock, e.objective)).collect();
    assert_eq!(a_curve, b_curve, "objective curves diverged");
}

/// ROADMAP's transport-evaluation instrument: the same sweep grid run
/// against the in-process server and the remote client must produce
/// identical statistical `SweepReport` JSON (timing fields excluded).
#[test]
fn remote_sweep_report_matches_inprocess() {
    let mut cfg = tiny_cfg();
    cfg.train.clocks = 6;
    let grid = SweepConfig {
        machines: vec![1, 2],
        staleness: vec![1],
        policies: vec!["ssp".into()],
        etas: Vec::new(),
        threads: 1,
    };
    let opts = SweepOptions {
        per_batch_s: Some(0.01),
        eval_samples: 64,
        ..SweepOptions::default()
    };
    let a = run_sweep_with(&cfg, &grid, &opts, ShardedServer::new).unwrap();
    let b = run_sweep_with(&cfg, &grid, &opts, |init, workers, policy| {
        transport::loopback(init, workers, policy, 2)
    })
    .unwrap();
    assert_eq!(
        metrics::sweep_json(&a, false).to_string(),
        metrics::sweep_json(&b, false).to_string(),
        "sweep reports diverged"
    );
}

/// The threaded runner over remote worker ports: at one machine the run
/// is fully deterministic, so the remote-backed `run_threaded_on` must
/// be value-identical to the in-process `run_threaded`.
#[test]
fn remote_threaded_matches_inprocess_at_one_machine() {
    let mut cfg = tiny_cfg();
    cfg.train.clocks = 8;
    let ds = build_dataset(&cfg);
    let opts = |_: ()| ThreadedOptions {
        machines: 1,
        engine_factory: native_factory(&cfg),
        eta: EtaSchedule::Fixed(cfg.train.eta),
        eval_every: 2,
        eval_samples: 64,
    };
    let a = run_threaded(&cfg, &ds, opts(()));

    // the remote side: serve the same config-derived server over
    // loopback, one connection set per port request
    let init = coordinator::init_params(&cfg);
    let server = Arc::new(ShardedServer::new(init, 1, cfg.ssp.policy));
    let svc = ShardService::bind(Arc::clone(&server), "127.0.0.1:0", 2).unwrap();
    let addrs = svc.addrs().to_vec();
    let b = run_threaded_on(&cfg, &ds, opts(()), |_p| {
        RemoteClient::connect(&addrs).expect("connect worker port")
    });

    assert_eq!(a.final_params, b.final_params, "final weights diverged");
    assert_eq!(a.final_objective, b.final_objective);
    assert_eq!(a.steps, b.steps);
    let a_curve: Vec<(u64, f64)> =
        a.evals.iter().map(|e| (e.0, e.2)).collect();
    let b_curve: Vec<(u64, f64)> =
        b.evals.iter().map(|e| (e.0, e.2)).collect();
    assert_eq!(a_curve, b_curve, "eval curves diverged");
    drop(svc);
}

/// The acceptance criterion's byte-count assertion: a gated fetch of an
/// unchanged model must move *less data on the wire* than the model
/// payload — the skip is bytes never sent, not just a stats field.
#[test]
fn gated_fetch_skips_unchanged_layers_on_the_wire() {
    let init = {
        let mut rng = sspdnn::util::Pcg64::new(3);
        ParamSet::glorot(&dims(), &mut rng)
    };
    let model_payload: u64 = init
        .layers
        .iter()
        .map(|l| l.n_bytes() as u64)
        .sum();
    let mut client = transport::loopback(init.clone(), 1, Policy::Async, 2);
    let mut buf = init.clone();
    // unknown provenance: the first fetch must copy everything
    let mut seen = vec![u64::MAX; 2];
    let mut own = Vec::new();

    let before_cold = client.wire_stats();
    let (_, fs_cold) = client.fetch_into(0, &mut buf, &mut seen, &mut own);
    let after_cold = client.wire_stats();
    assert_eq!(fs_cold.layers_copied, 2);
    let cold_bytes = after_cold.bytes_received - before_cold.bytes_received;
    assert!(
        cold_bytes >= model_payload,
        "cold fetch must carry the model: {cold_bytes} < {model_payload}"
    );

    // nothing changed: the hot fetch ships headers only
    let before_hot = client.wire_stats();
    let (_, fs_hot) = client.fetch_into(0, &mut buf, &mut seen, &mut own);
    let after_hot = client.wire_stats();
    assert_eq!(fs_hot.layers_copied, 0, "zero-layer delta fetch");
    assert_eq!(fs_hot.layers_skipped, 2);
    let hot_bytes = after_hot.bytes_received - before_hot.bytes_received;
    assert!(
        cold_bytes - hot_bytes >= model_payload,
        "gate must keep the model payload off the wire: \
         cold {cold_bytes} - hot {hot_bytes} < {model_payload}"
    );
    // and the gated buffer still matches the master exactly
    assert_eq!(buf, ParamServer::snapshot(&client));

    // gate off: the same zero-delta fetch ships every layer again
    let mut ungated = client.with_gate(false);
    let before_off = ungated.wire_stats();
    let (_, fs_off) = ungated.fetch_into(0, &mut buf, &mut seen, &mut own);
    let after_off = ungated.wire_stats();
    assert_eq!(fs_off.layers_copied, 2, "no-gate fetch copies everything");
    let off_bytes = after_off.bytes_received - before_off.bytes_received;
    assert!(
        off_bytes >= model_payload,
        "no-gate fetch must carry the model: {off_bytes} < {model_payload}"
    );
}

/// A worker reconnecting *within one server lifetime* may resume with a
/// stale revision vector: revisions only grow, so staleness can only
/// cause extra copies — the reconnected fetch must still land exactly
/// on the master.
#[test]
fn reconnect_with_stale_revision_vector_resumes_correctly() {
    let init = ParamSet::zeros(&dims());
    let server = Arc::new(ShardedServer::new(init.clone(), 2, Policy::Async));
    let svc = ShardService::bind(Arc::clone(&server), "127.0.0.1:0", 2).unwrap();
    let addrs = svc.addrs().to_vec();

    // first connection: fetch once so the gate has history
    let mut buf = init.clone();
    let mut seen = vec![0u64; 2];
    let mut own = Vec::new();
    {
        let mut c1 = RemoteClient::connect(&addrs).unwrap();
        ParamServer::commit(&mut c1, 0);
        c1.apply_arrival(&msg(0, 0, 0, 0.5));
        c1.apply_arrival(&msg(0, 0, 1, 0.5));
        let (_, fs) = c1.fetch_into(1, &mut buf, &mut seen, &mut own);
        assert_eq!(fs.layers_copied, 2);
    } // c1 drops: connection closes, service keeps running

    // more updates land while the worker is away
    server.commit(0);
    server.apply_arrival(&msg(0, 1, 1, 0.25));

    // second connection resumes with the carried-over (now stale for
    // layer 1) revision vector: exactly the changed layer ships
    let mut c2 = RemoteClient::connect(&addrs).unwrap();
    let (_, fs) = c2.fetch_into(1, &mut buf, &mut seen, &mut own);
    assert_eq!(fs.layers_copied, 1, "only the layer that moved re-ships");
    assert_eq!(fs.layers_skipped, 1);
    assert_eq!(buf, server.snapshot(), "resumed buffer matches master");
    drop(c2);
    drop(svc);
}

/// A `serve`/`train` config mismatch must fail loudly at connect: the
/// handshake's init digest catches two processes deriving different
/// initial parameters (the silent-corruption mode where every layer
/// gate-skips against a master the worker never actually held).
#[test]
#[should_panic(expected = "init digest")]
fn mismatched_init_is_rejected_by_check_run() {
    let init_served = ParamSet::zeros(&dims());
    let init_local = {
        let mut rng = sspdnn::util::Pcg64::new(9);
        ParamSet::glorot(&dims(), &mut rng)
    };
    let client = transport::loopback(init_served, 1, Policy::Async, 2);
    client.check_run(&init_local, 1, Policy::Async);
}

/// Across a server *restart* the revision counters restart too, so a
/// carried-over gate can collide (0 == 0) and wrongly keep old bits —
/// exactly the hazard `WorkerCache::reset_gate` exists for.
#[test]
fn server_restart_requires_gate_reset() {
    let d = dims();
    let init_a = ParamSet::zeros(&d);
    let init_b = {
        let mut rng = sspdnn::util::Pcg64::new(77);
        ParamSet::glorot(&d, &mut rng)
    };

    // lifetime 1: worker's cache premise matches server A (both zeros)
    let mut cache = WorkerCache::new(0, init_a.clone());
    {
        let mut c = transport::loopback(init_a.clone(), 1, Policy::Async, 2);
        let (buf, seen, own) = cache.refresh_target();
        let (_, fs) = c.fetch_into(0, buf, seen, own);
        assert_eq!(fs.layers_copied, 0, "nothing changed on server A");
    }

    // lifetime 2: a *new* server with different bits, revisions back at
    // zero. Without a reset the gate skips everything and the view
    // silently keeps server A's bits.
    let mut c = transport::loopback(init_b.clone(), 1, Policy::Async, 2);
    {
        let (buf, seen, own) = cache.refresh_target();
        let (_, fs) = c.fetch_into(0, buf, seen, own);
        assert_eq!(fs.layers_copied, 0, "the collision: stale gate skips");
    }
    assert_ne!(
        *cache.view(),
        ParamServer::snapshot(&c),
        "demonstrated hazard: view disagrees with the new master"
    );

    // the reset path makes the next fetch recopy everything
    cache.reset_gate();
    let (buf, seen, own) = cache.refresh_target();
    let (_, fs) = c.fetch_into(0, buf, seen, own);
    assert_eq!(fs.layers_copied, 2, "reset gate recopies every layer");
    assert_eq!(*cache.view(), ParamServer::snapshot(&c));
}

/// The multi-process tier's acceptance pin: the same simulated figure
/// run against (a) N *independent* per-group server processes with
/// synchronous commits, (b) the same split tier with pipelined commits,
/// and (c) the shared single-process tier with pipelined commits — all
/// three must reproduce the in-process `ShardedServer` run **bitwise**.
#[test]
fn split_driver_matches_sharded_bitwise_sync_and_pipelined() {
    let cfg = tiny_cfg();
    let ds = build_dataset(&cfg);
    let a = run_experiment_with(&cfg, fast_opts(), &ds, ShardedServer::new);
    let split_sync =
        run_experiment_with(&cfg, fast_opts(), &ds, |init, workers, policy| {
            transport::loopback_split(init, workers, policy, 2, None)
        });
    let split_pipe =
        run_experiment_with(&cfg, fast_opts(), &ds, |init, workers, policy| {
            transport::loopback_split(init, workers, policy, 2, Some(16))
        });
    let shared_pipe =
        run_experiment_with(&cfg, fast_opts(), &ds, |init, workers, policy| {
            transport::loopback(init, workers, policy, 2)
                .with_pipeline(8)
                .expect("enable pipeline")
        });
    for (name, r) in [
        ("split+sync", &split_sync),
        ("split+pipelined", &split_pipe),
        ("shared+pipelined", &shared_pipe),
    ] {
        assert_eq!(
            a.final_params, r.final_params,
            "{name}: final weights diverged"
        );
        assert_eq!(a.final_objective, r.final_objective, "{name}");
        assert_eq!(a.total_vtime, r.total_vtime, "{name}");
        assert_eq!(a.steps, r.steps, "{name}");
        assert_eq!(a.reads, r.reads, "{name}");
        let a_curve: Vec<(u64, f64)> =
            a.evals.iter().map(|e| (e.clock, e.objective)).collect();
        let r_curve: Vec<(u64, f64)> =
            r.evals.iter().map(|e| (e.clock, e.objective)).collect();
        assert_eq!(a_curve, r_curve, "{name}: objective curves diverged");
    }
}

/// The threaded runner over the *split* tier with pipelined ports: each
/// shard group is an independent full server (exactly what two `sspdnn
/// serve --group` processes hold), every worker port broadcasts its
/// COMMITs and overlaps them with compute, and at one machine the run
/// must still be value-identical to the in-process `run_threaded`.
#[test]
fn split_pipelined_threaded_matches_inprocess_at_one_machine() {
    let mut cfg = tiny_cfg();
    cfg.train.clocks = 8;
    let ds = build_dataset(&cfg);
    let opts = |_: ()| ThreadedOptions {
        machines: 1,
        engine_factory: native_factory(&cfg),
        eta: EtaSchedule::Fixed(cfg.train.eta),
        eval_every: 2,
        eval_samples: 64,
    };
    let a = run_threaded(&cfg, &ds, opts(()));

    // one independent per-group server process' worth of state per
    // group, each serving only its own shard range
    let init = coordinator::init_params(&cfg);
    let mut services = Vec::new();
    let mut addrs = Vec::new();
    for g in 0..2 {
        let server =
            Arc::new(ShardedServer::new(init.clone(), 1, cfg.ssp.policy));
        let svc =
            ShardService::bind_group(server, "127.0.0.1:0", 2, g).unwrap();
        addrs.extend_from_slice(svc.addrs());
        services.push(svc);
    }
    let b = run_threaded_on(&cfg, &ds, opts(()), |_p| {
        let port = RemoteClient::connect(&addrs).expect("connect worker port");
        assert!(port.exclusive(), "split endpoints must handshake exclusive");
        port.with_pipeline(16).expect("enable pipeline")
    });

    assert_eq!(a.final_params, b.final_params, "final weights diverged");
    assert_eq!(a.final_objective, b.final_objective);
    assert_eq!(a.steps, b.steps);
    let a_curve: Vec<(u64, f64)> =
        a.evals.iter().map(|e| (e.0, e.2)).collect();
    let b_curve: Vec<(u64, f64)> =
        b.evals.iter().map(|e| (e.0, e.2)).collect();
    assert_eq!(a_curve, b_curve, "eval curves diverged");
    drop(services);
}

/// Shutdown's accept-loop wake-up self-connects; with a wildcard bind
/// (`0.0.0.0` / `::`) the listen address is not a connectable
/// destination, which used to leave `shutdown` hanging on a parked
/// accept. Pin that dropping a wildcard-bound service completes.
#[test]
fn shutdown_completes_when_bound_to_wildcard_address() {
    let init = ParamSet::zeros(&dims());
    let server = Arc::new(ShardedServer::new(init, 1, Policy::Async));
    let svc = ShardService::bind(server, "0.0.0.0:0", 2).unwrap();
    assert_eq!(svc.groups(), 2);
    let done = std::thread::spawn(move || drop(svc));
    let deadline =
        std::time::Instant::now() + std::time::Duration::from_secs(10);
    while !done.is_finished() {
        assert!(
            std::time::Instant::now() < deadline,
            "shutdown hung on a wildcard-bound listener"
        );
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    done.join().unwrap();
}

/// A server-side rejection (the FIFO pre-check answering ERR) must
/// surface as a *typed* error — `TransportErrorKind::Server` — and, on
/// a pipelined connection, consume exactly its own in-flight window
/// slot: later acknowledgements still match their entries and the
/// connection stays usable. Both the synchronous and pipelined paths.
#[test]
fn err_reply_is_typed_and_pipeline_window_stays_aligned() {
    let init = ParamSet::zeros(&dims());

    // synchronous: the rejection surfaces on the offending call
    let sync = transport::loopback(init.clone(), 1, Policy::Async, 2);
    let e = sync
        .try_apply_arrival(&msg(0, 5, 0, 0.1))
        .expect_err("clock-5 update skips clocks 0..5");
    assert_eq!(e.kind, TransportErrorKind::Server);
    assert!(
        e.to_string().contains("out-of-order"),
        "unhelpful error: {e}"
    );
    sync.try_apply_arrival(&msg(0, 0, 0, 0.2)).unwrap();
    assert_eq!(sync.applied(0, 0), 1, "connection survived the ERR");

    // pipelined: good, bad, good enqueued on one connection — the
    // rejection surfaces at flush, the later update still applied
    let pipe = transport::loopback(init, 1, Policy::Async, 2)
        .with_pipeline(8)
        .expect("enable pipeline");
    pipe.try_apply_arrival(&msg(0, 0, 0, 0.1)).unwrap();
    pipe.try_apply_arrival(&msg(0, 7, 0, 0.1)).unwrap(); // rejected later
    pipe.try_apply_arrival(&msg(0, 1, 0, 0.1)).unwrap();
    let e = pipe.flush().expect_err("the enqueued rejection drains here");
    assert_eq!(e.kind, TransportErrorKind::Server);
    // no desync: the ERR consumed exactly its own window slot, so the
    // update behind it was acknowledged and applied...
    assert_eq!(pipe.applied(0, 0), 2, "update behind the ERR still landed");
    // ...and the connection keeps working
    pipe.try_apply_arrival(&msg(0, 2, 0, 0.3)).unwrap();
    pipe.flush().unwrap();
    assert_eq!(pipe.applied(0, 0), 3);
}

/// The client must reassemble frames across arbitrarily torn reads: a
/// fake server dribbles its HELLO_OK and a U64 reply one byte per
/// `write`, and the handshake plus a CLOCK round-trip must still work.
#[test]
fn client_reassembles_one_byte_server_writes() {
    use std::io::Write;

    use sspdnn::ssp::transport::wire::{self, op};

    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        let (mut s, _) = listener.accept().unwrap();
        s.set_nodelay(true).unwrap();
        let dribble = |s: &mut std::net::TcpStream, out: &[u8]| {
            for b in out {
                s.write_all(std::slice::from_ref(b)).unwrap();
                s.flush().unwrap();
            }
        };
        let mut dec = wire::FrameDecoder::default();
        let mut bytes_in = 0u64;
        let hello = wire::read_frame(&mut s, &mut dec, &mut bytes_in)
            .unwrap()
            .expect("client opens with HELLO");
        assert_eq!(hello.op, op::HELLO);
        // v5 HELLO: version:u32 | codec:u8 | codec_arg:u32
        assert_eq!(hello.payload.len(), 9, "v5 HELLO carries a codec request");
        assert_eq!(hello.payload[4], 0, "default codec request is off");
        // HELLO_OK for a 1-worker, 1-layer, 1-group shared async server
        let mut out = Vec::new();
        let mark = wire::begin_frame(&mut out, op::HELLO_OK);
        wire::put_u32(&mut out, wire::WIRE_VERSION);
        wire::put_u32(&mut out, 1); // workers
        wire::put_u32(&mut out, 1); // n_layers
        wire::put_u32(&mut out, 1); // groups
        wire::put_u32(&mut out, 0); // group
        wire::put_u32(&mut out, 0); // group start
        wire::put_u32(&mut out, 1); // group len
        wire::put_u8(&mut out, 2); // policy tag: async
        wire::put_u64(&mut out, 0); // staleness
        wire::put_u64(&mut out, 0); // init digest (check_run not used)
        wire::put_u8(&mut out, 0); // shared endpoint
        wire::put_u8(&mut out, 0); // not elastic
        wire::put_u64(&mut out, 0); // membership epoch
        wire::put_u8(&mut out, 0b1111); // codec support mask
        wire::put_u8(&mut out, 0); // echoed codec: off
        wire::put_u32(&mut out, 0); // codec arg
        wire::put_u32(&mut out, 1); // rows
        wire::put_u32(&mut out, 1); // cols
        wire::put_u32(&mut out, 1); // blen
        wire::end_frame(&mut out, mark);
        dribble(&mut s, &out);
        let clock = wire::read_frame(&mut s, &mut dec, &mut bytes_in)
            .unwrap()
            .expect("client asks for the clock");
        assert_eq!(clock.op, op::CLOCK);
        let mut out = Vec::new();
        let mark = wire::begin_frame(&mut out, op::U64);
        wire::put_u64(&mut out, 7);
        wire::end_frame(&mut out, mark);
        dribble(&mut s, &out);
    });

    let client =
        RemoteClient::connect(&[addr]).expect("handshake across torn writes");
    assert_eq!(client.clock(0), 7, "reply reassembled from 1-byte chunks");
    drop(client);
    server.join().unwrap();
}

/// The wire-compression byte assertion: with a lossy codec negotiated,
/// a gated fetch that carries exactly one changed layer must move
/// *strictly fewer bytes* than the same fetch under the raw codec —
/// and the per-format payload accounting must attribute the coded
/// bytes to the negotiated format, not to RAW.
#[test]
fn coded_hot_fetch_ships_fewer_bytes_than_gated_raw() {
    use sspdnn::ssp::transport::Codec;

    let init = {
        let mut rng = sspdnn::util::Pcg64::new(11);
        ParamSet::glorot(&dims(), &mut rng)
    };
    // the same single-layer hot fetch under each codec: the gate keeps
    // the unchanged layer off the wire in every run, so the byte delta
    // is purely coded-vs-raw payload of the layer that moved
    let hot_fetch = |codec: Codec| {
        let mut client =
            transport::loopback_codec(init.clone(), 1, Policy::Async, 2, codec);
        let mut buf = init.clone();
        let mut seen = vec![0u64; 2];
        let mut own = Vec::new();
        client.apply_arrival(&msg(0, 0, 0, 0.125));
        let before = client.wire_stats();
        let (_, fs) = client.fetch_into(0, &mut buf, &mut seen, &mut own);
        let after = client.wire_stats();
        assert_eq!(fs.layers_copied, 1, "exactly the hot layer ships");
        assert_eq!(fs.layers_skipped, 1);
        (after.fetch_bytes_received - before.fetch_bytes_received, after)
    };

    let (raw_bytes, raw_stats) = hot_fetch(Codec::Off);
    assert!(raw_stats.payload_bytes[0] > 0, "raw fetch accounts as RAW");
    for codec in [Codec::Bf16, Codec::F16] {
        let (coded_bytes, stats) = hot_fetch(codec);
        assert!(
            coded_bytes < raw_bytes,
            "{codec}: coded hot fetch must be strictly smaller than raw \
             ({coded_bytes} >= {raw_bytes})"
        );
        let fmt_tag = match codec {
            Codec::Bf16 => 1,
            _ => 2,
        };
        assert!(
            stats.payload_bytes[fmt_tag] > 0,
            "{codec}: coded bytes must be attributed to the coded format"
        );
        assert_eq!(
            stats.payload_bytes[0], 0,
            "{codec}: nothing should be accounted RAW on a coded connection"
        );
    }
}

/// Under a lossy codec the gated fetch and the snapshot expose the
/// *same* quantized view, so the gate's keep-old-bits premise stays
/// sound: dense quantization is a deterministic function of the server
/// bits, and an unchanged revision implies unchanged quantized bits.
#[test]
fn coded_gated_fetch_agrees_with_coded_snapshot() {
    use sspdnn::ssp::transport::Codec;

    for codec in [Codec::Bf16, Codec::F16, Codec::TopK { frac_ppm: 250_000 }] {
        let init = {
            let mut rng = sspdnn::util::Pcg64::new(23);
            ParamSet::glorot(&dims(), &mut rng)
        };
        let mut client =
            transport::loopback_codec(init.clone(), 1, Policy::Async, 2, codec);
        let mut buf = init.clone();
        let mut seen = vec![u64::MAX; 2];
        let mut own = Vec::new();
        client.apply_arrival(&msg(0, 0, 0, 0.3));
        client.apply_arrival(&msg(0, 0, 1, -0.7));
        let (_, fs) = client.fetch_into(0, &mut buf, &mut seen, &mut own);
        assert_eq!(fs.layers_copied, 2);
        assert_eq!(
            buf,
            ParamServer::snapshot(&client),
            "{codec}: gated view disagrees with snapshot"
        );
        // the hot re-fetch skips everything and the view stays aligned
        let (_, fs) = client.fetch_into(0, &mut buf, &mut seen, &mut own);
        assert_eq!(fs.layers_skipped, 2, "{codec}");
        assert_eq!(buf, ParamServer::snapshot(&client), "{codec}");
    }
}

/// The convergence-equivalence gate: `codec=off` must stay *bitwise* on
/// the raw-transport bits, and every lossy codec must land the fixed-
/// seed simulated figure run inside a tolerance band of the raw run's
/// final objective. Error feedback is what keeps the lossy runs inside
/// the band — dropped precision re-enters as carried residual.
#[test]
fn lossy_codecs_converge_within_tolerance_of_raw() {
    use sspdnn::ssp::transport::Codec;

    let cfg = tiny_cfg();
    let ds = build_dataset(&cfg);
    let run = |codec: Codec| {
        run_experiment_with(
            &cfg,
            fast_opts(),
            &ds,
            move |init, workers, policy| {
                transport::loopback_codec(init, workers, policy, 2, codec)
            },
        )
    };

    let base = run_experiment_with(&cfg, fast_opts(), &ds, ShardedServer::new);
    let raw = run(Codec::Off);
    assert_eq!(
        base.final_params, raw.final_params,
        "codec=off must stay bitwise on the raw-transport bits"
    );
    assert_eq!(base.final_objective, raw.final_objective);

    for codec in [Codec::Bf16, Codec::F16, Codec::TopK { frac_ppm: 500_000 }] {
        let lossy = run(codec);
        let rel = (lossy.final_objective - raw.final_objective).abs()
            / raw.final_objective.abs().max(1e-12);
        assert!(
            rel <= 0.25,
            "{codec}: final objective {} drifted {rel:.4} (> 25%) from raw {}",
            lossy.final_objective,
            raw.final_objective
        );
        assert!(
            lossy.final_objective.is_finite(),
            "{codec}: objective must stay finite"
        );
    }
}
