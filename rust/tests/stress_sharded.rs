//! Concurrency stress test for the sharded per-layer parameter server:
//! 8 real worker threads × 4 layers hammering fetch/commit/apply with no
//! coordinator in between.
//!
//! Asserted, at every read and at the end:
//! * no deadlock (the run completes; the barrier never wedges),
//! * bounded staleness observed at every read — both on the clock table
//!   (no observable clock exceeds own + s + 1) and on the *parameter
//!   content* (every fetched element stays inside the SSP-feasible
//!   envelope of guaranteed vs maximum-possible applied updates),
//! * read-my-writes (own applied counts equal own committed clock),
//! * conservation of the master sum: with all-ones deltas the final
//!   master must equal init + workers × clocks exactly (f32-exact in
//!   this range).

use std::sync::atomic::{AtomicU64, Ordering};

use sspdnn::nn::{LayerParams, ParamSet};
use sspdnn::ssp::{Policy, ShardedServer, UpdateMsg};
use sspdnn::tensor::Matrix;

const WORKERS: usize = 8;
const CLOCKS: u64 = 40;
const STALENESS: u64 = 3;

/// dims chain with 4 layers: 4 independent shards.
fn dims() -> Vec<usize> {
    vec![6, 5, 4, 3, 2]
}

fn ones_delta(d: &[usize], layer: usize) -> LayerParams {
    LayerParams {
        w: Matrix::from_fn(d[layer], d[layer + 1], |_, _| 1.0),
        b: vec![1.0; d[layer + 1]],
    }
}

#[test]
fn stress_8_workers_4_layers() {
    let d = dims();
    let n_layers = d.len() - 1;
    assert_eq!(n_layers, 4);
    let server = ShardedServer::new(
        ParamSet::zeros(&d),
        WORKERS,
        Policy::Ssp {
            staleness: STALENESS,
        },
    );
    let total_reads = AtomicU64::new(0);

    std::thread::scope(|scope| {
        for p in 0..WORKERS {
            let server = &server;
            let d = d.clone();
            let total_reads = &total_reads;
            scope.spawn(move || {
                for clock in 0..CLOCKS {
                    server.wait_until_ready(p);

                    // clock-table staleness bound, race-free form: while
                    // our own clock is `clock`, no worker can ever commit
                    // past clock + s + 1
                    for q in 0..WORKERS {
                        let cq = server.clocks().clock(q);
                        assert!(
                            cq <= clock + STALENESS + 1,
                            "P1 observed: worker {q} at {cq}, reader at {clock}"
                        );
                    }

                    let (snap, own, stats) = server.fetch(p);
                    total_reads.fetch_add(1, Ordering::Relaxed);

                    // read-my-writes: all of our own commits are applied
                    // (we applied them ourselves before this fetch)
                    assert_eq!(own, vec![clock; n_layers], "own clocks");

                    // ε accounting stays a probability
                    let rate = stats.epsilon_rate();
                    assert!((0.0..=1.0).contains(&rate), "eps rate {rate}");

                    // parameter-content staleness envelope: with all-ones
                    // deltas every element counts applied updates for its
                    // layer. Guaranteed floor: own `clock` updates plus
                    // (workers−1)·max(0, clock−s) foreign ones. Ceiling:
                    // no worker can exceed clock+s+1 commits.
                    let floor = clock
                        + (WORKERS as u64 - 1) * clock.saturating_sub(STALENESS);
                    let ceil = clock
                        + (WORKERS as u64 - 1) * (clock + STALENESS + 1);
                    for (l, lp) in snap.layers.iter().enumerate() {
                        let got = lp.w.at(0, 0) as u64;
                        assert!(
                            (got as f32 - lp.w.at(0, 0)).abs() == 0.0,
                            "layer {l} element not integral"
                        );
                        assert!(
                            got >= floor && got <= ceil,
                            "layer {l}: {got} outside SSP envelope \
                             [{floor}, {ceil}] at clock {clock}"
                        );
                    }

                    // commit: advance the clock, then apply our own
                    // per-layer updates (FIFO per (layer, worker))
                    let msgs: Vec<UpdateMsg> = (0..n_layers)
                        .map(|l| UpdateMsg::new(p, clock, l, ones_delta(&d, l)))
                        .collect();
                    server.commit(p);
                    server.apply_arrivals(&msgs);
                }
            });
        }
    });

    // no deadlock: every worker ran all its clocks
    assert_eq!(server.clocks().min(), CLOCKS);
    assert_eq!(server.clocks().max(), CLOCKS);
    assert_eq!(total_reads.load(Ordering::Relaxed), WORKERS as u64 * CLOCKS);
    assert_eq!(server.reads(), WORKERS as u64 * CLOCKS);

    // conservation: master = init + Σ updates, exactly
    let want = (WORKERS as u64 * CLOCKS) as f32;
    let master = server.snapshot();
    for (l, lp) in master.layers.iter().enumerate() {
        for &v in lp.w.data() {
            assert_eq!(v, want, "layer {l} weight sum");
        }
        for &v in &lp.b {
            assert_eq!(v, want, "layer {l} bias sum");
        }
    }
    // version vector fully caught up
    for l in 0..n_layers {
        for q in 0..WORKERS {
            assert_eq!(server.applied(l, q), CLOCKS);
        }
    }
    assert_eq!(server.applied_count(), WORKERS as u64 * CLOCKS * n_layers as u64);
}

/// Same shape under BSP: strict lockstep, still no deadlock, and the
/// conservation sum holds.
#[test]
fn stress_bsp_lockstep() {
    let d = dims();
    let n_layers = d.len() - 1;
    let server = ShardedServer::new(ParamSet::zeros(&d), WORKERS, Policy::Bsp);
    std::thread::scope(|scope| {
        for p in 0..WORKERS {
            let server = &server;
            let d = d.clone();
            scope.spawn(move || {
                for clock in 0..CLOCKS {
                    server.wait_until_ready(p);
                    for q in 0..WORKERS {
                        assert!(server.clocks().clock(q) <= clock + 1);
                    }
                    let (_, own, _) = server.fetch(p);
                    assert_eq!(own, vec![clock; n_layers]);
                    let msgs: Vec<UpdateMsg> = (0..n_layers)
                        .map(|l| UpdateMsg::new(p, clock, l, ones_delta(&d, l)))
                        .collect();
                    server.commit(p);
                    server.apply_arrivals(&msgs);
                }
            });
        }
    });
    let want = (WORKERS as u64 * CLOCKS) as f32;
    let master = server.snapshot();
    for lp in &master.layers {
        for &v in lp.w.data() {
            assert_eq!(v, want);
        }
    }
}
