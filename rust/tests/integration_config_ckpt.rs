//! Integration: config files drive experiments; checkpoints round-trip
//! trained state; the CLI argument surface parses realistic invocations.

use sspdnn::checkpoint;
use sspdnn::cli::Args;
use sspdnn::config::ExperimentConfig;
use sspdnn::coordinator::{build_dataset, run_experiment_on, DriverOptions};
use sspdnn::nn::{Activation, Labels, Loss, Mlp};
use sspdnn::ssp::Policy;

#[test]
fn config_file_roundtrip_drives_experiment() {
    let toml = r#"
        name = "from_file"
        [model]
        dims = [16, 24, 10]
        [ssp]
        staleness = 4
        [cluster]
        machines = 2
        straggler_prob = 0.0
        [train]
        clocks = 10
        batch = 8
        eta = 0.4
    "#;
    let path = std::env::temp_dir().join("sspdnn_itest_cfg.toml");
    std::fs::write(&path, toml).unwrap();
    let cfg =
        ExperimentConfig::load_file(path.to_str().unwrap(), Some("tiny")).unwrap();
    assert_eq!(cfg.name, "from_file");
    assert_eq!(cfg.model.dims, vec![16, 24, 10]);
    assert_eq!(cfg.ssp.policy, Policy::Ssp { staleness: 4 });
    assert_eq!(cfg.cluster.machines, 2);

    let ds = build_dataset(&cfg);
    let run = run_experiment_on(
        &cfg,
        DriverOptions {
            per_batch_s: Some(0.02),
            ..DriverOptions::default()
        },
        &ds,
    );
    assert!(run.final_objective < run.evals[0].objective);
    std::fs::remove_file(&path).ok();
}

#[test]
fn trained_checkpoint_restores_objective() {
    let mut cfg = ExperimentConfig::tiny();
    cfg.train.clocks = 15;
    let ds = build_dataset(&cfg);
    let run = run_experiment_on(
        &cfg,
        DriverOptions {
            per_batch_s: Some(0.02),
            ..DriverOptions::default()
        },
        &ds,
    );

    let path = std::env::temp_dir().join("sspdnn_itest.ckpt");
    checkpoint::save(&path, &cfg.model.dims, &run.final_params).unwrap();
    let (dims, restored) = checkpoint::load(&path).unwrap();
    assert_eq!(dims, cfg.model.dims);

    // restored params produce the same objective on the same data
    let mlp = Mlp::new(dims, Activation::Sigmoid, Loss::Xent);
    let idx: Vec<usize> = (0..128).collect();
    let (x, y) = ds.gather(&idx);
    let before = mlp.objective(&run.final_params, &x, &y);
    let after = mlp.objective(&restored, &x, &y);
    assert_eq!(before, after);
    std::fs::remove_file(&path).ok();
}

#[test]
fn cli_surface_parses_realistic_invocations() {
    let a = Args::parse(
        "train --preset timit --machines 6 --staleness 10 --clocks 120 \
         --eta 0.05 --out results"
            .split_whitespace()
            .map(String::from),
    )
    .unwrap();
    assert_eq!(a.command, "train");
    assert_eq!(a.get("preset"), Some("timit"));
    assert_eq!(a.get_usize("machines").unwrap(), Some(6));
    assert_eq!(a.get_u64("staleness").unwrap(), Some(10));
    assert_eq!(a.get_f64("eta").unwrap(), Some(0.05));
    assert_eq!(a.get("out"), Some("results"));

    let b = Args::parse(
        "speedup --preset imagenet --max-machines 6 --policy bsp"
            .split_whitespace()
            .map(String::from),
    )
    .unwrap();
    assert_eq!(b.get("policy"), Some("bsp"));
}

#[test]
fn labels_and_dataset_agree_on_class_range() {
    let cfg = ExperimentConfig::tiny();
    let ds = build_dataset(&cfg);
    assert_eq!(ds.n_classes, 10);
    let idx: Vec<usize> = (0..64).collect();
    let (_, y) = ds.gather(&idx);
    match y {
        Labels::Class(c) => assert!(c.iter().all(|&v| v < 10)),
        _ => panic!("xent dataset must yield class labels"),
    }
}
