//! Fault-tolerance tests of the supervised SSP transport: scripted
//! connection kills, torn frames, heartbeat leases, and warm restarts
//! from state dumps. The contract under test is the tentpole's — a
//! recovered fault is **bitwise invisible** (same final weights, same
//! protocol observables as a never-faulted run), and an unrecoverable
//! one is **loud and typed** (`Io` with the window drained when
//! supervision is off, `Protocol` when the server lost its state,
//! `Server` when a peer's lease lapses) — never a hang, never a
//! silent desync.

use std::sync::Arc;
use std::time::{Duration, Instant};

use sspdnn::checkpoint;
use sspdnn::nn::{LayerParams, ParamSet};
use sspdnn::ssp::transport::{
    self, ChaosProxy, FaultPolicy, RemoteClient, ServiceOptions,
    ShardService, TransportErrorKind,
};
use sspdnn::ssp::{ParamServer, Policy, ShardedServer, UpdateMsg};
use sspdnn::tensor::Matrix;

fn dims() -> Vec<usize> {
    vec![3, 4, 2]
}

fn msg(from: usize, clock: u64, layer: usize, v: f32) -> UpdateMsg {
    let d = dims();
    UpdateMsg::new(
        from,
        clock,
        layer,
        LayerParams {
            w: Matrix::from_fn(d[layer], d[layer + 1], |_, _| v),
            b: vec![v; d[layer + 1]],
        },
    )
}

/// The supervised policy every recovery test uses: generous retry
/// budget, tight backoff (loopback reconnects are instant).
fn supervised() -> FaultPolicy {
    FaultPolicy {
        connect_timeout: Duration::from_secs(5),
        io_timeout: None,
        max_retries: 10,
        backoff_base: Duration::from_millis(5),
    }
}

/// One deterministic protocol schedule, identical for every backing:
/// per clock, every worker ships one delta per layer and commits, then
/// one worker takes a gated read. Distinct float per (clock, worker,
/// layer) so any dropped/duplicated/reordered update changes the final
/// bits.
fn drive<S: ParamServer>(
    s: &mut S,
    buf: &mut ParamSet,
    seen: &mut [u64],
    own: &mut Vec<u64>,
    workers: usize,
    clocks: std::ops::Range<u64>,
) {
    let d = dims();
    for c in clocks {
        for w in 0..workers {
            for l in 0..d.len() - 1 {
                let v = (c as f32 + 1.0) * 0.01
                    + (w as f32) * 0.001
                    + (l as f32) * 0.0001;
                s.apply_arrival(&msg(w, c, l, v));
            }
            s.commit(w);
        }
        let _ = s.fetch_into((c as usize) % workers, buf, seen, own);
    }
}

fn fresh_read_state(init: &ParamSet) -> (ParamSet, Vec<u64>, Vec<u64>) {
    (init.clone(), vec![0u64; init.n_layers()], Vec::new())
}

/// Satellite (c): a mid-frame disconnect while a FETCH is on the wire
/// — the proxy writes a torn prefix of the request and kills the
/// connection — must surface as a typed `Io` error with the pipeline
/// window drained, when supervision is off (`max_retries = 0`). No
/// panic, no desync, and the failure is sticky (the client is dead,
/// not confused).
#[test]
fn mid_frame_disconnect_during_fetch_surfaces_typed_io_error() {
    let init = ParamSet::zeros(&dims());
    let server = Arc::new(ShardedServer::new(init.clone(), 1, Policy::Async));
    let svc = ShardService::bind(Arc::clone(&server), "127.0.0.1:0", 1)
        .expect("bind service");
    let script =
        transport::chaos::parse_script("torn@fetch:1").expect("script");
    let proxy =
        ChaosProxy::spawn(svc.addrs()[0], script, 7).expect("spawn proxy");

    let no_supervision = FaultPolicy {
        max_retries: 0,
        ..supervised()
    };
    let mut client =
        RemoteClient::connect_with(&[proxy.addr()], no_supervision)
            .expect("connect through proxy")
            .with_pipeline(4)
            .expect("enable pipeline");

    // a non-empty in-flight window when the fault hits: the fetch
    // settles these two acks first (unfaulted), then its own request
    // frame is torn mid-write and the connection dies
    client.try_apply_arrival(&msg(0, 0, 0, 0.25)).unwrap();
    client.try_apply_arrival(&msg(0, 0, 1, 0.25)).unwrap();
    let (mut buf, mut seen, mut own) = fresh_read_state(&init);
    let e = client
        .try_fetch_into(0, &mut buf, &mut seen, &mut own)
        .expect_err("torn FETCH must fail");
    assert_eq!(e.kind, TransportErrorKind::Io, "typed Io, got: {e}");
    assert_eq!(proxy.events_fired(), 1, "the scripted tear fired");
    assert_eq!(client.in_flight(), 0, "window drained, not leaked");
    assert_eq!(client.reconnects(), 0, "supervision off: no redial");

    // sticky: the connection is gone and every later round-trip says
    // so (the write itself may still land in the dead socket's buffer)
    let e2 = client
        .try_apply_arrival(&msg(0, 0, 0, 0.5))
        .and_then(|_| client.flush())
        .expect_err("dead connection stays dead");
    assert_eq!(e2.kind, TransportErrorKind::Io);
    // the torn frame never parsed server-side: both settled updates
    // landed, the dead fetch applied nothing
    assert_eq!(server.applied(0, 0), 1);
    assert_eq!(server.applied(1, 0), 1);
    drop(client);
    drop(proxy);
    drop(svc);
}

/// Satellite (d): kill the connections mid-run with a non-empty
/// pipelined window — twice on UPDATE frames, once on a FETCH — and
/// the supervised client must reconnect, resync the window
/// exactly-once, and finish with final weights **bitwise equal** to a
/// never-faulted in-process run of the same schedule.
#[test]
fn reconnect_under_pipelining_is_bitwise_invisible() {
    let d = dims();
    let init = ParamSet::zeros(&d);
    let workers = 2;

    // the never-faulted oracle
    let mut oracle = ShardedServer::new(init.clone(), workers, Policy::Async);
    let (mut buf_a, mut seen_a, mut own_a) = fresh_read_state(&init);
    drive(&mut oracle, &mut buf_a, &mut seen_a, &mut own_a, workers, 0..8);

    // same schedule through proxied endpoints that die three times.
    // Counts are per-proxy and monotone (+1 per frame), so each event
    // fires exactly once on both endpoints: replayed UPDATEs after a
    // recovery only shift *when* the later kills land, never whether.
    let mut faulted = transport::loopback_chaos(
        init.clone(),
        workers,
        Policy::Async,
        2,
        Some(4),
        "kill@update:5;kill@update:11;kill@fetch:6",
        0xFA017,
    );
    let (mut buf_b, mut seen_b, mut own_b) = fresh_read_state(&init);
    drive(&mut faulted, &mut buf_b, &mut seen_b, &mut own_b, workers, 0..8);

    for proxy in faulted.chaos_proxies() {
        assert_eq!(proxy.events_fired(), 3, "every scripted fault fired");
    }
    assert!(
        faulted.reconnects() >= 3,
        "three kills need at least three recoveries, saw {}",
        faulted.reconnects()
    );
    // recovery is invisible: same master bits, same gated views
    assert_eq!(
        ParamServer::snapshot(&faulted),
        oracle.snapshot(),
        "final weights diverged across recoveries"
    );
    assert_eq!(buf_a, buf_b, "gated views diverged");
    assert_eq!(seen_a, seen_b, "gate vectors diverged");
    assert_eq!(own_a, own_b, "own-version vectors diverged");
    assert_eq!(faulted.in_flight(), 0, "window fully drained");
}

/// Tentpole lease acceptance: a worker heartbeats once with a short
/// lease and goes silent; a peer parked on the BSP barrier must be
/// *released* with a typed Server error naming the expired lease —
/// within roughly one lease interval plus one 50ms poll slice, not at
/// some distant io timeout, and never hanging.
#[test]
fn expired_lease_releases_parked_barrier_waiters() {
    let init = ParamSet::zeros(&dims());
    let server = Arc::new(ShardedServer::new(init, 2, Policy::Bsp));
    let svc = ShardService::bind(Arc::clone(&server), "127.0.0.1:0", 2)
        .expect("bind service");
    let mut client =
        RemoteClient::connect(&svc.addrs().to_vec()).expect("connect");

    // worker 1 announces liveness with an 80ms lease, then "dies"
    client.heartbeat(1, Duration::from_millis(80)).expect("heartbeat");
    // worker 0 finishes clock 0 and parks on the barrier: under BSP it
    // needs worker 1's commit, which will never come
    client.commit(0);
    let t0 = Instant::now();
    let e = client
        .try_wait_until_ready(0)
        .expect_err("the dead peer's lease must release this wait");
    let waited = t0.elapsed();
    assert_eq!(e.kind, TransportErrorKind::Server, "typed ERR, got: {e}");
    let text = e.to_string();
    assert!(
        text.contains("lease expired"),
        "error should name the expired lease: {text}"
    );
    assert!(
        waited < Duration::from_secs(5),
        "released promptly, not at an io timeout ({waited:?})"
    );

    // the connection survived the ERR: the same worker can keep going
    // once the dead peer is accounted for out of band
    assert_eq!(client.clock(0), 1);
    drop(client);
    drop(svc);
}

/// Elastic counterpart of the lease acceptance test: the same silence
/// that *fails* a barrier wait on a fixed-membership tier merely
/// *shrinks* the membership on an elastic one. A chaos `pause` freezes
/// the dead worker's relay — the stalled-process fault: sockets stay
/// open, no TCP error, heartbeats stop arriving — until its lease
/// lapses. The survivor's parked BSP barrier wait must then be
/// RELEASED with an OK (epoch 1, victim out of the live set), the run
/// must keep going, and the victim must be able to re-ADMIT (epoch 2).
#[test]
fn paused_heartbeat_evicts_worker_and_releases_barrier_elastic() {
    let init = ParamSet::zeros(&dims());
    let server = Arc::new(ShardedServer::new(init, 2, Policy::Bsp));
    let svc = ShardService::bind_with(
        Arc::clone(&server),
        "127.0.0.1:0",
        1,
        ServiceOptions { elastic: true, ..ServiceOptions::default() },
    )
    .expect("bind elastic service");

    // worker 1's endpoint sits behind the pause proxy: its second
    // HEARTBEAT freezes the relay for 500ms, far past the 80ms lease
    let script =
        transport::chaos::parse_script("pause:500@heartbeat:2").unwrap();
    let proxy =
        ChaosProxy::spawn(svc.addrs()[0], script, 3).expect("spawn proxy");
    let dead = RemoteClient::connect_with(&[proxy.addr()], supervised())
        .expect("connect dead worker");
    dead.heartbeat(1, Duration::from_millis(80)).expect("first beat");

    // the survivor connects directly (its own liveness is not at stake)
    // and ships a full clock — its own updates must land for Eq. 5's
    // read guarantee, the dead peer's never will
    let mut alive =
        RemoteClient::connect(&svc.addrs().to_vec()).expect("connect");
    alive.apply_arrival(&msg(0, 0, 0, 0.1));
    alive.apply_arrival(&msg(0, 0, 1, 0.1));
    ParamServer::commit(&mut alive, 0);

    // the renewal hits the pause and arrives only after the freeze —
    // by which time the lease has lapsed and the survivor's parked
    // wait has evicted the silent worker
    let beat = std::thread::spawn(move || {
        dead.heartbeat(1, Duration::from_millis(80)).expect("late beat");
        dead
    });
    let t0 = Instant::now();
    alive
        .try_wait_until_ready(0)
        .expect("elastic tier must release the wait with OK, not ERR");
    let waited = t0.elapsed();
    assert!(
        waited < Duration::from_secs(5),
        "released by the eviction, not an io timeout ({waited:?})"
    );
    assert_eq!(server.membership_epoch(), 1, "eviction bumped the epoch");
    assert!(!server.is_live(1), "the silent worker left the live set");
    assert_eq!(server.live_mask(), 0b01);
    // the survivor keeps training: barrier now spans only the live set
    alive.apply_arrival(&msg(0, 1, 0, 0.1));
    alive.apply_arrival(&msg(0, 1, 1, 0.1));
    ParamServer::commit(&mut alive, 0);
    alive.try_wait_until_ready(0).expect("live set of one never waits");

    // the dead worker comes back: re-admission fast-forwards it to the
    // live min and bumps the epoch again
    let dead = beat.join().unwrap();
    assert_eq!(proxy.events_fired(), 1, "the scripted pause fired");
    let epoch = dead.try_admit(1).expect("re-admission");
    assert_eq!(epoch, 2);
    assert!(server.is_live(1));
    assert_eq!(
        server.clock(1),
        2,
        "rejoiner fast-forwarded to the live min clock"
    );
    drop(alive);
    drop(proxy);
    drop(svc);
}

/// Rejoin-replay determinism over the wire: the same membership
/// schedule (leave at clock 3, rejoin at the live min, same update
/// streams) must produce **bitwise-identical** final weights on every
/// run — the property that makes convergence-vs-eviction sweeps
/// reproducible experiments rather than anecdotes.
#[test]
fn membership_schedule_replays_bitwise_over_elastic_transport() {
    fn elastic_run() -> (ParamSet, u64, u64) {
        let d = dims();
        let init = ParamSet::zeros(&d);
        let mut client = transport::loopback_elastic(
            init,
            2,
            Policy::Ssp { staleness: 2 },
            2,
        );
        assert!(client.elastic(), "handshake must negotiate elastic");
        let send = |cl: &mut RemoteClient, p: usize, c: u64| {
            for l in 0..dims().len() - 1 {
                let v = (c as f32 + 1.0) * 0.01
                    + p as f32 * 0.001
                    + l as f32 * 1e-4;
                cl.apply_arrival(&msg(p, c, l, v));
            }
            ParamServer::commit(cl, p);
        };
        for c in 0..3 {
            send(&mut client, 0, c);
            send(&mut client, 1, c);
        }
        assert_eq!(client.try_leave(1).expect("leave"), 1);
        // the survivor runs alone: the dead peer no longer bounds it
        for c in 3..6 {
            send(&mut client, 0, c);
        }
        assert_eq!(client.try_admit(1).expect("rejoin"), 2);
        let resume = client.clock(1);
        for c in 6..8 {
            send(&mut client, 0, c);
        }
        for c in resume..resume + 2 {
            send(&mut client, 1, c);
        }
        let (epoch, mask) = sspdnn::ssp::WorkerPort::membership(&mut client);
        assert_eq!((epoch, mask), (2, 0b11), "both live again at epoch 2");
        (ParamServer::snapshot(&client), resume, epoch)
    }
    let a = elastic_run();
    let b = elastic_run();
    assert_eq!(a.1, b.1, "rejoin clocks diverged across replays");
    assert_eq!(a.2, b.2, "epochs diverged across replays");
    assert_eq!(a.0, b.0, "final weights diverged across replays");
}

/// Warm restart: quiesce, dump `ServerState`, kill the whole tier,
/// restart a *new* service from the dump on a new port (advertising
/// the original init digest), and point the same supervised client at
/// it. The client's reconnect probe must accept the resumed revision
/// counters and the combined run must be bitwise equal to a
/// never-faulted one.
#[test]
fn warm_restart_from_state_dump_is_bitwise_invisible() {
    let d = dims();
    let init = ParamSet::zeros(&d);
    let workers = 2;

    let mut oracle = ShardedServer::new(init.clone(), workers, Policy::Async);
    let (mut buf_a, mut seen_a, mut own_a) = fresh_read_state(&init);
    drive(&mut oracle, &mut buf_a, &mut seen_a, &mut own_a, workers, 0..8);

    // lifetime 1: service behind a pass-through proxy (no scripted
    // faults — the "fault" here is the whole tier going away)
    let server1 = Arc::new(ShardedServer::new(init.clone(), workers, Policy::Async));
    let svc1 = ShardService::bind(Arc::clone(&server1), "127.0.0.1:0", 1)
        .expect("bind service 1");
    let proxy = ChaosProxy::spawn(svc1.addrs()[0], Vec::new(), 1)
        .expect("spawn proxy");
    let mut client = RemoteClient::connect_with(&[proxy.addr()], supervised())
        .expect("connect")
        .with_pipeline(4)
        .expect("enable pipeline");

    let (mut buf_b, mut seen_b, mut own_b) = fresh_read_state(&init);
    drive(&mut client, &mut buf_b, &mut seen_b, &mut own_b, workers, 0..4);
    client.flush().expect("quiesce the in-flight window");

    // operator runbook: quiescent dump, then the process goes away
    let state = server1.export_state();
    let path = std::env::temp_dir()
        .join(format!("sspdnn_warm_restart_{}.ssps", std::process::id()));
    checkpoint::save_state(&path, &state).expect("save state dump");
    proxy.kill_connections();
    drop(svc1);
    drop(server1);

    // lifetime 2: a fresh process resumes from the dump — trained
    // weights, revision counters, clock table — and advertises the
    // *config-derived init* digest exactly like `serve --state`
    let restored = checkpoint::load_state(&path).expect("load state dump");
    let server2 = Arc::new(ShardedServer::from_state(restored));
    let svc2 = ShardService::bind_with(
        Arc::clone(&server2),
        "127.0.0.1:0",
        1,
        ServiceOptions {
            init_digest: Some(transport::param_digest(&init)),
            ..ServiceOptions::default()
        },
    )
    .expect("bind service 2");
    proxy.retarget(svc2.addrs()[0]);

    // the next op hits the dead connection; the supervisor redials
    // through the retargeted proxy, revalidates the handshake, probes
    // the revision floor, and the run continues as if nothing happened
    drive(&mut client, &mut buf_b, &mut seen_b, &mut own_b, workers, 4..8);
    assert!(client.reconnects() >= 1, "the restart forced a reconnect");
    assert_eq!(
        ParamServer::snapshot(&client),
        oracle.snapshot(),
        "final weights diverged across the warm restart"
    );
    assert_eq!(buf_a, buf_b, "gated views diverged");
    assert_eq!(seen_a, seen_b, "gate vectors diverged");
    assert_eq!(own_a, own_b, "own-version vectors diverged");

    drop(client);
    drop(svc2);
    let _ = std::fs::remove_file(&path);
}

/// The one unabsorbable fault must be *loud*: a server that restarts
/// cold (fresh state, same config/init) hands the reconnect probe
/// regressed revision counters, and the client fails with a typed
/// `Protocol` error telling the operator to warm-restart from a dump
/// — instead of silently gate-skipping against bits it never held.
#[test]
fn cold_restart_is_detected_and_refused() {
    let d = dims();
    let init = ParamSet::zeros(&d);
    let workers = 2;

    let server1 = Arc::new(ShardedServer::new(init.clone(), workers, Policy::Async));
    let svc1 = ShardService::bind(Arc::clone(&server1), "127.0.0.1:0", 1)
        .expect("bind service 1");
    let proxy = ChaosProxy::spawn(svc1.addrs()[0], Vec::new(), 1)
        .expect("spawn proxy");
    let mut client = RemoteClient::connect_with(&[proxy.addr()], supervised())
        .expect("connect")
        .with_pipeline(4)
        .expect("enable pipeline");

    // traffic raises the layer revisions and, through the gated reads,
    // the client's revision floor
    let (mut buf, mut seen, mut own) = fresh_read_state(&init);
    drive(&mut client, &mut buf, &mut seen, &mut own, workers, 0..4);
    client.flush().expect("quiesce");

    // the tier dies and comes back COLD: same init (handshake digest
    // matches!), but every revision and clock reset
    proxy.kill_connections();
    drop(svc1);
    drop(server1);
    let server2 = Arc::new(ShardedServer::new(init.clone(), workers, Policy::Async));
    let svc2 = ShardService::bind(Arc::clone(&server2), "127.0.0.1:0", 1)
        .expect("bind service 2");
    proxy.retarget(svc2.addrs()[0]);

    // a pipelined write may land in the dead socket's buffer and
    // enqueue successfully; the flush forces the round-trip either way
    let e = client
        .try_apply_arrival(&msg(0, 4, 0, 0.5))
        .and_then(|_| client.flush())
        .expect_err("regressed revisions must be refused");
    assert_eq!(e.kind, TransportErrorKind::Protocol, "typed, got: {e}");
    assert!(
        e.to_string().contains("restarted without its state"),
        "error should diagnose the cold restart: {e}"
    );
    drop(client);
    drop(svc2);
    drop(proxy);
}
