//! Integration tests: the full SSP training stack (data → engine → ssp →
//! sim → coordinator → metrics) exercised end to end on small workloads.

use sspdnn::config::{DataKind, ExperimentConfig};
use sspdnn::coordinator::{
    build_dataset, run_experiment_on, DriverOptions, EtaSchedule,
};
use sspdnn::metrics;
use sspdnn::ssp::Policy;

fn tiny_cfg() -> ExperimentConfig {
    let mut c = ExperimentConfig::tiny();
    c.train.clocks = 20;
    c.train.batches_per_clock = 2;
    c
}

fn opts() -> DriverOptions {
    DriverOptions {
        per_batch_s: Some(0.02),
        eval_samples: 128,
        ..DriverOptions::default()
    }
}

#[test]
fn all_policies_converge_on_tiny() {
    let cfg = tiny_cfg();
    let ds = build_dataset(&cfg);
    for policy in [
        Policy::Bsp,
        Policy::Ssp { staleness: 3 },
        Policy::Ssp { staleness: 10 },
        Policy::Async,
    ] {
        let mut c = cfg.clone();
        c.ssp.policy = policy;
        let run = run_experiment_on(&c, opts(), &ds);
        let first = run.evals.first().unwrap().objective;
        assert!(
            run.final_objective < first,
            "{}: {first} -> {}",
            policy.name(),
            run.final_objective
        );
        assert!(run.final_objective.is_finite());
    }
}

#[test]
fn speedup_curve_is_sane_on_machine_sweep() {
    let mut cfg = tiny_cfg();
    // the paper's regime: step size small relative to the parallel update
    // accumulation (TIMIT uses eta=0.05); large eta at high machine
    // counts trades statistical efficiency for none of the time win.
    cfg.train.eta = 0.15;
    cfg.train.clocks = 40;
    let ds = build_dataset(&cfg);
    let runs: Vec<_> = [1usize, 2, 4, 6]
        .iter()
        .map(|&n| {
            run_experiment_on(
                &cfg,
                DriverOptions {
                    machines: Some(n),
                    ..opts()
                },
                &ds,
            )
        })
        .collect();
    let sp = metrics::speedups(&runs);
    assert_eq!(sp[0], (1, 1.0));
    let last = sp.last().unwrap();
    assert!(last.1 > 1.0, "6 machines faster than 1: {sp:?}");
    assert!(last.1 <= 6.1, "not super-linear: {sp:?}");
}

#[test]
fn imagenet_kind_dataset_trains() {
    let mut cfg = ExperimentConfig::tiny();
    cfg.data.kind = DataKind::ImagenetLike;
    cfg.train.clocks = 12;
    let ds = build_dataset(&cfg);
    assert!(ds.x.data().iter().all(|&v| v >= 0.0), "LLC codes nonneg");
    let run = run_experiment_on(&cfg, opts(), &ds);
    assert!(run.final_objective < run.evals[0].objective);
}

#[test]
fn epsilon_rate_degrades_with_lossy_network() {
    let mut cfg = tiny_cfg();
    cfg.cluster.drop_prob = 0.0;
    let ds = build_dataset(&cfg);
    let clean = run_experiment_on(&cfg, opts(), &ds);
    cfg.cluster.drop_prob = 0.6;
    cfg.cluster.latency_s = 5e-3; // slow, congested network
    let lossy = run_experiment_on(&cfg, opts(), &ds);
    assert!(
        lossy.epsilon_rate <= clean.epsilon_rate,
        "lossy eps {} should not exceed clean {}",
        lossy.epsilon_rate,
        clean.epsilon_rate
    );
    assert!(lossy.congestion_events > 0);
    // SSP guarantee still holds: training still converges
    assert!(lossy.final_objective < lossy.evals[0].objective);
}

#[test]
fn decaying_eta_still_converges() {
    let cfg = tiny_cfg();
    let ds = build_dataset(&cfg);
    let run = run_experiment_on(
        &cfg,
        DriverOptions {
            eta: Some(EtaSchedule::Poly { eta0: 0.8, d: 0.3 }),
            ..opts()
        },
        &ds,
    );
    assert!(run.final_objective < run.evals[0].objective);
}

#[test]
fn run_metrics_are_consistent() {
    let cfg = tiny_cfg();
    let ds = build_dataset(&cfg);
    let run = run_experiment_on(&cfg, opts(), &ds);
    // every committed clock ships one message per layer
    let layers = (cfg.model.dims.len() - 1) as u64;
    let clocks = cfg.train.clocks as u64 * cfg.cluster.machines as u64;
    assert_eq!(run.messages, clocks * layers);
    assert!(run.bytes > 0);
    assert_eq!(run.steps, clocks * cfg.train.batches_per_clock as u64);
    // evals are time-ordered with non-decreasing clocks
    for w in run.evals.windows(2) {
        assert!(w[1].vtime >= w[0].vtime);
        assert!(w[1].clock >= w[0].clock);
    }
    // objective curve CSV shape
    let csv = metrics::curve_csv(&run);
    assert_eq!(csv.lines().count(), run.evals.len() + 1);
}

#[test]
fn barrier_bounds_clock_spread() {
    // with heavy stragglers and s=1 the run must still finish (no
    // deadlock) and the barrier must have been exercised
    let mut cfg = tiny_cfg();
    cfg.cluster.straggler_prob = 0.4;
    cfg.cluster.straggler_factor = 10.0;
    cfg.ssp.policy = Policy::Ssp { staleness: 1 };
    let ds = build_dataset(&cfg);
    let run = run_experiment_on(&cfg, opts(), &ds);
    assert!(run.barrier_wait_s > 0.0, "stragglers must trigger waits");
    assert_eq!(run.steps, 20 * 2 * 3);
}

#[test]
fn clock_loss_curve_has_entries_for_every_clock() {
    let cfg = tiny_cfg();
    let ds = build_dataset(&cfg);
    let run = run_experiment_on(&cfg, opts(), &ds);
    assert_eq!(run.clock_loss.len(), cfg.train.clocks);
    assert!(run.clock_loss.iter().all(|l| l.is_finite()));
    // training loss should also descend on average
    let n = run.clock_loss.len();
    let early: f64 = run.clock_loss[..n / 3].iter().sum::<f64>() / (n / 3) as f64;
    let late: f64 =
        run.clock_loss[2 * n / 3..].iter().sum::<f64>() / (n - 2 * n / 3) as f64;
    assert!(late < early, "{early} -> {late}");
}
