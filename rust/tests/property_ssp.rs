//! Property-based tests of the SSP protocol invariants (hand-rolled
//! randomized harness over `Pcg64` — the offline vendor set has no
//! proptest; each property runs hundreds of randomized trials and shrinks
//! nothing but reports the failing seed).
//!
//! Invariants (paper §3.1 / Ho et al. 2013):
//!  P1  bounded staleness: fastest − slowest ≤ s at every instant
//!  P2  conservation: master = init + Σ all applied updates (additivity,
//!      order-independence)
//!  P3  guaranteed visibility: at a read in clock c every (q, t≤c−s−1)
//!      update is included
//!  P4  read-my-writes: a worker's own committed updates are always in
//!      its view
//!  P5  ε accounting: included + missed = committed − guaranteed, rate ∈ [0,1]
//!
//! Every server-side invariant runs against **all four** backings of
//! `ParamServer` — the single-lock reference `Server`, the sharded
//! per-layer `ShardedServer`, `transport::RemoteClient` speaking the
//! framed wire protocol to a loopback-TCP `ShardService`, and the same
//! client against the *split* tier (one independent per-group server
//! process' worth of state, commits pipelined through a bounded
//! in-flight window). The remote trials use fewer seeds: each one
//! stands up a real socket stack. Oracle-equivalence properties drive
//! pairs of backings through identical random schedules asserting
//! bitwise-equal masters, own-version vectors and ε statistics at
//! every read.
//!
//! Every read additionally runs through the **version-gated zero-copy
//! path** (`fetch_into`): each worker keeps one reusable snapshot buffer
//! plus its per-layer last-seen revision vector across the whole random
//! schedule (stale vectors, interleaved commits, arbitrary gaps between
//! that worker's reads), and after every gated read the buffer must
//! equal the full `fetch` snapshot exactly, with identical own-version
//! and ε accounting.

use sspdnn::nn::{LayerParams, ParamSet};
use sspdnn::ssp::transport::{self, RemoteClient};
use sspdnn::ssp::{
    ClockTable, ParamServer, Policy, Server, ShardedServer, UpdateMsg,
    WorkerCache,
};
use sspdnn::tensor::Matrix;
use sspdnn::util::Pcg64;

fn dims() -> Vec<usize> {
    vec![3, 4, 2]
}

fn rand_delta(dims: &[usize], layer: usize, rng: &mut Pcg64) -> LayerParams {
    LayerParams {
        w: Matrix::randn(dims[layer], dims[layer + 1], 0.1, rng),
        b: (0..dims[layer + 1])
            .map(|_| rng.normal_f32(0.0, 0.1))
            .collect(),
    }
}

fn make_reference(init: ParamSet, workers: usize, policy: Policy) -> Server {
    Server::new(init, workers, policy)
}

fn make_sharded(init: ParamSet, workers: usize, policy: Policy) -> ShardedServer {
    ShardedServer::new(init, workers, policy)
}

/// The third backing: a `RemoteClient` over loopback TCP to a
/// `ShardService` wrapping a `ShardedServer` — with 2 shard groups, so
/// every multi-endpoint seam (per-group fetch fan-out, per-layer update
/// routing, own/stat reassembly) is exercised.
fn make_remote(init: ParamSet, workers: usize, policy: Policy) -> RemoteClient {
    transport::loopback(init, workers, policy, 2)
}

/// The fourth backing: the exclusive multi-process tier — one
/// independent full server per shard group, each serving only its own
/// range (what two `sspdnn serve --group` processes hold) — with
/// commits *pipelined* through a deliberately small in-flight window,
/// so window-full drains happen constantly under the random schedules.
fn make_remote_split(
    init: ParamSet,
    workers: usize,
    policy: Policy,
) -> RemoteClient {
    transport::loopback_split(init, workers, policy, 2, Some(4))
}

/// The fifth backing: the shared loopback tier with **every endpoint
/// behind a deterministic fault-injection proxy** and the client
/// supervised (10 reconnect attempts, 5ms backoff). The script tears a
/// FETCH request mid-frame and kills connections at fixed UPDATE
/// frame counts, so every trial exercises reconnect + handshake
/// revalidation + in-flight-window resync — and the invariants (and
/// bitwise oracle equivalence) must hold exactly as if the faults
/// never happened. Kill drops the matched frame before the teardown
/// and a torn frame never parses server-side, so no request is ever
/// double-applied or double-counted. The script stays on one opcode
/// (UPDATE, the most frequent frame) so its events fire in order on
/// every random schedule regardless of how the other ops interleave.
fn make_remote_chaos(
    init: ParamSet,
    workers: usize,
    policy: Policy,
) -> RemoteClient {
    transport::loopback_chaos(
        init,
        workers,
        policy,
        2,
        Some(4),
        "kill@update:3;torn@update:8;kill@update:14",
        0xC4A05,
    )
}

/// Drive a random but protocol-legal schedule against the server:
/// each step, a random non-blocked worker commits a clock; its per-layer
/// updates arrive after a random backlog of earlier arrivals drains.
fn random_schedule<S: ParamServer>(
    make: fn(ParamSet, usize, Policy) -> S,
    seed: u64,
    workers: usize,
    staleness: u64,
    steps: usize,
) {
    let mut rng = Pcg64::new(seed);
    let d = dims();
    let init = ParamSet::glorot(&d, &mut rng);
    let policy = Policy::Ssp { staleness };
    let mut server = make(init.clone(), workers, policy);
    let mut expected = init.clone(); // P2 accumulator
    let mut pending: Vec<UpdateMsg> = Vec::new(); // in-flight messages
    let mut committed = vec![0u64; workers];
    // per-worker reusable gated-fetch state, live across the whole
    // schedule: (snapshot buffer, last-seen revisions, own scratch)
    let mut gated: Vec<(ParamSet, Vec<u64>, Vec<u64>)> = (0..workers)
        .map(|_| (init.clone(), vec![0u64; d.len() - 1], Vec::new()))
        .collect();

    for _ in 0..steps {
        // pick a worker allowed to proceed
        let candidates: Vec<usize> =
            (0..workers).filter(|&p| !server.must_wait(p)).collect();
        assert!(
            !candidates.is_empty(),
            "P1 deadlock: every worker blocked (seed {seed})"
        );
        let p = candidates[rng.below(candidates.len())];

        // deliver a random prefix of pending arrivals (FIFO per worker)
        let deliver = rng.below(pending.len() + 1);
        for msg in pending.drain(..deliver) {
            server.apply_arrival(&msg);
        }

        // worker p commits its next clock
        let c = committed[p];
        for l in 0..d.len() - 1 {
            let delta = rand_delta(&d, l, &mut rng);
            // track expected master state (P2)
            expected.axpy_layer(l, 1.0, &delta);
            pending.push(UpdateMsg::new(p, c, l, delta));
        }
        committed[p] += 1;
        server.commit(p);

        // P1: staleness bound holds after every commit
        let min = (0..workers).map(|q| server.clock(q)).min().unwrap();
        let max = (0..workers).map(|q| server.clock(q)).max().unwrap();
        assert!(
            max - min <= staleness + 1,
            "P1 violated: spread {} > s+1={} (seed {seed})",
            max - min,
            staleness + 1
        );

        // P5 on a random reader that is read-ready
        let reader = rng.below(workers);
        if server.read_ready(reader) {
            let (snap, own_full, stats) = server.fetch(reader);
            let rate = stats.epsilon_rate();
            assert!((0.0..=1.0).contains(&rate), "P5 rate {rate} (seed {seed})");
            // the gated zero-copy read, resuming from this worker's
            // possibly-stale buffer, must reproduce the full fetch
            let (buf, seen, own) = &mut gated[reader];
            let (st2, _) = server.fetch_into(reader, buf, seen, own);
            assert_eq!(
                *buf, snap,
                "gated buffer != full snapshot (seed {seed})"
            );
            assert_eq!(*own, own_full, "gated own diverged (seed {seed})");
            assert_eq!(st2, stats, "gated eps stats diverged (seed {seed})");
        }
    }

    // drain everything → P2 conservation
    for msg in pending.drain(..) {
        server.apply_arrival(&msg);
    }
    let master = server.snapshot();
    let dist = master.dist_sq(&expected).sqrt();
    assert!(
        dist < 1e-3,
        "P2 violated: master != init + sum(updates), dist {dist} (seed {seed})"
    );
}

#[test]
fn p1_p2_p5_hold_over_random_schedules_reference() {
    for seed in 0..60 {
        let workers = 2 + (seed as usize % 5);
        let staleness = seed % 7;
        random_schedule(make_reference, seed, workers, staleness, 120);
    }
}

#[test]
fn p1_p2_p5_hold_over_random_schedules_sharded() {
    for seed in 0..60 {
        let workers = 2 + (seed as usize % 5);
        let staleness = seed % 7;
        random_schedule(make_sharded, seed, workers, staleness, 120);
    }
}

#[test]
fn p1_p2_p5_hold_over_random_schedules_remote() {
    // fewer, shorter trials: every one spins up a real loopback TCP
    // service and each protocol step is a round of synchronous RPCs
    for seed in 0..10 {
        let workers = 2 + (seed as usize % 5);
        let staleness = seed % 7;
        random_schedule(make_remote, seed, workers, staleness, 60);
    }
}

#[test]
fn p1_p2_p5_hold_over_random_schedules_remote_split_pipelined() {
    // fewer still: each trial stands up one socket stack per shard group
    for seed in 0..6 {
        let workers = 2 + (seed as usize % 5);
        let staleness = seed % 7;
        random_schedule(make_remote_split, seed, workers, staleness, 60);
    }
}

#[test]
fn p1_p2_p5_hold_over_random_schedules_under_scripted_faults() {
    // fewest: each trial stands up sockets *plus* one chaos proxy per
    // endpoint, and absorbs several scripted connection kills
    for seed in 0..4 {
        let workers = 2 + (seed as usize % 5);
        let staleness = seed % 7;
        random_schedule(make_remote_chaos, seed, workers, staleness, 60);
    }
}

/// Two backings must be *indistinguishable* under any legal schedule:
/// same master bits, same own-version vector, same ε statistics at
/// every read — both through the full fetch and through the gated
/// zero-copy path resuming from reused buffers. `make_a` builds the
/// oracle, `make_b` the implementation under test.
fn equivalence_schedule<A: ParamServer, B: ParamServer>(
    make_a: fn(ParamSet, usize, Policy) -> A,
    make_b: fn(ParamSet, usize, Policy) -> B,
    seed: u64,
    steps: usize,
) {
    let mut rng = Pcg64::new(seed ^ 0x5EED);
    let d = dims();
    let workers = 2 + (seed as usize % 4);
    let staleness = seed % 5;
    let policy = if seed % 7 == 0 {
        Policy::Async
    } else if seed % 5 == 0 {
        Policy::Bsp
    } else {
        Policy::Ssp { staleness }
    };
    let init = ParamSet::glorot(&d, &mut rng);
    let mut reference = make_a(init.clone(), workers, policy);
    let mut sharded = make_b(init.clone(), workers, policy);

    let mut pending: Vec<UpdateMsg> = Vec::new();
    let mut committed = vec![0u64; workers];
    // persistent gated-read state per (implementation, worker)
    let mut gated_ref: Vec<(ParamSet, Vec<u64>, Vec<u64>)> = (0..workers)
        .map(|_| (init.clone(), vec![0u64; d.len() - 1], Vec::new()))
        .collect();
    let mut gated_sh = gated_ref.clone();
    for _ in 0..steps {
        // both servers must agree on who may proceed
        for p in 0..workers {
            assert_eq!(
                ParamServer::must_wait(&reference, p),
                ParamServer::must_wait(&sharded, p),
                "must_wait diverged (seed {seed})"
            );
            assert_eq!(
                ParamServer::read_ready(&reference, p),
                ParamServer::read_ready(&sharded, p),
                "read_ready diverged (seed {seed})"
            );
        }
        let candidates: Vec<usize> = (0..workers)
            .filter(|&p| !ParamServer::must_wait(&reference, p))
            .collect();
        let p = candidates[rng.below(candidates.len())];

        let deliver = rng.below(pending.len() + 1);
        for msg in pending.drain(..deliver) {
            ParamServer::apply_arrival(&mut reference, &msg);
            ParamServer::apply_arrival(&mut sharded, &msg);
        }
        for l in 0..d.len() - 1 {
            let delta = rand_delta(&d, l, &mut rng);
            pending.push(UpdateMsg::new(p, committed[p], l, delta));
        }
        committed[p] += 1;
        ParamServer::commit(&mut reference, p);
        ParamServer::commit(&mut sharded, p);

        let reader = rng.below(workers);
        if ParamServer::read_ready(&reference, reader) {
            let (m_ref, own_ref, st_ref) =
                ParamServer::fetch(&mut reference, reader);
            let (m_sh, own_sh, st_sh) =
                ParamServer::fetch(&mut sharded, reader);
            assert_eq!(m_ref, m_sh, "master bits diverged (seed {seed})");
            assert_eq!(own_ref, own_sh, "own versions diverged (seed {seed})");
            assert_eq!(st_ref, st_sh, "eps stats diverged (seed {seed})");

            // the gated path must agree across implementations AND
            // with the full fetch, resuming from reused buffers
            let (b_r, s_r, o_r) = &mut gated_ref[reader];
            let (st_gr, fs_r) = ParamServer::fetch_into(
                &mut reference,
                reader,
                b_r,
                s_r,
                o_r,
            );
            let (b_s, s_s, o_s) = &mut gated_sh[reader];
            let (st_gs, fs_s) = ParamServer::fetch_into(
                &mut sharded,
                reader,
                b_s,
                s_s,
                o_s,
            );
            assert_eq!(*b_r, m_ref, "gated ref buffer (seed {seed})");
            assert_eq!(b_r, b_s, "gated buffers diverged (seed {seed})");
            assert_eq!(o_r, o_s, "gated own diverged (seed {seed})");
            assert_eq!(st_gr, st_ref, "gated stats != full (seed {seed})");
            assert_eq!(st_gr, st_gs, "gated stats diverged (seed {seed})");
            assert_eq!(
                fs_r, fs_s,
                "copy gate accounting diverged (seed {seed})"
            );
            assert_eq!(
                s_r, s_s,
                "last-seen revisions diverged (seed {seed})"
            );
        }
    }
    for msg in pending.drain(..) {
        ParamServer::apply_arrival(&mut reference, &msg);
        ParamServer::apply_arrival(&mut sharded, &msg);
    }
    assert_eq!(
        ParamServer::snapshot(&reference),
        ParamServer::snapshot(&sharded),
        "final master diverged (seed {seed})"
    );
    assert_eq!(ParamServer::reads(&reference), ParamServer::reads(&sharded));
}

/// The sharded server against the single-lock oracle.
#[test]
fn sharded_server_is_bitwise_equivalent_to_reference() {
    for seed in 0..40u64 {
        equivalence_schedule(make_reference, make_sharded, seed, 150);
    }
}

/// The remote client (loopback TCP, 2 shard endpoints) against the
/// single-lock oracle: the entire wire protocol — framing, per-group
/// fan-out, gated delta payloads, own/ε reassembly — must be
/// observation-equivalent to shared memory, bit for bit.
#[test]
fn remote_client_is_bitwise_equivalent_to_reference() {
    for seed in 0..8u64 {
        equivalence_schedule(make_reference, make_remote, seed, 80);
    }
}

/// The split tier with pipelined commits against the single-lock
/// oracle: COMMIT broadcast keeps N private clock tables in lockstep,
/// group-scoped readiness ANDs back to the global predicate, ε
/// statistics reassemble exactly, and the in-flight window drains
/// whenever the staleness gate needs an answer — all of it
/// observation-equivalent to shared memory, bit for bit.
#[test]
fn split_pipelined_client_is_bitwise_equivalent_to_reference() {
    for seed in 0..6u64 {
        equivalence_schedule(make_reference, make_remote_split, seed, 80);
    }
}

/// The tentpole acceptance pin: a supervised client whose connections
/// are scripted to die mid-schedule — torn frames, dropped frames,
/// reconnects with a non-empty in-flight window — must still be
/// **bitwise** indistinguishable from the shared-memory oracle at
/// every read: same master bits, same own-version vectors, same ε
/// statistics, same read counters. Recovery is invisible or it is
/// wrong.
#[test]
fn chaos_faulted_client_is_bitwise_equivalent_to_reference() {
    for seed in 0..4u64 {
        equivalence_schedule(make_reference, make_remote_chaos, seed, 80);
    }
}

/// Elastic membership under one consistency policy: drive a legal
/// random schedule, kill one worker a third of the way in (its
/// undelivered updates are lost with it), keep going on the survivors,
/// then re-admit it two thirds in — and the implementation under test
/// must stay bitwise-indistinguishable from the single-lock oracle at
/// every read, with the staleness bound holding over the *live* set
/// throughout.
fn eviction_schedule<A: ParamServer, B: ParamServer>(
    make_a: fn(ParamSet, usize, Policy) -> A,
    make_b: fn(ParamSet, usize, Policy) -> B,
    policy: Policy,
    seed: u64,
    steps: usize,
) {
    let mut rng = Pcg64::new(seed ^ 0xE1A5);
    let d = dims();
    let workers = 3 + (seed as usize % 3);
    let victim = seed as usize % workers;
    let init = ParamSet::glorot(&d, &mut rng);
    let mut oracle = make_a(init.clone(), workers, policy);
    let mut subject = make_b(init.clone(), workers, policy);

    let mut pending: Vec<UpdateMsg> = Vec::new();
    let mut committed = vec![0u64; workers];
    let mut live = vec![true; workers];
    let evict_at = steps / 3;
    let admit_at = 2 * steps / 3;

    for step in 0..steps {
        if step == evict_at {
            // the death: whatever the victim had in flight is lost
            pending.retain(|m| m.from != victim);
            let ea = ParamServer::evict_worker(&mut oracle, victim);
            let eb = ParamServer::evict_worker(&mut subject, victim);
            assert_eq!(ea, 1, "first transition is epoch 1 (seed {seed})");
            assert_eq!(ea, eb, "eviction epochs diverged (seed {seed})");
            live[victim] = false;
        }
        if step == admit_at {
            // quiesce before the rejoin: admission fast-forwards the
            // victim's version rows, so every in-flight update sent
            // before the admission must land first (the sim driver
            // enforces the same drain by dropping them outright)
            for m in pending.drain(..) {
                ParamServer::apply_arrival(&mut oracle, &m);
                ParamServer::apply_arrival(&mut subject, &m);
            }
            let ea = ParamServer::admit_worker(&mut oracle, victim);
            let eb = ParamServer::admit_worker(&mut subject, victim);
            assert_eq!(ea, 2, "rejoin is epoch 2 (seed {seed})");
            assert_eq!(ea, eb, "admission epochs diverged (seed {seed})");
            live[victim] = true;
            // the rejoiner resumes at its fast-forwarded clock
            committed[victim] = oracle.clock(victim);
            assert_eq!(
                committed[victim],
                subject.clock(victim),
                "fast-forwarded clocks diverged (seed {seed})"
            );
        }

        for p in (0..workers).filter(|&p| live[p]) {
            assert_eq!(
                ParamServer::must_wait(&oracle, p),
                ParamServer::must_wait(&subject, p),
                "must_wait diverged (seed {seed})"
            );
            assert_eq!(
                ParamServer::read_ready(&oracle, p),
                ParamServer::read_ready(&subject, p),
                "read_ready diverged (seed {seed})"
            );
        }
        let candidates: Vec<usize> = (0..workers)
            .filter(|&p| live[p] && !ParamServer::must_wait(&oracle, p))
            .collect();
        assert!(
            !candidates.is_empty(),
            "live workers deadlocked post-eviction (seed {seed})"
        );
        let p = candidates[rng.below(candidates.len())];

        let deliver = rng.below(pending.len() + 1);
        for m in pending.drain(..deliver) {
            ParamServer::apply_arrival(&mut oracle, &m);
            ParamServer::apply_arrival(&mut subject, &m);
        }
        for l in 0..d.len() - 1 {
            let delta = rand_delta(&d, l, &mut rng);
            pending.push(UpdateMsg::new(p, committed[p], l, delta));
        }
        committed[p] += 1;
        ParamServer::commit(&mut oracle, p);
        ParamServer::commit(&mut subject, p);

        // P1 over the live set: the dead worker's frozen clock neither
        // bounds nor is bounded
        let lmin = (0..workers)
            .filter(|&q| live[q])
            .map(|q| oracle.clock(q))
            .min()
            .unwrap();
        let lmax = (0..workers)
            .filter(|&q| live[q])
            .map(|q| oracle.clock(q))
            .max()
            .unwrap();
        let bound = match policy {
            Policy::Bsp => 1,
            Policy::Ssp { staleness } => staleness + 1,
            Policy::Async => u64::MAX,
        };
        assert!(
            lmax - lmin <= bound,
            "live-set P1 violated: spread {} > {bound} (seed {seed})",
            lmax - lmin
        );

        let reader = candidates[rng.below(candidates.len())];
        if ParamServer::read_ready(&oracle, reader) {
            let (m_a, own_a, st_a) = ParamServer::fetch(&mut oracle, reader);
            let (m_b, own_b, st_b) = ParamServer::fetch(&mut subject, reader);
            assert_eq!(m_a, m_b, "master bits diverged (seed {seed})");
            assert_eq!(own_a, own_b, "own versions diverged (seed {seed})");
            assert_eq!(st_a, st_b, "eps stats diverged (seed {seed})");
            let rate = st_a.epsilon_rate();
            assert!(
                (0.0..=1.0).contains(&rate),
                "P5 rate {rate} across membership change (seed {seed})"
            );
        }
    }
    for m in pending.drain(..) {
        ParamServer::apply_arrival(&mut oracle, &m);
        ParamServer::apply_arrival(&mut subject, &m);
    }
    assert_eq!(
        ParamServer::snapshot(&oracle),
        ParamServer::snapshot(&subject),
        "final master diverged (seed {seed})"
    );
}

/// Every staleness policy the suite covers, with a mid-run death and a
/// rejoin, on the sharded implementation.
#[test]
fn eviction_and_rejoin_match_reference_under_every_policy_sharded() {
    for (i, policy) in [
        Policy::Bsp,
        Policy::Ssp { staleness: 0 },
        Policy::Ssp { staleness: 1 },
        Policy::Ssp { staleness: 3 },
        Policy::Async,
    ]
    .into_iter()
    .enumerate()
    {
        for seed in 0..8u64 {
            eviction_schedule(
                make_reference,
                make_sharded,
                policy,
                seed * 31 + i as u64,
                90,
            );
        }
    }
}

/// The same membership schedules over the wire: LEAVE/ADMIT against
/// elastic loopback endpoints (shared tier).
fn make_remote_elastic(
    init: ParamSet,
    workers: usize,
    policy: Policy,
) -> RemoteClient {
    transport::loopback_elastic(init, workers, policy, 2)
}

/// ... and against the elastic *split* tier: one private server per
/// group, pipelined commits, membership changes broadcast like COMMITs.
fn make_remote_split_elastic(
    init: ParamSet,
    workers: usize,
    policy: Policy,
) -> RemoteClient {
    transport::loopback_split_elastic(init, workers, policy, 2, Some(4))
}

#[test]
fn eviction_and_rejoin_match_reference_under_every_policy_remote() {
    for (i, policy) in [
        Policy::Bsp,
        Policy::Ssp { staleness: 0 },
        Policy::Ssp { staleness: 1 },
        Policy::Ssp { staleness: 3 },
        Policy::Async,
    ]
    .into_iter()
    .enumerate()
    {
        // one socket stack per trial: fewer seeds, shorter schedules
        for seed in 0..2u64 {
            eviction_schedule(
                make_reference,
                make_remote_elastic,
                policy,
                seed * 31 + i as u64,
                45,
            );
        }
    }
}

#[test]
fn eviction_and_rejoin_match_reference_under_every_policy_remote_split() {
    for (i, policy) in [
        Policy::Bsp,
        Policy::Ssp { staleness: 0 },
        Policy::Ssp { staleness: 1 },
        Policy::Ssp { staleness: 3 },
        Policy::Async,
    ]
    .into_iter()
    .enumerate()
    {
        for seed in 0..2u64 {
            eviction_schedule(
                make_reference,
                make_remote_split_elastic,
                policy,
                seed * 31 + i as u64,
                40,
            );
        }
    }
}

/// Lease-expiry ε accounting (regression): an evicted worker's
/// *applied* history keeps counting in the ε totals, while its
/// committed-but-never-applied window contributions are dropped —
/// exactly once, not once per read. The post-eviction `ReadStats` must
/// equal a never-faulted oracle in which the victim only ever
/// committed what actually arrived.
fn epsilon_stats_after_eviction<S: ParamServer>(
    make: fn(ParamSet, usize, Policy) -> S,
) -> (sspdnn::ssp::ReadStats, ParamSet) {
    let d = dims();
    let policy = Policy::Ssp { staleness: 8 };
    let flat = |c: u64, p: usize, l: usize| {
        let v = (c as f32 + 1.0) * 0.01 + p as f32 * 0.001 + l as f32 * 1e-4;
        UpdateMsg::new(
            p,
            c,
            l,
            LayerParams {
                w: Matrix::from_fn(d[l], d[l + 1], |_, _| v),
                b: vec![v; d[l + 1]],
            },
        )
    };
    let mut server = make(ParamSet::zeros(&d), 3, policy);
    // workers 0 and 1: three clocks each, everything applied
    for c in 0..3u64 {
        for p in [0usize, 1] {
            for l in 0..d.len() - 1 {
                server.apply_arrival(&flat(c, p, l));
            }
            server.commit(p);
        }
    }
    // worker 2: commits five clocks, but only the first two clocks'
    // updates ever arrive — three clocks' worth die on the wire with it
    for c in 0..5u64 {
        if c < 2 {
            for l in 0..d.len() - 1 {
                server.apply_arrival(&flat(c, 2, l));
            }
        }
        server.commit(2);
    }
    let before = ParamServer::fetch(&mut server, 0).2;
    assert!(
        before.window_missed >= 3 * (d.len() - 1) as u64,
        "pre-eviction stats must count the in-flight window as missed"
    );
    assert_eq!(ParamServer::evict_worker(&mut server, 2), 1);
    let first = ParamServer::fetch(&mut server, 0);
    let second = ParamServer::fetch(&mut server, 0);
    assert_eq!(
        first.2, second.2,
        "the drop must happen exactly once, not per read"
    );
    assert_eq!(first.1, second.1);
    (first.2, ParamServer::snapshot(&server))
}

#[test]
fn eviction_drops_pending_window_contributions_exactly_once() {
    let d = dims();
    let flat = |c: u64, p: usize, l: usize| {
        let v = (c as f32 + 1.0) * 0.01 + p as f32 * 0.001 + l as f32 * 1e-4;
        UpdateMsg::new(
            p,
            c,
            l,
            LayerParams {
                w: Matrix::from_fn(d[l], d[l + 1], |_, _| v),
                b: vec![v; d[l + 1]],
            },
        )
    };
    // the never-faulted oracle: worker 2 only ever committed the two
    // clocks that actually arrived
    let mut oracle =
        make_reference(ParamSet::zeros(&d), 3, Policy::Ssp { staleness: 8 });
    for c in 0..3u64 {
        for p in [0usize, 1] {
            for l in 0..d.len() - 1 {
                oracle.apply_arrival(&flat(c, p, l));
            }
            oracle.commit(p);
        }
    }
    for c in 0..2u64 {
        for l in 0..d.len() - 1 {
            oracle.apply_arrival(&flat(c, 2, l));
        }
        oracle.commit(2);
    }
    let (want, master_oracle) = {
        let (m, _, st) = ParamServer::fetch(&mut oracle, 0);
        (st, m)
    };

    let (st_ref, m_ref) = epsilon_stats_after_eviction(make_reference);
    let (st_sh, m_sh) = epsilon_stats_after_eviction(make_sharded);
    assert_eq!(
        st_ref, want,
        "evicted worker's ε totals != never-faulted oracle (reference)"
    );
    assert_eq!(
        st_sh, want,
        "evicted worker's ε totals != never-faulted oracle (sharded)"
    );
    assert_eq!(m_ref, master_oracle, "applied history must stay in theta");
    assert_eq!(m_sh, master_oracle);
}

fn p3_guaranteed_visibility<S: ParamServer>(
    make: fn(ParamSet, usize, Policy) -> S,
) {
    // read_ready(p) must be false exactly while some guaranteed update is
    // missing; fetch after read_ready includes all of them.
    for seed in 0..40u64 {
        let mut rng = Pcg64::new(seed ^ 0xBEEF);
        let d = dims();
        let workers = 3;
        let s = 1u64;
        let mut server =
            make(ParamSet::zeros(&d), workers, Policy::Ssp { staleness: s });
        // all workers commit 2 clocks, arrivals randomly delayed
        let mut pending = Vec::new();
        for c in 0..2u64 {
            for p in 0..workers {
                for l in 0..d.len() - 1 {
                    pending.push(UpdateMsg::new(p, c, l, rand_delta(&d, l, &mut rng)));
                }
                server.commit(p);
            }
        }
        rng.shuffle(&mut pending);
        // stable-sort by (worker, clock) to respect FIFO per worker
        pending.sort_by_key(|m| (m.from, m.clock));

        // worker 0 is at clock 2; needs all ts ≤ 0 applied (s=1)
        let mut applied = 0;
        while !server.read_ready(0) {
            assert!(
                applied < pending.len(),
                "read never became ready (seed {seed})"
            );
            server.apply_arrival(&pending[applied]);
            applied += 1;
        }
        // every clock-0 update must now be applied, for every layer
        for l in 0..d.len() - 1 {
            for q in 0..workers {
                assert!(
                    server.applied(l, q) >= 1,
                    "P3: missing guaranteed update layer {l} worker {q} (seed {seed})"
                );
            }
        }
    }
}

#[test]
fn p3_guaranteed_visibility_enforced_by_read_ready_reference() {
    p3_guaranteed_visibility(make_reference);
}

#[test]
fn p3_guaranteed_visibility_enforced_by_read_ready_sharded() {
    p3_guaranteed_visibility(make_sharded);
}

#[test]
fn p3_guaranteed_visibility_enforced_by_read_ready_remote() {
    p3_guaranteed_visibility(make_remote);
}

#[test]
fn p3_guaranteed_visibility_enforced_by_read_ready_remote_split() {
    p3_guaranteed_visibility(make_remote_split);
}

#[test]
fn p4_read_my_writes_through_cache() {
    for seed in 0..40u64 {
        let mut rng = Pcg64::new(seed ^ 0xCAFE);
        let d = dims();
        let init = ParamSet::glorot(&d, &mut rng);
        let mut cache = WorkerCache::new(0, init.clone());
        let mut own_total = init.zeros_like();
        // several clocks of local updates, never fetched
        for _ in 0..5 {
            let mut upd = init.zeros_like();
            for l in 0..d.len() - 1 {
                let delta = rand_delta(&d, l, &mut rng);
                upd.layers[l] = delta;
            }
            cache.add_local_update(&upd);
            own_total.axpy(1.0, &upd);
            cache.commit_clock();
        }
        // view == init + all own updates (P4), regardless of server state
        let mut want = init.clone();
        want.axpy(1.0, &own_total);
        let dist = cache.view().dist_sq(&want).sqrt();
        assert!(dist < 1e-3, "P4 violated: dist {dist} (seed {seed})");
    }
}

#[test]
fn clock_table_randomized_gap_bound() {
    // pure clock-table property: following must_wait never violates the
    // bound, for random policies and worker counts
    for seed in 0..80u64 {
        let mut rng = Pcg64::new(seed);
        let workers = 2 + rng.below(6);
        let s = rng.below(5) as u64;
        let policy = Policy::Ssp { staleness: s };
        let mut t = ClockTable::new(workers);
        for _ in 0..200 {
            let ok: Vec<usize> =
                (0..workers).filter(|&p| !t.must_wait(p, policy)).collect();
            assert!(!ok.is_empty(), "deadlock (seed {seed})");
            t.advance(ok[rng.below(ok.len())]);
            assert!(t.max() - t.min() <= s + 1, "gap bound (seed {seed})");
        }
    }
}

#[test]
fn bsp_is_lockstep_under_random_scheduling() {
    for seed in 0..30u64 {
        let mut rng = Pcg64::new(seed);
        let workers = 2 + rng.below(4);
        let mut t = ClockTable::new(workers);
        for _ in 0..150 {
            let ok: Vec<usize> =
                (0..workers).filter(|&p| !t.must_wait(p, Policy::Bsp)).collect();
            t.advance(ok[rng.below(ok.len())]);
            assert!(t.max() - t.min() <= 1, "BSP lockstep (seed {seed})");
        }
    }
}
