//! The sharded, per-layer SSP parameter server — the scalable sibling of
//! the single-lock `Server`.
//!
//! The paper's structural insight (Theorem 3, §3.1) is that SSP
//! synchronization is *layerwise*: each layer's updates commit and
//! propagate independently of every other layer's. The single-lock
//! `Server` ignores that structure — every fetch, commit and eval
//! serializes on one `Mutex` and snapshots the whole `ParamSet` inside
//! the critical section, which is exactly the central-server bottleneck
//! that limits parallel scalability (Keuper & Pfreundt 2016).
//!
//! `ShardedServer` exploits the layerwise structure instead:
//!
//! * each layer's parameters live in their own **shard** behind their own
//!   `RwLock` — concurrent fetches share read locks, and an update to
//!   layer `l` only ever contends with traffic on layer `l`;
//! * the clock table and the per-(layer, worker) version vector are
//!   **atomics**, so the two hot predicates `must_wait` / `read_ready`
//!   never take any lock at all;
//! * `fetch` assembles its snapshot **layer by layer** with no global
//!   critical section. Snapshots are therefore atomic per layer but may
//!   tear *across* layers — precisely the consistency the protocol
//!   already grants (updates are per-layer messages; Eq. 5's guarantee
//!   is enforced per (layer, worker) timestamp, which `read_ready`
//!   still checks in full);
//! * blocked workers park on a single condvar that `commit` /
//!   `apply_arrival` pulse after releasing all shard locks, so wakeups
//!   never hold parameter state hostage.
//!
//! All methods take `&self`: the threaded coordinator shares one
//! `ShardedServer` across workers without any outer mutex. Given the
//! same operation sequence, the sharded server is *bitwise identical* to
//! the reference `Server` (same f32 additions in the same order) — the
//! property tests drive both through identical random schedules and
//! assert exactly that. The shard boundary is also the natural message
//! boundary for a future multi-process transport: one shard maps to one
//! independently-consistent network endpoint.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex, RwLock};

use crate::nn::ParamSet;

use super::{ParamServer, Policy, ReadStats, UpdateMsg};

/// Lock-free committed-clock table: `clocks[p] = c` means worker `p` has
/// committed `c` clocks (same contract as `ClockTable`, atomically).
#[derive(Debug)]
pub struct AtomicClockTable {
    clocks: Vec<AtomicU64>,
}

impl AtomicClockTable {
    fn new(workers: usize) -> AtomicClockTable {
        assert!(workers > 0);
        AtomicClockTable {
            clocks: (0..workers).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    pub fn workers(&self) -> usize {
        self.clocks.len()
    }

    pub fn clock(&self, p: usize) -> u64 {
        self.clocks[p].load(Ordering::Acquire)
    }

    /// Worker `p` finished a clock; returns the new committed count.
    fn advance(&self, p: usize) -> u64 {
        self.clocks[p].fetch_add(1, Ordering::AcqRel) + 1
    }

    pub fn min(&self) -> u64 {
        self.clocks
            .iter()
            .map(|c| c.load(Ordering::Acquire))
            .min()
            .unwrap()
    }

    pub fn max(&self) -> u64 {
        self.clocks
            .iter()
            .map(|c| c.load(Ordering::Acquire))
            .max()
            .unwrap()
    }

    /// SSP condition 1, lock-free (see `ClockTable::must_wait`).
    pub fn must_wait(&self, p: usize, policy: Policy) -> bool {
        match policy.staleness() {
            None => false,
            Some(s) => self.clock(p) > self.min() + s,
        }
    }
}

/// One layer's parameter state. The `RwLock` guards the parameters; the
/// version counters are written only while the write lock is held (so
/// they order with the parameter values) but are *read* lock-free by
/// `read_ready`.
#[derive(Debug)]
struct LayerShard {
    params: RwLock<crate::nn::LayerParams>,
    /// `versions[q]` = clocks of worker `q`'s updates applied to this
    /// layer (updates arrive FIFO per (layer, worker) link).
    versions: Vec<AtomicU64>,
}

/// Condvar the barrier parks on. The mutex guards no data — waiters
/// re-check their readiness predicate while holding it, which is what
/// rules out missed wakeups — so notifiers pulse it after releasing
/// every shard lock.
#[derive(Debug, Default)]
struct Notifier {
    lock: Mutex<()>,
    cv: Condvar,
}

#[derive(Debug)]
pub struct ShardedServer {
    shards: Vec<LayerShard>,
    clocks: AtomicClockTable,
    policy: Policy,
    workers: usize,
    bytes_received: AtomicU64,
    reads: AtomicU64,
    applied: AtomicU64,
    notify: Notifier,
}

impl ShardedServer {
    pub fn new(init: ParamSet, workers: usize, policy: Policy) -> ShardedServer {
        assert!(workers > 0);
        let shards = init
            .layers
            .into_iter()
            .map(|lp| LayerShard {
                params: RwLock::new(lp),
                versions: (0..workers).map(|_| AtomicU64::new(0)).collect(),
            })
            .collect();
        ShardedServer {
            shards,
            clocks: AtomicClockTable::new(workers),
            policy,
            workers,
            bytes_received: AtomicU64::new(0),
            reads: AtomicU64::new(0),
            applied: AtomicU64::new(0),
            notify: Notifier::default(),
        }
    }

    pub fn policy(&self) -> Policy {
        self.policy
    }

    pub fn clocks(&self) -> &AtomicClockTable {
        &self.clocks
    }

    pub fn n_layers(&self) -> usize {
        self.shards.len()
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Worker `p` finished a clock (its update messages are now in
    /// flight). Advances the clock table and wakes barrier waiters.
    pub fn commit(&self, worker: usize) -> u64 {
        let c = self.clocks.advance(worker);
        self.bump();
        c
    }

    /// A (possibly delayed) update message reaches its layer's shard.
    /// Locks only that shard for writing.
    pub fn apply_arrival(&self, msg: &UpdateMsg) {
        self.apply_no_wake(msg);
        self.bump();
    }

    /// Batched arrival application: one condvar pulse for the whole
    /// batch (the per-clock commit path of the threaded coordinator).
    pub fn apply_arrivals(&self, msgs: &[UpdateMsg]) {
        for msg in msgs {
            self.apply_no_wake(msg);
        }
        self.bump();
    }

    fn apply_no_wake(&self, msg: &UpdateMsg) {
        self.bytes_received
            .fetch_add(msg.bytes as u64, Ordering::Relaxed);
        let shard = &self.shards[msg.layer];
        let mut params = shard.params.write().unwrap();
        // FIFO check per (layer, worker), as VersionVector::record.
        let v = shard.versions[msg.from].load(Ordering::Relaxed);
        assert_eq!(
            v, msg.clock,
            "out-of-order update: layer {} worker {} expected clock {v}, got {}",
            msg.layer, msg.from, msg.clock
        );
        // θ ← θ + u, exactly as ParamTable::apply (bitwise-equal path).
        params.w.axpy(1.0, &msg.delta.w);
        for (x, y) in params.b.iter_mut().zip(&msg.delta.b) {
            *x += *y;
        }
        shard.versions[msg.from].store(v + 1, Ordering::Release);
        drop(params);
        self.applied.fetch_add(1, Ordering::Relaxed);
    }

    /// Must worker `p` block before starting its next clock? Lock-free.
    pub fn must_wait(&self, worker: usize) -> bool {
        self.clocks.must_wait(worker, self.policy)
    }

    /// Guaranteed-visibility check (Eq. 5): every update with timestamp
    /// ≤ c−s−1 applied, per (layer, worker). Lock-free.
    pub fn read_ready(&self, worker: usize) -> bool {
        let c = self.clocks.clock(worker);
        match self.policy.staleness() {
            None => true,
            Some(s) => {
                let through = c.saturating_sub(s);
                self.shards.iter().all(|shard| {
                    shard
                        .versions
                        .iter()
                        .all(|v| v.load(Ordering::Acquire) >= through)
                })
            }
        }
    }

    /// Block until worker `p` may start its next clock (barrier cleared
    /// *and* the read guarantee met). Ready-ness is monotone between a
    /// worker's own commits, so once this returns the worker can fetch.
    pub fn wait_until_ready(&self, worker: usize) {
        if self.is_ready(worker) {
            return;
        }
        let mut guard = self.notify.lock.lock().unwrap();
        while !self.is_ready(worker) {
            guard = self.notify.cv.wait(guard).unwrap();
        }
    }

    fn is_ready(&self, worker: usize) -> bool {
        !self.must_wait(worker) && self.read_ready(worker)
    }

    fn bump(&self) {
        // State changed *before* this lock is taken: any waiter that
        // checked its predicate too early is already parked in `wait`
        // (mutex released) by the time we acquire, so the notify below
        // cannot be missed.
        drop(self.notify.lock.lock().unwrap());
        self.notify.cv.notify_all();
    }

    /// Serve a read for worker `p`: layer-by-layer snapshot + per-layer
    /// applied counts of `p`'s own updates + ε statistics — the same
    /// contract as `Server::fetch`, with no global critical section.
    /// Each layer's slice is internally consistent (cloned under that
    /// shard's read lock); layers may tear against each other, which the
    /// layerwise protocol permits.
    pub fn fetch(&self, worker: usize) -> (ParamSet, Vec<u64>, ReadStats) {
        debug_assert!(self.read_ready(worker), "fetch before guarantee met");
        self.reads.fetch_add(1, Ordering::Relaxed);
        let c = self.clocks.clock(worker);
        let s = self.policy.staleness().unwrap_or(u64::MAX);
        let through = c.saturating_sub(s); // c − s
        // committed clocks hoisted once so the ε statistics of this read
        // are computed against a single clock-table view even while
        // other workers keep committing
        let committed: Vec<u64> =
            (0..self.workers).map(|q| self.clocks.clock(q)).collect();
        let mut stats = ReadStats::default();
        let mut own = Vec::with_capacity(self.shards.len());
        let mut layers = Vec::with_capacity(self.shards.len());
        for shard in &self.shards {
            let params = shard.params.read().unwrap();
            layers.push(params.clone());
            // versions read under the same read lock: consistent with
            // the layer slice just cloned.
            for (q, v) in shard.versions.iter().enumerate() {
                let applied = v.load(Ordering::Acquire);
                if q == worker {
                    own.push(applied);
                    continue;
                }
                let committed = committed[q];
                let guaranteed = through.min(committed);
                stats.guaranteed += guaranteed;
                let extra_applied = applied.saturating_sub(guaranteed);
                let extra_committed = committed.saturating_sub(guaranteed);
                stats.window_included += extra_applied;
                // concurrent arrivals can race a commit here; saturate
                // rather than underflow (single-threaded drives are
                // exact, matching `Server::fetch`)
                stats.window_missed +=
                    extra_committed.saturating_sub(extra_applied);
            }
        }
        (ParamSet { layers }, own, stats)
    }

    /// Assemble the current master state layer by layer (evaluation /
    /// checkpoint path — never blocks writers for the whole model).
    pub fn snapshot(&self) -> ParamSet {
        ParamSet {
            layers: self
                .shards
                .iter()
                .map(|s| s.params.read().unwrap().clone())
                .collect(),
        }
    }

    /// Applied clocks of `(layer, worker)` — the version vector, read
    /// lock-free.
    pub fn applied(&self, layer: usize, worker: usize) -> u64 {
        self.shards[layer].versions[worker].load(Ordering::Acquire)
    }

    pub fn applied_count(&self) -> u64 {
        self.applied.load(Ordering::Relaxed)
    }

    pub fn bytes_received(&self) -> u64 {
        self.bytes_received.load(Ordering::Relaxed)
    }

    pub fn reads(&self) -> u64 {
        self.reads.load(Ordering::Relaxed)
    }
}

impl ParamServer for ShardedServer {
    fn policy(&self) -> Policy {
        ShardedServer::policy(self)
    }

    fn workers(&self) -> usize {
        ShardedServer::workers(self)
    }

    fn n_layers(&self) -> usize {
        ShardedServer::n_layers(self)
    }

    fn clock(&self, worker: usize) -> u64 {
        self.clocks.clock(worker)
    }

    fn commit(&mut self, worker: usize) -> u64 {
        ShardedServer::commit(self, worker)
    }

    fn apply_arrival(&mut self, msg: &UpdateMsg) {
        ShardedServer::apply_arrival(self, msg)
    }

    fn must_wait(&self, worker: usize) -> bool {
        ShardedServer::must_wait(self, worker)
    }

    fn read_ready(&self, worker: usize) -> bool {
        ShardedServer::read_ready(self, worker)
    }

    fn fetch(&mut self, worker: usize) -> (ParamSet, Vec<u64>, ReadStats) {
        ShardedServer::fetch(self, worker)
    }

    fn snapshot(&self) -> ParamSet {
        ShardedServer::snapshot(self)
    }

    fn applied(&self, layer: usize, worker: usize) -> u64 {
        ShardedServer::applied(self, layer, worker)
    }

    fn reads(&self) -> u64 {
        ShardedServer::reads(self)
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::nn::LayerParams;
    use crate::ssp::Server;
    use crate::tensor::Matrix;

    fn dims() -> Vec<usize> {
        vec![2, 3, 2]
    }

    fn msg(from: usize, clock: u64, layer: usize) -> UpdateMsg {
        let d = dims();
        UpdateMsg::new(
            from,
            clock,
            layer,
            LayerParams {
                w: Matrix::from_fn(d[layer], d[layer + 1], |_, _| 0.1),
                b: vec![0.1; d[layer + 1]],
            },
        )
    }

    fn commit_and_arrive(srv: &ShardedServer, worker: usize) {
        let clock = srv.clocks().clock(worker);
        srv.commit(worker);
        for l in 0..srv.n_layers() {
            srv.apply_arrival(&msg(worker, clock, l));
        }
    }

    #[test]
    fn ssp_read_guarantee() {
        let srv = ShardedServer::new(
            ParamSet::zeros(&dims()),
            2,
            Policy::Ssp { staleness: 1 },
        );
        commit_and_arrive(&srv, 0);
        commit_and_arrive(&srv, 1);
        srv.commit(0); // clock-1 arrival delayed
        assert!(srv.read_ready(0));
        assert!(srv.read_ready(1));
    }

    #[test]
    fn read_not_ready_when_guaranteed_update_missing() {
        let srv = ShardedServer::new(
            ParamSet::zeros(&dims()),
            2,
            Policy::Ssp { staleness: 0 },
        );
        srv.commit(1);
        srv.commit(0);
        assert!(!srv.read_ready(0));
        for l in 0..srv.n_layers() {
            srv.apply_arrival(&msg(1, 0, l));
        }
        assert!(!srv.read_ready(0));
        for l in 0..srv.n_layers() {
            srv.apply_arrival(&msg(0, 0, l));
        }
        assert!(srv.read_ready(0));
    }

    #[test]
    fn epsilon_stats_count_window_inclusion() {
        let srv = ShardedServer::new(
            ParamSet::zeros(&dims()),
            2,
            Policy::Ssp { staleness: 2 },
        );
        srv.commit(1);
        srv.apply_arrival(&msg(1, 0, 0));
        srv.apply_arrival(&msg(1, 0, 1));
        srv.commit(1);
        let (_, own, stats) = srv.fetch(0);
        assert_eq!(own, vec![0, 0]);
        assert_eq!(stats.guaranteed, 0);
        assert_eq!(stats.window_included, 2);
        assert_eq!(stats.window_missed, 2);
        assert!((stats.epsilon_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn barrier_is_lock_free_and_matches_clock_table() {
        let srv = ShardedServer::new(
            ParamSet::zeros(&dims()),
            2,
            Policy::Ssp { staleness: 0 },
        );
        srv.commit(0);
        assert!(srv.must_wait(0));
        assert!(!srv.must_wait(1));
        srv.commit(1);
        assert!(!srv.must_wait(0));
    }

    #[test]
    fn async_always_ready() {
        let srv = ShardedServer::new(ParamSet::zeros(&dims()), 3, Policy::Async);
        for _ in 0..5 {
            srv.commit(0);
        }
        assert!(srv.read_ready(0));
        assert!(!srv.must_wait(0));
    }

    #[test]
    #[should_panic(expected = "out-of-order")]
    fn out_of_order_update_rejected() {
        let srv = ShardedServer::new(ParamSet::zeros(&dims()), 2, Policy::Bsp);
        srv.apply_arrival(&msg(0, 1, 0)); // skips clock 0
    }

    #[test]
    fn matches_reference_server_bitwise_on_a_fixed_schedule() {
        let init = {
            let mut rng = crate::util::Pcg64::new(42);
            ParamSet::glorot(&dims(), &mut rng)
        };
        let policy = Policy::Ssp { staleness: 2 };
        let mut reference = Server::new(init.clone(), 2, policy);
        let sharded = ShardedServer::new(init, 2, policy);

        for clock in 0..3u64 {
            for worker in 0..2 {
                reference.commit(worker);
                sharded.commit(worker);
                for l in 0..2 {
                    let m = msg(worker, clock, l);
                    reference.apply_arrival(&m);
                    sharded.apply_arrival(&m);
                }
            }
            let (p_ref, own_ref, st_ref) = reference.fetch(0);
            let (p_sh, own_sh, st_sh) = sharded.fetch(0);
            assert_eq!(p_ref, p_sh, "master diverged at clock {clock}");
            assert_eq!(own_ref, own_sh);
            assert_eq!(st_ref, st_sh);
        }
        assert_eq!(reference.reads(), sharded.reads());
    }

    #[test]
    fn wait_until_ready_blocks_and_wakes() {
        let srv = Arc::new(ShardedServer::new(
            ParamSet::zeros(&dims()),
            2,
            Policy::Bsp,
        ));
        // worker 0 is one clock ahead: it must wait for worker 1
        commit_and_arrive(&srv, 0);
        assert!(srv.must_wait(0));
        let waiter = {
            let srv = Arc::clone(&srv);
            std::thread::spawn(move || {
                srv.wait_until_ready(0);
                srv.clocks().clock(1)
            })
        };
        std::thread::sleep(std::time::Duration::from_millis(20));
        commit_and_arrive(&srv, 1); // releases the waiter
        let seen = waiter.join().unwrap();
        assert_eq!(seen, 1);
        assert!(srv.is_ready(0));
    }

    #[test]
    fn concurrent_commits_hold_staleness_bound() {
        let s = 2u64;
        let srv = Arc::new(ShardedServer::new(
            ParamSet::zeros(&dims()),
            4,
            Policy::Ssp { staleness: s },
        ));
        let clocks = 30u64;
        std::thread::scope(|scope| {
            for p in 0..4usize {
                let srv = Arc::clone(&srv);
                scope.spawn(move || {
                    for clock in 0..clocks {
                        srv.wait_until_ready(p);
                        // every observable clock obeys the SSP bound
                        // relative to this worker's own clock
                        let own = srv.clocks().clock(p);
                        for q in 0..4 {
                            assert!(
                                srv.clocks().clock(q) <= own + s + 1,
                                "staleness bound broken"
                            );
                        }
                        let ms: Vec<UpdateMsg> =
                            (0..srv.n_layers()).map(|l| msg(p, clock, l)).collect();
                        srv.commit(p);
                        srv.apply_arrivals(&ms);
                    }
                });
            }
        });
        assert_eq!(srv.clocks().min(), clocks);
        assert_eq!(srv.applied_count(), 4 * clocks * 2);
    }
}
