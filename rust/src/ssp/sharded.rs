//! The sharded, per-layer SSP parameter server — the scalable sibling of
//! the single-lock `Server`.
//!
//! The paper's structural insight (Theorem 3, §3.1) is that SSP
//! synchronization is *layerwise*: each layer's updates commit and
//! propagate independently of every other layer's. The single-lock
//! `Server` ignores that structure — every fetch, commit and eval
//! serializes on one `Mutex` and snapshots the whole `ParamSet` inside
//! the critical section, which is exactly the central-server bottleneck
//! that limits parallel scalability (Keuper & Pfreundt 2016).
//!
//! `ShardedServer` exploits the layerwise structure instead:
//!
//! * each layer's parameters live in their own **shard** behind their own
//!   `RwLock` — concurrent fetches share read locks, and an update to
//!   layer `l` only ever contends with traffic on layer `l`;
//! * the clock table and the per-(layer, worker) version vector are
//!   **atomics**, so the two hot predicates `must_wait` / `read_ready`
//!   never take any lock at all;
//! * `fetch` assembles its snapshot **layer by layer** with no global
//!   critical section. Snapshots are therefore atomic per layer but may
//!   tear *across* layers — precisely the consistency the protocol
//!   already grants (updates are per-layer messages; Eq. 5's guarantee
//!   is enforced per (layer, worker) timestamp, which `read_ready`
//!   still checks in full);
//! * blocked workers park on a single condvar that `commit` /
//!   `apply_arrival` pulse after releasing all shard locks, so wakeups
//!   never hold parameter state hostage.
//!
//! All methods take `&self`: the threaded coordinator shares one
//! `ShardedServer` across workers without any outer mutex. Given the
//! same operation sequence, the sharded server is *bitwise identical* to
//! the reference `Server` (same f32 additions in the same order) — the
//! property tests drive both through identical random schedules and
//! assert exactly that. The shard boundary is also the natural message
//! boundary for a future multi-process transport: one shard maps to one
//! independently-consistent network endpoint.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Condvar, Mutex, RwLock};

use crate::nn::{GradSet, LayerParams, ParamSet};

use super::{FetchStats, ParamServer, Policy, ReadStats, UpdateMsg, WorkerPort};

/// Lock-free committed-clock table: `clocks[p] = c` means worker `p` has
/// committed `c` clocks (same contract as `ClockTable`, atomically).
///
/// Elastic membership lives here too, because the min-clock is what
/// membership actually *means* to the protocol: `live[p] == false`
/// freezes worker `p`'s committed count in the table (history is never
/// rewritten) but removes it from the min the staleness barrier
/// compares against, so survivors stop waiting for a peer that will
/// never commit again.
#[derive(Debug)]
pub struct AtomicClockTable {
    clocks: Vec<AtomicU64>,
    live: Vec<AtomicBool>,
}

impl AtomicClockTable {
    fn new(workers: usize) -> AtomicClockTable {
        assert!(workers > 0);
        AtomicClockTable {
            clocks: (0..workers).map(|_| AtomicU64::new(0)).collect(),
            live: (0..workers).map(|_| AtomicBool::new(true)).collect(),
        }
    }

    pub fn workers(&self) -> usize {
        self.clocks.len()
    }

    pub fn clock(&self, p: usize) -> u64 {
        self.clocks[p].load(Ordering::Acquire)
    }

    /// Worker `p` finished a clock; returns the new committed count.
    fn advance(&self, p: usize) -> u64 {
        self.clocks[p].fetch_add(1, Ordering::AcqRel) + 1
    }

    /// Membership flag of worker `p` (lock-free).
    pub fn is_live(&self, p: usize) -> bool {
        self.live[p].load(Ordering::Acquire)
    }

    /// Flip `p`'s membership flag; returns false if it already held
    /// `to` (the CAS makes concurrent evict/admit races single-winner,
    /// so the epoch counter moves exactly once per transition).
    fn transition_live(&self, p: usize, to: bool) -> bool {
        self.live[p]
            .compare_exchange(!to, to, Ordering::SeqCst, Ordering::SeqCst)
            .is_ok()
    }

    /// Jump `p`'s committed count (admit fast-forward only — clocks are
    /// otherwise strictly advanced one commit at a time).
    fn set_clock(&self, p: usize, c: u64) {
        self.clocks[p].store(c, Ordering::SeqCst);
    }

    pub fn live_count(&self) -> usize {
        self.live
            .iter()
            .filter(|l| l.load(Ordering::Acquire))
            .count()
    }

    /// Live set as a bitmask (bit `p` set ⇔ worker `p` live). The wire
    /// protocol ships this in one u64; the worker-count ceiling is
    /// enforced where the mask crosses the process boundary.
    pub fn live_mask(&self) -> u64 {
        self.live
            .iter()
            .enumerate()
            .filter(|(_, l)| l.load(Ordering::Acquire))
            .fold(0u64, |m, (p, _)| m | (1u64 << (p & 63)))
    }

    /// Min committed clock over the live set only; `None` if every
    /// worker has been evicted.
    pub fn live_min(&self) -> Option<u64> {
        self.clocks
            .iter()
            .zip(&self.live)
            .filter(|(_, l)| l.load(Ordering::Acquire))
            .map(|(c, _)| c.load(Ordering::Acquire))
            .min()
    }

    /// The staleness barrier's min clock: over live workers (evicted
    /// clocks are frozen history, not a bound). With the degenerate
    /// empty live set it falls back to the frozen global min so the
    /// predicates stay total.
    pub fn min(&self) -> u64 {
        self.live_min().unwrap_or_else(|| {
            self.clocks
                .iter()
                .map(|c| c.load(Ordering::Acquire))
                .min()
                .unwrap()
        })
    }

    pub fn max(&self) -> u64 {
        self.clocks
            .iter()
            .map(|c| c.load(Ordering::Acquire))
            .max()
            .unwrap()
    }

    /// SSP condition 1, lock-free (see `ClockTable::must_wait`).
    pub fn must_wait(&self, p: usize, policy: Policy) -> bool {
        match policy.staleness() {
            None => false,
            Some(s) => self.clock(p) > self.min() + s,
        }
    }
}

/// One layer's parameter state. The `RwLock` guards the parameters; the
/// version counters are written only while the write lock is held (so
/// they order with the parameter values) but are *read* lock-free by
/// `read_ready`.
#[derive(Debug)]
struct LayerShard {
    params: RwLock<crate::nn::LayerParams>,
    /// `versions[q]` = clocks of worker `q`'s updates applied to this
    /// layer (updates arrive FIFO per (layer, worker) link).
    versions: Vec<AtomicU64>,
    /// Count of *effective* (nonzero-delta) updates applied — the
    /// revision the version-gated fetch compares against. Zero deltas
    /// advance `versions` (protocol FIFO bookkeeping) but cannot change
    /// θ, so they leave the revision alone and gated readers keep their
    /// buffered copy. Bumped (SeqCst) *before* the `versions` store so a
    /// lock-free reader that loads versions and then confirms the
    /// revision unchanged cannot have observed a newer effective update
    /// than its buffer holds.
    rev: AtomicU64,
}

/// Condvar the barrier parks on. The mutex guards no data — waiters
/// re-check their readiness predicate while holding it, which is what
/// rules out missed wakeups — so notifiers pulse it after releasing
/// every shard lock.
#[derive(Debug, Default)]
struct Notifier {
    lock: Mutex<()>,
    cv: Condvar,
}

/// Complete restartable protocol state of a [`ShardedServer`]: the
/// clock table plus, per layer, the parameters, the per-worker version
/// vector, and the effective-update revision counter the fetch gate
/// compares against. `checkpoint::{save_state, load_state}` give it a
/// checksummed on-disk format; `ShardedServer::from_state` rebuilds a
/// server whose every observable — clocks, readiness, gate revisions,
/// fetched bits — equals the dumped one.
#[derive(Debug, Clone, PartialEq)]
pub struct ServerState {
    pub policy: Policy,
    pub workers: usize,
    pub clocks: Vec<u64>,
    pub layers: Vec<LayerState>,
}

/// One layer's dump: parameters + version vector + revision counter.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerState {
    pub params: LayerParams,
    pub versions: Vec<u64>,
    pub rev: u64,
}

#[derive(Debug)]
pub struct ShardedServer {
    shards: Vec<LayerShard>,
    clocks: AtomicClockTable,
    policy: Policy,
    workers: usize,
    /// Membership epoch: bumped once per successful evict/admit
    /// transition. Workers re-derive their data shard from
    /// (epoch, live set), so observing a bump on a gated read is the
    /// rebalance trigger.
    epoch: AtomicU64,
    bytes_received: AtomicU64,
    reads: AtomicU64,
    applied: AtomicU64,
    layers_copied: AtomicU64,
    layers_skipped: AtomicU64,
    bytes_copied: AtomicU64,
    notify: Notifier,
}

impl ShardedServer {
    pub fn new(init: ParamSet, workers: usize, policy: Policy) -> ShardedServer {
        assert!(workers > 0);
        let shards = init
            .layers
            .into_iter()
            .map(|lp| LayerShard {
                params: RwLock::new(lp),
                versions: (0..workers).map(|_| AtomicU64::new(0)).collect(),
                rev: AtomicU64::new(0),
            })
            .collect();
        ShardedServer {
            shards,
            clocks: AtomicClockTable::new(workers),
            policy,
            workers,
            epoch: AtomicU64::new(0),
            bytes_received: AtomicU64::new(0),
            reads: AtomicU64::new(0),
            applied: AtomicU64::new(0),
            layers_copied: AtomicU64::new(0),
            layers_skipped: AtomicU64::new(0),
            bytes_copied: AtomicU64::new(0),
            notify: Notifier::default(),
        }
    }

    /// Rebuild a server from a [`ServerState`] dump — the shard-process
    /// warm-restart path. Clocks, version vectors and gate revisions
    /// resume exactly where the dump left them, so a restarted
    /// `serve --group` endpoint rejoins the run consistently: reconnect
    /// probes see revisions that never went backwards and carried-over
    /// gate vectors stay sound.
    pub fn from_state(state: ServerState) -> ShardedServer {
        let workers = state.workers;
        assert!(workers > 0, "state: zero workers");
        assert_eq!(state.clocks.len(), workers, "state: clock table shape");
        let shards: Vec<LayerShard> = state
            .layers
            .into_iter()
            .map(|ls| {
                assert_eq!(
                    ls.versions.len(),
                    workers,
                    "state: version vector shape"
                );
                LayerShard {
                    params: RwLock::new(ls.params),
                    versions: ls.versions.into_iter().map(AtomicU64::new).collect(),
                    rev: AtomicU64::new(ls.rev),
                }
            })
            .collect();
        assert!(!shards.is_empty(), "state: zero layers");
        ShardedServer {
            shards,
            clocks: AtomicClockTable {
                clocks: state.clocks.into_iter().map(AtomicU64::new).collect(),
                live: (0..workers).map(|_| AtomicBool::new(true)).collect(),
            },
            policy: state.policy,
            workers,
            // membership is lease-derived runtime state, not protocol
            // state: a restarted server starts all-live at epoch 0 and
            // re-learns evictions from expiring leases
            epoch: AtomicU64::new(0),
            bytes_received: AtomicU64::new(0),
            reads: AtomicU64::new(0),
            applied: AtomicU64::new(0),
            layers_copied: AtomicU64::new(0),
            layers_skipped: AtomicU64::new(0),
            bytes_copied: AtomicU64::new(0),
            notify: Notifier::default(),
        }
    }

    /// Dump the complete restartable state (see [`ServerState`]). Each
    /// layer is read under its shard lock so per-layer content is
    /// internally consistent; for an exact whole-server dump call this
    /// at quiescence (no in-flight COMMIT/UPDATE traffic). Traffic
    /// counters are not part of the protocol state and restart at zero.
    pub fn export_state(&self) -> ServerState {
        let layers = self
            .shards
            .iter()
            .map(|shard| LayerState {
                params: shard.params.read().unwrap().clone(),
                versions: shard
                    .versions
                    .iter()
                    .map(|v| v.load(Ordering::SeqCst))
                    .collect(),
                rev: shard.rev.load(Ordering::SeqCst),
            })
            .collect();
        ServerState {
            policy: self.policy,
            workers: self.workers,
            clocks: (0..self.workers).map(|p| self.clocks.clock(p)).collect(),
            layers,
        }
    }

    pub fn policy(&self) -> Policy {
        self.policy
    }

    pub fn clocks(&self) -> &AtomicClockTable {
        &self.clocks
    }

    pub fn n_layers(&self) -> usize {
        self.shards.len()
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Worker `p` finished a clock (its update messages are now in
    /// flight). Advances the clock table and wakes barrier waiters.
    pub fn commit(&self, worker: usize) -> u64 {
        let c = self.clocks.advance(worker);
        self.bump();
        c
    }

    /// Current membership epoch (0 at construction; +1 per evict/admit).
    pub fn membership_epoch(&self) -> u64 {
        self.epoch.load(Ordering::SeqCst)
    }

    /// Membership flag of `worker`.
    pub fn is_live(&self, worker: usize) -> bool {
        self.clocks.is_live(worker)
    }

    /// Live set as a bitmask (bit `p` set ⇔ worker `p` live).
    pub fn live_mask(&self) -> u64 {
        self.clocks.live_mask()
    }

    pub fn live_count(&self) -> usize {
        self.clocks.live_count()
    }

    /// Evict `worker` from the membership: its committed history stays
    /// applied (and counted), but it stops bounding the staleness
    /// barrier, its unapplied version entries stop gating `read_ready`,
    /// and its committed-but-never-applied window contributions drop
    /// out of the ε totals. Parked barrier waiters are pulsed so they
    /// re-check against the shrunken live set. Idempotent; returns the
    /// membership epoch after the call (bumped iff the worker was
    /// live). Late in-flight updates from an evicted worker are still
    /// accepted — FIFO bookkeeping stays intact, the bits simply count
    /// as best-effort extra until (unless) the worker re-admits.
    pub fn evict_worker(&self, worker: usize) -> u64 {
        assert!(worker < self.workers, "evict: worker out of range");
        if !self.clocks.transition_live(worker, false) {
            return self.membership_epoch();
        }
        let e = self.epoch.fetch_add(1, Ordering::SeqCst) + 1;
        self.bump();
        e
    }

    /// Re-admit an evicted `worker` at the current live min clock. Its
    /// clock and every per-layer version entry fast-forward to that
    /// floor *before* the live flag flips — the same move as a
    /// zero-delta update (versions advance, θ and the gate revision
    /// untouched), so the FIFO assert and every other worker's read
    /// guarantee stay sound and the rejoiner never drags the min
    /// backwards. Idempotent; returns the epoch after the call.
    pub fn admit_worker(&self, worker: usize) -> u64 {
        assert!(worker < self.workers, "admit: worker out of range");
        if self.clocks.is_live(worker) {
            return self.membership_epoch();
        }
        let target = self
            .clocks
            .live_min()
            .unwrap_or_else(|| self.clocks.clock(worker));
        if target > self.clocks.clock(worker) {
            self.clocks.set_clock(worker, target);
            for shard in &self.shards {
                // under the shard write lock so the store cannot race
                // an in-flight apply_delta's FIFO check on this entry
                let _guard = shard.params.write().unwrap();
                shard.versions[worker].store(target, Ordering::SeqCst);
            }
        }
        if !self.clocks.transition_live(worker, true) {
            return self.membership_epoch();
        }
        let e = self.epoch.fetch_add(1, Ordering::SeqCst) + 1;
        self.bump();
        e
    }

    /// A (possibly delayed) update message reaches its layer's shard.
    /// Locks only that shard for writing.
    pub fn apply_arrival(&self, msg: &UpdateMsg) {
        self.apply_no_wake(msg);
        self.bump();
    }

    /// Batched arrival application: one condvar pulse for the whole
    /// batch (the per-clock commit path of the threaded coordinator).
    pub fn apply_arrivals(&self, msgs: &[UpdateMsg]) {
        for msg in msgs {
            self.apply_no_wake(msg);
        }
        self.bump();
    }

    fn apply_no_wake(&self, msg: &UpdateMsg) {
        self.bytes_received
            .fetch_add(msg.bytes as u64, Ordering::Relaxed);
        self.apply_delta(msg.layer, msg.from, msg.clock, &msg.delta);
    }

    /// Apply one layer's additive delta under that shard's write lock —
    /// the shared body of the message path (`apply_arrival`) and the
    /// allocation-free local-commit path (`apply_commit`).
    fn apply_delta(
        &self,
        layer: usize,
        from: usize,
        clock: u64,
        delta: &LayerParams,
    ) {
        let shard = &self.shards[layer];
        let mut params = shard.params.write().unwrap();
        // FIFO check per (layer, worker), as VersionVector::record.
        let v = shard.versions[from].load(Ordering::Relaxed);
        assert_eq!(
            v, clock,
            "out-of-order update: layer {layer} worker {from} expected clock {v}, got {clock}"
        );
        // θ ← θ + u, exactly as ParamTable::apply (bitwise-equal path).
        params.w.axpy(1.0, &delta.w);
        for (x, y) in params.b.iter_mut().zip(&delta.b) {
            *x += *y;
        }
        // revision before versions (both SeqCst): see `LayerShard::rev`.
        if !delta.is_zero() {
            shard.rev.fetch_add(1, Ordering::SeqCst);
        }
        shard.versions[from].store(v + 1, Ordering::SeqCst);
        drop(params);
        self.applied.fetch_add(1, Ordering::Relaxed);
    }

    /// Shared-memory fast path for a worker's own clock commit: applies
    /// the accumulated per-layer deltas directly (no `UpdateMsg`
    /// allocation, no delta clone), with the same version bookkeeping
    /// and byte accounting as `apply_arrivals` over
    /// `WorkerCache::commit_clock`'s messages. One condvar pulse for the
    /// whole batch. The caller must have advanced the clock table with
    /// `commit` first, exactly as with the message path.
    pub fn apply_commit(&self, worker: usize, clock: u64, delta: &GradSet) {
        assert_eq!(delta.layers.len(), self.shards.len(), "commit layers");
        for (layer, lp) in delta.layers.iter().enumerate() {
            self.bytes_received
                .fetch_add((lp.n_bytes() + 32) as u64, Ordering::Relaxed);
            self.apply_delta(layer, worker, clock, lp);
        }
        self.bump();
    }

    /// Must worker `p` block before starting its next clock? Lock-free.
    pub fn must_wait(&self, worker: usize) -> bool {
        self.clocks.must_wait(worker, self.policy)
    }

    /// Guaranteed-visibility check (Eq. 5): every update with timestamp
    /// ≤ c−s−1 applied, per (layer, worker). Lock-free. Evicted workers
    /// are exempt — their in-flight updates may never arrive, so gating
    /// on them would deadlock every survivor; whatever did arrive is
    /// already folded into θ.
    pub fn read_ready(&self, worker: usize) -> bool {
        let c = self.clocks.clock(worker);
        match self.policy.staleness() {
            None => true,
            Some(s) => {
                let through = c.saturating_sub(s);
                self.shards.iter().all(|shard| {
                    shard.versions.iter().enumerate().all(|(q, v)| {
                        !self.clocks.is_live(q)
                            || v.load(Ordering::Acquire) >= through
                    })
                })
            }
        }
    }

    /// Block until worker `p` may start its next clock (barrier cleared
    /// *and* the read guarantee met). Ready-ness is monotone between a
    /// worker's own commits, so once this returns the worker can fetch.
    pub fn wait_until_ready(&self, worker: usize) {
        if self.is_ready(worker) {
            return;
        }
        let mut guard = self.notify.lock.lock().unwrap();
        while !self.is_ready(worker) {
            guard = self.notify.cv.wait(guard).unwrap();
        }
    }

    /// Bounded `wait_until_ready`: park at most `timeout`, returning
    /// whether the worker is ready. The transport's WAIT handler polls
    /// this instead of parking unconditionally, so a service shutdown
    /// can interrupt a barrier wait whose releasing commit will never
    /// arrive (e.g. the peer worker died).
    pub fn wait_ready_timeout(
        &self,
        worker: usize,
        timeout: std::time::Duration,
    ) -> bool {
        if self.is_ready(worker) {
            return true;
        }
        let deadline = std::time::Instant::now() + timeout;
        let mut guard = self.notify.lock.lock().unwrap();
        while !self.is_ready(worker) {
            let now = std::time::Instant::now();
            if now >= deadline {
                return false;
            }
            let (g, _) = self
                .notify
                .cv
                .wait_timeout(guard, deadline - now)
                .unwrap();
            guard = g;
        }
        true
    }

    fn is_ready(&self, worker: usize) -> bool {
        !self.must_wait(worker) && self.read_ready(worker)
    }

    /// Group-scoped read guarantee: Eq. 5's visibility check restricted
    /// to `layers`. The exclusive (multi-process) transport tier needs
    /// this because each server process only ever receives UPDATEs for
    /// its own shard group — the other layers' version vectors stay at
    /// zero forever, so the whole-model `read_ready` would deadlock.
    /// The client ANDs the group-scoped answers across processes, which
    /// equals the whole-model predicate because the check is a
    /// conjunction over (layer, worker) pairs.
    pub fn read_ready_group(
        &self,
        worker: usize,
        layers: std::ops::Range<usize>,
    ) -> bool {
        assert!(layers.end <= self.shards.len(), "group out of range");
        let c = self.clocks.clock(worker);
        match self.policy.staleness() {
            None => true,
            Some(s) => {
                let through = c.saturating_sub(s);
                self.shards[layers].iter().all(|shard| {
                    shard.versions.iter().enumerate().all(|(q, v)| {
                        !self.clocks.is_live(q)
                            || v.load(Ordering::Acquire) >= through
                    })
                })
            }
        }
    }

    /// Group-scoped [`ShardedServer::wait_ready_timeout`]: barrier
    /// cleared *and* the read guarantee met over `layers` only — what
    /// an exclusive endpoint's WAIT handler polls (it cannot see the
    /// other groups' shards).
    pub fn wait_ready_group_timeout(
        &self,
        worker: usize,
        layers: std::ops::Range<usize>,
        timeout: std::time::Duration,
    ) -> bool {
        let ready = |srv: &ShardedServer| {
            !srv.must_wait(worker)
                && srv.read_ready_group(worker, layers.clone())
        };
        if ready(self) {
            return true;
        }
        let deadline = std::time::Instant::now() + timeout;
        let mut guard = self.notify.lock.lock().unwrap();
        while !ready(self) {
            let now = std::time::Instant::now();
            if now >= deadline {
                return false;
            }
            let (g, _) = self
                .notify
                .cv
                .wait_timeout(guard, deadline - now)
                .unwrap();
            guard = g;
        }
        true
    }

    fn bump(&self) {
        // State changed *before* this lock is taken: any waiter that
        // checked its predicate too early is already parked in `wait`
        // (mutex released) by the time we acquire, so the notify below
        // cannot be missed.
        drop(self.notify.lock.lock().unwrap());
        self.notify.cv.notify_all();
    }

    /// Pulse every barrier waiter so it re-checks its predicate — and,
    /// in the transport's slice-polled WAIT handler, its stop flag and
    /// the worker leases — immediately instead of sleeping out the
    /// current timeout slice. The service shutdown and worker-eviction
    /// paths call this to release parked waits promptly.
    pub fn wake_all(&self) {
        self.bump();
    }

    /// Serve a read for worker `p`: layer-by-layer snapshot + per-layer
    /// applied counts of `p`'s own updates + ε statistics — the same
    /// contract as `Server::fetch`, with no global critical section.
    /// Each layer's slice is internally consistent (cloned under that
    /// shard's read lock); layers may tear against each other, which the
    /// layerwise protocol permits.
    pub fn fetch(&self, worker: usize) -> (ParamSet, Vec<u64>, ReadStats) {
        debug_assert!(self.read_ready(worker), "fetch before guarantee met");
        self.reads.fetch_add(1, Ordering::Relaxed);
        let c = self.clocks.clock(worker);
        let s = self.policy.staleness().unwrap_or(u64::MAX);
        let through = c.saturating_sub(s); // c − s
        // committed clocks hoisted once so the ε statistics of this read
        // are computed against a single clock-table view even while
        // other workers keep committing (membership snapshotted with it)
        let committed: Vec<u64> =
            (0..self.workers).map(|q| self.clocks.clock(q)).collect();
        let live: Vec<bool> =
            (0..self.workers).map(|q| self.clocks.is_live(q)).collect();
        let mut stats = ReadStats::default();
        let mut own = Vec::with_capacity(self.shards.len());
        let mut layers = Vec::with_capacity(self.shards.len());
        for shard in &self.shards {
            let params = shard.params.read().unwrap();
            layers.push(params.clone());
            // versions read under the same read lock: consistent with
            // the layer slice just cloned.
            for (q, v) in shard.versions.iter().enumerate() {
                let applied = v.load(Ordering::Acquire);
                if q == worker {
                    own.push(applied);
                    continue;
                }
                // an evicted worker's committed-but-never-applied
                // window contributions are dropped (clamp to what
                // actually arrived); its applied history keeps
                // counting as guaranteed/included
                let committed = if live[q] {
                    committed[q]
                } else {
                    committed[q].min(applied)
                };
                let guaranteed = through.min(committed);
                stats.guaranteed += guaranteed;
                let extra_applied = applied.saturating_sub(guaranteed);
                let extra_committed = committed.saturating_sub(guaranteed);
                stats.window_included += extra_applied;
                // concurrent arrivals can race a commit here; saturate
                // rather than underflow (single-threaded drives are
                // exact, matching `Server::fetch`)
                stats.window_missed +=
                    extra_committed.saturating_sub(extra_applied);
            }
        }
        (ParamSet { layers }, own, stats)
    }

    /// Per-layer ε / own accounting for one shard of a read, from the
    /// shard's version counters (loaded SeqCst). Mirrors the loop body
    /// of `fetch`; factored out so the gated path can run it either
    /// lock-free (skipped layer) or under the shard read lock (copied
    /// layer).
    #[allow(clippy::too_many_arguments)]
    fn layer_read_stats(
        shard: &LayerShard,
        worker: usize,
        through: u64,
        committed: &[u64],
        live: &[bool],
        own: &mut Vec<u64>,
        stats: &mut ReadStats,
    ) {
        for (q, v) in shard.versions.iter().enumerate() {
            let applied = v.load(Ordering::SeqCst);
            if q == worker {
                own.push(applied);
                continue;
            }
            // evicted: drop never-applied window contributions (see
            // `fetch`); applied history keeps counting
            let committed_q = if live[q] {
                committed[q]
            } else {
                committed[q].min(applied)
            };
            let guaranteed = through.min(committed_q);
            stats.guaranteed += guaranteed;
            let extra_applied = applied.saturating_sub(guaranteed);
            let extra_committed = committed_q.saturating_sub(guaranteed);
            stats.window_included += extra_applied;
            stats.window_missed +=
                extra_committed.saturating_sub(extra_applied);
        }
    }

    /// Version-gated zero-copy read: same observable contract as
    /// `fetch`, but the snapshot lands in the caller's reusable `buf`
    /// and only the layers whose revision advanced since `last_seen`
    /// are copied (and take a read lock at all). Skipped layers are
    /// confirmed by a revision double-check around the lock-free
    /// version reads: an effective update bumps the revision *before*
    /// its version store (both SeqCst), so if the revision is still
    /// `last_seen` after the version loads, those loads cannot have
    /// included an effective update the buffer is missing — the
    /// accounting a skipped layer reports is consistent with the bits
    /// the caller already holds. Zero-delta updates are the only
    /// in-between: they advance versions without a revision bump, which
    /// is sound because they cannot change θ.
    pub fn fetch_into(
        &self,
        worker: usize,
        buf: &mut ParamSet,
        last_seen: &mut [u64],
        own: &mut Vec<u64>,
    ) -> (ReadStats, FetchStats) {
        debug_assert!(self.read_ready(worker), "fetch before guarantee met");
        assert_eq!(buf.layers.len(), self.shards.len(), "fetch_into buffer");
        assert_eq!(last_seen.len(), self.shards.len(), "fetch_into last_seen");
        self.reads.fetch_add(1, Ordering::Relaxed);
        let c = self.clocks.clock(worker);
        let s = self.policy.staleness().unwrap_or(u64::MAX);
        let through = c.saturating_sub(s); // c − s
        let committed: Vec<u64> =
            (0..self.workers).map(|q| self.clocks.clock(q)).collect();
        let live: Vec<bool> =
            (0..self.workers).map(|q| self.clocks.is_live(q)).collect();
        let mut stats = ReadStats::default();
        let mut fs = FetchStats::default();
        own.clear();
        for (l, shard) in self.shards.iter().enumerate() {
            let own_mark = own.len();
            let stats_mark = stats;
            let rev_pre = shard.rev.load(Ordering::SeqCst);
            if rev_pre == last_seen[l] {
                Self::layer_read_stats(
                    shard, worker, through, &committed, &live, own, &mut stats,
                );
                if shard.rev.load(Ordering::SeqCst) == rev_pre {
                    fs.layers_skipped += 1;
                    continue;
                }
                // raced an effective update: discard the tentative
                // accounting and fall through to the locked copy
                own.truncate(own_mark);
                stats = stats_mark;
            }
            let params = shard.params.read().unwrap();
            // revision re-read under the lock: matches the copied bits
            last_seen[l] = shard.rev.load(Ordering::SeqCst);
            buf.layers[l].copy_from(&params);
            fs.layers_copied += 1;
            fs.bytes_copied += params.n_bytes() as u64;
            Self::layer_read_stats(
                shard, worker, through, &committed, &live, own, &mut stats,
            );
            drop(params);
        }
        self.layers_copied
            .fetch_add(fs.layers_copied, Ordering::Relaxed);
        self.layers_skipped
            .fetch_add(fs.layers_skipped, Ordering::Relaxed);
        self.bytes_copied
            .fetch_add(fs.bytes_copied, Ordering::Relaxed);
        (stats, fs)
    }

    /// Assemble the current master state layer by layer (evaluation /
    /// checkpoint path — never blocks writers for the whole model).
    pub fn snapshot(&self) -> ParamSet {
        ParamSet {
            layers: self
                .shards
                .iter()
                .map(|s| s.params.read().unwrap().clone())
                .collect(),
        }
    }

    /// Current master state into a reusable buffer — `snapshot` without
    /// the allocation.
    pub fn snapshot_into(&self, buf: &mut ParamSet) {
        assert_eq!(buf.layers.len(), self.shards.len(), "snapshot buffer");
        for (dst, shard) in buf.layers.iter_mut().zip(&self.shards) {
            dst.copy_from(&shard.params.read().unwrap());
        }
    }

    /// Gated variant of `snapshot_into` for a repeat reader (the
    /// evaluator thread): copies only the layers whose revision advanced
    /// since this buffer's previous snapshot, taking no lock at all for
    /// unchanged layers.
    pub fn snapshot_into_gated(
        &self,
        buf: &mut ParamSet,
        last_seen: &mut [u64],
    ) -> FetchStats {
        assert_eq!(buf.layers.len(), self.shards.len(), "snapshot buffer");
        assert_eq!(last_seen.len(), self.shards.len(), "snapshot last_seen");
        let mut fs = FetchStats::default();
        for (l, shard) in self.shards.iter().enumerate() {
            if shard.rev.load(Ordering::SeqCst) == last_seen[l] {
                fs.layers_skipped += 1;
                continue;
            }
            let params = shard.params.read().unwrap();
            last_seen[l] = shard.rev.load(Ordering::SeqCst);
            buf.layers[l].copy_from(&params);
            fs.layers_copied += 1;
            fs.bytes_copied += params.n_bytes() as u64;
        }
        self.layers_copied
            .fetch_add(fs.layers_copied, Ordering::Relaxed);
        self.layers_skipped
            .fetch_add(fs.layers_skipped, Ordering::Relaxed);
        self.bytes_copied
            .fetch_add(fs.bytes_copied, Ordering::Relaxed);
        fs
    }

    /// Aggregate copy accounting over every gated read served.
    pub fn copy_totals(&self) -> FetchStats {
        FetchStats {
            layers_copied: self.layers_copied.load(Ordering::Relaxed),
            layers_skipped: self.layers_skipped.load(Ordering::Relaxed),
            bytes_copied: self.bytes_copied.load(Ordering::Relaxed),
        }
    }

    /// `(w rows, w cols, b len)` of layer `l` — the transport handshake
    /// ships shapes so a remote client can allocate matching buffers.
    pub fn layer_shape(&self, l: usize) -> (usize, usize, usize) {
        let p = self.shards[l].params.read().unwrap();
        (p.w.rows(), p.w.cols(), p.b.len())
    }

    /// Group-scoped version-gated read for the transport endpoint
    /// (`transport::ShardService`): the per-layer logic of `fetch_into`
    /// restricted to `layers`. `sink` is called once per layer in
    /// order — `Some((rev, params))` under that shard's read lock for a
    /// layer whose revision moved past `last_seen` (the endpoint
    /// serializes the bits straight onto the wire), `None` for a layer
    /// the gate skipped (confirmed by the same revision double-check as
    /// `fetch_into`, so the subscriber's buffered copy is known
    /// current). `own` is cleared and refilled with `worker`'s applied
    /// counts for the group's layers. Deliberately does not touch the
    /// server-wide read/copy counters: transport accounting lives at
    /// the message boundary (`RemoteClient::wire_stats`).
    pub fn fetch_group_gated(
        &self,
        worker: usize,
        layers: std::ops::Range<usize>,
        last_seen: &[u64],
        own: &mut Vec<u64>,
        mut sink: impl FnMut(usize, Option<(u64, &LayerParams)>),
    ) -> ReadStats {
        assert!(layers.end <= self.shards.len(), "group out of range");
        assert_eq!(last_seen.len(), layers.len(), "group last_seen");
        let c = self.clocks.clock(worker);
        let s = self.policy.staleness().unwrap_or(u64::MAX);
        let through = c.saturating_sub(s);
        let committed: Vec<u64> =
            (0..self.workers).map(|q| self.clocks.clock(q)).collect();
        let live: Vec<bool> =
            (0..self.workers).map(|q| self.clocks.is_live(q)).collect();
        let mut stats = ReadStats::default();
        own.clear();
        for (i, l) in layers.enumerate() {
            let shard = &self.shards[l];
            let own_mark = own.len();
            let stats_mark = stats;
            let rev_pre = shard.rev.load(Ordering::SeqCst);
            if rev_pre == last_seen[i] {
                Self::layer_read_stats(
                    shard, worker, through, &committed, &live, own, &mut stats,
                );
                if shard.rev.load(Ordering::SeqCst) == rev_pre {
                    sink(l, None);
                    continue;
                }
                // raced an effective update: discard the tentative
                // accounting and fall through to the locked copy
                own.truncate(own_mark);
                stats = stats_mark;
            }
            let params = shard.params.read().unwrap();
            let rev = shard.rev.load(Ordering::SeqCst);
            Self::layer_read_stats(
                shard, worker, through, &committed, &live, own, &mut stats,
            );
            sink(l, Some((rev, &params)));
            drop(params);
        }
        stats
    }

    /// Group-scoped gated snapshot for the transport endpoint — the
    /// `snapshot_into_gated` sibling of `fetch_group_gated` (no worker,
    /// no ε statistics).
    pub fn snapshot_group_gated(
        &self,
        layers: std::ops::Range<usize>,
        last_seen: &[u64],
        mut sink: impl FnMut(usize, Option<(u64, &LayerParams)>),
    ) {
        assert!(layers.end <= self.shards.len(), "group out of range");
        assert_eq!(last_seen.len(), layers.len(), "group last_seen");
        for (i, l) in layers.enumerate() {
            let shard = &self.shards[l];
            if shard.rev.load(Ordering::SeqCst) == last_seen[i] {
                sink(l, None);
                continue;
            }
            let params = shard.params.read().unwrap();
            let rev = shard.rev.load(Ordering::SeqCst);
            sink(l, Some((rev, &params)));
            drop(params);
        }
    }

    /// Applied clocks of `(layer, worker)` — the version vector, read
    /// lock-free.
    pub fn applied(&self, layer: usize, worker: usize) -> u64 {
        self.shards[layer].versions[worker].load(Ordering::Acquire)
    }

    pub fn applied_count(&self) -> u64 {
        self.applied.load(Ordering::Relaxed)
    }

    pub fn bytes_received(&self) -> u64 {
        self.bytes_received.load(Ordering::Relaxed)
    }

    pub fn reads(&self) -> u64 {
        self.reads.load(Ordering::Relaxed)
    }
}

impl ParamServer for ShardedServer {
    fn policy(&self) -> Policy {
        ShardedServer::policy(self)
    }

    fn workers(&self) -> usize {
        ShardedServer::workers(self)
    }

    fn n_layers(&self) -> usize {
        ShardedServer::n_layers(self)
    }

    fn clock(&self, worker: usize) -> u64 {
        self.clocks.clock(worker)
    }

    fn commit(&mut self, worker: usize) -> u64 {
        ShardedServer::commit(self, worker)
    }

    fn apply_arrival(&mut self, msg: &UpdateMsg) {
        ShardedServer::apply_arrival(self, msg)
    }

    fn must_wait(&self, worker: usize) -> bool {
        ShardedServer::must_wait(self, worker)
    }

    fn read_ready(&self, worker: usize) -> bool {
        ShardedServer::read_ready(self, worker)
    }

    fn fetch(&mut self, worker: usize) -> (ParamSet, Vec<u64>, ReadStats) {
        ShardedServer::fetch(self, worker)
    }

    fn fetch_into(
        &mut self,
        worker: usize,
        buf: &mut ParamSet,
        last_seen: &mut [u64],
        own: &mut Vec<u64>,
    ) -> (ReadStats, FetchStats) {
        ShardedServer::fetch_into(self, worker, buf, last_seen, own)
    }

    fn snapshot(&self) -> ParamSet {
        ShardedServer::snapshot(self)
    }

    fn snapshot_into(&self, buf: &mut ParamSet) {
        ShardedServer::snapshot_into(self, buf)
    }

    fn copy_totals(&self) -> FetchStats {
        ShardedServer::copy_totals(self)
    }

    fn applied(&self, layer: usize, worker: usize) -> u64 {
        ShardedServer::applied(self, layer, worker)
    }

    fn reads(&self) -> u64 {
        ShardedServer::reads(self)
    }

    fn membership_epoch(&self) -> u64 {
        ShardedServer::membership_epoch(self)
    }

    fn is_live(&self, worker: usize) -> bool {
        ShardedServer::is_live(self, worker)
    }

    fn live_mask(&self) -> u64 {
        ShardedServer::live_mask(self)
    }

    fn evict_worker(&mut self, worker: usize) -> u64 {
        ShardedServer::evict_worker(self, worker)
    }

    fn admit_worker(&mut self, worker: usize) -> u64 {
        ShardedServer::admit_worker(self, worker)
    }
}

/// The shared-memory backing of the threaded runner: every worker
/// thread holds a `&ShardedServer` port onto the same server.
/// (Delegations deref `self` explicitly — `*self` is the
/// `&ShardedServer` — so the name-colliding inherent methods are
/// targeted unambiguously.)
impl WorkerPort for &ShardedServer {
    fn wait_until_ready(&mut self, worker: usize) {
        ShardedServer::wait_until_ready(*self, worker)
    }

    fn fetch_view(
        &mut self,
        worker: usize,
        buf: &mut ParamSet,
        last_seen: &mut [u64],
        own: &mut Vec<u64>,
    ) -> (ReadStats, FetchStats) {
        ShardedServer::fetch_into(*self, worker, buf, last_seen, own)
    }

    fn commit_clock(&mut self, worker: usize) -> u64 {
        ShardedServer::commit(*self, worker)
    }

    fn apply_commit(&mut self, worker: usize, clock: u64, delta: &GradSet) {
        ShardedServer::apply_commit(*self, worker, clock, delta)
    }

    fn snapshot_gated(
        &mut self,
        buf: &mut ParamSet,
        last_seen: &mut [u64],
    ) -> FetchStats {
        ShardedServer::snapshot_into_gated(*self, buf, last_seen)
    }

    fn master_snapshot(&mut self) -> ParamSet {
        ShardedServer::snapshot(*self)
    }

    fn membership(&mut self) -> (u64, u64) {
        (
            ShardedServer::membership_epoch(*self),
            ShardedServer::live_mask(*self),
        )
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::nn::LayerParams;
    use crate::ssp::Server;
    use crate::tensor::Matrix;

    fn dims() -> Vec<usize> {
        vec![2, 3, 2]
    }

    fn msg(from: usize, clock: u64, layer: usize) -> UpdateMsg {
        let d = dims();
        UpdateMsg::new(
            from,
            clock,
            layer,
            LayerParams {
                w: Matrix::from_fn(d[layer], d[layer + 1], |_, _| 0.1),
                b: vec![0.1; d[layer + 1]],
            },
        )
    }

    fn commit_and_arrive(srv: &ShardedServer, worker: usize) {
        let clock = srv.clocks().clock(worker);
        srv.commit(worker);
        for l in 0..srv.n_layers() {
            srv.apply_arrival(&msg(worker, clock, l));
        }
    }

    #[test]
    fn ssp_read_guarantee() {
        let srv = ShardedServer::new(
            ParamSet::zeros(&dims()),
            2,
            Policy::Ssp { staleness: 1 },
        );
        commit_and_arrive(&srv, 0);
        commit_and_arrive(&srv, 1);
        srv.commit(0); // clock-1 arrival delayed
        assert!(srv.read_ready(0));
        assert!(srv.read_ready(1));
    }

    #[test]
    fn read_not_ready_when_guaranteed_update_missing() {
        let srv = ShardedServer::new(
            ParamSet::zeros(&dims()),
            2,
            Policy::Ssp { staleness: 0 },
        );
        srv.commit(1);
        srv.commit(0);
        assert!(!srv.read_ready(0));
        for l in 0..srv.n_layers() {
            srv.apply_arrival(&msg(1, 0, l));
        }
        assert!(!srv.read_ready(0));
        for l in 0..srv.n_layers() {
            srv.apply_arrival(&msg(0, 0, l));
        }
        assert!(srv.read_ready(0));
    }

    #[test]
    fn epsilon_stats_count_window_inclusion() {
        let srv = ShardedServer::new(
            ParamSet::zeros(&dims()),
            2,
            Policy::Ssp { staleness: 2 },
        );
        srv.commit(1);
        srv.apply_arrival(&msg(1, 0, 0));
        srv.apply_arrival(&msg(1, 0, 1));
        srv.commit(1);
        let (_, own, stats) = srv.fetch(0);
        assert_eq!(own, vec![0, 0]);
        assert_eq!(stats.guaranteed, 0);
        assert_eq!(stats.window_included, 2);
        assert_eq!(stats.window_missed, 2);
        assert!((stats.epsilon_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn barrier_is_lock_free_and_matches_clock_table() {
        let srv = ShardedServer::new(
            ParamSet::zeros(&dims()),
            2,
            Policy::Ssp { staleness: 0 },
        );
        srv.commit(0);
        assert!(srv.must_wait(0));
        assert!(!srv.must_wait(1));
        srv.commit(1);
        assert!(!srv.must_wait(0));
    }

    #[test]
    fn async_always_ready() {
        let srv = ShardedServer::new(ParamSet::zeros(&dims()), 3, Policy::Async);
        for _ in 0..5 {
            srv.commit(0);
        }
        assert!(srv.read_ready(0));
        assert!(!srv.must_wait(0));
    }

    #[test]
    #[should_panic(expected = "out-of-order")]
    fn out_of_order_update_rejected() {
        let srv = ShardedServer::new(ParamSet::zeros(&dims()), 2, Policy::Bsp);
        srv.apply_arrival(&msg(0, 1, 0)); // skips clock 0
    }

    #[test]
    fn matches_reference_server_bitwise_on_a_fixed_schedule() {
        let init = {
            let mut rng = crate::util::Pcg64::new(42);
            ParamSet::glorot(&dims(), &mut rng)
        };
        let policy = Policy::Ssp { staleness: 2 };
        let mut reference = Server::new(init.clone(), 2, policy);
        let sharded = ShardedServer::new(init, 2, policy);

        for clock in 0..3u64 {
            for worker in 0..2 {
                reference.commit(worker);
                sharded.commit(worker);
                for l in 0..2 {
                    let m = msg(worker, clock, l);
                    reference.apply_arrival(&m);
                    sharded.apply_arrival(&m);
                }
            }
            let (p_ref, own_ref, st_ref) = reference.fetch(0);
            let (p_sh, own_sh, st_sh) = sharded.fetch(0);
            assert_eq!(p_ref, p_sh, "master diverged at clock {clock}");
            assert_eq!(own_ref, own_sh);
            assert_eq!(st_ref, st_sh);
        }
        assert_eq!(reference.reads(), sharded.reads());
    }

    #[test]
    fn wait_until_ready_blocks_and_wakes() {
        let srv = Arc::new(ShardedServer::new(
            ParamSet::zeros(&dims()),
            2,
            Policy::Bsp,
        ));
        // worker 0 is one clock ahead: it must wait for worker 1
        commit_and_arrive(&srv, 0);
        assert!(srv.must_wait(0));
        let waiter = {
            let srv = Arc::clone(&srv);
            std::thread::spawn(move || {
                srv.wait_until_ready(0);
                srv.clocks().clock(1)
            })
        };
        std::thread::sleep(std::time::Duration::from_millis(20));
        commit_and_arrive(&srv, 1); // releases the waiter
        let seen = waiter.join().unwrap();
        assert_eq!(seen, 1);
        assert!(srv.is_ready(0));
    }

    #[test]
    fn fetch_into_matches_full_fetch_and_gates_unchanged_layers() {
        let policy = Policy::Ssp { staleness: 3 };
        let init = {
            let mut rng = crate::util::Pcg64::new(11);
            ParamSet::glorot(&dims(), &mut rng)
        };
        let srv = ShardedServer::new(init.clone(), 2, policy);
        let mut buf = init.clone();
        let mut seen = vec![0u64; srv.n_layers()];
        let mut own = Vec::new();

        // nothing applied yet: gated fetch copies nothing, matches full
        let (st_into, fs) = srv.fetch_into(0, &mut buf, &mut seen, &mut own);
        assert_eq!(fs.layers_copied, 0);
        assert_eq!(fs.layers_skipped, 2);
        let (full, own_full, st_full) = srv.fetch(0);
        assert_eq!(buf, full);
        assert_eq!(own, own_full);
        assert_eq!(st_into, st_full);

        // one layer changes: exactly that layer is copied
        srv.commit(1);
        srv.apply_arrival(&msg(1, 0, 1));
        let (_, fs) = srv.fetch_into(0, &mut buf, &mut seen, &mut own);
        assert_eq!(fs.layers_copied, 1);
        assert_eq!(fs.layers_skipped, 1);
        assert!(fs.bytes_copied > 0);
        let (full, _, _) = srv.fetch(0);
        assert_eq!(buf, full);

        // buffer reuse across clocks keeps matching the full fetch
        srv.apply_arrival(&msg(1, 0, 0));
        srv.commit(0);
        srv.apply_arrival(&msg(0, 0, 0));
        srv.apply_arrival(&msg(0, 0, 1));
        let (_, fs) = srv.fetch_into(0, &mut buf, &mut seen, &mut own);
        assert_eq!(fs.layers_copied, 2);
        let (full, own_full, _) = srv.fetch(0);
        assert_eq!(buf, full);
        assert_eq!(own, own_full);
        let totals = srv.copy_totals();
        assert_eq!(totals.layers_copied, 3);
        assert_eq!(totals.layers_skipped, 3);
    }

    #[test]
    fn apply_commit_matches_message_path() {
        let init = {
            let mut rng = crate::util::Pcg64::new(13);
            ParamSet::glorot(&dims(), &mut rng)
        };
        let policy = Policy::Ssp { staleness: 2 };
        let by_msg = ShardedServer::new(init.clone(), 2, policy);
        let direct = ShardedServer::new(init.clone(), 2, policy);

        let mut delta = init.zeros_like();
        for (l, lp) in delta.layers.iter_mut().enumerate() {
            *lp = msg(0, 0, l).delta;
        }
        for clock in 0..3u64 {
            let msgs: Vec<UpdateMsg> = delta
                .layers
                .iter()
                .enumerate()
                .map(|(l, lp)| UpdateMsg::new(0, clock, l, lp.clone()))
                .collect();
            by_msg.commit(0);
            by_msg.apply_arrivals(&msgs);
            direct.commit(0);
            direct.apply_commit(0, clock, &delta);
        }
        assert_eq!(by_msg.snapshot(), direct.snapshot());
        assert_eq!(by_msg.applied_count(), direct.applied_count());
        assert_eq!(by_msg.bytes_received(), direct.bytes_received());
        for l in 0..2 {
            assert_eq!(by_msg.applied(l, 0), direct.applied(l, 0));
        }
    }

    #[test]
    fn zero_delta_advances_versions_but_not_revision() {
        let srv = ShardedServer::new(ParamSet::zeros(&dims()), 1, Policy::Async);
        let mut buf = ParamSet::zeros(&dims());
        let mut seen = vec![0u64; srv.n_layers()];
        let mut own = Vec::new();
        let zero = ParamSet::zeros(&dims());
        srv.commit(0);
        srv.apply_commit(0, 0, &zero);
        // protocol bookkeeping advanced...
        assert_eq!(srv.applied(0, 0), 1);
        assert_eq!(srv.applied(1, 0), 1);
        // ...but θ cannot have changed, so the gate skips every layer
        let (_, fs) = srv.fetch_into(0, &mut buf, &mut seen, &mut own);
        assert_eq!(fs.layers_copied, 0);
        assert_eq!(fs.layers_skipped, 2);
        assert_eq!(own, vec![1, 1]);
        assert_eq!(buf, srv.snapshot());
    }

    #[test]
    fn concurrent_gated_fetch_keeps_accounting_consistent() {
        // hammer fetch_into from a reader thread while a writer commits
        // effective updates: exercises the raced-skip rollback (rev
        // moved between the two SeqCst loads), whose regression mode is
        // duplicated `own` entries / double-counted stats. Async policy
        // so neither side ever blocks.
        let srv = Arc::new(ShardedServer::new(
            ParamSet::zeros(&dims()),
            2,
            Policy::Async,
        ));
        let clocks = 300u64;
        std::thread::scope(|scope| {
            {
                let srv = Arc::clone(&srv);
                scope.spawn(move || {
                    for clock in 0..clocks {
                        srv.commit(1);
                        for l in 0..srv.n_layers() {
                            srv.apply_arrival(&msg(1, clock, l));
                        }
                    }
                });
            }
            let srv = Arc::clone(&srv);
            scope.spawn(move || {
                let mut buf = ParamSet::zeros(&dims());
                let mut seen = vec![0u64; srv.n_layers()];
                let mut own = Vec::new();
                let layers = srv.n_layers() as u64;
                while srv.applied(0, 1) < clocks {
                    let (_, fs) =
                        srv.fetch_into(0, &mut buf, &mut seen, &mut own);
                    assert_eq!(
                        own.len(),
                        srv.n_layers(),
                        "own must have exactly one entry per layer"
                    );
                    assert!(own.iter().all(|&v| v == 0), "worker 0 never wrote");
                    assert_eq!(fs.layers_copied + fs.layers_skipped, layers);
                }
            });
        });
        // quiescent: a final gated fetch must exactly match the master
        let mut buf = ParamSet::zeros(&dims());
        let mut seen = vec![0u64; srv.n_layers()];
        let mut own = Vec::new();
        srv.fetch_into(0, &mut buf, &mut seen, &mut own);
        assert_eq!(buf, srv.snapshot());
    }

    #[test]
    fn gated_snapshot_tracks_master() {
        let srv = ShardedServer::new(
            ParamSet::zeros(&dims()),
            2,
            Policy::Ssp { staleness: 2 },
        );
        let mut buf = ParamSet::zeros(&dims());
        let mut seen = vec![0u64; srv.n_layers()];
        let fs = srv.snapshot_into_gated(&mut buf, &mut seen);
        assert_eq!(fs.layers_copied, 0);
        srv.commit(0);
        srv.apply_arrival(&msg(0, 0, 0));
        let fs = srv.snapshot_into_gated(&mut buf, &mut seen);
        assert_eq!(fs.layers_copied, 1);
        assert_eq!(fs.layers_skipped, 1);
        assert_eq!(buf, srv.snapshot());
        // plain snapshot_into always copies everything
        let mut full = ParamSet::zeros(&dims());
        srv.snapshot_into(&mut full);
        assert_eq!(full, buf);
    }

    #[test]
    fn group_gated_fetch_matches_fetch_into() {
        // driving the two halves [0, 1) and [1, 2) through the group
        // path must reproduce the whole-model gated fetch exactly:
        // same bits, same own counts, same summed ε stats, same gate
        // decisions
        let policy = Policy::Ssp { staleness: 3 };
        let init = {
            let mut rng = crate::util::Pcg64::new(21);
            ParamSet::glorot(&dims(), &mut rng)
        };
        let srv = ShardedServer::new(init.clone(), 2, policy);
        let oracle = ShardedServer::new(init.clone(), 2, policy);

        let mut buf = init.clone();
        let mut seen = vec![0u64; 2];
        let mut o_buf = init.clone();
        let mut o_seen = vec![0u64; 2];
        let mut o_own = Vec::new();

        for round in 0..3 {
            if round > 0 {
                let clock = round as u64 - 1;
                for s in [&srv, &oracle] {
                    s.commit(1);
                    // only layer 1 changes on round 2: the gate must
                    // skip layer 0 in both paths
                    if round == 1 {
                        s.apply_arrival(&msg(1, clock, 0));
                    }
                    s.apply_arrival(&msg(1, clock, 1));
                }
            }
            let (o_stats, o_fs) =
                oracle.fetch_into(0, &mut o_buf, &mut o_seen, &mut o_own);
            let mut stats_sum = ReadStats::default();
            let mut fs_sum = FetchStats::default();
            let mut own_all = Vec::new();
            for g in 0..2usize {
                let range = g..g + 1;
                let mut own = Vec::new();
                // snapshot of the gate state the request carries (the
                // wire path copies it into the request frame anyway)
                let seen_group: Vec<u64> = seen[range.clone()].to_vec();
                let stats = srv.fetch_group_gated(
                    0,
                    range.clone(),
                    &seen_group,
                    &mut own,
                    |l, copied| match copied {
                        None => fs_sum.layers_skipped += 1,
                        Some((rev, lp)) => {
                            buf.layers[l].copy_from(lp);
                            seen[l] = rev;
                            fs_sum.layers_copied += 1;
                            fs_sum.bytes_copied += lp.n_bytes() as u64;
                        }
                    },
                );
                stats_sum.guaranteed += stats.guaranteed;
                stats_sum.window_included += stats.window_included;
                stats_sum.window_missed += stats.window_missed;
                own_all.extend_from_slice(&own);
            }
            assert_eq!(buf, o_buf, "round {round}");
            assert_eq!(seen, o_seen, "round {round}");
            assert_eq!(own_all, o_own, "round {round}");
            assert_eq!(stats_sum, o_stats, "round {round}");
            assert_eq!(fs_sum, o_fs, "round {round}");
        }
    }

    #[test]
    fn group_gated_snapshot_skips_unchanged() {
        let srv = ShardedServer::new(ParamSet::zeros(&dims()), 1, Policy::Async);
        srv.commit(0);
        srv.apply_arrival(&msg(0, 0, 1));
        let mut seen = vec![0u64; 2];
        let seen_req = seen.clone(); // the gate state the request carries
        let mut copied = Vec::new();
        let mut buf = ParamSet::zeros(&dims());
        srv.snapshot_group_gated(0..2, &seen_req, |l, c| {
            if let Some((rev, lp)) = c {
                buf.layers[l].copy_from(lp);
                seen[l] = rev;
                copied.push(l);
            }
        });
        assert_eq!(copied, vec![1], "only the touched layer ships");
        assert_eq!(buf, srv.snapshot());
    }

    #[test]
    fn layer_shape_reports_wire_dims() {
        let srv = ShardedServer::new(ParamSet::zeros(&dims()), 1, Policy::Bsp);
        assert_eq!(srv.layer_shape(0), (2, 3, 3));
        assert_eq!(srv.layer_shape(1), (3, 2, 2));
    }

    #[test]
    fn concurrent_commits_hold_staleness_bound() {
        let s = 2u64;
        let srv = Arc::new(ShardedServer::new(
            ParamSet::zeros(&dims()),
            4,
            Policy::Ssp { staleness: s },
        ));
        let clocks = 30u64;
        std::thread::scope(|scope| {
            for p in 0..4usize {
                let srv = Arc::clone(&srv);
                scope.spawn(move || {
                    for clock in 0..clocks {
                        srv.wait_until_ready(p);
                        // every observable clock obeys the SSP bound
                        // relative to this worker's own clock
                        let own = srv.clocks().clock(p);
                        for q in 0..4 {
                            assert!(
                                srv.clocks().clock(q) <= own + s + 1,
                                "staleness bound broken"
                            );
                        }
                        let ms: Vec<UpdateMsg> =
                            (0..srv.n_layers()).map(|l| msg(p, clock, l)).collect();
                        srv.commit(p);
                        srv.apply_arrivals(&ms);
                    }
                });
            }
        });
        assert_eq!(srv.clocks().min(), clocks);
        assert_eq!(srv.applied_count(), 4 * clocks * 2);
    }

    #[test]
    fn export_state_roundtrips_every_observable() {
        let init = {
            let mut rng = crate::util::Pcg64::new(31);
            ParamSet::glorot(&dims(), &mut rng)
        };
        let srv =
            ShardedServer::new(init, 2, Policy::Ssp { staleness: 2 });
        commit_and_arrive(&srv, 0);
        commit_and_arrive(&srv, 1);
        srv.commit(0); // one in-flight clock: arrival intentionally absent
        let state = srv.export_state();
        assert_eq!(state.clocks, vec![2, 1]);
        assert_eq!(state.layers.len(), 2);

        let restored = ShardedServer::from_state(state.clone());
        // every protocol observable survives the roundtrip
        assert_eq!(restored.snapshot(), srv.snapshot());
        for p in 0..2 {
            assert_eq!(restored.clocks().clock(p), srv.clocks().clock(p));
            assert_eq!(restored.must_wait(p), srv.must_wait(p));
            assert_eq!(restored.read_ready(p), srv.read_ready(p));
            for l in 0..2 {
                assert_eq!(restored.applied(l, p), srv.applied(l, p));
            }
        }
        // gate revisions resume, not reset: a dump/restore is invisible
        // to carried-over last-seen vectors
        assert_eq!(restored.export_state(), state);
        // ...and the restored server keeps operating: the delayed
        // arrival lands with the same FIFO bookkeeping
        for l in 0..restored.n_layers() {
            restored.apply_arrival(&msg(0, 1, l));
        }
        assert_eq!(restored.applied(0, 0), 2);
    }

    #[test]
    fn wake_all_releases_a_timed_waiter_early() {
        let srv = Arc::new(ShardedServer::new(
            ParamSet::zeros(&dims()),
            2,
            Policy::Bsp,
        ));
        commit_and_arrive(&srv, 0); // worker 0 must now wait for worker 1
        let waiter = {
            let srv = Arc::clone(&srv);
            std::thread::spawn(move || {
                let t0 = std::time::Instant::now();
                let ready = srv
                    .wait_ready_timeout(0, std::time::Duration::from_secs(5));
                (ready, t0.elapsed())
            })
        };
        std::thread::sleep(std::time::Duration::from_millis(30));
        srv.wake_all(); // no state change: the waiter re-parks...
        std::thread::sleep(std::time::Duration::from_millis(30));
        commit_and_arrive(&srv, 1); // ...until the real release
        let (ready, waited) = waiter.join().unwrap();
        assert!(ready, "waiter released by the real commit");
        assert!(
            waited < std::time::Duration::from_secs(5),
            "woke before the timeout"
        );
    }
}
