//! The Stale Synchronous Parallel (SSP) parameter server — the paper's
//! coordination substrate (Section 3.1, Eq. 5; Ho et al. 2013).
//!
//! Protocol summary, as quoted by the paper:
//!
//! 1. workers commit additive updates `θ ← θ + u` at the end of each
//!    *clock*; the update from worker `q` at clock `t` is timestamped `t`;
//! 2. the slowest and fastest workers must be ≤ `s` clocks apart — the
//!    fastest blocks otherwise (`ClockTable::must_wait`);
//! 3. a worker reading at clock `c` is guaranteed to see every update with
//!    timestamp ≤ `c − s − 1`;
//! 4. read-my-writes: a worker always sees its own updates;
//! 5. best-effort: it *may* see in-window updates from other workers
//!    (timestamp in `[c − s, c + s − 1]`) — the `ε_{q,p}` indicator of
//!    Eq. (7). Here ε is realized physically: an in-window update is seen
//!    iff its (simulated) network arrival precedes the read.
//!
//! Updates are applied **per layer** (`UpdateMsg` carries one layer's
//! delta): layers synchronize independently of each other, the property
//! Theorem 3's layerwise analysis requires.
//!
//! Reads come in two flavors: the allocating `fetch`/`snapshot`, and the
//! **version-gated zero-copy** `fetch_into`/`snapshot_into` — the caller
//! keeps a reusable snapshot buffer plus a per-layer last-seen revision
//! vector, and the server copies only the layers that actually changed
//! (`FetchStats` reports what the gate moved vs skipped). Layerwise
//! independence is what makes the gate sound: each layer's copy is
//! allowed to be stale independently, exactly like any other SSP read.

mod client;
mod clock;
mod server;
mod sharded;
mod table;
pub mod transport;

pub use client::WorkerCache;
pub use clock::ClockTable;
pub use server::{FetchStats, ReadStats, Server};
pub use sharded::{AtomicClockTable, LayerState, ServerState, ShardedServer};
pub use table::{ParamTable, VersionVector};
pub use transport::{RemoteClient, ShardService};

use crate::nn::{GradSet, LayerParams, ParamSet};

/// The SSP parameter-server protocol surface, implemented by both the
/// single-lock reference `Server` and the scalable `ShardedServer`.
///
/// The trait exists so protocol invariants (P1–P5 in
/// `tests/property_ssp.rs`) and the discrete-event machinery can be
/// checked against *every* implementation, with the reference `Server`
/// acting as the bitwise oracle for equivalence tests. Methods take
/// `&mut self` to accommodate the single-threaded reference
/// implementation; `ShardedServer` additionally offers the same surface
/// on `&self` for lock-free concurrent use.
pub trait ParamServer {
    fn policy(&self) -> Policy;
    fn workers(&self) -> usize;
    fn n_layers(&self) -> usize;
    /// Committed clock count of `worker`.
    fn clock(&self, worker: usize) -> u64;
    /// Worker finished a clock; its updates are now in flight.
    fn commit(&mut self, worker: usize) -> u64;
    /// One layer-update reaches the server.
    fn apply_arrival(&mut self, msg: &UpdateMsg);
    /// SSP condition 1: must the worker block before its next clock?
    fn must_wait(&self, worker: usize) -> bool;
    /// Eq. 5's guarantee: is the master sufficient for a read?
    fn read_ready(&self, worker: usize) -> bool;
    /// Serve a read: snapshot + own applied counts + ε statistics.
    fn fetch(&mut self, worker: usize) -> (ParamSet, Vec<u64>, ReadStats);
    /// Version-gated zero-copy read: identical observable state to
    /// `fetch`, but the snapshot lands in the caller's reusable `buf`
    /// and only layers whose per-layer revision advanced since
    /// `last_seen` are copied (zero-delta updates advance the protocol's
    /// version vector but not the revision — they cannot change θ).
    /// `own` is cleared and refilled with the caller's per-layer applied
    /// counts. The caller must pass the same `(buf, last_seen)` pair it
    /// received the previous gated read into, initially the init
    /// parameters with `last_seen` all zero.
    fn fetch_into(
        &mut self,
        worker: usize,
        buf: &mut ParamSet,
        last_seen: &mut [u64],
        own: &mut Vec<u64>,
    ) -> (ReadStats, FetchStats);
    /// Current master state (evaluation / checkpoint path).
    fn snapshot(&self) -> ParamSet;
    /// Current master state into a reusable buffer (allocation-free
    /// sibling of `snapshot`).
    fn snapshot_into(&self, buf: &mut ParamSet);
    /// Aggregate copy accounting over all gated reads served.
    fn copy_totals(&self) -> FetchStats;
    /// Applied clocks of `(layer, worker)` — the version vector.
    fn applied(&self, layer: usize, worker: usize) -> u64;
    /// Total reads served.
    fn reads(&self) -> u64;

    // ---- elastic membership ----
    //
    // The worker set the protocol's min-clock and ε accounting range
    // over. Every implementation starts all-live at epoch 0; each
    // successful evict/admit transition bumps the epoch exactly once,
    // which is the signal workers rebalance their data shards on.

    /// Current membership epoch (0 ⇔ the original worker set).
    fn membership_epoch(&self) -> u64 {
        0
    }

    /// Membership flag of `worker`.
    fn is_live(&self, _worker: usize) -> bool {
        true
    }

    /// Live set as a bitmask (bit `p` set ⇔ worker `p` live; meaningful
    /// for ≤ 64 workers, which the transport enforces at its boundary).
    fn live_mask(&self) -> u64 {
        (0..self.workers().min(64))
            .filter(|&p| self.is_live(p))
            .fold(0u64, |m, p| m | (1u64 << p))
    }

    /// Remove `worker` from the membership: its applied history stays
    /// in θ and in the ε totals, but it stops bounding the staleness
    /// barrier, stops gating `read_ready`, and its never-applied window
    /// contributions drop from future reads' ε stats. Idempotent;
    /// returns the epoch after the call.
    fn evict_worker(&mut self, worker: usize) -> u64;

    /// Re-admit an evicted `worker`, fast-forwarding its clock and
    /// version entries to the live min so it neither stalls the barrier
    /// nor trips FIFO bookkeeping. Idempotent; returns the epoch after
    /// the call.
    fn admit_worker(&mut self, worker: usize) -> u64;
}

/// Per-worker handle onto a (possibly remote) SSP server for the
/// real-thread runner (`coordinator::run_threaded_on`): the `&mut self`
/// surface one worker thread drives for its whole run. Implemented by
/// `&ShardedServer` (shared memory — every thread's port is a reference
/// to the same server) and by `transport::RemoteClient` (one message
/// endpoint set per worker, the multi-process deployment shape).
///
/// The methods mirror the zero-copy hot path of `run_threaded`:
/// barrier + read-guarantee wait, version-gated fetch into the worker's
/// view buffer, clock commit, allocation-free delta hand-off, and the
/// gated evaluation snapshot.
pub trait WorkerPort: Send {
    /// Block until `worker` may start its next clock (barrier cleared
    /// and Eq. 5's read guarantee met).
    fn wait_until_ready(&mut self, worker: usize);
    /// `ParamServer::fetch_into` through this port.
    fn fetch_view(
        &mut self,
        worker: usize,
        buf: &mut ParamSet,
        last_seen: &mut [u64],
        own: &mut Vec<u64>,
    ) -> (ReadStats, FetchStats);
    /// Advance the clock table; returns the new committed count.
    fn commit_clock(&mut self, worker: usize) -> u64;
    /// Hand the clock's accumulated per-layer deltas to the server
    /// (the `ShardedServer::apply_commit` contract: call `commit_clock`
    /// first, deltas carry the just-finished clock's timestamp).
    fn apply_commit(&mut self, worker: usize, clock: u64, delta: &GradSet);
    /// Version-gated evaluation snapshot (`snapshot_into_gated`).
    fn snapshot_gated(
        &mut self,
        buf: &mut ParamSet,
        last_seen: &mut [u64],
    ) -> FetchStats;
    /// Full master snapshot (the end-of-run read).
    fn master_snapshot(&mut self) -> ParamSet;
    /// Membership observation for the rebalance check: `(epoch, live
    /// bitmask)`. Cheap — the shared-memory port reads the server's
    /// counters, the remote port answers from the epoch piggybacked on
    /// its latest gated read and only round-trips when it moved.
    /// Fixed-membership ports report `(0, !0)`.
    fn membership(&mut self) -> (u64, u64) {
        (0, !0u64)
    }
}

/// Consistency policy. `Bsp` ≡ `Ssp{staleness: 0}` with a full barrier;
/// `Async` removes the barrier entirely (no staleness bound — included as
/// the divergence-prone baseline the paper contrasts against, cf. Dean et
/// al. 2012).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    Bsp,
    Ssp { staleness: u64 },
    Async,
}

impl Policy {
    /// The staleness bound, `None` meaning unbounded.
    pub fn staleness(&self) -> Option<u64> {
        match self {
            Policy::Bsp => Some(0),
            Policy::Ssp { staleness } => Some(*staleness),
            Policy::Async => None,
        }
    }

    pub fn name(&self) -> String {
        match self {
            Policy::Bsp => "bsp".into(),
            Policy::Ssp { staleness } => format!("ssp(s={staleness})"),
            Policy::Async => "async".into(),
        }
    }
}

/// One layer's additive update from worker `from` committed at `clock`.
#[derive(Clone, Debug)]
pub struct UpdateMsg {
    pub from: usize,
    pub clock: u64,
    pub layer: usize,
    pub delta: LayerParams,
    /// Serialized size in bytes (for the network model).
    pub bytes: usize,
}

impl UpdateMsg {
    pub fn new(from: usize, clock: u64, layer: usize, delta: LayerParams) -> Self {
        let bytes = (delta.w.len() + delta.b.len()) * 4 + 32;
        UpdateMsg {
            from,
            clock,
            layer,
            delta,
            bytes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_staleness() {
        assert_eq!(Policy::Bsp.staleness(), Some(0));
        assert_eq!(Policy::Ssp { staleness: 10 }.staleness(), Some(10));
        assert_eq!(Policy::Async.staleness(), None);
        assert_eq!(Policy::Ssp { staleness: 3 }.name(), "ssp(s=3)");
    }

    #[test]
    fn update_msg_sizes() {
        use crate::tensor::Matrix;
        let m = UpdateMsg::new(
            1,
            4,
            0,
            LayerParams {
                w: Matrix::zeros(10, 5),
                b: vec![0.0; 5],
            },
        );
        assert_eq!(m.bytes, 55 * 4 + 32);
    }
}
