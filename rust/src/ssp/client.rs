//! Worker-side cache: the stale snapshot θ̃_{p,c} plus read-my-writes.
//!
//! Between fetches, a worker computes against its cached view with its
//! own pending updates folded in (SSP condition 4). At a clock boundary
//! it either drains the accumulated per-layer deltas into `UpdateMsg`s
//! for the server (`commit_clock`, the message path) or hands the
//! accumulated `GradSet` straight to the shared-memory server
//! (`pending` + `finish_commit`, the allocation-free path), and on fetch
//! refreshes the view — in place, through the version-gated
//! `ParamServer::fetch_into`, when running the zero-copy path.

use crate::nn::{GradSet, ParamSet};

use super::UpdateMsg;

#[derive(Clone, Debug)]
pub struct WorkerCache {
    worker: usize,
    /// θ̃_{p,c}: server snapshot + own folded-in updates — what the
    /// worker computes against. On the zero-copy path this buffer is
    /// also the target `fetch_into` copies changed layers into.
    view: ParamSet,
    /// Per-layer server revisions the view buffer last absorbed — the
    /// version gate's memory (`u64::MAX` = unknown, copy everything).
    last_seen: Vec<u64>,
    /// Layers that received a nonzero local fold-in since the last
    /// refresh. Folding `a1·g1` then `a2·g2` into the view is not
    /// bitwise the same as the server folding their committed sum once
    /// (f32 addition is non-associative) — and the sum can even cancel
    /// to exactly zero, in which case the server's revision would not
    /// advance and the gate would wrongly keep our drifted bits. Touched
    /// layers therefore force a recopy at the next refresh.
    touched: Vec<bool>,
    /// Scratch for the per-layer own-applied counts a fetch reports.
    own_scratch: Vec<u64>,
    /// Updates accumulated in the current (uncommitted) clock.
    pending: GradSet,
    pending_dirty: bool,
    /// Clock this worker is currently computing (timestamps of pending).
    clock: u64,
}

impl WorkerCache {
    /// `init` must be the same initial parameters the server was built
    /// with: the zero-copy fetch path starts from the shared premise
    /// that the view buffer holds the master state at revision 0.
    pub fn new(worker: usize, init: ParamSet) -> WorkerCache {
        let pending = init.zeros_like();
        let layers = init.n_layers();
        WorkerCache {
            worker,
            view: init,
            last_seen: vec![0; layers],
            touched: vec![false; layers],
            own_scratch: Vec::with_capacity(layers),
            pending,
            pending_dirty: false,
            clock: 0,
        }
    }

    pub fn worker(&self) -> usize {
        self.worker
    }

    pub fn clock(&self) -> u64 {
        self.clock
    }

    /// θ̃_{p,c}: the parameters this worker computes gradients against.
    pub fn view(&self) -> &ParamSet {
        &self.view
    }

    /// Accumulate a local additive update (−η·grad, Eq. 7's Δw^p term) and
    /// fold it into the view immediately (read-my-writes).
    pub fn add_local_update(&mut self, update: &GradSet) {
        self.add_scaled_local_update(1.0, update);
    }

    /// Scaled variant: add `alpha * g` (e.g. `alpha = -eta`).
    pub fn add_scaled_local_update(&mut self, alpha: f32, g: &GradSet) {
        self.pending.axpy(alpha, g);
        self.view.axpy(alpha, g);
        if alpha != 0.0 {
            for (t, lp) in self.touched.iter_mut().zip(&g.layers) {
                // early-exits at the first nonzero entry: O(1) on dense
                // gradients, a full scan only for genuinely zero layers
                if !*t && !lp.is_zero() {
                    *t = true;
                }
            }
        }
        self.pending_dirty = true;
    }

    /// End the current clock: drain pending updates into per-layer
    /// messages timestamped with the finished clock, advance local clock.
    pub fn commit_clock(&mut self) -> Vec<UpdateMsg> {
        let mut msgs = Vec::with_capacity(self.pending.n_layers());
        for (layer, lp) in self.pending.layers.iter().enumerate() {
            msgs.push(UpdateMsg::new(self.worker, self.clock, layer, lp.clone()));
        }
        self.finish_commit();
        msgs
    }

    /// The current clock's accumulated deltas — the payload the
    /// allocation-free commit path (`ShardedServer::apply_commit`) reads
    /// directly instead of cloning into messages. Pair with
    /// `finish_commit` once the server has taken the update.
    pub fn pending(&self) -> &GradSet {
        &self.pending
    }

    /// Close out the current clock after the server has absorbed
    /// `pending` (via `commit_clock`'s messages or `apply_commit`):
    /// zero the accumulator and advance the local clock.
    pub fn finish_commit(&mut self) {
        self.pending.fill_zero();
        self.pending_dirty = false;
        self.clock += 1;
    }

    /// Zero-copy refresh target for `ParamServer::fetch_into`: the view
    /// buffer, its per-layer last-seen revision vector, and the
    /// own-applied scratch, as one reusable package.
    ///
    /// Contract (shared-memory workers): callers fetch at clock
    /// boundaries, *after* their own commit has been applied at the
    /// server — the refreshed view is then exactly the server snapshot
    /// and no read-my-writes re-fold is needed. Layers the gate may
    /// skip are exactly the layers to which no effective update was
    /// applied *and* into which this worker folded nothing nonzero
    /// (touched layers have their gate entry invalidated here, forcing
    /// a recopy), so a skipped layer's buffer matches the master
    /// bit-for-bit up to the sign of zero.
    pub fn refresh_target(
        &mut self,
    ) -> (&mut ParamSet, &mut [u64], &mut Vec<u64>) {
        assert!(
            !self.pending_dirty,
            "fetch mid-clock would lose read-my-writes accounting"
        );
        for (seen, t) in self.last_seen.iter_mut().zip(&mut self.touched) {
            if *t {
                *seen = u64::MAX; // our fold-ins drifted this layer: recopy
                *t = false;
            }
        }
        (&mut self.view, &mut self.last_seen, &mut self.own_scratch)
    }

    /// Per-layer applied counts of this worker's own updates reported by
    /// the most recent gated fetch (the `own` scratch `refresh_target`
    /// hands to `ParamServer::fetch_into`). Empty before the first fetch.
    pub fn own_applied(&self) -> &[u64] {
        &self.own_scratch
    }

    /// Message-path read-my-writes re-fold for the zero-copy driver:
    /// after a gated `fetch_into`, fold back the portion of this
    /// worker's committed updates the server has not applied yet
    /// (`missing`), restricted to the layers flagged in `mask`. Folded
    /// layers are marked touched — their view bits now differ from the
    /// master, so the next `refresh_target` forces a recopy regardless
    /// of the server revision. This is the in-place equivalent of
    /// `install_snapshot`'s `view = snapshot + own_missing` for layers
    /// the gate refreshed, and a no-op (bitwise, up to the sign of
    /// zero) for layers it soundly skipped.
    pub fn refold_own_missing(&mut self, missing: &GradSet, mask: &[bool]) {
        assert!(
            !self.pending_dirty,
            "refold mid-clock would lose read-my-writes accounting"
        );
        assert_eq!(mask.len(), self.view.n_layers(), "refold mask layers");
        for (l, &folded) in mask.iter().enumerate() {
            if folded {
                self.view.axpy_layer(l, 1.0, &missing.layers[l]);
                self.touched[l] = true;
            }
        }
    }

    /// Invalidate the version gate without touching the view bits: the
    /// next gated fetch recopies every layer. Call after reconnecting
    /// to a *new server lifetime* — per-layer revision counters restart
    /// at zero on a fresh server, so a last-seen vector carried over
    /// from a previous lifetime could collide with the new counters and
    /// wrongly keep stale bits (within one lifetime revisions only
    /// grow, so a stale vector is safe and merely copies more). The
    /// pending accumulator and clock are deliberately left alone: a
    /// reconnect does not un-commit anything.
    pub fn reset_gate(&mut self) {
        self.last_seen.fill(u64::MAX);
        self.touched.fill(false);
    }

    /// Rejoin warm-start: adopt `clock` as the clock this worker will
    /// compute next and discard any half-accumulated pending deltas —
    /// a worker that was evicted and re-admitted resumes *at the live
    /// minimum*, not where it crashed, because the server fast-forwarded
    /// its clock row on admit and would reject commits timestamped in
    /// the past. The version gate is invalidated too (the view's
    /// provenance relative to the current server is unknown); the view
    /// bits themselves are left for the follow-up snapshot/gated fetch
    /// to overwrite.
    pub fn resume_at(&mut self, clock: u64) {
        self.pending.fill_zero();
        self.pending_dirty = false;
        self.clock = clock;
        self.reset_gate();
    }

    /// Install a fresh server snapshot (the message path: the snapshot
    /// may or may not include this worker's own recent commits).
    /// `own_missing` is the portion of our committed updates NOT yet in
    /// the snapshot (computed by the caller from the server's per-layer
    /// applied counts); it is re-folded on top so read-my-writes is
    /// never violated. Invalidates the version gate: the next gated
    /// fetch copies every layer.
    pub fn install_snapshot(&mut self, snapshot: ParamSet, own_missing: &GradSet) {
        assert!(
            !self.pending_dirty,
            "fetch mid-clock would lose read-my-writes accounting"
        );
        self.view = snapshot;
        self.view.axpy(1.0, own_missing);
        // unknown provenance relative to the server's revision counters
        self.last_seen.fill(u64::MAX);
        self.touched.fill(false);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn dims() -> Vec<usize> {
        vec![3, 4, 2]
    }

    fn unit_update(dims: &[usize], v: f32) -> GradSet {
        let mut g = ParamSet::zeros(dims);
        for l in &mut g.layers {
            l.w.fill(v);
        }
        g
    }

    #[test]
    fn read_my_writes_immediately_visible() {
        let mut rng = Pcg64::new(0);
        let init = ParamSet::glorot(&dims(), &mut rng);
        let mut c = WorkerCache::new(0, init.clone());
        let u = unit_update(&dims(), 0.1);
        c.add_local_update(&u);
        let got = c.view().layers[0].w.at(0, 0);
        let want = init.layers[0].w.at(0, 0) + 0.1;
        assert!((got - want).abs() < 1e-6);
    }

    #[test]
    fn commit_produces_one_msg_per_layer_and_advances_clock() {
        let init = ParamSet::zeros(&dims());
        let mut c = WorkerCache::new(3, init);
        c.add_local_update(&unit_update(&dims(), 0.5));
        assert_eq!(c.clock(), 0);
        let msgs = c.commit_clock();
        assert_eq!(c.clock(), 1);
        assert_eq!(msgs.len(), 2);
        assert!(msgs.iter().all(|m| m.from == 3 && m.clock == 0));
        assert_eq!(msgs[0].layer, 0);
        assert_eq!(msgs[1].layer, 1);
        assert!((msgs[0].delta.w.at(0, 0) - 0.5).abs() < 1e-6);
        // pending cleared: next commit sends zeros
        let msgs2 = c.commit_clock();
        assert_eq!(msgs2[0].delta.w.norm_sq(), 0.0);
    }

    #[test]
    fn pending_and_finish_commit_match_commit_clock() {
        let init = ParamSet::zeros(&dims());
        let mut a = WorkerCache::new(0, init.clone());
        let mut b = WorkerCache::new(0, init);
        let u = unit_update(&dims(), 0.25);
        a.add_local_update(&u);
        b.add_local_update(&u);
        let msgs = a.commit_clock();
        // the allocation-free path exposes the same deltas directly
        for (m, lp) in msgs.iter().zip(&b.pending().layers) {
            assert_eq!(&m.delta, lp);
        }
        b.finish_commit();
        assert_eq!(a.clock(), b.clock());
        assert_eq!(b.pending().layers[0].w.norm_sq(), 0.0);
    }

    #[test]
    fn scaled_update_is_minus_eta_grad() {
        let init = ParamSet::zeros(&dims());
        let mut c = WorkerCache::new(0, init);
        let g = unit_update(&dims(), 1.0);
        c.add_scaled_local_update(-0.05, &g);
        assert!((c.view().layers[0].w.at(0, 0) + 0.05).abs() < 1e-7);
    }

    #[test]
    fn install_snapshot_refolds_missing_own_updates() {
        let init = ParamSet::zeros(&dims());
        let mut c = WorkerCache::new(0, init.clone());
        c.add_local_update(&unit_update(&dims(), 0.2));
        c.commit_clock();
        // server snapshot that does NOT yet include our 0.2 update
        let server_snap = ParamSet::zeros(&dims());
        let missing = unit_update(&dims(), 0.2);
        c.install_snapshot(server_snap, &missing);
        assert!((c.view().layers[0].w.at(0, 0) - 0.2).abs() < 1e-7);
        // server snapshot that DOES include it
        let mut server_snap2 = ParamSet::zeros(&dims());
        server_snap2.axpy(1.0, &unit_update(&dims(), 0.2));
        c.install_snapshot(server_snap2, &init.zeros_like());
        assert!((c.view().layers[0].w.at(0, 0) - 0.2).abs() < 1e-7);
    }

    #[test]
    fn refresh_invalidates_touched_layers_only() {
        let init = ParamSet::zeros(&dims());
        let mut c = WorkerCache::new(0, init.clone());
        // nonzero fold-in hits layer 0 only: its gate entry must be
        // invalidated (forced recopy), the untouched layer's kept
        let mut u = init.zeros_like();
        u.layers[0].w.fill(0.1);
        c.add_local_update(&u);
        c.commit_clock();
        let (_, seen, _) = c.refresh_target();
        assert_eq!(seen[0], u64::MAX);
        assert_eq!(seen[1], 0);
    }

    #[test]
    fn install_snapshot_invalidates_version_gate() {
        let init = ParamSet::zeros(&dims());
        let mut c = WorkerCache::new(0, init.clone());
        {
            let (_, seen, _) = c.refresh_target();
            assert!(seen.iter().all(|&s| s == 0));
        }
        c.install_snapshot(init.clone(), &init.zeros_like());
        let (_, seen, _) = c.refresh_target();
        assert!(seen.iter().all(|&s| s == u64::MAX));
    }

    #[test]
    fn refold_marks_only_masked_layers_touched() {
        let init = ParamSet::zeros(&dims());
        let mut c = WorkerCache::new(0, init.clone());
        let missing = unit_update(&dims(), 0.3);
        c.refold_own_missing(&missing, &[true, false]);
        assert!((c.view().layers[0].w.at(0, 0) - 0.3).abs() < 1e-7);
        assert_eq!(c.view().layers[1].w.at(0, 0), 0.0, "unmasked untouched");
        let (_, seen, _) = c.refresh_target();
        assert_eq!(seen[0], u64::MAX, "refolded layer forces recopy");
        assert_eq!(seen[1], 0, "skipped layer keeps its gate entry");
    }

    #[test]
    fn reset_gate_forces_full_recopy_across_server_lifetimes() {
        // reconnect hazard: a fresh server restarts its revision
        // counters at 0, which collides with a last-seen vector from
        // the previous lifetime (0 == 0 skips the copy even though the
        // new master's bits differ). reset_gate makes the next refresh
        // copy everything, regardless of accumulated gate state.
        let init = ParamSet::zeros(&dims());
        let mut c = WorkerCache::new(0, init.clone());
        {
            let (_, seen, _) = c.refresh_target();
            assert!(seen.iter().all(|&s| s == 0), "fresh gate state");
        }
        c.reset_gate();
        let (_, seen, _) = c.refresh_target();
        assert!(
            seen.iter().all(|&s| s == u64::MAX),
            "reset gate must invalidate every layer"
        );
    }

    #[test]
    fn reset_gate_is_reusable_and_preserves_pending_clock() {
        // the reset path must be callable once per reconnect, however
        // many reconnects happen, without disturbing commit state
        let init = ParamSet::zeros(&dims());
        let mut c = WorkerCache::new(0, init.clone());
        c.add_local_update(&unit_update(&dims(), 0.5));
        c.commit_clock();
        assert_eq!(c.clock(), 1);
        for _ in 0..3 {
            c.reset_gate();
            let (_, seen, _) = c.refresh_target();
            assert!(seen.iter().all(|&s| s == u64::MAX));
            // simulate a gated fetch refreshing the gate
            for s in seen.iter_mut() {
                *s = 7;
            }
        }
        assert_eq!(c.clock(), 1, "reconnects never un-commit clocks");
        let got = c.view().layers[0].w.at(0, 0);
        assert!((got - 0.5).abs() < 1e-6, "view bits untouched by reset");
    }

    #[test]
    fn resume_at_discards_pending_and_invalidates_gate() {
        let init = ParamSet::zeros(&dims());
        let mut c = WorkerCache::new(0, init.clone());
        c.add_local_update(&unit_update(&dims(), 0.4));
        // crash mid-clock 0, re-admitted with the live min at clock 6
        c.resume_at(6);
        assert_eq!(c.clock(), 6);
        assert_eq!(c.pending().layers[0].w.norm_sq(), 0.0, "pending gone");
        let (_, seen, _) = c.refresh_target(); // no mid-clock panic
        assert!(
            seen.iter().all(|&s| s == u64::MAX),
            "view provenance unknown after rejoin"
        );
    }

    #[test]
    #[should_panic(expected = "mid-clock")]
    fn refold_mid_clock_panics() {
        let init = ParamSet::zeros(&dims());
        let mut c = WorkerCache::new(0, init.clone());
        c.add_local_update(&unit_update(&dims(), 0.2));
        c.refold_own_missing(&init.zeros_like(), &[false, false]);
    }

    #[test]
    #[should_panic(expected = "mid-clock")]
    fn snapshot_mid_clock_panics() {
        let init = ParamSet::zeros(&dims());
        let mut c = WorkerCache::new(0, init.clone());
        c.add_local_update(&unit_update(&dims(), 0.2));
        c.install_snapshot(init.clone(), &init.zeros_like());
    }

    #[test]
    #[should_panic(expected = "mid-clock")]
    fn refresh_mid_clock_panics() {
        let init = ParamSet::zeros(&dims());
        let mut c = WorkerCache::new(0, init);
        c.add_local_update(&unit_update(&dims(), 0.2));
        let _ = c.refresh_target();
    }
}
