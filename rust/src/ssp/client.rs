//! Worker-side cache: the stale snapshot θ̃_{p,c} plus read-my-writes.
//!
//! Between fetches, a worker computes against its cached snapshot with its
//! own pending updates folded in (SSP condition 4). At a clock boundary it
//! drains the accumulated per-layer deltas into `UpdateMsg`s for the
//! server and (on fetch) replaces the snapshot.

use crate::nn::{GradSet, ParamSet};

use super::UpdateMsg;

#[derive(Clone, Debug)]
pub struct WorkerCache {
    worker: usize,
    /// Server snapshot as of the last fetch (θ without own recent writes).
    snapshot: ParamSet,
    /// Own updates accumulated since the snapshot was taken, *already
    /// folded into `view`* (read-my-writes) but not yet part of any
    /// server state this cache has seen.
    own_since_snapshot: GradSet,
    /// snapshot + own_since_snapshot — what the worker computes against.
    view: ParamSet,
    /// Updates accumulated in the current (uncommitted) clock.
    pending: GradSet,
    pending_dirty: bool,
    /// Clock this worker is currently computing (timestamps of pending).
    clock: u64,
}

impl WorkerCache {
    pub fn new(worker: usize, init: ParamSet) -> WorkerCache {
        let zeros = init.zeros_like();
        WorkerCache {
            worker,
            snapshot: init.clone(),
            own_since_snapshot: zeros.clone(),
            view: init,
            pending: zeros,
            pending_dirty: false,
            clock: 0,
        }
    }

    pub fn worker(&self) -> usize {
        self.worker
    }

    pub fn clock(&self) -> u64 {
        self.clock
    }

    /// θ̃_{p,c}: the parameters this worker computes gradients against.
    pub fn view(&self) -> &ParamSet {
        &self.view
    }

    /// Accumulate a local additive update (−η·grad, Eq. 7's Δw^p term) and
    /// fold it into the view immediately (read-my-writes).
    pub fn add_local_update(&mut self, update: &GradSet) {
        self.pending.axpy(1.0, update);
        self.own_since_snapshot.axpy(1.0, update);
        self.view.axpy(1.0, update);
        self.pending_dirty = true;
    }

    /// Scaled variant: add `alpha * g` (e.g. `alpha = -eta`).
    pub fn add_scaled_local_update(&mut self, alpha: f32, g: &GradSet) {
        self.pending.axpy(alpha, g);
        self.own_since_snapshot.axpy(alpha, g);
        self.view.axpy(alpha, g);
        self.pending_dirty = true;
    }

    /// End the current clock: drain pending updates into per-layer
    /// messages timestamped with the finished clock, advance local clock.
    pub fn commit_clock(&mut self) -> Vec<UpdateMsg> {
        let mut msgs = Vec::with_capacity(self.pending.n_layers());
        for (layer, lp) in self.pending.layers.iter().enumerate() {
            msgs.push(UpdateMsg::new(self.worker, self.clock, layer, lp.clone()));
        }
        self.pending.fill_zero();
        self.pending_dirty = false;
        self.clock += 1;
        msgs
    }

    /// Install a fresh server snapshot. The server state may or may not
    /// include this worker's own recent commits; `own_applied_clocks[l]`
    /// says how many of our clocks the server had applied *for layer l*
    /// when the snapshot was taken — our own not-yet-applied updates are
    /// re-folded on top so read-my-writes is never violated.
    ///
    /// For simplicity of bookkeeping the cache tracks own updates since
    /// the last snapshot as a single accumulated delta; callers fetch at
    /// clock boundaries right after committing, so "own updates the
    /// snapshot may miss" == own_since_snapshot minus what arrived. The
    /// server tells us which of our commits it contains via
    /// `own_missing`: the portion of our accumulated delta NOT yet in the
    /// snapshot (computed server-side from arrival bookkeeping).
    pub fn install_snapshot(&mut self, snapshot: ParamSet, own_missing: &GradSet) {
        assert!(
            !self.pending_dirty,
            "fetch mid-clock would lose read-my-writes accounting"
        );
        self.view = snapshot.clone();
        self.view.axpy(1.0, own_missing);
        self.snapshot = snapshot;
        self.own_since_snapshot = own_missing.clone();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn dims() -> Vec<usize> {
        vec![3, 4, 2]
    }

    fn unit_update(dims: &[usize], v: f32) -> GradSet {
        let mut g = ParamSet::zeros(dims);
        for l in &mut g.layers {
            l.w.fill(v);
        }
        g
    }

    #[test]
    fn read_my_writes_immediately_visible() {
        let mut rng = Pcg64::new(0);
        let init = ParamSet::glorot(&dims(), &mut rng);
        let mut c = WorkerCache::new(0, init.clone());
        let u = unit_update(&dims(), 0.1);
        c.add_local_update(&u);
        let got = c.view().layers[0].w.at(0, 0);
        let want = init.layers[0].w.at(0, 0) + 0.1;
        assert!((got - want).abs() < 1e-6);
    }

    #[test]
    fn commit_produces_one_msg_per_layer_and_advances_clock() {
        let init = ParamSet::zeros(&dims());
        let mut c = WorkerCache::new(3, init);
        c.add_local_update(&unit_update(&dims(), 0.5));
        assert_eq!(c.clock(), 0);
        let msgs = c.commit_clock();
        assert_eq!(c.clock(), 1);
        assert_eq!(msgs.len(), 2);
        assert!(msgs.iter().all(|m| m.from == 3 && m.clock == 0));
        assert_eq!(msgs[0].layer, 0);
        assert_eq!(msgs[1].layer, 1);
        assert!((msgs[0].delta.w.at(0, 0) - 0.5).abs() < 1e-6);
        // pending cleared: next commit sends zeros
        let msgs2 = c.commit_clock();
        assert_eq!(msgs2[0].delta.w.norm_sq(), 0.0);
    }

    #[test]
    fn scaled_update_is_minus_eta_grad() {
        let init = ParamSet::zeros(&dims());
        let mut c = WorkerCache::new(0, init);
        let g = unit_update(&dims(), 1.0);
        c.add_scaled_local_update(-0.05, &g);
        assert!((c.view().layers[0].w.at(0, 0) + 0.05).abs() < 1e-7);
    }

    #[test]
    fn install_snapshot_refolds_missing_own_updates() {
        let init = ParamSet::zeros(&dims());
        let mut c = WorkerCache::new(0, init.clone());
        c.add_local_update(&unit_update(&dims(), 0.2));
        c.commit_clock();
        // server snapshot that does NOT yet include our 0.2 update
        let server_snap = ParamSet::zeros(&dims());
        let missing = unit_update(&dims(), 0.2);
        c.install_snapshot(server_snap, &missing);
        assert!((c.view().layers[0].w.at(0, 0) - 0.2).abs() < 1e-7);
        // server snapshot that DOES include it
        let mut server_snap2 = ParamSet::zeros(&dims());
        server_snap2.axpy(1.0, &unit_update(&dims(), 0.2));
        c.install_snapshot(server_snap2, &init.zeros_like());
        assert!((c.view().layers[0].w.at(0, 0) - 0.2).abs() < 1e-7);
    }

    #[test]
    #[should_panic(expected = "mid-clock")]
    fn snapshot_mid_clock_panics() {
        let init = ParamSet::zeros(&dims());
        let mut c = WorkerCache::new(0, init.clone());
        c.add_local_update(&unit_update(&dims(), 0.2));
        c.install_snapshot(init.clone(), &init.zeros_like());
    }
}
