//! Worker clock bookkeeping and the bounded-staleness barrier.

use super::Policy;

/// Per-worker committed-clock table. `clocks[p] = c` means worker `p` has
/// committed updates for clocks `0..c` (i.e. completed `c` clocks).
#[derive(Clone, Debug)]
pub struct ClockTable {
    clocks: Vec<u64>,
}

impl ClockTable {
    pub fn new(workers: usize) -> ClockTable {
        assert!(workers > 0);
        ClockTable {
            clocks: vec![0; workers],
        }
    }

    pub fn workers(&self) -> usize {
        self.clocks.len()
    }

    pub fn clock(&self, p: usize) -> u64 {
        self.clocks[p]
    }

    /// Worker `p` finished a clock and committed its updates.
    pub fn advance(&mut self, p: usize) -> u64 {
        self.clocks[p] += 1;
        self.clocks[p]
    }

    pub fn min(&self) -> u64 {
        *self.clocks.iter().min().unwrap()
    }

    /// Admit fast-forward: jump `p`'s committed count to `c`. Only the
    /// elastic re-admission path does this — everything else advances
    /// one commit at a time — and never backwards.
    pub fn fast_forward(&mut self, p: usize, c: u64) {
        assert!(c >= self.clocks[p], "clock fast-forward went backwards");
        self.clocks[p] = c;
    }

    pub fn max(&self) -> u64 {
        *self.clocks.iter().max().unwrap()
    }

    /// SSP condition 1: may worker `p` (having committed `clocks[p]`
    /// clocks) *start computing* its next clock under `policy`?
    ///
    /// The next clock's updates will be timestamped `clocks[p]`; reads in
    /// it must see all timestamps ≤ `clocks[p] − s − 1`, i.e. every worker
    /// must have committed at least `clocks[p] − s` clocks. Equivalently
    /// the fastest/slowest gap stays ≤ s.
    pub fn must_wait(&self, p: usize, policy: Policy) -> bool {
        match policy.staleness() {
            None => false,
            Some(s) => self.clocks[p] > self.min() + s,
        }
    }

    /// The highest timestamp whose updates are *guaranteed* visible to a
    /// read at clock `c` with staleness `s` (paper: `c − s − 1`), or None
    /// if nothing is guaranteed yet.
    pub fn guaranteed_ts(c: u64, s: u64) -> Option<u64> {
        (c).checked_sub(s + 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advance_and_bounds() {
        let mut t = ClockTable::new(3);
        assert_eq!(t.min(), 0);
        t.advance(0);
        t.advance(0);
        t.advance(1);
        assert_eq!(t.clock(0), 2);
        assert_eq!(t.min(), 0);
        assert_eq!(t.max(), 2);
    }

    #[test]
    fn ssp_barrier_blocks_fast_worker() {
        let mut t = ClockTable::new(2);
        let p = Policy::Ssp { staleness: 2 };
        // worker 0 races ahead
        for _ in 0..2 {
            assert!(!t.must_wait(0, p));
            t.advance(0);
        }
        assert!(!t.must_wait(0, p)); // gap 2 == s: still allowed
        t.advance(0);
        assert!(t.must_wait(0, p)); // gap 3 > s: blocked
        t.advance(1);
        assert!(!t.must_wait(0, p)); // slowest caught up one clock
    }

    #[test]
    fn bsp_is_full_barrier() {
        let mut t = ClockTable::new(3);
        let p = Policy::Bsp;
        t.advance(0);
        assert!(t.must_wait(0, p));
        t.advance(1);
        assert!(t.must_wait(0, p)); // worker 2 still at 0
        t.advance(2);
        assert!(!t.must_wait(0, p));
    }

    #[test]
    fn async_never_waits() {
        let mut t = ClockTable::new(2);
        for _ in 0..100 {
            t.advance(0);
        }
        assert!(!t.must_wait(0, Policy::Async));
    }

    #[test]
    fn guaranteed_ts_matches_paper() {
        // reading at clock c sees all u with timestamp <= c - s - 1
        assert_eq!(ClockTable::guaranteed_ts(10, 3), Some(6));
        assert_eq!(ClockTable::guaranteed_ts(3, 3), None);
        assert_eq!(ClockTable::guaranteed_ts(4, 3), Some(0));
        // s = 0: "guaranteed" range becomes [0, c-1] (paper §3.1)
        assert_eq!(ClockTable::guaranteed_ts(5, 0), Some(4));
    }
}
