//! The server-side parameter table: master state + per-layer version
//! vector tracking which (worker, clock) updates have been applied.
//!
//! Layers are independent rows (the paper's layerwise independent
//! updates): an update message carries exactly one layer's delta and the
//! version vector is tracked per (layer, worker).

use crate::nn::ParamSet;

use super::UpdateMsg;

/// `versions[layer][worker]` = number of clocks of that worker's updates
/// applied to the master for that layer (updates arrive FIFO per link).
#[derive(Clone, Debug, PartialEq)]
pub struct VersionVector {
    versions: Vec<Vec<u64>>,
}

impl VersionVector {
    pub fn new(layers: usize, workers: usize) -> VersionVector {
        VersionVector {
            versions: vec![vec![0; workers]; layers],
        }
    }

    pub fn applied(&self, layer: usize, worker: usize) -> u64 {
        self.versions[layer][worker]
    }

    pub fn record(&mut self, layer: usize, worker: usize, clock: u64) {
        let v = &mut self.versions[layer][worker];
        assert_eq!(
            *v, clock,
            "out-of-order update: layer {layer} worker {worker} \
             expected clock {v}, got {clock}"
        );
        *v += 1;
    }

    /// Oldest applied clock count across workers for a layer.
    pub fn layer_min(&self, layer: usize) -> u64 {
        *self.versions[layer].iter().min().unwrap()
    }

    /// Admit fast-forward: jump `worker`'s applied count on every layer
    /// to at least `clock` — the zero-delta move (versions advance, θ
    /// untouched) the elastic re-admission path uses so the rejoiner's
    /// FIFO bookkeeping restarts at its fast-forwarded clock.
    pub fn fast_forward(&mut self, worker: usize, clock: u64) {
        for layer in &mut self.versions {
            if layer[worker] < clock {
                layer[worker] = clock;
            }
        }
    }

    /// True iff every worker's updates with timestamp < `through` have
    /// been applied for every layer (the guaranteed-visibility check for
    /// a read needing timestamps ≤ through − 1).
    pub fn all_applied_through(&self, through: u64) -> bool {
        self.versions
            .iter()
            .all(|layer| layer.iter().all(|&v| v >= through))
    }
}

/// Master parameter state + version bookkeeping.
#[derive(Clone, Debug)]
pub struct ParamTable {
    master: ParamSet,
    versions: VersionVector,
    workers: usize,
    /// total updates applied (for metrics)
    applied_count: u64,
}

impl ParamTable {
    pub fn new(init: ParamSet, workers: usize) -> ParamTable {
        let layers = init.n_layers();
        ParamTable {
            master: init,
            versions: VersionVector::new(layers, workers),
            workers,
            applied_count: 0,
        }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    pub fn master(&self) -> &ParamSet {
        &self.master
    }

    pub fn versions(&self) -> &VersionVector {
        &self.versions
    }

    pub fn applied_count(&self) -> u64 {
        self.applied_count
    }

    /// Admit fast-forward of `worker`'s version entries (see
    /// `VersionVector::fast_forward`).
    pub fn fast_forward(&mut self, worker: usize, clock: u64) {
        self.versions.fast_forward(worker, clock);
    }

    /// Apply one layer-update (θ ← θ + u, associative & commutative).
    pub fn apply(&mut self, msg: &UpdateMsg) {
        self.versions.record(msg.layer, msg.from, msg.clock);
        self.master.axpy_layer(msg.layer, 1.0, &msg.delta);
        self.applied_count += 1;
    }

    /// Snapshot of the current master state (a worker fetch).
    pub fn snapshot(&self) -> ParamSet {
        self.master.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::LayerParams;
    use crate::tensor::Matrix;
    use crate::util::Pcg64;

    fn delta(dims: &[usize], layer: usize, v: f32) -> LayerParams {
        let mut w = Matrix::zeros(dims[layer], dims[layer + 1]);
        w.fill(v);
        LayerParams {
            w,
            b: vec![v; dims[layer + 1]],
        }
    }

    #[test]
    fn apply_accumulates_additively() {
        let dims = [3, 4, 2];
        let mut rng = Pcg64::new(0);
        let init = ParamSet::glorot(&dims, &mut rng);
        let mut t = ParamTable::new(init.clone(), 2);
        t.apply(&UpdateMsg::new(0, 0, 0, delta(&dims, 0, 0.5)));
        t.apply(&UpdateMsg::new(1, 0, 0, delta(&dims, 0, 0.25)));
        let snap = t.snapshot();
        let diff = snap.layers[0].w.at(0, 0) - init.layers[0].w.at(0, 0);
        assert!((diff - 0.75).abs() < 1e-6);
        // untouched layer unchanged
        assert_eq!(snap.layers[1].w, init.layers[1].w);
        assert_eq!(t.applied_count(), 2);
    }

    #[test]
    fn versions_track_per_layer_per_worker() {
        let dims = [3, 4, 2];
        let init = ParamSet::zeros(&dims);
        let mut t = ParamTable::new(init, 2);
        t.apply(&UpdateMsg::new(0, 0, 0, delta(&dims, 0, 1.0)));
        t.apply(&UpdateMsg::new(0, 0, 1, delta(&dims, 1, 1.0)));
        t.apply(&UpdateMsg::new(0, 1, 0, delta(&dims, 0, 1.0)));
        assert_eq!(t.versions().applied(0, 0), 2);
        assert_eq!(t.versions().applied(1, 0), 1);
        assert_eq!(t.versions().applied(0, 1), 0);
        assert!(!t.versions().all_applied_through(1));
        t.apply(&UpdateMsg::new(1, 0, 0, delta(&dims, 0, 1.0)));
        t.apply(&UpdateMsg::new(1, 0, 1, delta(&dims, 1, 1.0)));
        t.apply(&UpdateMsg::new(0, 1, 1, delta(&dims, 1, 1.0)));
        assert!(t.versions().all_applied_through(1));
        assert!(!t.versions().all_applied_through(2));
    }

    #[test]
    #[should_panic(expected = "out-of-order")]
    fn out_of_order_update_rejected() {
        let dims = [3, 4, 2];
        let mut t = ParamTable::new(ParamSet::zeros(&dims), 2);
        t.apply(&UpdateMsg::new(0, 1, 0, delta(&dims, 0, 1.0))); // skips clock 0
    }

    #[test]
    fn layer_min_tracks_slowest_writer() {
        let dims = [2, 2, 2];
        let mut t = ParamTable::new(ParamSet::zeros(&dims), 3);
        t.apply(&UpdateMsg::new(0, 0, 0, delta(&dims, 0, 0.0)));
        t.apply(&UpdateMsg::new(1, 0, 0, delta(&dims, 0, 0.0)));
        assert_eq!(t.versions().layer_min(0), 0); // worker 2 yet to write
        t.apply(&UpdateMsg::new(2, 0, 0, delta(&dims, 0, 0.0)));
        assert_eq!(t.versions().layer_min(0), 1);
    }
}
