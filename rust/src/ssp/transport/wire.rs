//! Framed binary wire format of the SSP transport.
//!
//! Every message is one frame:
//!
//! ```text
//! frame := len:u32 | op:u8 | payload[len - 1]
//! ```
//!
//! `len` counts the opcode byte plus the payload. All integers are
//! **little-endian**; `f32` payloads are raw LE bit patterns, so a
//! copied layer's bytes on the wire are exactly the bytes in the
//! server's shard — the remote gated fetch reproduces the in-process
//! `fetch_into` bit for bit. The full opcode table and payload layouts
//! are documented in `rust/EXPERIMENTS.md` §Transport.
//!
//! `FrameDecoder` is an incremental reassembler: feed it whatever the
//! socket returns — including one byte at a time — and it yields each
//! complete frame exactly once. Torn length prefixes, frames split
//! across reads, and multiple frames per read all decode identically
//! (pinned by the byte-by-byte tests below).

use std::io::Read;

use crate::nn::LayerParams;
use crate::tensor::Matrix;

/// Protocol version, exchanged in the HELLO handshake; mismatches are
/// rejected before any state flows. Version 2 added the `exclusive`
/// byte to HELLO_OK (multi-process server tier: an endpoint that hosts
/// *only* its group's shards, with its own clock table kept in sync by
/// client-side COMMIT broadcast). Version 3 added the HEARTBEAT
/// opcode (worker liveness leases: an expired lease releases the dead
/// worker's barrier waiters instead of hanging them forever). Version 4
/// adds elastic membership: ADMIT/LEAVE/EPOCH opcodes, a membership
/// epoch in HELLO_OK, and the current epoch prepended to FETCH_OK so
/// every gated read doubles as a membership observation. Version 5
/// adds negotiated payload codecs: HELLO carries the client's
/// requested codec (`codec:u8, codec_arg:u32`), HELLO_OK advertises
/// the server's supported set and echoes the accepted codec, and on a
/// coded connection every layer payload is a *coded layer* (format
/// byte + bf16/f16/top-k body — see `transport::codec`). `codec=off`
/// payloads remain byte-identical to wire v4.
pub const WIRE_VERSION: u32 = 5;

/// Upper bound on a single frame — a corrupt length prefix fails fast
/// instead of asking the decoder to buffer gigabytes.
pub const MAX_FRAME: usize = 1 << 30;

/// Opcodes. Requests are < 100, responses >= 100.
pub mod op {
    /// `{ version:u32, codec:u8, codec_arg:u32 }` → HELLO_OK. First
    /// frame on every connection; may be re-sent to re-negotiate the
    /// connection's payload codec (`codec` is a `transport::codec`
    /// wire tag, `codec_arg` the top-k fraction in ppm, else 0).
    pub const HELLO: u8 = 1;
    /// `{ worker:u32 }` → U64: committed clock count.
    pub const CLOCK: u8 = 2;
    /// `{ worker:u32 }` → U64: new committed clock after the advance.
    pub const COMMIT: u8 = 3;
    /// `{ worker:u32 }` → BOOL: SSP condition 1 (barrier).
    pub const MUST_WAIT: u8 = 4;
    /// `{ worker:u32 }` → BOOL: Eq. 5's read guarantee.
    pub const READ_READY: u8 = 5;
    /// `{ worker:u32 }` → OK, sent only once the worker may proceed
    /// (the server parks the connection on its barrier condvar).
    pub const WAIT: u8 = 6;
    /// `{ from:u32, clock:u64, layer:u32, layer-params }` → OK.
    /// One per-layer `UpdateMsg`; `layer` must belong to the
    /// connection's shard group.
    pub const UPDATE: u8 = 7;
    /// `{ worker:u32, last_seen:u64 × group_len }` → FETCH_OK.
    /// Version-gated delta read of the connection's shard group.
    pub const FETCH: u8 = 8;
    /// `{ last_seen:u64 × group_len }` → SNAP_OK. Gated snapshot
    /// (no read stats — the evaluation/checkpoint path).
    pub const SNAPSHOT: u8 = 9;
    /// `{ layer:u32, worker:u32 }` → U64: the version vector entry.
    pub const APPLIED: u8 = 10;
    /// `{ worker:u32, lease_ms:u64 }` → OK. Grants/renews the worker's
    /// liveness lease: once a worker has heartbeat at least once, the
    /// service treats a lapsed lease as worker death and fails any
    /// barrier WAIT that depends on it (typed ERR) instead of parking
    /// forever. Workers that never heartbeat never hold a lease and are
    /// never declared dead — the pre-lease flows are unchanged.
    pub const HEARTBEAT: u8 = 11;
    /// `{ worker:u32 }` → U64: membership epoch after the admission.
    /// Re-admits an evicted worker (elastic endpoints only): its clock
    /// and version entries fast-forward to the live min so it neither
    /// stalls the barrier nor trips FIFO bookkeeping. Also renews the
    /// worker's lease, so a rejoiner is live the instant it's admitted.
    /// Idempotent — admitting a live worker returns the current epoch.
    pub const ADMIT: u8 = 12;
    /// `{ worker:u32 }` → U64: membership epoch after the eviction.
    /// Graceful departure (elastic endpoints only): the worker's
    /// applied history stays in θ and the ε totals, but it stops
    /// bounding the barrier and gating reads. Idempotent.
    pub const LEAVE: u8 = 13;
    /// `{}` → `{ epoch:u64, live_mask:u64 }` (EPOCH_OK): the current
    /// membership epoch and live set (bit p ⇔ worker p live).
    pub const EPOCH: u8 = 14;

    /// Empty acknowledgement.
    pub const OK: u8 = 100;
    /// `{ version:u32, workers:u32, n_layers:u32, groups:u32,
    ///    group:u32, group_start:u32, group_len:u32,
    ///    policy_tag:u8, staleness:u64, init_digest:u64, exclusive:u8,
    ///    elastic:u8, epoch:u64,
    ///    codec_mask:u8, codec:u8, codec_arg:u32,
    ///    (rows:u32, cols:u32, blen:u32) × n_layers }`.
    /// `codec_mask` advertises the server's supported codecs (bit =
    /// wire tag); `codec`/`codec_arg` echo the accepted request — the
    /// client rejects a mismatch, so both ends always agree before
    /// any layer payload flows.
    /// `elastic` is 1 when the endpoint evicts lease-expired workers
    /// instead of failing waiters, and `epoch` is its membership epoch
    /// at handshake time (0 unless a prior connection already changed
    /// the membership).
    /// `init_digest` is `transport::param_digest` of the served master
    /// at bind time — the client's seed-mismatch tripwire. `exclusive`
    /// is 1 when this endpoint's process hosts *only* its group's
    /// shards (one `sspdnn serve --group i` per process): the client
    /// must then broadcast COMMITs to every endpoint, AND the
    /// group-scoped READ_READY answers, and route APPLIED to the
    /// owning group. 0 is the shared single-process tier (every
    /// endpoint wraps the same server).
    pub const HELLO_OK: u8 = 101;
    /// `{ value:u64 }`.
    pub const U64: u8 = 102;
    /// `{ value:u8 }` (0 or 1).
    pub const BOOL: u8 = 103;
    /// `{ epoch:u64,
    ///    guaranteed:u64, window_included:u64, window_missed:u64,
    ///    own:u64 × group_len,
    ///    (copied:u8, [rev:u64, layer-params]) × group_len }`.
    /// A layer's params ride the wire only when `copied == 1` — the
    /// revision gate's skip is a skip of actual bytes. On a coded
    /// connection `layer-params` is a *coded layer* (format byte +
    /// quantized body, `transport::codec`) instead of the raw v4
    /// layout. `epoch` is the
    /// endpoint's membership epoch at read time: survivors learn about
    /// evictions from the read they were already making, no extra
    /// round trip.
    pub const FETCH_OK: u8 = 104;
    /// `{ (copied:u8, [rev:u64, layer-params]) × group_len }`.
    pub const SNAP_OK: u8 = 105;
    /// `{ epoch:u64, live_mask:u64 }` — answer to EPOCH.
    pub const EPOCH_OK: u8 = 107;
    /// `{ utf-8 message }` — protocol-level failure; the connection
    /// stays usable (the request had no effect).
    pub const ERR: u8 = 106;
}

/// One decoded frame.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Frame {
    pub op: u8,
    pub payload: Vec<u8>,
}

/// Framing/decode failure. Converts into the `String` errors the rest
/// of the crate uses.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireError(pub String);

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "wire: {}", self.0)
    }
}

impl std::error::Error for WireError {}

impl From<WireError> for String {
    fn from(e: WireError) -> String {
        e.to_string()
    }
}

/// Incremental frame reassembler (see module docs).
#[derive(Debug, Default)]
pub struct FrameDecoder {
    buf: Vec<u8>,
    start: usize,
}

impl FrameDecoder {
    /// Append raw socket bytes.
    pub fn feed(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// No partial frame buffered (an EOF here is a clean close; an EOF
    /// with buffered bytes is a torn frame).
    pub fn is_empty(&self) -> bool {
        self.start == self.buf.len()
    }

    /// Pop the next complete frame, if one is buffered.
    pub fn next_frame(&mut self) -> Result<Option<Frame>, WireError> {
        let avail = self.buf.len() - self.start;
        if avail < 4 {
            return Ok(None);
        }
        let p = &self.buf[self.start..];
        let len = u32::from_le_bytes([p[0], p[1], p[2], p[3]]) as usize;
        if len == 0 {
            return Err(WireError("zero-length frame".into()));
        }
        if len > MAX_FRAME {
            return Err(WireError(format!("frame length {len} > MAX_FRAME")));
        }
        if avail < 4 + len {
            return Ok(None);
        }
        let op = p[4];
        let payload = p[5..4 + len].to_vec();
        self.start += 4 + len;
        // reclaim consumed space once it dominates the buffer
        if self.start == self.buf.len() {
            self.buf.clear();
            self.start = 0;
        } else if self.start > 64 * 1024 {
            self.buf.drain(..self.start);
            self.start = 0;
        }
        Ok(Some(Frame { op, payload }))
    }
}

/// Read from `stream` until one frame decodes. `Ok(None)` is a clean
/// close (EOF at a frame boundary); EOF mid-frame is an error.
/// `bytes_in` accumulates raw bytes received (wire accounting).
pub fn read_frame(
    stream: &mut std::net::TcpStream,
    dec: &mut FrameDecoder,
    bytes_in: &mut u64,
) -> Result<Option<Frame>, WireError> {
    loop {
        if let Some(f) = dec.next_frame()? {
            return Ok(Some(f));
        }
        let mut chunk = [0u8; 16 * 1024];
        let n = stream
            .read(&mut chunk)
            .map_err(|e| WireError(format!("read: {e}")))?;
        if n == 0 {
            return if dec.is_empty() {
                Ok(None)
            } else {
                Err(WireError("connection closed mid-frame".into()))
            };
        }
        *bytes_in += n as u64;
        dec.feed(&chunk[..n]);
    }
}

// ---------------- frame building ----------------

/// Open a frame in `out`; returns the mark `end_frame` patches.
pub fn begin_frame(out: &mut Vec<u8>, op: u8) -> usize {
    let mark = out.len();
    out.extend_from_slice(&[0, 0, 0, 0]);
    out.push(op);
    mark
}

/// Patch the length prefix of the frame opened at `mark`.
pub fn end_frame(out: &mut Vec<u8>, mark: usize) {
    let len = (out.len() - mark - 4) as u32;
    out[mark..mark + 4].copy_from_slice(&len.to_le_bytes());
}

/// One-shot frame with a fixed payload.
pub fn frame(op: u8, payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(5 + payload.len());
    let mark = begin_frame(&mut out, op);
    out.extend_from_slice(payload);
    end_frame(&mut out, mark);
    out
}

pub fn put_u8(out: &mut Vec<u8>, v: u8) {
    out.push(v);
}

pub fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    out.reserve(xs.len() * 4);
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Serialize one layer's parameters:
/// `rows:u32, cols:u32, blen:u32, w:f32 × rows·cols, b:f32 × blen`.
pub fn put_layer(out: &mut Vec<u8>, lp: &LayerParams) {
    put_u32(out, lp.w.rows() as u32);
    put_u32(out, lp.w.cols() as u32);
    put_u32(out, lp.b.len() as u32);
    put_f32s(out, lp.w.data());
    put_f32s(out, &lp.b);
}

// ---------------- payload reading ----------------

/// Cursor over one frame's payload. Every accessor checks bounds; a
/// short payload is a `WireError`, never a panic.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.remaining() < n {
            return Err(WireError(format!(
                "short payload: need {n} bytes, have {}",
                self.remaining()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    /// Borrow the next `n` raw payload bytes (bounds-checked) — the
    /// codec module's bulk decode path.
    pub fn bytes(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        self.take(n)
    }

    pub fn u32(&mut self) -> Result<u32, WireError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn u64(&mut self) -> Result<u64, WireError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    pub fn f32s_into(&mut self, dst: &mut [f32]) -> Result<(), WireError> {
        let bytes = self.take(dst.len() * 4)?;
        for (d, c) in dst.iter_mut().zip(bytes.chunks_exact(4)) {
            *d = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        }
        Ok(())
    }

    /// Trailing bytes after the last field are a protocol error.
    pub fn done(&self) -> Result<(), WireError> {
        if self.remaining() != 0 {
            return Err(WireError(format!(
                "{} trailing payload bytes",
                self.remaining()
            )));
        }
        Ok(())
    }

    /// Decode a layer into the caller's buffer; the wire shape must
    /// match the buffer's exactly.
    pub fn layer_into(&mut self, lp: &mut LayerParams) -> Result<(), WireError> {
        let rows = self.u32()? as usize;
        let cols = self.u32()? as usize;
        let blen = self.u32()? as usize;
        if rows != lp.w.rows() || cols != lp.w.cols() || blen != lp.b.len() {
            return Err(WireError(format!(
                "layer shape mismatch: wire {rows}x{cols}+{blen}, buffer {}x{}+{}",
                lp.w.rows(),
                lp.w.cols(),
                lp.b.len()
            )));
        }
        self.f32s_into(lp.w.data_mut())?;
        self.f32s_into(&mut lp.b)
    }

    /// Decode a layer, allocating, against an expected shape (the
    /// service's UPDATE path).
    pub fn layer(
        &mut self,
        rows: usize,
        cols: usize,
        blen: usize,
    ) -> Result<LayerParams, WireError> {
        let mut lp = LayerParams {
            w: Matrix::zeros(rows, cols),
            b: vec![0.0; blen],
        };
        self.layer_into(&mut lp)?;
        Ok(lp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_frames() -> Vec<Vec<u8>> {
        let mut a = Vec::new();
        let m = begin_frame(&mut a, op::CLOCK);
        put_u32(&mut a, 3);
        end_frame(&mut a, m);

        let mut b = Vec::new();
        let m = begin_frame(&mut b, op::FETCH);
        put_u32(&mut b, 1);
        put_u64(&mut b, u64::MAX);
        put_u64(&mut b, 7);
        end_frame(&mut b, m);

        let c = frame(op::OK, &[]);
        vec![a, b, c]
    }

    #[test]
    fn roundtrip_whole_frames() {
        let frames = sample_frames();
        let mut dec = FrameDecoder::default();
        for f in &frames {
            dec.feed(f);
        }
        let got: Vec<Frame> = std::iter::from_fn(|| dec.next_frame().unwrap())
            .collect();
        assert_eq!(got.len(), 3);
        assert_eq!(got[0].op, op::CLOCK);
        assert_eq!(got[0].payload, 3u32.to_le_bytes());
        assert_eq!(got[1].op, op::FETCH);
        assert_eq!(got[1].payload.len(), 4 + 8 + 8);
        assert_eq!(got[2], Frame { op: op::OK, payload: vec![] });
        assert!(dec.is_empty());
    }

    #[test]
    fn torn_reads_byte_by_byte_decode_identically() {
        // the satellite's adversarial case: the transport must survive
        // arbitrarily short reads — feed the decoder one byte at a time
        let frames = sample_frames();
        let stream: Vec<u8> = frames.concat();
        let mut dec = FrameDecoder::default();
        let mut got = Vec::new();
        for &byte in &stream {
            dec.feed(&[byte]);
            while let Some(f) = dec.next_frame().unwrap() {
                got.push(f);
            }
        }
        assert_eq!(got.len(), 3);
        let mut whole = FrameDecoder::default();
        whole.feed(&stream);
        for want in got {
            assert_eq!(whole.next_frame().unwrap(), Some(want));
        }
        assert!(whole.next_frame().unwrap().is_none());
    }

    #[test]
    fn torn_reads_random_chunking() {
        // every chunking of the byte stream yields the same frames
        let stream: Vec<u8> = sample_frames().concat();
        for chunk in [2usize, 3, 5, 7, 11] {
            let mut dec = FrameDecoder::default();
            let mut n = 0;
            for piece in stream.chunks(chunk) {
                dec.feed(piece);
                while dec.next_frame().unwrap().is_some() {
                    n += 1;
                }
            }
            assert_eq!(n, 3, "chunk size {chunk}");
            assert!(dec.is_empty());
        }
    }

    #[test]
    fn zero_and_oversized_lengths_rejected() {
        let mut dec = FrameDecoder::default();
        dec.feed(&0u32.to_le_bytes());
        assert!(dec.next_frame().is_err());

        let mut dec = FrameDecoder::default();
        dec.feed(&(MAX_FRAME as u32 + 1).to_le_bytes());
        assert!(dec.next_frame().is_err());
    }

    #[test]
    fn incomplete_frame_waits_for_more_bytes() {
        let f = frame(op::BOOL, &[1]);
        let mut dec = FrameDecoder::default();
        dec.feed(&f[..f.len() - 1]);
        assert_eq!(dec.next_frame().unwrap(), None);
        assert!(!dec.is_empty());
        dec.feed(&f[f.len() - 1..]);
        assert_eq!(
            dec.next_frame().unwrap(),
            Some(Frame { op: op::BOOL, payload: vec![1] })
        );
    }

    #[test]
    fn layer_roundtrip_bitwise() {
        let lp = LayerParams {
            w: Matrix::from_fn(3, 2, |r, c| (r * 2 + c) as f32 * 0.25 - 1.0),
            b: vec![0.5, -0.5],
        };
        let mut out = Vec::new();
        put_layer(&mut out, &lp);
        assert_eq!(out.len(), 12 + (6 + 2) * 4);
        let mut r = Reader::new(&out);
        let got = r.layer(3, 2, 2).unwrap();
        r.done().unwrap();
        assert_eq!(got, lp);

        // shape mismatch is an error, not a panic
        let mut r = Reader::new(&out);
        assert!(r.layer(2, 3, 2).is_err());
    }

    #[test]
    fn reader_bounds_checked() {
        let mut r = Reader::new(&[1, 2]);
        assert!(r.u32().is_err());
        let mut r = Reader::new(&[1, 2, 3, 4, 5]);
        assert_eq!(r.u32().unwrap(), u32::from_le_bytes([1, 2, 3, 4]));
        assert!(r.done().is_err());
        assert_eq!(r.u8().unwrap(), 5);
        r.done().unwrap();
    }
}
