//! `RemoteClient` — the worker side of the message boundary.
//!
//! A full [`ParamServer`] implementation over framed TCP: every trait
//! call becomes one request per relevant endpoint, so the
//! discrete-event driver (`run_experiment_with`), the sweep harness and
//! the P1–P5 property suite run against a remote server byte-for-byte
//! the way they run against the in-process `ShardedServer`. It also
//! implements [`WorkerPort`], so `coordinator::run_threaded_on` can put
//! one connection set under each OS worker thread — the multi-process
//! deployment shape.
//!
//! Two orthogonal deployment axes, both negotiated at the handshake or
//! chosen at construction:
//!
//! * **Shared vs. exclusive endpoints.** Shared (HELLO_OK `exclusive
//!   = 0`): every endpoint wraps one `ShardedServer` process, so
//!   control RPCs go to group 0 and a single COMMIT advances the one
//!   clock table. Exclusive (`= 1`, one `sspdnn serve --group i` per
//!   process): each process owns a private clock table and only its
//!   group's shards, so the client *broadcasts* every COMMIT (keeping
//!   the tables identical), ANDs the group-scoped READ_READY answers,
//!   fans WAIT out to every endpoint (readiness is monotone between a
//!   worker's own commits, so waiting the groups out sequentially is
//!   sound), and routes APPLIED to the owning group. ε statistics sum
//!   across groups exactly because each group computes them from the
//!   same clock table over its own disjoint layers.
//!
//! * **Synchronous vs. pipelined commits** ([`RemoteClient::
//!   with_pipeline`]). Synchronous: every UPDATE/COMMIT blocks on its
//!   acknowledgement — simple, but loopback RTTs bound commits/sec.
//!   Pipelined: each connection gets a dedicated writer thread and a
//!   bounded in-flight window; `apply_commit`/`commit_clock` enqueue
//!   their frames and return immediately, so the worker overlaps the
//!   next minibatch's compute with the previous clock's acks. The
//!   pending-acknowledgement queue is drained before *any* response is
//!   read on that connection (per-connection FIFO — the server
//!   processes a connection's frames in order — is what keeps the
//!   observable protocol bitwise identical to the synchronous path),
//!   and `commit_clock` itself never forces a drain: the blocking
//!   moves into `wait_until_ready`/`fetch_view`, i.e. exactly where
//!   the SSP staleness gate requires the worker to stop anyway. A
//!   server ERR consumes its pending entry like any acknowledgement
//!   (the window never desyncs) and surfaces as a typed
//!   [`TransportError`].
//!
//! Reads are **version-gated on the wire**: `fetch_into` ships the
//! caller's per-layer last-seen revision vector and receives only the
//! layers whose revision advanced (the endpoint's gate skip is a skip
//! of actual payload bytes — `wire_stats` exposes the saving). The
//! allocating `fetch`/`snapshot` paths keep a client-side **mirror** of
//! the master plus a per-connection cached revision vector, so even the
//! "full" reads only move changed layers over the network.
//!
//! Accounting (`reads`, `copy_totals`) is client-side: with one client
//! per worker process there is no meaningful server-global count, and
//! keeping it at the subscriber makes the numbers comparable with the
//! in-process servers call-for-call.

use std::collections::VecDeque;
use std::net::{SocketAddr, TcpStream};
use std::sync::{mpsc, Mutex};

use crate::nn::{GradSet, LayerParams, ParamSet};
use crate::ssp::{FetchStats, ParamServer, Policy, ReadStats, UpdateMsg, WorkerPort};
use crate::tensor::Matrix;

use super::service::{policy_decode, ShardService};
use super::wire::{self, op, Frame, FrameDecoder, WireError};

/// Raw transport accounting, from the client's side of the sockets.
/// In pipelined mode a frame counts as sent when it is handed to the
/// connection's writer thread (the moment it irrevocably enters the
/// send FIFO).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WireStats {
    pub frames_sent: u64,
    pub frames_received: u64,
    pub bytes_sent: u64,
    pub bytes_received: u64,
}

/// What went wrong, typed: protocol-level rejections the server
/// answered with an ERR frame (the connection and the in-flight window
/// stay usable), socket-level failures, and malformed/unexpected
/// replies.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportErrorKind {
    /// The server answered ERR (e.g. the FIFO pre-check rejected an
    /// out-of-order update). The offending request had no effect and
    /// the connection stays up.
    Server,
    /// Socket-level failure (connect, read, write, torn frame at EOF).
    Io,
    /// The bytes arrived but made no sense: undecodable frame,
    /// unexpected reply opcode, short payload, or a pipelined COMMIT
    /// acknowledgement disagreeing with the client's clock bookkeeping.
    Protocol,
}

/// A typed transport failure. Converts into the `String` errors the
/// connect paths use, and `Display`s with the same prefixes the
/// pre-typed error strings carried (so panic-message pins hold).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TransportError {
    pub kind: TransportErrorKind,
    pub msg: String,
}

impl TransportError {
    fn server(msg: impl Into<String>) -> TransportError {
        TransportError { kind: TransportErrorKind::Server, msg: msg.into() }
    }

    fn io(msg: impl Into<String>) -> TransportError {
        TransportError { kind: TransportErrorKind::Io, msg: msg.into() }
    }

    fn protocol(msg: impl Into<String>) -> TransportError {
        TransportError { kind: TransportErrorKind::Protocol, msg: msg.into() }
    }
}

impl std::fmt::Display for TransportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let kind = match self.kind {
            TransportErrorKind::Server => "server error",
            TransportErrorKind::Io => "transport io",
            TransportErrorKind::Protocol => "transport protocol",
        };
        write!(f, "{kind}: {}", self.msg)
    }
}

impl std::error::Error for TransportError {}

impl From<TransportError> for String {
    fn from(e: TransportError) -> String {
        e.to_string()
    }
}

impl From<WireError> for TransportError {
    fn from(e: WireError) -> TransportError {
        TransportError::protocol(e.to_string())
    }
}

/// Immutable facts learned at the HELLO handshake.
#[derive(Clone, Debug)]
struct Meta {
    workers: usize,
    n_layers: usize,
    policy: Policy,
    /// `(rows, cols, blen)` per layer — buffer allocation + shape checks.
    shapes: Vec<(usize, usize, usize)>,
    /// Layer range per shard group (contiguous, ascending).
    ranges: Vec<std::ops::Range<usize>>,
    /// Owning group of each layer.
    layer_group: Vec<usize>,
    /// FNV-1a digest of the served init (`transport::param_digest`),
    /// from the handshake — `check_run`'s seed-mismatch tripwire.
    init_digest: u64,
    /// Every endpoint is its own server process hosting only its
    /// group's shards (see module docs): COMMIT broadcasts, READ_READY
    /// / WAIT fan out, APPLIED routes to the owner.
    exclusive: bool,
    /// Version-gate delta reads (config `transport.gated`). Off: every
    /// gated read sends an always-miss sentinel, shipping every layer.
    gated: bool,
}

/// One expected-but-unread acknowledgement on a pipelined connection,
/// in FIFO order with the server's replies.
#[derive(Clone, Copy, Debug)]
enum Pending {
    /// An UPDATE's OK.
    ExpectOk,
    /// A COMMIT's U64 reply; must equal the client's locally tracked
    /// committed count (it advances only through this client).
    ExpectU64(u64),
}

/// The dedicated writer thread of one pipelined connection: everything
/// the client sends on that connection goes through its channel, so
/// the socket sees exactly the enqueue order (FIFO with the pending
/// queue). Dropping the writer closes the channel and joins.
struct Writer {
    tx: Option<mpsc::Sender<Vec<u8>>>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Writer {
    fn spawn(mut stream: TcpStream) -> Writer {
        let (tx, rx) = mpsc::channel::<Vec<u8>>();
        let handle = std::thread::spawn(move || {
            while let Ok(buf) = rx.recv() {
                if std::io::Write::write_all(&mut stream, &buf).is_err() {
                    // the reader side will see the failure as a recv
                    // error; just stop accepting frames
                    break;
                }
            }
        });
        Writer { tx: Some(tx), handle: Some(handle) }
    }

    fn send(&self, buf: Vec<u8>) -> Result<(), TransportError> {
        self.tx
            .as_ref()
            .expect("writer channel")
            .send(buf)
            .map_err(|_| {
                TransportError::io("writer thread gone (socket write failed)")
            })
    }
}

impl Drop for Writer {
    fn drop(&mut self) {
        drop(self.tx.take()); // disconnect: the thread drains and exits
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

struct Conn {
    stream: TcpStream,
    dec: FrameDecoder,
    /// `Some` in pipelined mode; owns a `try_clone` of `stream`.
    writer: Option<Writer>,
    /// Outstanding acknowledgements, FIFO with the server's replies.
    pending: VecDeque<Pending>,
}

/// The socket half: one connection per shard group + wire accounting.
struct ClientIo {
    conns: Vec<Conn>,
    wire: WireStats,
    /// Pipelined mode: max outstanding acknowledgements per connection
    /// before an enqueue first drains. `None` = synchronous.
    window: Option<usize>,
    /// Locally tracked committed clock per worker (`None` = unknown;
    /// the first pipelined commit for that worker runs one synchronous
    /// round to learn the server's count — the reconnect case).
    commits: Vec<Option<u64>>,
}

struct Inner {
    io: ClientIo,
    /// Client-side master mirror backing the allocating `fetch` /
    /// `snapshot` paths; refreshed through the same wire gate.
    mirror: ParamSet,
    /// The mirror's per-layer cached revision vector (`u64::MAX` =
    /// unknown — the first refresh copies everything).
    mirror_seen: Vec<u64>,
    reads: u64,
    copy_totals: FetchStats,
}

pub struct RemoteClient {
    meta: Meta,
    inner: Mutex<Inner>,
    /// Loopback services owned by this client (tests/bench): declared
    /// after `inner` so the sockets close before the services join
    /// their threads on drop.
    services: Vec<ShardService>,
}

impl ClientIo {
    fn send(&mut self, g: usize, frame_bytes: &[u8]) -> Result<(), TransportError> {
        let conn = &mut self.conns[g];
        match &conn.writer {
            Some(w) => w.send(frame_bytes.to_vec()).map_err(|mut e| {
                e.msg = format!("send (group {g}): {}", e.msg);
                e
            })?,
            None => std::io::Write::write_all(&mut conn.stream, frame_bytes)
                .map_err(|e| {
                    TransportError::io(format!("send (group {g}): {e}"))
                })?,
        }
        self.wire.frames_sent += 1;
        self.wire.bytes_sent += frame_bytes.len() as u64;
        Ok(())
    }

    fn recv(&mut self, g: usize) -> Result<Frame, TransportError> {
        let conn = &mut self.conns[g];
        let frame = wire::read_frame(
            &mut conn.stream,
            &mut conn.dec,
            &mut self.wire.bytes_received,
        )
        .map_err(|e| TransportError::io(format!("recv (group {g}): {e}")))?
        .ok_or_else(|| {
            TransportError::io(format!("server closed connection (group {g})"))
        })?;
        self.wire.frames_received += 1;
        if frame.op == op::ERR {
            return Err(TransportError::server(
                String::from_utf8_lossy(&frame.payload).into_owned(),
            ));
        }
        Ok(frame)
    }

    /// Consume one outstanding acknowledgement from `g`'s pending
    /// queue. The entry is popped *before* the reply is read, so a
    /// server ERR (which answers exactly that request) leaves the
    /// window aligned — the error is surfaced, not a desync.
    fn drain_one(&mut self, g: usize) -> Result<(), TransportError> {
        let expect = self.conns[g]
            .pending
            .pop_front()
            .expect("drain_one on an empty pending queue");
        let f = self.recv(g)?;
        match expect {
            Pending::ExpectOk => expect_op(&f, op::OK),
            Pending::ExpectU64(want) => {
                expect_op(&f, op::U64)?;
                let mut r = wire::Reader::new(&f.payload);
                let got = r.u64()?;
                r.done()?;
                if got != want {
                    return Err(TransportError::protocol(format!(
                        "pipelined COMMIT ack {got} != locally tracked \
                         {want} (group {g}) — another client committed \
                         for this worker?"
                    )));
                }
                Ok(())
            }
        }
    }

    /// Drain every outstanding acknowledgement on `g` — required
    /// before reading any synchronous reply on that connection (the
    /// server answers strictly in request order).
    fn drain(&mut self, g: usize) -> Result<(), TransportError> {
        while !self.conns[g].pending.is_empty() {
            self.drain_one(g)?;
        }
        Ok(())
    }

    /// Drain everything on every connection, reporting the first error
    /// but consuming every outstanding acknowledgement regardless (a
    /// server ERR consumes its entry; an io/protocol failure abandons
    /// that connection's queue — nothing more will arrive on it).
    fn flush_all(&mut self) -> Result<(), TransportError> {
        let mut first: Option<TransportError> = None;
        for g in 0..self.conns.len() {
            while !self.conns[g].pending.is_empty() {
                match self.drain_one(g) {
                    Ok(()) => {}
                    Err(e) => {
                        let fatal = e.kind != TransportErrorKind::Server;
                        if first.is_none() {
                            first = Some(e);
                        }
                        if fatal {
                            self.conns[g].pending.clear();
                            break;
                        }
                    }
                }
            }
        }
        match first {
            None => Ok(()),
            Some(e) => Err(e),
        }
    }

    /// Drain every connection's in-flight window (no-op when
    /// synchronous or empty). Called before reads whose answer spans
    /// connections — e.g. shared-mode READ_READY is evaluated by one
    /// endpoint but depends on updates pipelined to *other*
    /// connections; acknowledgements are sent after application, so a
    /// full drain makes every previously-issued operation visible and
    /// keeps the answer deterministic (bitwise equal to the oracle's).
    fn settle(&mut self) -> Result<(), TransportError> {
        if self.window.is_some() {
            for g in 0..self.conns.len() {
                self.drain(g)?;
            }
        }
        Ok(())
    }

    /// Make room for one more in-flight acknowledgement on `g`
    /// (pipelined mode): the bounded window that keeps the number of
    /// unread replies — and with it the receive-buffer footprint —
    /// finite without ever blocking on a whole round trip per frame.
    fn make_room(&mut self, g: usize) -> Result<(), TransportError> {
        let window = self.window.expect("make_room in synchronous mode");
        while self.conns[g].pending.len() >= window {
            self.drain_one(g)?;
        }
        Ok(())
    }

    /// Enqueue a frame expecting an acknowledgement later (pipelined
    /// fire-and-account path).
    fn enqueue(
        &mut self,
        g: usize,
        frame_bytes: &[u8],
        expect: Pending,
    ) -> Result<(), TransportError> {
        self.make_room(g)?;
        self.send(g, frame_bytes)?;
        self.conns[g].pending.push_back(expect);
        Ok(())
    }

    /// Synchronous request/response on one connection (draining any
    /// pipelined backlog first — the server replies in request order).
    fn rpc(&mut self, g: usize, frame_bytes: &[u8]) -> Result<Frame, TransportError> {
        self.send(g, frame_bytes)?;
        self.drain(g)?;
        self.recv(g)
    }

    /// Control RPC carrying one u32 argument, returning a u64.
    fn rpc_u64_on(
        &mut self,
        g: usize,
        opcode: u8,
        arg: u32,
    ) -> Result<u64, TransportError> {
        let f = self.rpc(g, &wire::frame(opcode, &arg.to_le_bytes()))?;
        expect_op(&f, op::U64)?;
        let mut r = wire::Reader::new(&f.payload);
        let v = r.u64()?;
        r.done()?;
        Ok(v)
    }

    /// Control RPC carrying one u32 argument, returning a bool.
    fn rpc_bool_on(
        &mut self,
        g: usize,
        opcode: u8,
        arg: u32,
    ) -> Result<bool, TransportError> {
        let f = self.rpc(g, &wire::frame(opcode, &arg.to_le_bytes()))?;
        expect_op(&f, op::BOOL)?;
        let mut r = wire::Reader::new(&f.payload);
        let v = r.u8()?;
        r.done()?;
        Ok(v != 0)
    }

    /// The COMMIT targets: every endpoint in exclusive mode (each
    /// process's private clock table must advance), group 0 alone in
    /// shared mode (they all wrap the same table).
    fn commit_targets(&self, meta: &Meta) -> std::ops::Range<usize> {
        if meta.exclusive {
            0..self.conns.len()
        } else {
            0..1
        }
    }

    /// Advance `worker`'s clock. Synchronous mode (or the first
    /// pipelined commit for this worker — the count is still unknown,
    /// e.g. right after a reconnect): a blocking COMMIT round,
    /// asserting every exclusive endpoint agrees. Pipelined steady
    /// state: the COMMIT frames enter the send FIFOs with an expected
    /// acknowledgement queued, and the locally tracked count is
    /// returned immediately — no round trip on the worker's hot path.
    fn commit(&mut self, meta: &Meta, worker: usize) -> Result<u64, TransportError> {
        let targets = self.commit_targets(meta);
        let bytes = wire::frame(op::COMMIT, &(worker as u32).to_le_bytes());
        if self.window.is_some() {
            if let Some(known) = self.commits[worker] {
                let expected = known + 1;
                for g in targets {
                    self.enqueue(g, &bytes, Pending::ExpectU64(expected))?;
                }
                self.commits[worker] = Some(expected);
                return Ok(expected);
            }
        }
        let mut agreed: Option<u64> = None;
        for g in targets {
            let f = self.rpc(g, &bytes)?;
            expect_op(&f, op::U64)?;
            let mut r = wire::Reader::new(&f.payload);
            let v = r.u64()?;
            r.done()?;
            match agreed {
                None => agreed = Some(v),
                Some(prev) if prev != v => {
                    return Err(TransportError::protocol(format!(
                        "exclusive endpoints disagree on worker {worker}'s \
                         clock: {prev} vs {v} (group {g})"
                    )));
                }
                Some(_) => {}
            }
        }
        let v = agreed.expect("at least one commit target");
        self.commits[worker] = Some(v);
        Ok(v)
    }

    /// Ship one per-layer additive update to its owning endpoint —
    /// synchronously, or into the pipeline's in-flight window.
    fn update(
        &mut self,
        meta: &Meta,
        from: usize,
        clock: u64,
        layer: usize,
        delta: &LayerParams,
    ) -> Result<(), TransportError> {
        let g = meta.layer_group[layer];
        let mut tx = Vec::with_capacity(21 + delta.n_bytes() + 12);
        let mark = wire::begin_frame(&mut tx, op::UPDATE);
        wire::put_u32(&mut tx, from as u32);
        wire::put_u64(&mut tx, clock);
        wire::put_u32(&mut tx, layer as u32);
        wire::put_layer(&mut tx, delta);
        wire::end_frame(&mut tx, mark);
        if self.window.is_some() {
            return self.enqueue(g, &tx, Pending::ExpectOk);
        }
        let f = self.rpc(g, &tx)?;
        expect_op(&f, op::OK)
    }

    /// Whole-clock commit of per-layer updates. Synchronous mode:
    /// every layer's UPDATE frame is written to its owning endpoint
    /// before any acknowledgement is read (per-connection ordering
    /// preserves the per-layer FIFO), so an L-layer commit costs ~1
    /// round trip per *group*. Pipelined mode: the frames enter the
    /// send FIFOs and the call returns — the acks drain at the next
    /// blocking read on each connection (or when the window fills),
    /// overlapping the worker's next minibatch with the network.
    fn commit_updates(
        &mut self,
        meta: &Meta,
        worker: usize,
        clock: u64,
        delta: &crate::nn::GradSet,
    ) -> Result<(), TransportError> {
        for (layer, lp) in delta.layers.iter().enumerate() {
            let g = meta.layer_group[layer];
            let mut tx = Vec::with_capacity(21 + lp.n_bytes() + 12);
            let mark = wire::begin_frame(&mut tx, op::UPDATE);
            wire::put_u32(&mut tx, worker as u32);
            wire::put_u64(&mut tx, clock);
            wire::put_u32(&mut tx, layer as u32);
            wire::put_layer(&mut tx, lp);
            wire::end_frame(&mut tx, mark);
            if self.window.is_some() {
                self.enqueue(g, &tx, Pending::ExpectOk)?;
            } else {
                self.send(g, &tx)?;
            }
        }
        if self.window.is_some() {
            return Ok(());
        }
        for (g, range) in meta.ranges.iter().enumerate() {
            for _ in range.clone() {
                let f = self.recv(g)?;
                expect_op(&f, op::OK)?;
            }
        }
        Ok(())
    }

    /// Block until `worker` may proceed. Shared mode: one WAIT parked
    /// on group 0 (its server sees every shard). Exclusive mode: WAIT
    /// fans out to every endpoint — each can only vouch for its own
    /// shards' read guarantee — and the replies are collected in
    /// order; since readiness is monotone between a worker's own
    /// commits (peers only advance), all conditions hold simultaneously
    /// once the last OK arrives.
    fn wait(&mut self, meta: &Meta, worker: usize) -> Result<(), TransportError> {
        self.settle()?;
        let targets = if meta.exclusive { self.conns.len() } else { 1 };
        let bytes = wire::frame(op::WAIT, &(worker as u32).to_le_bytes());
        for g in 0..targets {
            self.send(g, &bytes)?;
        }
        for g in 0..targets {
            self.drain(g)?;
            let f = self.recv(g)?;
            expect_op(&f, op::OK)?;
        }
        Ok(())
    }

    /// Eq. 5's read guarantee. Exclusive mode ANDs the group-scoped
    /// answers (the predicate is a conjunction over (layer, worker)
    /// pairs, and the groups partition the layers).
    fn read_ready(&mut self, meta: &Meta, worker: usize) -> Result<bool, TransportError> {
        self.settle()?;
        if !meta.exclusive {
            return self.rpc_bool_on(0, op::READ_READY, worker as u32);
        }
        let bytes = wire::frame(op::READ_READY, &(worker as u32).to_le_bytes());
        for g in 0..self.conns.len() {
            self.send(g, &bytes)?;
        }
        let mut all = true;
        for g in 0..self.conns.len() {
            self.drain(g)?;
            let f = self.recv(g)?;
            expect_op(&f, op::BOOL)?;
            let mut r = wire::Reader::new(&f.payload);
            all &= r.u8()? != 0;
            r.done()?;
        }
        Ok(all)
    }

    /// The (layer, worker) version-vector entry, from the endpoint
    /// that owns the layer — the only process whose vector moves for it
    /// in exclusive mode (and an equally valid answer in shared mode).
    fn applied(
        &mut self,
        meta: &Meta,
        layer: usize,
        worker: usize,
    ) -> Result<u64, TransportError> {
        let g = meta.layer_group[layer];
        let mut payload = Vec::with_capacity(8);
        wire::put_u32(&mut payload, layer as u32);
        wire::put_u32(&mut payload, worker as u32);
        let f = self.rpc(g, &wire::frame(op::APPLIED, &payload))?;
        expect_op(&f, op::U64)?;
        let mut r = wire::Reader::new(&f.payload);
        let v = r.u64()?;
        r.done()?;
        Ok(v)
    }

    /// Version-gated read fan-out: one pipelined FETCH per endpoint
    /// (all requests sent before any response is read — one round-trip
    /// of latency regardless of group count), responses decoded in
    /// group order so `own` comes back in layer order.
    fn gated_fetch(
        &mut self,
        meta: &Meta,
        worker: usize,
        buf: &mut ParamSet,
        last_seen: &mut [u64],
        own: &mut Vec<u64>,
        use_gate: bool,
    ) -> Result<(ReadStats, FetchStats), TransportError> {
        // shared-mode ε statistics read the clock table, which pending
        // pipelined COMMITs on other connections may still be moving
        self.settle()?;
        for (g, range) in meta.ranges.iter().enumerate() {
            let mut tx = Vec::with_capacity(9 + 4 + 8 * range.len());
            let mark = wire::begin_frame(&mut tx, op::FETCH);
            wire::put_u32(&mut tx, worker as u32);
            for l in range.clone() {
                wire::put_u64(&mut tx, if use_gate { last_seen[l] } else { u64::MAX });
            }
            wire::end_frame(&mut tx, mark);
            self.send(g, &tx)?;
        }
        let mut stats = ReadStats::default();
        let mut fs = FetchStats::default();
        own.clear();
        for (g, range) in meta.ranges.iter().enumerate() {
            self.drain(g)?;
            let f = self.recv(g)?;
            expect_op(&f, op::FETCH_OK)?;
            let mut r = wire::Reader::new(&f.payload);
            stats.guaranteed += r.u64()?;
            stats.window_included += r.u64()?;
            stats.window_missed += r.u64()?;
            for _ in range.clone() {
                own.push(r.u64()?);
            }
            for l in range.clone() {
                if r.u8()? == 1 {
                    let rev = r.u64()?;
                    r.layer_into(&mut buf.layers[l])?;
                    last_seen[l] = rev;
                    fs.layers_copied += 1;
                    fs.bytes_copied += buf.layers[l].n_bytes() as u64;
                } else {
                    fs.layers_skipped += 1;
                }
            }
            r.done()?;
        }
        Ok((stats, fs))
    }

    /// Gated snapshot fan-out (no worker identity, no ε statistics).
    fn gated_snapshot(
        &mut self,
        meta: &Meta,
        buf: &mut ParamSet,
        last_seen: &mut [u64],
        use_gate: bool,
    ) -> Result<FetchStats, TransportError> {
        for (g, range) in meta.ranges.iter().enumerate() {
            let mut tx = Vec::with_capacity(9 + 8 * range.len());
            let mark = wire::begin_frame(&mut tx, op::SNAPSHOT);
            for l in range.clone() {
                wire::put_u64(&mut tx, if use_gate { last_seen[l] } else { u64::MAX });
            }
            wire::end_frame(&mut tx, mark);
            self.send(g, &tx)?;
        }
        let mut fs = FetchStats::default();
        for (g, range) in meta.ranges.iter().enumerate() {
            self.drain(g)?;
            let f = self.recv(g)?;
            expect_op(&f, op::SNAP_OK)?;
            let mut r = wire::Reader::new(&f.payload);
            for l in range.clone() {
                if r.u8()? == 1 {
                    let rev = r.u64()?;
                    r.layer_into(&mut buf.layers[l])?;
                    last_seen[l] = rev;
                    fs.layers_copied += 1;
                    fs.bytes_copied += buf.layers[l].n_bytes() as u64;
                } else {
                    fs.layers_skipped += 1;
                }
            }
            r.done()?;
        }
        Ok(fs)
    }
}

fn expect_op(f: &Frame, want: u8) -> Result<(), TransportError> {
    if f.op != want {
        return Err(TransportError::protocol(format!(
            "unexpected reply opcode {} (want {want})",
            f.op
        )));
    }
    Ok(())
}

/// Everything a HELLO_OK tells one connection.
struct Hello {
    workers: usize,
    n_layers: usize,
    groups: usize,
    group: usize,
    range: std::ops::Range<usize>,
    policy: Policy,
    init_digest: u64,
    exclusive: bool,
    shapes: Vec<(usize, usize, usize)>,
}

fn handshake(addr: &SocketAddr) -> Result<(Conn, Hello), String> {
    let stream = TcpStream::connect(addr)
        .map_err(|e| format!("connect {addr}: {e}"))?;
    stream
        .set_nodelay(true)
        .map_err(|e| format!("nodelay: {e}"))?;
    let mut conn = Conn {
        stream,
        dec: FrameDecoder::default(),
        writer: None,
        pending: VecDeque::new(),
    };
    let hello = wire::frame(op::HELLO, &wire::WIRE_VERSION.to_le_bytes());
    std::io::Write::write_all(&mut conn.stream, &hello)
        .map_err(|e| format!("hello: {e}"))?;
    let mut bytes_in = 0u64;
    let f = wire::read_frame(&mut conn.stream, &mut conn.dec, &mut bytes_in)
        .map_err(String::from)?
        .ok_or("server closed during handshake")?;
    if f.op == op::ERR {
        return Err(format!(
            "handshake rejected: {}",
            String::from_utf8_lossy(&f.payload)
        ));
    }
    expect_op(&f, op::HELLO_OK)?;
    let mut r = wire::Reader::new(&f.payload);
    let version = r.u32().map_err(String::from)?;
    if version != wire::WIRE_VERSION {
        return Err(format!(
            "wire version {version} != {}",
            wire::WIRE_VERSION
        ));
    }
    let workers = r.u32().map_err(String::from)? as usize;
    let n_layers = r.u32().map_err(String::from)? as usize;
    let groups = r.u32().map_err(String::from)? as usize;
    let group = r.u32().map_err(String::from)? as usize;
    let start = r.u32().map_err(String::from)? as usize;
    let len = r.u32().map_err(String::from)? as usize;
    let tag = r.u8().map_err(String::from)?;
    let staleness = r.u64().map_err(String::from)?;
    let policy = policy_decode(tag, staleness)?;
    let init_digest = r.u64().map_err(String::from)?;
    let exclusive = r.u8().map_err(String::from)? != 0;
    let mut shapes = Vec::with_capacity(n_layers);
    for _ in 0..n_layers {
        let rows = r.u32().map_err(String::from)? as usize;
        let cols = r.u32().map_err(String::from)? as usize;
        let blen = r.u32().map_err(String::from)? as usize;
        shapes.push((rows, cols, blen));
    }
    r.done().map_err(String::from)?;
    if group >= groups || start + len > n_layers {
        return Err("inconsistent handshake geometry".into());
    }
    Ok((
        conn,
        Hello {
            workers,
            n_layers,
            groups,
            group,
            range: start..start + len,
            policy,
            init_digest,
            exclusive,
            shapes,
        },
    ))
}

impl RemoteClient {
    /// Lock the connection state, recovering from poisoning: transport
    /// failures panic *between* request/response cycles (never with a
    /// half-written frame buffered), so `Inner` is consistent even if a
    /// previous call panicked — e.g. after an ERR reply the connection
    /// and the caller's client remain usable.
    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner
            .lock()
            .unwrap_or_else(|poisoned| poisoned.into_inner())
    }

    /// Connect to explicit group endpoints (any order; each connection
    /// reports which group it serves). Tests pass
    /// [`ShardService::addrs`] straight through.
    pub fn connect(addrs: &[SocketAddr]) -> Result<RemoteClient, String> {
        if addrs.is_empty() {
            return Err("no endpoint addresses".into());
        }
        let mut pairs = Vec::with_capacity(addrs.len());
        for addr in addrs {
            pairs.push(handshake(addr)?);
        }
        Self::assemble(pairs)
    }

    /// [`RemoteClient::connect`] from `host:port` strings — the config
    /// path for an explicit `transport.group_addrs` endpoint list (one
    /// per shard group, any order; bracketed IPv6 accepted).
    pub fn connect_hosts(addrs: &[String]) -> Result<RemoteClient, String> {
        let mut resolved = Vec::with_capacity(addrs.len());
        for a in addrs {
            let (host, port) = super::service::split_addr(a)?;
            resolved.push(resolve(host, port)?);
        }
        Self::connect(&resolved)
    }

    /// Connect to a base address and discover the sibling group
    /// endpoints by the CLI port convention (group `g` on `port + g`).
    pub fn connect_base(addr: &str) -> Result<RemoteClient, String> {
        let (host, port) = super::service::split_addr(addr)?;
        let first: SocketAddr = resolve(host, port)?;
        let (conn, hello) = handshake(&first)?;
        let groups = hello.groups;
        if hello.group != 0 {
            return Err(format!(
                "{addr} serves group {} — point --server at group 0",
                hello.group
            ));
        }
        let mut pairs = vec![(conn, hello)];
        for g in 1..groups {
            let p = port
                .checked_add(g as u16)
                .ok_or_else(|| format!("group {g} port overflows u16"))?;
            pairs.push(handshake(&resolve(host, p)?)?);
        }
        Self::assemble(pairs)
    }

    fn assemble(pairs: Vec<(Conn, Hello)>) -> Result<RemoteClient, String> {
        let first = &pairs[0].1;
        let (workers, n_layers, groups, policy) =
            (first.workers, first.n_layers, first.groups, first.policy);
        let init_digest = first.init_digest;
        let exclusive = first.exclusive;
        let shapes = first.shapes.clone();
        if pairs.len() != groups {
            return Err(format!(
                "server has {groups} shard groups, connected to {}",
                pairs.len()
            ));
        }
        let mut ranges: Vec<Option<std::ops::Range<usize>>> =
            vec![None; groups];
        let mut conns: Vec<Option<Conn>> =
            pairs.iter().map(|_| None).collect();
        for (conn, h) in pairs {
            if h.workers != workers
                || h.n_layers != n_layers
                || h.groups != groups
                || h.policy != policy
                || h.init_digest != init_digest
                || h.shapes != shapes
            {
                return Err("endpoints disagree about the server".into());
            }
            if h.exclusive != exclusive {
                return Err(
                    "endpoints mix exclusive (multi-process) and shared \
                     serving modes"
                        .into(),
                );
            }
            if ranges[h.group].is_some() {
                return Err(format!("group {} connected twice", h.group));
            }
            ranges[h.group] = Some(h.range);
            conns[h.group] = Some(conn);
        }
        let ranges: Vec<std::ops::Range<usize>> =
            ranges.into_iter().map(Option::unwrap).collect();
        let conns: Vec<Conn> = conns.into_iter().map(Option::unwrap).collect();
        // groups must tile 0..n_layers contiguously in order
        let mut next = 0;
        for r in &ranges {
            if r.start != next {
                return Err("shard groups do not tile the layers".into());
            }
            next = r.end;
        }
        if next != n_layers {
            return Err("shard groups do not cover every layer".into());
        }
        let mut layer_group = vec![0usize; n_layers];
        for (g, r) in ranges.iter().enumerate() {
            for l in r.clone() {
                layer_group[l] = g;
            }
        }
        let mirror = ParamSet {
            layers: shapes
                .iter()
                .map(|&(rows, cols, blen)| LayerParams {
                    w: Matrix::zeros(rows, cols),
                    b: vec![0.0; blen],
                })
                .collect(),
        };
        Ok(RemoteClient {
            meta: Meta {
                workers,
                n_layers,
                policy,
                shapes,
                ranges,
                layer_group,
                init_digest,
                exclusive,
                gated: true,
            },
            inner: Mutex::new(Inner {
                io: ClientIo {
                    conns,
                    wire: WireStats::default(),
                    window: None,
                    commits: vec![None; workers],
                },
                mirror,
                mirror_seen: vec![u64::MAX; n_layers],
                reads: 0,
                copy_totals: FetchStats::default(),
            }),
            services: Vec::new(),
        })
    }

    /// Disable/enable on-wire version gating (config `transport.gated`;
    /// off ships every layer on every read — the bench's baseline).
    pub fn with_gate(mut self, gated: bool) -> RemoteClient {
        self.meta.gated = gated;
        self
    }

    /// Switch commits to the pipelined path: every connection gets a
    /// dedicated writer thread, and UPDATE/COMMIT frames are enqueued
    /// with at most `window` unread acknowledgements in flight per
    /// connection (the bound keeps the unread-reply backlog finite;
    /// acknowledgements are a few bytes, so even a generous window
    /// cannot back-pressure the server's response writes). `window >=
    /// 1`. See the module docs for why the observable protocol stays
    /// bitwise identical to the synchronous path.
    pub fn with_pipeline(mut self, window: usize) -> Result<RemoteClient, String> {
        if window == 0 {
            return Err("pipeline window must be >= 1".into());
        }
        let inner = self
            .inner
            .get_mut()
            .unwrap_or_else(|poisoned| poisoned.into_inner());
        for (g, conn) in inner.io.conns.iter_mut().enumerate() {
            let stream = conn
                .stream
                .try_clone()
                .map_err(|e| format!("clone stream (group {g}): {e}"))?;
            conn.writer = Some(Writer::spawn(stream));
        }
        inner.io.window = Some(window);
        Ok(self)
    }

    /// Commits ride the pipelined (writer-thread, in-flight-window)
    /// path rather than blocking per acknowledgement.
    pub fn pipelined(&self) -> bool {
        self.lock().io.window.is_some()
    }

    /// Adopt a loopback service so it lives (and shuts down) with this
    /// client — the tests' single-process harness. May be called once
    /// per served process (the multi-process split harness owns one
    /// service per shard group).
    pub(super) fn attach_service(&mut self, svc: ShardService) {
        self.services.push(svc);
    }

    /// The attached loopback services, if any.
    pub fn services(&self) -> &[ShardService] {
        &self.services
    }

    pub fn groups(&self) -> usize {
        self.meta.ranges.len()
    }

    /// Every endpoint is its own server process (see module docs).
    pub fn exclusive(&self) -> bool {
        self.meta.exclusive
    }

    /// Client-side transport accounting (frames/bytes both directions).
    pub fn wire_stats(&self) -> WireStats {
        self.lock().io.wire
    }

    /// Drain every in-flight acknowledgement (pipelined mode; a no-op
    /// when nothing is pending). Returns the first failure while still
    /// consuming every outstanding reply, so the window stays aligned
    /// and the connections stay usable after a server-side rejection.
    pub fn flush(&self) -> Result<(), TransportError> {
        self.lock().io.flush_all()
    }

    /// [`ParamServer::apply_arrival`] with a typed error instead of a
    /// panic. Synchronous mode reports a rejection immediately; in
    /// pipelined mode the frame is enqueued and a rejection surfaces at
    /// the next drain ([`RemoteClient::flush`] or any blocking read on
    /// that connection).
    pub fn try_apply_arrival(
        &self,
        msg: &UpdateMsg,
    ) -> Result<(), TransportError> {
        self.lock()
            .io
            .update(&self.meta, msg.from, msg.clock, msg.layer, &msg.delta)
    }

    /// [`WorkerPort::apply_commit`] with a typed error instead of a
    /// panic (same deferred-surfacing rule as
    /// [`RemoteClient::try_apply_arrival`]).
    pub fn try_apply_commit(
        &self,
        worker: usize,
        clock: u64,
        delta: &GradSet,
    ) -> Result<(), TransportError> {
        assert_eq!(delta.layers.len(), self.meta.n_layers, "commit layers");
        self.lock()
            .io
            .commit_updates(&self.meta, worker, clock, delta)
    }

    /// Assert the remote server matches what a local run assumes —
    /// called by the `--server` driver path before training starts.
    /// Shapes, worker count and policy are all in the handshake; the
    /// init *bits* are equal by construction (both sides derive them
    /// from the config seed — `coordinator::init_params`).
    pub fn check_run(&self, init: &ParamSet, workers: usize, policy: Policy) {
        assert_eq!(
            self.meta.workers, workers,
            "remote server worker count differs from the run's"
        );
        assert_eq!(
            self.meta.policy, policy,
            "remote server policy differs from the run's"
        );
        assert_eq!(
            self.meta.n_layers,
            init.n_layers(),
            "remote server layer count differs from the run's"
        );
        for (l, lp) in init.layers.iter().enumerate() {
            assert_eq!(
                self.meta.shapes[l],
                (lp.w.rows(), lp.w.cols(), lp.b.len()),
                "remote layer {l} shape differs from the run's"
            );
        }
        assert_eq!(
            self.meta.init_digest,
            super::param_digest(init),
            "remote init digest differs from the run's: the two \
             processes derive different initial parameters (config \
             seed mismatch?) — the version gate's premise would \
             silently break"
        );
    }

    /// Block until `worker` may start its next clock — the remote
    /// sibling of `ShardedServer::wait_until_ready` (the server parks
    /// this connection on its barrier condvar; other workers' clients
    /// are unaffected because each has its own connections). In
    /// exclusive mode the wait fans out to every endpoint; any
    /// pipelined commit backlog drains first, which is exactly the
    /// "drain only when the staleness gate requires it" rule.
    pub fn wait_until_ready(&self, worker: usize) {
        self.lock()
            .io
            .wait(&self.meta, worker)
            .unwrap_or_else(|e| panic!("ssp transport: {e}"));
    }

    /// Version-gated evaluation snapshot — the remote sibling of
    /// `ShardedServer::snapshot_into_gated` (feeds `copy_totals`).
    pub fn snapshot_into_gated(
        &self,
        buf: &mut ParamSet,
        last_seen: &mut [u64],
    ) -> FetchStats {
        assert_eq!(buf.layers.len(), self.meta.n_layers, "snapshot buffer");
        assert_eq!(last_seen.len(), self.meta.n_layers, "snapshot last_seen");
        let mut inner = self.lock();
        let inner = &mut *inner;
        let fs = inner
            .io
            .gated_snapshot(&self.meta, buf, last_seen, self.meta.gated)
            .unwrap_or_else(|e| panic!("ssp transport: {e}"));
        inner.copy_totals.absorb(&fs);
        fs
    }
}

impl Drop for RemoteClient {
    /// Flush the in-flight window before the sockets close: the last
    /// clock's pipelined UPDATEs must be applied (acknowledged) before
    /// any *other* connection — e.g. the threaded runner's final
    /// master-snapshot port — can observe the server, and dropping the
    /// worker's port is exactly the runner's ordering point for that.
    fn drop(&mut self) {
        let inner = self
            .inner
            .get_mut()
            .unwrap_or_else(|poisoned| poisoned.into_inner());
        let _ = inner.io.flush_all();
    }
}

impl ParamServer for RemoteClient {
    fn policy(&self) -> Policy {
        self.meta.policy
    }

    fn workers(&self) -> usize {
        self.meta.workers
    }

    fn n_layers(&self) -> usize {
        self.meta.n_layers
    }

    fn clock(&self, worker: usize) -> u64 {
        self.lock()
            .io
            .rpc_u64_on(0, op::CLOCK, worker as u32)
            .unwrap_or_else(|e| panic!("ssp transport: {e}"))
    }

    fn commit(&mut self, worker: usize) -> u64 {
        self.lock()
            .io
            .commit(&self.meta, worker)
            .unwrap_or_else(|e| panic!("ssp transport: {e}"))
    }

    fn apply_arrival(&mut self, msg: &UpdateMsg) {
        self.try_apply_arrival(msg)
            .unwrap_or_else(|e| panic!("ssp transport: {e}"));
    }

    fn must_wait(&self, worker: usize) -> bool {
        self.lock()
            .io
            .rpc_bool_on(0, op::MUST_WAIT, worker as u32)
            .unwrap_or_else(|e| panic!("ssp transport: {e}"))
    }

    fn read_ready(&self, worker: usize) -> bool {
        self.lock()
            .io
            .read_ready(&self.meta, worker)
            .unwrap_or_else(|e| panic!("ssp transport: {e}"))
    }

    fn fetch(&mut self, worker: usize) -> (ParamSet, Vec<u64>, ReadStats) {
        let mut inner = self.lock();
        let inner = &mut *inner;
        inner.reads += 1;
        let mut own = Vec::with_capacity(self.meta.n_layers);
        let (stats, _fs) = inner
            .io
            .gated_fetch(
                &self.meta,
                worker,
                &mut inner.mirror,
                &mut inner.mirror_seen,
                &mut own,
                self.meta.gated,
            )
            .unwrap_or_else(|e| panic!("ssp transport: {e}"));
        (inner.mirror.clone(), own, stats)
    }

    fn fetch_into(
        &mut self,
        worker: usize,
        buf: &mut ParamSet,
        last_seen: &mut [u64],
        own: &mut Vec<u64>,
    ) -> (ReadStats, FetchStats) {
        assert_eq!(buf.layers.len(), self.meta.n_layers, "fetch_into buffer");
        assert_eq!(last_seen.len(), self.meta.n_layers, "fetch_into last_seen");
        let mut inner = self.lock();
        let inner = &mut *inner;
        inner.reads += 1;
        let (stats, fs) = inner
            .io
            .gated_fetch(&self.meta, worker, buf, last_seen, own, self.meta.gated)
            .unwrap_or_else(|e| panic!("ssp transport: {e}"));
        inner.copy_totals.absorb(&fs);
        (stats, fs)
    }

    fn snapshot(&self) -> ParamSet {
        let mut inner = self.lock();
        let inner = &mut *inner;
        inner
            .io
            .gated_snapshot(
                &self.meta,
                &mut inner.mirror,
                &mut inner.mirror_seen,
                self.meta.gated,
            )
            .unwrap_or_else(|e| panic!("ssp transport: {e}"));
        inner.mirror.clone()
    }

    fn snapshot_into(&self, buf: &mut ParamSet) {
        assert_eq!(buf.layers.len(), self.meta.n_layers, "snapshot buffer");
        let mut inner = self.lock();
        let inner = &mut *inner;
        inner
            .io
            .gated_snapshot(
                &self.meta,
                &mut inner.mirror,
                &mut inner.mirror_seen,
                self.meta.gated,
            )
            .unwrap_or_else(|e| panic!("ssp transport: {e}"));
        buf.copy_from(&inner.mirror);
    }

    fn copy_totals(&self) -> FetchStats {
        self.lock().copy_totals
    }

    fn applied(&self, layer: usize, worker: usize) -> u64 {
        assert!(layer < self.meta.n_layers, "layer out of range");
        self.lock()
            .io
            .applied(&self.meta, layer, worker)
            .unwrap_or_else(|e| panic!("ssp transport: {e}"))
    }

    fn reads(&self) -> u64 {
        self.lock().reads
    }
}

/// The per-worker connection set as a threaded-runner port: the same
/// hot-path sequence `run_threaded` drives in shared memory, each step
/// one (batched or pipelined) message exchange.
impl WorkerPort for RemoteClient {
    fn wait_until_ready(&mut self, worker: usize) {
        RemoteClient::wait_until_ready(self, worker)
    }

    fn fetch_view(
        &mut self,
        worker: usize,
        buf: &mut ParamSet,
        last_seen: &mut [u64],
        own: &mut Vec<u64>,
    ) -> (ReadStats, FetchStats) {
        ParamServer::fetch_into(self, worker, buf, last_seen, own)
    }

    fn commit_clock(&mut self, worker: usize) -> u64 {
        ParamServer::commit(self, worker)
    }

    fn apply_commit(&mut self, worker: usize, clock: u64, delta: &GradSet) {
        self.try_apply_commit(worker, clock, delta)
            .unwrap_or_else(|e| panic!("ssp transport: {e}"));
    }

    fn snapshot_gated(
        &mut self,
        buf: &mut ParamSet,
        last_seen: &mut [u64],
    ) -> FetchStats {
        RemoteClient::snapshot_into_gated(self, buf, last_seen)
    }

    fn master_snapshot(&mut self) -> ParamSet {
        ParamServer::snapshot(self)
    }
}

fn resolve(host: &str, port: u16) -> Result<SocketAddr, String> {
    use std::net::ToSocketAddrs;
    (host, port)
        .to_socket_addrs()
        .map_err(|e| format!("resolve {host}:{port}: {e}"))?
        .next()
        .ok_or_else(|| format!("{host}:{port} resolves to nothing"))
}
