//! `RemoteClient` — the worker side of the message boundary.
//!
//! A full [`ParamServer`] implementation over framed TCP: every trait
//! call becomes one synchronous request per relevant endpoint, so the
//! discrete-event driver (`run_experiment_with`), the sweep harness and
//! the P1–P5 property suite run against a remote server byte-for-byte
//! the way they run against the in-process `ShardedServer`. It also
//! implements [`WorkerPort`], so `coordinator::run_threaded_on` can put
//! one connection set under each OS worker thread — the multi-process
//! deployment shape.
//!
//! Reads are **version-gated on the wire**: `fetch_into` ships the
//! caller's per-layer last-seen revision vector and receives only the
//! layers whose revision advanced (the endpoint's gate skip is a skip
//! of actual payload bytes — `wire_stats` exposes the saving). The
//! allocating `fetch`/`snapshot` paths keep a client-side **mirror** of
//! the master plus a per-connection cached revision vector, so even the
//! "full" reads only move changed layers over the network.
//!
//! Accounting (`reads`, `copy_totals`) is client-side: with one client
//! per worker process there is no meaningful server-global count, and
//! keeping it at the subscriber makes the numbers comparable with the
//! in-process servers call-for-call.

use std::net::{SocketAddr, TcpStream};
use std::sync::Mutex;

use crate::nn::{GradSet, LayerParams, ParamSet};
use crate::ssp::{FetchStats, ParamServer, Policy, ReadStats, UpdateMsg, WorkerPort};
use crate::tensor::Matrix;

use super::service::{policy_decode, ShardService};
use super::wire::{self, op, Frame, FrameDecoder};

/// Raw transport accounting, from the client's side of the sockets.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WireStats {
    pub frames_sent: u64,
    pub frames_received: u64,
    pub bytes_sent: u64,
    pub bytes_received: u64,
}

/// Immutable facts learned at the HELLO handshake.
#[derive(Clone, Debug)]
struct Meta {
    workers: usize,
    n_layers: usize,
    policy: Policy,
    /// `(rows, cols, blen)` per layer — buffer allocation + shape checks.
    shapes: Vec<(usize, usize, usize)>,
    /// Layer range per shard group (contiguous, ascending).
    ranges: Vec<std::ops::Range<usize>>,
    /// Owning group of each layer.
    layer_group: Vec<usize>,
    /// FNV-1a digest of the served init (`transport::param_digest`),
    /// from the handshake — `check_run`'s seed-mismatch tripwire.
    init_digest: u64,
    /// Version-gate delta reads (config `transport.gated`). Off: every
    /// gated read sends an always-miss sentinel, shipping every layer.
    gated: bool,
}

struct Conn {
    stream: TcpStream,
    dec: FrameDecoder,
}

/// The socket half: one connection per shard group + wire accounting.
struct ClientIo {
    conns: Vec<Conn>,
    wire: WireStats,
}

struct Inner {
    io: ClientIo,
    /// Client-side master mirror backing the allocating `fetch` /
    /// `snapshot` paths; refreshed through the same wire gate.
    mirror: ParamSet,
    /// The mirror's per-layer cached revision vector (`u64::MAX` =
    /// unknown — the first refresh copies everything).
    mirror_seen: Vec<u64>,
    reads: u64,
    copy_totals: FetchStats,
}

pub struct RemoteClient {
    meta: Meta,
    inner: Mutex<Inner>,
    /// A loopback service owned by this client (tests/bench): declared
    /// after `inner` so the sockets close before the service joins its
    /// threads on drop.
    service: Option<ShardService>,
}

impl ClientIo {
    fn send(&mut self, g: usize, frame_bytes: &[u8]) -> Result<(), String> {
        std::io::Write::write_all(&mut self.conns[g].stream, frame_bytes)
            .map_err(|e| format!("send (group {g}): {e}"))?;
        self.wire.frames_sent += 1;
        self.wire.bytes_sent += frame_bytes.len() as u64;
        Ok(())
    }

    fn recv(&mut self, g: usize) -> Result<Frame, String> {
        let conn = &mut self.conns[g];
        let frame = wire::read_frame(
            &mut conn.stream,
            &mut conn.dec,
            &mut self.wire.bytes_received,
        )
        .map_err(|e| format!("recv (group {g}): {e}"))?
        .ok_or_else(|| format!("server closed connection (group {g})"))?;
        self.wire.frames_received += 1;
        if frame.op == op::ERR {
            return Err(format!(
                "server error: {}",
                String::from_utf8_lossy(&frame.payload)
            ));
        }
        Ok(frame)
    }

    fn rpc(&mut self, g: usize, frame_bytes: &[u8]) -> Result<Frame, String> {
        self.send(g, frame_bytes)?;
        self.recv(g)
    }

    /// Control RPC carrying one u32 argument, returning a u64.
    fn rpc_u64(&mut self, opcode: u8, arg: u32) -> Result<u64, String> {
        let f = self.rpc(0, &wire::frame(opcode, &arg.to_le_bytes()))?;
        expect_op(&f, op::U64)?;
        let mut r = wire::Reader::new(&f.payload);
        let v = r.u64()?;
        r.done()?;
        Ok(v)
    }

    /// Control RPC carrying one u32 argument, returning a bool.
    fn rpc_bool(&mut self, opcode: u8, arg: u32) -> Result<bool, String> {
        let f = self.rpc(0, &wire::frame(opcode, &arg.to_le_bytes()))?;
        expect_op(&f, op::BOOL)?;
        let mut r = wire::Reader::new(&f.payload);
        let v = r.u8()?;
        r.done()?;
        Ok(v != 0)
    }

    /// Ship one per-layer additive update to its owning endpoint.
    fn update(
        &mut self,
        meta: &Meta,
        from: usize,
        clock: u64,
        layer: usize,
        delta: &LayerParams,
    ) -> Result<(), String> {
        let g = meta.layer_group[layer];
        let mut tx = Vec::with_capacity(21 + delta.n_bytes() + 12);
        let mark = wire::begin_frame(&mut tx, op::UPDATE);
        wire::put_u32(&mut tx, from as u32);
        wire::put_u64(&mut tx, clock);
        wire::put_u32(&mut tx, layer as u32);
        wire::put_layer(&mut tx, delta);
        wire::end_frame(&mut tx, mark);
        let f = self.rpc(g, &tx)?;
        expect_op(&f, op::OK)
    }

    /// Pipelined whole-clock commit: every layer's UPDATE frame is
    /// written to its owning endpoint before any acknowledgement is
    /// read (per-connection ordering preserves the per-layer FIFO), so
    /// an L-layer commit costs ~1 round trip per *group*, not L
    /// sequential round trips.
    fn commit_updates(
        &mut self,
        meta: &Meta,
        worker: usize,
        clock: u64,
        delta: &crate::nn::GradSet,
    ) -> Result<(), String> {
        for (layer, lp) in delta.layers.iter().enumerate() {
            let g = meta.layer_group[layer];
            let mut tx = Vec::with_capacity(21 + lp.n_bytes() + 12);
            let mark = wire::begin_frame(&mut tx, op::UPDATE);
            wire::put_u32(&mut tx, worker as u32);
            wire::put_u64(&mut tx, clock);
            wire::put_u32(&mut tx, layer as u32);
            wire::put_layer(&mut tx, lp);
            wire::end_frame(&mut tx, mark);
            self.send(g, &tx)?;
        }
        for (g, range) in meta.ranges.iter().enumerate() {
            for _ in range.clone() {
                let f = self.recv(g)?;
                expect_op(&f, op::OK)?;
            }
        }
        Ok(())
    }

    /// Version-gated read fan-out: one pipelined FETCH per endpoint
    /// (all requests sent before any response is read — one round-trip
    /// of latency regardless of group count), responses decoded in
    /// group order so `own` comes back in layer order.
    fn gated_fetch(
        &mut self,
        meta: &Meta,
        worker: usize,
        buf: &mut ParamSet,
        last_seen: &mut [u64],
        own: &mut Vec<u64>,
        use_gate: bool,
    ) -> Result<(ReadStats, FetchStats), String> {
        for (g, range) in meta.ranges.iter().enumerate() {
            let mut tx = Vec::with_capacity(9 + 4 + 8 * range.len());
            let mark = wire::begin_frame(&mut tx, op::FETCH);
            wire::put_u32(&mut tx, worker as u32);
            for l in range.clone() {
                wire::put_u64(&mut tx, if use_gate { last_seen[l] } else { u64::MAX });
            }
            wire::end_frame(&mut tx, mark);
            self.send(g, &tx)?;
        }
        let mut stats = ReadStats::default();
        let mut fs = FetchStats::default();
        own.clear();
        for (g, range) in meta.ranges.iter().enumerate() {
            let f = self.recv(g)?;
            expect_op(&f, op::FETCH_OK)?;
            let mut r = wire::Reader::new(&f.payload);
            stats.guaranteed += r.u64()?;
            stats.window_included += r.u64()?;
            stats.window_missed += r.u64()?;
            for _ in range.clone() {
                own.push(r.u64()?);
            }
            for l in range.clone() {
                if r.u8()? == 1 {
                    let rev = r.u64()?;
                    r.layer_into(&mut buf.layers[l])?;
                    last_seen[l] = rev;
                    fs.layers_copied += 1;
                    fs.bytes_copied += buf.layers[l].n_bytes() as u64;
                } else {
                    fs.layers_skipped += 1;
                }
            }
            r.done()?;
        }
        Ok((stats, fs))
    }

    /// Gated snapshot fan-out (no worker identity, no ε statistics).
    fn gated_snapshot(
        &mut self,
        meta: &Meta,
        buf: &mut ParamSet,
        last_seen: &mut [u64],
        use_gate: bool,
    ) -> Result<FetchStats, String> {
        for (g, range) in meta.ranges.iter().enumerate() {
            let mut tx = Vec::with_capacity(9 + 8 * range.len());
            let mark = wire::begin_frame(&mut tx, op::SNAPSHOT);
            for l in range.clone() {
                wire::put_u64(&mut tx, if use_gate { last_seen[l] } else { u64::MAX });
            }
            wire::end_frame(&mut tx, mark);
            self.send(g, &tx)?;
        }
        let mut fs = FetchStats::default();
        for (g, range) in meta.ranges.iter().enumerate() {
            let f = self.recv(g)?;
            expect_op(&f, op::SNAP_OK)?;
            let mut r = wire::Reader::new(&f.payload);
            for l in range.clone() {
                if r.u8()? == 1 {
                    let rev = r.u64()?;
                    r.layer_into(&mut buf.layers[l])?;
                    last_seen[l] = rev;
                    fs.layers_copied += 1;
                    fs.bytes_copied += buf.layers[l].n_bytes() as u64;
                } else {
                    fs.layers_skipped += 1;
                }
            }
            r.done()?;
        }
        Ok(fs)
    }
}

fn expect_op(f: &Frame, want: u8) -> Result<(), String> {
    if f.op != want {
        return Err(format!("unexpected reply opcode {} (want {want})", f.op));
    }
    Ok(())
}

/// Everything a HELLO_OK tells one connection.
struct Hello {
    workers: usize,
    n_layers: usize,
    groups: usize,
    group: usize,
    range: std::ops::Range<usize>,
    policy: Policy,
    init_digest: u64,
    shapes: Vec<(usize, usize, usize)>,
}

fn handshake(addr: &SocketAddr) -> Result<(Conn, Hello), String> {
    let stream = TcpStream::connect(addr)
        .map_err(|e| format!("connect {addr}: {e}"))?;
    stream
        .set_nodelay(true)
        .map_err(|e| format!("nodelay: {e}"))?;
    let mut conn = Conn {
        stream,
        dec: FrameDecoder::default(),
    };
    let hello = wire::frame(op::HELLO, &wire::WIRE_VERSION.to_le_bytes());
    std::io::Write::write_all(&mut conn.stream, &hello)
        .map_err(|e| format!("hello: {e}"))?;
    let mut bytes_in = 0u64;
    let f = wire::read_frame(&mut conn.stream, &mut conn.dec, &mut bytes_in)
        .map_err(String::from)?
        .ok_or("server closed during handshake")?;
    if f.op == op::ERR {
        return Err(format!(
            "handshake rejected: {}",
            String::from_utf8_lossy(&f.payload)
        ));
    }
    expect_op(&f, op::HELLO_OK)?;
    let mut r = wire::Reader::new(&f.payload);
    let version = r.u32()?;
    if version != wire::WIRE_VERSION {
        return Err(format!(
            "wire version {version} != {}",
            wire::WIRE_VERSION
        ));
    }
    let workers = r.u32()? as usize;
    let n_layers = r.u32()? as usize;
    let groups = r.u32()? as usize;
    let group = r.u32()? as usize;
    let start = r.u32()? as usize;
    let len = r.u32()? as usize;
    let tag = r.u8()?;
    let staleness = r.u64()?;
    let policy = policy_decode(tag, staleness)?;
    let init_digest = r.u64()?;
    let mut shapes = Vec::with_capacity(n_layers);
    for _ in 0..n_layers {
        let rows = r.u32()? as usize;
        let cols = r.u32()? as usize;
        let blen = r.u32()? as usize;
        shapes.push((rows, cols, blen));
    }
    r.done()?;
    if group >= groups || start + len > n_layers {
        return Err("inconsistent handshake geometry".into());
    }
    Ok((
        conn,
        Hello {
            workers,
            n_layers,
            groups,
            group,
            range: start..start + len,
            policy,
            init_digest,
            shapes,
        },
    ))
}

impl RemoteClient {
    /// Lock the connection state, recovering from poisoning: transport
    /// failures panic *between* request/response cycles (never with a
    /// half-written frame buffered), so `Inner` is consistent even if a
    /// previous call panicked — e.g. after an ERR reply the connection
    /// and the caller's client remain usable.
    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner
            .lock()
            .unwrap_or_else(|poisoned| poisoned.into_inner())
    }

    /// Connect to explicit group endpoints (any order; each connection
    /// reports which group it serves). Tests pass
    /// [`ShardService::addrs`] straight through.
    pub fn connect(addrs: &[SocketAddr]) -> Result<RemoteClient, String> {
        if addrs.is_empty() {
            return Err("no endpoint addresses".into());
        }
        let mut pairs = Vec::with_capacity(addrs.len());
        for addr in addrs {
            pairs.push(handshake(addr)?);
        }
        Self::assemble(pairs)
    }

    /// Connect to a base address and discover the sibling group
    /// endpoints by the CLI port convention (group `g` on `port + g`).
    pub fn connect_base(addr: &str) -> Result<RemoteClient, String> {
        let (host, port) = super::service::split_addr(addr)?;
        let first: SocketAddr = resolve(host, port)?;
        let (conn, hello) = handshake(&first)?;
        let groups = hello.groups;
        if hello.group != 0 {
            return Err(format!(
                "{addr} serves group {} — point --server at group 0",
                hello.group
            ));
        }
        let mut pairs = vec![(conn, hello)];
        for g in 1..groups {
            let p = port
                .checked_add(g as u16)
                .ok_or_else(|| format!("group {g} port overflows u16"))?;
            pairs.push(handshake(&resolve(host, p)?)?);
        }
        Self::assemble(pairs)
    }

    fn assemble(pairs: Vec<(Conn, Hello)>) -> Result<RemoteClient, String> {
        let first = &pairs[0].1;
        let (workers, n_layers, groups, policy) =
            (first.workers, first.n_layers, first.groups, first.policy);
        let init_digest = first.init_digest;
        let shapes = first.shapes.clone();
        if pairs.len() != groups {
            return Err(format!(
                "server has {groups} shard groups, connected to {}",
                pairs.len()
            ));
        }
        let mut ranges: Vec<Option<std::ops::Range<usize>>> =
            vec![None; groups];
        let mut conns: Vec<Option<Conn>> =
            pairs.iter().map(|_| None).collect();
        for (conn, h) in pairs {
            if h.workers != workers
                || h.n_layers != n_layers
                || h.groups != groups
                || h.policy != policy
                || h.init_digest != init_digest
                || h.shapes != shapes
            {
                return Err("endpoints disagree about the server".into());
            }
            if ranges[h.group].is_some() {
                return Err(format!("group {} connected twice", h.group));
            }
            ranges[h.group] = Some(h.range);
            conns[h.group] = Some(conn);
        }
        let ranges: Vec<std::ops::Range<usize>> =
            ranges.into_iter().map(Option::unwrap).collect();
        let conns: Vec<Conn> = conns.into_iter().map(Option::unwrap).collect();
        // groups must tile 0..n_layers contiguously in order
        let mut next = 0;
        for r in &ranges {
            if r.start != next {
                return Err("shard groups do not tile the layers".into());
            }
            next = r.end;
        }
        if next != n_layers {
            return Err("shard groups do not cover every layer".into());
        }
        let mut layer_group = vec![0usize; n_layers];
        for (g, r) in ranges.iter().enumerate() {
            for l in r.clone() {
                layer_group[l] = g;
            }
        }
        let mirror = ParamSet {
            layers: shapes
                .iter()
                .map(|&(rows, cols, blen)| LayerParams {
                    w: Matrix::zeros(rows, cols),
                    b: vec![0.0; blen],
                })
                .collect(),
        };
        Ok(RemoteClient {
            meta: Meta {
                workers,
                n_layers,
                policy,
                shapes,
                ranges,
                layer_group,
                init_digest,
                gated: true,
            },
            inner: Mutex::new(Inner {
                io: ClientIo {
                    conns,
                    wire: WireStats::default(),
                },
                mirror,
                mirror_seen: vec![u64::MAX; n_layers],
                reads: 0,
                copy_totals: FetchStats::default(),
            }),
            service: None,
        })
    }

    /// Disable/enable on-wire version gating (config `transport.gated`;
    /// off ships every layer on every read — the bench's baseline).
    pub fn with_gate(mut self, gated: bool) -> RemoteClient {
        self.meta.gated = gated;
        self
    }

    /// Adopt a loopback service so it lives (and shuts down) with this
    /// client — the tests' single-process harness.
    pub(super) fn attach_service(&mut self, svc: ShardService) {
        self.service = Some(svc);
    }

    /// The attached loopback service, if any.
    pub fn service(&self) -> Option<&ShardService> {
        self.service.as_ref()
    }

    pub fn groups(&self) -> usize {
        self.meta.ranges.len()
    }

    /// Client-side transport accounting (frames/bytes both directions).
    pub fn wire_stats(&self) -> WireStats {
        self.lock().io.wire
    }

    /// Assert the remote server matches what a local run assumes —
    /// called by the `--server` driver path before training starts.
    /// Shapes, worker count and policy are all in the handshake; the
    /// init *bits* are equal by construction (both sides derive them
    /// from the config seed — `coordinator::init_params`).
    pub fn check_run(&self, init: &ParamSet, workers: usize, policy: Policy) {
        assert_eq!(
            self.meta.workers, workers,
            "remote server worker count differs from the run's"
        );
        assert_eq!(
            self.meta.policy, policy,
            "remote server policy differs from the run's"
        );
        assert_eq!(
            self.meta.n_layers,
            init.n_layers(),
            "remote server layer count differs from the run's"
        );
        for (l, lp) in init.layers.iter().enumerate() {
            assert_eq!(
                self.meta.shapes[l],
                (lp.w.rows(), lp.w.cols(), lp.b.len()),
                "remote layer {l} shape differs from the run's"
            );
        }
        assert_eq!(
            self.meta.init_digest,
            super::param_digest(init),
            "remote init digest differs from the run's: the two \
             processes derive different initial parameters (config \
             seed mismatch?) — the version gate's premise would \
             silently break"
        );
    }

    /// Block until `worker` may start its next clock — the remote
    /// sibling of `ShardedServer::wait_until_ready` (the server parks
    /// this connection on its barrier condvar; other workers' clients
    /// are unaffected because each has its own connections).
    pub fn wait_until_ready(&self, worker: usize) {
        let mut inner = self.lock();
        let f = inner
            .io
            .rpc(0, &wire::frame(op::WAIT, &(worker as u32).to_le_bytes()))
            .unwrap_or_else(|e| panic!("ssp transport: {e}"));
        expect_op(&f, op::OK).unwrap_or_else(|e| panic!("ssp transport: {e}"));
    }

    /// Version-gated evaluation snapshot — the remote sibling of
    /// `ShardedServer::snapshot_into_gated` (feeds `copy_totals`).
    pub fn snapshot_into_gated(
        &self,
        buf: &mut ParamSet,
        last_seen: &mut [u64],
    ) -> FetchStats {
        assert_eq!(buf.layers.len(), self.meta.n_layers, "snapshot buffer");
        assert_eq!(last_seen.len(), self.meta.n_layers, "snapshot last_seen");
        let mut inner = self.lock();
        let inner = &mut *inner;
        let fs = inner
            .io
            .gated_snapshot(&self.meta, buf, last_seen, self.meta.gated)
            .unwrap_or_else(|e| panic!("ssp transport: {e}"));
        inner.copy_totals.absorb(&fs);
        fs
    }
}

impl ParamServer for RemoteClient {
    fn policy(&self) -> Policy {
        self.meta.policy
    }

    fn workers(&self) -> usize {
        self.meta.workers
    }

    fn n_layers(&self) -> usize {
        self.meta.n_layers
    }

    fn clock(&self, worker: usize) -> u64 {
        self.lock()
            .io
            .rpc_u64(op::CLOCK, worker as u32)
            .unwrap_or_else(|e| panic!("ssp transport: {e}"))
    }

    fn commit(&mut self, worker: usize) -> u64 {
        self.lock()
            .io
            .rpc_u64(op::COMMIT, worker as u32)
            .unwrap_or_else(|e| panic!("ssp transport: {e}"))
    }

    fn apply_arrival(&mut self, msg: &UpdateMsg) {
        self.lock()
            .io
            .update(&self.meta, msg.from, msg.clock, msg.layer, &msg.delta)
            .unwrap_or_else(|e| panic!("ssp transport: {e}"));
    }

    fn must_wait(&self, worker: usize) -> bool {
        self.lock()
            .io
            .rpc_bool(op::MUST_WAIT, worker as u32)
            .unwrap_or_else(|e| panic!("ssp transport: {e}"))
    }

    fn read_ready(&self, worker: usize) -> bool {
        self.lock()
            .io
            .rpc_bool(op::READ_READY, worker as u32)
            .unwrap_or_else(|e| panic!("ssp transport: {e}"))
    }

    fn fetch(&mut self, worker: usize) -> (ParamSet, Vec<u64>, ReadStats) {
        let mut inner = self.lock();
        let inner = &mut *inner;
        inner.reads += 1;
        let mut own = Vec::with_capacity(self.meta.n_layers);
        let (stats, _fs) = inner
            .io
            .gated_fetch(
                &self.meta,
                worker,
                &mut inner.mirror,
                &mut inner.mirror_seen,
                &mut own,
                self.meta.gated,
            )
            .unwrap_or_else(|e| panic!("ssp transport: {e}"));
        (inner.mirror.clone(), own, stats)
    }

    fn fetch_into(
        &mut self,
        worker: usize,
        buf: &mut ParamSet,
        last_seen: &mut [u64],
        own: &mut Vec<u64>,
    ) -> (ReadStats, FetchStats) {
        assert_eq!(buf.layers.len(), self.meta.n_layers, "fetch_into buffer");
        assert_eq!(last_seen.len(), self.meta.n_layers, "fetch_into last_seen");
        let mut inner = self.lock();
        let inner = &mut *inner;
        inner.reads += 1;
        let (stats, fs) = inner
            .io
            .gated_fetch(&self.meta, worker, buf, last_seen, own, self.meta.gated)
            .unwrap_or_else(|e| panic!("ssp transport: {e}"));
        inner.copy_totals.absorb(&fs);
        (stats, fs)
    }

    fn snapshot(&self) -> ParamSet {
        let mut inner = self.lock();
        let inner = &mut *inner;
        inner
            .io
            .gated_snapshot(
                &self.meta,
                &mut inner.mirror,
                &mut inner.mirror_seen,
                self.meta.gated,
            )
            .unwrap_or_else(|e| panic!("ssp transport: {e}"));
        inner.mirror.clone()
    }

    fn snapshot_into(&self, buf: &mut ParamSet) {
        assert_eq!(buf.layers.len(), self.meta.n_layers, "snapshot buffer");
        let mut inner = self.lock();
        let inner = &mut *inner;
        inner
            .io
            .gated_snapshot(
                &self.meta,
                &mut inner.mirror,
                &mut inner.mirror_seen,
                self.meta.gated,
            )
            .unwrap_or_else(|e| panic!("ssp transport: {e}"));
        buf.copy_from(&inner.mirror);
    }

    fn copy_totals(&self) -> FetchStats {
        self.lock().copy_totals
    }

    fn applied(&self, layer: usize, worker: usize) -> u64 {
        assert!(layer < self.meta.n_layers, "layer out of range");
        let mut payload = Vec::with_capacity(8);
        wire::put_u32(&mut payload, layer as u32);
        wire::put_u32(&mut payload, worker as u32);
        let mut inner = self.lock();
        let f = inner
            .io
            .rpc(0, &wire::frame(op::APPLIED, &payload))
            .unwrap_or_else(|e| panic!("ssp transport: {e}"));
        expect_op(&f, op::U64).unwrap_or_else(|e| panic!("ssp transport: {e}"));
        let mut r = wire::Reader::new(&f.payload);
        let v = r.u64().unwrap_or_else(|e| panic!("ssp transport: {e}"));
        r.done().unwrap_or_else(|e| panic!("ssp transport: {e}"));
        v
    }

    fn reads(&self) -> u64 {
        self.lock().reads
    }
}

/// The per-worker connection set as a threaded-runner port: the same
/// hot-path sequence `run_threaded` drives in shared memory, each step
/// one (batched) message exchange.
impl WorkerPort for RemoteClient {
    fn wait_until_ready(&mut self, worker: usize) {
        RemoteClient::wait_until_ready(self, worker)
    }

    fn fetch_view(
        &mut self,
        worker: usize,
        buf: &mut ParamSet,
        last_seen: &mut [u64],
        own: &mut Vec<u64>,
    ) -> (ReadStats, FetchStats) {
        ParamServer::fetch_into(self, worker, buf, last_seen, own)
    }

    fn commit_clock(&mut self, worker: usize) -> u64 {
        ParamServer::commit(self, worker)
    }

    fn apply_commit(&mut self, worker: usize, clock: u64, delta: &GradSet) {
        assert_eq!(delta.layers.len(), self.meta.n_layers, "commit layers");
        self.lock()
            .io
            .commit_updates(&self.meta, worker, clock, delta)
            .unwrap_or_else(|e| panic!("ssp transport: {e}"));
    }

    fn snapshot_gated(
        &mut self,
        buf: &mut ParamSet,
        last_seen: &mut [u64],
    ) -> FetchStats {
        RemoteClient::snapshot_into_gated(self, buf, last_seen)
    }

    fn master_snapshot(&mut self) -> ParamSet {
        ParamServer::snapshot(self)
    }
}

fn resolve(host: &str, port: u16) -> Result<SocketAddr, String> {
    use std::net::ToSocketAddrs;
    (host, port)
        .to_socket_addrs()
        .map_err(|e| format!("resolve {host}:{port}: {e}"))?
        .next()
        .ok_or_else(|| format!("{host}:{port} resolves to nothing"))
}
