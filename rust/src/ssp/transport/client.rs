//! `RemoteClient` — the worker side of the message boundary.
//!
//! A full [`ParamServer`] implementation over framed TCP: every trait
//! call becomes one request per relevant endpoint, so the
//! discrete-event driver (`run_experiment_with`), the sweep harness and
//! the P1–P5 property suite run against a remote server byte-for-byte
//! the way they run against the in-process `ShardedServer`. It also
//! implements [`WorkerPort`], so `coordinator::run_threaded_on` can put
//! one connection set under each OS worker thread — the multi-process
//! deployment shape.
//!
//! Two orthogonal deployment axes, both negotiated at the handshake or
//! chosen at construction:
//!
//! * **Shared vs. exclusive endpoints.** Shared (HELLO_OK `exclusive
//!   = 0`): every endpoint wraps one `ShardedServer` process, so
//!   control RPCs go to group 0 and a single COMMIT advances the one
//!   clock table. Exclusive (`= 1`, one `sspdnn serve --group i` per
//!   process): each process owns a private clock table and only its
//!   group's shards, so the client *broadcasts* every COMMIT (keeping
//!   the tables identical), ANDs the group-scoped READ_READY answers,
//!   fans WAIT out to every endpoint (readiness is monotone between a
//!   worker's own commits, so waiting the groups out sequentially is
//!   sound), and routes APPLIED to the owning group. ε statistics sum
//!   across groups exactly because each group computes them from the
//!   same clock table over its own disjoint layers.
//!
//! * **Synchronous vs. pipelined commits** ([`RemoteClient::
//!   with_pipeline`]). Synchronous: every UPDATE/COMMIT blocks on its
//!   acknowledgement — simple, but loopback RTTs bound commits/sec.
//!   Pipelined: each connection gets a dedicated writer thread and a
//!   bounded in-flight window; `apply_commit`/`commit_clock` enqueue
//!   their frames and return immediately, so the worker overlaps the
//!   next minibatch's compute with the previous clock's acks. The
//!   pending-acknowledgement queue is drained before *any* response is
//!   read on that connection (per-connection FIFO — the server
//!   processes a connection's frames in order — is what keeps the
//!   observable protocol bitwise identical to the synchronous path),
//!   and `commit_clock` itself never forces a drain: the blocking
//!   moves into `wait_until_ready`/`fetch_view`, i.e. exactly where
//!   the SSP staleness gate requires the worker to stop anyway. A
//!   server ERR consumes its pending entry like any acknowledgement
//!   (the window never desyncs) and surfaces as a typed
//!   [`TransportError`].
//!
//! **Fault tolerance.** Every operation runs under a connection
//! supervisor parameterized by a [`FaultPolicy`]: an `Io` failure
//! triggers reconnect of every endpoint with exponential backoff, the
//! fresh handshakes are validated against the original, a revision
//! probe rules out a server that cold-restarted without its state, and
//! the in-flight pipeline window is resynchronized — TCP's FIFO
//! guarantee means the server applied a *prefix* of each connection's
//! frames, so per-entry point queries (APPLIED / CLOCK) decide exactly
//! which suffix to replay. A successful recovery is bitwise invisible
//! to the SSP gate. When the retry budget runs out the window is
//! abandoned (`in_flight` drops to 0) and a typed
//! [`TransportErrorKind::Lost`] surfaces. The default policy is
//! [`FaultPolicy::none`] — supervision off, every fault surfaces
//! immediately, the pre-fault behavior. Liveness is covered from the
//! other side by heartbeat leases ([`RemoteClient::with_lease`]): the
//! server releases barrier waits parked on workers whose lease lapsed.
//!
//! Reads are **version-gated on the wire**: `fetch_into` ships the
//! caller's per-layer last-seen revision vector and receives only the
//! layers whose revision advanced (the endpoint's gate skip is a skip
//! of actual payload bytes — `wire_stats` exposes the saving). The
//! allocating `fetch`/`snapshot` paths keep a client-side **mirror** of
//! the master plus a per-connection cached revision vector, so even the
//! "full" reads only move changed layers over the network.
//!
//! Accounting (`reads`, `copy_totals`) is client-side: with one client
//! per worker process there is no meaningful server-global count, and
//! keeping it at the subscriber makes the numbers comparable with the
//! in-process servers call-for-call.

use std::collections::VecDeque;
use std::net::{SocketAddr, TcpStream};
use std::sync::{mpsc, Mutex};

use crate::nn::{GradSet, LayerParams, ParamSet};
use crate::ssp::{FetchStats, ParamServer, Policy, ReadStats, UpdateMsg, WorkerPort};
use crate::tensor::Matrix;

use super::codec::{self, Codec};
use super::service::{policy_decode, ShardService};
use super::wire::{self, op, Frame, FrameDecoder, WireError};

/// Raw transport accounting, from the client's side of the sockets.
/// In pipelined mode a frame counts as sent when it is handed to the
/// connection's writer thread (the moment it irrevocably enters the
/// send FIFO).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WireStats {
    pub frames_sent: u64,
    pub frames_received: u64,
    pub bytes_sent: u64,
    pub bytes_received: u64,
    /// UPDATE frame bytes, counted once per frame at encode time — a
    /// supervised replay of the same bytes is not double-counted, so
    /// this measures the commit path's logical wire cost per clock.
    pub update_bytes_sent: u64,
    /// FETCH_OK frame bytes (length prefix + opcode included) received
    /// on the gated fetch path — the hot-read wire cost per clock.
    pub fetch_bytes_received: u64,
    /// SNAP_OK frame bytes (length prefix + opcode included) received
    /// on the gated snapshot path.
    pub snapshot_bytes_received: u64,
    /// Layer payload bytes by codec format tag ([`codec::fmt`]), both
    /// directions: UPDATE layer bodies as encoded, FETCH/SNAPSHOT
    /// layer bodies as decoded. `codec=off` traffic all lands on
    /// `fmt::RAW`; a top-k frame that fell back to dense lands on
    /// `fmt::BF16` — the array attributes bytes to the format actually
    /// on the wire, not the requested codec.
    pub payload_bytes: [u64; 4],
}

/// What went wrong, typed: protocol-level rejections the server
/// answered with an ERR frame (the connection and the in-flight window
/// stay usable), socket-level failures, and malformed/unexpected
/// replies.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportErrorKind {
    /// The server answered ERR (e.g. the FIFO pre-check rejected an
    /// out-of-order update). The offending request had no effect and
    /// the connection stays up.
    Server,
    /// Socket-level failure (connect, read, write, torn frame at EOF).
    Io,
    /// The bytes arrived but made no sense: undecodable frame,
    /// unexpected reply opcode, short payload, or a pipelined COMMIT
    /// acknowledgement disagreeing with the client's clock bookkeeping.
    Protocol,
    /// The connection supervisor exhausted its reconnect budget
    /// ([`FaultPolicy::max_retries`]): the server tier is gone, not
    /// glitching. The in-flight window has been abandoned.
    Lost,
}

/// A typed transport failure. Converts into the `String` errors the
/// connect paths use, and `Display`s with the same prefixes the
/// pre-typed error strings carried (so panic-message pins hold).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TransportError {
    pub kind: TransportErrorKind,
    pub msg: String,
}

impl TransportError {
    fn server(msg: impl Into<String>) -> TransportError {
        TransportError { kind: TransportErrorKind::Server, msg: msg.into() }
    }

    fn io(msg: impl Into<String>) -> TransportError {
        TransportError { kind: TransportErrorKind::Io, msg: msg.into() }
    }

    fn protocol(msg: impl Into<String>) -> TransportError {
        TransportError { kind: TransportErrorKind::Protocol, msg: msg.into() }
    }

    fn lost(msg: impl Into<String>) -> TransportError {
        TransportError { kind: TransportErrorKind::Lost, msg: msg.into() }
    }
}

impl std::fmt::Display for TransportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let kind = match self.kind {
            TransportErrorKind::Server => "server error",
            TransportErrorKind::Io => "transport io",
            TransportErrorKind::Protocol => "transport protocol",
            TransportErrorKind::Lost => "transport lost",
        };
        write!(f, "{kind}: {}", self.msg)
    }
}

impl std::error::Error for TransportError {}

impl From<TransportError> for String {
    fn from(e: TransportError) -> String {
        e.to_string()
    }
}

impl From<WireError> for TransportError {
    fn from(e: WireError) -> TransportError {
        TransportError::protocol(e.to_string())
    }
}

/// How the client treats a faulty server tier — the connection
/// supervisor's knobs, single-sourced from the `[transport]` config
/// section (`connect_timeout_ms` / `io_timeout_ms` / `max_retries` /
/// `backoff_base_ms`). The default is [`FaultPolicy::none`]:
/// supervision off, every socket failure surfaces immediately — the
/// pre-fault behavior, and what every test that *pins* failure modes
/// wants.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultPolicy {
    /// Bound on every TCP connect (initial and reconnect).
    pub connect_timeout: std::time::Duration,
    /// Socket read timeout for request/response exchanges; `None`
    /// blocks forever. WAIT is exempt (a barrier legitimately outlasts
    /// any timeout — dead peers are the server lease table's job).
    pub io_timeout: Option<std::time::Duration>,
    /// Reconnect attempts per supervised operation before the client
    /// declares the tier [`TransportErrorKind::Lost`]. `0` disables
    /// supervision entirely.
    pub max_retries: u32,
    /// First reconnect delay; doubles per attempt (capped at 2 s).
    pub backoff_base: std::time::Duration,
}

impl FaultPolicy {
    /// Supervision off: connect bounded at 5 s, reads block forever,
    /// no retries. Every fault surfaces as a typed error immediately.
    pub fn none() -> FaultPolicy {
        FaultPolicy {
            connect_timeout: std::time::Duration::from_secs(5),
            io_timeout: None,
            max_retries: 0,
            backoff_base: std::time::Duration::from_millis(50),
        }
    }

    /// Delay before reconnect `attempt` (1-based): `backoff_base ×
    /// 2^(attempt−1)`, capped at 2 s so a long budget degrades into
    /// steady polling rather than unbounded sleeps.
    fn backoff(&self, attempt: u32) -> std::time::Duration {
        let factor = 1u32 << attempt.saturating_sub(1).min(6);
        (self.backoff_base * factor).min(std::time::Duration::from_secs(2))
    }
}

impl Default for FaultPolicy {
    fn default() -> FaultPolicy {
        FaultPolicy::none()
    }
}

/// Immutable facts learned at the HELLO handshake.
#[derive(Clone, Debug)]
struct Meta {
    workers: usize,
    n_layers: usize,
    policy: Policy,
    /// `(rows, cols, blen)` per layer — buffer allocation + shape checks.
    shapes: Vec<(usize, usize, usize)>,
    /// Layer range per shard group (contiguous, ascending).
    ranges: Vec<std::ops::Range<usize>>,
    /// Owning group of each layer.
    layer_group: Vec<usize>,
    /// FNV-1a digest of the served init (`transport::param_digest`),
    /// from the handshake — `check_run`'s seed-mismatch tripwire.
    init_digest: u64,
    /// Every endpoint is its own server process hosting only its
    /// group's shards (see module docs): COMMIT broadcasts, READ_READY
    /// / WAIT fan out, APPLIED routes to the owner.
    exclusive: bool,
    /// The endpoints evict lease-expired workers instead of failing
    /// parked waiters, and accept ADMIT/LEAVE (HELLO_OK `elastic`).
    elastic: bool,
    /// Version-gate delta reads (config `transport.gated`). Off: every
    /// gated read sends an always-miss sentinel, shipping every layer.
    gated: bool,
    /// Negotiated payload codec ([`RemoteClient::with_codec`]); every
    /// connection re-negotiates it at the handshake on reconnect.
    /// `Off` keeps every payload bitwise-identical to wire v4.
    codec: Codec,
}

/// All-live bitmask over `workers` workers (bit p ⇔ worker p).
fn full_mask(workers: usize) -> u64 {
    if workers >= 64 {
        !0u64
    } else {
        (1u64 << workers) - 1
    }
}

/// One expected-but-unread acknowledgement on a pipelined connection,
/// in FIFO order with the server's replies. Each entry carries enough
/// to *replay* the request after a reconnect: TCP guarantees the
/// server applied a prefix of the connection's frames, so the
/// un-acknowledged entries are a suffix of which any individual entry
/// may or may not have landed — a point query (APPLIED / CLOCK)
/// decides, and the frame is resent only if it didn't.
#[derive(Clone, Debug)]
enum Pending {
    /// An UPDATE awaiting its OK. `frame` is the encoded bytes as
    /// sent; `(from, clock, layer)` keys the landed-check.
    Update {
        from: u32,
        clock: u64,
        layer: u32,
        frame: Vec<u8>,
    },
    /// A COMMIT awaiting its U64 reply, which must equal `expected` —
    /// the client's locally tracked committed count (it advances only
    /// through this client).
    Commit { worker: u32, expected: u64 },
}

/// The dedicated writer thread of one pipelined connection: everything
/// the client sends on that connection goes through its channel, so
/// the socket sees exactly the enqueue order (FIFO with the pending
/// queue). Dropping the writer closes the channel and joins.
struct Writer {
    tx: Option<mpsc::Sender<Vec<u8>>>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Writer {
    fn spawn(mut stream: TcpStream) -> Writer {
        let (tx, rx) = mpsc::channel::<Vec<u8>>();
        let handle = std::thread::spawn(move || {
            while let Ok(buf) = rx.recv() {
                if std::io::Write::write_all(&mut stream, &buf).is_err() {
                    // the reader side will see the failure as a recv
                    // error; just stop accepting frames
                    break;
                }
            }
        });
        Writer { tx: Some(tx), handle: Some(handle) }
    }

    fn send(&self, buf: Vec<u8>) -> Result<(), TransportError> {
        self.tx
            .as_ref()
            .expect("writer channel")
            .send(buf)
            .map_err(|_| {
                TransportError::io("writer thread gone (socket write failed)")
            })
    }
}

impl Drop for Writer {
    fn drop(&mut self) {
        drop(self.tx.take()); // disconnect: the thread drains and exits
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

struct Conn {
    /// Where this connection dialed — the supervisor redials it here.
    addr: SocketAddr,
    stream: TcpStream,
    dec: FrameDecoder,
    /// `Some` in pipelined mode; owns a `try_clone` of `stream`.
    writer: Option<Writer>,
    /// Outstanding acknowledgements, FIFO with the server's replies.
    pending: VecDeque<Pending>,
}

/// The socket half: one connection per shard group + wire accounting.
struct ClientIo {
    conns: Vec<Conn>,
    wire: WireStats,
    /// Pipelined mode: max outstanding acknowledgements per connection
    /// before an enqueue first drains. `None` = synchronous.
    window: Option<usize>,
    /// Locally tracked committed clock per worker (`None` = unknown;
    /// the first pipelined commit for that worker runs one synchronous
    /// round to learn the server's count — the reconnect case).
    commits: Vec<Option<u64>>,
    /// The connection supervisor's retry/timeout/backoff knobs.
    faults: FaultPolicy,
    /// Per group: in-flight entries parked by a reconnect, awaiting
    /// resync (kept outside `Conn` so a failed reconnect attempt
    /// cannot lose them). Cleared by a successful resync or `abandon`.
    replay: Vec<VecDeque<Pending>>,
    /// Highest per-layer revision ever observed on the wire. Within
    /// one server lifetime revisions only grow, so a reconnect probe
    /// seeing a *smaller* revision proves the server cold-restarted —
    /// the one fault reconnect cannot transparently absorb.
    rev_floor: Vec<u64>,
    /// Completed reconnect-and-resync cycles (`RemoteClient::
    /// reconnects`).
    recovered: u64,
    /// Highest membership epoch observed anywhere: handshakes, the
    /// epoch piggybacked on every FETCH_OK, EPOCH answers, and
    /// LEAVE/ADMIT replies. Monotone — epochs only grow within one
    /// server lifetime.
    epoch_seen: u64,
    /// Epoch at which `mask` was last fetched. `membership()`
    /// round-trips only while `epoch_seen > mask_epoch` — i.e. only
    /// when a piggybacked epoch proves the cached live set is stale.
    mask_epoch: u64,
    /// Live-set bitmask as of `mask_epoch` (starts all-live).
    mask: u64,
}

struct Inner {
    io: ClientIo,
    /// Per-(worker, layer) error-feedback residuals for the lossy
    /// codecs' commit path (untouched while `Meta::codec` is `Off`).
    /// Kept outside `ClientIo` so encoding — which consumes residual —
    /// happens exactly once per delta, before the supervised closure
    /// that may retry the send.
    ef: codec::ErrorFeedback,
    /// Client-side master mirror backing the allocating `fetch` /
    /// `snapshot` paths; refreshed through the same wire gate.
    mirror: ParamSet,
    /// The mirror's per-layer cached revision vector (`u64::MAX` =
    /// unknown — the first refresh copies everything).
    mirror_seen: Vec<u64>,
    reads: u64,
    copy_totals: FetchStats,
}

pub struct RemoteClient {
    meta: Meta,
    inner: Mutex<Inner>,
    /// Background heartbeat thread ([`RemoteClient::with_lease`]).
    /// Declared after `inner` and before `services` so on drop the
    /// main sockets close first, then the keeper joins (its own
    /// connections close with it), and only then do any loopback
    /// services join their connection threads.
    lease: Option<LeaseKeeper>,
    /// Fault-injection proxies owned by this client (the chaos test
    /// harness); torn down after the sockets, before the services.
    chaos: Vec<super::chaos::ChaosProxy>,
    /// Loopback services owned by this client (tests/bench): declared
    /// last so every socket closes before the services join their
    /// threads on drop.
    services: Vec<ShardService>,
}

impl ClientIo {
    fn send(&mut self, g: usize, frame_bytes: &[u8]) -> Result<(), TransportError> {
        let conn = &mut self.conns[g];
        match &conn.writer {
            Some(w) => w.send(frame_bytes.to_vec()).map_err(|mut e| {
                e.msg = format!("send (group {g}): {}", e.msg);
                e
            })?,
            None => std::io::Write::write_all(&mut conn.stream, frame_bytes)
                .map_err(|e| {
                    TransportError::io(format!("send (group {g}): {e}"))
                })?,
        }
        self.wire.frames_sent += 1;
        self.wire.bytes_sent += frame_bytes.len() as u64;
        Ok(())
    }

    fn recv(&mut self, g: usize) -> Result<Frame, TransportError> {
        let conn = &mut self.conns[g];
        let frame = wire::read_frame(
            &mut conn.stream,
            &mut conn.dec,
            &mut self.wire.bytes_received,
        )
        .map_err(|e| TransportError::io(format!("recv (group {g}): {e}")))?
        .ok_or_else(|| {
            TransportError::io(format!("server closed connection (group {g})"))
        })?;
        self.wire.frames_received += 1;
        if frame.op == op::ERR {
            return Err(TransportError::server(
                String::from_utf8_lossy(&frame.payload).into_owned(),
            ));
        }
        Ok(frame)
    }

    /// Consume one outstanding acknowledgement from `g`'s pending
    /// queue. The entry is popped *before* the reply is read, so a
    /// server ERR (which answers exactly that request) leaves the
    /// window aligned — the error is surfaced, not a desync. An Io
    /// failure pushes the entry back instead: whether it landed is
    /// unknown, and the supervisor's resync needs it to find out.
    fn drain_one(&mut self, g: usize) -> Result<(), TransportError> {
        let expect = self.conns[g]
            .pending
            .pop_front()
            .expect("drain_one on an empty pending queue");
        let f = match self.recv(g) {
            Ok(f) => f,
            Err(e) => {
                if e.kind == TransportErrorKind::Io {
                    self.conns[g].pending.push_front(expect);
                }
                return Err(e);
            }
        };
        match expect {
            Pending::Update { .. } => expect_op(&f, op::OK),
            Pending::Commit { expected, .. } => {
                let got = u64_reply(&f)?;
                if got != expected {
                    return Err(TransportError::protocol(format!(
                        "pipelined COMMIT ack {got} != locally tracked \
                         {expected} (group {g}) — another client \
                         committed for this worker?"
                    )));
                }
                Ok(())
            }
        }
    }

    /// Drain every outstanding acknowledgement on `g` — required
    /// before reading any synchronous reply on that connection (the
    /// server answers strictly in request order).
    fn drain(&mut self, g: usize) -> Result<(), TransportError> {
        while !self.conns[g].pending.is_empty() {
            self.drain_one(g)?;
        }
        Ok(())
    }

    /// Drain everything on every connection, reporting the first error
    /// but consuming every acknowledgement a live connection still
    /// owes (a server ERR consumes its entry and draining continues; a
    /// fatal failure stops that connection's drain — an Io fault keeps
    /// its entry queued for the supervisor's resync).
    fn flush_all(&mut self) -> Result<(), TransportError> {
        let mut first: Option<TransportError> = None;
        for g in 0..self.conns.len() {
            while !self.conns[g].pending.is_empty() {
                match self.drain_one(g) {
                    Ok(()) => {}
                    Err(e) => {
                        let fatal = e.kind != TransportErrorKind::Server;
                        if first.is_none() {
                            first = Some(e);
                        }
                        if fatal {
                            break;
                        }
                    }
                }
            }
        }
        match first {
            None => Ok(()),
            Some(e) => Err(e),
        }
    }

    /// Drain every connection's in-flight window (no-op when
    /// synchronous or empty). Called before reads whose answer spans
    /// connections — e.g. shared-mode READ_READY is evaluated by one
    /// endpoint but depends on updates pipelined to *other*
    /// connections; acknowledgements are sent after application, so a
    /// full drain makes every previously-issued operation visible and
    /// keeps the answer deterministic (bitwise equal to the oracle's).
    fn settle(&mut self) -> Result<(), TransportError> {
        if self.window.is_some() {
            for g in 0..self.conns.len() {
                self.drain(g)?;
            }
        }
        Ok(())
    }

    /// Make room for one more in-flight acknowledgement on `g`
    /// (pipelined mode): the bounded window that keeps the number of
    /// unread replies — and with it the receive-buffer footprint —
    /// finite without ever blocking on a whole round trip per frame.
    fn make_room(&mut self, g: usize) -> Result<(), TransportError> {
        let window = self.window.expect("make_room in synchronous mode");
        while self.conns[g].pending.len() >= window {
            self.drain_one(g)?;
        }
        Ok(())
    }

    /// Enqueue a request expecting an acknowledgement later (pipelined
    /// fire-and-account path). The entry itself carries (or rebuilds)
    /// the frame bytes, so the in-flight window stays replayable.
    fn enqueue(&mut self, g: usize, expect: Pending) -> Result<(), TransportError> {
        self.make_room(g)?;
        let commit_frame;
        let frame_bytes: &[u8] = match &expect {
            Pending::Update { frame, .. } => frame,
            Pending::Commit { worker, .. } => {
                commit_frame = wire::frame(op::COMMIT, &worker.to_le_bytes());
                &commit_frame
            }
        };
        self.send(g, frame_bytes)?;
        self.conns[g].pending.push_back(expect);
        Ok(())
    }

    /// Synchronous request/response on one connection (draining any
    /// pipelined backlog first — the server replies in request order).
    fn rpc(&mut self, g: usize, frame_bytes: &[u8]) -> Result<Frame, TransportError> {
        self.send(g, frame_bytes)?;
        self.drain(g)?;
        self.recv(g)
    }

    /// Control RPC carrying one u32 argument, returning a u64.
    fn rpc_u64_on(
        &mut self,
        g: usize,
        opcode: u8,
        arg: u32,
    ) -> Result<u64, TransportError> {
        let f = self.rpc(g, &wire::frame(opcode, &arg.to_le_bytes()))?;
        expect_op(&f, op::U64)?;
        let mut r = wire::Reader::new(&f.payload);
        let v = r.u64()?;
        r.done()?;
        Ok(v)
    }

    /// Control RPC carrying one u32 argument, returning a bool.
    fn rpc_bool_on(
        &mut self,
        g: usize,
        opcode: u8,
        arg: u32,
    ) -> Result<bool, TransportError> {
        let f = self.rpc(g, &wire::frame(opcode, &arg.to_le_bytes()))?;
        expect_op(&f, op::BOOL)?;
        let mut r = wire::Reader::new(&f.payload);
        let v = r.u8()?;
        r.done()?;
        Ok(v != 0)
    }

    /// The COMMIT targets: every endpoint in exclusive mode (each
    /// process's private clock table must advance), group 0 alone in
    /// shared mode (they all wrap the same table).
    fn commit_targets(&self, meta: &Meta) -> std::ops::Range<usize> {
        if meta.exclusive {
            0..self.conns.len()
        } else {
            0..1
        }
    }

    /// Advance `worker`'s clock. Pipelined steady state: the COMMIT
    /// frames enter the send FIFOs with an expected acknowledgement
    /// queued, and the locally tracked count is returned immediately —
    /// no round trip on the worker's hot path. Synchronous mode (or
    /// the first pipelined commit, count still unknown): a blocking
    /// COMMIT round. Under supervision the round runs against a
    /// *predetermined* target clock (learned up front), so a reconnect
    /// mid-broadcast can tell which endpoints the commit reached;
    /// without supervision it is the pre-fault agreement round,
    /// byte-for-byte.
    fn commit(&mut self, meta: &Meta, worker: usize) -> Result<u64, TransportError> {
        if self.window.is_some() {
            if let Some(known) = self.commits[worker] {
                let expected = known + 1;
                self.supervised(meta, |io, resume| {
                    io.commit_pipelined_round(meta, worker, expected, resume)
                })?;
                self.commits[worker] = Some(expected);
                return Ok(expected);
            }
        }
        if self.faults.max_retries > 0 {
            if self.commits[worker].is_none() {
                let base = self.learn_clock(meta, worker)?;
                self.commits[worker] = Some(base);
            }
            let expected = self.commits[worker].expect("just learned") + 1;
            self.supervised(meta, |io, resume| {
                io.commit_known(meta, worker, expected, resume)
            })?;
            self.commits[worker] = Some(expected);
            return Ok(expected);
        }
        let v = self.commit_agree(meta, worker)?;
        self.commits[worker] = Some(v);
        Ok(v)
    }

    /// The unsupervised blocking COMMIT round: every target must
    /// return the same new count (exclusive endpoints advance in
    /// lockstep or something is deeply wrong).
    fn commit_agree(&mut self, meta: &Meta, worker: usize) -> Result<u64, TransportError> {
        let bytes = wire::frame(op::COMMIT, &(worker as u32).to_le_bytes());
        let mut agreed: Option<u64> = None;
        for g in self.commit_targets(meta) {
            let f = self.rpc(g, &bytes)?;
            let v = u64_reply(&f)?;
            match agreed {
                None => agreed = Some(v),
                Some(prev) if prev != v => {
                    return Err(TransportError::protocol(format!(
                        "exclusive endpoints disagree on worker {worker}'s \
                         clock: {prev} vs {v} (group {g})"
                    )));
                }
                Some(_) => {}
            }
        }
        Ok(agreed.expect("at least one commit target"))
    }

    /// Blocking COMMIT round toward a predetermined target count. On a
    /// resumed attempt (post-reconnect) each endpoint's clock is
    /// queried first: targets the original broadcast (or the resync
    /// replay) already reached are skipped, so the commit lands
    /// exactly once everywhere.
    fn commit_known(
        &mut self,
        meta: &Meta,
        worker: usize,
        expected: u64,
        resume: bool,
    ) -> Result<(), TransportError> {
        let bytes = wire::frame(op::COMMIT, &(worker as u32).to_le_bytes());
        for g in self.commit_targets(meta) {
            if resume {
                let c = self.rpc_u64_on(g, op::CLOCK, worker as u32)?;
                if c == expected {
                    continue; // landed before the fault (or via resync)
                }
                if c + 1 != expected {
                    return Err(TransportError::protocol(format!(
                        "resumed commit for worker {worker} found group \
                         {g} at clock {c}, target {expected}"
                    )));
                }
            }
            let f = self.rpc(g, &bytes)?;
            let got = u64_reply(&f)?;
            if got != expected {
                return Err(TransportError::protocol(format!(
                    "COMMIT for worker {worker} returned {got}, locally \
                     tracked target {expected} (group {g}) — another \
                     client committed for this worker?"
                )));
            }
        }
        Ok(())
    }

    /// Pipelined COMMIT broadcast. A resumed attempt finishes the
    /// round synchronously: the replay queue has already resynced
    /// whatever was enqueued before the fault, and `commit_known`
    /// skips the targets it reached.
    fn commit_pipelined_round(
        &mut self,
        meta: &Meta,
        worker: usize,
        expected: u64,
        resume: bool,
    ) -> Result<(), TransportError> {
        if resume {
            return self.commit_known(meta, worker, expected, true);
        }
        for g in self.commit_targets(meta) {
            self.enqueue(
                g,
                Pending::Commit { worker: worker as u32, expected },
            )?;
        }
        Ok(())
    }

    /// Learn `worker`'s committed count from the server tier, repairing
    /// any lagging exclusive endpoint up to the maximum — the aftermath
    /// of a crash mid-COMMIT-broadcast. Idempotent (repair rounds
    /// verify each +1), so it runs under supervision itself.
    fn learn_clock(&mut self, meta: &Meta, worker: usize) -> Result<u64, TransportError> {
        self.supervised(meta, |io, _resume| {
            let targets = io.commit_targets(meta);
            let mut clocks = Vec::with_capacity(targets.len());
            for g in targets.clone() {
                clocks.push(io.rpc_u64_on(g, op::CLOCK, worker as u32)?);
            }
            let goal = *clocks.iter().max().expect("at least one target");
            let bytes = wire::frame(op::COMMIT, &(worker as u32).to_le_bytes());
            for (g, mut c) in targets.zip(clocks) {
                while c < goal {
                    let f = io.rpc(g, &bytes)?;
                    let got = u64_reply(&f)?;
                    if got != c + 1 {
                        return Err(TransportError::protocol(format!(
                            "clock repair for worker {worker} expected \
                             {}, got {got} (group {g})",
                            c + 1
                        )));
                    }
                    c = got;
                }
            }
            Ok(goal)
        })
    }

    /// Ship one pre-encoded UPDATE frame to its owning endpoint —
    /// synchronously, or into the pipeline's in-flight window. The
    /// caller encodes ([`encode_update_frame`]) so the error-feedback
    /// residual advances exactly once per delta; this function only
    /// moves bytes, and may therefore run under supervised retry. On a
    /// resumed attempt (post-reconnect) the server's version vector is
    /// consulted first, so an update that landed before the fault is
    /// never double-applied.
    fn update_frame(
        &mut self,
        meta: &Meta,
        from: usize,
        clock: u64,
        layer: usize,
        frame: &[u8],
        resume: bool,
    ) -> Result<(), TransportError> {
        if resume {
            let landed = self.applied(meta, layer, from)?;
            if landed > clock {
                return Ok(());
            }
            if landed < clock {
                return Err(TransportError::protocol(format!(
                    "resumed update found layer {layer} at applied \
                     {landed} < clock {clock} — the server lost state"
                )));
            }
        }
        let g = meta.layer_group[layer];
        if self.window.is_some() {
            return self.enqueue(
                g,
                Pending::Update {
                    from: from as u32,
                    clock,
                    layer: layer as u32,
                    frame: frame.to_vec(),
                },
            );
        }
        let f = self.rpc(g, frame)?;
        expect_op(&f, op::OK)
    }

    /// Whole-clock commit of pre-encoded per-layer UPDATE frames
    /// (`frames[l]` is layer `l`'s frame). Synchronous mode: every
    /// layer's frame is written to its owning endpoint before any
    /// acknowledgement is read (per-connection ordering preserves the
    /// per-layer FIFO), so an L-layer commit costs ~1 round trip per
    /// *group*. Pipelined mode: the frames enter the send FIFOs and
    /// the call returns — the acks drain at the next blocking read on
    /// each connection (or when the window fills), overlapping the
    /// worker's next minibatch with the network.
    fn commit_frames(
        &mut self,
        meta: &Meta,
        worker: usize,
        clock: u64,
        frames: &[Vec<u8>],
        resume: bool,
    ) -> Result<(), TransportError> {
        if resume {
            // recovery path: per-layer query-and-skip, one at a time —
            // rare enough that clarity beats batching
            for (layer, frame) in frames.iter().enumerate() {
                self.update_frame(meta, worker, clock, layer, frame, true)?;
            }
            return Ok(());
        }
        for (layer, frame) in frames.iter().enumerate() {
            let g = meta.layer_group[layer];
            if self.window.is_some() {
                self.enqueue(
                    g,
                    Pending::Update {
                        from: worker as u32,
                        clock,
                        layer: layer as u32,
                        frame: frame.clone(),
                    },
                )?;
            } else {
                self.send(g, frame)?;
            }
        }
        if self.window.is_some() {
            return Ok(());
        }
        for (g, range) in meta.ranges.iter().enumerate() {
            for _ in range.clone() {
                let f = self.recv(g)?;
                expect_op(&f, op::OK)?;
            }
        }
        Ok(())
    }

    /// Block until `worker` may proceed. Shared mode: one WAIT parked
    /// on group 0 (its server sees every shard). Exclusive mode: WAIT
    /// fans out to every endpoint — each can only vouch for its own
    /// shards' read guarantee — and the replies are collected in
    /// order; since readiness is monotone between a worker's own
    /// commits (peers only advance), all conditions hold simultaneously
    /// once the last OK arrives.
    fn wait(&mut self, meta: &Meta, worker: usize) -> Result<(), TransportError> {
        self.settle()?;
        let targets = if meta.exclusive { self.conns.len() } else { 1 };
        // WAIT is exempt from the io timeout: a barrier legitimately
        // outlasts any bound (it opens only when *other* workers
        // commit). A dead peer is the server lease table's job — it
        // fails the wait with a typed ERR — and a killed server still
        // surfaces instantly as EOF. A frozen-but-connected server
        // during WAIT therefore hangs; that is the documented gap.
        for g in 0..targets {
            self.conns[g]
                .stream
                .set_read_timeout(None)
                .map_err(|e| {
                    TransportError::io(format!("read timeout (group {g}): {e}"))
                })?;
        }
        let result = self.wait_exchange(worker, targets);
        for g in 0..targets {
            // best-effort restore; a dead socket is replaced (with the
            // timeout re-armed) by the supervisor anyway
            let _ = self.conns[g]
                .stream
                .set_read_timeout(self.faults.io_timeout);
        }
        result
    }

    fn wait_exchange(
        &mut self,
        worker: usize,
        targets: usize,
    ) -> Result<(), TransportError> {
        let bytes = wire::frame(op::WAIT, &(worker as u32).to_le_bytes());
        for g in 0..targets {
            self.send(g, &bytes)?;
        }
        for g in 0..targets {
            self.drain(g)?;
            let f = self.recv(g)?;
            expect_op(&f, op::OK)?;
        }
        Ok(())
    }

    /// Eq. 5's read guarantee. Exclusive mode ANDs the group-scoped
    /// answers (the predicate is a conjunction over (layer, worker)
    /// pairs, and the groups partition the layers).
    fn read_ready(&mut self, meta: &Meta, worker: usize) -> Result<bool, TransportError> {
        self.settle()?;
        if !meta.exclusive {
            return self.rpc_bool_on(0, op::READ_READY, worker as u32);
        }
        let bytes = wire::frame(op::READ_READY, &(worker as u32).to_le_bytes());
        for g in 0..self.conns.len() {
            self.send(g, &bytes)?;
        }
        let mut all = true;
        for g in 0..self.conns.len() {
            self.drain(g)?;
            let f = self.recv(g)?;
            expect_op(&f, op::BOOL)?;
            let mut r = wire::Reader::new(&f.payload);
            all &= r.u8()? != 0;
            r.done()?;
        }
        Ok(all)
    }

    /// The (layer, worker) version-vector entry, from the endpoint
    /// that owns the layer — the only process whose vector moves for it
    /// in exclusive mode (and an equally valid answer in shared mode).
    fn applied(
        &mut self,
        meta: &Meta,
        layer: usize,
        worker: usize,
    ) -> Result<u64, TransportError> {
        let g = meta.layer_group[layer];
        let mut payload = Vec::with_capacity(8);
        wire::put_u32(&mut payload, layer as u32);
        wire::put_u32(&mut payload, worker as u32);
        let f = self.rpc(g, &wire::frame(op::APPLIED, &payload))?;
        expect_op(&f, op::U64)?;
        let mut r = wire::Reader::new(&f.payload);
        let v = r.u64()?;
        r.done()?;
        Ok(v)
    }

    /// Version-gated read fan-out: one pipelined FETCH per endpoint
    /// (all requests sent before any response is read — one round-trip
    /// of latency regardless of group count), responses decoded in
    /// group order so `own` comes back in layer order.
    fn gated_fetch(
        &mut self,
        meta: &Meta,
        worker: usize,
        buf: &mut ParamSet,
        last_seen: &mut [u64],
        own: &mut Vec<u64>,
        use_gate: bool,
    ) -> Result<(ReadStats, FetchStats), TransportError> {
        // shared-mode ε statistics read the clock table, which pending
        // pipelined COMMITs on other connections may still be moving
        self.settle()?;
        for (g, range) in meta.ranges.iter().enumerate() {
            let mut tx = Vec::with_capacity(9 + 4 + 8 * range.len());
            let mark = wire::begin_frame(&mut tx, op::FETCH);
            wire::put_u32(&mut tx, worker as u32);
            for l in range.clone() {
                wire::put_u64(&mut tx, if use_gate { last_seen[l] } else { u64::MAX });
            }
            wire::end_frame(&mut tx, mark);
            self.send(g, &tx)?;
        }
        let mut stats = ReadStats::default();
        let mut fs = FetchStats::default();
        own.clear();
        for (g, range) in meta.ranges.iter().enumerate() {
            self.drain(g)?;
            let f = self.recv(g)?;
            expect_op(&f, op::FETCH_OK)?;
            self.wire.fetch_bytes_received += f.payload.len() as u64 + 5;
            let mut r = wire::Reader::new(&f.payload);
            let epoch = r.u64()?;
            if epoch > self.epoch_seen {
                self.epoch_seen = epoch;
            }
            stats.guaranteed += r.u64()?;
            stats.window_included += r.u64()?;
            stats.window_missed += r.u64()?;
            for _ in range.clone() {
                own.push(r.u64()?);
            }
            for l in range.clone() {
                if r.u8()? == 1 {
                    let rev = r.u64()?;
                    let before = r.remaining();
                    let tag = if meta.codec.is_off() {
                        r.layer_into(&mut buf.layers[l])?;
                        codec::fmt::RAW
                    } else {
                        codec::read_layer_coded_into(&mut r, &mut buf.layers[l])?
                    };
                    self.wire.payload_bytes[tag as usize] +=
                        (before - r.remaining()) as u64;
                    last_seen[l] = rev;
                    if rev > self.rev_floor[l] {
                        self.rev_floor[l] = rev;
                    }
                    fs.layers_copied += 1;
                    fs.bytes_copied += buf.layers[l].n_bytes() as u64;
                } else {
                    fs.layers_skipped += 1;
                }
            }
            r.done()?;
        }
        Ok((stats, fs))
    }

    /// Gated snapshot fan-out (no worker identity, no ε statistics).
    fn gated_snapshot(
        &mut self,
        meta: &Meta,
        buf: &mut ParamSet,
        last_seen: &mut [u64],
        use_gate: bool,
    ) -> Result<FetchStats, TransportError> {
        for (g, range) in meta.ranges.iter().enumerate() {
            let mut tx = Vec::with_capacity(9 + 8 * range.len());
            let mark = wire::begin_frame(&mut tx, op::SNAPSHOT);
            for l in range.clone() {
                wire::put_u64(&mut tx, if use_gate { last_seen[l] } else { u64::MAX });
            }
            wire::end_frame(&mut tx, mark);
            self.send(g, &tx)?;
        }
        let mut fs = FetchStats::default();
        for (g, range) in meta.ranges.iter().enumerate() {
            self.drain(g)?;
            let f = self.recv(g)?;
            expect_op(&f, op::SNAP_OK)?;
            self.wire.snapshot_bytes_received += f.payload.len() as u64 + 5;
            let mut r = wire::Reader::new(&f.payload);
            for l in range.clone() {
                if r.u8()? == 1 {
                    let rev = r.u64()?;
                    let before = r.remaining();
                    let tag = if meta.codec.is_off() {
                        r.layer_into(&mut buf.layers[l])?;
                        codec::fmt::RAW
                    } else {
                        codec::read_layer_coded_into(&mut r, &mut buf.layers[l])?
                    };
                    self.wire.payload_bytes[tag as usize] +=
                        (before - r.remaining()) as u64;
                    last_seen[l] = rev;
                    if rev > self.rev_floor[l] {
                        self.rev_floor[l] = rev;
                    }
                    fs.layers_copied += 1;
                    fs.bytes_copied += buf.layers[l].n_bytes() as u64;
                } else {
                    fs.layers_skipped += 1;
                }
            }
            r.done()?;
        }
        Ok(fs)
    }

    /// EPOCH round trip: the endpoint's membership epoch + live mask
    /// (group 0 — in exclusive mode every process converges on the
    /// same answer because each observes the same heartbeat silence,
    /// and group 0 sweeps its lease table before answering).
    fn epoch_rpc(&mut self) -> Result<(u64, u64), TransportError> {
        self.settle()?;
        let f = self.rpc(0, &wire::frame(op::EPOCH, &[]))?;
        expect_op(&f, op::EPOCH_OK)?;
        let mut r = wire::Reader::new(&f.payload);
        let e = r.u64()?;
        let m = r.u64()?;
        r.done()?;
        if e > self.epoch_seen {
            self.epoch_seen = e;
        }
        if e >= self.mask_epoch {
            self.mask_epoch = e;
            self.mask = m;
        }
        Ok((e, m))
    }

    /// The cheap membership observation backing `WorkerPort::
    /// membership`: answer `(epoch, live mask)` from cache, and
    /// round-trip for a fresh mask only when an epoch piggybacked on a
    /// gated read (or a LEAVE/ADMIT reply) proved the cache stale.
    fn membership(&mut self) -> Result<(u64, u64), TransportError> {
        if self.epoch_seen > self.mask_epoch {
            self.epoch_rpc()?;
        }
        Ok((self.mask_epoch, self.mask))
    }

    /// Broadcast a membership change (LEAVE or ADMIT) — to every
    /// endpoint in exclusive mode, mirroring the COMMIT broadcast that
    /// keeps the per-process clock tables in lockstep. Both opcodes
    /// are idempotent per endpoint, so a supervised retry after a
    /// reconnect simply re-broadcasts. Returns the highest epoch any
    /// endpoint reported.
    fn member_change(
        &mut self,
        meta: &Meta,
        opcode: u8,
        worker: usize,
    ) -> Result<u64, TransportError> {
        self.settle()?;
        let bytes = wire::frame(opcode, &(worker as u32).to_le_bytes());
        let mut epoch = 0u64;
        for g in self.commit_targets(meta) {
            let f = self.rpc(g, &bytes)?;
            epoch = epoch.max(u64_reply(&f)?);
        }
        if epoch > self.epoch_seen {
            self.epoch_seen = epoch;
        }
        Ok(epoch)
    }

    // ---------------- connection supervision ----------------

    /// Run `op` under the connection supervisor. An `Io` failure
    /// triggers reconnect-and-resync of **every** endpoint (a
    /// healthy-looking sibling connection may still hold an unread
    /// reply from before the fault, so partial reconnection is
    /// unsound) with exponential backoff, then retries `op` with
    /// `resume = true` so it can skip work that landed before the
    /// fault. Non-Io failures (server rejections, protocol divergence)
    /// propagate immediately — retrying cannot help them. When the
    /// retry budget is exhausted (or zero — supervision off) the
    /// in-flight window is abandoned, so the caller observes a drained
    /// pipeline, and the original error (or a typed `Lost`) surfaces.
    fn supervised<T>(
        &mut self,
        meta: &Meta,
        mut op: impl FnMut(&mut ClientIo, bool) -> Result<T, TransportError>,
    ) -> Result<T, TransportError> {
        let mut resume = false;
        let mut attempts = 0u32;
        loop {
            let err = match op(self, resume) {
                Ok(v) => return Ok(v),
                Err(e) if e.kind != TransportErrorKind::Io => return Err(e),
                Err(e) => e,
            };
            loop {
                attempts += 1;
                if attempts > self.faults.max_retries {
                    self.abandon();
                    if self.faults.max_retries == 0 {
                        return Err(err); // supervision off: surface as-is
                    }
                    return Err(TransportError::lost(format!(
                        "retry budget exhausted after {} reconnect \
                         attempt(s): {}",
                        self.faults.max_retries, err.msg
                    )));
                }
                std::thread::sleep(self.faults.backoff(attempts));
                match self.recover(meta) {
                    Ok(()) => break,
                    Err(e) if e.kind == TransportErrorKind::Io => continue,
                    Err(e) => {
                        self.abandon();
                        return Err(e);
                    }
                }
            }
            resume = true;
        }
    }

    /// Reconnect every endpoint and make the fault invisible:
    /// re-handshake (validated against the original), probe the
    /// revision floor (detecting a server that restarted *without* its
    /// state — the one unabsorbable fault), then replay the un-landed
    /// suffix of the in-flight window in FIFO order.
    fn recover(&mut self, meta: &Meta) -> Result<(), TransportError> {
        self.recovered += 1;
        // park the in-flight window where a failed attempt cannot
        // lose it (between attempts nothing new is enqueued, so plain
        // append preserves FIFO order)
        for g in 0..self.conns.len() {
            let pending = std::mem::take(&mut self.conns[g].pending);
            self.replay[g].extend(pending);
        }
        let faults = self.faults;
        for g in 0..self.conns.len() {
            let addr = self.conns[g].addr;
            let (mut conn, hello) = handshake(&addr, &faults, meta.codec)?;
            validate_hello(meta, g, &hello)?;
            // the epoch may legitimately have moved while we were gone
            // (e.g. our own lease lapsed and we were evicted)
            if hello.epoch > self.epoch_seen {
                self.epoch_seen = hello.epoch;
            }
            if self.window.is_some() {
                let stream = conn.stream.try_clone().map_err(|e| {
                    TransportError::io(format!("clone stream (group {g}): {e}"))
                })?;
                conn.writer = Some(Writer::spawn(stream));
            }
            self.conns[g] = conn;
        }
        for g in 0..self.conns.len() {
            self.probe_gate(meta, g)?;
            self.resync_pending(meta, g)?;
        }
        Ok(())
    }

    /// Cold-restart tripwire: ask the fresh connection for every
    /// layer's revision (a gated SNAPSHOT against `last_seen = 0` —
    /// the gate copies exactly the layers whose revision differs from
    /// 0) and compare against the highest revisions this client ever
    /// saw. Within one server lifetime revisions only grow; any
    /// regression proves the tier restarted without its state, which
    /// reconnection must *not* paper over — the version-gate premise
    /// (and the clock tables) would silently break.
    fn probe_gate(&mut self, meta: &Meta, g: usize) -> Result<(), TransportError> {
        let range = meta.ranges[g].clone();
        let mut tx = Vec::with_capacity(9 + 8 * range.len());
        let mark = wire::begin_frame(&mut tx, op::SNAPSHOT);
        for _ in range.clone() {
            wire::put_u64(&mut tx, 0);
        }
        wire::end_frame(&mut tx, mark);
        let f = self.rpc(g, &tx)?;
        expect_op(&f, op::SNAP_OK)?;
        let mut r = wire::Reader::new(&f.payload);
        for l in range {
            if r.u8()? == 1 {
                let (rows, cols, blen) = meta.shapes[l];
                let rev = r.u64()?;
                // payload discarded — but decoded, under whatever
                // codec the fresh connection just re-negotiated
                if meta.codec.is_off() {
                    let _ = r.layer(rows, cols, blen)?;
                } else {
                    let mut scratch = LayerParams {
                        w: Matrix::zeros(rows, cols),
                        b: vec![0.0; blen],
                    };
                    codec::read_layer_coded_into(&mut r, &mut scratch)?;
                }
                if rev < self.rev_floor[l] {
                    return Err(TransportError::protocol(format!(
                        "layer {l} revision went backwards across the \
                         reconnect ({rev} < {}): the server restarted \
                         without its state — restart the run, or \
                         warm-restart the server from a state dump",
                        self.rev_floor[l]
                    )));
                }
                self.rev_floor[l] = rev;
            } else if self.rev_floor[l] != 0 {
                return Err(TransportError::protocol(format!(
                    "layer {l} revision reset to 0 across the reconnect \
                     (was ≥ {}): the server restarted without its state",
                    self.rev_floor[l]
                )));
            }
        }
        r.done()?;
        Ok(())
    }

    /// Replay `g`'s parked in-flight entries in FIFO order. The server
    /// applied a *prefix* of the old connection's frames (TCP), so per
    /// entry a point query decides landed-or-not: an UPDATE is landed
    /// iff its (layer, worker) applied count moved past its clock, a
    /// COMMIT iff the endpoint's clock reached its target. Entries are
    /// popped only after they are settled, so a fault mid-resync
    /// resumes exactly where it stopped.
    fn resync_pending(&mut self, meta: &Meta, g: usize) -> Result<(), TransportError> {
        while let Some(entry) = self.replay[g].front().cloned() {
            match &entry {
                Pending::Update { from, clock, layer, frame } => {
                    let landed =
                        self.applied(meta, *layer as usize, *from as usize)?;
                    if landed == *clock {
                        let frame = frame.clone();
                        let f = self.rpc(g, &frame)?;
                        expect_op(&f, op::OK)?;
                    } else if landed < *clock {
                        return Err(TransportError::protocol(format!(
                            "resync found layer {layer} at applied \
                             {landed} < in-flight clock {clock}: the \
                             server lost applied state"
                        )));
                    }
                }
                Pending::Commit { worker, expected } => {
                    let mut c = self.rpc_u64_on(g, op::CLOCK, *worker)?;
                    let bytes =
                        wire::frame(op::COMMIT, &worker.to_le_bytes());
                    while c < *expected {
                        let f = self.rpc(g, &bytes)?;
                        let got = u64_reply(&f)?;
                        if got != c + 1 {
                            return Err(TransportError::protocol(format!(
                                "resync COMMIT for worker {worker} \
                                 expected {}, got {got} (group {g})",
                                c + 1
                            )));
                        }
                        c = got;
                    }
                }
            }
            self.replay[g].pop_front();
        }
        Ok(())
    }

    /// Give up on the in-flight window and the local clock knowledge —
    /// the terminal-failure path. The pipeline reports drained
    /// (`in_flight == 0`), and any later commit on a recovered
    /// connection re-learns the server's count instead of trusting a
    /// number the lost frames may have falsified.
    fn abandon(&mut self) {
        for conn in &mut self.conns {
            conn.pending.clear();
        }
        for q in &mut self.replay {
            q.clear();
        }
        for c in &mut self.commits {
            *c = None;
        }
    }

    /// Outstanding un-acknowledged requests: the live window plus any
    /// entries parked for resync.
    fn in_flight(&self) -> usize {
        self.conns.iter().map(|c| c.pending.len()).sum::<usize>()
            + self.replay.iter().map(|q| q.len()).sum::<usize>()
    }
}

/// Build one UPDATE frame: routing header plus the layer delta under
/// `cdc` — the v4 raw layout for [`Codec::Off`], error-fed
/// quantization otherwise. Encoding happens exactly once per
/// (worker, clock, layer); the supervised retry/replay paths resend
/// the returned bytes, so the error-feedback residual advance is
/// exactly-once by construction. Byte accounting (`update_bytes_sent`,
/// `payload_bytes`) is attributed here, at encode time.
fn encode_update_frame(
    stats: &mut WireStats,
    ef: &mut codec::ErrorFeedback,
    cdc: Codec,
    from: usize,
    clock: u64,
    layer: usize,
    delta: &LayerParams,
) -> Vec<u8> {
    let mut tx = Vec::with_capacity(21 + delta.n_bytes() + 12);
    let mark = wire::begin_frame(&mut tx, op::UPDATE);
    wire::put_u32(&mut tx, from as u32);
    wire::put_u64(&mut tx, clock);
    wire::put_u32(&mut tx, layer as u32);
    let before = tx.len();
    let tag = if cdc.is_off() {
        wire::put_layer(&mut tx, delta);
        codec::fmt::RAW
    } else {
        ef.encode_delta(from, layer, delta, cdc, &mut tx)
    };
    stats.payload_bytes[tag as usize] += (tx.len() - before) as u64;
    wire::end_frame(&mut tx, mark);
    stats.update_bytes_sent += tx.len() as u64;
    tx
}

fn u64_reply(f: &Frame) -> Result<u64, TransportError> {
    expect_op(f, op::U64)?;
    let mut r = wire::Reader::new(&f.payload);
    let v = r.u64()?;
    r.done()?;
    Ok(v)
}

fn expect_op(f: &Frame, want: u8) -> Result<(), TransportError> {
    if f.op != want {
        return Err(TransportError::protocol(format!(
            "unexpected reply opcode {} (want {want})",
            f.op
        )));
    }
    Ok(())
}

/// Everything a HELLO_OK tells one connection.
struct Hello {
    workers: usize,
    n_layers: usize,
    groups: usize,
    group: usize,
    range: std::ops::Range<usize>,
    policy: Policy,
    init_digest: u64,
    exclusive: bool,
    elastic: bool,
    epoch: u64,
    /// Codec set the endpoint advertises (bit = wire tag).
    codec_mask: u8,
    /// The codec the endpoint accepted — must echo the request.
    codec: Codec,
    shapes: Vec<(usize, usize, usize)>,
}

/// The wire-v5 HELLO frame: protocol version plus the requested
/// payload codec (`tag:u8, arg:u32`; see [`Codec::wire_code`]).
fn hello_frame(codec_req: Codec) -> Vec<u8> {
    let (tag, arg) = codec_req.wire_code();
    let mut payload = Vec::with_capacity(9);
    wire::put_u32(&mut payload, wire::WIRE_VERSION);
    wire::put_u8(&mut payload, tag);
    wire::put_u32(&mut payload, arg);
    wire::frame(op::HELLO, &payload)
}

/// Decode a HELLO_OK payload (shared by the connect-time handshake and
/// [`RemoteClient::with_codec`]'s renegotiation round).
fn parse_hello(payload: &[u8]) -> Result<Hello, TransportError> {
    let mut r = wire::Reader::new(payload);
    let version = r.u32()?;
    if version != wire::WIRE_VERSION {
        return Err(TransportError::protocol(format!(
            "wire version {version} != {}",
            wire::WIRE_VERSION
        )));
    }
    let workers = r.u32()? as usize;
    let n_layers = r.u32()? as usize;
    let groups = r.u32()? as usize;
    let group = r.u32()? as usize;
    let start = r.u32()? as usize;
    let len = r.u32()? as usize;
    let tag = r.u8()?;
    let staleness = r.u64()?;
    let policy = policy_decode(tag, staleness).map_err(TransportError::protocol)?;
    let init_digest = r.u64()?;
    let exclusive = r.u8()? != 0;
    let elastic = r.u8()? != 0;
    let epoch = r.u64()?;
    let codec_mask = r.u8()?;
    let ctag = r.u8()?;
    let carg = r.u32()?;
    let codec = Codec::from_wire(ctag, carg).map_err(TransportError::protocol)?;
    let mut shapes = Vec::with_capacity(n_layers);
    for _ in 0..n_layers {
        let rows = r.u32()? as usize;
        let cols = r.u32()? as usize;
        let blen = r.u32()? as usize;
        shapes.push((rows, cols, blen));
    }
    r.done()?;
    if group >= groups || start + len > n_layers {
        return Err(TransportError::protocol(
            "inconsistent handshake geometry",
        ));
    }
    Ok(Hello {
        workers,
        n_layers,
        groups,
        group,
        range: start..start + len,
        policy,
        init_digest,
        exclusive,
        elastic,
        epoch,
        codec_mask,
        codec,
        shapes,
    })
}

/// The server must have advertised and echoed exactly the codec this
/// client requested — both sides agree before any layer bytes flow.
fn check_codec_echo(h: &Hello, requested: Codec) -> Result<(), TransportError> {
    let (tag, _) = requested.wire_code();
    if h.codec_mask & (1u8 << tag) == 0 {
        return Err(TransportError::protocol(format!(
            "server does not support codec {requested} \
             (advertised mask {:#06b})",
            h.codec_mask
        )));
    }
    if h.codec != requested {
        return Err(TransportError::protocol(format!(
            "server echoed codec {}, requested {requested}",
            h.codec
        )));
    }
    Ok(())
}

fn handshake(
    addr: &SocketAddr,
    faults: &FaultPolicy,
    codec_req: Codec,
) -> Result<(Conn, Hello), TransportError> {
    let stream = TcpStream::connect_timeout(addr, faults.connect_timeout)
        .map_err(|e| TransportError::io(format!("connect {addr}: {e}")))?;
    stream
        .set_nodelay(true)
        .map_err(|e| TransportError::io(format!("nodelay: {e}")))?;
    stream
        .set_read_timeout(faults.io_timeout)
        .map_err(|e| TransportError::io(format!("read timeout: {e}")))?;
    let mut conn = Conn {
        addr: *addr,
        stream,
        dec: FrameDecoder::default(),
        writer: None,
        pending: VecDeque::new(),
    };
    let hello = hello_frame(codec_req);
    std::io::Write::write_all(&mut conn.stream, &hello)
        .map_err(|e| TransportError::io(format!("hello: {e}")))?;
    let mut bytes_in = 0u64;
    let f = wire::read_frame(&mut conn.stream, &mut conn.dec, &mut bytes_in)
        .map_err(|e| TransportError::io(e.to_string()))?
        .ok_or_else(|| TransportError::io("server closed during handshake"))?;
    if f.op == op::ERR {
        return Err(TransportError::protocol(format!(
            "handshake rejected: {}",
            String::from_utf8_lossy(&f.payload)
        )));
    }
    expect_op(&f, op::HELLO_OK)?;
    let h = parse_hello(&f.payload)?;
    check_codec_echo(&h, codec_req)?;
    Ok((conn, h))
}

/// A reconnected endpoint must still be the same logical server: every
/// handshake fact is checked against what the original connection
/// learned. `init_digest` deliberately included — a warm-restarted
/// server advertises its configured digest (`ServiceOptions::
/// init_digest`), so a matching digest plus a non-regressed revision
/// floor is exactly "same run, state intact".
fn validate_hello(meta: &Meta, g: usize, h: &Hello) -> Result<(), TransportError> {
    if h.workers != meta.workers
        || h.n_layers != meta.n_layers
        || h.groups != meta.ranges.len()
        || h.group != g
        || h.range != meta.ranges[g]
        || h.policy != meta.policy
        || h.init_digest != meta.init_digest
        || h.exclusive != meta.exclusive
        || h.elastic != meta.elastic
        || h.codec != meta.codec
        || h.shapes != meta.shapes
    {
        return Err(TransportError::protocol(format!(
            "reconnected endpoint (group {g}) no longer matches the \
             original handshake — different server?"
        )));
    }
    Ok(())
}

/// Background heartbeat thread: renews every worker's lease on every
/// endpoint each interval over its *own* connections (HELLO +
/// HEARTBEAT only — the main connections' frame ordering, and with it
/// the pipelined window accounting, is untouched). A failed endpoint
/// is redialed next round; heartbeating is best-effort by design —
/// missing renewals is precisely how a dead client is *supposed* to
/// present to the server's lease table.
struct LeaseKeeper {
    stop: std::sync::Arc<std::sync::atomic::AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl LeaseKeeper {
    fn spawn(
        addrs: Vec<SocketAddr>,
        workers: usize,
        lease: std::time::Duration,
        every: std::time::Duration,
        faults: FaultPolicy,
    ) -> LeaseKeeper {
        use std::sync::atomic::Ordering;
        let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
        let stop2 = std::sync::Arc::clone(&stop);
        let lease_ms = lease.as_millis().max(1) as u64;
        let handle = std::thread::spawn(move || {
            let mut conns: Vec<Option<Conn>> = addrs.iter().map(|_| None).collect();
            while !stop2.load(Ordering::Relaxed) {
                for (i, addr) in addrs.iter().enumerate() {
                    if conns[i].is_none() {
                        // HELLO + HEARTBEAT only — the raw-payload
                        // codec is all these connections ever need
                        conns[i] = handshake(addr, &faults, Codec::Off)
                            .ok()
                            .map(|(c, _)| c);
                    }
                    if let Some(conn) = &mut conns[i] {
                        if heartbeat_all(conn, workers, lease_ms).is_err() {
                            conns[i] = None; // redial next round
                        }
                    }
                }
                // sliced sleep so drop() never waits a full interval
                let mut left = every;
                let slice = std::time::Duration::from_millis(25);
                while left > std::time::Duration::ZERO
                    && !stop2.load(Ordering::Relaxed)
                {
                    let d = left.min(slice);
                    std::thread::sleep(d);
                    left -= d;
                }
            }
        });
        LeaseKeeper { stop, handle: Some(handle) }
    }
}

impl Drop for LeaseKeeper {
    fn drop(&mut self) {
        self.stop.store(true, std::sync::atomic::Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// One HEARTBEAT round: renew every worker's lease on `conn`.
fn heartbeat_all(
    conn: &mut Conn,
    workers: usize,
    lease_ms: u64,
) -> Result<(), TransportError> {
    for w in 0..workers {
        let mut payload = Vec::with_capacity(12);
        wire::put_u32(&mut payload, w as u32);
        wire::put_u64(&mut payload, lease_ms);
        let tx = wire::frame(op::HEARTBEAT, &payload);
        std::io::Write::write_all(&mut conn.stream, &tx)
            .map_err(|e| TransportError::io(format!("heartbeat: {e}")))?;
        let mut bytes_in = 0u64;
        let f = wire::read_frame(&mut conn.stream, &mut conn.dec, &mut bytes_in)
            .map_err(|e| TransportError::io(e.to_string()))?
            .ok_or_else(|| {
                TransportError::io("server closed during heartbeat")
            })?;
        if f.op == op::ERR {
            return Err(TransportError::server(
                String::from_utf8_lossy(&f.payload).into_owned(),
            ));
        }
        expect_op(&f, op::OK)?;
    }
    Ok(())
}

impl RemoteClient {
    /// Lock the connection state, recovering from poisoning: transport
    /// failures panic *between* request/response cycles (never with a
    /// half-written frame buffered), so `Inner` is consistent even if a
    /// previous call panicked — e.g. after an ERR reply the connection
    /// and the caller's client remain usable.
    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner
            .lock()
            .unwrap_or_else(|poisoned| poisoned.into_inner())
    }

    /// Connect to explicit group endpoints (any order; each connection
    /// reports which group it serves). Tests pass
    /// [`ShardService::addrs`] straight through. Supervision off
    /// ([`FaultPolicy::none`]); see [`RemoteClient::connect_with`].
    pub fn connect(addrs: &[SocketAddr]) -> Result<RemoteClient, String> {
        Self::connect_with(addrs, FaultPolicy::none())
    }

    /// [`RemoteClient::connect`] under a [`FaultPolicy`]: connects are
    /// bounded, sockets get the io timeout, and every subsequent
    /// operation runs under the connection supervisor.
    pub fn connect_with(
        addrs: &[SocketAddr],
        faults: FaultPolicy,
    ) -> Result<RemoteClient, String> {
        if addrs.is_empty() {
            return Err("no endpoint addresses".into());
        }
        let mut pairs = Vec::with_capacity(addrs.len());
        for addr in addrs {
            pairs.push(
                handshake(addr, &faults, Codec::Off).map_err(String::from)?,
            );
        }
        Self::assemble(pairs, faults)
    }

    /// [`RemoteClient::connect`] from `host:port` strings — the config
    /// path for an explicit `transport.group_addrs` endpoint list (one
    /// per shard group, any order; bracketed IPv6 accepted).
    pub fn connect_hosts(addrs: &[String]) -> Result<RemoteClient, String> {
        Self::connect_hosts_with(addrs, FaultPolicy::none())
    }

    /// [`RemoteClient::connect_hosts`] under a [`FaultPolicy`].
    pub fn connect_hosts_with(
        addrs: &[String],
        faults: FaultPolicy,
    ) -> Result<RemoteClient, String> {
        let mut resolved = Vec::with_capacity(addrs.len());
        for a in addrs {
            let (host, port) = super::service::split_addr(a)?;
            resolved.push(resolve(host, port)?);
        }
        Self::connect_with(&resolved, faults)
    }

    /// Connect to a base address and discover the sibling group
    /// endpoints by the CLI port convention (group `g` on `port + g`).
    pub fn connect_base(addr: &str) -> Result<RemoteClient, String> {
        Self::connect_base_with(addr, FaultPolicy::none())
    }

    /// [`RemoteClient::connect_base`] under a [`FaultPolicy`].
    pub fn connect_base_with(
        addr: &str,
        faults: FaultPolicy,
    ) -> Result<RemoteClient, String> {
        let (host, port) = super::service::split_addr(addr)?;
        let first: SocketAddr = resolve(host, port)?;
        let (conn, hello) =
            handshake(&first, &faults, Codec::Off).map_err(String::from)?;
        let groups = hello.groups;
        if hello.group != 0 {
            return Err(format!(
                "{addr} serves group {} — point --server at group 0",
                hello.group
            ));
        }
        let mut pairs = vec![(conn, hello)];
        for g in 1..groups {
            let p = port
                .checked_add(g as u16)
                .ok_or_else(|| format!("group {g} port overflows u16"))?;
            pairs.push(
                handshake(&resolve(host, p)?, &faults, Codec::Off)
                    .map_err(String::from)?,
            );
        }
        Self::assemble(pairs, faults)
    }

    fn assemble(
        pairs: Vec<(Conn, Hello)>,
        faults: FaultPolicy,
    ) -> Result<RemoteClient, String> {
        let first = &pairs[0].1;
        let (workers, n_layers, groups, policy) =
            (first.workers, first.n_layers, first.groups, first.policy);
        let init_digest = first.init_digest;
        let exclusive = first.exclusive;
        let elastic = first.elastic;
        let epoch_seen = pairs.iter().map(|(_, h)| h.epoch).max().unwrap_or(0);
        let shapes = first.shapes.clone();
        if pairs.len() != groups {
            return Err(format!(
                "server has {groups} shard groups, connected to {}",
                pairs.len()
            ));
        }
        let mut ranges: Vec<Option<std::ops::Range<usize>>> =
            vec![None; groups];
        let mut conns: Vec<Option<Conn>> =
            pairs.iter().map(|_| None).collect();
        for (conn, h) in pairs {
            if h.workers != workers
                || h.n_layers != n_layers
                || h.groups != groups
                || h.policy != policy
                || h.init_digest != init_digest
                || h.shapes != shapes
            {
                return Err("endpoints disagree about the server".into());
            }
            if h.exclusive != exclusive {
                return Err(
                    "endpoints mix exclusive (multi-process) and shared \
                     serving modes"
                        .into(),
                );
            }
            if h.elastic != elastic {
                return Err(
                    "endpoints mix elastic and fixed-membership serving \
                     modes"
                        .into(),
                );
            }
            if ranges[h.group].is_some() {
                return Err(format!("group {} connected twice", h.group));
            }
            ranges[h.group] = Some(h.range);
            conns[h.group] = Some(conn);
        }
        let ranges: Vec<std::ops::Range<usize>> =
            ranges.into_iter().map(Option::unwrap).collect();
        let conns: Vec<Conn> = conns.into_iter().map(Option::unwrap).collect();
        // groups must tile 0..n_layers contiguously in order
        let mut next = 0;
        for r in &ranges {
            if r.start != next {
                return Err("shard groups do not tile the layers".into());
            }
            next = r.end;
        }
        if next != n_layers {
            return Err("shard groups do not cover every layer".into());
        }
        let mut layer_group = vec![0usize; n_layers];
        for (g, r) in ranges.iter().enumerate() {
            for l in r.clone() {
                layer_group[l] = g;
            }
        }
        let mirror = ParamSet {
            layers: shapes
                .iter()
                .map(|&(rows, cols, blen)| LayerParams {
                    w: Matrix::zeros(rows, cols),
                    b: vec![0.0; blen],
                })
                .collect(),
        };
        Ok(RemoteClient {
            meta: Meta {
                workers,
                n_layers,
                policy,
                shapes,
                ranges,
                layer_group,
                init_digest,
                exclusive,
                elastic,
                gated: true,
                codec: Codec::Off,
            },
            inner: Mutex::new(Inner {
                ef: codec::ErrorFeedback::new(workers, n_layers),
                io: ClientIo {
                    conns,
                    wire: WireStats::default(),
                    window: None,
                    commits: vec![None; workers],
                    faults,
                    replay: (0..groups).map(|_| VecDeque::new()).collect(),
                    rev_floor: vec![0u64; n_layers],
                    recovered: 0,
                    epoch_seen,
                    mask_epoch: 0,
                    mask: full_mask(workers),
                },
                mirror,
                mirror_seen: vec![u64::MAX; n_layers],
                reads: 0,
                copy_totals: FetchStats::default(),
            }),
            lease: None,
            chaos: Vec::new(),
            services: Vec::new(),
        })
    }

    /// Replace the connection supervisor's knobs after construction
    /// (the loopback test path: connect plain, then arm supervision).
    /// Re-arms every socket's read timeout to match.
    pub fn with_faults(mut self, faults: FaultPolicy) -> Result<RemoteClient, String> {
        let inner = self
            .inner
            .get_mut()
            .unwrap_or_else(|poisoned| poisoned.into_inner());
        inner.io.faults = faults;
        for (g, conn) in inner.io.conns.iter().enumerate() {
            conn.stream
                .set_read_timeout(faults.io_timeout)
                .map_err(|e| format!("read timeout (group {g}): {e}"))?;
        }
        Ok(self)
    }

    /// Start the background lease keeper: a dedicated thread renews
    /// every worker's lease on every endpoint each `every` interval
    /// (`every` must undercut `lease`, or the lease would lapse between
    /// renewals under zero jitter). The server side drops barrier waits
    /// for lease-expired workers — see `LeaseTable`.
    pub fn with_lease(
        mut self,
        lease: std::time::Duration,
        every: std::time::Duration,
    ) -> Result<RemoteClient, String> {
        if lease.is_zero() || every.is_zero() {
            return Err("lease and heartbeat intervals must be > 0".into());
        }
        if every >= lease {
            return Err(format!(
                "heartbeat interval {every:?} must undercut the lease \
                 {lease:?}"
            ));
        }
        let workers = self.meta.workers;
        let inner = self
            .inner
            .get_mut()
            .unwrap_or_else(|poisoned| poisoned.into_inner());
        let addrs: Vec<SocketAddr> =
            inner.io.conns.iter().map(|c| c.addr).collect();
        let faults = inner.io.faults;
        self.lease = Some(LeaseKeeper::spawn(addrs, workers, lease, every, faults));
        Ok(self)
    }

    /// One synchronous lease renewal for `worker` on every endpoint —
    /// the test/CLI path (the background keeper uses its own
    /// connections).
    pub fn heartbeat(
        &self,
        worker: usize,
        lease: std::time::Duration,
    ) -> Result<(), TransportError> {
        let lease_ms = lease.as_millis().max(1) as u64;
        let mut inner = self.lock();
        let mut payload = Vec::with_capacity(12);
        wire::put_u32(&mut payload, worker as u32);
        wire::put_u64(&mut payload, lease_ms);
        let tx = wire::frame(op::HEARTBEAT, &payload);
        for g in 0..inner.io.conns.len() {
            let f = inner.io.rpc(g, &tx)?;
            expect_op(&f, op::OK)?;
        }
        Ok(())
    }

    /// Completed reconnect-and-resync cycles since construction.
    pub fn reconnects(&self) -> u64 {
        self.lock().io.recovered
    }

    /// Outstanding un-acknowledged pipelined requests (live window +
    /// entries parked for resync). `0` after any terminal failure — the
    /// drained-window guarantee.
    pub fn in_flight(&self) -> usize {
        self.lock().io.in_flight()
    }

    /// Disable/enable on-wire version gating (config `transport.gated`;
    /// off ships every layer on every read — the bench's baseline).
    pub fn with_gate(mut self, gated: bool) -> RemoteClient {
        self.meta.gated = gated;
        self
    }

    /// Negotiate a payload codec on every connection (wire v5,
    /// config `transport.codec` / `--codec`): each endpoint gets a
    /// fresh HELLO requesting `codec` and must advertise + echo it.
    /// Call *before* [`RemoteClient::with_pipeline`] — renegotiation
    /// must not race a writer thread — and before any layer traffic
    /// that should ride the codec. [`Codec::Off`] (the default) keeps
    /// every payload bitwise-identical to wire v4; the lossy codecs
    /// error-feed the commit path (see the [`codec`] module docs) and
    /// quantize FETCH/SNAPSHOT emission densely.
    pub fn with_codec(mut self, cdc: Codec) -> Result<RemoteClient, String> {
        if cdc == self.meta.codec {
            return Ok(self);
        }
        let inner = self
            .inner
            .get_mut()
            .unwrap_or_else(|poisoned| poisoned.into_inner());
        if inner.io.window.is_some() {
            return Err(
                "negotiate the codec before enabling the pipeline".into()
            );
        }
        let hello = hello_frame(cdc);
        for g in 0..inner.io.conns.len() {
            let f = inner.io.rpc(g, &hello).map_err(String::from)?;
            if f.op != op::HELLO_OK {
                return Err(format!(
                    "codec renegotiation (group {g}): unexpected reply \
                     opcode {}",
                    f.op
                ));
            }
            let h = parse_hello(&f.payload).map_err(String::from)?;
            check_codec_echo(&h, cdc)
                .map_err(|e| format!("group {g}: {}", e.msg))?;
        }
        self.meta.codec = cdc;
        Ok(self)
    }

    /// The negotiated payload codec ([`Codec::Off`] unless
    /// [`RemoteClient::with_codec`] changed it).
    pub fn codec(&self) -> Codec {
        self.meta.codec
    }

    /// Switch commits to the pipelined path: every connection gets a
    /// dedicated writer thread, and UPDATE/COMMIT frames are enqueued
    /// with at most `window` unread acknowledgements in flight per
    /// connection (the bound keeps the unread-reply backlog finite;
    /// acknowledgements are a few bytes, so even a generous window
    /// cannot back-pressure the server's response writes). `window >=
    /// 1`. See the module docs for why the observable protocol stays
    /// bitwise identical to the synchronous path.
    pub fn with_pipeline(mut self, window: usize) -> Result<RemoteClient, String> {
        if window == 0 {
            return Err("pipeline window must be >= 1".into());
        }
        let inner = self
            .inner
            .get_mut()
            .unwrap_or_else(|poisoned| poisoned.into_inner());
        for (g, conn) in inner.io.conns.iter_mut().enumerate() {
            let stream = conn
                .stream
                .try_clone()
                .map_err(|e| format!("clone stream (group {g}): {e}"))?;
            conn.writer = Some(Writer::spawn(stream));
        }
        inner.io.window = Some(window);
        Ok(self)
    }

    /// Commits ride the pipelined (writer-thread, in-flight-window)
    /// path rather than blocking per acknowledgement.
    pub fn pipelined(&self) -> bool {
        self.lock().io.window.is_some()
    }

    /// Adopt a loopback service so it lives (and shuts down) with this
    /// client — the tests' single-process harness. May be called once
    /// per served process (the multi-process split harness owns one
    /// service per shard group).
    pub(super) fn attach_service(&mut self, svc: ShardService) {
        self.services.push(svc);
    }

    /// Adopt a fault-injection proxy so it lives (and tears down) with
    /// this client — the chaos harness (`transport::loopback_chaos`).
    pub fn attach_chaos(&mut self, proxy: super::chaos::ChaosProxy) {
        self.chaos.push(proxy);
    }

    /// The attached fault-injection proxies, if any.
    pub fn chaos_proxies(&self) -> &[super::chaos::ChaosProxy] {
        &self.chaos
    }

    /// The attached loopback services, if any.
    pub fn services(&self) -> &[ShardService] {
        &self.services
    }

    pub fn groups(&self) -> usize {
        self.meta.ranges.len()
    }

    /// Every endpoint is its own server process (see module docs).
    pub fn exclusive(&self) -> bool {
        self.meta.exclusive
    }

    /// The endpoints evict lease-expired workers and accept
    /// ADMIT/LEAVE (negotiated at the handshake).
    pub fn elastic(&self) -> bool {
        self.meta.elastic
    }

    /// Graceful departure: broadcast LEAVE for `worker` to every
    /// endpoint whose clock table it bounds. Typed-error sibling of
    /// [`ParamServer::evict_worker`]; returns the membership epoch.
    pub fn try_leave(&self, worker: usize) -> Result<u64, TransportError> {
        let meta = &self.meta;
        self.lock().io.supervised(meta, |io, _resume| {
            io.member_change(meta, op::LEAVE, worker)
        })
    }

    /// Re-admission: broadcast ADMIT for `worker`. Typed-error sibling
    /// of [`ParamServer::admit_worker`]; returns the membership epoch.
    pub fn try_admit(&self, worker: usize) -> Result<u64, TransportError> {
        let meta = &self.meta;
        self.lock().io.supervised(meta, |io, _resume| {
            io.member_change(meta, op::ADMIT, worker)
        })
    }

    /// Client-side transport accounting (frames/bytes both directions).
    pub fn wire_stats(&self) -> WireStats {
        self.lock().io.wire
    }

    /// Drain every in-flight acknowledgement (pipelined mode; a no-op
    /// when nothing is pending). Returns the first failure while still
    /// consuming every outstanding reply, so the window stays aligned
    /// and the connections stay usable after a server-side rejection.
    pub fn flush(&self) -> Result<(), TransportError> {
        let meta = &self.meta;
        self.lock().io.supervised(meta, |io, _resume| io.flush_all())
    }

    /// [`ParamServer::apply_arrival`] with a typed error instead of a
    /// panic. Synchronous mode reports a rejection immediately; in
    /// pipelined mode the frame is enqueued and a rejection surfaces at
    /// the next drain ([`RemoteClient::flush`] or any blocking read on
    /// that connection).
    pub fn try_apply_arrival(
        &self,
        msg: &UpdateMsg,
    ) -> Result<(), TransportError> {
        let meta = &self.meta;
        let mut inner = self.lock();
        let Inner { io, ef, .. } = &mut *inner;
        // encode exactly once, *outside* the supervised closure: a
        // retried attempt replays these bytes, so the error-feedback
        // residual is consumed by exactly one emitted frame
        let frame = encode_update_frame(
            &mut io.wire,
            ef,
            meta.codec,
            msg.from,
            msg.clock,
            msg.layer,
            &msg.delta,
        );
        io.supervised(meta, |io, resume| {
            io.update_frame(meta, msg.from, msg.clock, msg.layer, &frame, resume)
        })
    }

    /// [`WorkerPort::apply_commit`] with a typed error instead of a
    /// panic (same deferred-surfacing rule as
    /// [`RemoteClient::try_apply_arrival`]).
    pub fn try_apply_commit(
        &self,
        worker: usize,
        clock: u64,
        delta: &GradSet,
    ) -> Result<(), TransportError> {
        assert_eq!(delta.layers.len(), self.meta.n_layers, "commit layers");
        let meta = &self.meta;
        let mut inner = self.lock();
        let Inner { io, ef, .. } = &mut *inner;
        // encode the whole clock up front (exactly-once error
        // feedback; see `try_apply_arrival`), then move bytes under
        // supervision
        let frames: Vec<Vec<u8>> = delta
            .layers
            .iter()
            .enumerate()
            .map(|(layer, lp)| {
                encode_update_frame(
                    &mut io.wire,
                    ef,
                    meta.codec,
                    worker,
                    clock,
                    layer,
                    lp,
                )
            })
            .collect();
        io.supervised(meta, |io, resume| {
            io.commit_frames(meta, worker, clock, &frames, resume)
        })
    }

    /// Assert the remote server matches what a local run assumes —
    /// called by the `--server` driver path before training starts.
    /// Shapes, worker count and policy are all in the handshake; the
    /// init *bits* are equal by construction (both sides derive them
    /// from the config seed — `coordinator::init_params`).
    pub fn check_run(&self, init: &ParamSet, workers: usize, policy: Policy) {
        assert_eq!(
            self.meta.workers, workers,
            "remote server worker count differs from the run's"
        );
        assert_eq!(
            self.meta.policy, policy,
            "remote server policy differs from the run's"
        );
        assert_eq!(
            self.meta.n_layers,
            init.n_layers(),
            "remote server layer count differs from the run's"
        );
        for (l, lp) in init.layers.iter().enumerate() {
            assert_eq!(
                self.meta.shapes[l],
                (lp.w.rows(), lp.w.cols(), lp.b.len()),
                "remote layer {l} shape differs from the run's"
            );
        }
        assert_eq!(
            self.meta.init_digest,
            super::param_digest(init),
            "remote init digest differs from the run's: the two \
             processes derive different initial parameters (config \
             seed mismatch?) — the version gate's premise would \
             silently break"
        );
    }

    /// Block until `worker` may start its next clock — the remote
    /// sibling of `ShardedServer::wait_until_ready` (the server parks
    /// this connection on its barrier condvar; other workers' clients
    /// are unaffected because each has its own connections). In
    /// exclusive mode the wait fans out to every endpoint; any
    /// pipelined commit backlog drains first, which is exactly the
    /// "drain only when the staleness gate requires it" rule.
    pub fn wait_until_ready(&self, worker: usize) {
        self.try_wait_until_ready(worker)
            .unwrap_or_else(|e| panic!("ssp transport: {e}"));
    }

    /// [`RemoteClient::wait_until_ready`] with a typed error instead of
    /// a panic — e.g. the lease table failing the wait because a peer's
    /// lease expired surfaces as `TransportErrorKind::Server`.
    pub fn try_wait_until_ready(&self, worker: usize) -> Result<(), TransportError> {
        let meta = &self.meta;
        self.lock()
            .io
            .supervised(meta, |io, _resume| io.wait(meta, worker))
    }

    /// Version-gated evaluation snapshot — the remote sibling of
    /// `ShardedServer::snapshot_into_gated` (feeds `copy_totals`).
    pub fn snapshot_into_gated(
        &self,
        buf: &mut ParamSet,
        last_seen: &mut [u64],
    ) -> FetchStats {
        assert_eq!(buf.layers.len(), self.meta.n_layers, "snapshot buffer");
        assert_eq!(last_seen.len(), self.meta.n_layers, "snapshot last_seen");
        let meta = &self.meta;
        let mut inner = self.lock();
        let inner = &mut *inner;
        let fs = inner
            .io
            .supervised(meta, |io, _resume| {
                io.gated_snapshot(meta, buf, last_seen, meta.gated)
            })
            .unwrap_or_else(|e| panic!("ssp transport: {e}"));
        inner.copy_totals.absorb(&fs);
        fs
    }

    /// [`ParamServer::fetch_into`] with a typed error instead of a
    /// panic — the fault-injection tests' entry point.
    pub fn try_fetch_into(
        &self,
        worker: usize,
        buf: &mut ParamSet,
        last_seen: &mut [u64],
        own: &mut Vec<u64>,
    ) -> Result<(ReadStats, FetchStats), TransportError> {
        assert_eq!(buf.layers.len(), self.meta.n_layers, "fetch_into buffer");
        assert_eq!(last_seen.len(), self.meta.n_layers, "fetch_into last_seen");
        let meta = &self.meta;
        let mut inner = self.lock();
        let inner = &mut *inner;
        inner.reads += 1;
        let (stats, fs) = inner.io.supervised(meta, |io, _resume| {
            io.gated_fetch(meta, worker, buf, last_seen, own, meta.gated)
        })?;
        inner.copy_totals.absorb(&fs);
        Ok((stats, fs))
    }
}

impl Drop for RemoteClient {
    /// Flush the in-flight window before the sockets close: the last
    /// clock's pipelined UPDATEs must be applied (acknowledged) before
    /// any *other* connection — e.g. the threaded runner's final
    /// master-snapshot port — can observe the server, and dropping the
    /// worker's port is exactly the runner's ordering point for that.
    fn drop(&mut self) {
        let RemoteClient { meta, inner, .. } = self;
        let inner = inner
            .get_mut()
            .unwrap_or_else(|poisoned| poisoned.into_inner());
        let _ = inner.io.supervised(meta, |io, _resume| io.flush_all());
    }
}

impl ParamServer for RemoteClient {
    fn policy(&self) -> Policy {
        self.meta.policy
    }

    fn workers(&self) -> usize {
        self.meta.workers
    }

    fn n_layers(&self) -> usize {
        self.meta.n_layers
    }

    fn clock(&self, worker: usize) -> u64 {
        let meta = &self.meta;
        self.lock()
            .io
            .supervised(meta, |io, _resume| {
                io.rpc_u64_on(0, op::CLOCK, worker as u32)
            })
            .unwrap_or_else(|e| panic!("ssp transport: {e}"))
    }

    fn commit(&mut self, worker: usize) -> u64 {
        self.lock()
            .io
            .commit(&self.meta, worker)
            .unwrap_or_else(|e| panic!("ssp transport: {e}"))
    }

    fn apply_arrival(&mut self, msg: &UpdateMsg) {
        self.try_apply_arrival(msg)
            .unwrap_or_else(|e| panic!("ssp transport: {e}"));
    }

    fn must_wait(&self, worker: usize) -> bool {
        let meta = &self.meta;
        self.lock()
            .io
            .supervised(meta, |io, _resume| {
                io.rpc_bool_on(0, op::MUST_WAIT, worker as u32)
            })
            .unwrap_or_else(|e| panic!("ssp transport: {e}"))
    }

    fn read_ready(&self, worker: usize) -> bool {
        let meta = &self.meta;
        self.lock()
            .io
            .supervised(meta, |io, _resume| io.read_ready(meta, worker))
            .unwrap_or_else(|e| panic!("ssp transport: {e}"))
    }

    fn fetch(&mut self, worker: usize) -> (ParamSet, Vec<u64>, ReadStats) {
        let meta = &self.meta;
        let mut inner = self.lock();
        let inner = &mut *inner;
        inner.reads += 1;
        let mut own = Vec::with_capacity(meta.n_layers);
        let Inner { io, mirror, mirror_seen, .. } = &mut *inner;
        let (stats, _fs) = io
            .supervised(meta, |io, _resume| {
                io.gated_fetch(meta, worker, mirror, mirror_seen, &mut own, meta.gated)
            })
            .unwrap_or_else(|e| panic!("ssp transport: {e}"));
        (inner.mirror.clone(), own, stats)
    }

    fn fetch_into(
        &mut self,
        worker: usize,
        buf: &mut ParamSet,
        last_seen: &mut [u64],
        own: &mut Vec<u64>,
    ) -> (ReadStats, FetchStats) {
        assert_eq!(buf.layers.len(), self.meta.n_layers, "fetch_into buffer");
        assert_eq!(last_seen.len(), self.meta.n_layers, "fetch_into last_seen");
        let meta = &self.meta;
        let mut inner = self.lock();
        let inner = &mut *inner;
        inner.reads += 1;
        let (stats, fs) = inner
            .io
            .supervised(meta, |io, _resume| {
                io.gated_fetch(meta, worker, buf, last_seen, own, meta.gated)
            })
            .unwrap_or_else(|e| panic!("ssp transport: {e}"));
        inner.copy_totals.absorb(&fs);
        (stats, fs)
    }

    fn snapshot(&self) -> ParamSet {
        let meta = &self.meta;
        let mut inner = self.lock();
        let inner = &mut *inner;
        let Inner { io, mirror, mirror_seen, .. } = &mut *inner;
        io.supervised(meta, |io, _resume| {
            io.gated_snapshot(meta, mirror, mirror_seen, meta.gated)
        })
        .unwrap_or_else(|e| panic!("ssp transport: {e}"));
        inner.mirror.clone()
    }

    fn snapshot_into(&self, buf: &mut ParamSet) {
        assert_eq!(buf.layers.len(), self.meta.n_layers, "snapshot buffer");
        let meta = &self.meta;
        let mut inner = self.lock();
        let inner = &mut *inner;
        let Inner { io, mirror, mirror_seen, .. } = &mut *inner;
        io.supervised(meta, |io, _resume| {
            io.gated_snapshot(meta, mirror, mirror_seen, meta.gated)
        })
        .unwrap_or_else(|e| panic!("ssp transport: {e}"));
        buf.copy_from(&inner.mirror);
    }

    fn copy_totals(&self) -> FetchStats {
        self.lock().copy_totals
    }

    fn applied(&self, layer: usize, worker: usize) -> u64 {
        assert!(layer < self.meta.n_layers, "layer out of range");
        let meta = &self.meta;
        self.lock()
            .io
            .supervised(meta, |io, _resume| io.applied(meta, layer, worker))
            .unwrap_or_else(|e| panic!("ssp transport: {e}"))
    }

    fn reads(&self) -> u64 {
        self.lock().reads
    }

    fn membership_epoch(&self) -> u64 {
        self.lock().io.epoch_seen
    }

    fn is_live(&self, worker: usize) -> bool {
        if worker >= 64 {
            return true; // the elastic mask covers ≤ 64 workers
        }
        ParamServer::live_mask(self) & (1u64 << worker) != 0
    }

    fn live_mask(&self) -> u64 {
        let meta = &self.meta;
        if !meta.elastic {
            return full_mask(meta.workers);
        }
        self.lock()
            .io
            .supervised(meta, |io, _resume| io.epoch_rpc())
            .map(|(_, m)| m)
            .unwrap_or_else(|e| panic!("ssp transport: {e}"))
    }

    fn evict_worker(&mut self, worker: usize) -> u64 {
        let meta = &self.meta;
        self.lock()
            .io
            .supervised(meta, |io, _resume| {
                io.member_change(meta, op::LEAVE, worker)
            })
            .unwrap_or_else(|e| panic!("ssp transport: {e}"))
    }

    fn admit_worker(&mut self, worker: usize) -> u64 {
        let meta = &self.meta;
        self.lock()
            .io
            .supervised(meta, |io, _resume| {
                io.member_change(meta, op::ADMIT, worker)
            })
            .unwrap_or_else(|e| panic!("ssp transport: {e}"))
    }
}

/// The per-worker connection set as a threaded-runner port: the same
/// hot-path sequence `run_threaded` drives in shared memory, each step
/// one (batched or pipelined) message exchange.
impl WorkerPort for RemoteClient {
    fn wait_until_ready(&mut self, worker: usize) {
        RemoteClient::wait_until_ready(self, worker)
    }

    fn fetch_view(
        &mut self,
        worker: usize,
        buf: &mut ParamSet,
        last_seen: &mut [u64],
        own: &mut Vec<u64>,
    ) -> (ReadStats, FetchStats) {
        ParamServer::fetch_into(self, worker, buf, last_seen, own)
    }

    fn commit_clock(&mut self, worker: usize) -> u64 {
        ParamServer::commit(self, worker)
    }

    fn apply_commit(&mut self, worker: usize, clock: u64, delta: &GradSet) {
        self.try_apply_commit(worker, clock, delta)
            .unwrap_or_else(|e| panic!("ssp transport: {e}"));
    }

    fn snapshot_gated(
        &mut self,
        buf: &mut ParamSet,
        last_seen: &mut [u64],
    ) -> FetchStats {
        RemoteClient::snapshot_into_gated(self, buf, last_seen)
    }

    fn master_snapshot(&mut self) -> ParamSet {
        ParamServer::snapshot(self)
    }

    fn membership(&mut self) -> (u64, u64) {
        if !self.meta.elastic {
            return (0, !0u64); // fixed membership, per the trait docs
        }
        let meta = &self.meta;
        self.lock()
            .io
            .supervised(meta, |io, _resume| io.membership())
            .unwrap_or_else(|e| panic!("ssp transport: {e}"))
    }
}

fn resolve(host: &str, port: u16) -> Result<SocketAddr, String> {
    use std::net::ToSocketAddrs;
    (host, port)
        .to_socket_addrs()
        .map_err(|e| format!("resolve {host}:{port}: {e}"))?
        .next()
        .ok_or_else(|| format!("{host}:{port} resolves to nothing"))
}
