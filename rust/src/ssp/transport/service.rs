//! `ShardService` — the server side of the message boundary.
//!
//! One TCP endpoint per **shard group** (a contiguous block of layer
//! shards), all wrapping a single shared `ShardedServer`. Each accepted
//! connection is served by its own thread running a synchronous
//! request/response loop over the framed wire protocol (`wire`):
//! commits and clock-table reads are answered from the lock-free
//! tables, per-layer `UpdateMsg`s are applied under only their shard's
//! write lock, and gated FETCH/SNAPSHOT requests stream exactly the
//! layers whose revision moved past the subscriber's last-seen vector —
//! the in-process revision gate, realized as bytes *not* sent.
//!
//! The service is stateless per request (the subscriber carries its own
//! revision vector in every gated read), which is what makes worker
//! reconnects trivially sound within one server lifetime: revisions
//! only grow, so a stale vector can only cause extra copies, never a
//! wrong skip. Across server *restarts* the client must invalidate its
//! gate (`WorkerCache::reset_gate`).

use std::net::{
    IpAddr, Ipv4Addr, Ipv6Addr, SocketAddr, TcpListener, TcpStream,
};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::ssp::{Policy, ShardedServer, UpdateMsg};

use super::codec::{self, Codec};
use super::wire::{self, op, Frame, FrameDecoder, Reader};

/// Contiguous layer partition: `groups` blocks as equal as possible,
/// earlier groups taking the remainder. Clamped to `[1, n_layers]` —
/// more endpoints than layers would serve empty groups.
pub fn group_ranges(n_layers: usize, groups: usize) -> Vec<std::ops::Range<usize>> {
    assert!(n_layers > 0, "no layers to serve");
    let groups = groups.clamp(1, n_layers);
    let base = n_layers / groups;
    let rem = n_layers % groups;
    let mut out = Vec::with_capacity(groups);
    let mut start = 0;
    for g in 0..groups {
        let len = base + usize::from(g < rem);
        out.push(start..start + len);
        start += len;
    }
    debug_assert_eq!(start, n_layers);
    out
}

/// Encode a policy for the HELLO handshake.
pub(super) fn policy_code(p: Policy) -> (u8, u64) {
    match p {
        Policy::Bsp => (0, 0),
        Policy::Ssp { staleness } => (1, staleness),
        Policy::Async => (2, 0),
    }
}

/// Decode the HELLO policy code.
pub(super) fn policy_decode(tag: u8, staleness: u64) -> Result<Policy, String> {
    match tag {
        0 => Ok(Policy::Bsp),
        1 => Ok(Policy::Ssp { staleness }),
        2 => Ok(Policy::Async),
        t => Err(format!("unknown policy tag {t}")),
    }
}

/// Tunables of a running service, single-sourced from the
/// `[transport]` config section by the CLI (`TransportConfig::
/// service_options`). Everything has a safe default, so library users
/// keep calling [`ShardService::bind`] unchanged.
#[derive(Clone, Debug)]
pub struct ServiceOptions {
    /// Bound on the shutdown path's self-connect that wakes a parked
    /// accept loop (`[transport] wake_timeout_ms`).
    pub wake_timeout: std::time::Duration,
    /// Advertise this digest in HELLO_OK instead of digesting the
    /// served master at bind time. A warm-restarted shard process
    /// (`serve --state`) serves *trained* parameters, but its clients
    /// validate the config-derived **init** digest on every handshake —
    /// the restart path passes the original digest here.
    pub init_digest: Option<u64>,
    /// Elastic membership (`[transport] elastic`). When true, a lapsed
    /// worker lease **evicts** the worker from the membership
    /// (`ShardedServer::evict_worker`) instead of failing parked
    /// barrier waiters with an ERR: survivors resume behind the
    /// shrunken live set and learn the new epoch from their next gated
    /// read. Also unlocks the ADMIT/LEAVE opcodes. False preserves the
    /// fail-fast lease semantics bit for bit.
    pub elastic: bool,
}

impl Default for ServiceOptions {
    fn default() -> ServiceOptions {
        ServiceOptions {
            wake_timeout: std::time::Duration::from_millis(500),
            init_digest: None,
            elastic: false,
        }
    }
}

/// Per-worker liveness leases, granted and renewed by HEARTBEAT
/// frames. A worker that has never heartbeat holds no lease and is
/// never declared dead (pre-lease clients keep working unchanged); a
/// worker whose granted lease lapses is presumed dead, and every
/// parked barrier WAIT on this service fails with a typed ERR within
/// one poll slice instead of hanging forever on a commit that will
/// never arrive.
#[derive(Debug)]
struct LeaseTable {
    deadlines: Vec<Mutex<Option<std::time::Instant>>>,
}

impl LeaseTable {
    fn new(workers: usize) -> LeaseTable {
        LeaseTable {
            deadlines: (0..workers).map(|_| Mutex::new(None)).collect(),
        }
    }

    fn renew(&self, w: usize, lease: std::time::Duration) {
        *self.deadlines[w].lock().unwrap_or_else(|e| e.into_inner()) =
            Some(std::time::Instant::now() + lease);
    }

    /// First worker whose granted lease has lapsed, if any.
    fn expired(&self) -> Option<usize> {
        let now = std::time::Instant::now();
        self.deadlines.iter().position(|d| {
            matches!(
                *d.lock().unwrap_or_else(|e| e.into_inner()),
                Some(t) if t < now
            )
        })
    }

    /// Atomically take `w`'s lapsed deadline: true for exactly one
    /// caller per expiry — the elastic eviction's single-winner gate,
    /// so concurrent connection threads racing on the same dead worker
    /// evict (and log) it once.
    fn claim(&self, w: usize) -> bool {
        let mut d =
            self.deadlines[w].lock().unwrap_or_else(|e| e.into_inner());
        match *d {
            Some(t) if t < std::time::Instant::now() => {
                *d = None;
                true
            }
            _ => false,
        }
    }

    /// Forget `w`'s lease entirely (the admission path: a rejoiner is
    /// pre-lease again until its first HEARTBEAT re-arms liveness, so
    /// a stale deadline can't re-evict it before it ever beats).
    fn clear(&self, w: usize) {
        *self.deadlines[w].lock().unwrap_or_else(|e| e.into_inner()) = None;
    }
}

/// What a connection needs to know about its endpoint.
#[derive(Clone, Debug)]
struct EndpointInfo {
    group: usize,
    groups: usize,
    range: std::ops::Range<usize>,
    /// Digest advertised in HELLO_OK for `RemoteClient::check_run` —
    /// the served master at bind time (the init parameters), or
    /// `ServiceOptions::init_digest` on a warm restart.
    init_digest: u64,
    /// This endpoint's process hosts *only* its group's shards
    /// (`ShardService::bind_group`, one OS process per shard group):
    /// readiness answers are group-scoped and the client keeps the
    /// per-process clock tables in sync by broadcasting COMMITs.
    exclusive: bool,
    /// Worker liveness leases, shared by every endpoint of this
    /// process (a worker is alive or dead for the whole service, not
    /// per shard group).
    leases: Arc<LeaseTable>,
    /// Elastic membership: lapsed leases evict instead of erroring,
    /// and ADMIT/LEAVE are accepted (see [`ServiceOptions::elastic`]).
    elastic: bool,
}

/// A running shard service: `groups` listener threads plus one thread
/// per live connection. Dropping the service shuts it down (listeners
/// are unblocked and joined; connection threads exit when their peer
/// disconnects — drop all clients before the service).
pub struct ShardService {
    addrs: Vec<SocketAddr>,
    stop: Arc<AtomicBool>,
    opts: ServiceOptions,
    /// The served state, kept so shutdown can pulse parked barrier
    /// waiters (they re-check the stop flag immediately instead of
    /// sleeping out their current poll slice).
    servers: Vec<Arc<ShardedServer>>,
    listeners: Vec<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl ShardService {
    /// Serve `server` over TCP. `addr` is `host:port`; with port 0
    /// every group binds its own ephemeral port (tests — read the real
    /// addresses back from [`ShardService::addrs`]), otherwise group
    /// `g` listens on `port + g` (the CLI convention `RemoteClient::
    /// connect_base` assumes).
    pub fn bind(
        server: Arc<ShardedServer>,
        addr: &str,
        groups: usize,
    ) -> Result<ShardService, String> {
        ShardService::bind_with(server, addr, groups, ServiceOptions::default())
    }

    /// [`ShardService::bind`] with explicit [`ServiceOptions`].
    pub fn bind_with(
        server: Arc<ShardedServer>,
        addr: &str,
        groups: usize,
        opts: ServiceOptions,
    ) -> Result<ShardService, String> {
        let (host, port) = split_addr(addr)?;
        let ranges = group_ranges(server.n_layers(), groups);
        // the master at bind time IS the init: serve binds before any
        // worker can commit (a warm restart overrides via the options)
        let init_digest = opts
            .init_digest
            .unwrap_or_else(|| super::param_digest(&server.snapshot()));
        let elastic = opts.elastic;
        if elastic && server.workers() > 64 {
            return Err(format!(
                "elastic membership supports at most 64 workers (the \
                 wire live mask is one u64), got {}",
                server.workers()
            ));
        }
        let leases = Arc::new(LeaseTable::new(server.workers()));
        let mut svc = ShardService::empty(opts);
        for (g, range) in ranges.iter().enumerate() {
            let bind_port = if port == 0 {
                0
            } else {
                port.checked_add(g as u16)
                    .ok_or_else(|| format!("group {g} port overflows u16"))?
            };
            let info = EndpointInfo {
                group: g,
                groups: ranges.len(),
                range: range.clone(),
                init_digest,
                exclusive: false,
                leases: Arc::clone(&leases),
                elastic,
            };
            svc.listen(Arc::clone(&server), host, bind_port, info)?;
        }
        Ok(svc)
    }

    /// Serve **one** shard group of an `groups`-way partition from this
    /// process — the multi-process server tier (`sspdnn serve --group
    /// i`, one process per machine). `server` must be the *full* model
    /// built from the shared config (shapes and the init digest come
    /// from it, and they must agree across every process), but only
    /// this group's shards ever receive UPDATEs here; the endpoint
    /// answers readiness questions scoped to its own range and relies
    /// on clients broadcasting every COMMIT so its private clock table
    /// tracks its siblings'.
    pub fn bind_group(
        server: Arc<ShardedServer>,
        addr: &str,
        groups: usize,
        group: usize,
    ) -> Result<ShardService, String> {
        ShardService::bind_group_with(
            server,
            addr,
            groups,
            group,
            ServiceOptions::default(),
        )
    }

    /// [`ShardService::bind_group`] with explicit [`ServiceOptions`] —
    /// the warm-restart path passes the original init digest here so
    /// reconnecting clients still validate against their config.
    pub fn bind_group_with(
        server: Arc<ShardedServer>,
        addr: &str,
        groups: usize,
        group: usize,
        opts: ServiceOptions,
    ) -> Result<ShardService, String> {
        let (host, port) = split_addr(addr)?;
        let ranges = group_ranges(server.n_layers(), groups);
        if group >= ranges.len() {
            return Err(format!(
                "group {group} out of range: {} layer shards partition \
                 into {} group(s)",
                server.n_layers(),
                ranges.len()
            ));
        }
        let init_digest = opts
            .init_digest
            .unwrap_or_else(|| super::param_digest(&server.snapshot()));
        if opts.elastic && server.workers() > 64 {
            return Err(format!(
                "elastic membership supports at most 64 workers (the \
                 wire live mask is one u64), got {}",
                server.workers()
            ));
        }
        let info = EndpointInfo {
            group,
            groups: ranges.len(),
            range: ranges[group].clone(),
            init_digest,
            exclusive: true,
            leases: Arc::new(LeaseTable::new(server.workers())),
            elastic: opts.elastic,
        };
        let mut svc = ShardService::empty(opts);
        svc.listen(server, host, port, info)?;
        Ok(svc)
    }

    fn empty(opts: ServiceOptions) -> ShardService {
        ShardService {
            addrs: Vec::new(),
            stop: Arc::new(AtomicBool::new(false)),
            opts,
            servers: Vec::new(),
            listeners: Vec::new(),
            conns: Arc::new(Mutex::new(Vec::new())),
        }
    }

    /// Bind one endpoint and spawn its accept loop.
    fn listen(
        &mut self,
        server: Arc<ShardedServer>,
        host: &str,
        port: u16,
        info: EndpointInfo,
    ) -> Result<(), String> {
        let listener = TcpListener::bind((host, port))
            .map_err(|e| format!("bind {host}:{port}: {e}"))?;
        self.addrs.push(
            listener
                .local_addr()
                .map_err(|e| format!("local_addr: {e}"))?,
        );
        self.servers.push(Arc::clone(&server));
        let stop = Arc::clone(&self.stop);
        let conns = Arc::clone(&self.conns);
        self.listeners.push(std::thread::spawn(move || {
            for stream in listener.incoming() {
                if stop.load(Ordering::Acquire) {
                    break;
                }
                let Ok(stream) = stream else { continue };
                let _ = stream.set_nodelay(true);
                let server = Arc::clone(&server);
                let info = info.clone();
                let conn_stop = Arc::clone(&stop);
                let handle = std::thread::spawn(move || {
                    serve_conn(&server, &info, &conn_stop, stream)
                });
                // recover from poisoning: a panicked connection thread
                // must not take the accept loop (and with it the whole
                // service tier) down with it
                let mut conns = conns
                    .lock()
                    .unwrap_or_else(|e| e.into_inner());
                // reap finished connections so a long-lived serve
                // process doesn't accumulate JoinHandles forever
                conns.retain(|h| !h.is_finished());
                conns.push(handle);
            }
        }));
        Ok(())
    }

    /// The bound endpoint addresses, indexed by shard group.
    pub fn addrs(&self) -> &[SocketAddr] {
        &self.addrs
    }

    pub fn groups(&self) -> usize {
        self.addrs.len()
    }

    /// Block on the listener threads — the `serve` CLI's foreground
    /// mode (returns only after `shutdown`, i.e. effectively never).
    pub fn join(mut self) {
        for l in self.listeners.drain(..) {
            let _ = l.join();
        }
    }

    /// Stop accepting, unblock and join the listeners, then join every
    /// connection thread (their peers must have disconnected first).
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Release);
        // pulse the barrier condvars so parked WAIT handlers re-check
        // the stop flag now instead of sleeping out their poll slice
        for server in &self.servers {
            server.wake_all();
        }
        for addr in &self.addrs {
            // unblock a parked accept; the listener re-checks `stop`.
            // A wildcard bind (`0.0.0.0` / `::`) is not a connectable
            // destination on every platform, so aim the wake-up at the
            // loopback of the same family instead — and bound it, so
            // shutdown can never hang on a dead route. A failed wake is
            // a join that may hang until the next real connection, so
            // it must be visible, not swallowed.
            if let Err(e) = TcpStream::connect_timeout(
                &wake_addr(addr),
                self.opts.wake_timeout,
            ) {
                crate::warn_!(
                    "shutdown wake-up connect to {} failed ({e}); the \
                     group's listener will only exit on its next \
                     accepted connection",
                    wake_addr(addr)
                );
            }
        }
        for l in self.listeners.drain(..) {
            let _ = l.join();
        }
        // recover from poisoning (a panicked connection thread) — the
        // remaining healthy threads still deserve a join
        let handles: Vec<JoinHandle<()>> = self
            .conns
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .drain(..)
            .collect();
        for h in handles {
            let _ = h.join();
        }
    }
}

/// Where to connect to wake a listener parked in `accept` on `addr`:
/// `addr` itself for a concrete bind, the same-family loopback (same
/// port) for a wildcard bind.
fn wake_addr(addr: &SocketAddr) -> SocketAddr {
    if !addr.ip().is_unspecified() {
        return *addr;
    }
    let loopback = match addr.ip() {
        IpAddr::V4(_) => IpAddr::V4(Ipv4Addr::LOCALHOST),
        IpAddr::V6(_) => IpAddr::V6(Ipv6Addr::LOCALHOST),
    };
    SocketAddr::new(loopback, addr.port())
}

impl Drop for ShardService {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Split a `host:port` address: IPv4 / hostname form, or a bracketed
/// IPv6 literal `[::1]:7070` (the returned host has the brackets
/// stripped, which is what `ToSocketAddrs`/`TcpListener::bind` take).
/// An *unbracketed* IPv6 literal is ambiguous — every `:` is a
/// candidate split — and is rejected with the bracketed spelling in
/// the error instead of mis-parsing into a confusing connect failure.
/// The single parser shared by `TransportConfig::validate`,
/// `ShardService::bind` and `RemoteClient::connect_base` so they all
/// agree on what an address is.
pub fn split_addr(addr: &str) -> Result<(&str, u16), String> {
    if let Some(rest) = addr.strip_prefix('[') {
        let (host, port) = rest.split_once("]:").ok_or_else(|| {
            format!("address {addr:?} is not [ipv6]:port")
        })?;
        let port = port
            .parse::<u16>()
            .map_err(|_| format!("bad port in address {addr:?}"))?;
        return Ok((host, port));
    }
    let (host, port) = addr
        .rsplit_once(':')
        .ok_or_else(|| format!("address {addr:?} is not host:port"))?;
    if host.contains(':') {
        return Err(format!(
            "address {addr:?} looks like an unbracketed IPv6 literal — \
             write it as \"[{host}]:{port}\""
        ));
    }
    let port = port
        .parse::<u16>()
        .map_err(|_| format!("bad port in address {addr:?}"))?;
    Ok((host, port))
}

/// One connection's synchronous request/response loop. I/O errors and
/// torn frames drop the connection; protocol-level errors are answered
/// with an ERR frame and the connection stays up.
fn serve_conn(
    server: &ShardedServer,
    info: &EndpointInfo,
    stop: &AtomicBool,
    mut stream: TcpStream,
) {
    let mut dec = FrameDecoder::default();
    let mut out: Vec<u8> = Vec::new();
    let mut scratch: Vec<u8> = Vec::new();
    let mut bytes_in = 0u64;
    // per-connection negotiated payload codec — raw f32 until a HELLO
    // requests otherwise (re-negotiable by a later HELLO)
    let mut conn_codec = Codec::Off;
    loop {
        let frame = match wire::read_frame(&mut stream, &mut dec, &mut bytes_in) {
            Ok(Some(f)) => f,
            Ok(None) => break, // clean close
            Err(e) => {
                crate::debug!("transport conn (group {}): {e}", info.group);
                break;
            }
        };
        out.clear();
        scratch.clear();
        if let Err(msg) = handle(
            server,
            info,
            stop,
            &frame,
            &mut out,
            &mut scratch,
            &mut conn_codec,
        ) {
            out.clear();
            let mark = wire::begin_frame(&mut out, op::ERR);
            out.extend_from_slice(msg.as_bytes());
            wire::end_frame(&mut out, mark);
        }
        if std::io::Write::write_all(&mut stream, &out).is_err() {
            break;
        }
    }
}

fn check_worker(server: &ShardedServer, w: usize) -> Result<(), String> {
    if w >= server.workers() {
        return Err(format!("worker {w} >= {}", server.workers()));
    }
    Ok(())
}

/// Elastic endpoints: evict every worker whose granted lease has
/// lapsed. `LeaseTable::claim` is the single-winner gate, so however
/// many connection threads observe the same dead worker, exactly one
/// evicts it (one epoch bump, one log line). Non-elastic endpoints
/// never call this — their lapsed leases fail parked waiters instead.
fn evict_expired(server: &ShardedServer, info: &EndpointInfo) {
    debug_assert!(info.elastic);
    while let Some(q) = info.leases.expired() {
        if info.leases.claim(q) {
            let epoch = server.evict_worker(q);
            crate::warn_!(
                "worker {q} lease expired: evicted from membership \
                 (epoch {epoch}, live {:#b})",
                server.live_mask()
            );
        }
    }
}

fn handle(
    server: &ShardedServer,
    info: &EndpointInfo,
    stop: &AtomicBool,
    f: &Frame,
    out: &mut Vec<u8>,
    scratch: &mut Vec<u8>,
    conn_codec: &mut Codec,
) -> Result<(), String> {
    let mut r = Reader::new(&f.payload);
    match f.op {
        op::HELLO => {
            let ver = r.u32()?;
            let codec_tag = r.u8()?;
            let codec_arg = r.u32()?;
            r.done()?;
            if ver != wire::WIRE_VERSION {
                return Err(format!(
                    "wire version {ver} != {}",
                    wire::WIRE_VERSION
                ));
            }
            // negotiation: validate the requested codec *before*
            // adopting it — an unknown tag leaves the connection on
            // its previous codec and answers ERR
            let requested = Codec::from_wire(codec_tag, codec_arg)?;
            if requested != *conn_codec {
                *conn_codec = requested;
                if !requested.is_off() {
                    crate::warn_!(
                        "negotiated codec {requested} (group {})",
                        info.group
                    );
                }
            }
            let mark = wire::begin_frame(out, op::HELLO_OK);
            wire::put_u32(out, wire::WIRE_VERSION);
            wire::put_u32(out, server.workers() as u32);
            wire::put_u32(out, server.n_layers() as u32);
            wire::put_u32(out, info.groups as u32);
            wire::put_u32(out, info.group as u32);
            wire::put_u32(out, info.range.start as u32);
            wire::put_u32(out, info.range.len() as u32);
            let (tag, staleness) = policy_code(server.policy());
            wire::put_u8(out, tag);
            wire::put_u64(out, staleness);
            wire::put_u64(out, info.init_digest);
            wire::put_u8(out, u8::from(info.exclusive));
            wire::put_u8(out, u8::from(info.elastic));
            wire::put_u64(out, server.membership_epoch());
            // advertise the supported codec set and echo the accepted
            // request — the client verifies the echo
            wire::put_u8(out, codec::SUPPORTED_MASK);
            let (tag, arg) = conn_codec.wire_code();
            wire::put_u8(out, tag);
            wire::put_u32(out, arg);
            for l in 0..server.n_layers() {
                let (rows, cols, blen) = server.layer_shape(l);
                wire::put_u32(out, rows as u32);
                wire::put_u32(out, cols as u32);
                wire::put_u32(out, blen as u32);
            }
            wire::end_frame(out, mark);
        }
        op::CLOCK => {
            let w = r.u32()? as usize;
            r.done()?;
            check_worker(server, w)?;
            reply_u64(out, server.clocks().clock(w));
        }
        op::COMMIT => {
            let w = r.u32()? as usize;
            r.done()?;
            check_worker(server, w)?;
            reply_u64(out, server.commit(w));
        }
        op::MUST_WAIT => {
            let w = r.u32()? as usize;
            r.done()?;
            check_worker(server, w)?;
            reply_bool(out, server.must_wait(w));
        }
        op::READ_READY => {
            let w = r.u32()? as usize;
            r.done()?;
            check_worker(server, w)?;
            // an exclusive endpoint can only vouch for its own shards
            // (the others live in sibling processes); the client ANDs
            // the group-scoped answers
            let ready = if info.exclusive {
                server.read_ready_group(w, info.range.clone())
            } else {
                server.read_ready(w)
            };
            reply_bool(out, ready);
        }
        op::WAIT => {
            let w = r.u32()? as usize;
            r.done()?;
            check_worker(server, w)?;
            // park in bounded slices so a service shutdown interrupts a
            // barrier wait whose releasing commit will never arrive
            loop {
                let slice = std::time::Duration::from_millis(50);
                let ready = if info.exclusive {
                    server.wait_ready_group_timeout(
                        w,
                        info.range.clone(),
                        slice,
                    )
                } else {
                    server.wait_ready_timeout(w, slice)
                };
                if ready {
                    break;
                }
                if stop.load(Ordering::Acquire) {
                    return Err("server shutting down".into());
                }
                // a dead peer's commit never arrives. Elastic: evict it
                // — the live min recomputes over the survivors and this
                // wait resolves on its own next slice. Fail-fast: fail
                // the barrier wait (typed ERR) instead of parking
                // forever.
                if info.elastic {
                    evict_expired(server, info);
                } else if let Some(q) = info.leases.expired() {
                    return Err(format!(
                        "worker {q} lease expired: releasing worker \
                         {w}'s barrier wait (peer presumed dead)"
                    ));
                }
            }
            reply_ok(out);
        }
        op::HEARTBEAT => {
            let w = r.u32()? as usize;
            let lease_ms = r.u64()?;
            r.done()?;
            check_worker(server, w)?;
            if lease_ms == 0 {
                return Err("heartbeat lease must be > 0 ms".into());
            }
            info.leases
                .renew(w, std::time::Duration::from_millis(lease_ms));
            reply_ok(out);
        }
        op::ADMIT => {
            let w = r.u32()? as usize;
            r.done()?;
            check_worker(server, w)?;
            if !info.elastic {
                return Err(format!(
                    "ADMIT refused: endpoint (group {}) is not elastic",
                    info.group
                ));
            }
            // a lapsed deadline from the worker's previous life must
            // not re-evict it before its first new HEARTBEAT — the
            // rejoiner restarts pre-lease
            info.leases.clear(w);
            let was_live = server.is_live(w);
            let epoch = server.admit_worker(w);
            if !was_live {
                crate::warn_!(
                    "worker {w} admitted to membership (epoch {epoch}, \
                     live {:#b})",
                    server.live_mask()
                );
            }
            reply_u64(out, epoch);
        }
        op::LEAVE => {
            let w = r.u32()? as usize;
            r.done()?;
            check_worker(server, w)?;
            if !info.elastic {
                return Err(format!(
                    "LEAVE refused: endpoint (group {}) is not elastic",
                    info.group
                ));
            }
            info.leases.clear(w);
            let was_live = server.is_live(w);
            let epoch = server.evict_worker(w);
            if was_live {
                crate::warn_!(
                    "worker {w} left membership (epoch {epoch}, live \
                     {:#b})",
                    server.live_mask()
                );
            }
            reply_u64(out, epoch);
        }
        op::EPOCH => {
            r.done()?;
            if info.elastic {
                evict_expired(server, info);
            }
            let mark = wire::begin_frame(out, op::EPOCH_OK);
            wire::put_u64(out, server.membership_epoch());
            wire::put_u64(out, server.live_mask());
            wire::end_frame(out, mark);
        }
        op::APPLIED => {
            let layer = r.u32()? as usize;
            let w = r.u32()? as usize;
            r.done()?;
            check_worker(server, w)?;
            if layer >= server.n_layers() {
                return Err(format!("layer {layer} >= {}", server.n_layers()));
            }
            // only the owning process's version vector moves in
            // exclusive mode — answering for a foreign layer would be
            // silently wrong (forever zero), so refuse
            if info.exclusive && !info.range.contains(&layer) {
                return Err(format!(
                    "layer {layer} outside exclusive group {} ({:?})",
                    info.group, info.range
                ));
            }
            reply_u64(out, server.applied(layer, w));
        }
        op::UPDATE => {
            let from = r.u32()? as usize;
            let clock = r.u64()?;
            let layer = r.u32()? as usize;
            check_worker(server, from)?;
            if !info.range.contains(&layer) {
                return Err(format!(
                    "layer {layer} outside group {} ({:?})",
                    info.group, info.range
                ));
            }
            let (rows, cols, blen) = server.layer_shape(layer);
            // decode-and-widen: a coded connection ships quantized
            // (or sparse) deltas; the shard always applies f32
            let delta = if conn_codec.is_off() {
                r.layer(rows, cols, blen)?
            } else {
                codec::read_layer_coded(&mut r, rows, cols, blen)?
            };
            r.done()?;
            // FIFO pre-check so a buggy client gets an ERR reply
            // instead of panicking (and lock-poisoning) the shard
            let expect = server.applied(layer, from);
            if clock != expect {
                return Err(format!(
                    "out-of-order update: layer {layer} worker {from} \
                     expected clock {expect}, got {clock}"
                ));
            }
            server.apply_arrival(&UpdateMsg::new(from, clock, layer, delta));
            reply_ok(out);
        }
        op::FETCH => {
            let w = r.u32()? as usize;
            check_worker(server, w)?;
            let n = info.range.len();
            let mut last_seen = vec![0u64; n];
            for s in last_seen.iter_mut() {
                *s = r.u64()?;
            }
            r.done()?;
            // sweep lapsed leases first so the piggybacked epoch (and
            // the ε accounting of this very read) already reflect the
            // eviction — a fetching survivor learns of a death from the
            // read it was making anyway
            if info.elastic {
                evict_expired(server, info);
            }
            let mut own = Vec::with_capacity(n);
            let cdc = *conn_codec;
            let stats = server.fetch_group_gated(
                w,
                info.range.clone(),
                &last_seen,
                &mut own,
                |_, copied| match copied {
                    None => wire::put_u8(scratch, 0),
                    Some((rev, lp)) => {
                        wire::put_u8(scratch, 1);
                        wire::put_u64(scratch, rev);
                        if cdc.is_off() {
                            wire::put_layer(scratch, lp);
                        } else {
                            // version-gated emission: quantization is
                            // deterministic, so a gate skip still
                            // means "you hold this revision's image"
                            codec::put_layer_quantized(scratch, lp, cdc);
                        }
                    }
                },
            );
            let mark = wire::begin_frame(out, op::FETCH_OK);
            wire::put_u64(out, server.membership_epoch());
            wire::put_u64(out, stats.guaranteed);
            wire::put_u64(out, stats.window_included);
            wire::put_u64(out, stats.window_missed);
            debug_assert_eq!(own.len(), n);
            for &v in &own {
                wire::put_u64(out, v);
            }
            out.extend_from_slice(scratch);
            wire::end_frame(out, mark);
        }
        op::SNAPSHOT => {
            let n = info.range.len();
            let mut last_seen = vec![0u64; n];
            for s in last_seen.iter_mut() {
                *s = r.u64()?;
            }
            r.done()?;
            let cdc = *conn_codec;
            server.snapshot_group_gated(
                info.range.clone(),
                &last_seen,
                |_, copied| match copied {
                    None => wire::put_u8(scratch, 0),
                    Some((rev, lp)) => {
                        wire::put_u8(scratch, 1);
                        wire::put_u64(scratch, rev);
                        if cdc.is_off() {
                            wire::put_layer(scratch, lp);
                        } else {
                            codec::put_layer_quantized(scratch, lp, cdc);
                        }
                    }
                },
            );
            let mark = wire::begin_frame(out, op::SNAP_OK);
            out.extend_from_slice(scratch);
            wire::end_frame(out, mark);
        }
        other => return Err(format!("unknown opcode {other}")),
    }
    Ok(())
}

fn reply_ok(out: &mut Vec<u8>) {
    let mark = wire::begin_frame(out, op::OK);
    wire::end_frame(out, mark);
}

fn reply_u64(out: &mut Vec<u8>, v: u64) {
    let mark = wire::begin_frame(out, op::U64);
    wire::put_u64(out, v);
    wire::end_frame(out, mark);
}

fn reply_bool(out: &mut Vec<u8>, v: bool) {
    let mark = wire::begin_frame(out, op::BOOL);
    wire::put_u8(out, u8::from(v));
    wire::end_frame(out, mark);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn group_ranges_partition_contiguously() {
        assert_eq!(group_ranges(2, 1), vec![0..2]);
        assert_eq!(group_ranges(2, 2), vec![0..1, 1..2]);
        // clamped: more endpoints than layers serves no empty groups
        assert_eq!(group_ranges(2, 5), vec![0..1, 1..2]);
        assert_eq!(group_ranges(7, 3), vec![0..3, 3..5, 5..7]);
        assert_eq!(group_ranges(5, 0), vec![0..5]);
    }

    #[test]
    fn policy_codes_roundtrip() {
        for p in [
            Policy::Bsp,
            Policy::Async,
            Policy::Ssp { staleness: 0 },
            Policy::Ssp { staleness: 17 },
        ] {
            let (tag, s) = policy_code(p);
            assert_eq!(policy_decode(tag, s).unwrap(), p);
        }
        assert!(policy_decode(9, 0).is_err());
    }

    #[test]
    fn split_addr_parses() {
        assert_eq!(split_addr("127.0.0.1:0").unwrap(), ("127.0.0.1", 0));
        assert_eq!(split_addr("localhost:7070").unwrap(), ("localhost", 7070));
        assert!(split_addr("nope").is_err());
        assert!(split_addr("host:notaport").is_err());
    }

    #[test]
    fn split_addr_handles_ipv6() {
        // bracketed literals parse, brackets stripped (the form
        // ToSocketAddrs / TcpListener::bind take)
        assert_eq!(split_addr("[::1]:7070").unwrap(), ("::1", 7070));
        assert_eq!(split_addr("[::]:0").unwrap(), ("::", 0));
        assert_eq!(
            split_addr("[fe80::1]:9000").unwrap(),
            ("fe80::1", 9000)
        );
        // malformed bracket forms are rejected, not mis-split
        assert!(split_addr("[::1]7070").is_err());
        assert!(split_addr("[::1:7070").is_err());
        assert!(split_addr("[::1]:").is_err());
        assert!(split_addr("[::1]:nope").is_err());
        // an unbracketed IPv6 literal gets a clear error that names the
        // bracketed spelling instead of a confusing connect failure
        let err = split_addr("::1:7070").unwrap_err();
        assert!(err.contains("[::1]:7070"), "unhelpful error: {err}");
    }

    #[test]
    fn wake_addr_resolves_wildcard_binds_to_loopback() {
        let v4: SocketAddr = "0.0.0.0:7070".parse().unwrap();
        assert_eq!(wake_addr(&v4), "127.0.0.1:7070".parse().unwrap());
        let v6: SocketAddr = "[::]:7070".parse().unwrap();
        assert_eq!(wake_addr(&v6), "[::1]:7070".parse().unwrap());
        let concrete: SocketAddr = "10.1.2.3:7070".parse().unwrap();
        assert_eq!(wake_addr(&concrete), concrete);
    }
}
