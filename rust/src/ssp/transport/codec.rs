//! Negotiated wire payload codecs (wire v5): bf16/f16 quantized layer
//! payloads and top-k sparse delta payloads, with client-side
//! error-feedback accumulators on the commit path.
//!
//! Per Keuper & Pfreundt (1609.06870) communication volume is *the*
//! scalability ceiling for distributed DNN training; the codecs here
//! engineer that budget the way Das et al. (1602.06709) do for sync
//! SGD. `codec=off` (the default) keeps every payload raw f32 LE,
//! bitwise-identical to wire v4 — the bitwise-oracle suites run there.
//!
//! ## Negotiation
//!
//! The client *requests* a codec in HELLO (`codec:u8, codec_arg:u32`);
//! the server *advertises* its supported set as a bitmask in HELLO_OK
//! and echoes the accepted codec. An unknown tag is rejected with ERR
//! at the handshake, and the client verifies the echo matches its
//! request — both sides always agree on the connection's codec before
//! any layer bytes flow. The codec is per-connection state: a
//! reconnect re-negotiates the same codec from `Meta`.
//!
//! ## Coded layer payload
//!
//! On a `codec=off` connection a layer is exactly the v4 layout
//! (`wire::put_layer`, no prefix byte). On a coded connection every
//! layer payload carries a one-byte format tag so the *emitter* can
//! choose per frame:
//!
//! ```text
//! coded-layer := fmt:u8 | rows:u32 | cols:u32 | blen:u32 | body
//! fmt = 0 raw   body = f32 × (rows·cols + blen)       (LE bits)
//! fmt = 1 bf16  body = u16 × (rows·cols + blen)       (bf16 bits)
//! fmt = 2 f16   body = u16 × (rows·cols + blen)       (IEEE binary16)
//! fmt = 3 topk  body = count:u32 | (idx:u32, val:f32) × count
//! ```
//!
//! Top-k indexes the flattened `w‖b` vector; indices are strictly
//! ascending (decode rejects duplicates and disorder), values are
//! exact f32 copies. Entries not listed are zero — top-k is only ever
//! a *delta* encoding (UPDATE); parameter emission (FETCH/SNAPSHOT)
//! under the top-k codec uses dense bf16, because the server keeps no
//! per-subscriber residual state and a dropped parameter entry —
//! unlike a dropped delta entry — would never be corrected.
//!
//! ## Error feedback
//!
//! Quantizing deltas without memory makes the rounding error a bias
//! that accumulates in θ clock after clock. [`ErrorFeedback`] keeps a
//! per-(worker, layer) residual `r` and emits `q(r + δ)`, carrying
//! `r ← (r + δ) − widen(q(r + δ))` into the next clock. For bf16/f16
//! round-to-nearest the subtraction is exact (Sterbenz: the quantized
//! value is within a factor 2 of the accumulator), and for top-k the
//! emitted entries are exact copies (residual exactly 0 there) — so
//! per layer per clock, `emitted + residual == r + δ` bitwise, the
//! invariant the tests pin for every in-range accumulator (which
//! gradient-scale deltas always are). Quantizers clamp finite overflow
//! to the format's max finite value (never ±inf) so a clipped delta
//! leaves a finite, correcting residual; in that clamped regime the
//! emitted value is no longer within a factor 2 of the accumulator,
//! so the carried remainder is rounded rather than exact — the
//! residual keeps shrinking clock over clock until the clamp value
//! drops below the accumulator's f32 ulp (a regime only a diverged
//! run reaches), it just isn't a bitwise reconstruction there.

use crate::nn::LayerParams;
use crate::tensor::Matrix;
use crate::util::half::{
    bf16_to_f32, f16_to_f32, f32_to_bf16_finite, f32_to_f16_finite,
};

use super::wire::{self, Reader, WireError};

/// Coded-layer payload format tags (the `fmt` byte).
pub mod fmt {
    pub const RAW: u8 = 0;
    pub const BF16: u8 = 1;
    pub const F16: u8 = 2;
    pub const TOPK: u8 = 3;
}

/// Bitmask of codecs a server advertises in HELLO_OK (bit = wire tag).
/// Every endpoint in this crate supports the full set.
pub const SUPPORTED_MASK: u8 = 0b1111;

/// A negotiated payload codec. `Off` is the wire-v4 bitwise oracle;
/// the rest trade precision for bytes, with error feedback keeping the
/// quantization error out of θ.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Codec {
    /// Raw f32 LE payloads, bitwise-identical to wire v4. Default.
    Off,
    /// Dense bfloat16 payloads: 2 bytes/entry, f32's range.
    Bf16,
    /// Dense IEEE binary16 payloads: 2 bytes/entry, 8× finer mantissa
    /// than bf16 but range capped at ±65504 (clamped, error-fed).
    F16,
    /// Top-k sparse deltas: keep the `frac` largest-magnitude entries
    /// per layer (at least 1), exact f32 values + u32 indices. Falls
    /// back to dense bf16 per frame when 8k + 4 ≥ 2n, so dense layers
    /// never pay index overhead. `frac` is in parts-per-million so
    /// negotiation and `Eq` are exact.
    TopK { frac_ppm: u32 },
}

impl Codec {
    /// Parse the `--codec` / `[transport] codec` grammar:
    /// `off | bf16 | f16 | topk:<frac>` with `0 < frac <= 1`.
    pub fn parse(s: &str) -> Result<Codec, String> {
        match s {
            "off" => Ok(Codec::Off),
            "bf16" => Ok(Codec::Bf16),
            "f16" => Ok(Codec::F16),
            _ => {
                let frac = s
                    .strip_prefix("topk:")
                    .ok_or_else(|| format!(
                        "bad codec {s:?} (off|bf16|f16|topk:<frac>)"
                    ))?
                    .parse::<f64>()
                    .map_err(|_| format!("bad topk fraction in {s:?}"))?;
                if !(frac > 0.0 && frac <= 1.0) {
                    return Err(format!(
                        "topk fraction must be in (0, 1], got {frac}"
                    ));
                }
                Ok(Codec::TopK {
                    frac_ppm: (frac * 1e6).round().max(1.0) as u32,
                })
            }
        }
    }

    /// The HELLO wire encoding: `(tag, arg)`. `arg` is the top-k
    /// fraction in ppm, 0 for the argument-free codecs.
    pub fn wire_code(self) -> (u8, u32) {
        match self {
            Codec::Off => (fmt::RAW, 0),
            Codec::Bf16 => (fmt::BF16, 0),
            Codec::F16 => (fmt::F16, 0),
            Codec::TopK { frac_ppm } => (fmt::TOPK, frac_ppm),
        }
    }

    /// Decode a HELLO's requested codec; unknown tags and bad top-k
    /// arguments fail the handshake.
    pub fn from_wire(tag: u8, arg: u32) -> Result<Codec, String> {
        match tag {
            fmt::RAW => Ok(Codec::Off),
            fmt::BF16 => Ok(Codec::Bf16),
            fmt::F16 => Ok(Codec::F16),
            fmt::TOPK => {
                if arg == 0 || arg > 1_000_000 {
                    return Err(format!(
                        "topk fraction {arg} ppm out of (0, 1e6]"
                    ));
                }
                Ok(Codec::TopK { frac_ppm: arg })
            }
            t => Err(format!("unknown codec tag {t}")),
        }
    }

    pub fn is_off(self) -> bool {
        self == Codec::Off
    }
}

impl std::fmt::Display for Codec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Codec::Off => write!(f, "off"),
            Codec::Bf16 => write!(f, "bf16"),
            Codec::F16 => write!(f, "f16"),
            Codec::TopK { frac_ppm } => {
                write!(f, "topk:{}", *frac_ppm as f64 / 1e6)
            }
        }
    }
}

fn put_header(out: &mut Vec<u8>, tag: u8, lp: &LayerParams) {
    wire::put_u8(out, tag);
    wire::put_u32(out, lp.w.rows() as u32);
    wire::put_u32(out, lp.w.cols() as u32);
    wire::put_u32(out, lp.b.len() as u32);
}

fn put_dense_u16(
    out: &mut Vec<u8>,
    lp: &LayerParams,
    narrow: impl Fn(f32) -> u16,
) {
    out.reserve((lp.w.data().len() + lp.b.len()) * 2);
    for &x in lp.w.data().iter().chain(lp.b.iter()) {
        out.extend_from_slice(&narrow(x).to_le_bytes());
    }
}

/// Serialize one layer's *parameters* under `codec` — the server's
/// FETCH/SNAPSHOT emission. Dense quantization only (see module docs
/// for why top-k never rides a parameter read); returns the format tag
/// chosen. Must not be called with `Codec::Off` (raw emission keeps
/// the v4 `wire::put_layer` layout with no format byte).
pub(super) fn put_layer_quantized(
    out: &mut Vec<u8>,
    lp: &LayerParams,
    codec: Codec,
) -> u8 {
    debug_assert!(!codec.is_off());
    let tag = match codec {
        Codec::F16 => fmt::F16,
        _ => fmt::BF16,
    };
    put_header(out, tag, lp);
    match tag {
        fmt::F16 => put_dense_u16(out, lp, f32_to_f16_finite),
        _ => put_dense_u16(out, lp, f32_to_bf16_finite),
    }
    tag
}

/// Decode one coded layer (format byte + shape + body) into the
/// caller's buffer, widening to f32; returns the format tag found (the
/// client's per-codec byte accounting keys on it). Top-k zeroes the
/// buffer first — unlisted entries are zero by definition. Shape
/// mismatches, unknown format tags, out-of-range or non-ascending
/// top-k indices are wire errors.
pub(super) fn read_layer_coded_into(
    r: &mut Reader<'_>,
    lp: &mut LayerParams,
) -> Result<u8, WireError> {
    let tag = r.u8()?;
    if tag == fmt::RAW {
        r.layer_into(lp)?;
        return Ok(fmt::RAW);
    }
    let rows = r.u32()? as usize;
    let cols = r.u32()? as usize;
    let blen = r.u32()? as usize;
    if rows != lp.w.rows() || cols != lp.w.cols() || blen != lp.b.len() {
        return Err(WireError(format!(
            "coded layer shape mismatch: wire {rows}x{cols}+{blen}, \
             buffer {}x{}+{}",
            lp.w.rows(),
            lp.w.cols(),
            lp.b.len()
        )));
    }
    let wlen = rows * cols;
    let n = wlen + blen;
    match tag {
        fmt::BF16 | fmt::F16 => {
            let widen = if tag == fmt::F16 { f16_to_f32 } else { bf16_to_f32 };
            let bytes = r.bytes(n * 2)?;
            let mut chunks = bytes.chunks_exact(2);
            for d in lp.w.data_mut().iter_mut().chain(lp.b.iter_mut()) {
                let c = chunks.next().expect("sized above");
                *d = widen(u16::from_le_bytes([c[0], c[1]]));
            }
            Ok(tag)
        }
        fmt::TOPK => {
            let count = r.u32()? as usize;
            if count > n {
                return Err(WireError(format!(
                    "topk count {count} > layer size {n}"
                )));
            }
            lp.w.data_mut().fill(0.0);
            lp.b.fill(0.0);
            let mut prev: Option<u32> = None;
            for _ in 0..count {
                let idx = r.u32()?;
                let mut v = [0.0f32];
                r.f32s_into(&mut v)?;
                if let Some(p) = prev {
                    if idx <= p {
                        return Err(WireError(format!(
                            "topk indices not strictly ascending: \
                             {idx} after {p}"
                        )));
                    }
                }
                if idx as usize >= n {
                    return Err(WireError(format!(
                        "topk index {idx} >= layer size {n}"
                    )));
                }
                prev = Some(idx);
                let i = idx as usize;
                if i < wlen {
                    lp.w.data_mut()[i] = v[0];
                } else {
                    lp.b[i - wlen] = v[0];
                }
            }
            Ok(fmt::TOPK)
        }
        t => Err(WireError(format!("unknown coded-layer format {t}"))),
    }
}

/// Decode a coded layer, allocating, against an expected shape (the
/// service's UPDATE ingest path — decode-and-widen).
pub(super) fn read_layer_coded(
    r: &mut Reader<'_>,
    rows: usize,
    cols: usize,
    blen: usize,
) -> Result<LayerParams, WireError> {
    let mut lp = LayerParams {
        w: Matrix::zeros(rows, cols),
        b: vec![0.0; blen],
    };
    read_layer_coded_into(r, &mut lp)?;
    Ok(lp)
}

/// Client-side error-feedback state: one residual vector per
/// (worker, layer), plus the top-k selection scratch. All storage is
/// lazily sized on first use and reused thereafter — allocation-free
/// at steady state, per the PR 2/4 discipline.
pub(super) struct ErrorFeedback {
    /// `residuals[worker][layer]` = flattened `w‖b` residual.
    residuals: Vec<Vec<Vec<f32>>>,
    /// Accumulator scratch (`r + δ`) for the top-k path.
    acc: Vec<f32>,
    /// Index scratch for the top-k selection.
    idx: Vec<u32>,
}

impl ErrorFeedback {
    pub fn new(workers: usize, n_layers: usize) -> ErrorFeedback {
        ErrorFeedback {
            residuals: vec![vec![Vec::new(); n_layers]; workers],
            acc: Vec::new(),
            idx: Vec::new(),
        }
    }

    /// Serialize one layer's *delta* under `codec` with error feedback,
    /// appending the coded layer to `out`; returns the format tag the
    /// size heuristic chose. Must not be called with `Codec::Off`.
    pub fn encode_delta(
        &mut self,
        worker: usize,
        layer: usize,
        lp: &LayerParams,
        codec: Codec,
        out: &mut Vec<u8>,
    ) -> u8 {
        debug_assert!(!codec.is_off());
        let n = lp.w.data().len() + lp.b.len();
        let r = &mut self.residuals[worker][layer];
        if r.len() != n {
            r.resize(n, 0.0);
        }
        match codec {
            Codec::Bf16 => {
                dense_ef(out, lp, r, f32_to_bf16_finite, bf16_to_f32, fmt::BF16)
            }
            Codec::F16 => {
                dense_ef(out, lp, r, f32_to_f16_finite, f16_to_f32, fmt::F16)
            }
            Codec::TopK { frac_ppm } => {
                let k = ((n as u64 * frac_ppm as u64).div_ceil(1_000_000)
                    as usize)
                    .max(1)
                    .min(n);
                // index pairs cost 8k + a count word; dense bf16 costs
                // 2n — when sparsity can't win, don't pay for indices
                if 8 * k + 4 >= 2 * n {
                    return dense_ef(
                        out,
                        lp,
                        r,
                        f32_to_bf16_finite,
                        bf16_to_f32,
                        fmt::BF16,
                    );
                }
                self.acc.clear();
                self.acc.extend(
                    lp.w.data()
                        .iter()
                        .chain(lp.b.iter())
                        .zip(r.iter())
                        .map(|(&d, &res)| res + d),
                );
                self.idx.clear();
                self.idx.extend(0..n as u32);
                let acc = &self.acc;
                // k largest by |accumulator|, ties broken by index so
                // the selected *set* is a pure function of the values
                let ord = |&a: &u32, &b: &u32| {
                    acc[b as usize]
                        .abs()
                        .total_cmp(&acc[a as usize].abs())
                        .then(a.cmp(&b))
                };
                self.idx.select_nth_unstable_by(k - 1, ord);
                let sel = &mut self.idx[..k];
                sel.sort_unstable();
                put_header(out, fmt::TOPK, lp);
                wire::put_u32(out, k as u32);
                out.reserve(8 * k);
                for &i in sel.iter() {
                    wire::put_u32(out, i);
                    out.extend_from_slice(
                        &acc[i as usize].to_le_bytes(),
                    );
                }
                // emitted entries are exact copies: residual 0 there,
                // the full accumulator everywhere else
                r.copy_from_slice(acc);
                for &i in sel.iter() {
                    r[i as usize] = 0.0;
                }
                fmt::TOPK
            }
            Codec::Off => unreachable!("raw path never error-feeds"),
        }
    }

    /// Residual snapshot for a (worker, layer) — test/introspection
    /// hook for the error-feedback invariant.
    #[cfg(test)]
    pub fn residual(&self, worker: usize, layer: usize) -> &[f32] {
        &self.residuals[worker][layer]
    }
}

/// Dense quantize-with-feedback: emit `q(r + δ)` per entry, keep the
/// (Sterbenz-exact) remainder in `r`. Non-finite accumulators emit as
/// themselves and clear the residual — inf/NaN are carried once, not
/// compounded.
fn dense_ef(
    out: &mut Vec<u8>,
    lp: &LayerParams,
    r: &mut [f32],
    narrow: impl Fn(f32) -> u16,
    widen: impl Fn(u16) -> f32,
    tag: u8,
) -> u8 {
    put_header(out, tag, lp);
    out.reserve(r.len() * 2);
    for (&d, res) in lp.w.data().iter().chain(lp.b.iter()).zip(r.iter_mut()) {
        let acc = *res + d;
        let h = narrow(acc);
        out.extend_from_slice(&h.to_le_bytes());
        *res = if acc.is_finite() { acc - widen(h) } else { 0.0 };
    }
    tag
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn layer(rows: usize, cols: usize, blen: usize, seed: u64) -> LayerParams {
        let mut rng = Pcg64::new(seed);
        LayerParams {
            w: Matrix::from_fn(rows, cols, |_, _| {
                rng.normal_f32(0.0, 1.0)
            }),
            b: (0..blen).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
        }
    }

    fn zeros_like(lp: &LayerParams) -> LayerParams {
        LayerParams {
            w: Matrix::zeros(lp.w.rows(), lp.w.cols()),
            b: vec![0.0; lp.b.len()],
        }
    }

    #[test]
    fn parse_and_display_round_trip() {
        for s in ["off", "bf16", "f16", "topk:0.1", "topk:0.005"] {
            let c = Codec::parse(s).unwrap();
            assert_eq!(c.to_string(), s);
            let (tag, arg) = c.wire_code();
            assert_eq!(Codec::from_wire(tag, arg).unwrap(), c);
        }
        assert!(Codec::parse("topk:0").is_err());
        assert!(Codec::parse("topk:1.5").is_err());
        assert!(Codec::parse("int8").is_err());
        assert!(Codec::from_wire(9, 0).is_err());
        assert!(Codec::from_wire(fmt::TOPK, 0).is_err());
    }

    /// bf16/f16 dense payloads widen exactly: decode(encode(x)) equals
    /// the direct rounding of x, entry for entry, and a second
    /// encode of the decoded values is the identity (widen-exact).
    #[test]
    fn dense_round_trip_is_widen_exact() {
        let lp = layer(7, 5, 5, 11);
        for codec in [Codec::Bf16, Codec::F16] {
            let mut out = Vec::new();
            let tag = put_layer_quantized(&mut out, &lp, codec);
            let mut got = zeros_like(&lp);
            let mut r = Reader::new(&out);
            read_layer_coded_into(&mut r, &mut got).unwrap();
            r.done().unwrap();
            let narrow: fn(f32) -> u16 = match codec {
                Codec::F16 => f32_to_f16_finite,
                _ => f32_to_bf16_finite,
            };
            for (x, y) in lp
                .w
                .data()
                .iter()
                .chain(lp.b.iter())
                .zip(got.w.data().iter().chain(got.b.iter()))
            {
                let widen = if tag == fmt::F16 { f16_to_f32 } else { bf16_to_f32 };
                assert_eq!(*y, widen(narrow(*x)));
                // widen-exact: re-quantizing the widened value is free
                assert_eq!(narrow(*y), narrow(*x));
            }
        }
    }

    /// Top-k payloads have strictly ascending, duplicate-free indices;
    /// decode enforces it.
    #[test]
    fn topk_indices_ascending_and_deduped() {
        let lp = layer(10, 10, 10, 23);
        let mut ef = ErrorFeedback::new(1, 1);
        let mut out = Vec::new();
        let tag = ef.encode_delta(
            0,
            0,
            &lp,
            Codec::TopK { frac_ppm: 100_000 },
            &mut out,
        );
        assert_eq!(tag, fmt::TOPK);
        let mut r = Reader::new(&out);
        assert_eq!(r.u8().unwrap(), fmt::TOPK);
        for _ in 0..3 {
            r.u32().unwrap(); // shape
        }
        let count = r.u32().unwrap();
        assert_eq!(count, 11, "ceil(110 · 0.1)");
        let mut prev = None;
        for _ in 0..count {
            let idx = r.u32().unwrap();
            let mut v = [0.0f32];
            r.f32s_into(&mut v).unwrap();
            if let Some(p) = prev {
                assert!(idx > p, "ascending, deduped: {idx} after {p}");
            }
            prev = Some(idx);
        }
        r.done().unwrap();

        // decode path rejects disorder: swap two index words
        let mut torn = out.clone();
        let base = 1 + 12 + 4;
        let (i, j) = (base, base + 8);
        for b in 0..4 {
            torn.swap(i + b, j + b);
        }
        let mut got = zeros_like(&lp);
        assert!(
            read_layer_coded_into(&mut Reader::new(&torn), &mut got).is_err()
        );
    }

    /// The size heuristic: a tiny layer (or a huge fraction) makes
    /// index pairs cost more than dense bf16 — the frame falls back.
    #[test]
    fn topk_falls_back_to_dense_when_indices_cost_more() {
        let lp = layer(2, 2, 1, 5);
        let mut ef = ErrorFeedback::new(1, 1);
        let mut out = Vec::new();
        let tag = ef.encode_delta(
            0,
            0,
            &lp,
            Codec::TopK { frac_ppm: 900_000 },
            &mut out,
        );
        assert_eq!(tag, fmt::BF16, "8k+4 >= 2n must choose dense");
    }

    /// Empty and 0-dim layers encode and decode under every codec.
    #[test]
    fn empty_layers_round_trip() {
        let empty = LayerParams {
            w: Matrix::zeros(0, 0),
            b: Vec::new(),
        };
        let mut ef = ErrorFeedback::new(1, 1);
        for codec in
            [Codec::Bf16, Codec::F16, Codec::TopK { frac_ppm: 500_000 }]
        {
            let mut out = Vec::new();
            put_layer_quantized(&mut out, &empty, codec);
            let mut got = empty.clone();
            let mut r = Reader::new(&out);
            read_layer_coded_into(&mut r, &mut got).unwrap();
            r.done().unwrap();
            assert_eq!(got, empty);

            let mut out = Vec::new();
            ef.encode_delta(0, 0, &empty, codec, &mut out);
            let mut r = Reader::new(&out);
            read_layer_coded_into(&mut r, &mut got).unwrap();
            r.done().unwrap();
            assert_eq!(got, empty);
        }
    }

    /// The error-feedback invariant, per layer per clock: the widened
    /// emitted delta plus the new residual equals the accumulator
    /// (old residual + exact delta) **bitwise**, for every codec — no
    /// quantization error ever leaks out of the feedback loop.
    #[test]
    fn error_feedback_invariant_bitwise() {
        let codecs = [
            Codec::Bf16,
            Codec::F16,
            Codec::TopK { frac_ppm: 200_000 },
        ];
        for codec in codecs {
            let mut ef = ErrorFeedback::new(1, 1);
            let mut prev_residual = vec![0.0f32; 6 * 4 + 4];
            for clock in 0..8u64 {
                let delta = layer(6, 4, 4, 100 + clock);
                let mut out = Vec::new();
                ef.encode_delta(0, 0, &delta, codec, &mut out);
                let mut emitted = zeros_like(&delta);
                read_layer_coded_into(&mut Reader::new(&out), &mut emitted)
                    .unwrap();
                let res = ef.residual(0, 0);
                for (i, (&d, &r_old)) in delta
                    .w
                    .data()
                    .iter()
                    .chain(delta.b.iter())
                    .zip(prev_residual.iter())
                    .enumerate()
                {
                    let acc = r_old + d;
                    let e = if i < delta.w.data().len() {
                        emitted.w.data()[i]
                    } else {
                        emitted.b[i - delta.w.data().len()]
                    };
                    assert_eq!(
                        (e + res[i]).to_bits(),
                        acc.to_bits(),
                        "{codec:?} clock {clock} entry {i}: \
                         emitted {e} + residual {} != acc {acc}",
                        res[i]
                    );
                }
                prev_residual.copy_from_slice(ef.residual(0, 0));
            }
        }
    }

    /// Coded layers inside frames survive torn reads: a FETCH_OK-style
    /// frame holding coded payloads is fed to `FrameDecoder` in every
    /// chunking the RNG produces, and each trial decodes identically.
    #[test]
    fn coded_frames_survive_torn_reads() {
        let lp = layer(5, 3, 3, 77);
        let mut payload = Vec::new();
        put_layer_quantized(&mut payload, &lp, Codec::Bf16);
        let mut ef = ErrorFeedback::new(1, 1);
        ef.encode_delta(0, 0, &lp, Codec::TopK { frac_ppm: 100_000 }, &mut payload);
        let frame = wire::frame(wire::op::FETCH_OK, &payload);

        let mut rng = Pcg64::new(13);
        for _ in 0..50 {
            let mut dec = wire::FrameDecoder::default();
            let mut fed = 0;
            let mut got = None;
            while fed < frame.len() {
                let n = (rng.below(7) + 1).min(frame.len() - fed);
                dec.feed(&frame[fed..fed + n]);
                fed += n;
                if let Some(f) = dec.next_frame().unwrap() {
                    got = Some(f);
                }
            }
            let f = got.expect("whole frame fed");
            assert_eq!(f.payload, payload, "torn reassembly changed bytes");
            let mut r = Reader::new(&f.payload);
            let mut dense = zeros_like(&lp);
            let mut sparse = zeros_like(&lp);
            read_layer_coded_into(&mut r, &mut dense).unwrap();
            read_layer_coded_into(&mut r, &mut sparse).unwrap();
            r.done().unwrap();
        }
    }

    /// Raw passthrough: a `fmt=0` coded layer is `put_layer` behind a
    /// tag byte and decodes bitwise.
    #[test]
    fn raw_fmt_passthrough_bitwise() {
        let lp = layer(4, 6, 6, 3);
        let mut out = Vec::new();
        wire::put_u8(&mut out, fmt::RAW);
        wire::put_layer(&mut out, &lp);
        let mut got = zeros_like(&lp);
        let mut r = Reader::new(&out);
        read_layer_coded_into(&mut r, &mut got).unwrap();
        r.done().unwrap();
        assert_eq!(got, lp);
    }
}
