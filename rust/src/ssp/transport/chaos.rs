//! Deterministic fault-injection proxy — the transport's adversary.
//!
//! A [`ChaosProxy`] is a TCP relay that sits between a [`RemoteClient`]
//! (connect the client to [`ChaosProxy::addr`]) and a real
//! [`ShardService`] endpoint. The server→client direction is copied
//! verbatim; the client→server direction is *reframed*: each frame is
//! decoded with the wire [`FrameDecoder`] and re-encoded byte-
//! identically, which gives the proxy exact frame boundaries to inject
//! faults at. Faults are **scripted, not sampled**: a script is an
//! ordered list of [`ChaosEvent`]s, each matching the n-th
//! client→server frame of a given opcode (counted globally across all
//! of the proxy's connections), so a failing run replays exactly —
//! the property the fault-injection tests' bitwise pins depend on.
//! The only randomness is the torn-write prefix length when the script
//! doesn't fix it, and that is drawn from a seeded [`Pcg64`].
//!
//! Supported faults ([`ChaosAction`]): drop the connection cold
//! (`Kill`), hold a frame back (`Delay`), freeze the relay in *both*
//! directions while the sockets stay open (`Pause` — the GC-pause /
//! network-partition shape that lease expiry must catch without a
//! disconnect to tip it off), forward a frame twice and
//! then kill (`DuplicateThenKill` — exercising the server's FIFO
//! pre-check as the duplicate filter), and write only a prefix of a
//! frame's bytes before killing (`TornWriteThenKill` — the mid-frame
//! disconnect). [`ChaosProxy::retarget`] points live fault injection
//! at a *restarted* server (the warm-restart drill), and
//! [`ChaosProxy::kill_connections`] force-drops every proxied
//! connection — a whole-tier crash, from the client's point of view.
//!
//! `sspdnn chaos --listen A --target B --script S --seed N` runs the
//! same relay as a process for the CI chaos-smoke drill.

use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use super::wire::{self, FrameDecoder};
use crate::util::Pcg64;

/// What to do to the matched client→server frame (and its connection).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChaosAction {
    /// Swallow the frame and drop the connection — the request
    /// vanishes mid-flight, landed-ness unknown to the client.
    Kill,
    /// Hold the frame for the duration, then forward it intact.
    Delay(Duration),
    /// Suspend relaying in **both** directions for the duration, then
    /// resume with every frame intact — no socket is killed and no
    /// byte is lost. Unlike `Delay` (one held frame, replies still
    /// flowing), a paused connection goes silent end-to-end: requests
    /// queue, replies stall, heartbeats stop arriving. This is the
    /// stalled-process fault that only a lease — not a TCP error —
    /// can detect.
    Pause(Duration),
    /// Forward the frame twice, then drop the connection. Aimed at
    /// UPDATE: the server's FIFO pre-check rejects the duplicate with
    /// an ERR, proving at-most-once application.
    DuplicateThenKill,
    /// Forward only a prefix of the frame's bytes, then drop the
    /// connection — the torn write / mid-frame disconnect. `keep:
    /// None` draws a prefix length in `1..len` from the seeded rng.
    TornWriteThenKill { keep: Option<usize> },
}

/// One scripted fault: fire `action` on the `nth` (1-based)
/// client→server frame with opcode `op`. Counts are global across the
/// proxy's connections and never reset; events fire strictly in script
/// order (an event whose count was already passed when it becomes
/// `next` can no longer fire — order scripts the way traffic flows).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChaosEvent {
    pub op: u8,
    pub nth: u64,
    pub action: ChaosAction,
}

struct Script {
    events: Vec<ChaosEvent>,
    /// Index of the next unfired event.
    next: usize,
    /// Client→server frames seen so far, per opcode.
    counts: [u64; 256],
}

struct Shared {
    target: Mutex<SocketAddr>,
    script: Mutex<Script>,
    fired: AtomicU64,
    stop: AtomicBool,
    /// Clones of every live proxied stream, for `kill_connections`.
    conns: Mutex<Vec<TcpStream>>,
    /// Relay thread handles, joined at proxy drop.
    threads: Mutex<Vec<std::thread::JoinHandle<()>>>,
    rng: Mutex<Pcg64>,
    /// While set and in the future, both relay directions hold their
    /// next forward until this instant ([`ChaosAction::Pause`]).
    pause_until: Mutex<Option<std::time::Instant>>,
}

impl Shared {
    /// Count the frame; fire (and consume) the next scripted event if
    /// it matches.
    fn on_frame(&self, op: u8) -> Option<ChaosAction> {
        let mut s = self.script.lock().unwrap();
        s.counts[op as usize] += 1;
        let ev = *s.events.get(s.next)?;
        if ev.op == op && s.counts[op as usize] == ev.nth {
            s.next += 1;
            self.fired.fetch_add(1, Ordering::Relaxed);
            return Some(ev.action);
        }
        None
    }

    /// Begin (or extend) a relay-wide pause ending at `now + d`.
    fn pause_for(&self, d: Duration) {
        let until = std::time::Instant::now() + d;
        let mut p = self.pause_until.lock().unwrap();
        *p = Some(p.map_or(until, |t| t.max(until)));
    }

    /// Sleep out any active pause before forwarding. Relay threads call
    /// this in front of every write, so a single scripted `Pause`
    /// freezes the whole proxy — both directions, every connection.
    fn pause_gate(&self) {
        let until = *self.pause_until.lock().unwrap();
        if let Some(t) = until {
            let now = std::time::Instant::now();
            if now < t {
                std::thread::sleep(t - now);
            }
        }
    }
}

/// The proxy: listener + accept thread + two relay threads per proxied
/// connection. Dropping it kills every connection and joins everything.
pub struct ChaosProxy {
    addr: SocketAddr,
    shared: Arc<Shared>,
    accept: Option<std::thread::JoinHandle<()>>,
}

impl ChaosProxy {
    /// Listen on an ephemeral loopback port, relaying to `target`
    /// under `script`.
    pub fn spawn(
        target: SocketAddr,
        script: Vec<ChaosEvent>,
        seed: u64,
    ) -> Result<ChaosProxy, String> {
        Self::spawn_on("127.0.0.1:0", target, script, seed)
    }

    /// [`ChaosProxy::spawn`] on an explicit listen address (the CLI).
    pub fn spawn_on(
        listen: &str,
        target: SocketAddr,
        script: Vec<ChaosEvent>,
        seed: u64,
    ) -> Result<ChaosProxy, String> {
        let listener = TcpListener::bind(listen)
            .map_err(|e| format!("chaos bind {listen}: {e}"))?;
        let addr = listener
            .local_addr()
            .map_err(|e| format!("chaos local addr: {e}"))?;
        let shared = Arc::new(Shared {
            target: Mutex::new(target),
            script: Mutex::new(Script {
                events: script,
                next: 0,
                counts: [0u64; 256],
            }),
            fired: AtomicU64::new(0),
            stop: AtomicBool::new(false),
            conns: Mutex::new(Vec::new()),
            threads: Mutex::new(Vec::new()),
            rng: Mutex::new(Pcg64::new(seed)),
            pause_until: Mutex::new(None),
        });
        let shared2 = Arc::clone(&shared);
        let accept = std::thread::spawn(move || {
            for incoming in listener.incoming() {
                if shared2.stop.load(Ordering::Relaxed) {
                    break;
                }
                let client = match incoming {
                    Ok(c) => c,
                    Err(_) => continue,
                };
                let target = *shared2.target.lock().unwrap();
                let server = match TcpStream::connect_timeout(
                    &target,
                    Duration::from_secs(5),
                ) {
                    Ok(s) => s,
                    Err(_) => {
                        // no server behind the proxy right now: the
                        // client sees EOF and (if supervised) retries
                        let _ = client.shutdown(Shutdown::Both);
                        continue;
                    }
                };
                let _ = client.set_nodelay(true);
                let _ = server.set_nodelay(true);
                let (c2, s2) = match (client.try_clone(), server.try_clone())
                {
                    (Ok(c), Ok(s)) => (c, s),
                    _ => {
                        let _ = client.shutdown(Shutdown::Both);
                        let _ = server.shutdown(Shutdown::Both);
                        continue;
                    }
                };
                {
                    let mut conns = shared2.conns.lock().unwrap();
                    if let (Ok(c), Ok(s)) =
                        (client.try_clone(), server.try_clone())
                    {
                        conns.push(c);
                        conns.push(s);
                    }
                }
                let sh_a = Arc::clone(&shared2);
                let sh_b = Arc::clone(&shared2);
                let a = std::thread::spawn(move || {
                    relay_c2s(client, server, &sh_a);
                });
                let b = std::thread::spawn(move || {
                    relay_s2c(s2, c2, &sh_b);
                });
                let mut threads = shared2.threads.lock().unwrap();
                threads.push(a);
                threads.push(b);
            }
        });
        Ok(ChaosProxy { addr, shared, accept: Some(accept) })
    }

    /// Where clients connect.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Point *future* connections at a different server — the
    /// warm-restart drill (existing connections keep their old target;
    /// combine with [`ChaosProxy::kill_connections`]).
    pub fn retarget(&self, target: SocketAddr) {
        *self.shared.target.lock().unwrap() = target;
    }

    /// Force-drop every proxied connection — a whole-tier crash from
    /// the client's perspective.
    pub fn kill_connections(&self) {
        let mut conns = self.shared.conns.lock().unwrap();
        for s in conns.drain(..) {
            let _ = s.shutdown(Shutdown::Both);
        }
    }

    /// Scripted events fired so far.
    pub fn events_fired(&self) -> u64 {
        self.shared.fired.load(Ordering::Relaxed)
    }
}

impl Drop for ChaosProxy {
    fn drop(&mut self) {
        self.shared.stop.store(true, Ordering::Relaxed);
        self.kill_connections();
        // wake the accept loop (same pattern as ShardService::shutdown)
        let wake = SocketAddr::new(
            std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST),
            self.addr.port(),
        );
        let _ =
            TcpStream::connect_timeout(&wake, Duration::from_millis(500));
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let handles: Vec<_> =
            self.shared.threads.lock().unwrap().drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
    }
}

/// Client→server relay: decode frames, consult the script, re-encode
/// byte-identically (`len | op | payload` is a deterministic layout).
fn relay_c2s(mut client: TcpStream, mut server: TcpStream, shared: &Shared) {
    let mut dec = FrameDecoder::default();
    let mut bytes_in = 0u64;
    loop {
        let frame =
            match wire::read_frame(&mut client, &mut dec, &mut bytes_in) {
                Ok(Some(f)) => f,
                Ok(None) | Err(_) => break, // client done or undecodable
            };
        let bytes = wire::frame(frame.op, &frame.payload);
        match shared.on_frame(frame.op) {
            None => {
                shared.pause_gate();
                if server.write_all(&bytes).is_err() {
                    break;
                }
            }
            Some(ChaosAction::Delay(d)) => {
                std::thread::sleep(d);
                if server.write_all(&bytes).is_err() {
                    break;
                }
            }
            Some(ChaosAction::Pause(d)) => {
                shared.pause_for(d);
                shared.pause_gate();
                if server.write_all(&bytes).is_err() {
                    break;
                }
            }
            Some(ChaosAction::Kill) => break,
            Some(ChaosAction::DuplicateThenKill) => {
                let _ = server.write_all(&bytes);
                let _ = server.write_all(&bytes);
                let _ = server.flush();
                // give the duplicate a moment to be *processed* before
                // the teardown races it through the kernel buffers
                std::thread::sleep(Duration::from_millis(20));
                break;
            }
            Some(ChaosAction::TornWriteThenKill { keep }) => {
                let k = match keep {
                    Some(k) => k.min(bytes.len().saturating_sub(1)).max(1),
                    None => {
                        let mut rng = shared.rng.lock().unwrap();
                        1 + rng.below(bytes.len().saturating_sub(1).max(1))
                    }
                };
                let _ = server.write_all(&bytes[..k]);
                let _ = server.flush();
                break;
            }
        }
    }
    let _ = client.shutdown(Shutdown::Both);
    let _ = server.shutdown(Shutdown::Both);
}

/// Server→client relay: a raw byte copy (faults are injected on the
/// request path only — replies either arrive intact or the connection
/// is already dead), except that an active [`ChaosAction::Pause`]
/// holds replies too, so a paused client really hears nothing.
fn relay_s2c(mut server: TcpStream, mut client: TcpStream, shared: &Shared) {
    let mut buf = [0u8; 4096];
    loop {
        match server.read(&mut buf) {
            Ok(0) | Err(_) => break,
            Ok(n) => {
                shared.pause_gate();
                if client.write_all(&buf[..n]).is_err() {
                    break;
                }
            }
        }
    }
    let _ = server.shutdown(Shutdown::Both);
    let _ = client.shutdown(Shutdown::Both);
}

/// Parse a fault script: events separated by `;` or `,`, each
/// `action[:arg]@opname:n` — e.g. `kill@update:7`, `delay:50@fetch:2`
/// (ms), `pause:400@heartbeat:3` (freeze both directions 400 ms),
/// `dup@update:5`, `torn@fetch:1`, `torn:9@update:3` (keep 9
/// bytes). Opnames: hello, clock, commit, must_wait, read_ready, wait,
/// update, fetch, snapshot, applied, heartbeat, admit, leave, epoch.
pub fn parse_script(s: &str) -> Result<Vec<ChaosEvent>, String> {
    let mut events = Vec::new();
    for part in s.split(|c| c == ';' || c == ',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (action_s, at) = part
            .split_once('@')
            .ok_or_else(|| format!("chaos event `{part}`: missing `@`"))?;
        let (op_s, nth_s) = at.split_once(':').ok_or_else(|| {
            format!("chaos event `{part}`: missing `:n` after opname")
        })?;
        let op = opcode(op_s.trim())?;
        let nth: u64 = nth_s
            .trim()
            .parse()
            .map_err(|_| format!("chaos event `{part}`: bad count"))?;
        if nth == 0 {
            return Err(format!("chaos event `{part}`: count is 1-based"));
        }
        let (name, arg) = match action_s.split_once(':') {
            Some((n, a)) => (n.trim(), Some(a.trim())),
            None => (action_s.trim(), None),
        };
        let action = match (name, arg) {
            ("kill", None) => ChaosAction::Kill,
            ("delay", Some(ms)) => {
                let ms: u64 = ms.parse().map_err(|_| {
                    format!("chaos event `{part}`: bad delay ms")
                })?;
                ChaosAction::Delay(Duration::from_millis(ms))
            }
            ("pause", Some(ms)) => {
                let ms: u64 = ms.parse().map_err(|_| {
                    format!("chaos event `{part}`: bad pause ms")
                })?;
                ChaosAction::Pause(Duration::from_millis(ms))
            }
            ("dup", None) => ChaosAction::DuplicateThenKill,
            ("torn", None) => ChaosAction::TornWriteThenKill { keep: None },
            ("torn", Some(k)) => {
                let k: usize = k.parse().map_err(|_| {
                    format!("chaos event `{part}`: bad torn prefix")
                })?;
                if k == 0 {
                    return Err(format!(
                        "chaos event `{part}`: torn prefix must be >= 1"
                    ));
                }
                ChaosAction::TornWriteThenKill { keep: Some(k) }
            }
            _ => {
                return Err(format!(
                    "chaos event `{part}`: unknown action `{action_s}` \
                     (kill, delay:<ms>, pause:<ms>, dup, torn[:bytes])"
                ))
            }
        };
        events.push(ChaosEvent { op, nth, action });
    }
    if events.is_empty() {
        return Err("empty chaos script".into());
    }
    Ok(events)
}

fn opcode(name: &str) -> Result<u8, String> {
    use super::wire::op;
    Ok(match name {
        "hello" => op::HELLO,
        "clock" => op::CLOCK,
        "commit" => op::COMMIT,
        "must_wait" => op::MUST_WAIT,
        "read_ready" => op::READ_READY,
        "wait" => op::WAIT,
        "update" => op::UPDATE,
        "fetch" => op::FETCH,
        "snapshot" => op::SNAPSHOT,
        "applied" => op::APPLIED,
        "heartbeat" => op::HEARTBEAT,
        "admit" => op::ADMIT,
        "leave" => op::LEAVE,
        "epoch" => op::EPOCH,
        _ => return Err(format!("unknown opcode name `{name}`")),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ssp::transport::wire::op;

    #[test]
    fn script_grammar_round_trips() {
        let evs = parse_script(
            "kill@update:7; delay:50@fetch:2, dup@update:9; \
             torn@commit:1; torn:9@update:3; pause:400@heartbeat:3",
        )
        .unwrap();
        assert_eq!(
            evs,
            vec![
                ChaosEvent { op: op::UPDATE, nth: 7, action: ChaosAction::Kill },
                ChaosEvent {
                    op: op::FETCH,
                    nth: 2,
                    action: ChaosAction::Delay(Duration::from_millis(50)),
                },
                ChaosEvent {
                    op: op::UPDATE,
                    nth: 9,
                    action: ChaosAction::DuplicateThenKill,
                },
                ChaosEvent {
                    op: op::COMMIT,
                    nth: 1,
                    action: ChaosAction::TornWriteThenKill { keep: None },
                },
                ChaosEvent {
                    op: op::UPDATE,
                    nth: 3,
                    action: ChaosAction::TornWriteThenKill { keep: Some(9) },
                },
                ChaosEvent {
                    op: op::HEARTBEAT,
                    nth: 3,
                    action: ChaosAction::Pause(Duration::from_millis(400)),
                },
            ]
        );
    }

    #[test]
    fn script_grammar_rejects_garbage() {
        assert!(parse_script("").is_err());
        assert!(parse_script("kill@update").is_err(), "missing count");
        assert!(parse_script("kill@update:0").is_err(), "0 is not 1-based");
        assert!(parse_script("kill@nosuch:1").is_err(), "unknown opcode");
        assert!(parse_script("explode@update:1").is_err(), "unknown action");
        assert!(parse_script("delay@update:1").is_err(), "delay needs ms");
        assert!(parse_script("pause@update:1").is_err(), "pause needs ms");
        assert!(parse_script("torn:0@update:1").is_err(), "empty prefix");
        assert!(parse_script("update:3").is_err(), "missing @");
    }

    #[test]
    fn events_fire_in_script_order_with_global_counts() {
        let shared = Shared {
            target: Mutex::new("127.0.0.1:1".parse().unwrap()),
            script: Mutex::new(Script {
                events: parse_script("kill@update:2;kill@commit:2").unwrap(),
                next: 0,
                counts: [0u64; 256],
            }),
            fired: AtomicU64::new(0),
            stop: AtomicBool::new(false),
            conns: Mutex::new(Vec::new()),
            threads: Mutex::new(Vec::new()),
            rng: Mutex::new(Pcg64::new(7)),
            pause_until: Mutex::new(None),
        };
        // commit #1 passes while the update event is still pending
        assert_eq!(shared.on_frame(op::COMMIT), None);
        assert_eq!(shared.on_frame(op::UPDATE), None);
        assert_eq!(shared.on_frame(op::UPDATE), Some(ChaosAction::Kill));
        // now the commit event is next; its count is already 1
        assert_eq!(shared.on_frame(op::UPDATE), None, "script advanced past");
        assert_eq!(shared.on_frame(op::COMMIT), Some(ChaosAction::Kill));
        assert_eq!(shared.fired.load(Ordering::Relaxed), 2);
    }
}
