//! Multi-process SSP transport: a real message boundary at the shard
//! seam.
//!
//! PR 1 sharded the parameter server per layer; each shard is an
//! independently-consistent unit (own lock, own version vector, own
//! slice of the clock-table protocol). This module puts a network
//! endpoint exactly there:
//!
//! * [`wire`] — the framed little-endian binary protocol (length
//!   prefix, one opcode byte, fixed layouts; documented in
//!   `rust/EXPERIMENTS.md` §Transport) and the incremental
//!   [`wire::FrameDecoder`] that survives arbitrarily torn reads.
//! * [`ShardService`] — one TCP endpoint per **shard group** over a
//!   shared [`ShardedServer`](crate::ssp::ShardedServer): per-layer
//!   `UpdateMsg` commits, clock-table advances, barrier waits, and
//!   **version-gated delta fetches** — the endpoint skips unchanged
//!   layers for each subscriber the same way the in-process revision
//!   gate skips copying them, except here the skip is payload bytes
//!   that never touch the wire.
//! * [`RemoteClient`] — the full `ssp::ParamServer` implementation over
//!   those endpoints (plus `ssp::WorkerPort` for the threaded runner),
//!   so the discrete-event driver, the sweep harness and the P1–P5
//!   property suite run against a remote server unchanged, bitwise
//!   equal to the in-process backings on any fixed schedule.
//!
//! Two deployment shapes:
//!
//! * **Shared tier** — `sspdnn serve` hosts a config's whole server in
//!   one process; every shard-group endpoint wraps the same
//!   [`ShardedServer`](crate::ssp::ShardedServer).
//! * **Exclusive tier** — one `sspdnn serve --group <i>` *process per
//!   shard group* ([`ShardService::bind_group`]): each process owns a
//!   private clock/version table for its shards, and the client keeps
//!   the tables identical by broadcasting COMMITs and fanning the
//!   barrier/readiness queries out (the cross-group barrier protocol —
//!   see the [`client`] docs for why the answers compose exactly).
//!
//! Orthogonally, [`RemoteClient::with_pipeline`] switches commits from
//! blocking request/response to a per-connection writer thread with a
//! bounded in-flight acknowledgement window — communication hiding
//! that leaves the observable protocol bitwise identical.
//!
//! `sspdnn train --server host:port` drives either tier; the
//! `[transport]` TOML table / CLI flags pick addresses, shard group
//! count, gating and pipelining. Tests and benches run the same stacks
//! over loopback in-process via [`loopback`] / [`loopback_split`].

pub mod chaos;
mod client;
pub mod codec;
mod service;
pub mod wire;

use std::sync::Arc;

use crate::nn::ParamSet;

use super::{Policy, ShardedServer};

pub use chaos::{ChaosAction, ChaosEvent, ChaosProxy};
pub use client::{
    FaultPolicy, RemoteClient, TransportError, TransportErrorKind, WireStats,
};
pub use codec::Codec;
pub use service::{group_ranges, split_addr, ServiceOptions, ShardService};

/// Order-sensitive FNV-1a digest over every parameter's f32 bit
/// pattern. The HELLO handshake carries the served master's digest *at
/// bind time* (i.e. of the initial parameters), and
/// `RemoteClient::check_run` compares it against the worker's locally
/// derived init — so a `serve`/`train` config-seed mismatch fails
/// loudly at connect instead of silently breaking the version gate's
/// premise that the worker's initial buffer holds the master at
/// revision 0.
pub fn param_digest(ps: &ParamSet) -> u64 {
    const PRIME: u64 = 0x100000001b3;
    let mut h: u64 = 0xcbf29ce484222325;
    for lp in &ps.layers {
        for &x in lp.w.data() {
            for b in x.to_le_bytes() {
                h ^= u64::from(b);
                h = h.wrapping_mul(PRIME);
            }
        }
        for &x in &lp.b {
            for b in x.to_le_bytes() {
                h ^= u64::from(b);
                h = h.wrapping_mul(PRIME);
            }
        }
    }
    h
}

/// Single-process harness: host `server` on ephemeral loopback
/// endpoints and hand back a connected client that owns the service
/// (dropping the client tears both down). The tests', benches' and
/// property suite's way of standing up the full TCP stack.
pub fn serve_local(
    server: Arc<ShardedServer>,
    groups: usize,
) -> RemoteClient {
    let svc = ShardService::bind(server, "127.0.0.1:0", groups)
        .expect("bind loopback shard service");
    let mut client =
        RemoteClient::connect(svc.addrs()).expect("connect loopback client");
    client.attach_service(svc);
    client
}

/// [`loopback`] with elastic membership on ([`ServiceOptions::elastic`]):
/// the endpoints accept ADMIT/LEAVE, answer EPOCH, and evict
/// lease-expired workers instead of failing their barrier waiters.
pub fn loopback_elastic(
    init: ParamSet,
    workers: usize,
    policy: Policy,
    groups: usize,
) -> RemoteClient {
    let server = Arc::new(ShardedServer::new(init, workers, policy));
    let svc = ShardService::bind_with(
        server,
        "127.0.0.1:0",
        groups,
        ServiceOptions { elastic: true, ..ServiceOptions::default() },
    )
    .expect("bind elastic shard service");
    let mut client =
        RemoteClient::connect(svc.addrs()).expect("connect elastic client");
    client.attach_service(svc);
    client
}

/// [`serve_split`] with elastic membership on: every per-group process
/// evicts and admits independently off the same LEAVE/ADMIT broadcast
/// (and the same heartbeat silence), so the private membership views
/// stay in lockstep the same way the private clock tables do.
pub fn loopback_split_elastic(
    init: ParamSet,
    workers: usize,
    policy: Policy,
    groups: usize,
    window: Option<usize>,
) -> RemoteClient {
    let n_groups = group_ranges(init.n_layers(), groups).len();
    let mut services = Vec::with_capacity(n_groups);
    let mut addrs = Vec::with_capacity(n_groups);
    for g in 0..n_groups {
        let server =
            Arc::new(ShardedServer::new(init.clone(), workers, policy));
        let svc = ShardService::bind_group_with(
            server,
            "127.0.0.1:0",
            groups,
            g,
            ServiceOptions { elastic: true, ..ServiceOptions::default() },
        )
        .expect("bind exclusive elastic shard service");
        addrs.extend_from_slice(svc.addrs());
        services.push(svc);
    }
    let mut client =
        RemoteClient::connect(&addrs).expect("connect split elastic client");
    if let Some(w) = window {
        client = client.with_pipeline(w).expect("enable pipeline");
    }
    for svc in services {
        client.attach_service(svc);
    }
    client
}

/// [`serve_local`] plus the server construction — signature-compatible
/// with the `make_server` constructors the property suite and
/// `run_experiment_with` take, so a remote backing is one closure away:
/// `|i, w, p| transport::loopback(i, w, p, groups)`.
pub fn loopback(
    init: ParamSet,
    workers: usize,
    policy: Policy,
    groups: usize,
) -> RemoteClient {
    serve_local(Arc::new(ShardedServer::new(init, workers, policy)), groups)
}

/// [`loopback`] with a negotiated payload codec: the client re-HELLOs
/// every endpoint requesting `codec` before any layer bytes flow —
/// the convergence-equivalence and byte-accounting tests' harness.
/// `Codec::Off` is exactly [`loopback`].
pub fn loopback_codec(
    init: ParamSet,
    workers: usize,
    policy: Policy,
    groups: usize,
    codec: Codec,
) -> RemoteClient {
    loopback(init, workers, policy, groups)
        .with_codec(codec)
        .expect("negotiate payload codec")
}

/// Multi-process harness in one process: `groups` *independent*
/// [`ShardedServer`]s — each constructed from the same init, exactly as
/// `sspdnn serve --group i` processes construct theirs from the same
/// config — each behind its own exclusive loopback endpoint
/// ([`ShardService::bind_group`]), assembled by one client. Every
/// cross-group protocol path (COMMIT broadcast, barrier fan-out,
/// per-group ε statistics) is exercised for real; only the process
/// boundary is simulated.
pub fn serve_split(
    init: ParamSet,
    workers: usize,
    policy: Policy,
    groups: usize,
) -> RemoteClient {
    let n_groups = group_ranges(init.n_layers(), groups).len();
    let mut services = Vec::with_capacity(n_groups);
    let mut addrs = Vec::with_capacity(n_groups);
    for g in 0..n_groups {
        let server =
            Arc::new(ShardedServer::new(init.clone(), workers, policy));
        let svc = ShardService::bind_group(server, "127.0.0.1:0", groups, g)
            .expect("bind exclusive shard service");
        addrs.extend_from_slice(svc.addrs());
        services.push(svc);
    }
    let mut client =
        RemoteClient::connect(&addrs).expect("connect split client");
    for svc in services {
        client.attach_service(svc);
    }
    client
}

/// [`serve_split`] under the property suite's `make_server` signature —
/// a pipelined exclusive multi-process backing is one closure away:
/// `|i, w, p| transport::loopback_split(i, w, p, groups, window)`
/// (`window: None` keeps commits synchronous).
pub fn loopback_split(
    init: ParamSet,
    workers: usize,
    policy: Policy,
    groups: usize,
    window: Option<usize>,
) -> RemoteClient {
    let client = serve_split(init, workers, policy, groups);
    match window {
        None => client,
        Some(w) => client.with_pipeline(w).expect("enable pipeline"),
    }
}

/// [`loopback`] with every endpoint behind its own fault-injection
/// [`chaos::ChaosProxy`] running `script` (each proxy counts its own
/// frames — see [`chaos::ChaosEvent`]), and the client supervised so
/// the scripted faults are absorbed by reconnect-and-resync. The
/// proxies and the service live (and tear down) with the client.
/// `window: Some(w)` additionally pipelines commits — faults then land
/// inside a non-empty in-flight window.
pub fn loopback_chaos(
    init: ParamSet,
    workers: usize,
    policy: Policy,
    groups: usize,
    window: Option<usize>,
    script: &str,
    seed: u64,
) -> RemoteClient {
    let server = Arc::new(ShardedServer::new(init, workers, policy));
    let svc = ShardService::bind(server, "127.0.0.1:0", groups)
        .expect("bind loopback shard service");
    let events = chaos::parse_script(script).expect("chaos script");
    let mut proxies = Vec::with_capacity(svc.addrs().len());
    let mut addrs = Vec::with_capacity(svc.addrs().len());
    for (i, addr) in svc.addrs().iter().enumerate() {
        let proxy =
            chaos::ChaosProxy::spawn(*addr, events.clone(), seed ^ i as u64)
                .expect("spawn chaos proxy");
        addrs.push(proxy.addr());
        proxies.push(proxy);
    }
    let faults = FaultPolicy {
        connect_timeout: std::time::Duration::from_secs(5),
        io_timeout: None,
        max_retries: 10,
        backoff_base: std::time::Duration::from_millis(5),
    };
    let mut client = RemoteClient::connect_with(&addrs, faults)
        .expect("connect chaos client");
    if let Some(w) = window {
        client = client.with_pipeline(w).expect("enable pipeline");
    }
    for proxy in proxies {
        client.attach_chaos(proxy);
    }
    client.attach_service(svc);
    client
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::LayerParams;
    use crate::ssp::{ParamServer, UpdateMsg};
    use crate::tensor::Matrix;

    fn dims() -> Vec<usize> {
        vec![3, 4, 2]
    }

    fn msg(from: usize, clock: u64, layer: usize, v: f32) -> UpdateMsg {
        let d = dims();
        UpdateMsg::new(
            from,
            clock,
            layer,
            LayerParams {
                w: Matrix::from_fn(d[layer], d[layer + 1], |_, _| v),
                b: vec![v; d[layer + 1]],
            },
        )
    }

    #[test]
    fn param_digest_is_deterministic_and_bit_sensitive() {
        let mut rng = crate::util::Pcg64::new(31);
        let a = ParamSet::glorot(&dims(), &mut rng);
        assert_eq!(param_digest(&a), param_digest(&a.clone()));
        let mut b = a.clone();
        *b.layers[1].w.at_mut(0, 0) += 1e-7;
        assert_ne!(param_digest(&a), param_digest(&b), "bit flip detected");
        // order-sensitive: swapping two layers' roles changes the hash
        assert_ne!(
            param_digest(&ParamSet::zeros(&dims())),
            param_digest(&a),
        );
    }

    #[test]
    fn handshake_reports_server_geometry() {
        let init = ParamSet::zeros(&dims());
        let client =
            loopback(init.clone(), 3, Policy::Ssp { staleness: 5 }, 2);
        assert_eq!(client.workers(), 3);
        assert_eq!(client.n_layers(), 2);
        assert_eq!(client.groups(), 2);
        assert_eq!(client.policy(), Policy::Ssp { staleness: 5 });
        client.check_run(&init, 3, Policy::Ssp { staleness: 5 });
    }

    #[test]
    fn commit_update_fetch_roundtrip() {
        let init = ParamSet::zeros(&dims());
        let mut client = loopback(init.clone(), 2, Policy::Async, 2);
        assert_eq!(client.clock(0), 0);
        assert_eq!(ParamServer::commit(&mut client, 0), 1);
        assert_eq!(client.clock(0), 1);
        client.apply_arrival(&msg(0, 0, 0, 0.5));
        client.apply_arrival(&msg(0, 0, 1, 0.25));
        assert_eq!(client.applied(0, 0), 1);
        assert_eq!(client.applied(1, 0), 1);
        let (snap, own, _stats) = client.fetch(1);
        assert_eq!(own, vec![0, 0], "worker 1 wrote nothing");
        assert!((snap.layers[0].w.at(0, 0) - 0.5).abs() < 1e-7);
        assert!((snap.layers[1].b[0] - 0.25).abs() < 1e-7);
        assert_eq!(client.reads(), 1);
        // snapshot agrees with fetch
        assert_eq!(ParamServer::snapshot(&client), snap);
    }

    #[test]
    fn gated_fetch_into_matches_full_fetch_across_reuse() {
        let init = {
            let mut rng = crate::util::Pcg64::new(5);
            ParamSet::glorot(&dims(), &mut rng)
        };
        let mut client =
            loopback(init.clone(), 2, Policy::Ssp { staleness: 4 }, 2);
        let mut buf = init.clone();
        let mut seen = vec![0u64; 2];
        let mut own = Vec::new();

        // nothing committed: everything gated, buffer already current
        let (_, fs) = client.fetch_into(0, &mut buf, &mut seen, &mut own);
        assert_eq!(fs.layers_copied, 0);
        assert_eq!(fs.layers_skipped, 2);
        let (full, own_full, _) = client.fetch(0);
        assert_eq!(buf, full);
        assert_eq!(own, own_full);

        // one layer changes: exactly one layer rides the wire
        ParamServer::commit(&mut client, 1);
        client.apply_arrival(&msg(1, 0, 1, 0.1));
        let (_, fs) = client.fetch_into(0, &mut buf, &mut seen, &mut own);
        assert_eq!(fs.layers_copied, 1);
        assert_eq!(fs.layers_skipped, 1);
        let (full, _, _) = client.fetch(0);
        assert_eq!(buf, full);
        let totals = client.copy_totals();
        assert_eq!(totals.layers_copied, 1);
        assert_eq!(totals.layers_skipped, 3);
    }

    #[test]
    fn barrier_wait_blocks_until_peer_commits() {
        let init = ParamSet::zeros(&dims());
        let server = Arc::new(ShardedServer::new(init, 2, Policy::Bsp));
        let mut fast = serve_local(Arc::clone(&server), 1);
        // worker 0 runs one clock ahead: it must wait for worker 1
        ParamServer::commit(&mut fast, 0);
        fast.apply_arrival(&msg(0, 0, 0, 0.1));
        fast.apply_arrival(&msg(0, 0, 1, 0.1));
        assert!(fast.must_wait(0));
        let t = std::thread::spawn(move || {
            fast.wait_until_ready(0);
            fast.clock(1)
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        // a second worker's commit (directly on the shared server, as
        // another process would) releases the waiter
        server.commit(1);
        server.apply_arrival(&msg(1, 0, 0, 0.1));
        server.apply_arrival(&msg(1, 0, 1, 0.1));
        let seen = t.join().unwrap();
        assert_eq!(seen, 1);
    }

    #[test]
    fn out_of_order_update_is_rejected_not_fatal() {
        let init = ParamSet::zeros(&dims());
        let mut client = loopback(init, 1, Policy::Async, 1);
        let bad = msg(0, 3, 0, 0.1); // skips clocks 0..3
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || client.apply_arrival(&bad),
        ));
        assert!(result.is_err(), "out-of-order update must be refused");
        // the connection survives the ERR: a legal update still lands
        client.apply_arrival(&msg(0, 0, 0, 0.2));
        assert_eq!(client.applied(0, 0), 1);
    }

    #[test]
    fn split_exclusive_pipelined_roundtrip() {
        let init = ParamSet::zeros(&dims());
        let mut client =
            loopback_split(init.clone(), 2, Policy::Async, 2, Some(4));
        assert!(client.exclusive());
        assert!(client.pipelined());
        assert_eq!(client.groups(), 2);
        // first pipelined commit runs the synchronous agreement round
        assert_eq!(ParamServer::commit(&mut client, 0), 1);
        client.apply_arrival(&msg(0, 0, 0, 0.5));
        client.apply_arrival(&msg(0, 0, 1, 0.25));
        client.flush().expect("drain in-flight window");
        assert_eq!(client.applied(0, 0), 1);
        assert_eq!(client.applied(1, 0), 1);
        assert_eq!(client.clock(0), 1);
        // steady-state pipelined commit: locally tracked count
        assert_eq!(ParamServer::commit(&mut client, 0), 2);
        client.flush().expect("drain commit acks");
        assert_eq!(client.clock(0), 2);
        let (snap, own, _stats) = client.fetch(1);
        assert_eq!(own, vec![0, 0], "worker 1 wrote nothing");
        assert!((snap.layers[0].w.at(0, 0) - 0.5).abs() < 1e-7);
        assert!((snap.layers[1].b[0] - 0.25).abs() < 1e-7);
    }

    #[test]
    fn split_exclusive_barrier_fans_out() {
        let init = ParamSet::zeros(&dims());
        // worker 0 commits; under BSP it must wait for worker 1, and
        // the release requires *both* group processes to observe
        // worker 1's progress — the cross-group barrier path
        let mut a = loopback_split(init, 2, Policy::Bsp, 2, None);
        ParamServer::commit(&mut a, 0);
        a.apply_arrival(&msg(0, 0, 0, 0.1));
        a.apply_arrival(&msg(0, 0, 1, 0.1));
        assert!(a.must_wait(0));
        assert!(!a.read_ready(0), "worker 1's clock-0 update missing");
        ParamServer::commit(&mut a, 1);
        a.apply_arrival(&msg(1, 0, 0, 0.1));
        a.apply_arrival(&msg(1, 0, 1, 0.1));
        assert!(!a.must_wait(0));
        assert!(a.read_ready(0));
        a.wait_until_ready(0); // returns immediately now
    }

    #[test]
    fn wire_stats_track_both_directions() {
        let init = ParamSet::zeros(&dims());
        let client = loopback(init, 1, Policy::Async, 1);
        let before = client.wire_stats();
        let _ = client.clock(0);
        let after = client.wire_stats();
        assert_eq!(after.frames_sent, before.frames_sent + 1);
        assert_eq!(after.frames_received, before.frames_received + 1);
        assert!(after.bytes_sent > before.bytes_sent);
        assert!(after.bytes_received > before.bytes_received);
    }
}
