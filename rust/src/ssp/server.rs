//! The SSP parameter server: master table + clock barrier + read protocol.
//!
//! Transport is external (the discrete-event simulator or the threaded
//! coordinator decides *when* `apply_arrival` happens); the server owns
//! the consistency logic: what a read must include, when a worker must
//! block, and the ε_{q,p} accounting of best-effort in-window updates.

use crate::nn::ParamSet;

use super::{ClockTable, ParamServer, ParamTable, Policy, UpdateMsg};

/// Statistics for one fetch (read) — quantifies Eq. (5)'s three terms.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ReadStats {
    /// Updates required by the guarantee (timestamp ≤ c−s−1) per the
    /// (layer, worker) grid, all of which were included.
    pub guaranteed: u64,
    /// In-window updates from other workers that were included (ε = 1).
    pub window_included: u64,
    /// In-window updates committed but *not* yet arrived (ε = 0).
    pub window_missed: u64,
}

impl ReadStats {
    /// Fraction of best-effort updates actually delivered.
    pub fn epsilon_rate(&self) -> f64 {
        let total = self.window_included + self.window_missed;
        if total == 0 {
            1.0
        } else {
            self.window_included as f64 / total as f64
        }
    }
}

/// Copy accounting for the version-gated read path (`fetch_into` /
/// `snapshot_into_gated`): how much parameter data actually moved, and
/// how much the per-layer revision gate saved.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FetchStats {
    /// Layers whose revision advanced since the caller's buffer was
    /// last current — copied.
    pub layers_copied: u64,
    /// Layers skipped because the buffer already held the layer's bits.
    pub layers_skipped: u64,
    /// f32 payload bytes copied (sum over copied layers).
    pub bytes_copied: u64,
}

impl FetchStats {
    pub fn absorb(&mut self, other: &FetchStats) {
        self.layers_copied += other.layers_copied;
        self.layers_skipped += other.layers_skipped;
        self.bytes_copied += other.bytes_copied;
    }
}

#[derive(Debug)]
pub struct Server {
    table: ParamTable,
    clocks: ClockTable,
    policy: Policy,
    /// `layer_revs[l]` = count of *effective* (nonzero-delta) updates
    /// applied to layer `l` — the revision the fetch gate compares
    /// against. Zero deltas advance the version vector (protocol FIFO)
    /// but cannot change θ, so they leave the revision alone.
    layer_revs: Vec<u64>,
    /// Membership flags (`ShardedServer` keeps the same flags inside
    /// its atomic clock table): an evicted worker's history is frozen,
    /// not rewritten — it just stops bounding the barrier and the read
    /// guarantee.
    live: Vec<bool>,
    /// Membership epoch: +1 per evict/admit transition.
    epoch: u64,
    bytes_received: u64,
    reads: u64,
    copy_totals: FetchStats,
}

impl Server {
    pub fn new(init: ParamSet, workers: usize, policy: Policy) -> Server {
        let layers = init.n_layers();
        Server {
            table: ParamTable::new(init, workers),
            clocks: ClockTable::new(workers),
            policy,
            layer_revs: vec![0; layers],
            live: vec![true; workers],
            epoch: 0,
            bytes_received: 0,
            reads: 0,
            copy_totals: FetchStats::default(),
        }
    }

    pub fn policy(&self) -> Policy {
        self.policy
    }

    pub fn clocks(&self) -> &ClockTable {
        &self.clocks
    }

    pub fn table(&self) -> &ParamTable {
        &self.table
    }

    pub fn n_layers(&self) -> usize {
        self.table.master().n_layers()
    }

    /// Worker `p` finished a clock (its update messages are now in
    /// flight). Advances the clock table — the barrier works on *commit*
    /// counts, arrivals lag behind.
    pub fn commit(&mut self, worker: usize) -> u64 {
        self.clocks.advance(worker)
    }

    /// A (delayed) update message reaches the server.
    pub fn apply_arrival(&mut self, msg: &UpdateMsg) {
        self.bytes_received += msg.bytes as u64;
        if !msg.delta.is_zero() {
            self.layer_revs[msg.layer] += 1;
        }
        self.table.apply(msg);
    }

    /// Min committed clock over the live set (frozen global min with
    /// the degenerate empty live set) — what the staleness barrier
    /// compares against under elastic membership.
    fn live_min(&self) -> u64 {
        (0..self.clocks.workers())
            .filter(|&q| self.live[q])
            .map(|q| self.clocks.clock(q))
            .min()
            .unwrap_or_else(|| self.clocks.min())
    }

    /// Must worker `p` block before *starting* its next clock?
    pub fn must_wait(&self, worker: usize) -> bool {
        match self.policy.staleness() {
            None => false,
            Some(s) => self.clocks.clock(worker) > self.live_min() + s,
        }
    }

    /// Is the master state sufficient for worker `p` (about to compute
    /// clock `c = clocks[p]`) to read? Guarantee: every update with
    /// timestamp ≤ c−s−1 must have been applied — i.e. applied counts
    /// ≥ c−s for every live (layer, worker). Async has no guarantee;
    /// evicted workers are exempt (their in-flight updates may never
    /// arrive).
    pub fn read_ready(&self, worker: usize) -> bool {
        let c = self.clocks.clock(worker);
        match self.policy.staleness() {
            None => true,
            Some(s) => {
                let through = c.saturating_sub(s);
                (0..self.n_layers()).all(|l| {
                    (0..self.clocks.workers()).all(|q| {
                        !self.live[q]
                            || self.table.versions().applied(l, q) >= through
                    })
                })
            }
        }
    }

    /// Current membership epoch (0 at construction).
    pub fn membership_epoch(&self) -> u64 {
        self.epoch
    }

    pub fn is_live(&self, worker: usize) -> bool {
        self.live[worker]
    }

    /// Evict `worker` — the reference semantics `ShardedServer` is
    /// pinned against: history frozen, barrier and read guarantee
    /// released, pending window contributions dropped from future ε
    /// stats. Idempotent; returns the epoch after the call.
    pub fn evict_worker(&mut self, worker: usize) -> u64 {
        if self.live[worker] {
            self.live[worker] = false;
            self.epoch += 1;
        }
        self.epoch
    }

    /// Re-admit `worker` at the live min clock, fast-forwarding its
    /// clock and version entries first (zero-delta move: θ and the gate
    /// revisions untouched). Idempotent; returns the epoch after.
    pub fn admit_worker(&mut self, worker: usize) -> u64 {
        if !self.live[worker] {
            let target = self.live_min().max(self.clocks.clock(worker));
            self.clocks.fast_forward(worker, target);
            self.table.fast_forward(worker, target);
            self.live[worker] = true;
            self.epoch += 1;
        }
        self.epoch
    }

    /// Serve a read for worker `p`: snapshot + per-layer applied counts of
    /// `p`'s own updates (for client-side read-my-writes reconstruction)
    /// + ε statistics.
    pub fn fetch(&mut self, worker: usize) -> (ParamSet, Vec<u64>, ReadStats) {
        debug_assert!(self.read_ready(worker), "fetch before guarantee met");
        self.reads += 1;
        let c = self.clocks.clock(worker);
        let s = self.policy.staleness().unwrap_or(u64::MAX);
        let through = c.saturating_sub(s); // c − s (Async: s = u64::MAX ⇒ 0)
        let mut stats = ReadStats::default();
        let layers = self.n_layers();
        for l in 0..layers {
            for q in 0..self.clocks.workers() {
                if q == worker {
                    continue;
                }
                let applied = self.table.versions().applied(l, q);
                // an evicted worker's committed-but-never-applied
                // window contributions are dropped (clamp to what
                // actually arrived); its applied history keeps counting
                let committed = if self.live[q] {
                    self.clocks.clock(q)
                } else {
                    self.clocks.clock(q).min(applied)
                };
                let guaranteed = through.min(committed);
                stats.guaranteed += guaranteed;
                let extra_applied = applied.saturating_sub(guaranteed);
                let extra_committed = committed.saturating_sub(guaranteed);
                stats.window_included += extra_applied;
                stats.window_missed += extra_committed - extra_applied;
            }
        }
        let own: Vec<u64> = (0..layers)
            .map(|l| self.table.versions().applied(l, worker))
            .collect();
        (self.table.snapshot(), own, stats)
    }

    /// Version-gated zero-copy read: same contract as `fetch`, but the
    /// snapshot lands in the caller's reusable `buf` and only the layers
    /// whose revision advanced since `last_seen` are copied. `own` is
    /// cleared and refilled with the per-layer applied counts of the
    /// caller's updates. Caller contract: `buf` holds exactly the layer
    /// bits it held when `last_seen` was last updated (initially: the
    /// init parameters with `last_seen` all zero).
    pub fn fetch_into(
        &mut self,
        worker: usize,
        buf: &mut ParamSet,
        last_seen: &mut [u64],
        own: &mut Vec<u64>,
    ) -> (ReadStats, FetchStats) {
        debug_assert!(self.read_ready(worker), "fetch before guarantee met");
        let layers = self.n_layers();
        assert_eq!(buf.layers.len(), layers, "fetch_into buffer layers");
        assert_eq!(last_seen.len(), layers, "fetch_into last_seen layers");
        self.reads += 1;
        let c = self.clocks.clock(worker);
        let s = self.policy.staleness().unwrap_or(u64::MAX);
        let through = c.saturating_sub(s);
        let mut stats = ReadStats::default();
        let mut fs = FetchStats::default();
        own.clear();
        for l in 0..layers {
            for q in 0..self.clocks.workers() {
                if q == worker {
                    continue;
                }
                let applied = self.table.versions().applied(l, q);
                // evicted: drop never-applied window contributions
                // (see `fetch`)
                let committed = if self.live[q] {
                    self.clocks.clock(q)
                } else {
                    self.clocks.clock(q).min(applied)
                };
                let guaranteed = through.min(committed);
                stats.guaranteed += guaranteed;
                let extra_applied = applied.saturating_sub(guaranteed);
                let extra_committed = committed.saturating_sub(guaranteed);
                stats.window_included += extra_applied;
                stats.window_missed += extra_committed - extra_applied;
            }
            own.push(self.table.versions().applied(l, worker));
            let rev = self.layer_revs[l];
            if rev == last_seen[l] {
                fs.layers_skipped += 1;
            } else {
                let src = &self.table.master().layers[l];
                buf.layers[l].copy_from(src);
                fs.layers_copied += 1;
                fs.bytes_copied += src.n_bytes() as u64;
                last_seen[l] = rev;
            }
        }
        self.copy_totals.absorb(&fs);
        (stats, fs)
    }

    /// Current master state into a reusable buffer (evaluation /
    /// checkpoint path without the allocation).
    pub fn snapshot_into(&self, buf: &mut ParamSet) {
        buf.copy_from(self.table.master());
    }

    /// Gated variant of `snapshot_into` for a repeat reader (the
    /// evaluator): copies only layers whose revision advanced since this
    /// buffer's previous snapshot. Feeds `copy_totals`, matching
    /// `ShardedServer::snapshot_into_gated`.
    pub fn snapshot_into_gated(
        &mut self,
        buf: &mut ParamSet,
        last_seen: &mut [u64],
    ) -> FetchStats {
        let mut fs = FetchStats::default();
        for (l, rev) in self.layer_revs.iter().enumerate() {
            if *rev == last_seen[l] {
                fs.layers_skipped += 1;
                continue;
            }
            let src = &self.table.master().layers[l];
            buf.layers[l].copy_from(src);
            fs.layers_copied += 1;
            fs.bytes_copied += src.n_bytes() as u64;
            last_seen[l] = *rev;
        }
        self.copy_totals.absorb(&fs);
        fs
    }

    /// Aggregate copy accounting over every gated read served.
    pub fn copy_totals(&self) -> FetchStats {
        self.copy_totals
    }

    pub fn bytes_received(&self) -> u64 {
        self.bytes_received
    }

    pub fn reads(&self) -> u64 {
        self.reads
    }
}

impl ParamServer for Server {
    fn policy(&self) -> Policy {
        Server::policy(self)
    }

    fn workers(&self) -> usize {
        self.clocks.workers()
    }

    fn n_layers(&self) -> usize {
        Server::n_layers(self)
    }

    fn clock(&self, worker: usize) -> u64 {
        self.clocks.clock(worker)
    }

    fn commit(&mut self, worker: usize) -> u64 {
        Server::commit(self, worker)
    }

    fn apply_arrival(&mut self, msg: &UpdateMsg) {
        Server::apply_arrival(self, msg)
    }

    fn must_wait(&self, worker: usize) -> bool {
        Server::must_wait(self, worker)
    }

    fn read_ready(&self, worker: usize) -> bool {
        Server::read_ready(self, worker)
    }

    fn fetch(&mut self, worker: usize) -> (ParamSet, Vec<u64>, ReadStats) {
        Server::fetch(self, worker)
    }

    fn fetch_into(
        &mut self,
        worker: usize,
        buf: &mut ParamSet,
        last_seen: &mut [u64],
        own: &mut Vec<u64>,
    ) -> (ReadStats, FetchStats) {
        Server::fetch_into(self, worker, buf, last_seen, own)
    }

    fn snapshot(&self) -> ParamSet {
        self.table.snapshot()
    }

    fn snapshot_into(&self, buf: &mut ParamSet) {
        Server::snapshot_into(self, buf)
    }

    fn copy_totals(&self) -> FetchStats {
        Server::copy_totals(self)
    }

    fn applied(&self, layer: usize, worker: usize) -> u64 {
        self.table.versions().applied(layer, worker)
    }

    fn reads(&self) -> u64 {
        Server::reads(self)
    }

    fn membership_epoch(&self) -> u64 {
        Server::membership_epoch(self)
    }

    fn is_live(&self, worker: usize) -> bool {
        Server::is_live(self, worker)
    }

    fn evict_worker(&mut self, worker: usize) -> u64 {
        Server::evict_worker(self, worker)
    }

    fn admit_worker(&mut self, worker: usize) -> u64 {
        Server::admit_worker(self, worker)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::LayerParams;
    use crate::tensor::Matrix;

    fn dims() -> Vec<usize> {
        vec![2, 3, 2]
    }

    fn msg(from: usize, clock: u64, layer: usize) -> UpdateMsg {
        let d = dims();
        UpdateMsg::new(
            from,
            clock,
            layer,
            LayerParams {
                w: Matrix::from_fn(d[layer], d[layer + 1], |_, _| 0.1),
                b: vec![0.1; d[layer + 1]],
            },
        )
    }

    fn commit_and_arrive(srv: &mut Server, worker: usize) {
        let clock = srv.clocks().clock(worker);
        srv.commit(worker);
        for l in 0..srv.n_layers() {
            srv.apply_arrival(&msg(worker, clock, l));
        }
    }

    #[test]
    fn ssp_read_guarantee() {
        let mut srv = Server::new(
            ParamSet::zeros(&dims()),
            2,
            Policy::Ssp { staleness: 1 },
        );
        // both workers commit clock 0 and updates arrive
        commit_and_arrive(&mut srv, 0);
        commit_and_arrive(&mut srv, 1);
        // worker 0 commits clock 1, but its arrival is delayed
        srv.commit(0);
        // worker 0 now at clock 2, s=1 → needs ts ≤ 0 applied: satisfied
        assert!(srv.read_ready(0));
        // worker 1 at clock 1 needs ts ≤ -1: trivially ready
        assert!(srv.read_ready(1));
    }

    #[test]
    fn read_not_ready_when_guaranteed_update_missing() {
        let mut srv = Server::new(
            ParamSet::zeros(&dims()),
            2,
            Policy::Ssp { staleness: 0 },
        );
        // worker 1 commits clock 0 but the update has NOT arrived
        srv.commit(1);
        srv.commit(0);
        // worker 0 at clock 1, s=0 → needs all ts ≤ 0 applied; worker 1's
        // clock-0 update is still in flight
        assert!(!srv.read_ready(0));
        for l in 0..srv.n_layers() {
            srv.apply_arrival(&msg(1, 0, l));
        }
        // still missing worker 0's own clock-0 arrival
        assert!(!srv.read_ready(0));
        for l in 0..srv.n_layers() {
            srv.apply_arrival(&msg(0, 0, l));
        }
        assert!(srv.read_ready(0));
    }

    #[test]
    fn epsilon_stats_count_window_inclusion() {
        let mut srv = Server::new(
            ParamSet::zeros(&dims()),
            2,
            Policy::Ssp { staleness: 2 },
        );
        // worker 1 commits clocks 0,1: clock-0 arrives, clock-1 in flight
        let m0 = msg(1, 0, 0);
        let m0b = msg(1, 0, 1);
        srv.commit(1);
        srv.apply_arrival(&m0);
        srv.apply_arrival(&m0b);
        srv.commit(1);
        // worker 0 at clock 0: everything from worker 1 is in-window
        let (_, own, stats) = srv.fetch(0);
        assert_eq!(own, vec![0, 0]);
        assert_eq!(stats.guaranteed, 0);
        assert_eq!(stats.window_included, 2); // clock-0 arrived (2 layers)
        assert_eq!(stats.window_missed, 2); // clock-1 in flight (2 layers)
        assert!((stats.epsilon_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn barrier_delegates_to_clock_table() {
        let mut srv = Server::new(
            ParamSet::zeros(&dims()),
            2,
            Policy::Ssp { staleness: 0 },
        );
        srv.commit(0);
        assert!(srv.must_wait(0));
        srv.commit(1);
        assert!(!srv.must_wait(0));
    }

    #[test]
    fn own_applied_counts_reported() {
        let mut srv = Server::new(
            ParamSet::zeros(&dims()),
            2,
            Policy::Ssp { staleness: 5 },
        );
        srv.commit(0);
        srv.apply_arrival(&msg(0, 0, 0)); // layer 0 arrived, layer 1 not
        let (_, own, _) = srv.fetch(0);
        assert_eq!(own, vec![1, 0]);
    }

    #[test]
    fn async_window_accounting_counts_every_commit_as_best_effort() {
        // Regression for the staleness window under Policy::Async
        // (s = u64::MAX): nothing is guaranteed, every committed update
        // is best-effort, and included/missed split by arrival.
        let mut srv = Server::new(ParamSet::zeros(&dims()), 2, Policy::Async);
        // worker 1 commits 3 clocks; clocks 0 and 1 arrive (both layers),
        // clock 2 stays in flight
        for clock in 0..3u64 {
            srv.commit(1);
            if clock < 2 {
                for l in 0..srv.n_layers() {
                    srv.apply_arrival(&msg(1, clock, l));
                }
            }
        }
        let (_, own, stats) = srv.fetch(0);
        assert_eq!(own, vec![0, 0]);
        assert_eq!(stats.guaranteed, 0, "async guarantees nothing");
        assert_eq!(stats.window_included, 2 * 2); // 2 clocks × 2 layers
        assert_eq!(stats.window_missed, 2); // 1 clock × 2 layers
        assert!((stats.epsilon_rate() - 4.0 / 6.0).abs() < 1e-12);

        // ... and the fetching worker's own committed clock does not
        // overflow the window arithmetic even at clock 0 or clock 1000
        for _ in 0..1000 {
            srv.commit(0);
        }
        let (_, _, stats) = srv.fetch(0);
        assert_eq!(stats.guaranteed, 0);
        assert_eq!(stats.window_missed, 2);
    }

    #[test]
    fn async_always_ready() {
        let mut srv = Server::new(ParamSet::zeros(&dims()), 3, Policy::Async);
        for _ in 0..5 {
            srv.commit(0);
        }
        assert!(srv.read_ready(0));
        assert!(!srv.must_wait(0));
    }
}
