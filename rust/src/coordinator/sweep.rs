//! Parallel deterministic sweep harness for the discrete-event driver.
//!
//! The paper's figure protocol (Figs 2–5) and the trade-off studies it
//! cites (Jin et al.'s sync/async comparison, Das et al.'s design-space
//! sweeps) all need *dense grids*: every `(machines, staleness, policy,
//! eta)` combination is one full simulated training run. The harness
//! turns such a grid into independent **cells**, dispatches them across
//! OS threads under a bounded thread budget shared with the intra-op
//! GEMM pool (`outer_workers = budget / train.intra_op_threads`), and
//! collects one [`SweepReport`].
//!
//! Determinism is the design constraint everything else serves:
//!
//! * every cell trains from the **root seed** (`train.seed`) itself:
//!   same Glorot init, same eval subset, same batch streams per worker
//!   index — exactly the driver's "same seed across machine counts so
//!   trajectories match" invariant the `speedup` command relies on, so
//!   differences along any grid axis isolate the protocol effect
//!   (staleness, policy, eta, parallelism) instead of seed noise, and
//!   editing the grid never changes an existing cell's result;
//! * a cell's run is a pure function of its `(config, seed)` pair — it
//!   never depends on which thread executed it or in which order;
//! * cells share one dataset (built from `data.seed`) and one
//!   calibrated `per_batch_s`, measured once before dispatch (pin it
//!   via [`SweepOptions::per_batch_s`] for cross-process repeatability);
//! * results are written into a slot indexed by cell, never appended.
//!
//! Consequence: the statistical content of a report is **bitwise
//! identical at any thread budget** (`tests/property_driver.rs` pins
//! budgets {1, 2, 4, 7}); only the wall-clock fields differ. To draw an
//! independent replicate of a whole sweep, change the root seed.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::config::{ExperimentConfig, SweepConfig};
use crate::data::Dataset;
use crate::nn::{Labels, Mlp, ParamSet};
use crate::ssp::{ParamServer, Policy, Server};
use crate::tensor::Matrix;

use super::driver::{
    build_dataset, measure_per_batch_into, run_experiment_with, RunResult,
};
use super::engine::{EngineKind, NativeEngine};
use super::DriverOptions;

/// One grid point: a full driver run at this configuration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SweepCell {
    /// Position in the expanded grid (also the result slot).
    pub index: usize,
    pub machines: usize,
    pub policy: Policy,
    pub eta: f32,
    /// Training seed — the root seed, shared by every cell so grid
    /// axes stay statistically comparable (see module docs).
    pub seed: u64,
}

/// Harness knobs independent of the grid itself.
#[derive(Clone, Copy, Debug)]
pub struct SweepOptions {
    /// Total thread budget, shared with the intra-op GEMM pool: the
    /// harness runs `max(1, threads / train.intra_op_threads)` cells
    /// concurrently so `outer × intra` never exceeds the budget.
    pub threads: usize,
    pub eval_every: u64,
    pub eval_samples: usize,
    /// Virtual seconds per minibatch. `None` calibrates once on this
    /// host and shares the value across all cells (deterministic within
    /// the process; pin it for cross-process bitwise repeatability).
    pub per_batch_s: Option<f64>,
    /// Driver allocation-audit warmup (see `DriverOptions`).
    pub warmup_clocks: u64,
}

impl Default for SweepOptions {
    fn default() -> Self {
        SweepOptions {
            threads: 1,
            eval_every: 2,
            eval_samples: 512,
            per_batch_s: None,
            warmup_clocks: 4,
        }
    }
}

/// One cell's outcome: the deterministic run statistics plus wall-clock
/// throughput (the only fields allowed to vary across thread budgets).
#[derive(Clone, Debug)]
pub struct CellResult {
    pub index: usize,
    pub machines: usize,
    pub policy: String,
    pub staleness: Option<u64>,
    pub eta: f32,
    pub seed: u64,
    pub final_objective: f64,
    pub total_vtime: f64,
    pub steps: u64,
    pub barrier_wait_s: f64,
    pub read_wait_s: f64,
    pub compute_s: f64,
    pub epsilon_rate: f64,
    pub steady_reallocs: u64,
    /// (virtual seconds, min clock, objective) convergence curve.
    pub evals: Vec<(f64, u64, f64)>,
    /// Host seconds this cell took (timing section — not deterministic).
    pub wall_s: f64,
    /// Committed clocks per host second across the cell's workers.
    pub clocks_per_s: f64,
}

impl CellResult {
    fn from_run(
        cell: &SweepCell,
        run: &RunResult,
        batches_per_clock: usize,
        wall_s: f64,
    ) -> CellResult {
        let committed = run.steps as f64 / batches_per_clock.max(1) as f64;
        CellResult {
            index: cell.index,
            machines: cell.machines,
            policy: cell.policy.name(),
            staleness: cell.policy.staleness(),
            eta: cell.eta,
            seed: cell.seed,
            final_objective: run.final_objective,
            total_vtime: run.total_vtime,
            steps: run.steps,
            barrier_wait_s: run.barrier_wait_s,
            read_wait_s: run.read_wait_s,
            compute_s: run.compute_s,
            epsilon_rate: run.epsilon_rate,
            steady_reallocs: run.steady_reallocs,
            evals: run
                .evals
                .iter()
                .map(|e| (e.vtime, e.clock, e.objective))
                .collect(),
            wall_s,
            clocks_per_s: if wall_s > 0.0 { committed / wall_s } else { 0.0 },
        }
    }
}

/// The consolidated sweep outcome (`metrics::sweep_json` serializes it).
#[derive(Clone, Debug)]
pub struct SweepReport {
    pub name: String,
    /// `train.seed` the per-cell seeds were derived from.
    pub root_seed: u64,
    /// Total thread budget the caller granted.
    pub thread_budget: usize,
    /// Concurrent cells actually run (`budget / intra_op_threads`).
    pub outer_workers: usize,
    pub intra_op_threads: usize,
    /// Shared virtual seconds per minibatch (calibrated or pinned).
    pub per_batch_s: f64,
    /// Host seconds for the whole sweep.
    pub wall_s: f64,
    pub cells: Vec<CellResult>,
}

/// Expand a grid into cells: `machines × etas × policy-cells`, where a
/// `"ssp"` policy entry contributes one cell per staleness value and
/// `"bsp"`/`"async"` contribute one each (their semantics carry no
/// staleness knob). Cell order — and therefore result-slot assignment —
/// is the deterministic nesting order machines → etas → policies →
/// staleness. Every cell carries the root training seed (see module
/// docs: shared-seed cells keep grid axes comparable, the same way the
/// speedup protocol holds the seed fixed across machine counts).
pub fn sweep_cells(
    grid: &SweepConfig,
    base: &ExperimentConfig,
) -> Result<Vec<SweepCell>, String> {
    grid.validate()?;
    let etas: Vec<f32> = if grid.etas.is_empty() {
        vec![base.train.eta]
    } else {
        grid.etas.clone()
    };
    let root = base.train.seed;
    let mut cells = Vec::new();
    for &machines in &grid.machines {
        for &eta in &etas {
            for policy in &grid.policies {
                let expanded: Vec<Policy> = match policy.as_str() {
                    "ssp" => grid
                        .staleness
                        .iter()
                        .map(|&s| Policy::Ssp { staleness: s })
                        .collect(),
                    "bsp" => vec![Policy::Bsp],
                    "async" => vec![Policy::Async],
                    // grid.validate() above rejects anything else
                    other => unreachable!("unvalidated policy {other:?}"),
                };
                for policy in expanded {
                    let index = cells.len();
                    cells.push(SweepCell {
                        index,
                        machines,
                        policy,
                        eta,
                        seed: root,
                    });
                }
            }
        }
    }
    if cells.is_empty() {
        return Err("sweep grid is empty".into());
    }
    Ok(cells)
}

/// Calibrate the shared per-minibatch virtual duration once, through a
/// persistent gather workspace (same measurement protocol the driver
/// uses, on a deterministic prefix batch).
fn calibrate(cfg: &ExperimentConfig, dataset: &Dataset) -> f64 {
    let mlp = Mlp::new(
        cfg.model.dims.clone(),
        cfg.model.activation,
        cfg.model.loss,
    )
    .with_intra_op_threads(cfg.train.intra_op_threads)
    .with_gemm(cfg.train.gemm_selection().ok());
    let mut engine = EngineKind::Native(NativeEngine::new(mlp));
    let init = super::init_params(cfg);
    let idx: Vec<usize> =
        (0..cfg.train.batch.min(dataset.n_samples())).collect();
    let mut x = Matrix::zeros(idx.len(), dataset.n_features());
    let mut y = Labels::Class(Vec::with_capacity(idx.len()));
    dataset.gather_into(&idx, &mut x, &mut y);
    let mut grads = init.zeros_like();
    measure_per_batch_into(
        &mut engine,
        &init,
        &x,
        &y,
        &mut grads,
        cfg.cluster.cores_per_machine,
    )
}

/// Run a sweep on the single-lock reference `Server` (the driver's
/// default backing).
pub fn run_sweep(
    cfg: &ExperimentConfig,
    grid: &SweepConfig,
    opts: &SweepOptions,
) -> Result<SweepReport, String> {
    run_sweep_with(cfg, grid, opts, Server::new)
}

/// Generic sweep: any [`ParamServer`] can back the cells. Cells are
/// pulled from a shared atomic counter by `outer_workers` scoped
/// threads and written into their index slot; the report's statistical
/// content is identical for any thread budget.
pub fn run_sweep_with<S: ParamServer>(
    cfg: &ExperimentConfig,
    grid: &SweepConfig,
    opts: &SweepOptions,
    make_server: impl Fn(ParamSet, usize, Policy) -> S + Sync,
) -> Result<SweepReport, String> {
    let cells = sweep_cells(grid, cfg)?;
    let dataset = build_dataset(cfg);
    let per_batch_s = match opts.per_batch_s {
        Some(v) => v,
        None => calibrate(cfg, &dataset),
    };
    let budget = opts.threads.max(1);
    let intra = cfg.train.intra_op_threads.max(1);
    // cells.len() >= 1 (sweep_cells rejects empty grids)
    let outer = (budget / intra).clamp(1, cells.len());

    let start = Instant::now();
    let next = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<CellResult>>> =
        (0..cells.len()).map(|_| Mutex::new(None)).collect();

    std::thread::scope(|scope| {
        for _ in 0..outer {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= cells.len() {
                    break;
                }
                let cell = &cells[i];
                let mut c = cfg.clone();
                c.cluster.machines = cell.machines;
                c.ssp.policy = cell.policy;
                c.train.eta = cell.eta;
                c.train.seed = cell.seed;
                let t = Instant::now();
                let run = run_experiment_with(
                    &c,
                    DriverOptions {
                        machines: Some(cell.machines),
                        eval_every: opts.eval_every,
                        eval_samples: opts.eval_samples,
                        per_batch_s: Some(per_batch_s),
                        warmup_clocks: opts.warmup_clocks,
                        ..DriverOptions::default()
                    },
                    &dataset,
                    |init, m, p| make_server(init, m, p),
                );
                let wall = t.elapsed().as_secs_f64();
                *results[i].lock().unwrap() = Some(CellResult::from_run(
                    cell,
                    &run,
                    c.train.batches_per_clock,
                    wall,
                ));
            });
        }
    });

    let cells_out: Vec<CellResult> = results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("cell never ran"))
        .collect();
    Ok(SweepReport {
        name: cfg.name.clone(),
        root_seed: cfg.train.seed,
        thread_budget: budget,
        outer_workers: outer,
        intra_op_threads: intra,
        per_batch_s,
        wall_s: start.elapsed().as_secs_f64(),
        cells: cells_out,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SweepConfig;

    fn grid(machines: Vec<usize>, staleness: Vec<u64>) -> SweepConfig {
        SweepConfig {
            machines,
            staleness,
            policies: vec!["ssp".into()],
            etas: vec![],
            threads: 1,
        }
    }

    #[test]
    fn cell_expansion_order_and_seeds() {
        let base = ExperimentConfig::tiny();
        let mut g = grid(vec![1, 2], vec![0, 4]);
        g.policies = vec!["ssp".into(), "bsp".into()];
        let cells = sweep_cells(&g, &base).unwrap();
        // per machines: ssp(s=0), ssp(s=4), bsp — nesting order fixed
        assert_eq!(cells.len(), 6);
        assert_eq!(cells[0].policy, Policy::Ssp { staleness: 0 });
        assert_eq!(cells[1].policy, Policy::Ssp { staleness: 4 });
        assert_eq!(cells[2].policy, Policy::Bsp);
        assert_eq!(cells[3].machines, 2);
        for (i, c) in cells.iter().enumerate() {
            assert_eq!(c.index, i);
            // every cell shares the root seed: grid axes compare the
            // protocol effect, never seed noise, and editing the grid
            // can't silently change an existing cell's run
            assert_eq!(c.seed, base.train.seed);
        }
    }

    #[test]
    fn empty_or_invalid_grids_rejected() {
        let base = ExperimentConfig::tiny();
        let mut g = grid(vec![], vec![0]);
        assert!(sweep_cells(&g, &base).is_err());
        g = grid(vec![1], vec![0]);
        g.policies = vec!["nope".into()];
        assert!(sweep_cells(&g, &base).is_err());
        g = grid(vec![0], vec![0]);
        assert!(g.validate().is_err() || sweep_cells(&g, &base).is_err());
    }

    #[test]
    fn eta_defaults_to_train_eta() {
        let base = ExperimentConfig::tiny();
        let g = grid(vec![1], vec![2]);
        let cells = sweep_cells(&g, &base).unwrap();
        assert_eq!(cells.len(), 1);
        assert_eq!(cells[0].eta, base.train.eta);
    }

    #[test]
    fn tiny_sweep_runs_and_orders_cells() {
        let mut base = ExperimentConfig::tiny();
        base.train.clocks = 6;
        base.train.batches_per_clock = 1;
        let g = grid(vec![1, 2], vec![2]);
        let report = run_sweep(
            &base,
            &g,
            &SweepOptions {
                threads: 2,
                per_batch_s: Some(0.01),
                eval_samples: 64,
                ..SweepOptions::default()
            },
        )
        .unwrap();
        assert_eq!(report.cells.len(), 2);
        assert_eq!(report.cells[0].machines, 1);
        assert_eq!(report.cells[1].machines, 2);
        assert_eq!(report.outer_workers, 2);
        for c in &report.cells {
            assert!(c.final_objective.is_finite());
            assert!(c.wall_s >= 0.0);
            assert!(!c.evals.is_empty());
        }
    }
}
