//! Gradient engines: where (loss, grads) come from.
//!
//! `NativeEngine` runs the built-in Rust backprop (`nn`). The PJRT engine
//! (`runtime::PjrtEngine`) runs the AOT-compiled JAX artifact; both are
//! interchangeable behind `GradEngine`, and the integration tests assert
//! they agree numerically.

use crate::nn::{GradSet, Labels, Mlp, ParamSet, Workspace};
use crate::tensor::Matrix;

/// Anything that can turn (params, minibatch) into (loss, gradients).
/// `Send` so engines can move into worker threads (`run_threaded`).
pub trait GradEngine: Send {
    /// Batch-mean loss and gradients at `params`.
    fn loss_and_grads(
        &mut self,
        params: &ParamSet,
        x: &Matrix,
        y: &Labels,
    ) -> (f64, GradSet);

    /// Batch-mean loss with gradients written into the caller's reusable
    /// buffer — the zero-allocation training-loop path. Engines with
    /// internal buffers override this to skip the default's extra copy.
    fn loss_and_grads_into(
        &mut self,
        params: &ParamSet,
        x: &Matrix,
        y: &Labels,
        grads: &mut GradSet,
    ) -> f64 {
        let (loss, g) = self.loss_and_grads(params, x, y);
        grads.copy_from(&g);
        loss
    }

    /// Objective only (used by evaluation instrumentation).
    fn objective(&mut self, params: &ParamSet, x: &Matrix, y: &Labels) -> f64;

    fn name(&self) -> &'static str;
}

/// Which engine a run uses (mirrors `config::Engine` but carries state).
pub enum EngineKind {
    Native(NativeEngine),
    Boxed(Box<dyn GradEngine>),
}

impl GradEngine for EngineKind {
    fn loss_and_grads(
        &mut self,
        params: &ParamSet,
        x: &Matrix,
        y: &Labels,
    ) -> (f64, GradSet) {
        match self {
            EngineKind::Native(e) => e.loss_and_grads(params, x, y),
            EngineKind::Boxed(e) => e.loss_and_grads(params, x, y),
        }
    }

    fn loss_and_grads_into(
        &mut self,
        params: &ParamSet,
        x: &Matrix,
        y: &Labels,
        grads: &mut GradSet,
    ) -> f64 {
        match self {
            EngineKind::Native(e) => e.loss_and_grads_into(params, x, y, grads),
            EngineKind::Boxed(e) => e.loss_and_grads_into(params, x, y, grads),
        }
    }

    fn objective(&mut self, params: &ParamSet, x: &Matrix, y: &Labels) -> f64 {
        match self {
            EngineKind::Native(e) => e.objective(params, x, y),
            EngineKind::Boxed(e) => e.objective(params, x, y),
        }
    }

    fn name(&self) -> &'static str {
        match self {
            EngineKind::Native(e) => e.name(),
            EngineKind::Boxed(e) => e.name(),
        }
    }
}

/// The native Rust backprop engine with a reusable training workspace +
/// gradient buffer and a *separate* persistent evaluation workspace
/// (eval batches are a different size than training batches — sharing
/// one workspace would reallocate activations on every train↔eval
/// switch). Allocation-free per step and per eval after warmup.
pub struct NativeEngine {
    mlp: Mlp,
    ws: Workspace,
    eval_ws: Workspace,
    grads: Option<GradSet>,
}

impl NativeEngine {
    pub fn new(mlp: Mlp) -> NativeEngine {
        NativeEngine {
            mlp,
            ws: Workspace::default(),
            eval_ws: Workspace::default(),
            grads: None,
        }
    }

    pub fn mlp(&self) -> &Mlp {
        &self.mlp
    }

    /// Classification accuracy through the persistent eval workspace.
    pub fn accuracy(&mut self, params: &ParamSet, x: &Matrix, y: &Labels) -> f64 {
        self.mlp.accuracy_ws(params, x, y, &mut self.eval_ws)
    }
}

impl GradEngine for NativeEngine {
    fn loss_and_grads(
        &mut self,
        params: &ParamSet,
        x: &Matrix,
        y: &Labels,
    ) -> (f64, GradSet) {
        let grads = self
            .grads
            .get_or_insert_with(|| params.zeros_like());
        let loss = self
            .mlp
            .loss_and_grads_ws(params, x, y, &mut self.ws, grads);
        (loss, grads.clone())
    }

    fn loss_and_grads_into(
        &mut self,
        params: &ParamSet,
        x: &Matrix,
        y: &Labels,
        grads: &mut GradSet,
    ) -> f64 {
        self.mlp.loss_and_grads_ws(params, x, y, &mut self.ws, grads)
    }

    fn objective(&mut self, params: &ParamSet, x: &Matrix, y: &Labels) -> f64 {
        self.mlp.objective_ws(params, x, y, &mut self.eval_ws)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{Activation, Loss};
    use crate::util::Pcg64;

    #[test]
    fn native_engine_matches_direct_mlp() {
        let mlp = Mlp::new(vec![6, 5, 3], Activation::Sigmoid, Loss::Xent);
        let mut rng = Pcg64::new(4);
        let p = ParamSet::glorot(&mlp.dims, &mut rng);
        let x = Matrix::randn(4, 6, 1.0, &mut rng);
        let y = Labels::Class(vec![0, 1, 2, 0]);
        let (l_direct, g_direct) = mlp.loss_and_grads(&p, &x, &y);
        let mut eng = NativeEngine::new(mlp.clone());
        let (l_eng, g_eng) = eng.loss_and_grads(&p, &x, &y);
        assert_eq!(l_direct, l_eng);
        for (a, b) in g_direct.layers.iter().zip(&g_eng.layers) {
            assert_eq!(a.w, b.w);
        }
        let obj = eng.objective(&p, &x, &y);
        assert!((obj - l_direct).abs() < 1e-12);
        assert_eq!(eng.name(), "native");
    }

    #[test]
    fn loss_and_grads_into_matches_allocating_path() {
        let mlp = Mlp::new(vec![5, 4, 3], Activation::Sigmoid, Loss::Xent);
        let mut rng = Pcg64::new(9);
        let p = ParamSet::glorot(&mlp.dims, &mut rng);
        let x = Matrix::randn(6, 5, 1.0, &mut rng);
        let y = Labels::Class(vec![0, 1, 2, 0, 1, 2]);
        let mut a = NativeEngine::new(mlp.clone());
        let mut b = EngineKind::Native(NativeEngine::new(mlp));
        let (l1, g1) = a.loss_and_grads(&p, &x, &y);
        let mut g2 = p.zeros_like();
        // run twice through the same buffer: reuse must not drift
        b.loss_and_grads_into(&p, &x, &y, &mut g2);
        let l2 = b.loss_and_grads_into(&p, &x, &y, &mut g2);
        assert_eq!(l1, l2);
        assert_eq!(g1, g2);
        // eval workspace is persistent and independent of training size
        let obj1 = b.objective(&p, &x, &y);
        let obj2 = b.objective(&p, &x, &y);
        assert_eq!(obj1, obj2);
    }
}
