//! Gradient engines: where (loss, grads) come from.
//!
//! `NativeEngine` runs the built-in Rust backprop (`nn`). The PJRT engine
//! (`runtime::PjrtEngine`) runs the AOT-compiled JAX artifact; both are
//! interchangeable behind `GradEngine`, and the integration tests assert
//! they agree numerically.

use crate::nn::{GradSet, Labels, Mlp, ParamSet, Workspace};
use crate::tensor::Matrix;

/// Anything that can turn (params, minibatch) into (loss, gradients).
/// `Send` so engines can move into worker threads (`run_threaded`).
pub trait GradEngine: Send {
    /// Batch-mean loss and gradients at `params`.
    fn loss_and_grads(
        &mut self,
        params: &ParamSet,
        x: &Matrix,
        y: &Labels,
    ) -> (f64, GradSet);

    /// Objective only (used by evaluation instrumentation).
    fn objective(&mut self, params: &ParamSet, x: &Matrix, y: &Labels) -> f64;

    fn name(&self) -> &'static str;
}

/// Which engine a run uses (mirrors `config::Engine` but carries state).
pub enum EngineKind {
    Native(NativeEngine),
    Boxed(Box<dyn GradEngine>),
}

impl GradEngine for EngineKind {
    fn loss_and_grads(
        &mut self,
        params: &ParamSet,
        x: &Matrix,
        y: &Labels,
    ) -> (f64, GradSet) {
        match self {
            EngineKind::Native(e) => e.loss_and_grads(params, x, y),
            EngineKind::Boxed(e) => e.loss_and_grads(params, x, y),
        }
    }

    fn objective(&mut self, params: &ParamSet, x: &Matrix, y: &Labels) -> f64 {
        match self {
            EngineKind::Native(e) => e.objective(params, x, y),
            EngineKind::Boxed(e) => e.objective(params, x, y),
        }
    }

    fn name(&self) -> &'static str {
        match self {
            EngineKind::Native(e) => e.name(),
            EngineKind::Boxed(e) => e.name(),
        }
    }
}

/// The native Rust backprop engine with a reusable workspace + gradient
/// buffer (allocation-free per step after warmup).
pub struct NativeEngine {
    mlp: Mlp,
    ws: Workspace,
    grads: Option<GradSet>,
}

impl NativeEngine {
    pub fn new(mlp: Mlp) -> NativeEngine {
        NativeEngine {
            mlp,
            ws: Workspace::default(),
            grads: None,
        }
    }

    pub fn mlp(&self) -> &Mlp {
        &self.mlp
    }
}

impl GradEngine for NativeEngine {
    fn loss_and_grads(
        &mut self,
        params: &ParamSet,
        x: &Matrix,
        y: &Labels,
    ) -> (f64, GradSet) {
        let grads = self
            .grads
            .get_or_insert_with(|| params.zeros_like());
        let loss = self
            .mlp
            .loss_and_grads_ws(params, x, y, &mut self.ws, grads);
        (loss, grads.clone())
    }

    fn objective(&mut self, params: &ParamSet, x: &Matrix, y: &Labels) -> f64 {
        let out = self.mlp.forward_ws(params, x, &mut self.ws);
        crate::nn::loss_value(self.mlp.loss, &out, y)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{Activation, Loss};
    use crate::util::Pcg64;

    #[test]
    fn native_engine_matches_direct_mlp() {
        let mlp = Mlp::new(vec![6, 5, 3], Activation::Sigmoid, Loss::Xent);
        let mut rng = Pcg64::new(4);
        let p = ParamSet::glorot(&mlp.dims, &mut rng);
        let x = Matrix::randn(4, 6, 1.0, &mut rng);
        let y = Labels::Class(vec![0, 1, 2, 0]);
        let (l_direct, g_direct) = mlp.loss_and_grads(&p, &x, &y);
        let mut eng = NativeEngine::new(mlp.clone());
        let (l_eng, g_eng) = eng.loss_and_grads(&p, &x, &y);
        assert_eq!(l_direct, l_eng);
        for (a, b) in g_direct.layers.iter().zip(&g_eng.layers) {
            assert_eq!(a.w, b.w);
        }
        let obj = eng.objective(&p, &x, &y);
        assert!((obj - l_direct).abs() < 1e-12);
        assert_eq!(eng.name(), "native");
    }
}
