//! Real-thread SSP runner: OS threads + a shared-memory parameter server
//! (Mutex + Condvar), the in-process analogue of Petuum's single-node
//! mode. Used by the end-to-end example to prove the coordinator works
//! under true concurrency (the discrete-event driver is the instrument
//! for the paper's figures; this is the deployment-shaped path).
//!
//! In shared memory every committed update is immediately visible
//! (ε ≡ 1); the staleness barrier still governs how far apart workers may
//! drift, so SSP vs BSP behaviour is real.

use std::sync::{Arc, Condvar, Mutex};
use std::thread;

use crate::config::ExperimentConfig;
use crate::data::Dataset;
use crate::nn::ParamSet;
use crate::ssp::Server;
use crate::util::Pcg64;

use super::engine::{EngineKind, GradEngine};
use super::EtaSchedule;

pub struct ThreadedOptions {
    pub machines: usize,
    /// Build one engine per worker thread (engines are not Sync).
    pub engine_factory: Box<dyn Fn(usize) -> EngineKind + Send + Sync>,
    pub eta: EtaSchedule,
    /// Log the master objective every this many clocks (on worker 0).
    pub eval_every: u64,
    pub eval_samples: usize,
}

#[derive(Clone, Debug)]
pub struct ThreadedResult {
    pub wall_seconds: f64,
    pub steps: u64,
    /// (clock, wall seconds, objective) evaluation curve.
    pub evals: Vec<(u64, f64, f64)>,
    pub final_objective: f64,
    pub final_params: ParamSet,
}

struct Shared {
    server: Mutex<Server>,
    cv: Condvar,
}

/// Run SSP training on real threads. Returns the measured wall-clock
/// curve; the statistical path is identical to the simulated driver's
/// (same update rule, same staleness semantics, ε ≡ 1).
pub fn run_threaded(
    cfg: &ExperimentConfig,
    dataset: &Dataset,
    opts: ThreadedOptions,
) -> ThreadedResult {
    let machines = opts.machines;
    let policy = cfg.ssp.policy;
    let mut root_rng = Pcg64::new(cfg.train.seed);
    let mut init_rng = Pcg64::new(cfg.train.seed ^ 0xD11);
    let init = ParamSet::glorot(&cfg.model.dims, &mut init_rng);

    // fixed eval subset
    let mut eval_rng = Pcg64::new(cfg.train.seed ^ 0xE7A1);
    let eval_idx: Vec<usize> = (0..opts.eval_samples.min(dataset.n_samples()))
        .map(|_| eval_rng.below(dataset.n_samples()))
        .collect();
    let (eval_x, eval_y) = dataset.gather(&eval_idx);

    let shards = dataset.shard(machines, &mut root_rng.split(1));
    let shared = Arc::new(Shared {
        server: Mutex::new(Server::new(init.clone(), machines, policy)),
        cv: Condvar::new(),
    });

    let start = std::time::Instant::now();
    let evals = Arc::new(Mutex::new(Vec::new()));

    thread::scope(|scope| {
        for shard in shards {
            let p = shard.worker();
            let shared = Arc::clone(&shared);
            let mut engine = (opts.engine_factory)(p);
            let mut batches =
                shard.minibatches(cfg.train.batch, root_rng.split(100 + p as u64));
            let init = init.clone();
            let eta = opts.eta;
            let evals = Arc::clone(&evals);
            let (eval_x, eval_y) = (eval_x.clone(), eval_y.clone());
            let dataset = &*dataset;
            let cfg = &*cfg;
            scope.spawn(move || {
                let mut cache = crate::ssp::WorkerCache::new(p, init);
                let mut steps: u64 = 0;
                for clock in 0..cfg.train.clocks as u64 {
                    // barrier + fetch under the lock
                    {
                        let mut srv = shared.server.lock().unwrap();
                        while srv.must_wait(p) {
                            srv = shared.cv.wait(srv).unwrap();
                        }
                        debug_assert!(srv.read_ready(p));
                        let (snap, _own, _stats) = srv.fetch(p);
                        // shared memory: snapshot already contains all our
                        // own commits (applied at commit time) → nothing
                        // missing.
                        let missing = snap.zeros_like();
                        cache.install_snapshot(snap, &missing);
                    }
                    // compute outside the lock
                    for _ in 0..cfg.train.batches_per_clock {
                        let idx = batches.next_batch();
                        let (x, y) = dataset.gather(&idx);
                        let (_, grads) =
                            engine.loss_and_grads(cache.view(), &x, &y);
                        cache.add_scaled_local_update(-eta.at(steps), &grads);
                        steps += 1;
                    }
                    crate::debug!(
                        "worker {p}: clock {clock} computed ({} steps)",
                        steps
                    );
                    // commit under the lock: apply updates instantly
                    {
                        let mut srv = shared.server.lock().unwrap();
                        let msgs = cache.commit_clock();
                        srv.commit(p);
                        for m in msgs {
                            srv.apply_arrival(&m);
                        }
                        shared.cv.notify_all();
                        if p == 0 && (clock + 1) % opts.eval_every == 0 {
                            let snap = srv.table().snapshot();
                            drop(srv);
                            let obj = engine.objective(&snap, &eval_x, &eval_y);
                            evals.lock().unwrap().push((
                                clock + 1,
                                start.elapsed().as_secs_f64(),
                                obj,
                            ));
                        }
                    }
                }
            });
        }
    });

    let wall_seconds = start.elapsed().as_secs_f64();
    let srv = shared.server.lock().unwrap();
    let final_params = srv.table().snapshot();
    drop(srv);
    let mut engine = (opts.engine_factory)(0);
    let final_objective = engine.objective(&final_params, &eval_x, &eval_y);
    let steps =
        (machines * cfg.train.clocks * cfg.train.batches_per_clock) as u64;

    ThreadedResult {
        wall_seconds,
        steps,
        evals: Arc::try_unwrap(evals).unwrap().into_inner().unwrap(),
        final_objective,
        final_params,
    }
}

/// Convenience: threaded run with native engines.
pub fn native_factory(
    cfg: &ExperimentConfig,
) -> Box<dyn Fn(usize) -> EngineKind + Send + Sync> {
    let mlp = crate::nn::Mlp::new(
        cfg.model.dims.clone(),
        cfg.model.activation,
        cfg.model.loss,
    );
    Box::new(move |_p| {
        EngineKind::Native(super::engine::NativeEngine::new(mlp.clone()))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::driver::build_dataset;
    use crate::ssp::Policy;

    fn tiny_cfg() -> ExperimentConfig {
        let mut c = ExperimentConfig::tiny();
        c.train.clocks = 10;
        c.train.batches_per_clock = 2;
        c
    }

    #[test]
    fn threaded_run_descends() {
        let cfg = tiny_cfg();
        let ds = build_dataset(&cfg);
        let r = run_threaded(
            &cfg,
            &ds,
            ThreadedOptions {
                machines: 3,
                engine_factory: native_factory(&cfg),
                eta: EtaSchedule::Fixed(cfg.train.eta),
                eval_every: 2,
                eval_samples: 128,
            },
        );
        assert_eq!(r.steps, 3 * 10 * 2);
        assert!(!r.evals.is_empty());
        let first = r.evals.first().unwrap().2;
        assert!(
            r.final_objective < first,
            "{first} -> {}",
            r.final_objective
        );
    }

    #[test]
    fn threaded_bsp_also_works() {
        let mut cfg = tiny_cfg();
        cfg.ssp.policy = Policy::Bsp;
        let ds = build_dataset(&cfg);
        let r = run_threaded(
            &cfg,
            &ds,
            ThreadedOptions {
                machines: 2,
                engine_factory: native_factory(&cfg),
                eta: EtaSchedule::Fixed(cfg.train.eta),
                eval_every: 5,
                eval_samples: 64,
            },
        );
        assert!(r.final_objective.is_finite());
    }
}
