//! Real-thread SSP runner: OS threads + a shared-memory parameter
//! server, the in-process analogue of Petuum's single-node mode. Used by
//! the end-to-end example to prove the coordinator works under true
//! concurrency (the discrete-event driver is the instrument for the
//! paper's figures; this is the deployment-shaped path).
//!
//! Two interchangeable server backends:
//!
//! * `run_threaded` — the **sharded per-layer server**
//!   (`ssp::ShardedServer`) on the **zero-copy hot path**: fetches go
//!   through the version-gated `fetch_into` straight into each worker's
//!   reusable view buffer (only layers whose revision advanced are
//!   copied), minibatches are gathered into per-worker batch buffers,
//!   gradients land in a per-worker buffer, commits hand the
//!   accumulated deltas to the server without cloning them into
//!   messages, and evaluation runs on a **dedicated evaluator thread**:
//!   worker 0 takes a cheap gated snapshot at the clock boundary and
//!   hands the buffer over an mpsc channel, then keeps training while
//!   the evaluator (which owns its own engine and eval set) computes
//!   the objective and sends the buffer back for reuse. The steady
//!   state allocates nothing and copies nothing redundant.
//! * `run_threaded_global` — the original single-lock reference
//!   (`Mutex<Server>` + condvar, full-copy fetch, message-based
//!   commits, eval on worker 0's thread), kept as the baseline the
//!   `sharded_server` bench compares against and as the oracle for the
//!   equivalence tests (for 1 machine the two paths are value-identical
//!   at every eval point and in the final parameters).
//!
//! In shared memory a worker applies its own committed update before its
//! next fetch, so read-my-writes always holds and nothing needs
//! re-folding after a fetch. Under the global lock every committed
//! update is immediately visible (ε ≡ 1); under the sharded server a
//! reader can overlap another worker's in-flight commit and miss part
//! of its in-window update (ε ≤ 1) — exactly the best-effort semantics
//! of Eq. 5 condition 5. The staleness barrier governs how far workers
//! drift in both.
//!
//! The worker loop itself is generic over [`ssp::WorkerPort`]
//! (`run_threaded_on`): `run_threaded` backs it with `&ShardedServer`
//! ports (shared memory), and `ssp::transport::RemoteClient` backs it
//! with one framed-TCP connection set per worker — the same loop,
//! byte-for-byte, across a real process boundary.

use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread;

use crate::config::ExperimentConfig;
use crate::data::Dataset;
use crate::nn::{Labels, ParamSet};
use crate::ssp::{Server, ShardedServer, WorkerPort};
use crate::tensor::Matrix;
use crate::util::Pcg64;

use super::engine::{EngineKind, GradEngine};
use super::EtaSchedule;

pub struct ThreadedOptions {
    pub machines: usize,
    /// Build one engine per thread (engines are not Sync). Called with
    /// the worker index `0..machines` for the training threads, and —
    /// in `run_threaded` — once with index `machines` for the dedicated
    /// evaluator thread; factories that index per-worker state must
    /// accommodate that extra slot.
    pub engine_factory: Box<dyn Fn(usize) -> EngineKind + Send + Sync>,
    pub eta: EtaSchedule,
    /// Log the master objective every this many clocks (on worker 0).
    pub eval_every: u64,
    pub eval_samples: usize,
}

#[derive(Clone, Debug)]
pub struct ThreadedResult {
    pub wall_seconds: f64,
    pub steps: u64,
    /// (clock, wall seconds, objective) evaluation curve.
    pub evals: Vec<(u64, f64, f64)>,
    pub final_objective: f64,
    pub final_params: ParamSet,
}

/// Deterministic run setup shared by both backends — identical seeds
/// produce identical init/eval/shard/batch streams, which is what makes
/// the two paths comparable run-for-run.
struct Setup {
    init: ParamSet,
    eval_x: crate::tensor::Matrix,
    eval_y: crate::nn::Labels,
    shards: Vec<crate::data::Shard>,
}

fn setup(cfg: &ExperimentConfig, dataset: &Dataset, opts: &ThreadedOptions) -> (Setup, Pcg64) {
    let mut root_rng = Pcg64::new(cfg.train.seed);
    let init = super::init_params(cfg);

    // fixed eval subset
    let mut eval_rng = Pcg64::new(cfg.train.seed ^ 0xE7A1);
    let eval_idx: Vec<usize> = (0..opts.eval_samples.min(dataset.n_samples()))
        .map(|_| eval_rng.below(dataset.n_samples()))
        .collect();
    let (eval_x, eval_y) = dataset.gather(&eval_idx);

    let shards = dataset.shard(opts.machines, &mut root_rng.split(1));
    (
        Setup {
            init,
            eval_x,
            eval_y,
            shards,
        },
        root_rng,
    )
}

/// One in-flight evaluation hand-off: worker 0 fills the snapshot
/// buffer with a cheap version-gated copy at the clock boundary (so the
/// evaluated state is exactly the post-commit master, deterministically)
/// and sends it to the evaluator thread; the evaluator computes the
/// objective and sends the package back for reuse. Two packages
/// ping-pong, so the steady state allocates nothing and worker 0 only
/// ever blocks if it laps the evaluator twice.
struct EvalJob {
    clock: u64,
    wall: f64,
    snap: ParamSet,
    last_seen: Vec<u64>,
}

/// Run SSP training on real threads against the **sharded per-layer
/// server**, on the zero-copy hot path (`fetch_into` + reusable batch /
/// gradient buffers + allocation-free commits + evaluator thread). The
/// statistical path matches the simulated driver's (same update rule,
/// same staleness semantics); no global lock and no steady-state
/// allocation anywhere on the hot path.
pub fn run_threaded(
    cfg: &ExperimentConfig,
    dataset: &Dataset,
    opts: ThreadedOptions,
) -> ThreadedResult {
    let machines = opts.machines;
    let policy = cfg.ssp.policy;
    let (su, root_rng) = setup(cfg, dataset, &opts);
    let server = ShardedServer::new(su.init.clone(), machines, policy);
    run_threaded_ports(cfg, dataset, &opts, su, root_rng, |_| &server)
}

/// The same runner over any [`WorkerPort`] backing — the seam the
/// multi-process transport plugs into. `port_for(p)` is called once per
/// worker `0..machines` (each port moves onto that worker's thread, so
/// a remote backing hands every worker its own connection set — exactly
/// the per-process deployment shape) and once more with index
/// `machines` for the final master snapshot. The server behind the
/// ports must hold the same initial parameters this config derives
/// (`coordinator::init_params`); `run_threaded` itself is this function
/// applied to `&ShardedServer` ports.
///
/// Ports may acknowledge commits asynchronously — e.g. a pipelined
/// `transport::RemoteClient` lets `apply_commit`/`commit_clock` return
/// before the server acks, overlapping the next minibatch's compute
/// with the previous clock's network round trips — provided dropping
/// the port flushes everything still in flight. Each worker's port
/// drops when its thread ends, before the scoped join completes, so the
/// final master-snapshot port (index `machines`) always observes every
/// commit.
pub fn run_threaded_on<P: WorkerPort>(
    cfg: &ExperimentConfig,
    dataset: &Dataset,
    opts: ThreadedOptions,
    port_for: impl FnMut(usize) -> P,
) -> ThreadedResult {
    let (su, root_rng) = setup(cfg, dataset, &opts);
    run_threaded_ports(cfg, dataset, &opts, su, root_rng, port_for)
}

fn run_threaded_ports<P: WorkerPort>(
    cfg: &ExperimentConfig,
    dataset: &Dataset,
    opts: &ThreadedOptions,
    su: Setup,
    mut root_rng: Pcg64,
    mut port_for: impl FnMut(usize) -> P,
) -> ThreadedResult {
    let machines = opts.machines;
    let start = std::time::Instant::now();
    let evals = Arc::new(Mutex::new(Vec::new()));

    // evaluation plumbing: requests flow worker 0 → evaluator, drained
    // buffers flow back evaluator → worker 0
    let (eval_tx, eval_rx) = mpsc::channel::<EvalJob>();
    let (pool_tx, pool_rx) = mpsc::channel::<EvalJob>();
    for _ in 0..2 {
        pool_tx
            .send(EvalJob {
                clock: 0,
                wall: 0.0,
                snap: su.init.clone(),
                last_seen: vec![0; su.init.n_layers()],
            })
            .unwrap();
    }

    thread::scope(|scope| {
        // the dedicated evaluator: owns its own engine, borrows the eval
        // set, and reuses the ping-pong snapshot buffers. Exits when
        // worker 0 drops its sender.
        {
            let mut engine = (opts.engine_factory)(machines);
            let evals = Arc::clone(&evals);
            let (eval_x, eval_y) = (&su.eval_x, &su.eval_y);
            let pool_tx = pool_tx.clone();
            scope.spawn(move || {
                while let Ok(job) = eval_rx.recv() {
                    let obj = engine.objective(&job.snap, eval_x, eval_y);
                    evals.lock().unwrap().push((job.clock, job.wall, obj));
                    // hand the buffer back; if the worker is gone the
                    // run is over and the buffer just drops
                    let _ = pool_tx.send(job);
                }
            });
        }
        drop(pool_tx); // only the evaluator refills the pool now

        let mut eval_chan = Some((eval_tx, pool_rx));
        for shard in &su.shards {
            let p = shard.worker();
            // the worker's server port (shared-memory reference or a
            // remote connection set) moves onto its thread
            let mut port = port_for(p);
            let mut engine = (opts.engine_factory)(p);
            let mut batches =
                shard.minibatches(cfg.train.batch, root_rng.split(100 + p as u64));
            let init = su.init.clone();
            let eta = opts.eta;
            // only worker 0 evaluates: it takes the channel pair
            let eval_chan = if p == 0 { eval_chan.take() } else { None };
            let dataset = &*dataset;
            let cfg = &*cfg;
            let opts = &*opts;
            scope.spawn(move || {
                // per-worker reusable buffers: gradient accumulator,
                // batch indices, batch features/labels — written every
                // step, allocated once
                let mut grads = init.zeros_like();
                let mut cache = crate::ssp::WorkerCache::new(p, init);
                let mut idx = Vec::with_capacity(cfg.train.batch);
                let mut bx =
                    Matrix::zeros(cfg.train.batch, dataset.n_features());
                let mut by =
                    Labels::Class(Vec::with_capacity(cfg.train.batch));
                let mut steps: u64 = 0;
                // membership epoch this worker last re-sharded at
                // (fixed-membership ports report epoch 0 forever, so
                // the elastic branch below never fires for them)
                let mut epoch: u64 = 0;
                for clock in 0..cfg.train.clocks as u64 {
                    // barrier + read guarantee: park on the server's
                    // condvar; no parameter state is locked while waiting
                    port.wait_until_ready(p);
                    // version-gated zero-copy fetch straight into the
                    // cache's view buffer: only layers whose revision
                    // advanced since our last fetch move at all (over a
                    // remote port, only those layers ride the wire).
                    // Our own commits were applied by us before this
                    // fetch, so the refreshed view needs no
                    // read-my-writes re-fold.
                    let (buf, seen, own) = cache.refresh_target();
                    port.fetch_view(p, buf, seen, own);

                    // elastic membership: the gated fetch piggybacks the
                    // server's membership epoch; when it moves, re-derive
                    // this worker's data shard from the new live set. The
                    // deal is a pure function of (epoch, seed), so every
                    // survivor lands on the same partition regardless of
                    // which clock it noticed the transition at.
                    let (cur, mask) = port.membership();
                    if cur > epoch {
                        epoch = cur;
                        let shards = dataset.shard_elastic(
                            machines,
                            mask,
                            epoch,
                            cfg.train.seed,
                        );
                        batches = shards[p].minibatches(
                            cfg.train.batch,
                            super::elastic_batch_rng(cfg.train.seed, epoch, p),
                        );
                        crate::info!(
                            "worker {p}: membership epoch {epoch} observed, \
                             re-sharded to {} samples",
                            shards[p].len()
                        );
                    }

                    // compute without holding anything
                    for _ in 0..cfg.train.batches_per_clock {
                        batches.next_batch_into(&mut idx);
                        dataset.gather_into(&idx, &mut bx, &mut by);
                        engine.loss_and_grads_into(
                            cache.view(),
                            &bx,
                            &by,
                            &mut grads,
                        );
                        cache.add_scaled_local_update(-eta.at(steps), &grads);
                        steps += 1;
                    }
                    crate::debug!(
                        "worker {p}: clock {clock} computed ({} steps)",
                        steps
                    );
                    // allocation-free per-shard commit: clock advance is
                    // atomic, each layer's accumulated delta is applied
                    // under only its own shard's lock (no UpdateMsg
                    // clones), waiters get one condvar pulse
                    let committed = cache.clock();
                    port.commit_clock(p);
                    port.apply_commit(p, committed, cache.pending());
                    cache.finish_commit();

                    if let Some((tx, pool)) = &eval_chan {
                        if (clock + 1) % opts.eval_every == 0 {
                            // cheap gated snapshot at the clock boundary
                            // (deterministic state), objective off-thread
                            let mut job =
                                pool.recv().expect("evaluator died");
                            port.snapshot_gated(
                                &mut job.snap,
                                &mut job.last_seen,
                            );
                            job.clock = clock + 1;
                            job.wall = start.elapsed().as_secs_f64();
                            tx.send(job).expect("evaluator died");
                        }
                    }
                }
            });
        }
    });

    let wall_seconds = start.elapsed().as_secs_f64();
    let final_params = port_for(machines).master_snapshot();
    let mut engine = (opts.engine_factory)(0);
    let final_objective = engine.objective(&final_params, &su.eval_x, &su.eval_y);
    let steps =
        (machines * cfg.train.clocks * cfg.train.batches_per_clock) as u64;

    ThreadedResult {
        wall_seconds,
        steps,
        evals: Arc::try_unwrap(evals).unwrap().into_inner().unwrap(),
        final_objective,
        final_params,
    }
}

struct GlobalShared {
    server: Mutex<Server>,
    cv: Condvar,
}

/// The single-lock reference runner: every fetch, commit and eval
/// serializes on one `Mutex<Server>`. Kept as the baseline for the
/// `sharded_server` bench and the equivalence tests.
pub fn run_threaded_global(
    cfg: &ExperimentConfig,
    dataset: &Dataset,
    opts: ThreadedOptions,
) -> ThreadedResult {
    let machines = opts.machines;
    let policy = cfg.ssp.policy;
    let (su, mut root_rng) = setup(cfg, dataset, &opts);

    let shared = Arc::new(GlobalShared {
        server: Mutex::new(Server::new(su.init.clone(), machines, policy)),
        cv: Condvar::new(),
    });

    let start = std::time::Instant::now();
    let evals = Arc::new(Mutex::new(Vec::new()));

    thread::scope(|scope| {
        for shard in &su.shards {
            let p = shard.worker();
            let shared = Arc::clone(&shared);
            let mut engine = (opts.engine_factory)(p);
            let mut batches =
                shard.minibatches(cfg.train.batch, root_rng.split(100 + p as u64));
            let init = su.init.clone();
            let eta = opts.eta;
            let evals = Arc::clone(&evals);
            let (eval_x, eval_y) = (&su.eval_x, &su.eval_y);
            let dataset = &*dataset;
            let cfg = &*cfg;
            let opts = &opts;
            scope.spawn(move || {
                let mut cache = crate::ssp::WorkerCache::new(p, init);
                let mut steps: u64 = 0;
                for clock in 0..cfg.train.clocks as u64 {
                    // barrier + fetch under the lock
                    {
                        let mut srv = shared.server.lock().unwrap();
                        while srv.must_wait(p) {
                            srv = shared.cv.wait(srv).unwrap();
                        }
                        debug_assert!(srv.read_ready(p));
                        let (snap, _own, _stats) = srv.fetch(p);
                        let missing = snap.zeros_like();
                        cache.install_snapshot(snap, &missing);
                    }
                    // compute outside the lock
                    for _ in 0..cfg.train.batches_per_clock {
                        let idx = batches.next_batch();
                        let (x, y) = dataset.gather(&idx);
                        let (_, grads) =
                            engine.loss_and_grads(cache.view(), &x, &y);
                        cache.add_scaled_local_update(-eta.at(steps), &grads);
                        steps += 1;
                    }
                    // commit under the lock: apply updates instantly
                    {
                        let mut srv = shared.server.lock().unwrap();
                        let msgs = cache.commit_clock();
                        srv.commit(p);
                        for m in msgs {
                            srv.apply_arrival(&m);
                        }
                        shared.cv.notify_all();
                        if p == 0 && (clock + 1) % opts.eval_every == 0 {
                            let snap = srv.table().snapshot();
                            drop(srv);
                            let obj = engine.objective(&snap, eval_x, eval_y);
                            evals.lock().unwrap().push((
                                clock + 1,
                                start.elapsed().as_secs_f64(),
                                obj,
                            ));
                        }
                    }
                }
            });
        }
    });

    let wall_seconds = start.elapsed().as_secs_f64();
    let srv = shared.server.lock().unwrap();
    let final_params = srv.table().snapshot();
    drop(srv);
    let mut engine = (opts.engine_factory)(0);
    let final_objective = engine.objective(&final_params, &su.eval_x, &su.eval_y);
    let steps =
        (machines * cfg.train.clocks * cfg.train.batches_per_clock) as u64;

    ThreadedResult {
        wall_seconds,
        steps,
        evals: Arc::try_unwrap(evals).unwrap().into_inner().unwrap(),
        final_objective,
        final_params,
    }
}

/// Convenience: threaded run with native engines.
pub fn native_factory(
    cfg: &ExperimentConfig,
) -> Box<dyn Fn(usize) -> EngineKind + Send + Sync> {
    let mlp = crate::nn::Mlp::new(
        cfg.model.dims.clone(),
        cfg.model.activation,
        cfg.model.loss,
    )
    .with_intra_op_threads(cfg.train.intra_op_threads)
    .with_gemm(cfg.train.gemm_selection().ok());
    Box::new(move |_p| {
        EngineKind::Native(super::engine::NativeEngine::new(mlp.clone()))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::driver::build_dataset;
    use crate::ssp::Policy;

    fn tiny_cfg() -> ExperimentConfig {
        let mut c = ExperimentConfig::tiny();
        c.train.clocks = 10;
        c.train.batches_per_clock = 2;
        c
    }

    fn opts(cfg: &ExperimentConfig, machines: usize) -> ThreadedOptions {
        ThreadedOptions {
            machines,
            engine_factory: native_factory(cfg),
            eta: EtaSchedule::Fixed(cfg.train.eta),
            eval_every: 2,
            eval_samples: 128,
        }
    }

    #[test]
    fn threaded_run_descends() {
        let cfg = tiny_cfg();
        let ds = build_dataset(&cfg);
        let r = run_threaded(&cfg, &ds, opts(&cfg, 3));
        assert_eq!(r.steps, 3 * 10 * 2);
        assert!(!r.evals.is_empty());
        let first = r.evals.first().unwrap().2;
        assert!(
            r.final_objective < first,
            "{first} -> {}",
            r.final_objective
        );
    }

    #[test]
    fn threaded_bsp_also_works() {
        let mut cfg = tiny_cfg();
        cfg.ssp.policy = Policy::Bsp;
        let ds = build_dataset(&cfg);
        let r = run_threaded(&cfg, &ds, opts(&cfg, 2));
        assert!(r.final_objective.is_finite());
    }

    #[test]
    fn global_lock_reference_still_descends() {
        let cfg = tiny_cfg();
        let ds = build_dataset(&cfg);
        let r = run_threaded_global(&cfg, &ds, opts(&cfg, 3));
        assert_eq!(r.steps, 3 * 10 * 2);
        let first = r.evals.first().unwrap().2;
        assert!(r.final_objective < first);
    }

    #[test]
    fn sharded_matches_global_bitwise_on_one_machine() {
        // with a single worker both paths run the exact same sequence of
        // f32 operations: the zero-copy path must be value-identical
        // (identical params, objectives and eval curve; the only bit
        // divergence permitted anywhere is the sign of zero, which no
        // comparison or arithmetic path distinguishes)
        let cfg = tiny_cfg();
        let ds = build_dataset(&cfg);
        let a = run_threaded(&cfg, &ds, opts(&cfg, 1));
        let b = run_threaded_global(&cfg, &ds, opts(&cfg, 1));
        assert_eq!(a.final_params, b.final_params);
        assert_eq!(a.final_objective, b.final_objective);
        let a_curve: Vec<(u64, f64)> =
            a.evals.iter().map(|e| (e.0, e.2)).collect();
        let b_curve: Vec<(u64, f64)> =
            b.evals.iter().map(|e| (e.0, e.2)).collect();
        assert_eq!(a_curve, b_curve);
    }
}
