//! The discrete-event SSP training driver.
//!
//! Executes the paper's Algorithm 1 / Eq. (7) faithfully: P workers, each
//! with a stale cached view θ̃_{p,c}, computing real minibatch gradients
//! against it, committing per-layer additive updates at clock boundaries,
//! with the bounded-staleness barrier, guaranteed-visibility reads,
//! read-my-writes, and best-effort in-window delivery (ε via the network
//! model). Compute and communication take *virtual* time (see DESIGN.md
//! "real statistics, virtual time"); the statistical path is exact.
//!
//! Two implementations of the same run, value-identical by construction
//! (pinned by `tests/property_driver.rs`):
//!
//! * **`run_experiment_with`** — the zero-copy hot loop. One simulated
//!   clock performs zero steady-state allocations: fetches go through
//!   the version-gated [`ParamServer::fetch_into`] straight into each
//!   worker's reusable view buffer, read-my-writes re-folds reuse a
//!   per-worker scratch `GradSet`, minibatches are gathered into
//!   per-worker batch buffers (`next_batch_into` + `gather_into`),
//!   gradients land in a per-worker buffer (`loss_and_grads_into`),
//!   commits recycle pooled own-pending entries and pooled per-layer
//!   arrival slots instead of cloning `UpdateMsg`s, and evaluation
//!   snapshots into a persistent buffer. An allocation audit arms once
//!   every worker passes `DriverOptions::warmup_clocks` and counts any
//!   later growth of the monitored pools (`RunResult::steady_reallocs`).
//! * **`run_experiment_alloc_with`** — the pre-refactor allocating loop,
//!   kept frozen as the bitwise test oracle (`fetch` snapshot clones,
//!   `install_snapshot`, `dataset.gather`, `commit_clock` messages, an
//!   append-only arrivals log).

use std::collections::VecDeque;

use crate::config::{DataKind, ExperimentConfig};
use crate::data::{imagenet_like, timit_like, Dataset, MinibatchIter, SynthSpec};
use crate::net::NetModel;
use crate::nn::{GradSet, Labels, LayerParams, Mlp, OptimState, Optimizer, ParamSet};
use crate::sim::{ComputeModel, EventQueue};
use crate::ssp::{ParamServer, Policy, ReadStats, Server, UpdateMsg, WorkerCache};
use crate::tensor::Matrix;
use crate::util::Pcg64;

use super::engine::{EngineKind, GradEngine, NativeEngine};
use super::trace::{Trace, TraceEvent};
use super::tracker::{EvalPoint, Tracker};
use super::EtaSchedule;

/// Extra knobs on top of `ExperimentConfig` (bench sweeps override these).
pub struct DriverOptions {
    /// Number of worker machines for this run (overrides cluster config).
    pub machines: Option<usize>,
    /// Evaluate the master objective every this many global min-clocks.
    pub eval_every: u64,
    /// Evaluation subset size (fixed random subset of the dataset).
    pub eval_samples: usize,
    /// Learning-rate schedule override (default: fixed at train.eta).
    pub eta: Option<EtaSchedule>,
    /// Virtual seconds one minibatch gradient takes on a paper machine;
    /// `None` = calibrate from a real measured step on this host.
    pub per_batch_s: Option<f64>,
    /// Stop early once the master objective reaches this value.
    pub target_objective: Option<f64>,
    /// Record per-clock parameter snapshots distance (theory runs).
    pub track_master_trajectory: bool,
    /// Gradient engine factory output; `None` = native.
    pub engine: Option<EngineKind>,
    /// Worker-local optimizer (paper: plain SGD).
    pub optimizer: Optimizer,
    /// L2 weight decay coefficient.
    pub weight_decay: f32,
    /// Collect a structured protocol trace (RunResult::trace).
    pub trace: bool,
    /// Zero-copy path only: arm the steady-state allocation audit once
    /// every worker has committed this many clocks. Growth of any
    /// monitored pool after arming counts into
    /// `RunResult::steady_reallocs`.
    pub warmup_clocks: u64,
    /// Scripted membership transitions (zero-copy path only): the
    /// simulated analogue of lease-expiry eviction and re-admission,
    /// letting sweeps price "losing k of m workers at clock t" in
    /// convergence terms. Empty = fixed membership, bitwise identical
    /// to the pre-elastic driver.
    pub membership: Vec<MembershipEvent>,
}

impl Default for DriverOptions {
    fn default() -> Self {
        DriverOptions {
            machines: None,
            eval_every: 2,
            eval_samples: 512,
            eta: None,
            per_batch_s: None,
            target_objective: None,
            track_master_trajectory: false,
            engine: None,
            optimizer: Optimizer::Sgd,
            weight_decay: 0.0,
            trace: false,
            warmup_clocks: 4,
            membership: Vec::new(),
        }
    }
}

/// One scripted membership transition for the simulated driver.
///
/// A **leave** (`join == false`) fires at the victim's own commit
/// boundary: the moment worker `worker` finishes its `at_clock`-th
/// clock it is evicted — its committed history stays in the master,
/// its still-in-flight update messages are dropped (they died with
/// it, which is exactly the ε-accounting case the lease clamp covers),
/// and the survivors re-shard deterministically from the bumped epoch.
///
/// A **join** (`join == true`) fires once the *live* minimum clock
/// reaches `at_clock`: the worker is re-admitted at the live minimum
/// (zero-delta fast-forward, master untouched), warm-starts its cache
/// from its next gated fetch, and takes its slice of the new epoch's
/// re-shard.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MembershipEvent {
    pub at_clock: u64,
    pub worker: usize,
    pub join: bool,
}

/// One membership transition a run actually performed
/// ([`RunResult::membership`]).
#[derive(Clone, Debug, PartialEq)]
pub struct MembershipChange {
    /// Virtual time of the transition.
    pub vtime: f64,
    /// Membership epoch after the transition.
    pub epoch: u64,
    pub worker: usize,
    pub join: bool,
}

/// Outcome of one driver run.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub name: String,
    pub policy: String,
    pub machines: usize,
    /// (virtual seconds, min clock, master objective, param msd, per-layer msd)
    pub evals: Vec<EvalPoint>,
    pub final_objective: f64,
    pub total_vtime: f64,
    /// Virtual seconds workers spent blocked on the staleness barrier.
    pub barrier_wait_s: f64,
    /// Virtual seconds workers spent waiting for guaranteed arrivals.
    pub read_wait_s: f64,
    /// Virtual seconds of pure compute.
    pub compute_s: f64,
    pub messages: u64,
    pub bytes: u64,
    pub congestion_events: u64,
    /// Aggregated ε statistics over all reads.
    pub epsilon_rate: f64,
    pub reads: u64,
    /// Total minibatch steps executed across workers.
    pub steps: u64,
    /// Mean training loss per clock index (averaged over workers).
    pub clock_loss: Vec<f64>,
    /// Master parameter trajectory (only if track_master_trajectory).
    pub master_trajectory: Vec<ParamSet>,
    /// Final master parameters.
    pub final_params: ParamSet,
    /// Structured protocol trace (only if DriverOptions::trace).
    pub trace: Option<Trace>,
    /// Allocation-growth events on the zero-copy driver's monitored
    /// pools (event-queue heap, arrival slots, own-pending entries)
    /// after the warmup audit armed. 0 at steady state; always 0 on the
    /// allocating oracle path, which is not audited.
    pub steady_reallocs: u64,
    /// Membership transitions performed (scripted leaves/joins on the
    /// zero-copy path; always empty on the allocating oracle).
    pub membership: Vec<MembershipChange>,
}

#[derive(Clone, Copy, Debug, PartialEq)]
enum WorkerStatus {
    Ready,
    Blocked,
    Done,
}

#[derive(Clone, Debug, PartialEq)]
enum Payload {
    StartClock { worker: usize },
    ComputeDone { worker: usize },
    Arrival { idx: usize },
}

/// Build the dataset described by the config.
pub fn build_dataset(cfg: &ExperimentConfig) -> Dataset {
    let mut rng = Pcg64::new(cfg.data.seed);
    let spec = SynthSpec {
        n_samples: cfg.data.n_samples,
        n_features: cfg.data.n_features,
        n_classes: cfg.data.n_classes,
        ..match cfg.data.kind {
            DataKind::TimitLike => SynthSpec::timit_default(),
            DataKind::ImagenetLike => SynthSpec::imagenet_default(),
        }
    };
    match cfg.data.kind {
        DataKind::TimitLike => timit_like(&spec).generate(&mut rng),
        DataKind::ImagenetLike => imagenet_like(&spec).generate(&mut rng),
    }
}

/// Measure one real gradient step to calibrate the compute model
/// (allocating oracle path).
fn measure_per_batch(
    engine: &mut EngineKind,
    params: &ParamSet,
    x: &Matrix,
    y: &Labels,
    cores: usize,
) -> f64 {
    // warmup + 3 measurements, take the min (steady-state)
    engine.loss_and_grads(params, x, y);
    let mut best = f64::INFINITY;
    for _ in 0..3 {
        let t = std::time::Instant::now();
        engine.loss_and_grads(params, x, y);
        best = best.min(t.elapsed().as_secs_f64());
    }
    ComputeModel::calibrated_per_batch(best, cores)
}

/// Same calibration through the caller's reusable gradient buffer — the
/// zero-copy path measures the exact step it will run. Also used by the
/// sweep harness, which calibrates once and shares the value across
/// every cell so virtual-time axes are comparable.
pub(crate) fn measure_per_batch_into(
    engine: &mut EngineKind,
    params: &ParamSet,
    x: &Matrix,
    y: &Labels,
    grads: &mut GradSet,
    cores: usize,
) -> f64 {
    engine.loss_and_grads_into(params, x, y, grads);
    let mut best = f64::INFINITY;
    for _ in 0..3 {
        let t = std::time::Instant::now();
        engine.loss_and_grads_into(params, x, y, grads);
        best = best.min(t.elapsed().as_secs_f64());
    }
    ComputeModel::calibrated_per_batch(best, cores)
}

/// Run one full SSP training experiment under the given config.
pub fn run_experiment(cfg: &ExperimentConfig, opts: DriverOptions) -> RunResult {
    let dataset = build_dataset(cfg);
    run_experiment_on(cfg, opts, &dataset)
}

/// Same, with a pre-built dataset (benches reuse one dataset across the
/// machine sweep so curves are comparable). Uses the single-lock
/// reference `Server` on the zero-copy hot loop.
pub fn run_experiment_on(
    cfg: &ExperimentConfig,
    opts: DriverOptions,
    dataset: &Dataset,
) -> RunResult {
    run_experiment_with(cfg, opts, dataset, Server::new)
}

/// The pre-refactor allocating driver on the reference `Server`, kept as
/// the value-equality oracle for the zero-copy loop.
pub fn run_experiment_alloc_on(
    cfg: &ExperimentConfig,
    opts: DriverOptions,
    dataset: &Dataset,
) -> RunResult {
    run_experiment_alloc_with(cfg, opts, dataset, Server::new)
}

// ======================================================================
// The zero-copy driver (default path)
// ======================================================================

/// One pooled in-flight update message. A slot is referenced by exactly
/// one scheduled `Arrival` event and recycled into its layer's free list
/// the moment that event fires (the network model never drops a message
/// outright — congestion only delays it — so every slot comes back).
struct ArrivalSlot {
    msg: UpdateMsg,
    /// Virtual send time (trace delay accounting).
    sent: f64,
}

/// Reusable backing storage for the in-flight update queue: the
/// allocating oracle appends every message of the whole run to a vector;
/// this pool instead recycles slots per layer (layer shapes differ, so a
/// delta buffer is only reusable within its own layer). After warmup the
/// in-flight population is bounded and `allocs` stops moving — which the
/// steady-state audit asserts.
struct ArrivalPool {
    slots: Vec<ArrivalSlot>,
    /// Free slot indices, per layer.
    free: Vec<Vec<usize>>,
    /// Slots ever allocated (allocation audit).
    allocs: u64,
}

impl ArrivalPool {
    fn new(layers: usize) -> ArrivalPool {
        ArrivalPool {
            slots: Vec::new(),
            free: vec![Vec::new(); layers],
            allocs: 0,
        }
    }

    /// Fill a slot (recycled if possible) with one layer's committed
    /// delta and return its index for the `Arrival` event payload.
    fn acquire(
        &mut self,
        from: usize,
        clock: u64,
        layer: usize,
        delta: &LayerParams,
        sent: f64,
    ) -> usize {
        if let Some(i) = self.free[layer].pop() {
            let slot = &mut self.slots[i];
            debug_assert_eq!(slot.msg.layer, layer);
            slot.msg.from = from;
            slot.msg.clock = clock;
            slot.msg.delta.copy_from(delta);
            slot.sent = sent;
            i
        } else {
            self.allocs += 1;
            self.slots.push(ArrivalSlot {
                msg: UpdateMsg::new(from, clock, layer, delta.clone()),
                sent,
            });
            self.slots.len() - 1
        }
    }

    /// The slot's arrival fired and was applied: recycle it.
    fn release(&mut self, idx: usize) {
        let layer = self.slots[idx].msg.layer;
        self.free[layer].push(idx);
    }
}

/// Steady-state allocation audit: capacities/allocation counters of the
/// monitored reusable structures, captured once every worker passes the
/// warmup clock. Any later growth is a reallocation the zero-copy path
/// promised not to make. (Instrumentation output — eval points, traces,
/// the optional master trajectory — is bounded per eval and exempt.)
struct AllocAudit {
    armed: bool,
    queue_cap: usize,
    arrival_allocs: u64,
    own_allocs: u64,
}

impl AllocAudit {
    fn new() -> AllocAudit {
        AllocAudit {
            armed: false,
            queue_cap: 0,
            arrival_allocs: 0,
            own_allocs: 0,
        }
    }

    fn arm(&mut self, queue_cap: usize, arrival_allocs: u64, own_allocs: u64) {
        self.armed = true;
        self.queue_cap = queue_cap;
        self.arrival_allocs = arrival_allocs;
        self.own_allocs = own_allocs;
    }

    fn growth(&self, queue_cap: usize, arrival_allocs: u64, own_allocs: u64) -> u64 {
        if !self.armed {
            return 0;
        }
        u64::from(queue_cap > self.queue_cap)
            + (arrival_allocs - self.arrival_allocs)
            + (own_allocs - self.own_allocs)
    }
}

/// Per-worker state of the zero-copy loop: every buffer a clock needs,
/// allocated once.
struct ZcWorker {
    cache: WorkerCache,
    optim: OptimState,
    batches: MinibatchIter,
    /// Own committed-but-possibly-unapplied updates: (clock, per-layer).
    own_pending: VecDeque<(u64, GradSet)>,
    /// Recycled own-pending entries (drained once fully applied).
    own_pool: Vec<GradSet>,
    /// Entries ever allocated (allocation audit).
    own_allocs: u64,
    /// Gradient buffer (`loss_and_grads_into` target).
    grads: GradSet,
    /// Read-my-writes reconstruction scratch.
    own_missing: GradSet,
    /// Layers `own_missing` currently holds a (possibly zero) re-fold
    /// for — zeroed lazily at the next fetch.
    missing_mask: Vec<bool>,
    /// Minibatch index / feature / label buffers.
    idx: Vec<usize>,
    bx: Matrix,
    by: Labels,
    status: WorkerStatus,
    blocked_on_barrier: bool,
    clocks_done: u64,
}

/// The generic zero-copy driver: any [`ParamServer`] implementation can
/// back the simulated figures — the single-lock reference `Server`
/// (default) or the sharded per-layer `ShardedServer`. Given the same
/// config the two produce bitwise-identical runs, and both reproduce the
/// allocating oracle (`run_experiment_alloc_with`) value-for-value: the
/// zero-copy loop performs the same f32 operations in the same order,
/// the only permitted bit divergence being the sign of zero
/// (`tests/property_driver.rs` pins all three pairings).
pub fn run_experiment_with<S: ParamServer>(
    cfg: &ExperimentConfig,
    mut opts: DriverOptions,
    dataset: &Dataset,
    make_server: impl FnOnce(ParamSet, usize, Policy) -> S,
) -> RunResult {
    let machines = opts.machines.unwrap_or(cfg.cluster.machines);
    assert!(machines >= 1);
    let policy = cfg.ssp.policy;
    let mut root_rng = Pcg64::new(cfg.train.seed);

    let mlp = Mlp::new(
        cfg.model.dims.clone(),
        cfg.model.activation,
        cfg.model.loss,
    )
    .with_intra_op_threads(cfg.train.intra_op_threads)
    .with_gemm(cfg.train.gemm_selection().ok());
    let mut engine = opts
        .engine
        .take()
        .unwrap_or_else(|| EngineKind::Native(NativeEngine::new(mlp.clone())));

    // init params — same seed across machine counts so trajectories match
    // (shared derivation: the serve deployment path builds the remote
    // server from the same bits)
    let init = super::init_params(cfg);
    let model_bytes = init.n_params() * 4;
    let n_layers = init.n_layers();

    // evaluation subset (fixed), gathered once into a persistent
    // workspace the eval path reuses for the whole run
    let mut eval_rng = Pcg64::new(cfg.train.seed ^ 0xE7A1);
    let eval_idx: Vec<usize> = (0..opts.eval_samples.min(dataset.n_samples()))
        .map(|_| eval_rng.below(dataset.n_samples()))
        .collect();
    let mut eval_x = Matrix::zeros(eval_idx.len(), dataset.n_features());
    let mut eval_y = Labels::Class(Vec::with_capacity(eval_idx.len()));
    dataset.gather_into(&eval_idx, &mut eval_x, &mut eval_y);

    // shards & workers
    let shards = dataset.shard(machines, &mut root_rng.split(1));
    let mut workers: Vec<ZcWorker> = shards
        .iter()
        .map(|sh| ZcWorker {
            cache: WorkerCache::new(sh.worker(), init.clone()),
            optim: OptimState::new(opts.optimizer, opts.weight_decay),
            batches: sh.minibatches(cfg.train.batch, root_rng.split(100 + sh.worker() as u64)),
            own_pending: VecDeque::new(),
            own_pool: Vec::new(),
            own_allocs: 0,
            grads: init.zeros_like(),
            own_missing: init.zeros_like(),
            missing_mask: vec![false; n_layers],
            idx: Vec::with_capacity(cfg.train.batch),
            bx: Matrix::zeros(cfg.train.batch, dataset.n_features()),
            by: Labels::Class(Vec::with_capacity(cfg.train.batch)),
            status: WorkerStatus::Ready,
            blocked_on_barrier: false,
            clocks_done: 0,
        })
        .collect();

    let mut server = make_server(init.clone(), machines, policy);
    let mut net = NetModel::new(&cfg.cluster, machines, root_rng.split(2));

    // calibrate compute model through worker 0's persistent batch
    // workspace (same batch-RNG consumption as the oracle)
    let per_batch_s = opts.per_batch_s.unwrap_or_else(|| {
        let w0 = &mut workers[0];
        w0.batches.next_batch_into(&mut w0.idx);
        dataset.gather_into(&w0.idx, &mut w0.bx, &mut w0.by);
        measure_per_batch_into(
            &mut engine,
            &init,
            &w0.bx,
            &w0.by,
            &mut w0.grads,
            cfg.cluster.cores_per_machine,
        )
    });
    let mut compute =
        ComputeModel::new(&cfg.cluster, per_batch_s, machines, root_rng.split(3));

    let eta = opts.eta.unwrap_or(EtaSchedule::Fixed(cfg.train.eta));

    let mut queue: EventQueue<Payload> = EventQueue::new();
    let mut arrivals = ArrivalPool::new(n_layers);
    let mut trace = opts.trace.then(Trace::default);

    let mut tracker = Tracker::new();
    let mut eval_snap = init.clone();
    let mut barrier_wait = vec![0.0f64; machines];
    let mut read_wait = vec![0.0f64; machines];
    let mut block_start = vec![0.0f64; machines];
    let mut compute_s = 0.0f64;
    let mut steps: u64 = 0;
    let mut eps_acc = ReadStats::default();
    // preallocated to the clock horizon: in-loop resizes stay in place
    let mut clock_loss_sum: Vec<f64> = Vec::with_capacity(cfg.train.clocks);
    let mut clock_loss_cnt: Vec<u64> = Vec::with_capacity(cfg.train.clocks);
    let mut last_eval_clock: i64 = -1;
    let mut master_trajectory = Vec::new();
    let mut reached_target = false;
    let mut audit = AllocAudit::new();

    // scripted membership (the elastic-eviction sim). With no events the
    // machinery below is inert — `alive` stays all-true, no arrival is
    // ever dropped — and the run is bitwise identical to fixed
    // membership.
    let mut pending_members = std::mem::take(&mut opts.membership);
    if !pending_members.is_empty() {
        assert!(
            machines <= 64,
            "membership events support at most 64 workers (live-mask width)"
        );
    }
    let mut alive = vec![true; machines];
    // virtual time of each worker's latest eviction: arrivals it sent at
    // or before that instant are its in-flight updates — they died with
    // it and are dropped (exactly once) instead of applied
    let mut drop_before = vec![f64::NEG_INFINITY; machines];
    let mut membership_log: Vec<MembershipChange> = Vec::new();

    for p in 0..machines {
        queue.push(0.0, Payload::StartClock { worker: p });
    }

    // ---- the event loop ----
    while let Some(ev) = queue.pop() {
        let now = ev.time;
        match ev.payload {
            Payload::StartClock { worker } => {
                try_start_clock(
                    worker,
                    now,
                    cfg,
                    &mut workers[worker],
                    &mut server,
                    &mut engine,
                    dataset,
                    &eta,
                    &mut compute,
                    &mut net,
                    model_bytes,
                    &mut queue,
                    &mut block_start,
                    &mut eps_acc,
                    &mut steps,
                    &mut compute_s,
                    &mut clock_loss_sum,
                    &mut clock_loss_cnt,
                    trace.as_mut(),
                );
            }
            Payload::ComputeDone { worker } => {
                let w = &mut workers[worker];
                // commit: recycle an own-pending entry, absorb the
                // accumulated deltas without cloning messages
                let committed = w.cache.clock();
                let mut own = match w.own_pool.pop() {
                    Some(g) => g,
                    None => {
                        w.own_allocs += 1;
                        init.zeros_like()
                    }
                };
                own.copy_from(w.cache.pending());
                w.own_pending.push_back((committed, own));
                w.clocks_done += 1;
                server.commit(worker);
                if let Some(tr) = trace.as_mut() {
                    tr.push(
                        now,
                        TraceEvent::Commit {
                            worker,
                            clock: w.clocks_done - 1,
                        },
                    );
                }
                for layer in 0..n_layers {
                    let idx = arrivals.acquire(
                        worker,
                        committed,
                        layer,
                        &w.cache.pending().layers[layer],
                        now,
                    );
                    let bytes = arrivals.slots[idx].msg.bytes;
                    let t = net.arrival_time(worker, now, bytes);
                    queue.push(t, Payload::Arrival { idx });
                }
                w.cache.finish_commit();
                let leaving = pending_members.iter().position(|e| {
                    !e.join && e.worker == worker && e.at_clock == w.clocks_done
                });
                if leaving.is_some()
                    || w.clocks_done >= cfg.train.clocks as u64
                    || reached_target
                {
                    w.status = WorkerStatus::Done;
                } else {
                    w.status = WorkerStatus::Ready;
                    queue.push(now, Payload::StartClock { worker });
                }
                if let Some(i) = leaving {
                    let e = pending_members.swap_remove(i);
                    let epoch = server.evict_worker(e.worker);
                    alive[e.worker] = false;
                    drop_before[e.worker] = now;
                    membership_log.push(MembershipChange {
                        vtime: now,
                        epoch,
                        worker: e.worker,
                        join: false,
                    });
                    rebalance_live(
                        dataset,
                        &mut workers,
                        &alive,
                        epoch,
                        cfg.train.batch,
                        cfg.train.seed,
                    );
                }
                // a commit (or an eviction) can unblock barrier waiters
                wake_blocked(&mut workers, &server, now, &mut queue, &mut barrier_wait, &mut read_wait, &mut block_start, trace.as_mut());

                // evaluation at live min-clock boundaries (a frozen dead
                // clock must not pin evaluation forever)
                let Some(min_clock) = (0..machines)
                    .filter(|&p| alive[p])
                    .map(|p| workers[p].clocks_done)
                    .min()
                else {
                    continue;
                };
                if min_clock as i64 > last_eval_clock
                    && min_clock % opts.eval_every == 0
                {
                    last_eval_clock = min_clock as i64;
                    server.snapshot_into(&mut eval_snap);
                    let obj = engine.objective(&eval_snap, &eval_x, &eval_y);
                    if let Some(tr) = trace.as_mut() {
                        tr.push(
                            now,
                            TraceEvent::Eval {
                                clock: min_clock,
                                objective: obj,
                            },
                        );
                    }
                    tracker.record(now, min_clock, obj, &eval_snap);
                    if opts.track_master_trajectory {
                        master_trajectory.push(eval_snap.clone());
                    }
                    if let Some(t) = opts.target_objective {
                        if obj <= t {
                            reached_target = true;
                        }
                    }
                }
                if !audit.armed && min_clock >= opts.warmup_clocks {
                    let own_allocs: u64 =
                        workers.iter().map(|w| w.own_allocs).sum();
                    audit.arm(queue.capacity(), arrivals.allocs, own_allocs);
                }

                // joins fire once the live minimum reaches their clock:
                // the rejoiner is admitted at the live min (zero-delta
                // fast-forward), resumes its cache there, and everyone
                // re-shards from the bumped epoch
                while let Some(i) = pending_members
                    .iter()
                    .position(|e| e.join && min_clock >= e.at_clock)
                {
                    let e = pending_members.swap_remove(i);
                    if alive[e.worker] {
                        continue; // already a member: nothing to do
                    }
                    let epoch = server.admit_worker(e.worker);
                    alive[e.worker] = true;
                    let resume = server.clock(e.worker);
                    let w = &mut workers[e.worker];
                    w.cache.resume_at(resume);
                    w.clocks_done = resume;
                    // pre-crash commits died with the old incarnation:
                    // nothing of theirs is still owed a refold
                    while let Some((_, g)) = w.own_pending.pop_front() {
                        w.own_pool.push(g);
                    }
                    w.status = WorkerStatus::Ready;
                    membership_log.push(MembershipChange {
                        vtime: now,
                        epoch,
                        worker: e.worker,
                        join: true,
                    });
                    rebalance_live(
                        dataset,
                        &mut workers,
                        &alive,
                        epoch,
                        cfg.train.batch,
                        cfg.train.seed,
                    );
                    queue.push(now, Payload::StartClock { worker: e.worker });
                }
            }
            Payload::Arrival { idx } => {
                let (from, sent) =
                    (arrivals.slots[idx].msg.from, arrivals.slots[idx].sent);
                if sent <= drop_before[from] {
                    // the sender was evicted with this update in flight:
                    // it never reaches the master (its *applied* counts
                    // freeze below its committed clock — the ε clamp's
                    // case) and must not race a rejoin's fast-forwarded
                    // version rows
                    arrivals.release(idx);
                    continue;
                }
                {
                    let slot = &arrivals.slots[idx];
                    server.apply_arrival(&slot.msg);
                    if let Some(tr) = trace.as_mut() {
                        tr.push(
                            now,
                            TraceEvent::Arrival {
                                worker: slot.msg.from,
                                clock: slot.msg.clock,
                                layer: slot.msg.layer,
                                delay_s: now - slot.sent,
                            },
                        );
                    }
                }
                arrivals.release(idx);
                wake_blocked(&mut workers, &server, now, &mut queue, &mut barrier_wait, &mut read_wait, &mut block_start, trace.as_mut());
            }
        }
    }

    let total_vtime = queue.now();
    let final_params = server.snapshot();
    let final_objective = engine.objective(&final_params, &eval_x, &eval_y);
    let own_allocs: u64 = workers.iter().map(|w| w.own_allocs).sum();
    let steady_reallocs =
        audit.growth(queue.capacity(), arrivals.allocs, own_allocs);

    let clock_loss: Vec<f64> = clock_loss_sum
        .iter()
        .zip(&clock_loss_cnt)
        .map(|(s, c)| if *c > 0 { s / *c as f64 } else { f64::NAN })
        .collect();

    RunResult {
        name: cfg.name.clone(),
        policy: policy.name(),
        machines,
        evals: tracker.into_points(),
        final_objective,
        total_vtime,
        barrier_wait_s: barrier_wait.iter().sum(),
        read_wait_s: read_wait.iter().sum(),
        compute_s,
        messages: net.messages(),
        bytes: net.bytes(),
        congestion_events: net.congestion_events(),
        epsilon_rate: eps_acc.epsilon_rate(),
        reads: server.reads(),
        steps,
        clock_loss,
        master_trajectory,
        final_params,
        trace,
        steady_reallocs,
        membership: membership_log,
    }
}

/// Deterministic post-transition re-shard: survivors re-derive their
/// data shards and minibatch streams from `(epoch, seed)` alone — not
/// from any live rng state — so a membership history replays
/// bit-for-bit no matter when each transition was observed. Dead
/// workers keep their (now empty) slots; indices stay worker-aligned.
fn rebalance_live(
    dataset: &Dataset,
    workers: &mut [ZcWorker],
    alive: &[bool],
    epoch: u64,
    batch: usize,
    seed: u64,
) {
    let mask = alive
        .iter()
        .enumerate()
        .fold(0u64, |m, (w, &a)| if a { m | (1u64 << (w & 63)) } else { m });
    let shards = dataset.shard_elastic(workers.len(), mask, epoch, seed);
    for sh in &shards {
        let w = sh.worker();
        if alive[w] {
            workers[w].batches =
                sh.minibatches(batch, super::elastic_batch_rng(seed, epoch, w));
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn try_start_clock<S: ParamServer>(
    worker: usize,
    now: f64,
    cfg: &ExperimentConfig,
    w: &mut ZcWorker,
    server: &mut S,
    engine: &mut EngineKind,
    dataset: &Dataset,
    eta: &EtaSchedule,
    compute: &mut ComputeModel,
    net: &mut NetModel,
    model_bytes: usize,
    queue: &mut EventQueue<Payload>,
    block_start: &mut [f64],
    eps_acc: &mut ReadStats,
    steps: &mut u64,
    compute_s: &mut f64,
    clock_loss_sum: &mut Vec<f64>,
    clock_loss_cnt: &mut Vec<u64>,
    mut trace: Option<&mut Trace>,
) {
    if w.status == WorkerStatus::Done {
        return;
    }
    if server.must_wait(worker) || !server.read_ready(worker) {
        if w.status != WorkerStatus::Blocked {
            w.status = WorkerStatus::Blocked;
            w.blocked_on_barrier = server.must_wait(worker);
            block_start[worker] = now;
            if let Some(tr) = trace.as_deref_mut() {
                tr.push(
                    now,
                    TraceEvent::BlockStart {
                        worker,
                        on_barrier: w.blocked_on_barrier,
                    },
                );
            }
        }
        return;
    }
    w.status = WorkerStatus::Ready;
    if let Some(tr) = trace.as_deref_mut() {
        let max_clock = (0..server.workers())
            .map(|q| server.clock(q))
            .max()
            .unwrap_or(0);
        let observed = max_clock - server.clock(worker);
        tr.push(
            now,
            TraceEvent::ClockStart {
                worker,
                clock: server.clock(worker),
                observed_staleness: observed,
            },
        );
    }

    // ---- version-gated zero-copy fetch straight into the view ----
    {
        let (buf, seen, own) = w.cache.refresh_target();
        let (stats, _fs) = server.fetch_into(worker, buf, seen, own);
        eps_acc.guaranteed += stats.guaranteed;
        eps_acc.window_included += stats.window_included;
        eps_acc.window_missed += stats.window_missed;
    }

    // reconstruct own not-yet-applied updates layerwise into the
    // persistent scratch; only layers the previous reconstruction
    // dirtied need re-zeroing
    for l in 0..w.missing_mask.len() {
        if w.missing_mask[l] {
            let lp = &mut w.own_missing.layers[l];
            lp.w.fill(0.0);
            lp.b.fill(0.0);
            w.missing_mask[l] = false;
        }
    }
    let own_applied = w.cache.own_applied();
    for (clk, upd) in &w.own_pending {
        for (l, layer) in upd.layers.iter().enumerate() {
            if *clk >= own_applied[l] {
                w.own_missing.axpy_layer(l, 1.0, layer);
                w.missing_mask[l] = true;
            }
        }
    }
    // prune fully-applied entries back into the pool
    let min_applied = own_applied.iter().copied().min().unwrap_or(0);
    while let Some((clk, _)) = w.own_pending.front() {
        if *clk < min_applied {
            let (_, g) = w.own_pending.pop_front().unwrap();
            w.own_pool.push(g);
        } else {
            break;
        }
    }
    w.cache.refold_own_missing(&w.own_missing, &w.missing_mask);

    // ---- compute the clock's minibatches (real gradients) ----
    let clock = w.cache.clock();
    let mut loss_sum = 0.0;
    for _ in 0..cfg.train.batches_per_clock {
        w.batches.next_batch_into(&mut w.idx);
        dataset.gather_into(&w.idx, &mut w.bx, &mut w.by);
        let loss =
            engine.loss_and_grads_into(w.cache.view(), &w.bx, &w.by, &mut w.grads);
        let step_eta = eta.at(*steps);
        let dir = w.optim.direction(w.cache.view(), &w.grads);
        w.cache.add_scaled_local_update(-step_eta, dir);
        loss_sum += loss;
        *steps += 1;
    }
    let mean_loss = loss_sum / cfg.train.batches_per_clock as f64;
    let ci = clock as usize;
    if clock_loss_sum.len() <= ci {
        clock_loss_sum.resize(ci + 1, 0.0);
        clock_loss_cnt.resize(ci + 1, 0);
    }
    clock_loss_sum[ci] += mean_loss;
    clock_loss_cnt[ci] += 1;

    // ---- virtual durations ----
    let fetch_cost = net.fetch_duration(model_bytes);
    let dur = compute.clock_duration(worker, cfg.train.batches_per_clock);
    *compute_s += dur;
    queue.push(now + fetch_cost + dur, Payload::ComputeDone { worker });
}

#[allow(clippy::too_many_arguments)]
fn wake_blocked<S: ParamServer>(
    workers: &mut [ZcWorker],
    server: &S,
    now: f64,
    queue: &mut EventQueue<Payload>,
    barrier_wait: &mut [f64],
    read_wait: &mut [f64],
    block_start: &mut [f64],
    mut trace: Option<&mut Trace>,
) {
    for p in 0..workers.len() {
        if workers[p].status == WorkerStatus::Blocked {
            let barrier = server.must_wait(p);
            let read = !server.read_ready(p);
            if !barrier && !read {
                let waited = now - block_start[p];
                if workers[p].blocked_on_barrier {
                    barrier_wait[p] += waited;
                } else {
                    read_wait[p] += waited;
                }
                if let Some(tr) = trace.as_deref_mut() {
                    tr.push(now, TraceEvent::BlockEnd { worker: p, waited_s: waited });
                }
                workers[p].status = WorkerStatus::Ready;
                queue.push(now, Payload::StartClock { worker: p });
            }
        }
    }
}

// ======================================================================
// The allocating oracle (pre-refactor loop, frozen)
// ======================================================================

struct AllocWorkerState {
    cache: WorkerCache,
    optim: OptimState,
    batches: MinibatchIter,
    /// Own committed-but-possibly-unapplied updates: (clock, per-layer).
    own_pending: VecDeque<(u64, GradSet)>,
    status: WorkerStatus,
    blocked_on_barrier: bool,
    clocks_done: u64,
}

/// The pre-refactor allocating driver, generic over [`ParamServer`]:
/// per-clock `fetch` snapshot clones, `install_snapshot`, allocating
/// `dataset.gather`, `commit_clock` message clones and an append-only
/// arrivals log. Kept verbatim as the value-equality oracle the
/// zero-copy loop is tested against — do not optimize this path.
pub fn run_experiment_alloc_with<S: ParamServer>(
    cfg: &ExperimentConfig,
    mut opts: DriverOptions,
    dataset: &Dataset,
    make_server: impl FnOnce(ParamSet, usize, Policy) -> S,
) -> RunResult {
    let machines = opts.machines.unwrap_or(cfg.cluster.machines);
    assert!(machines >= 1);
    let policy = cfg.ssp.policy;
    let mut root_rng = Pcg64::new(cfg.train.seed);

    let mlp = Mlp::new(
        cfg.model.dims.clone(),
        cfg.model.activation,
        cfg.model.loss,
    )
    .with_intra_op_threads(cfg.train.intra_op_threads)
    .with_gemm(cfg.train.gemm_selection().ok());
    let mut engine = opts
        .engine
        .take()
        .unwrap_or_else(|| EngineKind::Native(NativeEngine::new(mlp.clone())));

    // init params — same seed across machine counts so trajectories match
    // (shared derivation: the serve deployment path builds the remote
    // server from the same bits)
    let init = super::init_params(cfg);
    let model_bytes = init.n_params() * 4;

    // evaluation subset (fixed)
    let mut eval_rng = Pcg64::new(cfg.train.seed ^ 0xE7A1);
    let eval_idx: Vec<usize> = (0..opts.eval_samples.min(dataset.n_samples()))
        .map(|_| eval_rng.below(dataset.n_samples()))
        .collect();
    let (eval_x, eval_y) = dataset.gather(&eval_idx);

    // shards & workers
    let shards = dataset.shard(machines, &mut root_rng.split(1));
    let mut workers: Vec<AllocWorkerState> = shards
        .iter()
        .map(|sh| AllocWorkerState {
            cache: WorkerCache::new(sh.worker(), init.clone()),
            optim: OptimState::new(opts.optimizer, opts.weight_decay),
            batches: sh.minibatches(cfg.train.batch, root_rng.split(100 + sh.worker() as u64)),
            own_pending: VecDeque::new(),
            status: WorkerStatus::Ready,
            blocked_on_barrier: false,
            clocks_done: 0,
        })
        .collect();

    let mut server = make_server(init.clone(), machines, policy);
    let mut net = NetModel::new(&cfg.cluster, machines, root_rng.split(2));

    // calibrate compute model
    let per_batch_s = opts.per_batch_s.unwrap_or_else(|| {
        let idx = workers[0].batches.next_batch();
        let (x, y) = dataset.gather(&idx);
        measure_per_batch(&mut engine, &init, &x, &y, cfg.cluster.cores_per_machine)
    });
    let mut compute =
        ComputeModel::new(&cfg.cluster, per_batch_s, machines, root_rng.split(3));

    let eta = opts.eta.unwrap_or(EtaSchedule::Fixed(cfg.train.eta));

    let mut queue: EventQueue<Payload> = EventQueue::new();
    let mut arrivals: Vec<(UpdateMsg, f64)> = Vec::new(); // (msg, send time)
    let mut trace = opts.trace.then(Trace::default);

    let mut tracker = Tracker::new();
    let mut barrier_wait = vec![0.0f64; machines];
    let mut read_wait = vec![0.0f64; machines];
    let mut block_start = vec![0.0f64; machines];
    let mut compute_s = 0.0f64;
    let mut steps: u64 = 0;
    let mut eps_acc = ReadStats::default();
    let mut clock_loss_sum: Vec<f64> = Vec::new();
    let mut clock_loss_cnt: Vec<u64> = Vec::new();
    let mut last_eval_clock: i64 = -1;
    let mut master_trajectory = Vec::new();
    let mut reached_target = false;

    for p in 0..machines {
        queue.push(0.0, Payload::StartClock { worker: p });
    }

    // ---- the event loop ----
    while let Some(ev) = queue.pop() {
        let now = ev.time;
        match ev.payload {
            Payload::StartClock { worker } => {
                try_start_clock_alloc(
                    worker,
                    now,
                    cfg,
                    &mut workers[worker],
                    &mut server,
                    &mut engine,
                    dataset,
                    &eta,
                    &mut compute,
                    &mut net,
                    model_bytes,
                    &mut queue,
                    &mut block_start,
                    &mut eps_acc,
                    &mut steps,
                    &mut compute_s,
                    &mut clock_loss_sum,
                    &mut clock_loss_cnt,
                    trace.as_mut(),
                );
            }
            Payload::ComputeDone { worker } => {
                let w = &mut workers[worker];
                // commit: drain pending into per-layer messages
                let msgs = w.cache.commit_clock();
                let mut own = init.zeros_like();
                for m in &msgs {
                    own.layers[m.layer] = m.delta.clone();
                }
                w.own_pending.push_back((w.clocks_done, own));
                w.clocks_done += 1;
                server.commit(worker);
                if let Some(tr) = trace.as_mut() {
                    tr.push(
                        now,
                        TraceEvent::Commit {
                            worker,
                            clock: w.clocks_done - 1,
                        },
                    );
                }
                for m in msgs {
                    let t = net.arrival_time(worker, now, m.bytes);
                    arrivals.push((m, now));
                    queue.push(
                        t,
                        Payload::Arrival {
                            idx: arrivals.len() - 1,
                        },
                    );
                }
                if w.clocks_done >= cfg.train.clocks as u64 || reached_target {
                    w.status = WorkerStatus::Done;
                } else {
                    w.status = WorkerStatus::Ready;
                    queue.push(now, Payload::StartClock { worker });
                }
                // a commit can unblock barrier waiters
                wake_blocked_alloc(&mut workers, &server, now, &mut queue, &mut barrier_wait, &mut read_wait, &mut block_start, trace.as_mut());

                // evaluation at min-clock boundaries
                let min_clock = (0..machines)
                    .map(|p| workers[p].clocks_done)
                    .min()
                    .unwrap();
                if min_clock as i64 > last_eval_clock
                    && min_clock % opts.eval_every == 0
                {
                    last_eval_clock = min_clock as i64;
                    let snap = server.snapshot();
                    let obj = engine.objective(&snap, &eval_x, &eval_y);
                    if let Some(tr) = trace.as_mut() {
                        tr.push(
                            now,
                            TraceEvent::Eval {
                                clock: min_clock,
                                objective: obj,
                            },
                        );
                    }
                    tracker.record(now, min_clock, obj, &snap);
                    if opts.track_master_trajectory {
                        master_trajectory.push(snap);
                    }
                    if let Some(t) = opts.target_objective {
                        if obj <= t {
                            reached_target = true;
                        }
                    }
                }
            }
            Payload::Arrival { idx } => {
                let (msg, sent) = &arrivals[idx];
                server.apply_arrival(msg);
                if let Some(tr) = trace.as_mut() {
                    tr.push(
                        now,
                        TraceEvent::Arrival {
                            worker: msg.from,
                            clock: msg.clock,
                            layer: msg.layer,
                            delay_s: now - sent,
                        },
                    );
                }
                wake_blocked_alloc(&mut workers, &server, now, &mut queue, &mut barrier_wait, &mut read_wait, &mut block_start, trace.as_mut());
            }
        }
    }

    let total_vtime = queue.now();
    let final_params = server.snapshot();
    let final_objective = engine.objective(&final_params, &eval_x, &eval_y);

    let clock_loss: Vec<f64> = clock_loss_sum
        .iter()
        .zip(&clock_loss_cnt)
        .map(|(s, c)| if *c > 0 { s / *c as f64 } else { f64::NAN })
        .collect();

    RunResult {
        name: cfg.name.clone(),
        policy: policy.name(),
        machines,
        evals: tracker.into_points(),
        final_objective,
        total_vtime,
        barrier_wait_s: barrier_wait.iter().sum(),
        read_wait_s: read_wait.iter().sum(),
        compute_s,
        messages: net.messages(),
        bytes: net.bytes(),
        congestion_events: net.congestion_events(),
        epsilon_rate: eps_acc.epsilon_rate(),
        reads: server.reads(),
        steps,
        clock_loss,
        master_trajectory,
        final_params,
        trace,
        steady_reallocs: 0,
        membership: Vec::new(),
    }
}

#[allow(clippy::too_many_arguments)]
fn try_start_clock_alloc<S: ParamServer>(
    worker: usize,
    now: f64,
    cfg: &ExperimentConfig,
    w: &mut AllocWorkerState,
    server: &mut S,
    engine: &mut EngineKind,
    dataset: &Dataset,
    eta: &EtaSchedule,
    compute: &mut ComputeModel,
    net: &mut NetModel,
    model_bytes: usize,
    queue: &mut EventQueue<Payload>,
    block_start: &mut [f64],
    eps_acc: &mut ReadStats,
    steps: &mut u64,
    compute_s: &mut f64,
    clock_loss_sum: &mut Vec<f64>,
    clock_loss_cnt: &mut Vec<u64>,
    mut trace: Option<&mut Trace>,
) {
    if w.status == WorkerStatus::Done {
        return;
    }
    if server.must_wait(worker) || !server.read_ready(worker) {
        if w.status != WorkerStatus::Blocked {
            w.status = WorkerStatus::Blocked;
            w.blocked_on_barrier = server.must_wait(worker);
            block_start[worker] = now;
            if let Some(tr) = trace.as_deref_mut() {
                tr.push(
                    now,
                    TraceEvent::BlockStart {
                        worker,
                        on_barrier: w.blocked_on_barrier,
                    },
                );
            }
        }
        return;
    }
    w.status = WorkerStatus::Ready;
    if let Some(tr) = trace.as_deref_mut() {
        let max_clock = (0..server.workers())
            .map(|q| server.clock(q))
            .max()
            .unwrap_or(0);
        let observed = max_clock - server.clock(worker);
        tr.push(
            now,
            TraceEvent::ClockStart {
                worker,
                clock: server.clock(worker),
                observed_staleness: observed,
            },
        );
    }

    // ---- fetch (read with staleness semantics) ----
    let (snapshot, own_applied, stats) = server.fetch(worker);
    eps_acc.guaranteed += stats.guaranteed;
    eps_acc.window_included += stats.window_included;
    eps_acc.window_missed += stats.window_missed;

    // reconstruct own not-yet-applied updates, layerwise
    let mut own_missing = snapshot.zeros_like();
    for (clk, upd) in &w.own_pending {
        for (l, layer) in upd.layers.iter().enumerate() {
            if *clk >= own_applied[l] {
                own_missing.axpy_layer(l, 1.0, layer);
            }
        }
    }
    // prune fully-applied entries
    let min_applied = own_applied.iter().copied().min().unwrap_or(0);
    while let Some((clk, _)) = w.own_pending.front() {
        if *clk < min_applied {
            w.own_pending.pop_front();
        } else {
            break;
        }
    }
    w.cache.install_snapshot(snapshot, &own_missing);

    // ---- compute the clock's minibatches (real gradients) ----
    let clock = w.cache.clock();
    let mut loss_sum = 0.0;
    for _ in 0..cfg.train.batches_per_clock {
        let idx = w.batches.next_batch();
        let (x, y) = dataset.gather(&idx);
        let (loss, grads) = engine.loss_and_grads(w.cache.view(), &x, &y);
        let step_eta = eta.at(*steps);
        let dir = w.optim.direction(w.cache.view(), &grads).clone();
        w.cache.add_scaled_local_update(-step_eta, &dir);
        loss_sum += loss;
        *steps += 1;
    }
    let mean_loss = loss_sum / cfg.train.batches_per_clock as f64;
    let ci = clock as usize;
    if clock_loss_sum.len() <= ci {
        clock_loss_sum.resize(ci + 1, 0.0);
        clock_loss_cnt.resize(ci + 1, 0);
    }
    clock_loss_sum[ci] += mean_loss;
    clock_loss_cnt[ci] += 1;

    // ---- virtual durations ----
    let fetch_cost = net.fetch_duration(model_bytes);
    let dur = compute.clock_duration(worker, cfg.train.batches_per_clock);
    *compute_s += dur;
    queue.push(now + fetch_cost + dur, Payload::ComputeDone { worker });
}

#[allow(clippy::too_many_arguments)]
fn wake_blocked_alloc<S: ParamServer>(
    workers: &mut [AllocWorkerState],
    server: &S,
    now: f64,
    queue: &mut EventQueue<Payload>,
    barrier_wait: &mut [f64],
    read_wait: &mut [f64],
    block_start: &mut [f64],
    mut trace: Option<&mut Trace>,
) {
    for p in 0..workers.len() {
        if workers[p].status == WorkerStatus::Blocked {
            let barrier = server.must_wait(p);
            let read = !server.read_ready(p);
            if !barrier && !read {
                let waited = now - block_start[p];
                if workers[p].blocked_on_barrier {
                    barrier_wait[p] += waited;
                } else {
                    read_wait[p] += waited;
                }
                if let Some(tr) = trace.as_deref_mut() {
                    tr.push(now, TraceEvent::BlockEnd { worker: p, waited_s: waited });
                }
                workers[p].status = WorkerStatus::Ready;
                queue.push(now, Payload::StartClock { worker: p });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ssp::Policy;

    fn tiny_cfg() -> ExperimentConfig {
        let mut c = ExperimentConfig::tiny();
        c.train.clocks = 12;
        c.train.batches_per_clock = 2;
        c
    }

    fn fast_opts() -> DriverOptions {
        DriverOptions {
            per_batch_s: Some(0.01),
            eval_samples: 128,
            ..DriverOptions::default()
        }
    }

    #[test]
    fn run_completes_and_descends() {
        let cfg = tiny_cfg();
        let r = run_experiment(&cfg, fast_opts());
        assert_eq!(r.machines, 3);
        assert!(r.total_vtime > 0.0);
        assert!(!r.evals.is_empty());
        let first = r.evals.first().unwrap().objective;
        assert!(
            r.final_objective < first,
            "objective must descend: {first} -> {}",
            r.final_objective
        );
        assert_eq!(r.steps, 12 * 2 * 3);
    }

    #[test]
    fn more_machines_more_steps_per_vtime() {
        let cfg = tiny_cfg();
        let r1 = run_experiment(
            &cfg,
            DriverOptions {
                machines: Some(1),
                ..fast_opts()
            },
        );
        let r3 = run_experiment(
            &cfg,
            DriverOptions {
                machines: Some(3),
                ..fast_opts()
            },
        );
        let rate1 = r1.steps as f64 / r1.total_vtime;
        let rate3 = r3.steps as f64 / r3.total_vtime;
        assert!(
            rate3 > 1.8 * rate1,
            "3 machines should process >1.8x steps/s: {rate1} vs {rate3}"
        );
    }

    #[test]
    fn bsp_waits_more_than_ssp() {
        let mut cfg = tiny_cfg();
        cfg.cluster.straggler_prob = 0.3;
        cfg.cluster.straggler_factor = 5.0;
        cfg.ssp.policy = Policy::Bsp;
        let bsp = run_experiment(&cfg, fast_opts());
        cfg.ssp.policy = Policy::Ssp { staleness: 8 };
        let ssp = run_experiment(&cfg, fast_opts());
        assert!(
            bsp.barrier_wait_s > ssp.barrier_wait_s,
            "bsp {} vs ssp {}",
            bsp.barrier_wait_s,
            ssp.barrier_wait_s
        );
    }

    #[test]
    fn single_machine_matches_sequential_sgd() {
        // with 1 machine, SSP degenerates to plain SGD: the master after
        // each clock equals a local SGD trajectory on the same batches.
        let mut cfg = tiny_cfg();
        cfg.ssp.policy = Policy::Ssp { staleness: 0 };
        let r = run_experiment(
            &cfg,
            DriverOptions {
                machines: Some(1),
                ..fast_opts()
            },
        );
        assert!(r.final_objective.is_finite());
        assert_eq!(r.epsilon_rate, 1.0); // no other workers, no window
    }

    #[test]
    fn sharded_server_matches_reference() {
        // the discrete-event driver generic over ParamServer: backing it
        // with the sharded per-layer server must reproduce the reference
        // run bitwise (same f32 ops in the same order — the property
        // suite pins the servers; this pins the driver plumbing)
        use crate::ssp::ShardedServer;
        let cfg = tiny_cfg();
        let ds = build_dataset(&cfg);
        let a = run_experiment_on(&cfg, fast_opts(), &ds);
        let b = run_experiment_with(&cfg, fast_opts(), &ds, ShardedServer::new);
        assert_eq!(a.final_objective, b.final_objective);
        assert_eq!(a.total_vtime, b.total_vtime);
        assert_eq!(a.steps, b.steps);
        assert_eq!(a.final_params, b.final_params);
        let a_curve: Vec<(u64, f64)> =
            a.evals.iter().map(|e| (e.clock, e.objective)).collect();
        let b_curve: Vec<(u64, f64)> =
            b.evals.iter().map(|e| (e.clock, e.objective)).collect();
        assert_eq!(a_curve, b_curve);
    }

    // NOTE: zero-copy ≡ allocating-oracle equivalence (both server
    // backings, all policies, traces) lives in tests/property_driver.rs.

    #[test]
    fn scripted_eviction_completes_and_logs_epoch() {
        use crate::ssp::ShardedServer;
        let cfg = tiny_cfg();
        let ds = build_dataset(&cfg);
        let opts = DriverOptions {
            membership: vec![MembershipEvent {
                at_clock: 4,
                worker: 2,
                join: false,
            }],
            ..fast_opts()
        };
        let r = run_experiment_with(&cfg, opts, &ds, ShardedServer::new);
        assert_eq!(r.membership.len(), 1);
        assert_eq!(r.membership[0].epoch, 1);
        assert_eq!(r.membership[0].worker, 2);
        assert!(!r.membership[0].join);
        assert!(r.final_objective.is_finite());
        // victim stops after 4 clocks; the survivors run the horizon out
        assert_eq!(r.steps, (4 + 12 + 12) * 2);
        let first = r.evals.first().unwrap().objective;
        assert!(
            r.final_objective < first,
            "run must keep converging past the eviction: {first} -> {}",
            r.final_objective
        );
    }

    #[test]
    fn eviction_matches_between_server_backings() {
        // the elastic predicates must stay oracle-disciplined: the
        // single-lock reference and the sharded server walk the same
        // membership schedule to bitwise-identical weights
        use crate::ssp::ShardedServer;
        let cfg = tiny_cfg();
        let ds = build_dataset(&cfg);
        let sched = vec![MembershipEvent {
            at_clock: 3,
            worker: 0,
            join: false,
        }];
        let a = run_experiment_with(
            &cfg,
            DriverOptions { membership: sched.clone(), ..fast_opts() },
            &ds,
            Server::new,
        );
        let b = run_experiment_with(
            &cfg,
            DriverOptions { membership: sched, ..fast_opts() },
            &ds,
            ShardedServer::new,
        );
        assert_eq!(a.final_params, b.final_params);
        assert_eq!(a.total_vtime, b.total_vtime);
        assert_eq!(a.membership, b.membership);
    }

    #[test]
    fn membership_schedule_replays_bitwise() {
        // leave at clock 3, rejoin once the live min reaches 6: the
        // identical schedule must reproduce identical final weights —
        // the determinism the elastic re-shard's (epoch, seed) keying
        // exists to provide
        use crate::ssp::ShardedServer;
        let cfg = tiny_cfg();
        let ds = build_dataset(&cfg);
        let sched = vec![
            MembershipEvent { at_clock: 3, worker: 1, join: false },
            MembershipEvent { at_clock: 6, worker: 1, join: true },
        ];
        let run = || {
            run_experiment_with(
                &cfg,
                DriverOptions { membership: sched.clone(), ..fast_opts() },
                &ds,
                ShardedServer::new,
            )
        };
        let a = run();
        let b = run();
        assert_eq!(a.final_params, b.final_params);
        assert_eq!(a.steps, b.steps);
        assert_eq!(a.membership, b.membership);
        assert_eq!(a.membership.len(), 2);
        assert_eq!(a.membership[1].epoch, 2);
        assert!(a.membership[1].join, "second transition is the rejoin");
        // the rejoiner really trained again after re-admission
        assert!(a.steps > (3 + 12 + 12) * 2, "rejoin must add steps");
    }

    #[test]
    fn deterministic_given_config() {
        let cfg = tiny_cfg();
        let a = run_experiment(&cfg, fast_opts());
        let b = run_experiment(&cfg, fast_opts());
        assert_eq!(a.final_objective, b.final_objective);
        assert_eq!(a.total_vtime, b.total_vtime);
        assert_eq!(a.steps, b.steps);
    }

    #[test]
    fn target_objective_stops_early() {
        let cfg = tiny_cfg();
        let full = run_experiment(&cfg, fast_opts());
        let early = run_experiment(
            &cfg,
            DriverOptions {
                target_objective: Some(full.evals[0].objective),
                ..fast_opts()
            },
        );
        assert!(early.total_vtime <= full.total_vtime);
    }

    #[test]
    fn steady_state_is_allocation_free() {
        // after the warmup audit arms, the monitored pools must not grow
        let mut cfg = tiny_cfg();
        cfg.train.clocks = 20;
        cfg.cluster.drop_prob = 0.0; // keep the in-flight population flat
        cfg.cluster.straggler_prob = 0.0;
        let r = run_experiment(
            &cfg,
            DriverOptions {
                warmup_clocks: 6,
                ..fast_opts()
            },
        );
        assert_eq!(
            r.steady_reallocs, 0,
            "zero-copy driver must not allocate at steady state"
        );
    }
}
