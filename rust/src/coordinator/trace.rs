//! Structured event tracing of a distributed run — the debugging
//! instrument for SSP behaviour (who blocked when, how stale each read
//! was, where the virtual time went).

use std::fmt::Write as _;

/// One traced protocol event, stamped with virtual time.
#[derive(Clone, Debug, PartialEq)]
pub enum TraceEvent {
    ClockStart {
        worker: usize,
        clock: u64,
        /// How many clocks behind the global max this worker's view was
        /// at the read (observed staleness, ≤ s by construction).
        observed_staleness: u64,
    },
    Commit {
        worker: usize,
        clock: u64,
    },
    Arrival {
        worker: usize,
        clock: u64,
        layer: usize,
        delay_s: f64,
    },
    BlockStart {
        worker: usize,
        on_barrier: bool,
    },
    BlockEnd {
        worker: usize,
        waited_s: f64,
    },
    Eval {
        clock: u64,
        objective: f64,
    },
}

/// Trace collector: ring-bounded so long runs cannot blow memory.
#[derive(Clone, Debug)]
pub struct Trace {
    events: Vec<(f64, TraceEvent)>,
    cap: usize,
    dropped: u64,
}

impl Default for Trace {
    fn default() -> Self {
        Trace::with_capacity(100_000)
    }
}

impl Trace {
    pub fn with_capacity(cap: usize) -> Trace {
        Trace {
            events: Vec::new(),
            cap,
            dropped: 0,
        }
    }

    pub fn push(&mut self, vtime: f64, ev: TraceEvent) {
        if self.events.len() >= self.cap {
            self.dropped += 1;
            return;
        }
        self.events.push((vtime, ev));
    }

    pub fn events(&self) -> &[(f64, TraceEvent)] {
        &self.events
    }

    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Aggregate summary per worker: clocks, blocked spells, mean
    /// observed staleness, mean arrival delay.
    pub fn summary(&self, workers: usize) -> TraceSummary {
        let mut s = TraceSummary {
            per_worker: vec![WorkerSummary::default(); workers],
            events: self.events.len() as u64,
            dropped: self.dropped,
        };
        for (_, ev) in &self.events {
            match ev {
                TraceEvent::ClockStart {
                    worker,
                    observed_staleness,
                    ..
                } => {
                    let w = &mut s.per_worker[*worker];
                    w.clocks += 1;
                    w.staleness_sum += *observed_staleness as f64;
                }
                TraceEvent::BlockEnd { worker, waited_s } => {
                    let w = &mut s.per_worker[*worker];
                    w.blocks += 1;
                    w.blocked_s += waited_s;
                }
                TraceEvent::Arrival {
                    worker, delay_s, ..
                } => {
                    let w = &mut s.per_worker[*worker];
                    w.arrivals += 1;
                    w.delay_sum += delay_s;
                }
                _ => {}
            }
        }
        s
    }

    /// CSV export (`vtime,event,worker,clock,layer,value`).
    pub fn to_csv(&self) -> String {
        let mut out = String::from("vtime,event,worker,clock,layer,value\n");
        for (t, ev) in &self.events {
            match ev {
                TraceEvent::ClockStart {
                    worker,
                    clock,
                    observed_staleness,
                } => {
                    let _ = writeln!(
                        out,
                        "{t:.6},clock_start,{worker},{clock},,{observed_staleness}"
                    );
                }
                TraceEvent::Commit { worker, clock } => {
                    let _ = writeln!(out, "{t:.6},commit,{worker},{clock},,");
                }
                TraceEvent::Arrival {
                    worker,
                    clock,
                    layer,
                    delay_s,
                } => {
                    let _ = writeln!(
                        out,
                        "{t:.6},arrival,{worker},{clock},{layer},{delay_s:.6}"
                    );
                }
                TraceEvent::BlockStart { worker, on_barrier } => {
                    let _ = writeln!(
                        out,
                        "{t:.6},block_start,{worker},,,{}",
                        if *on_barrier { "barrier" } else { "read" }
                    );
                }
                TraceEvent::BlockEnd { worker, waited_s } => {
                    let _ =
                        writeln!(out, "{t:.6},block_end,{worker},,,{waited_s:.6}");
                }
                TraceEvent::Eval { clock, objective } => {
                    let _ = writeln!(out, "{t:.6},eval,,{clock},,{objective:.6}");
                }
            }
        }
        out
    }
}

#[derive(Clone, Debug, Default, PartialEq)]
pub struct WorkerSummary {
    pub clocks: u64,
    pub blocks: u64,
    pub blocked_s: f64,
    pub arrivals: u64,
    pub delay_sum: f64,
    pub staleness_sum: f64,
}

impl WorkerSummary {
    pub fn mean_staleness(&self) -> f64 {
        if self.clocks == 0 {
            0.0
        } else {
            self.staleness_sum / self.clocks as f64
        }
    }

    pub fn mean_delay(&self) -> f64 {
        if self.arrivals == 0 {
            0.0
        } else {
            self.delay_sum / self.arrivals as f64
        }
    }
}

#[derive(Clone, Debug)]
pub struct TraceSummary {
    pub per_worker: Vec<WorkerSummary>,
    pub events: u64,
    pub dropped: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Trace {
        let mut t = Trace::default();
        t.push(
            0.0,
            TraceEvent::ClockStart {
                worker: 0,
                clock: 0,
                observed_staleness: 0,
            },
        );
        t.push(1.0, TraceEvent::Commit { worker: 0, clock: 0 });
        t.push(
            1.2,
            TraceEvent::Arrival {
                worker: 0,
                clock: 0,
                layer: 1,
                delay_s: 0.2,
            },
        );
        t.push(
            1.5,
            TraceEvent::BlockStart {
                worker: 1,
                on_barrier: true,
            },
        );
        t.push(
            2.5,
            TraceEvent::BlockEnd {
                worker: 1,
                waited_s: 1.0,
            },
        );
        t.push(
            3.0,
            TraceEvent::ClockStart {
                worker: 0,
                clock: 1,
                observed_staleness: 2,
            },
        );
        t.push(
            3.0,
            TraceEvent::Eval {
                clock: 1,
                objective: 2.5,
            },
        );
        t
    }

    #[test]
    fn summary_aggregates_per_worker() {
        let s = sample().summary(2);
        assert_eq!(s.per_worker[0].clocks, 2);
        assert_eq!(s.per_worker[0].arrivals, 1);
        assert!((s.per_worker[0].mean_delay() - 0.2).abs() < 1e-12);
        assert!((s.per_worker[0].mean_staleness() - 1.0).abs() < 1e-12);
        assert_eq!(s.per_worker[1].blocks, 1);
        assert!((s.per_worker[1].blocked_s - 1.0).abs() < 1e-12);
        assert_eq!(s.events, 7);
    }

    #[test]
    fn csv_has_one_row_per_event() {
        let csv = sample().to_csv();
        assert_eq!(csv.lines().count(), 8); // header + 7 events
        assert!(csv.contains("block_start,1,,,barrier"));
        assert!(csv.contains("eval,,1,,2.5"));
    }

    #[test]
    fn capacity_bound_drops_not_grows() {
        let mut t = Trace::with_capacity(3);
        for i in 0..10 {
            t.push(i as f64, TraceEvent::Commit { worker: 0, clock: i });
        }
        assert_eq!(t.events().len(), 3);
        assert_eq!(t.dropped(), 7);
    }
}
