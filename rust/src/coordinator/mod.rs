//! Layer-3 coordination: the SSP training driver.
//!
//! * `engine`   — `GradEngine` abstraction (native backprop or a PJRT
//!   artifact) so the driver is agnostic to where gradients come from.
//! * `driver`   — the discrete-event SSP training run: real gradients &
//!   parameter versions, virtual time (see DESIGN.md). The default loop
//!   is zero-copy/zero-allocation at steady state; the pre-refactor
//!   allocating loop survives as `run_experiment_alloc_*`, the value-
//!   equality oracle.
//! * `sweep`    — parallel deterministic grid sweeps over (machines,
//!   staleness, policy, eta): every cell trains from the root seed
//!   (grid axes compare the protocol effect, not seed noise), thread
//!   budget shared with the intra-op GEMM pool, bitwise-reproducible
//!   `SweepReport` at any parallelism.
//! * `threaded` — real-thread SSP runners: `run_threaded` on the sharded
//!   per-layer server (the deployment path), `run_threaded_global` on
//!   the single-lock reference server (bench baseline / oracle).
//! * `tracker`  — objective / parameter-convergence instrumentation
//!   (Figures 2, 3, 6).

mod driver;
mod engine;
mod sweep;
mod threaded;
mod trace;
mod tracker;

pub use driver::{
    build_dataset, run_experiment, run_experiment_alloc_on,
    run_experiment_alloc_with, run_experiment_on, run_experiment_with,
    DriverOptions, MembershipChange, MembershipEvent, RunResult,
};
pub use sweep::{
    run_sweep, run_sweep_with, sweep_cells, CellResult, SweepCell,
    SweepOptions, SweepReport,
};
pub use engine::{EngineKind, GradEngine, NativeEngine};
pub use trace::{Trace, TraceEvent, TraceSummary, WorkerSummary};
pub use threaded::{
    native_factory, run_threaded, run_threaded_global, run_threaded_on,
    ThreadedOptions, ThreadedResult,
};
pub use tracker::{EvalPoint, Tracker};

use crate::config::ExperimentConfig;
use crate::nn::ParamSet;
use crate::util::Pcg64;

/// The deterministic initial parameters every runner derives from the
/// config seed (`seed ^ 0xD11`, Glorot). One definition on purpose:
/// the `serve` deployment path must build its remote server from the
/// same bits the driver, the threaded runner and the sweep calibration
/// assume, or the version-gated fetch premise ("the worker's initial
/// buffer holds the master at revision 0") silently breaks.
pub fn init_params(cfg: &ExperimentConfig) -> ParamSet {
    let mut init_rng = Pcg64::new(cfg.train.seed ^ 0xD11);
    ParamSet::glorot(&cfg.model.dims, &mut init_rng)
}

/// The minibatch rng stream worker `worker` adopts after an elastic
/// re-shard at membership `epoch`: a pure function of `(seed, epoch,
/// worker)`, so every layer — the simulated driver, each surviving
/// thread of the real runner, a rejoining process — derives the
/// identical stream independently, without sharing rng state or
/// agreeing on when the epoch was observed. (The splitmix-style odd
/// constant matches `Dataset::shard_elastic`'s epoch mix.)
pub fn elastic_batch_rng(seed: u64, epoch: u64, worker: usize) -> Pcg64 {
    let mut root = Pcg64::new(seed ^ epoch.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    root.split(100 + worker as u64)
}

/// Learning-rate schedule. The paper's experiments use a fixed rate
/// (§6.1); the theory (Assumption 1) requires η_t = O(t^−d), provided for
/// the theorem-validation experiments.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum EtaSchedule {
    Fixed(f32),
    /// η_t = eta0 · (1 + t)^−d
    Poly { eta0: f32, d: f32 },
}

impl EtaSchedule {
    pub fn at(&self, t: u64) -> f32 {
        match self {
            EtaSchedule::Fixed(e) => *e,
            EtaSchedule::Poly { eta0, d } => {
                eta0 * ((1.0 + t as f32).powf(-d))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eta_schedules() {
        let f = EtaSchedule::Fixed(0.05);
        assert_eq!(f.at(0), 0.05);
        assert_eq!(f.at(1000), 0.05);
        let p = EtaSchedule::Poly { eta0: 1.0, d: 0.5 };
        assert_eq!(p.at(0), 1.0);
        assert!((p.at(3) - 0.5).abs() < 1e-6);
        assert!(p.at(100) < p.at(10));
    }
}
