//! Run instrumentation: objective curves (Figs 2–3) and parameter
//! convergence (Fig 6 — mean squared difference of consecutive parameter
//! snapshots, total and per layer for the Thm 2 layerwise view).

use crate::nn::ParamSet;

/// One evaluation point on a run's trajectory.
#[derive(Clone, Debug)]
pub struct EvalPoint {
    /// Virtual seconds since run start.
    pub vtime: f64,
    /// Global min clock at evaluation.
    pub clock: u64,
    /// Master objective on the fixed evaluation subset.
    pub objective: f64,
    /// Mean squared diff of master params vs the previous eval point
    /// (Fig 6's quantity); 0 at the first point.
    pub param_msd: f64,
    /// Per-layer mean squared diff (layerwise convergence, Thm 2).
    pub layer_msd: Vec<f64>,
}

#[derive(Debug, Default)]
pub struct Tracker {
    points: Vec<EvalPoint>,
    prev: Option<ParamSet>,
}

impl Tracker {
    pub fn new() -> Tracker {
        Tracker::default()
    }

    pub fn record(&mut self, vtime: f64, clock: u64, objective: f64, params: &ParamSet) {
        let (param_msd, layer_msd) = match &self.prev {
            None => (0.0, vec![0.0; params.n_layers()]),
            Some(prev) => {
                let per = prev.layer_dist_sq(params);
                let sizes: Vec<usize> = params
                    .layers
                    .iter()
                    .map(|l| l.w.len() + l.b.len())
                    .collect();
                let msd = per.iter().sum::<f64>() / params.n_params() as f64;
                let layer_msd = per
                    .iter()
                    .zip(&sizes)
                    .map(|(d, &n)| d / n as f64)
                    .collect();
                (msd, layer_msd)
            }
        };
        // reuse the previous-snapshot buffer: one allocation on the
        // first record, copy-in-place on every later one (the zero-copy
        // driver evaluates through here each eval interval)
        match &mut self.prev {
            Some(prev) => prev.copy_from(params),
            None => self.prev = Some(params.clone()),
        }
        self.points.push(EvalPoint {
            vtime,
            clock,
            objective,
            param_msd,
            layer_msd,
        });
    }

    pub fn points(&self) -> &[EvalPoint] {
        &self.points
    }

    pub fn into_points(self) -> Vec<EvalPoint> {
        self.points
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn msd_tracks_consecutive_diffs() {
        let dims = [3, 4, 2];
        let mut rng = Pcg64::new(0);
        let a = ParamSet::glorot(&dims, &mut rng);
        let mut b = a.clone();
        b.layers[0].w.fill(0.0); // change layer 0 only

        let mut t = Tracker::new();
        t.record(0.0, 0, 1.0, &a);
        t.record(1.0, 2, 0.9, &b);
        t.record(2.0, 4, 0.8, &b); // unchanged

        let pts = t.points();
        assert_eq!(pts.len(), 3);
        assert_eq!(pts[0].param_msd, 0.0);
        assert!(pts[1].param_msd > 0.0);
        assert_eq!(pts[2].param_msd, 0.0, "no change between evals");
        // only layer 0 moved
        assert!(pts[1].layer_msd[0] > 0.0);
        assert_eq!(pts[1].layer_msd[1], 0.0);
    }

    #[test]
    fn objective_and_clock_passthrough() {
        let dims = [2, 2];
        let p = ParamSet::zeros(&dims);
        let mut t = Tracker::new();
        t.record(0.5, 3, 42.0, &p);
        assert_eq!(t.points()[0].clock, 3);
        assert_eq!(t.points()[0].objective, 42.0);
        assert_eq!(t.points()[0].vtime, 0.5);
    }
}
