//! Wall-clock stopwatch + human formatting, used by the bench harness.

use std::time::Instant;

#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Stopwatch {
            start: Instant::now(),
        }
    }

    pub fn reset(&mut self) {
        self.start = Instant::now();
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_secs() * 1e3
    }
}

/// "1.23s", "45.6ms", "789us" — compact duration formatting.
pub fn fmt_duration(secs: f64) -> String {
    if secs >= 60.0 {
        format!("{:.1}m", secs / 60.0)
    } else if secs >= 1.0 {
        format!("{secs:.2}s")
    } else if secs >= 1e-3 {
        format!("{:.1}ms", secs * 1e3)
    } else {
        format!("{:.0}us", secs * 1e6)
    }
}

/// Time a closure `n` times, returning per-iteration seconds (min/mean).
pub fn time_n<F: FnMut()>(n: usize, mut f: F) -> (f64, f64) {
    let mut best = f64::INFINITY;
    let mut total = 0.0;
    for _ in 0..n {
        let t = Instant::now();
        f();
        let dt = t.elapsed().as_secs_f64();
        best = best.min(dt);
        total += dt;
    }
    (best, total / n as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn formats() {
        assert_eq!(fmt_duration(120.0), "2.0m");
        assert_eq!(fmt_duration(1.5), "1.50s");
        assert_eq!(fmt_duration(0.0456), "45.6ms");
        assert_eq!(fmt_duration(1e-5), "10us");
    }

    #[test]
    fn time_n_counts() {
        let mut calls = 0;
        let (best, mean) = time_n(5, || calls += 1);
        assert_eq!(calls, 5);
        assert!(best >= 0.0 && mean >= best);
    }
}
