//! Leveled stderr logger, controlled by `SSPDNN_LOG` (error|warn|info|debug|trace).

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX); // MAX = uninitialized

fn init_from_env() -> u8 {
    let lvl = match std::env::var("SSPDNN_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    } as u8;
    LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

pub fn level() -> u8 {
    let l = LEVEL.load(Ordering::Relaxed);
    if l == u8::MAX {
        init_from_env()
    } else {
        l
    }
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= level()
}

pub fn log(l: Level, args: std::fmt::Arguments<'_>) {
    if enabled(l) {
        let tag = match l {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{tag}] {args}");
    }
}

#[macro_export]
macro_rules! info {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, format_args!($($t)*)) };
}

#[macro_export]
macro_rules! warn_ {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, format_args!($($t)*)) };
}

#[macro_export]
macro_rules! debug {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, format_args!($($t)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordering_of_levels() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
