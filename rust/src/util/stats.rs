//! Streaming and batch statistics used by metrics, benches and theory
//! experiments.

/// Welford online mean/variance accumulator.
#[derive(Clone, Debug, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        OnlineStats {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn merge(&mut self, other: &OnlineStats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        self.m2 += other.m2
            + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.mean = mean;
        self.n = n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Percentile of a sample (linear interpolation, like numpy's default).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = p / 100.0 * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (rank - lo as f64) * (s[hi] - s[lo])
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64)
        .sqrt()
}

/// Ordinary least-squares slope/intercept of y over x; used to check
/// contraction rates in the theory experiments.
pub fn linear_fit(x: &[f64], y: &[f64]) -> (f64, f64) {
    assert_eq!(x.len(), y.len());
    let n = x.len() as f64;
    let mx = mean(x);
    let my = mean(y);
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    for (xi, yi) in x.iter().zip(y) {
        sxx += (xi - mx) * (xi - mx);
        sxy += (xi - mx) * (yi - my);
    }
    let slope = if sxx == 0.0 { 0.0 } else { sxy / sxx };
    (slope, my - slope * mx * (n / n))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_matches_batch() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut st = OnlineStats::new();
        for &x in &xs {
            st.push(x);
        }
        assert!((st.mean() - 4.0).abs() < 1e-12);
        let var = xs.iter().map(|x| (x - 4.0) * (x - 4.0)).sum::<f64>() / 5.0;
        assert!((st.variance() - var).abs() < 1e-12);
        assert_eq!(st.min(), 1.0);
        assert_eq!(st.max(), 10.0);
    }

    #[test]
    fn merge_equals_combined() {
        let a_xs = [1.0, 5.0, 2.0];
        let b_xs = [7.0, -2.0, 0.5, 3.0];
        let mut a = OnlineStats::new();
        let mut b = OnlineStats::new();
        let mut all = OnlineStats::new();
        for &x in &a_xs {
            a.push(x);
            all.push(x);
        }
        for &x in &b_xs {
            b.push(x);
            all.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - all.mean()).abs() < 1e-12);
        assert!((a.variance() - all.variance()).abs() < 1e-12);
        assert_eq!(a.count(), 7);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn linear_fit_recovers_line() {
        let x: Vec<f64> = (0..20).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|v| 3.0 * v - 2.0).collect();
        let (m, c) = linear_fit(&x, &y);
        assert!((m - 3.0).abs() < 1e-9);
        assert!((c + 2.0).abs() < 1e-9);
    }
}
