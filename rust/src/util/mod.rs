//! Dependency-free utilities: RNG, statistics, JSON, logging, timing.
//!
//! The build environment is offline (only the `xla` crate and its
//! dependency closure are vendored), so the usual ecosystem crates
//! (`rand`, `serde`, `log`) are reimplemented here at the scale this
//! project needs.

pub mod half;
pub mod json;
pub mod log;
pub mod rng;
pub mod stats;
pub mod timer;

pub use rng::Pcg64;
pub use stats::OnlineStats;
pub use timer::Stopwatch;
