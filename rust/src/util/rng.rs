//! PCG64 pseudo-random number generator (O'Neill, PCG-XSL-RR 128/64).
//!
//! Deterministic, seedable, splittable — every stochastic component of the
//! system (data generation, minibatch order, straggler draws, network
//! drops) takes an explicit `Pcg64` so whole experiments replay bit-for-bit
//! from a single seed.

const MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

/// PCG-XSL-RR 128/64: 128-bit LCG state, 64-bit xorshift-rotate output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

impl Pcg64 {
    /// Seed with an arbitrary 64-bit value; stream constant fixed.
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Seed with an explicit stream (odd-ified internally): independent
    /// streams from the same seed never collide.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
        };
        rng.next_u64();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.next_u64();
        rng
    }

    /// Derive an independent child generator (used to give every worker,
    /// shard and subsystem its own stream).
    pub fn split(&mut self, tag: u64) -> Pcg64 {
        let seed = self.next_u64();
        Pcg64::with_stream(seed ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15), tag)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(MULT).wrapping_add(self.inc);
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn uniform_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in `[0, n)` (Lemire rejection-free for our n ranges).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // multiply-shift; bias negligible for n << 2^64
        (((self.next_u64() as u128) * (n as u128)) >> 64) as usize
    }

    /// Standard normal via Box–Muller (no cached second value: keeps the
    /// generator state a pure function of draw count).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-300 {
                let u2 = self.uniform();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    #[inline]
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Log-normal with the given *underlying* normal parameters.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with rate `lambda`.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        let u = loop {
            let u = self.uniform();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / lambda
    }

    /// Bernoulli draw.
    #[inline]
    pub fn coin(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        self.shuffle(&mut v);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn split_streams_are_independent() {
        let mut root = Pcg64::new(7);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same <= 1);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Pcg64::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Pcg64::new(9);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let k = r.below(10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Pcg64::new(13);
        let n = 20_000;
        let mean = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn coin_probability() {
        let mut r = Pcg64::new(17);
        let hits = (0..10_000).filter(|_| r.coin(0.3)).count();
        assert!((hits as f64 / 10_000.0 - 0.3).abs() < 0.02);
    }
}
