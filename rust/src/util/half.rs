//! Half-precision storage bit math, single-sourced for every consumer:
//! the GEMM pack buffers (`tensor::pack`, bf16 storage / f32 compute)
//! and the transport's negotiated wire codecs (`ssp::transport::codec`,
//! bf16/f16 quantized layer payloads).
//!
//! Two 16-bit formats:
//!
//! - **bfloat16** — f32's top 16 bits (8-bit exponent, 7-bit mantissa).
//!   Same dynamic range as f32, widening is a shift: exact and branch
//!   free, which is why the GEMM microkernels widen it inline.
//! - **IEEE binary16 (f16)** — 5-bit exponent, 10-bit mantissa. 3 more
//!   mantissa bits than bf16 (8× finer relative precision) at the cost
//!   of range: max finite 65504, subnormals below 2⁻¹⁴.
//!
//! Both narrowing conversions are round-to-nearest-even; both widening
//! conversions are exact (each format is a subset of f32). The `_finite`
//! variants clamp finite overflow to the format's largest finite value
//! instead of ±inf — the wire codecs use them so a clipped delta leaves
//! a finite residual for error feedback rather than poisoning the
//! accumulator with inf.

/// Round an f32 to bfloat16 storage bits, round-to-nearest-even:
/// add `0x7FFF + (lsb of the kept half)` and truncate. NaNs keep their
/// sign/payload top bits with the quiet bit forced (never collapse to
/// inf); overflow saturates to ±inf through the same carry.
#[inline]
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) as u16) | 0x0040;
    }
    let round = ((bits >> 16) & 1) + 0x7FFF;
    ((bits.wrapping_add(round)) >> 16) as u16
}

/// Widen bfloat16 storage bits back to f32 — exact (bf16 ⊂ f32).
#[inline]
pub fn bf16_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// Largest finite bf16, as storage bits (≈ 3.3895e38).
pub const BF16_MAX_BITS: u16 = 0x7F7F;
/// Largest finite f16, as storage bits (65504.0).
pub const F16_MAX_BITS: u16 = 0x7BFF;

/// [`f32_to_bf16`] with finite inputs clamped to ±max-finite instead of
/// overflowing to ±inf. Infinite inputs still map to ±inf, NaN to NaN.
#[inline]
pub fn f32_to_bf16_finite(x: f32) -> u16 {
    let h = f32_to_bf16(x);
    if x.is_finite() && h & 0x7FFF == 0x7F80 {
        return h & 0x8000 | BF16_MAX_BITS;
    }
    h
}

/// Round an f32 to IEEE binary16 storage bits, round-to-nearest-even.
/// Subnormal f16 results are rounded correctly (the carry out of a
/// subnormal mantissa lands on the smallest normal by construction);
/// overflow saturates to ±inf; NaNs keep their top payload bits with
/// the quiet bit forced.
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let man = bits & 0x007F_FFFF;
    if exp == 0xFF {
        if man == 0 {
            return sign | 0x7C00; // ±inf
        }
        return sign | 0x7E00 | ((man >> 13) as u16 & 0x01FF); // quiet NaN
    }
    let e = exp - 127 + 15; // rebias toward f16's 5-bit exponent
    if e >= 0x1F {
        return sign | 0x7C00; // overflow before rounding can help
    }
    if e <= 0 {
        // subnormal (or zero) result: shift the full 24-bit significand
        // (implicit bit restored) into the 10-bit subnormal position
        if e < -10 {
            return sign; // below half the smallest subnormal: ±0
        }
        let man = man | 0x0080_0000;
        let shift = (14 - e) as u32; // 14..=24
        let halfway = 1u32 << (shift - 1);
        let rest = man & ((1u32 << shift) - 1);
        let mut h = (man >> shift) as u16;
        if rest > halfway || (rest == halfway && h & 1 == 1) {
            h += 1; // a carry here is the smallest normal — still right
        }
        return sign | h;
    }
    // normal result: round the 23-bit mantissa to 10 bits; a mantissa
    // carry overflows into the exponent field arithmetically, which is
    // exactly the IEEE successor (including the step onto ±inf)
    let mut h = ((e as u32) << 10) | (man >> 13);
    let rest = man & 0x1FFF;
    if rest > 0x1000 || (rest == 0x1000 && h & 1 == 1) {
        h += 1;
    }
    if h >= 0x7C00 {
        return sign | 0x7C00;
    }
    sign | h as u16
}

/// [`f32_to_f16`] with finite inputs clamped to ±65504 instead of
/// overflowing to ±inf. Infinite inputs still map to ±inf, NaN to NaN.
#[inline]
pub fn f32_to_f16_finite(x: f32) -> u16 {
    let h = f32_to_f16(x);
    if x.is_finite() && h & 0x7FFF == 0x7C00 {
        return h & 0x8000 | F16_MAX_BITS;
    }
    h
}

/// Widen IEEE binary16 storage bits back to f32 — exact (f16 ⊂ f32).
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = (h >> 10) & 0x1F;
    let man = (h & 0x03FF) as u32;
    match exp {
        0 => {
            if man == 0 {
                return f32::from_bits(sign); // ±0
            }
            // subnormal: man · 2⁻²⁴, exact in f32 (both factors are)
            let v = man as f32 * f32::from_bits(0x3380_0000);
            f32::from_bits(v.to_bits() | sign)
        }
        0x1F => f32::from_bits(sign | 0x7F80_0000 | (man << 13)),
        e => f32::from_bits(sign | ((e as u32 + 112) << 23) | (man << 13)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    /// The 12 hand-verified bf16 bit vectors (moved here from
    /// `tensor/pack.rs` when the bit math was single-sourced): exact
    /// values, both tie directions, carry across the exponent, overflow
    /// to inf, and NaN quieting.
    #[test]
    fn bf16_round_to_nearest_even() {
        assert_eq!(f32_to_bf16(1.0), 0x3F80);
        assert_eq!(f32_to_bf16(-2.0), 0xC000);
        assert_eq!(f32_to_bf16(0.0), 0x0000);
        assert_eq!(f32_to_bf16(-0.0), 0x8000);
        // tie: halfway between 0x3F80 and 0x3F81 rounds to even (0x3F80)
        assert_eq!(f32_to_bf16(f32::from_bits(0x3F80_8000)), 0x3F80);
        // tie the other way: halfway above odd 0x3F81 rounds up to 0x3F82
        assert_eq!(f32_to_bf16(f32::from_bits(0x3F81_8000)), 0x3F82);
        // just over halfway always rounds up
        assert_eq!(f32_to_bf16(f32::from_bits(0x3F80_8001)), 0x3F81);
        assert_eq!(f32_to_bf16(f32::from_bits(0x3F80_7FFF)), 0x3F80);
        // carry propagates through the exponent
        assert_eq!(f32_to_bf16(f32::from_bits(0x3F7F_FFFF)), 0x3F80);
        // overflow saturates to inf
        assert_eq!(bf16_to_f32(f32_to_bf16(f32::MAX)), f32::INFINITY);
        assert_eq!(f32_to_bf16(f32::INFINITY), 0x7F80);
        assert_eq!(f32_to_bf16(f32::NEG_INFINITY), 0xFF80);
        // NaN stays NaN (quiet bit forced, sign kept)
        assert!(bf16_to_f32(f32_to_bf16(f32::NAN)).is_nan());
        assert!(bf16_to_f32(f32_to_bf16(f32::from_bits(0xFF80_0001))).is_nan());
    }

    /// Hand-verified f16 bit vectors mirroring the bf16 set: exact
    /// values, both tie directions, mantissa carry, the subnormal range
    /// (down to the 2⁻²⁵ round-to-zero boundary), overflow, and NaN.
    #[test]
    fn f16_round_to_nearest_even() {
        assert_eq!(f32_to_f16(1.0), 0x3C00);
        assert_eq!(f32_to_f16(-2.0), 0xC000);
        assert_eq!(f32_to_f16(0.0), 0x0000);
        assert_eq!(f32_to_f16(-0.0), 0x8000);
        assert_eq!(f32_to_f16(65504.0), 0x7BFF);
        // tie: 1 + 2⁻¹¹ is halfway between 0x3C00 and 0x3C01 → even
        assert_eq!(f32_to_f16(f32::from_bits(0x3F80_1000)), 0x3C00);
        // tie above odd 0x3C01 rounds up to 0x3C02
        assert_eq!(f32_to_f16(f32::from_bits(0x3F80_3000)), 0x3C02);
        assert_eq!(f32_to_f16(f32::from_bits(0x3F80_1001)), 0x3C01);
        assert_eq!(f32_to_f16(f32::from_bits(0x3F80_0FFF)), 0x3C00);
        // mantissa carry across the exponent: 1.99999988… → 2.0
        assert_eq!(f32_to_f16(f32::from_bits(0x3FFF_FFFF)), 0x4000);
        // subnormals: smallest (2⁻²⁴), its tie at 2⁻²⁵ (→ even = 0),
        // just above the tie, and the normal/subnormal boundary
        assert_eq!(f32_to_f16(f32::from_bits(0x3380_0000)), 0x0001);
        assert_eq!(f32_to_f16(f32::from_bits(0x3300_0000)), 0x0000);
        assert_eq!(f32_to_f16(f32::from_bits(0x3300_0001)), 0x0001);
        assert_eq!(f32_to_f16(f32::from_bits(0x3880_0000)), 0x0400); // 2⁻¹⁴
        assert_eq!(f32_to_f16(f32::from_bits(0x3800_0000)), 0x0200); // 2⁻¹⁵
        // 65520 is halfway between 65504 and the overflow step → inf
        assert_eq!(f32_to_f16(65520.0), 0x7C00);
        assert_eq!(f32_to_f16(65519.996), 0x7BFF);
        assert_eq!(f32_to_f16(f32::MAX), 0x7C00);
        assert_eq!(f32_to_f16(f32::INFINITY), 0x7C00);
        assert_eq!(f32_to_f16(f32::NEG_INFINITY), 0xFC00);
        assert!(f16_to_f32(f32_to_f16(f32::NAN)).is_nan());
        assert!(f16_to_f32(f32_to_f16(f32::from_bits(0xFF80_0001))).is_nan());
    }

    #[test]
    fn finite_variants_clamp_overflow_only() {
        assert_eq!(f32_to_bf16_finite(f32::MAX), BF16_MAX_BITS);
        assert_eq!(f32_to_bf16_finite(-f32::MAX), 0x8000 | BF16_MAX_BITS);
        assert_eq!(f32_to_bf16_finite(f32::INFINITY), 0x7F80);
        assert_eq!(f32_to_bf16_finite(1.0), 0x3F80);
        assert_eq!(f32_to_f16_finite(1.0e9), F16_MAX_BITS);
        assert_eq!(f32_to_f16_finite(-1.0e9), 0x8000 | F16_MAX_BITS);
        assert_eq!(f32_to_f16_finite(f32::NEG_INFINITY), 0xFC00);
        assert_eq!(f32_to_f16_finite(65504.0), F16_MAX_BITS);
        assert!(f16_to_f32(f32_to_f16_finite(f32::NAN)).is_nan());
    }

    /// Widening then narrowing is the identity on every non-NaN storage
    /// pattern — the "widen-exact" half of the codec round-trip pin.
    #[test]
    fn widen_then_narrow_is_identity() {
        for h in 0..=u16::MAX {
            if h & 0x7F80 == 0x7F80 && h & 0x007F != 0 {
                assert!(bf16_to_f32(h).is_nan());
            } else {
                assert_eq!(f32_to_bf16(bf16_to_f32(h)), h, "bf16 {h:#06x}");
            }
            if h & 0x7C00 == 0x7C00 && h & 0x03FF != 0 {
                assert!(f16_to_f32(h).is_nan());
            } else {
                assert_eq!(f32_to_f16(f16_to_f32(h)), h, "f16 {h:#06x}");
            }
        }
    }

    /// The nearest bf16 at or below `|x|` and its successor, compared in
    /// f64 with the overflow step treated as 2¹²⁸ (IEEE round-to-nearest
    /// overflows to inf only past max-finite + ½ulp).
    fn bf16_ref(x: f32) -> u16 {
        if x.is_infinite() {
            return if x < 0.0 { 0xFF80 } else { 0x7F80 };
        }
        let bits = x.to_bits();
        let lo = (bits >> 16) as u16; // truncation toward zero magnitude
        let hi = lo.wrapping_add(1);
        let vl = bf16_to_f32(lo) as f64;
        let vh = if bf16_to_f32(hi).is_infinite() {
            2f64.powi(128) * if x < 0.0 { -1.0 } else { 1.0 }
        } else {
            bf16_to_f32(hi) as f64
        };
        let (dl, dh) = ((x as f64 - vl).abs(), (vh - x as f64).abs());
        if dl < dh || (dl == dh && lo & 1 == 0) {
            lo
        } else {
            hi
        }
    }

    /// f16 reference: binary search the magnitude-ordered storage space
    /// for the floor value, then the same nearest/tie-to-even selection
    /// (overflow step = 65536, the unbounded successor of 65504).
    fn f16_ref(x: f32) -> u16 {
        let sign = if x.is_sign_negative() { 0x8000 } else { 0 };
        if x.is_infinite() {
            return sign | 0x7C00;
        }
        let ax = x.abs() as f64;
        let (mut lo, mut hi) = (0u16, 0x7C00u16);
        while hi - lo > 1 {
            let mid = (lo + hi) / 2;
            if (f16_to_f32(mid) as f64) <= ax {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        let floor = if (f16_to_f32(hi) as f64) <= ax { hi } else { lo };
        let succ = floor + 1;
        let vl = f16_to_f32(floor) as f64;
        let vh = if succ >= 0x7C00 { 65536.0 } else { f16_to_f32(succ) as f64 };
        let (dl, dh) = ((ax - vl).abs(), (vh - ax).abs());
        let h = if dl < dh || (dl == dh && floor & 1 == 0) {
            floor
        } else {
            succ.min(0x7C00)
        };
        sign | h
    }

    /// The 20k-sample RNE property test: uniformly random f32 bit
    /// patterns (NaNs skipped) must round exactly as the oracle that
    /// picks the nearer of the two neighbouring representables, ties to
    /// even — covering normals, subnormals, huge and tiny magnitudes.
    #[test]
    fn rne_matches_oracle_on_20k_samples() {
        let mut rng = Pcg64::new(0xB16B00B5);
        let mut n = 0;
        while n < 20_000 {
            let x = f32::from_bits(rng.next_u64() as u32);
            if x.is_nan() {
                continue;
            }
            n += 1;
            assert_eq!(
                f32_to_bf16(x),
                bf16_ref(x),
                "bf16 mismatch at {x:e} ({:#010x})",
                x.to_bits()
            );
            assert_eq!(
                f32_to_f16(x),
                f16_ref(x),
                "f16 mismatch at {x:e} ({:#010x})",
                x.to_bits()
            );
        }
    }
}
