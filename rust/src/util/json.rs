//! Minimal JSON reader/writer (serde is not available offline).
//!
//! Covers the full JSON grammar needed by the artifact manifest
//! (`artifacts/manifest.json`) and the metrics emitters: objects, arrays,
//! strings with escapes, numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    // ---------------- accessors ----------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `[1, 2, 3]` -> `vec![1usize, 2, 3]` convenience.
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?
            .iter()
            .map(|j| j.as_usize())
            .collect::<Option<Vec<_>>>()
    }

    // ---------------- constructors ----------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }

    // ---------------- serialization ----------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if *n == n.trunc() && n.abs() < 1e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{}", n);
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // ---------------- parsing ----------------

    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 5 > self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.bytes[self.pos + 1..self.pos + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(
                                char::from_u32(cp).unwrap_or('\u{fffd}'),
                            );
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 code point
                    let start = self.pos;
                    let text =
                        std::str::from_utf8(&self.bytes[start..]).unwrap();
                    let c = text.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let text = r#"{
          "format": 1,
          "artifacts": {
            "tiny": {
              "file": "tiny.hlo.txt",
              "layer_dims": [16, 32, 10],
              "batch": 8,
              "inputs": [{"name": "w0", "shape": [16, 32], "dtype": "float32"}]
            }
          }
        }"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.get("format").unwrap().as_usize(), Some(1));
        let tiny = j.get("artifacts").unwrap().get("tiny").unwrap();
        assert_eq!(tiny.get("file").unwrap().as_str(), Some("tiny.hlo.txt"));
        assert_eq!(
            tiny.get("layer_dims").unwrap().as_usize_vec(),
            Some(vec![16, 32, 10])
        );
        // serialize -> parse -> equal
        let again = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, again);
    }

    #[test]
    fn escapes_and_unicode() {
        let j = Json::parse(r#""a\n\t\"\\Aé""#).unwrap();
        assert_eq!(j.as_str(), Some("a\n\t\"\\Aé"));
        let again = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, again);
    }

    #[test]
    fn numbers() {
        for (t, v) in [
            ("0", 0.0),
            ("-3", -3.0),
            ("2.5", 2.5),
            ("1e3", 1000.0),
            ("-1.5E-2", -0.015),
        ] {
            assert_eq!(Json::parse(t).unwrap().as_f64(), Some(v), "{t}");
        }
    }

    #[test]
    fn rejects_garbage() {
        for t in ["", "{", "[1,", "tru", "\"abc", "1 2", "{\"a\" 1}"] {
            assert!(Json::parse(t).is_err(), "{t:?}");
        }
    }

    #[test]
    fn integers_serialize_without_dot() {
        assert_eq!(Json::num(5).to_string(), "5");
        assert_eq!(Json::num(2.5).to_string(), "2.5");
    }
}
