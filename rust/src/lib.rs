//! # sspdnn — Distributed Training of DNNs under the Stale Synchronous Parallel setting
//!
//! A production-quality reproduction of *"Distributed Training of Deep Neural
//! Networks with Theoretical Analysis: Under SSP Setting"* (Kumar, Xie, Yin,
//! Xing; CMU, 2015).
//!
//! The system is a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the SSP parameter server, worker coordination,
//!   data sharding, the discrete-event cluster simulator, metrics and the CLI.
//! * **Layer 2 (`python/compile/model.py`)** — the DNN forward/backward pass in
//!   JAX, AOT-lowered to HLO text at build time (`make artifacts`).
//! * **Layer 1 (`python/compile/kernels/`)** — the fused dense-layer Pallas
//!   kernels called from the Layer-2 graph.
//!
//! Python never runs on the training path: the Rust binary loads the compiled
//! HLO artifacts through PJRT (`runtime`), or falls back to the built-in
//! native engine (`nn`) for configurations without pre-built artifacts.

pub mod checkpoint;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod metrics;
pub mod net;
pub mod nn;
pub mod runtime;
pub mod sim;
pub mod ssp;
pub mod tensor;
pub mod theory;
pub mod util;
