//! # sspdnn — Distributed Training of DNNs under the Stale Synchronous Parallel setting
//!
//! A production-quality reproduction of *"Distributed Training of Deep Neural
//! Networks with Theoretical Analysis: Under SSP Setting"* (Kumar, Xie, Yin,
//! Xing; CMU, 2015).
//!
//! The system is a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the SSP parameter server, worker coordination,
//!   data sharding, the discrete-event cluster simulator, metrics and the CLI.
//! * **Layer 2 (`python/compile/model.py`)** — the DNN forward/backward pass in
//!   JAX, AOT-lowered to HLO text at build time (`make artifacts`).
//! * **Layer 1 (`python/compile/kernels/`)** — the fused dense-layer Pallas
//!   kernels called from the Layer-2 graph.
//!
//! Python never runs on the training path: the Rust binary loads the compiled
//! HLO artifacts through PJRT (`runtime`, behind the `xla` feature), or falls
//! back to the built-in native engine (`nn`) for configurations without
//! pre-built artifacts.
//!
//! ## The parameter server is sharded per layer
//!
//! The paper's structural result (Theorem 3, §3.1) is that SSP consistency
//! is *layerwise*: every update message carries exactly one layer's delta,
//! timestamps are tracked per (layer, worker), and the read guarantee of
//! Eq. 5 is enforced shard by shard. The `ssp` module therefore provides
//! two implementations of the same `ssp::ParamServer` protocol surface:
//!
//! * `ssp::Server` — the single-lock reference implementation. It is the
//!   oracle: simple enough to audit, used by the discrete-event driver
//!   (`coordinator::driver`, which needs `&mut` determinism anyway), by the
//!   `run_threaded_global` baseline, and by the equivalence tests.
//! * `ssp::ShardedServer` — the deployment-shaped implementation behind
//!   `coordinator::run_threaded`. Each layer's parameters live in their own
//!   shard behind their own `RwLock`; the clock table and per-(layer,
//!   worker) version vector are atomics, so the barrier predicates
//!   (`must_wait`, `read_ready`) never take a lock; `fetch` assembles its
//!   snapshot layer by layer with no global critical section; blocked
//!   workers park on a condvar that commits pulse. Given the same operation
//!   sequence the two implementations are bitwise identical (asserted by
//!   `tests/property_ssp.rs`).
//! * `ssp::transport` — the shard boundary as a **real message
//!   boundary**: `ShardService` serves a `ShardedServer` over one TCP
//!   endpoint per shard group (framed little-endian wire protocol,
//!   `rust/EXPERIMENTS.md` §Transport), and `ssp::RemoteClient` is a
//!   third `ParamServer` implementation speaking it — the property
//!   suite, the discrete-event driver, the sweep harness and the
//!   threaded runner (via `ssp::WorkerPort` / `run_threaded_on`) run
//!   against a remote server unchanged, bitwise-equal on fixed
//!   schedules. Gated fetches carry the subscriber's revision vector,
//!   so unchanged layers never touch the wire. Deployment:
//!   `sspdnn serve` + `sspdnn train --server`, `[transport]` config.
//!
//! ## The steady-state training step is zero-copy and zero-allocation
//!
//! Both `ParamServer` implementations additionally serve the
//! **version-gated zero-copy read path**: `fetch_into` writes into the
//! caller's reusable snapshot buffer and copies only the layers whose
//! per-layer *revision* (count of effective, nonzero-delta updates)
//! advanced since that caller's previous read — the layerwise
//! independence of Theorem 3 makes staleness of one layer's copy
//! independent of every other's, so "has this layer changed?" is one
//! atomic compare. `snapshot_into` (and the sharded
//! `snapshot_into_gated`) do the same for evaluation snapshots, and the
//! sharded `apply_commit` absorbs a worker's accumulated clock delta
//! without cloning it into messages. On top of this,
//! `coordinator::run_threaded` reuses per-worker batch, gradient and
//! view buffers (`Dataset::gather_into`, `MinibatchIter::next_batch_into`,
//! `GradEngine::loss_and_grads_into`, `nn::Workspace` borrowing the
//! minibatch as activation 0) and runs evaluation on a **dedicated
//! evaluator thread** fed cheap gated snapshots over a channel — the
//! training threads allocate nothing and copy nothing redundant at
//! steady state. `FetchStats` counts what the gate copied vs skipped;
//! `benches/sharded_server.rs` tracks the resulting throughput in
//! `bench_results/BENCH_hotpath.json` (methodology: `rust/EXPERIMENTS.md`).
//!
//! The **discrete-event driver** (`coordinator::run_experiment_with`)
//! runs the same zero-copy machinery: version-gated fetches into each
//! simulated worker's view, pooled arrival slots and own-pending
//! entries instead of per-clock message clones, and an allocation audit
//! (`RunResult::steady_reallocs`) that pins "zero steady-state
//! allocations per simulated clock". The pre-refactor allocating loop
//! is retained as `run_experiment_alloc_*` — the value-equality oracle
//! (`tests/property_driver.rs`). Dense figure grids run through
//! `coordinator::sweep` (CLI `sweep`, TOML `[sweep]`): cells dispatched
//! across a bounded thread budget shared with the intra-op GEMM pool,
//! every cell training from the shared root seed (axes compare the
//! protocol effect, not seed noise), so a `SweepReport`'s
//! statistical content is bitwise identical at any parallelism
//! (`benches/driver_sweep.rs` → `bench_results/BENCH_driver.json`).

pub mod checkpoint;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod metrics;
pub mod net;
pub mod nn;
pub mod runtime;
pub mod sim;
pub mod ssp;
pub mod tensor;
pub mod theory;
pub mod util;
