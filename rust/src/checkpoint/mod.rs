//! Binary parameter checkpoints: deterministic round-trip of a ParamSet.
//!
//! Format (little-endian):
//!   magic "SSPD" | u32 version | u32 n_dims | u64 dims... |
//!   f32 data in `ParamSet::flatten` order | u64 fnv1a checksum
//!
//! A second format dumps a whole `ShardedServer` for shard-process
//! warm restarts (`save_state` / `load_state`):
//!   magic "SSPS" | u32 version | u8 policy_tag | u64 staleness |
//!   u32 workers | u32 n_layers | u64 clocks × workers |
//!   per layer { u32 rows | u32 cols | u32 blen | f32 w × rows·cols |
//!               f32 b × blen | u64 versions × workers | u64 rev } |
//!   u64 fnv1a checksum
//! Both formats end in the same checksum; `save_state` writes through a
//! `.tmp` sibling + rename so a crash mid-dump never leaves a torn file
//! where a restart would look for its state.

use std::io::{Read, Write};
use std::path::Path;

use crate::nn::{LayerParams, ParamSet};
use crate::ssp::{LayerState, Policy, ServerState};
use crate::tensor::Matrix;

const MAGIC: &[u8; 4] = b"SSPD";
const VERSION: u32 = 1;

const STATE_MAGIC: &[u8; 4] = b"SSPS";
const STATE_VERSION: u32 = 1;

#[derive(Debug)]
pub enum CheckpointError {
    Io(std::io::Error),
    BadMagic,
    BadVersion(u32),
    Corrupt,
}

impl std::fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CheckpointError::Io(e) => write!(f, "io: {e}"),
            CheckpointError::BadMagic => {
                write!(f, "bad magic / not a checkpoint")
            }
            CheckpointError::BadVersion(v) => {
                write!(f, "unsupported version {v}")
            }
            CheckpointError::Corrupt => {
                write!(f, "checksum mismatch (corrupt checkpoint)")
            }
        }
    }
}

impl std::error::Error for CheckpointError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CheckpointError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for CheckpointError {
    fn from(e: std::io::Error) -> Self {
        CheckpointError::Io(e)
    }
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Save parameters (and the dims chain needed to restore them).
pub fn save(path: impl AsRef<Path>, dims: &[usize], params: &ParamSet) -> Result<(), CheckpointError> {
    let flat = params.flatten();
    let mut buf = Vec::with_capacity(flat.len() * 4 + 64);
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&VERSION.to_le_bytes());
    buf.extend_from_slice(&(dims.len() as u32).to_le_bytes());
    for &d in dims {
        buf.extend_from_slice(&(d as u64).to_le_bytes());
    }
    for v in &flat {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    let sum = fnv1a(&buf);
    buf.extend_from_slice(&sum.to_le_bytes());
    if let Some(dir) = path.as_ref().parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    f.write_all(&buf)?;
    Ok(())
}

/// Load a checkpoint; returns (dims, params).
pub fn load(path: impl AsRef<Path>) -> Result<(Vec<usize>, ParamSet), CheckpointError> {
    let mut buf = Vec::new();
    std::fs::File::open(path)?.read_to_end(&mut buf)?;
    if buf.len() < 24 || &buf[..4] != MAGIC {
        return Err(CheckpointError::BadMagic);
    }
    let body_len = buf.len() - 8;
    let stored = u64::from_le_bytes(buf[body_len..].try_into().unwrap());
    if fnv1a(&buf[..body_len]) != stored {
        return Err(CheckpointError::Corrupt);
    }
    let version = u32::from_le_bytes(buf[4..8].try_into().unwrap());
    if version != VERSION {
        return Err(CheckpointError::BadVersion(version));
    }
    let n_dims = u32::from_le_bytes(buf[8..12].try_into().unwrap()) as usize;
    let mut off = 12;
    let mut dims = Vec::with_capacity(n_dims);
    for _ in 0..n_dims {
        dims.push(u64::from_le_bytes(buf[off..off + 8].try_into().unwrap()) as usize);
        off += 8;
    }
    let n_params: usize = dims.windows(2).map(|w| w[0] * w[1] + w[1]).sum();
    let mut flat = Vec::with_capacity(n_params);
    for _ in 0..n_params {
        flat.push(f32::from_le_bytes(buf[off..off + 4].try_into().unwrap()));
        off += 4;
    }
    Ok((dims.clone(), ParamSet::unflatten(&dims, &flat)))
}

fn state_policy_code(p: Policy) -> (u8, u64) {
    match p {
        Policy::Bsp => (0, 0),
        Policy::Ssp { staleness } => (1, staleness),
        Policy::Async => (2, 0),
    }
}

fn state_policy_decode(tag: u8, staleness: u64) -> Result<Policy, CheckpointError> {
    match tag {
        0 => Ok(Policy::Bsp),
        1 => Ok(Policy::Ssp { staleness }),
        2 => Ok(Policy::Async),
        _ => Err(CheckpointError::Corrupt),
    }
}

/// Dump a `ShardedServer::export_state` to disk (format in the module
/// docs). Writes a `.tmp` sibling first and renames it into place so a
/// crash mid-write never leaves a torn state file.
pub fn save_state(
    path: impl AsRef<Path>,
    state: &ServerState,
) -> Result<(), CheckpointError> {
    let (tag, staleness) = state_policy_code(state.policy);
    let mut buf = Vec::new();
    buf.extend_from_slice(STATE_MAGIC);
    buf.extend_from_slice(&STATE_VERSION.to_le_bytes());
    buf.push(tag);
    buf.extend_from_slice(&staleness.to_le_bytes());
    buf.extend_from_slice(&(state.workers as u32).to_le_bytes());
    buf.extend_from_slice(&(state.layers.len() as u32).to_le_bytes());
    for &c in &state.clocks {
        buf.extend_from_slice(&c.to_le_bytes());
    }
    for layer in &state.layers {
        buf.extend_from_slice(&(layer.params.w.rows() as u32).to_le_bytes());
        buf.extend_from_slice(&(layer.params.w.cols() as u32).to_le_bytes());
        buf.extend_from_slice(&(layer.params.b.len() as u32).to_le_bytes());
        for v in layer.params.w.data() {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        for v in &layer.params.b {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        for &v in &layer.versions {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        buf.extend_from_slice(&layer.rev.to_le_bytes());
    }
    let sum = fnv1a(&buf);
    buf.extend_from_slice(&sum.to_le_bytes());
    let path = path.as_ref();
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let tmp = path.with_extension("tmp");
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(&buf)?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Load a server-state dump written by [`save_state`].
pub fn load_state(path: impl AsRef<Path>) -> Result<ServerState, CheckpointError> {
    let mut buf = Vec::new();
    std::fs::File::open(path)?.read_to_end(&mut buf)?;
    if buf.len() < 33 || &buf[..4] != STATE_MAGIC {
        return Err(CheckpointError::BadMagic);
    }
    let body_len = buf.len() - 8;
    let stored = u64::from_le_bytes(buf[body_len..].try_into().unwrap());
    if fnv1a(&buf[..body_len]) != stored {
        return Err(CheckpointError::Corrupt);
    }
    let version = u32::from_le_bytes(buf[4..8].try_into().unwrap());
    if version != STATE_VERSION {
        return Err(CheckpointError::BadVersion(version));
    }
    fn take<'a>(
        body: &'a [u8],
        off: &mut usize,
        n: usize,
    ) -> Result<&'a [u8], CheckpointError> {
        if body.len() - *off < n {
            return Err(CheckpointError::Corrupt);
        }
        let s = &body[*off..*off + n];
        *off += n;
        Ok(s)
    }
    let body = &buf[..body_len];
    let mut off = 8usize;
    let tag = take(body, &mut off, 1)?[0];
    let staleness =
        u64::from_le_bytes(take(body, &mut off, 8)?.try_into().unwrap());
    let policy = state_policy_decode(tag, staleness)?;
    let workers =
        u32::from_le_bytes(take(body, &mut off, 4)?.try_into().unwrap()) as usize;
    let n_layers =
        u32::from_le_bytes(take(body, &mut off, 4)?.try_into().unwrap()) as usize;
    if workers == 0 || n_layers == 0 {
        return Err(CheckpointError::Corrupt);
    }
    let mut clocks = Vec::with_capacity(workers);
    for _ in 0..workers {
        clocks.push(u64::from_le_bytes(
            take(body, &mut off, 8)?.try_into().unwrap(),
        ));
    }
    let mut layers = Vec::with_capacity(n_layers);
    for _ in 0..n_layers {
        let rows = u32::from_le_bytes(
            take(body, &mut off, 4)?.try_into().unwrap(),
        ) as usize;
        let cols = u32::from_le_bytes(
            take(body, &mut off, 4)?.try_into().unwrap(),
        ) as usize;
        let blen = u32::from_le_bytes(
            take(body, &mut off, 4)?.try_into().unwrap(),
        ) as usize;
        let mut w = Matrix::zeros(rows, cols);
        let w_bytes = take(body, &mut off, rows * cols * 4)?;
        for (d, c) in w.data_mut().iter_mut().zip(w_bytes.chunks_exact(4)) {
            *d = f32::from_le_bytes(c.try_into().unwrap());
        }
        let mut b = vec![0.0f32; blen];
        let b_bytes = take(body, &mut off, blen * 4)?;
        for (d, c) in b.iter_mut().zip(b_bytes.chunks_exact(4)) {
            *d = f32::from_le_bytes(c.try_into().unwrap());
        }
        let mut versions = Vec::with_capacity(workers);
        for _ in 0..workers {
            versions.push(u64::from_le_bytes(
                take(body, &mut off, 8)?.try_into().unwrap(),
            ));
        }
        let rev =
            u64::from_le_bytes(take(body, &mut off, 8)?.try_into().unwrap());
        layers.push(LayerState {
            params: LayerParams { w, b },
            versions,
            rev,
        });
    }
    if off != body.len() {
        return Err(CheckpointError::Corrupt);
    }
    Ok(ServerState { policy, workers, clocks, layers })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn roundtrip() {
        let dims = vec![7, 5, 3];
        let mut rng = Pcg64::new(1);
        let p = ParamSet::glorot(&dims, &mut rng);
        let path = std::env::temp_dir().join("sspdnn_ckpt_test.bin");
        save(&path, &dims, &p).unwrap();
        let (d2, p2) = load(&path).unwrap();
        assert_eq!(d2, dims);
        assert_eq!(p2, p);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corruption_detected() {
        let dims = vec![3, 2];
        let p = ParamSet::zeros(&dims);
        let path = std::env::temp_dir().join("sspdnn_ckpt_corrupt.bin");
        save(&path, &dims, &p).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        assert!(matches!(load(&path), Err(CheckpointError::Corrupt)));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bad_magic_detected() {
        let path = std::env::temp_dir().join("sspdnn_ckpt_magic.bin");
        std::fs::write(&path, b"NOPE....").unwrap();
        assert!(matches!(load(&path), Err(CheckpointError::BadMagic)));
        std::fs::remove_file(&path).ok();
    }

    fn sample_state() -> ServerState {
        let dims = vec![3, 4, 2];
        let mut rng = Pcg64::new(5);
        let p = ParamSet::glorot(&dims, &mut rng);
        ServerState {
            policy: Policy::Ssp { staleness: 3 },
            workers: 2,
            clocks: vec![4, 3],
            layers: p
                .layers
                .into_iter()
                .enumerate()
                .map(|(l, lp)| LayerState {
                    params: lp,
                    versions: vec![4, 3],
                    rev: 7 + l as u64,
                })
                .collect(),
        }
    }

    #[test]
    fn server_state_roundtrips_bitwise() {
        let state = sample_state();
        let path = std::env::temp_dir().join("sspdnn_state_test.bin");
        save_state(&path, &state).unwrap();
        let got = load_state(&path).unwrap();
        assert_eq!(got, state);
        // no .tmp sibling left behind by the atomic-rename write
        assert!(!path.with_extension("tmp").exists());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn server_state_corruption_and_truncation_detected() {
        let state = sample_state();
        let path = std::env::temp_dir().join("sspdnn_state_corrupt.bin");
        save_state(&path, &state).unwrap();
        let bytes = std::fs::read(&path).unwrap();

        let mut flipped = bytes.clone();
        let mid = flipped.len() / 2;
        flipped[mid] ^= 0xFF;
        std::fs::write(&path, &flipped).unwrap();
        assert!(matches!(load_state(&path), Err(CheckpointError::Corrupt)));

        std::fs::write(&path, &bytes[..bytes.len() / 3]).unwrap();
        assert!(load_state(&path).is_err(), "truncated dump must not load");

        std::fs::write(&path, b"NOPE....").unwrap();
        assert!(matches!(load_state(&path), Err(CheckpointError::BadMagic)));
        std::fs::remove_file(&path).ok();
    }
}
