//! Binary parameter checkpoints: deterministic round-trip of a ParamSet.
//!
//! Format (little-endian):
//!   magic "SSPD" | u32 version | u32 n_dims | u64 dims... |
//!   f32 data in `ParamSet::flatten` order | u64 fnv1a checksum

use std::io::{Read, Write};
use std::path::Path;

use crate::nn::ParamSet;

const MAGIC: &[u8; 4] = b"SSPD";
const VERSION: u32 = 1;

#[derive(Debug)]
pub enum CheckpointError {
    Io(std::io::Error),
    BadMagic,
    BadVersion(u32),
    Corrupt,
}

impl std::fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CheckpointError::Io(e) => write!(f, "io: {e}"),
            CheckpointError::BadMagic => {
                write!(f, "bad magic / not a checkpoint")
            }
            CheckpointError::BadVersion(v) => {
                write!(f, "unsupported version {v}")
            }
            CheckpointError::Corrupt => {
                write!(f, "checksum mismatch (corrupt checkpoint)")
            }
        }
    }
}

impl std::error::Error for CheckpointError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CheckpointError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for CheckpointError {
    fn from(e: std::io::Error) -> Self {
        CheckpointError::Io(e)
    }
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Save parameters (and the dims chain needed to restore them).
pub fn save(path: impl AsRef<Path>, dims: &[usize], params: &ParamSet) -> Result<(), CheckpointError> {
    let flat = params.flatten();
    let mut buf = Vec::with_capacity(flat.len() * 4 + 64);
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&VERSION.to_le_bytes());
    buf.extend_from_slice(&(dims.len() as u32).to_le_bytes());
    for &d in dims {
        buf.extend_from_slice(&(d as u64).to_le_bytes());
    }
    for v in &flat {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    let sum = fnv1a(&buf);
    buf.extend_from_slice(&sum.to_le_bytes());
    if let Some(dir) = path.as_ref().parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    f.write_all(&buf)?;
    Ok(())
}

/// Load a checkpoint; returns (dims, params).
pub fn load(path: impl AsRef<Path>) -> Result<(Vec<usize>, ParamSet), CheckpointError> {
    let mut buf = Vec::new();
    std::fs::File::open(path)?.read_to_end(&mut buf)?;
    if buf.len() < 24 || &buf[..4] != MAGIC {
        return Err(CheckpointError::BadMagic);
    }
    let body_len = buf.len() - 8;
    let stored = u64::from_le_bytes(buf[body_len..].try_into().unwrap());
    if fnv1a(&buf[..body_len]) != stored {
        return Err(CheckpointError::Corrupt);
    }
    let version = u32::from_le_bytes(buf[4..8].try_into().unwrap());
    if version != VERSION {
        return Err(CheckpointError::BadVersion(version));
    }
    let n_dims = u32::from_le_bytes(buf[8..12].try_into().unwrap()) as usize;
    let mut off = 12;
    let mut dims = Vec::with_capacity(n_dims);
    for _ in 0..n_dims {
        dims.push(u64::from_le_bytes(buf[off..off + 8].try_into().unwrap()) as usize);
        off += 8;
    }
    let n_params: usize = dims.windows(2).map(|w| w[0] * w[1] + w[1]).sum();
    let mut flat = Vec::with_capacity(n_params);
    for _ in 0..n_params {
        flat.push(f32::from_le_bytes(buf[off..off + 4].try_into().unwrap()));
        off += 4;
    }
    Ok((dims.clone(), ParamSet::unflatten(&dims, &flat)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn roundtrip() {
        let dims = vec![7, 5, 3];
        let mut rng = Pcg64::new(1);
        let p = ParamSet::glorot(&dims, &mut rng);
        let path = std::env::temp_dir().join("sspdnn_ckpt_test.bin");
        save(&path, &dims, &p).unwrap();
        let (d2, p2) = load(&path).unwrap();
        assert_eq!(d2, dims);
        assert_eq!(p2, p);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corruption_detected() {
        let dims = vec![3, 2];
        let p = ParamSet::zeros(&dims);
        let path = std::env::temp_dir().join("sspdnn_ckpt_corrupt.bin");
        save(&path, &dims, &p).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        assert!(matches!(load(&path), Err(CheckpointError::Corrupt)));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bad_magic_detected() {
        let path = std::env::temp_dir().join("sspdnn_ckpt_magic.bin");
        std::fs::write(&path, b"NOPE....").unwrap();
        assert!(matches!(load(&path), Err(CheckpointError::BadMagic)));
        std::fs::remove_file(&path).ok();
    }
}
