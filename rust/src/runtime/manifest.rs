//! artifacts/manifest.json — the contract between `python/compile/aot.py`
//! and the Rust runtime: per-artifact shapes, dtypes and argument order.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn n_elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    /// "step" (loss+grads) or "forward".
    pub kind: String,
    pub layer_dims: Vec<usize>,
    pub batch: usize,
    pub loss: String,
    /// "jnp" (autodiff) or "pallas" (layerwise manual backprop).
    pub impl_: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

fn tensor_specs(j: &Json) -> Result<Vec<TensorSpec>, String> {
    j.as_arr()
        .ok_or("specs not an array")?
        .iter()
        .map(|t| {
            Ok(TensorSpec {
                name: t
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or("spec.name")?
                    .to_string(),
                shape: t
                    .get("shape")
                    .and_then(Json::as_usize_vec)
                    .ok_or("spec.shape")?,
                dtype: t
                    .get("dtype")
                    .and_then(Json::as_str)
                    .ok_or("spec.dtype")?
                    .to_string(),
            })
        })
        .collect()
}

impl Manifest {
    /// Load `<dir>/manifest.json`; artifact file paths resolve within dir.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest, String> {
        let dir = dir.as_ref();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Manifest, String> {
        let j = Json::parse(text).map_err(|e| e.to_string())?;
        let format = j.get("format").and_then(Json::as_usize).ok_or("format")?;
        if format != 1 {
            return Err(format!("unsupported manifest format {format}"));
        }
        let arts = j
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or("artifacts")?;
        let mut manifest = Manifest::default();
        for (name, a) in arts {
            let spec = ArtifactSpec {
                name: name.clone(),
                file: dir.join(a.get("file").and_then(Json::as_str).ok_or("file")?),
                kind: a
                    .get("kind")
                    .and_then(Json::as_str)
                    .unwrap_or("step")
                    .to_string(),
                layer_dims: a
                    .get("layer_dims")
                    .and_then(Json::as_usize_vec)
                    .ok_or("layer_dims")?,
                batch: a.get("batch").and_then(Json::as_usize).ok_or("batch")?,
                loss: a
                    .get("loss")
                    .and_then(Json::as_str)
                    .unwrap_or("xent")
                    .to_string(),
                impl_: a
                    .get("impl")
                    .and_then(Json::as_str)
                    .unwrap_or("jnp")
                    .to_string(),
                inputs: tensor_specs(a.get("inputs").ok_or("inputs")?)?,
                outputs: tensor_specs(a.get("outputs").ok_or("outputs")?)?,
            };
            manifest.artifacts.insert(name.clone(), spec);
        }
        Ok(manifest)
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.get(name)
    }

    pub fn names(&self) -> Vec<&str> {
        self.artifacts.keys().map(|s| s.as_str()).collect()
    }
}

impl ArtifactSpec {
    /// Sanity-check the manifest entry against its own dims chain: the
    /// flat input order must be [w0, b0, ..., x(, y)] and a step
    /// artifact's outputs [loss, g_w0, g_b0, ...].
    pub fn validate(&self) -> Result<(), String> {
        let dims = &self.layer_dims;
        let n_layers = dims.len() - 1;
        let want_inputs = 2 * n_layers + if self.kind == "step" { 2 } else { 1 };
        if self.inputs.len() != want_inputs {
            return Err(format!(
                "{}: {} inputs, expected {want_inputs}",
                self.name,
                self.inputs.len()
            ));
        }
        for m in 0..n_layers {
            let w = &self.inputs[2 * m];
            if w.shape != [dims[m], dims[m + 1]] {
                return Err(format!("{}: bad w{m} shape {:?}", self.name, w.shape));
            }
            let b = &self.inputs[2 * m + 1];
            if b.shape != [dims[m + 1]] {
                return Err(format!("{}: bad b{m} shape {:?}", self.name, b.shape));
            }
        }
        let x = &self.inputs[2 * n_layers];
        if x.shape != [self.batch, dims[0]] {
            return Err(format!("{}: bad x shape {:?}", self.name, x.shape));
        }
        if self.kind == "step" {
            if self.outputs.len() != 1 + 2 * n_layers {
                return Err(format!("{}: bad output count", self.name));
            }
            if !self.outputs[0].shape.is_empty() {
                return Err(format!("{}: loss must be scalar", self.name));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": 1,
      "artifacts": {
        "tiny": {
          "file": "tiny.hlo.txt",
          "kind": "step",
          "layer_dims": [4, 3, 2],
          "batch": 5,
          "loss": "xent",
          "impl": "jnp",
          "inputs": [
            {"name": "w0", "shape": [4, 3], "dtype": "float32"},
            {"name": "b0", "shape": [3], "dtype": "float32"},
            {"name": "w1", "shape": [3, 2], "dtype": "float32"},
            {"name": "b1", "shape": [2], "dtype": "float32"},
            {"name": "x", "shape": [5, 4], "dtype": "float32"},
            {"name": "y", "shape": [5], "dtype": "int32"}
          ],
          "outputs": [
            {"name": "loss", "shape": [], "dtype": "float32"},
            {"name": "g_w0", "shape": [4, 3], "dtype": "float32"},
            {"name": "g_b0", "shape": [3], "dtype": "float32"},
            {"name": "g_w1", "shape": [3, 2], "dtype": "float32"},
            {"name": "g_b1", "shape": [2], "dtype": "float32"}
          ]
        }
      }
    }"#;

    #[test]
    fn parse_and_validate() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        let a = m.get("tiny").unwrap();
        assert_eq!(a.file, PathBuf::from("/tmp/a/tiny.hlo.txt"));
        assert_eq!(a.batch, 5);
        assert_eq!(a.inputs.len(), 6);
        assert_eq!(a.inputs[5].dtype, "int32");
        a.validate().unwrap();
        assert_eq!(m.names(), vec!["tiny"]);
    }

    #[test]
    fn validate_catches_shape_errors() {
        let mut m = Manifest::parse(SAMPLE, Path::new("/tmp")).unwrap();
        let a = m.artifacts.get_mut("tiny").unwrap();
        a.inputs[0].shape = vec![9, 9];
        assert!(a.validate().is_err());
    }

    #[test]
    fn rejects_bad_format() {
        assert!(Manifest::parse(r#"{"format": 2, "artifacts": {}}"#, Path::new(".")).is_err());
        assert!(Manifest::parse("not json", Path::new(".")).is_err());
    }

    #[test]
    fn real_manifest_if_built() {
        // integration: if `make artifacts` has run, the real manifest must
        // parse and validate.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.get("tiny").is_some());
            for (_, a) in &m.artifacts {
                a.validate().unwrap();
                assert!(a.file.exists(), "{} missing", a.file.display());
            }
        }
    }
}
