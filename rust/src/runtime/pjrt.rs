//! The PJRT gradient engine: executes the AOT-compiled Layer-2 step
//! function (loss + layerwise grads) from the Rust hot path.

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::GradEngine;
use crate::nn::{GradSet, Labels, LayerParams, ParamSet};
use crate::tensor::Matrix;

use super::manifest::ArtifactSpec;

/// A compiled step artifact bound to a PJRT CPU client.
pub struct PjrtEngine {
    spec: ArtifactSpec,
    client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
    n_layers: usize,
}

// SAFETY: each PjrtEngine owns its *own* PJRT CPU client (created in
// `load`) and the only Rc clones of that client live inside `exe`, also
// owned by this struct. Moving the whole engine to another thread moves
// every reference together; the engine is used by one thread at a time
// (GradEngine takes &mut self). The PJRT CPU plugin itself is
// thread-compatible.
unsafe impl Send for PjrtEngine {}

impl PjrtEngine {
    /// Compile `spec`'s HLO text on a fresh CPU client.
    pub fn load(spec: &ArtifactSpec) -> Result<PjrtEngine> {
        spec.validate().map_err(|e| anyhow!(e))?;
        if spec.kind != "step" {
            bail!("PjrtEngine requires a step artifact, got {}", spec.kind);
        }
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let proto = xla::HloModuleProto::from_text_file(&spec.file)
            .with_context(|| format!("parse HLO text {}", spec.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).context("compile artifact")?;
        Ok(PjrtEngine {
            spec: spec.clone(),
            client,
            exe,
            n_layers: spec.layer_dims.len() - 1,
        })
    }

    pub fn spec(&self) -> &ArtifactSpec {
        &self.spec
    }

    /// Marshal inputs host→device. Device buffers (not `execute`'s
    /// literal path): the C-side `execute` creates input buffers it never
    /// frees — ~n_params·4 bytes leaked per call, OOM on big models
    /// (§Perf iteration 4). With `execute_b` we own every buffer and drop
    /// it after the call.
    fn buffers(&self, params: &ParamSet, x: &Matrix, y: &Labels) -> Result<Vec<xla::PjRtBuffer>> {
        let dims = &self.spec.layer_dims;
        if params.n_layers() != self.n_layers {
            bail!("param layers {} != artifact {}", params.n_layers(), self.n_layers);
        }
        if x.rows() != self.spec.batch || x.cols() != dims[0] {
            bail!(
                "x shape ({}, {}) != artifact ({}, {})",
                x.rows(),
                x.cols(),
                self.spec.batch,
                dims[0]
            );
        }
        let mut bufs = Vec::with_capacity(2 * self.n_layers + 2);
        for (m, l) in params.layers.iter().enumerate() {
            bufs.push(self.client.buffer_from_host_buffer::<f32>(
                l.w.data(),
                &[dims[m], dims[m + 1]],
                None,
            )?);
            bufs.push(self.client.buffer_from_host_buffer::<f32>(
                &l.b,
                &[dims[m + 1]],
                None,
            )?);
        }
        bufs.push(self.client.buffer_from_host_buffer::<f32>(
            x.data(),
            &[x.rows(), x.cols()],
            None,
        )?);
        match y {
            Labels::Class(cls) => {
                if self.spec.loss != "xent" {
                    bail!("class labels with non-xent artifact");
                }
                let ys: Vec<i32> = cls.iter().map(|&c| c as i32).collect();
                bufs.push(self.client.buffer_from_host_buffer::<i32>(
                    &ys,
                    &[ys.len()],
                    None,
                )?);
            }
            Labels::Dense(t) => {
                if self.spec.loss != "mse" {
                    bail!("dense targets with non-mse artifact");
                }
                bufs.push(self.client.buffer_from_host_buffer::<f32>(
                    t.data(),
                    &[t.rows(), t.cols()],
                    None,
                )?);
            }
        }
        Ok(bufs)
    }

    /// Execute the artifact; returns (loss, grads).
    pub fn step(&self, params: &ParamSet, x: &Matrix, y: &Labels) -> Result<(f64, GradSet)> {
        let bufs = self.buffers(params, x, y)?;
        let result = self.exe.execute_b::<xla::PjRtBuffer>(&bufs)?[0][0]
            .to_literal_sync()?;
        drop(bufs);
        let mut outs = result.to_tuple()?;
        if outs.len() != 1 + 2 * self.n_layers {
            bail!("artifact returned {} outputs", outs.len());
        }
        let dims = &self.spec.layer_dims;
        let loss: f32 = outs[0].to_vec::<f32>()?[0];
        let mut layers = Vec::with_capacity(self.n_layers);
        for m in 0..self.n_layers {
            let wdata = outs[1 + 2 * m].to_vec::<f32>()?;
            let bdata = outs[2 + 2 * m].to_vec::<f32>()?;
            layers.push(LayerParams {
                w: Matrix::from_vec(dims[m], dims[m + 1], wdata),
                b: bdata,
            });
        }
        // keep `outs` alive until reads complete
        outs.clear();
        Ok((loss as f64, GradSet { layers }))
    }
}

impl GradEngine for PjrtEngine {
    fn loss_and_grads(
        &mut self,
        params: &ParamSet,
        x: &Matrix,
        y: &Labels,
    ) -> (f64, GradSet) {
        self.step(params, x, y).expect("pjrt step failed")
    }

    fn objective(&mut self, params: &ParamSet, x: &Matrix, y: &Labels) -> f64 {
        // evaluation batches may not match the artifact batch; fall back
        // to chunked execution over artifact-sized slices.
        let b = self.spec.batch;
        let mut total = 0.0;
        let mut n = 0usize;
        let rows = x.rows();
        let mut r = 0;
        while r + b <= rows {
            let mut xb = Matrix::zeros(b, x.cols());
            for i in 0..b {
                xb.row_mut(i).copy_from_slice(x.row(r + i));
            }
            let yb = match y {
                Labels::Class(c) => Labels::Class(c[r..r + b].to_vec()),
                Labels::Dense(t) => {
                    let mut tb = Matrix::zeros(b, t.cols());
                    for i in 0..b {
                        tb.row_mut(i).copy_from_slice(t.row(r + i));
                    }
                    Labels::Dense(tb)
                }
            };
            let (loss, _) = self.step(params, &xb, &yb).expect("pjrt eval failed");
            total += loss * b as f64;
            n += b;
            r += b;
        }
        if n == 0 {
            f64::NAN
        } else {
            total / n as f64
        }
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}
