//! Build-time stub for the PJRT engine, compiled when the `xla` feature
//! is off (the dependency-free default build).
//!
//! Keeps the full `PjrtEngine` API surface so callers (`main.rs`, the
//! integration tests) compile unchanged; `load` always fails with a
//! descriptive error, so the engine can never actually be constructed —
//! the remaining methods are unreachable by construction.

use crate::coordinator::GradEngine;
use crate::nn::{GradSet, Labels, ParamSet};
use crate::tensor::Matrix;

use super::manifest::ArtifactSpec;

const UNAVAILABLE: &str =
    "PJRT support not compiled in: rebuild with `--features xla` \
     (requires the vendored xla/anyhow crates)";

/// Placeholder with the real engine's API; never constructable.
pub struct PjrtEngine {
    _unconstructable: std::convert::Infallible,
}

impl PjrtEngine {
    /// Always fails in the stub build.
    pub fn load(spec: &ArtifactSpec) -> Result<PjrtEngine, String> {
        spec.validate()?;
        Err(UNAVAILABLE.to_string())
    }

    pub fn step(
        &self,
        _params: &ParamSet,
        _x: &Matrix,
        _y: &Labels,
    ) -> Result<(f64, GradSet), String> {
        match self._unconstructable {}
    }
}

impl GradEngine for PjrtEngine {
    fn loss_and_grads(
        &mut self,
        _params: &ParamSet,
        _x: &Matrix,
        _y: &Labels,
    ) -> (f64, GradSet) {
        match self._unconstructable {}
    }

    fn objective(&mut self, _params: &ParamSet, _x: &Matrix, _y: &Labels) -> f64 {
        match self._unconstructable {}
    }

    fn name(&self) -> &'static str {
        "pjrt-stub"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    #[test]
    fn load_reports_unavailable() {
        let spec = ArtifactSpec {
            name: "tiny".into(),
            file: PathBuf::from("tiny.hlo.txt"),
            kind: "step".into(),
            layer_dims: vec![4, 3, 2],
            batch: 5,
            loss: "xent".into(),
            impl_: "jnp".into(),
            inputs: vec![],
            outputs: vec![],
        };
        // invalid spec (no inputs) fails validation first
        assert!(PjrtEngine::load(&spec).is_err());
    }
}
