//! PJRT runtime: load the AOT-compiled HLO artifacts and run them on the
//! training path — Python never executes at run time.
//!
//! Flow (see /opt/xla-example/load_hlo): `HloModuleProto::from_text_file`
//! (HLO *text*, the interchange format that survives the jax≥0.5 /
//! xla_extension 0.5.1 proto-id mismatch) → `XlaComputation::from_proto`
//! → `PjRtClient::compile` → `execute`.

mod manifest;
#[cfg(feature = "xla")]
mod pjrt;
#[cfg(not(feature = "xla"))]
mod pjrt_stub;

pub use manifest::{ArtifactSpec, Manifest, TensorSpec};
#[cfg(feature = "xla")]
pub use pjrt::PjrtEngine;
#[cfg(not(feature = "xla"))]
pub use pjrt_stub::PjrtEngine;
