//! sspdnn — the SSP-DNN leader binary.
//!
//! Subcommands:
//!   train     run one SSP training experiment (simulated cluster)
//!   speedup   machine sweep + Fig 4/5-style speedup table
//!   theory    Theorem 1/2/3 empirical validation
//!   data      generate a synthetic dataset, print Table-1 stats
//!   artifact  inspect / smoke-run an AOT artifact through PJRT
//!   presets   list config presets
//!
//! Common flags: --preset <name>, --config <file.toml>, --machines N,
//! --staleness S, --policy bsp|ssp|async, --clocks N, --eta F,
//! --out <dir> (write CSV/JSON results).

use sspdnn::cli::Args;
use sspdnn::config::{ExperimentConfig, SweepConfig, TomlDoc, TransportConfig};
use sspdnn::coordinator::{
    build_dataset, init_params, run_experiment_on, run_experiment_with,
    run_sweep, DriverOptions, EtaSchedule, MembershipEvent, SweepOptions,
};
use sspdnn::metrics;
use sspdnn::runtime::{Manifest, PjrtEngine};
use sspdnn::ssp::transport::{RemoteClient, ShardService};
use sspdnn::ssp::{Policy, ShardedServer};
use sspdnn::tensor::dispatch::{self, GemmKernel};
use sspdnn::theory;
use sspdnn::util::timer::fmt_duration;

fn main() {
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let code = match args.command.as_str() {
        "train" => cmd_train(&args),
        "serve" => cmd_serve(&args),
        "chaos" => cmd_chaos(&args),
        "simulate" => cmd_simulate(&args),
        "sweep" => cmd_sweep(&args),
        "speedup" => cmd_speedup(&args),
        "theory" => cmd_theory(&args),
        "data" => cmd_data(&args),
        "artifact" => cmd_artifact(&args),
        "presets" => cmd_presets(),
        "" | "help" | "--help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => Err(format!("unknown command {other:?}; see `sspdnn help`")),
    };
    if let Err(e) = code {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

const HELP: &str = "\
sspdnn — Distributed Training of DNNs under the SSP Setting (Kumar et al. 2015)

USAGE: sspdnn <command> [flags]

COMMANDS:
  train      run one SSP training experiment on the simulated cluster
  serve      host a config's sharded SSP parameter server over TCP
             (one endpoint per shard group; workers attach with
             `train --server`)
  chaos      deterministic fault-injection TCP proxy in front of one
             serve endpoint: drops, delays, duplicates, or tears
             frames at scripted protocol boundaries
  simulate   traced protocol run: per-worker staleness/blocking/delay stats
  sweep      parallel deterministic grid sweep over (machines, staleness,
             policy, eta) cells; consolidated SweepReport JSON/CSV
  speedup    sweep 1..N machines, print the paper's speedup table (Fig 4/5)
  theory     empirical validation of Theorems 1-3
  data       generate a synthetic dataset and print Table-1 statistics
  artifact   inspect and smoke-run an AOT artifact via PJRT
  presets    list built-in experiment presets

FLAGS (train/speedup/theory):
  --preset <tiny|timit|timit_paper|imagenet|imagenet_paper>
  --config <file.toml>        overrides on top of the preset
  --machines N                number of worker machines
  --staleness S               SSP staleness bound
  --policy <ssp|bsp|async>
  --clocks N  --eta F  --batch N  --samples N
  --threads T                 intra-op GEMM threads per worker (default 1)
  --gemm-kernel <auto|scalar|avx2|avx512|neon>
                              GEMM microkernel dispatch path (default auto:
                              best available; env SSPDNN_GEMM_KERNEL also
                              honoured when no flag/config is given)
  --gemm-bf16                 pack GEMM operand panels as bf16 (f32 compute)
  --engine <native|pjrt>      gradient engine (pjrt needs artifacts/)
  --out <dir>                 write curve CSV + run JSON

FLAGS (transport; also settable via the [transport] TOML table):
  --server host:port          train: back the run with a remote parameter
                              server (group 0's endpoint; siblings are
                              discovered on port+1, port+2, ...)
  --group-addrs a:p,b:p,...   train: explicit endpoint per shard group
                              (multi-process tier on arbitrary hosts;
                              overrides the port+g discovery)
  --no-gate                   train: ship every layer on every fetch
                              (disable the version-gated delta reads)
  --sync-commits              train: block on every UPDATE/COMMIT ack
                              (disable the pipelined commit path)
  --window N                  train: max in-flight unacked frames per
                              connection when pipelining (default 32)
  --codec <off|bf16|f16|topk:F>
                              train: negotiated payload codec (wire v5).
                              off = raw f32 payloads, bitwise wire v4
                              (default). bf16/f16 quantize layer
                              payloads to 2 bytes/entry; topk:F ships
                              the F fraction (0 < F <= 1) of largest
                              delta entries as exact (index, value)
                              pairs. Lossy commit paths carry
                              per-layer error-feedback residuals, so
                              the rounding error never biases θ
  --retries N                 train: reconnect budget per supervised op
                              (overrides [transport] max_retries; 0 =
                              fail fast, no supervision)
  --lease-ms N                train: heartbeat lease duration in ms; an
                              expired lease releases the dead worker's
                              barrier waiters server-side (0 = off)
  --elastic                   serve: elastic membership — an expired
                              lease EVICTS the worker (membership epoch
                              bump; survivors re-shard over the live
                              set and keep converging) instead of
                              failing its barrier waits; an evicted
                              worker may re-ADMIT and rejoin at the
                              live minimum (at most 64 workers)
  --leave w@c,...             train/simulate: membership schedule — each
                              worker w dies after finishing clock c
                              (evicted; its in-flight updates are lost,
                              survivors rebalance its data shard)
  --join w@c,...              train/simulate: worker w rejoins once the
                              live min clock reaches c (re-admitted at
                              the live minimum, takes a shard back)
  --addr host:port            serve: base listen address (group g binds
                              port+g; default 127.0.0.1:7070)
  --shard-groups N            serve: endpoint count (clamped to layers)
  --group N                   serve: host ONLY shard group N in this
                              process (exclusive tier: run one such
                              process per group, same config each)
  --state <file>              serve: warm-restart from a server-state
                              dump (clock table + trained weights; the
                              handshake still advertises the config's
                              init digest, so workers re-attach)
  --state-out <file>          serve: periodically dump server state to
                              <file> (atomic tmp+rename) for warm
                              restarts
  --state-every-ms N          serve: dump cadence for --state-out
                              (default 1000)

FLAGS (chaos):
  --target host:port          the serve endpoint to relay to (required)
  --listen host:port          proxy listen address (default 127.0.0.1:0)
  --script S                  fault script: action[:arg]@op:n items
                              joined by ';' — e.g.
                              'kill@update:40;delay:25@fetch:3;torn@commit:7;
                               pause:500@heartbeat:2' (pause freezes the
                              relay both ways, sockets kept open)
  --seed N                    torn-write length RNG seed (default 1)

FLAGS (sweep; grid also settable via the [sweep] TOML table):
  --grid-machines 1,2,4       machine counts to sweep
  --grid-staleness 0,10       staleness bounds for ssp cells
  --grid-policies ssp,bsp     policy names (ssp|bsp|async)
  --grid-etas 0.05,0.1        learning rates (default: train.eta)
  --budget N                  total thread budget shared with --threads
                              (cells in flight = budget / threads)
  --per-batch-s F             pin virtual seconds per minibatch
                              (default: calibrate once on this host)
  --out <dir>                 write <name>_sweep.json + _sweep.csv
";

/// Read + parse the `--config` TOML once; commands that need the raw
/// document (the sweep grid lives in its `[sweep]` table) reuse it via
/// `build_config_with` instead of re-reading the file.
fn config_doc(args: &Args) -> Result<Option<TomlDoc>, String> {
    match args.get("config") {
        None => Ok(None),
        Some(path) => {
            let text =
                std::fs::read_to_string(path).map_err(|e| e.to_string())?;
            Ok(Some(sspdnn::config::parse_toml(&text)?))
        }
    }
}

fn build_config(args: &Args) -> Result<ExperimentConfig, String> {
    build_config_with(args, config_doc(args)?.as_ref())
}

fn build_config_with(
    args: &Args,
    doc: Option<&TomlDoc>,
) -> Result<ExperimentConfig, String> {
    let preset = args.get("preset").unwrap_or("tiny");
    let mut cfg = ExperimentConfig::preset(preset)
        .ok_or_else(|| format!("unknown preset {preset:?}"))?;
    if let Some(doc) = doc {
        cfg.apply_toml(doc)?;
    }
    if let Some(m) = args.get_usize("machines").map_err(|e| e.to_string())? {
        cfg.cluster.machines = m;
    }
    if let Some(s) = args.get_u64("staleness").map_err(|e| e.to_string())? {
        cfg.ssp.policy = Policy::Ssp { staleness: s };
    }
    match args.get("policy") {
        Some("bsp") => cfg.ssp.policy = Policy::Bsp,
        Some("async") => cfg.ssp.policy = Policy::Async,
        Some("ssp") | None => {}
        Some(p) => return Err(format!("unknown policy {p:?}")),
    }
    if let Some(c) = args.get_usize("clocks").map_err(|e| e.to_string())? {
        cfg.train.clocks = c;
    }
    if let Some(e) = args.get_f64("eta").map_err(|e| e.to_string())? {
        cfg.train.eta = e as f32;
    }
    if let Some(b) = args.get_usize("batch").map_err(|e| e.to_string())? {
        cfg.train.batch = b;
    }
    if let Some(n) = args.get_usize("samples").map_err(|e| e.to_string())? {
        cfg.data.n_samples = n;
    }
    if let Some(t) = args.get_usize("threads").map_err(|e| e.to_string())? {
        cfg.train.intra_op_threads = t;
    }
    if let Some(k) = args.get("gemm-kernel") {
        cfg.train.gemm_kernel = GemmKernel::parse(k).ok_or_else(|| {
            format!("bad --gemm-kernel {k:?} (auto|scalar|avx2|avx512|neon)")
        })?;
    }
    if args.get_bool("gemm-bf16") {
        cfg.train.gemm_bf16 = true;
    }
    cfg.validate()?;
    // every GEMM that doesn't carry an explicit pool selection (serial
    // free functions, ad-hoc pools in sweeps/theory) follows the config
    if let Ok(sel) = cfg.train.gemm_selection() {
        dispatch::set_default(sel);
    }
    Ok(cfg)
}

fn driver_opts(args: &Args, cfg: &ExperimentConfig) -> Result<DriverOptions, String> {
    let mut opts = DriverOptions::default();
    if args.get("engine") == Some("pjrt") {
        let name = cfg
            .train
            .artifact
            .clone()
            .ok_or("config has no artifact name for the pjrt engine")?;
        let manifest =
            Manifest::load(args.get("artifacts").unwrap_or("artifacts"))?;
        let spec = manifest
            .get(&name)
            .ok_or_else(|| format!("artifact {name:?} not in manifest"))?;
        let engine = PjrtEngine::load(spec).map_err(|e| e.to_string())?;
        opts.engine = Some(sspdnn::coordinator::EngineKind::Boxed(Box::new(engine)));
    }
    opts.membership = parse_membership(args)?;
    Ok(opts)
}

/// `--leave 2@5,0@9` / `--join 2@12`: comma-separated `worker@clock`
/// membership events for the simulated driver (leaves fire when the
/// worker finishes clock c, joins once the live min reaches c).
fn parse_membership(args: &Args) -> Result<Vec<MembershipEvent>, String> {
    let mut events = Vec::new();
    for (flag, join) in [("leave", false), ("join", true)] {
        let Some(spec) = args.get(flag) else { continue };
        for item in spec.split(',').filter(|s| !s.trim().is_empty()) {
            let item = item.trim();
            let (w, c) = item
                .split_once('@')
                .ok_or_else(|| format!("--{flag}: want worker@clock, got {item:?}"))?;
            events.push(MembershipEvent {
                worker: w
                    .parse()
                    .map_err(|_| format!("--{flag}: bad worker in {item:?}"))?,
                at_clock: c
                    .parse()
                    .map_err(|_| format!("--{flag}: bad clock in {item:?}"))?,
                join,
            });
        }
    }
    Ok(events)
}

/// The `[transport]` table plus its CLI overrides.
fn transport_config(
    args: &Args,
    doc: Option<&TomlDoc>,
) -> Result<TransportConfig, String> {
    let mut tcfg = TransportConfig::default();
    if let Some(doc) = doc {
        tcfg.apply_toml(doc)?;
    }
    if let Some(a) = args.get("addr") {
        tcfg.addr = a.to_string();
    }
    if let Some(g) = args.get_usize("shard-groups").map_err(|e| e.to_string())? {
        tcfg.shard_groups = g;
    }
    if args.get_bool("no-gate") {
        tcfg.gated = false;
    }
    if args.get_bool("sync-commits") {
        tcfg.pipeline = false;
    }
    if let Some(w) = args.get_usize("window").map_err(|e| e.to_string())? {
        tcfg.window = w;
    }
    if let Some(s) = args.get("group-addrs") {
        tcfg.group_addrs = parse_list("group-addrs", s)?;
    }
    if let Some(r) = args.get_u64("retries").map_err(|e| e.to_string())? {
        tcfg.max_retries = u32::try_from(r)
            .map_err(|_| format!("--retries {r} out of range"))?;
    }
    if let Some(l) = args.get_u64("lease-ms").map_err(|e| e.to_string())? {
        tcfg.lease_ms = l;
    }
    if args.get_bool("elastic") {
        tcfg.elastic = true;
    }
    if let Some(c) = args.get("codec") {
        tcfg.codec = c.to_string();
    }
    tcfg.validate()?;
    Ok(tcfg)
}

fn cmd_train(args: &Args) -> Result<(), String> {
    let doc = config_doc(args)?;
    let cfg = build_config_with(args, doc.as_ref())?;
    let opts = driver_opts(args, &cfg)?;
    println!(
        "train: {} | {} machines | {} | {} params | engine {}",
        cfg.name,
        cfg.cluster.machines,
        cfg.ssp.policy.name(),
        cfg.model.n_params(),
        if args.get("engine") == Some("pjrt") { "pjrt" } else { "native" },
    );
    println!("gemm: {}", dispatch::describe(dispatch::current()));
    let dataset = build_dataset(&cfg);
    let run = match args.get("server") {
        None => run_experiment_on(&cfg, opts, &dataset),
        Some(addr) => {
            // remote deployment path: the driver's parameter server is a
            // RemoteClient speaking the shard-group wire protocol to one
            // `sspdnn serve` process (shared tier) or one `serve
            // --group g` process per shard group (exclusive tier)
            let tcfg = transport_config(args, doc.as_ref())?;
            let faults = tcfg.fault_policy();
            let client = if tcfg.group_addrs.is_empty() {
                RemoteClient::connect_base_with(addr, faults)?
            } else {
                RemoteClient::connect_hosts_with(&tcfg.group_addrs, faults)?
            };
            let client = client.with_gate(tcfg.gated);
            // negotiate the payload codec before pipelining: the
            // renegotiation HELLO must not race a writer thread
            let codec = tcfg.parsed_codec()?;
            let client = client.with_codec(codec)?;
            let client = if tcfg.pipeline {
                client.with_pipeline(tcfg.window)?
            } else {
                client
            };
            // heartbeat lease: the server drops this run's barrier
            // waits if the trainer dies without saying goodbye
            let client = if tcfg.lease_ms > 0 {
                client.with_lease(
                    std::time::Duration::from_millis(tcfg.lease_ms),
                    std::time::Duration::from_millis(tcfg.heartbeat_ms),
                )?
            } else {
                client
            };
            println!(
                "remote parameter server: {addr} ({} {} endpoints, gate {}, \
                 codec {}, commits {}, retries {}, lease {})",
                client.groups(),
                if client.exclusive() { "exclusive" } else { "shared" },
                if tcfg.gated { "on" } else { "off" },
                client.codec(),
                if client.pipelined() {
                    format!("pipelined (window {})", tcfg.window)
                } else {
                    "synchronous".to_string()
                },
                tcfg.max_retries,
                if tcfg.lease_ms > 0 {
                    format!("{}ms / beat {}ms", tcfg.lease_ms, tcfg.heartbeat_ms)
                } else {
                    "off".to_string()
                },
            );
            run_experiment_with(&cfg, opts, &dataset, move |init, workers, policy| {
                client.check_run(&init, workers, policy);
                client
            })
        }
    };
    // deployment-independent fingerprint of the trained model — lets a
    // multi-process run be diffed against a single-process run with grep
    println!(
        "final weights digest: {:016x}",
        sspdnn::ssp::transport::param_digest(&run.final_params)
    );
    println!(
        "objective: {:.4} -> {:.4} over {} (virtual) | {} steps | eps {:.3}",
        run.evals.first().map(|e| e.objective).unwrap_or(f64::NAN),
        run.final_objective,
        fmt_duration(run.total_vtime),
        run.steps,
        run.epsilon_rate,
    );
    println!(
        "waits: barrier {} | read {} | compute {}",
        fmt_duration(run.barrier_wait_s),
        fmt_duration(run.read_wait_s),
        fmt_duration(run.compute_s),
    );
    let objs: Vec<f64> = run.evals.iter().map(|e| e.objective).collect();
    println!("objective curve: {}", metrics::sparkline(&objs));
    for m in &run.membership {
        println!(
            "membership: worker {} {} at {} (epoch {})",
            m.worker,
            if m.join { "joined" } else { "evicted" },
            fmt_duration(m.vtime),
            m.epoch,
        );
    }
    if let Some(dir) = args.get("out") {
        metrics::write_file(
            &format!("{dir}/{}_curve.csv", cfg.name),
            &metrics::curve_csv(&run),
        )
        .map_err(|e| e.to_string())?;
        metrics::write_file(
            &format!("{dir}/{}_run.json", cfg.name),
            &metrics::run_json(&run).to_string(),
        )
        .map_err(|e| e.to_string())?;
        println!("wrote {dir}/{}_curve.csv and _run.json", cfg.name);
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<(), String> {
    let doc = config_doc(args)?;
    let cfg = build_config_with(args, doc.as_ref())?;
    let tcfg = transport_config(args, doc.as_ref())?;
    // the served master starts from the exact bits every worker derives
    // from the shared config seed — the gated-fetch premise
    let init = init_params(&cfg);
    let workers = cfg.cluster.machines;
    let n_layers = cfg.model.dims.len() - 1;
    let (server, warm_digest) = match args.get("state") {
        None => (
            std::sync::Arc::new(ShardedServer::new(init, workers, cfg.ssp.policy)),
            None,
        ),
        // warm restart: resume a crashed/retired shard process from a
        // quiescent state dump — trained weights, revision counters,
        // and the clock table all continue where they left off, so a
        // supervised client's reconnect probe sees no rev regression
        Some(path) => {
            let state = sspdnn::checkpoint::load_state(path)
                .map_err(|e| format!("--state {path}: {e}"))?;
            if state.workers != workers {
                return Err(format!(
                    "--state {path} has {} workers but the config says {workers}",
                    state.workers
                ));
            }
            if state.layers.len() != n_layers {
                return Err(format!(
                    "--state {path} has {} layers but the config model has {n_layers}",
                    state.layers.len()
                ));
            }
            if state.policy != cfg.ssp.policy {
                return Err(format!(
                    "--state {path} policy {:?} differs from the config's {:?}",
                    state.policy, cfg.ssp.policy
                ));
            }
            println!(
                "warm restart from {path} (clocks {:?})",
                state.clocks
            );
            // clients validate the config-derived *init* digest on
            // every handshake; the restarted master holds trained bits,
            // so advertise the init digest explicitly
            let digest = sspdnn::ssp::transport::param_digest(&init);
            (
                std::sync::Arc::new(ShardedServer::from_state(state)),
                Some(digest),
            )
        }
    };
    if let Some(out) = args.get("state-out") {
        let every = args
            .get_u64("state-every-ms")
            .map_err(|e| e.to_string())?
            .unwrap_or(1000)
            .max(1);
        let dump = server.clone();
        let out = out.to_string();
        println!("state dumps: {out} every {every}ms");
        std::thread::spawn(move || loop {
            std::thread::sleep(std::time::Duration::from_millis(every));
            // tmp + rename so a kill mid-dump never truncates the last
            // good dump (load_state also checksums against torn writes)
            let state = dump.export_state();
            let tmp = format!("{out}.tmp");
            if sspdnn::checkpoint::save_state(&tmp, &state).is_ok() {
                let _ = std::fs::rename(&tmp, &out);
            }
        });
    }
    let opts = tcfg.service_options(warm_digest);
    let group = args.get_usize("group").map_err(|e| e.to_string())?;
    let svc = match group {
        // shared tier: this one process hosts every shard group
        None => ShardService::bind_with(
            server,
            &tcfg.addr,
            tcfg.shard_groups,
            opts,
        )?,
        // exclusive tier: this process hosts ONLY group g's shards and
        // its private clock table; its siblings run as separate `serve
        // --group <j>` processes (same config — the cross-process
        // protocol depends on identical init/geometry, which the
        // client's handshake digest check enforces)
        Some(g) => {
            let addr = tcfg.group_addr(g)?;
            ShardService::bind_group_with(
                server,
                &addr,
                tcfg.shard_groups,
                g,
                opts,
            )?
        }
    };
    match group {
        None => println!(
            "serve: {} | {} workers | {} | {} layer shards over {} endpoints",
            cfg.name,
            workers,
            cfg.ssp.policy.name(),
            cfg.model.dims.len() - 1,
            svc.groups(),
        ),
        Some(g) => println!(
            "serve: {} | {} workers | {} | exclusive group {g}/{} \
             ({} layer shards total)",
            cfg.name,
            workers,
            cfg.ssp.policy.name(),
            tcfg.shard_groups,
            cfg.model.dims.len() - 1,
        ),
    }
    if tcfg.elastic {
        println!(
            "elastic membership: on (lease {})",
            if tcfg.lease_ms > 0 {
                format!("{}ms", tcfg.lease_ms)
            } else {
                "off — evictions only via LEAVE".to_string()
            }
        );
    }
    println!("gemm: {}", dispatch::describe(dispatch::current()));
    for (g, a) in svc.addrs().iter().enumerate() {
        match group {
            None => println!("  group {g}: {a}"),
            Some(mine) => println!("  group {mine}: {a}"),
        }
    }
    // `train --server` discovers sibling groups on port+1, port+2, ...
    // — that convention only holds when a fixed base port was bound
    // (port 0 gives every group an unrelated ephemeral port)
    let ephemeral = sspdnn::ssp::transport::split_addr(&tcfg.addr)
        .map(|(_, p)| p == 0)
        .unwrap_or(false);
    if ephemeral && (svc.groups() > 1 || group.is_some()) {
        println!(
            "note: ephemeral ports — `train --server` needs a fixed base \
             port (or --group-addrs) to find the sibling groups; rerun \
             with --addr host:PORT"
        );
    } else if group.is_none() || group == Some(0) {
        println!(
            "attach workers with: sspdnn train --server {} [--preset ...]",
            svc.addrs()[0]
        );
    }
    svc.join();
    Ok(())
}

/// `sspdnn chaos --listen A --target B --script S [--seed N]` — a
/// standalone fault-injection relay for multi-process drills: park it
/// between a trainer and one `serve` endpoint and the scripted faults
/// fire at exact protocol frame counts, deterministically.
fn cmd_chaos(args: &Args) -> Result<(), String> {
    use std::net::ToSocketAddrs;
    let target = args.get("target").ok_or("chaos needs --target host:port")?;
    let target_addr = target
        .to_socket_addrs()
        .map_err(|e| format!("bad --target {target:?}: {e}"))?
        .next()
        .ok_or_else(|| format!("--target {target:?} resolved to no address"))?;
    let script_text = args
        .get("script")
        .ok_or("chaos needs --script (e.g. 'kill@update:40')")?;
    let script = sspdnn::ssp::transport::chaos::parse_script(script_text)?;
    let n_events = script.len();
    let seed = args.get_u64("seed").map_err(|e| e.to_string())?.unwrap_or(1);
    let listen = args.get("listen").unwrap_or("127.0.0.1:0");
    let proxy = sspdnn::ssp::transport::ChaosProxy::spawn_on(
        listen,
        target_addr,
        script,
        seed,
    )?;
    println!(
        "chaos proxy: {} -> {target} ({n_events} scripted faults, seed {seed})",
        proxy.addr()
    );
    println!(
        "attach the trainer here, e.g. --server {} or --group-addrs {}",
        proxy.addr(),
        proxy.addr()
    );
    // relay until killed; the proxy threads own all the work
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_simulate(args: &Args) -> Result<(), String> {
    let cfg = build_config(args)?;
    let dataset = build_dataset(&cfg);
    let run = run_experiment_on(
        &cfg,
        DriverOptions {
            trace: true,
            membership: parse_membership(args)?,
            ..DriverOptions::default()
        },
        &dataset,
    );
    let trace = run.trace.as_ref().expect("trace requested");
    let summary = trace.summary(run.machines);
    println!(
        "protocol trace: {} events ({} dropped) over {} virtual",
        summary.events,
        summary.dropped,
        fmt_duration(run.total_vtime)
    );
    let rows: Vec<Vec<String>> = summary
        .per_worker
        .iter()
        .enumerate()
        .map(|(p, w)| {
            vec![
                p.to_string(),
                w.clocks.to_string(),
                format!("{:.2}", w.mean_staleness()),
                w.blocks.to_string(),
                fmt_duration(w.blocked_s),
                format!("{:.2}ms", w.mean_delay() * 1e3),
            ]
        })
        .collect();
    println!(
        "{}",
        metrics::render_table(
            &["worker", "clocks", "mean staleness", "blocks", "blocked", "mean delay"],
            &rows
        )
    );
    println!(
        "eps rate {:.3} | congestion events {} | {:.1} MB shipped",
        run.epsilon_rate,
        run.congestion_events,
        run.bytes as f64 / 1e6
    );
    if let Some(dir) = args.get("out") {
        let path = format!("{dir}/{}_trace.csv", cfg.name);
        metrics::write_file(&path, &trace.to_csv()).map_err(|e| e.to_string())?;
        println!("wrote {path}");
    }
    Ok(())
}

fn parse_list<T: std::str::FromStr>(flag: &str, s: &str) -> Result<Vec<T>, String> {
    s.split(',')
        .map(|p| p.trim())
        .filter(|p| !p.is_empty())
        .map(|p| {
            p.parse::<T>()
                .map_err(|_| format!("bad --{flag} item {p:?}"))
        })
        .collect()
}

fn cmd_sweep(args: &Args) -> Result<(), String> {
    let doc = config_doc(args)?;
    let cfg = build_config_with(args, doc.as_ref())?;
    let mut grid = SweepConfig::default();
    if let Some(doc) = &doc {
        grid.apply_toml(doc)?;
    }
    if let Some(s) = args.get("grid-machines") {
        grid.machines = parse_list("grid-machines", s)?;
    }
    if let Some(s) = args.get("grid-staleness") {
        grid.staleness = parse_list("grid-staleness", s)?;
    }
    if let Some(s) = args.get("grid-policies") {
        grid.policies = parse_list("grid-policies", s)?;
    }
    if let Some(s) = args.get("grid-etas") {
        grid.etas = parse_list("grid-etas", s)?;
    }
    if let Some(t) = args.get_usize("budget").map_err(|e| e.to_string())? {
        grid.threads = t;
    }
    grid.validate()?;
    let per_batch_s =
        args.get_f64("per-batch-s").map_err(|e| e.to_string())?;
    if let Some(v) = per_batch_s {
        if !(v > 0.0 && v.is_finite()) {
            return Err(format!("--per-batch-s must be > 0, got {v}"));
        }
    }
    let opts = SweepOptions {
        threads: grid.threads,
        per_batch_s,
        ..SweepOptions::default()
    };
    let report = run_sweep(&cfg, &grid, &opts)?;
    println!(
        "sweep: {} | {} cells | budget {} ({} cells in flight x {} intra-op) | per-batch {:.3}ms",
        report.name,
        report.cells.len(),
        report.thread_budget,
        report.outer_workers,
        report.intra_op_threads,
        report.per_batch_s * 1e3,
    );
    let rows: Vec<Vec<String>> = report
        .cells
        .iter()
        .map(|c| {
            vec![
                c.machines.to_string(),
                c.policy.clone(),
                format!("{:.3}", c.eta),
                format!("{:.4}", c.final_objective),
                fmt_duration(c.total_vtime),
                fmt_duration(c.barrier_wait_s),
                format!("{:.3}", c.epsilon_rate),
                format!("{:.2}s", c.wall_s),
                format!("{:.1}", c.clocks_per_s),
            ]
        })
        .collect();
    println!(
        "{}",
        metrics::render_table(
            &[
                "machines", "policy", "eta", "final", "vtime", "barrier",
                "eps", "wall", "clocks/s"
            ],
            &rows
        )
    );
    println!("sweep wall: {:.2}s", report.wall_s);
    if let Some(dir) = args.get("out") {
        let json_path = format!("{dir}/{}_sweep.json", cfg.name);
        metrics::write_file(
            &json_path,
            &metrics::sweep_json(&report, true).to_string(),
        )
        .map_err(|e| e.to_string())?;
        let csv_path = format!("{dir}/{}_sweep.csv", cfg.name);
        metrics::write_file(&csv_path, &metrics::sweep_csv(&report))
            .map_err(|e| e.to_string())?;
        println!("wrote {json_path} and {csv_path}");
    }
    Ok(())
}

fn cmd_speedup(args: &Args) -> Result<(), String> {
    let cfg = build_config(args)?;
    let max = args
        .get_usize("max-machines")
        .map_err(|e| e.to_string())?
        .unwrap_or(cfg.cluster.machines);
    let dataset = build_dataset(&cfg);
    println!("speedup sweep on {} (1..{} machines)", cfg.name, max);
    let mut runs = Vec::new();
    for n in 1..=max {
        let run = run_experiment_on(
            &cfg,
            DriverOptions {
                machines: Some(n),
                ..DriverOptions::default()
            },
            &dataset,
        );
        println!(
            "  n={n}: final {:.4} in {}",
            run.final_objective,
            fmt_duration(run.total_vtime)
        );
        runs.push(run);
    }
    let sp = metrics::speedups(&runs);
    let rows: Vec<Vec<String>> = sp
        .iter()
        .map(|(n, s)| {
            vec![n.to_string(), format!("{s:.2}"), format!("{:.2}", *n as f64)]
        })
        .collect();
    println!(
        "{}",
        metrics::render_table(&["machines", "speedup", "linear"], &rows)
    );
    Ok(())
}

fn cmd_theory(args: &Args) -> Result<(), String> {
    let cfg = build_config(args)?;
    let dataset = build_dataset(&cfg);
    let eta = EtaSchedule::Poly {
        eta0: cfg.train.eta,
        d: args
            .get_f64("decay")
            .map_err(|e| e.to_string())?
            .unwrap_or(0.6) as f32,
    };
    let s = cfg.ssp.policy.staleness().unwrap_or(10);
    println!("Theorem 1/3: ||theta_ssp - theta_seq|| (relative), staleness {s}");
    let r1 = theory::theorem1_experiment(&cfg, &dataset, s, eta);
    let rows: Vec<Vec<String>> = r1
        .points
        .iter()
        .map(|p| {
            vec![
                p.updates.to_string(),
                format!("{:.3e}", p.rel_dist),
                p.layer_rel_dist
                    .iter()
                    .map(|d| format!("{d:.2e}"))
                    .collect::<Vec<_>>()
                    .join(" "),
            ]
        })
        .collect();
    println!(
        "{}",
        metrics::render_table(&["updates", "rel_dist", "per-layer"], &rows)
    );
    println!("log-log slope: {:.3} (negative = contraction)\n", r1.log_slope);

    println!("Theorem 2: layerwise movement contraction (undistributed)");
    let r2 = theory::theorem2_experiment(&cfg, &dataset, eta);
    for (m, slope) in r2.layer_slopes.iter().enumerate() {
        println!("  layer {m}: log-slope {slope:.3}");
    }
    println!(
        "  final ||w|| = {:.3} | diverged: {}",
        r2.final_norm, r2.diverged
    );
    Ok(())
}

fn cmd_data(args: &Args) -> Result<(), String> {
    let cfg = build_config(args)?;
    let ds = build_dataset(&cfg);
    let (name, nf, nc, ns) = ds.stats();
    println!(
        "{}",
        metrics::render_table(
            &["Dataset", "#Features", "#Classes", "#Samples"],
            &[vec![name, nf.to_string(), nc.to_string(), ns.to_string()]],
        )
    );
    Ok(())
}

fn cmd_artifact(args: &Args) -> Result<(), String> {
    let dir = args.get("artifacts").unwrap_or("artifacts");
    let manifest = Manifest::load(dir)?;
    match args.get("name") {
        None => {
            for name in manifest.names() {
                let a = manifest.get(name).unwrap();
                println!(
                    "{name}: dims {:?} batch {} loss {} impl {} ({})",
                    a.layer_dims,
                    a.batch,
                    a.loss,
                    a.impl_,
                    a.file.display()
                );
            }
        }
        Some(name) => {
            let spec = manifest
                .get(name)
                .ok_or_else(|| format!("no artifact {name:?}"))?;
            spec.validate()?;
            println!("compiling {name} via PJRT ...");
            let engine = PjrtEngine::load(spec).map_err(|e| e.to_string())?;
            // smoke run with random inputs
            use sspdnn::nn::{Labels, ParamSet};
            use sspdnn::tensor::Matrix;
            use sspdnn::util::Pcg64;
            let mut rng = Pcg64::new(0);
            let params = ParamSet::glorot(&spec.layer_dims, &mut rng);
            let x = Matrix::randn(spec.batch, spec.layer_dims[0], 1.0, &mut rng);
            let y = Labels::Class(
                (0..spec.batch)
                    .map(|_| rng.below(*spec.layer_dims.last().unwrap()) as u32)
                    .collect(),
            );
            let (loss, grads) =
                engine.step(&params, &x, &y).map_err(|e| e.to_string())?;
            println!(
                "smoke run OK: loss {loss:.4}, grad norm {:.4}",
                grads.norm()
            );
        }
    }
    Ok(())
}

fn cmd_presets() -> Result<(), String> {
    for name in [
        "tiny",
        "timit_scaled",
        "timit_paper",
        "imagenet_scaled",
        "imagenet_paper",
    ] {
        let c = ExperimentConfig::preset(name).unwrap();
        println!(
            "{name:16} dims {:?} params {} | {} | mb {} eta {}",
            c.model.dims,
            c.model.n_params(),
            c.ssp.policy.name(),
            c.train.batch,
            c.train.eta
        );
    }
    Ok(())
}
