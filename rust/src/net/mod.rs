//! Simulated cluster fabric (paper testbed: 10 GbE, 6 machines).
//!
//! Produces *virtual* transfer durations for update/fetch messages:
//! lognormal per-message latency around a configured mean, serialization
//! at link bandwidth, occasional congestion events (retransmit penalty).
//! Congestion is what physically realizes the paper's ε_{q,p} = 0: a
//! delayed in-window update simply misses the reader's fetch.
//!
//! Links are FIFO per source worker (TCP semantics): arrivals from one
//! worker never reorder.

use crate::config::ClusterConfig;
use crate::util::Pcg64;

#[derive(Debug)]
pub struct NetModel {
    latency_s: f64,
    bandwidth_bps: f64,
    drop_prob: f64,
    /// Multiplier applied to latency on a congestion event.
    congestion_penalty: f64,
    /// Lognormal sigma of per-message latency jitter.
    jitter_sigma: f64,
    /// Last arrival time per source link, for FIFO enforcement.
    last_arrival: Vec<f64>,
    rng: Pcg64,
    /// Totals for metrics.
    messages: u64,
    bytes: u64,
    congestion_events: u64,
}

impl NetModel {
    pub fn new(cfg: &ClusterConfig, workers: usize, rng: Pcg64) -> NetModel {
        NetModel {
            latency_s: cfg.latency_s,
            bandwidth_bps: cfg.bandwidth_bps,
            drop_prob: cfg.drop_prob,
            congestion_penalty: 20.0,
            jitter_sigma: 0.25,
            last_arrival: vec![0.0; workers],
            rng,
            messages: 0,
            bytes: 0,
            congestion_events: 0,
        }
    }

    /// Virtual arrival time at the server of `bytes` sent by `src` at
    /// `send_time`.
    pub fn arrival_time(&mut self, src: usize, send_time: f64, bytes: usize) -> f64 {
        self.messages += 1;
        self.bytes += bytes as u64;
        let base_latency =
            self.latency_s * self.rng.lognormal(0.0, self.jitter_sigma);
        let wire = bytes as f64 / self.bandwidth_bps;
        let mut delay = base_latency + wire;
        if self.rng.coin(self.drop_prob) {
            // lost/queued packet: retransmission-scale penalty
            self.congestion_events += 1;
            delay += self.latency_s * self.congestion_penalty
                + self.rng.exponential(1.0 / (self.latency_s * 10.0));
        }
        let t = send_time + delay;
        let fifo = &mut self.last_arrival[src];
        let arrival = t.max(*fifo + 1e-9);
        *fifo = arrival;
        arrival
    }

    /// Duration of a parameter fetch of `bytes` (server → worker): one
    /// RTT plus wire time. Fetches hit the local cache when the snapshot
    /// is fresh; the coordinator decides when to pay this.
    pub fn fetch_duration(&mut self, bytes: usize) -> f64 {
        2.0 * self.latency_s * self.rng.lognormal(0.0, self.jitter_sigma)
            + bytes as f64 / self.bandwidth_bps
    }

    pub fn messages(&self) -> u64 {
        self.messages
    }

    pub fn bytes(&self) -> u64 {
        self.bytes
    }

    pub fn congestion_events(&self) -> u64 {
        self.congestion_events
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model(drop: f64) -> NetModel {
        let cfg = ClusterConfig {
            latency_s: 100e-6,
            bandwidth_bps: 1.25e9,
            drop_prob: drop,
            ..ClusterConfig::default()
        };
        NetModel::new(&cfg, 4, Pcg64::new(1))
    }

    #[test]
    fn arrival_after_send_and_scales_with_bytes() {
        let mut n = model(0.0);
        let a = n.arrival_time(0, 1.0, 1_000);
        assert!(a > 1.0);
        let b = n.arrival_time(1, 1.0, 1_250_000_000); // 1s of wire time
        assert!(b - 1.0 > 1.0, "wire time dominates: {}", b - 1.0);
    }

    #[test]
    fn fifo_per_source() {
        let mut n = model(0.5); // heavy congestion → reordering pressure
        let mut last = 0.0;
        for i in 0..50 {
            let a = n.arrival_time(2, i as f64 * 1e-6, 100);
            assert!(a > last, "FIFO violated at {i}");
            last = a;
        }
    }

    #[test]
    fn different_sources_may_interleave() {
        let mut n = model(0.0);
        let a = n.arrival_time(0, 0.0, 1_000_000_000); // huge message
        let b = n.arrival_time(1, 0.0, 100); // tiny message
        assert!(b < a, "tiny message from another link arrives first");
    }

    #[test]
    fn congestion_events_counted_and_slow() {
        let mut clean = model(0.0);
        let mut lossy = model(0.9);
        let mut clean_sum = 0.0;
        let mut lossy_sum = 0.0;
        for _ in 0..200 {
            clean_sum += clean.arrival_time(0, 0.0, 100);
            lossy_sum += lossy.arrival_time(1, 0.0, 100);
        }
        assert_eq!(clean.congestion_events(), 0);
        assert!(lossy.congestion_events() > 100);
        assert!(lossy_sum > 2.0 * clean_sum);
    }

    #[test]
    fn metrics_accumulate() {
        let mut n = model(0.0);
        n.arrival_time(0, 0.0, 500);
        n.arrival_time(0, 0.0, 700);
        assert_eq!(n.messages(), 2);
        assert_eq!(n.bytes(), 1200);
    }
}
