//! TOML-subset parser: `[section]` headers, `key = value` pairs, `#`
//! comments. Values: integers, floats, booleans, quoted strings, and
//! arrays of integers, floats, or quoted strings. That is the entire
//! grammar the config system uses.

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
    IntArray(Vec<i64>),
    /// An array with at least one non-integer item (e.g. the `[sweep]`
    /// table's `etas = [0.05, 0.1]`).
    FloatArray(Vec<f64>),
    /// An array of quoted strings (e.g. the `[transport]` table's
    /// `group_addrs = ["10.0.0.1:7070", "10.0.0.2:7070"]`). No mixing
    /// with numeric items.
    StrArray(Vec<String>),
}

impl TomlValue {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Int(i) => Some(*i as f64),
            TomlValue::Float(f) => Some(*f),
            _ => None,
        }
    }

    /// Numeric-array view: both array flavors (and, for convenience, a
    /// bare number) coerce to `Vec<f64>`.
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        match self {
            TomlValue::Int(i) => Some(vec![*i as f64]),
            TomlValue::Float(f) => Some(vec![*f]),
            TomlValue::IntArray(v) => {
                Some(v.iter().map(|&x| x as f64).collect())
            }
            TomlValue::FloatArray(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// Parsed document: ordered (section, key, value) triples; the root
/// section is "".
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TomlDoc {
    entries: Vec<(String, String, TomlValue)>,
}

impl TomlDoc {
    pub fn entries(&self) -> &[(String, String, TomlValue)] {
        &self.entries
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.entries
            .iter()
            .find(|(s, k, _)| s == section && k == key)
            .map(|(_, _, v)| v)
    }
}

pub fn parse_toml(text: &str) -> Result<TomlDoc, String> {
    let mut doc = TomlDoc::default();
    let mut section = String::new();
    for (ln, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| format!("line {}: bad section header", ln + 1))?;
            section = name.trim().to_string();
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .ok_or_else(|| format!("line {}: expected key = value", ln + 1))?;
        let key = key.trim().to_string();
        let value = parse_value(value.trim())
            .map_err(|e| format!("line {}: {e}", ln + 1))?;
        doc.entries.push((section.clone(), key, value));
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // '#' outside of quotes starts a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or("unterminated string")?;
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?;
        // a quoted first item makes it a string array (every item must
        // then be quoted — no mixed arrays)
        if inner.trim_start().starts_with('"') {
            let mut items = Vec::new();
            for part in inner.split(',') {
                let part = part.trim();
                if part.is_empty() {
                    continue;
                }
                let item = part
                    .strip_prefix('"')
                    .and_then(|p| p.strip_suffix('"'))
                    .ok_or_else(|| {
                        format!("bad string-array item {part:?}")
                    })?;
                items.push(item.to_string());
            }
            return Ok(TomlValue::StrArray(items));
        }
        // all-integer arrays stay IntArray (model dims etc.); any
        // non-integer item promotes the whole array to FloatArray
        let mut ints = Vec::new();
        let mut floats = Vec::new();
        let mut all_int = true;
        for part in inner.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            if all_int {
                if let Ok(i) = part.parse::<i64>() {
                    ints.push(i);
                    floats.push(i as f64);
                    continue;
                }
                all_int = false;
            }
            floats.push(
                part.parse::<f64>()
                    .map_err(|_| format!("bad array item {part:?}"))?,
            );
        }
        return Ok(if all_int {
            TomlValue::IntArray(ints)
        } else {
            TomlValue::FloatArray(floats)
        });
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value {s:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_document() {
        let doc = parse_toml(
            r#"
            # experiment
            name = "fig2"   # trailing comment
            [ssp]
            staleness = 10
            [train]
            eta = 0.05
            paper_scale = false
            [model]
            dims = [360, 2048, 2001]
            "#,
        )
        .unwrap();
        assert_eq!(doc.get("", "name"), Some(&TomlValue::Str("fig2".into())));
        assert_eq!(doc.get("ssp", "staleness"), Some(&TomlValue::Int(10)));
        assert_eq!(doc.get("train", "eta"), Some(&TomlValue::Float(0.05)));
        assert_eq!(
            doc.get("train", "paper_scale"),
            Some(&TomlValue::Bool(false))
        );
        assert_eq!(
            doc.get("model", "dims"),
            Some(&TomlValue::IntArray(vec![360, 2048, 2001]))
        );
    }

    #[test]
    fn hash_inside_string_kept() {
        let doc = parse_toml(r##"tag = "a#b""##).unwrap();
        assert_eq!(doc.get("", "tag"), Some(&TomlValue::Str("a#b".into())));
    }

    #[test]
    fn errors() {
        assert!(parse_toml("[broken").is_err());
        assert!(parse_toml("novalue").is_err());
        assert!(parse_toml("x = ").is_err());
        assert!(parse_toml("x = [1, two]").is_err());
        assert!(parse_toml("x = [0.1, two]").is_err());
        assert!(parse_toml(r#"x = "unterminated"#).is_err());
    }

    #[test]
    fn float_arrays_and_numeric_views() {
        let doc =
            parse_toml("a = [0.05, 0.1]\nb = [1, 2.5]\nc = [1, 2]\nd = 3")
                .unwrap();
        assert_eq!(
            doc.get("", "a"),
            Some(&TomlValue::FloatArray(vec![0.05, 0.1]))
        );
        // a single float item promotes the whole array
        assert_eq!(
            doc.get("", "b"),
            Some(&TomlValue::FloatArray(vec![1.0, 2.5]))
        );
        // all-integer arrays keep their historical type
        assert_eq!(doc.get("", "c"), Some(&TomlValue::IntArray(vec![1, 2])));
        assert_eq!(
            doc.get("", "a").unwrap().as_f64_vec(),
            Some(vec![0.05, 0.1])
        );
        assert_eq!(
            doc.get("", "c").unwrap().as_f64_vec(),
            Some(vec![1.0, 2.0])
        );
        assert_eq!(doc.get("", "d").unwrap().as_f64_vec(), Some(vec![3.0]));
        assert_eq!(TomlValue::Str("x".into()).as_f64_vec(), None);
    }

    #[test]
    fn string_arrays() {
        let doc = parse_toml(
            r#"addrs = ["10.0.0.1:7070", "[::1]:7171"]
               empty = []"#,
        )
        .unwrap();
        assert_eq!(
            doc.get("", "addrs"),
            Some(&TomlValue::StrArray(vec![
                "10.0.0.1:7070".into(),
                "[::1]:7171".into()
            ]))
        );
        // an empty array has no first quoted item: stays IntArray
        assert_eq!(doc.get("", "empty"), Some(&TomlValue::IntArray(vec![])));
        assert_eq!(doc.get("", "addrs").unwrap().as_f64_vec(), None);
        assert!(parse_toml(r#"x = ["a", 3]"#).is_err(), "no mixed arrays");
        assert!(parse_toml(r#"x = ["a", b]"#).is_err());
    }

    #[test]
    fn negative_and_float_forms() {
        let doc = parse_toml("a = -3\nb = 1e-4\nc = -0.5").unwrap();
        assert_eq!(doc.get("", "a"), Some(&TomlValue::Int(-3)));
        assert_eq!(doc.get("", "b").unwrap().as_f64(), Some(1e-4));
        assert_eq!(doc.get("", "c").unwrap().as_f64(), Some(-0.5));
    }
}
