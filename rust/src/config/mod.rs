//! Typed experiment configuration + a TOML-subset loader.
//!
//! Everything an experiment needs is one `ExperimentConfig`: model
//! architecture, dataset generator, SSP policy, simulated cluster, and
//! training hyperparameters. Presets reproduce the paper's §6.1 settings;
//! config files (TOML subset: `[section]`, `key = value`, int/float/bool/
//! string/int-array values, `#` comments) override presets; CLI flags
//! override files.

mod toml;

pub use toml::{parse_toml, TomlDoc, TomlValue};

use crate::nn::{Activation, Loss};
use crate::ssp::Policy;
use crate::tensor::dispatch::{GemmKernel, Selection};

#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    /// Layer widths [input, hidden..., output].
    pub dims: Vec<usize>,
    pub activation: Activation,
    pub loss: Loss,
}

impl ModelConfig {
    pub fn n_params(&self) -> usize {
        self.dims.windows(2).map(|w| w[0] * w[1] + w[1]).sum()
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DataKind {
    TimitLike,
    ImagenetLike,
}

#[derive(Clone, Debug, PartialEq)]
pub struct DataConfig {
    pub kind: DataKind,
    pub n_samples: usize,
    pub n_features: usize,
    pub n_classes: usize,
    pub seed: u64,
}

#[derive(Clone, Debug, PartialEq)]
pub struct SspConfig {
    pub policy: Policy,
}

/// Simulated cluster (paper testbed: 6 machines × 16 cores, 10 GbE).
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterConfig {
    pub machines: usize,
    pub cores_per_machine: usize,
    /// Mean one-way message latency, seconds.
    pub latency_s: f64,
    /// Link bandwidth, bytes/second (10 GbE ≈ 1.25e9 B/s).
    pub bandwidth_bps: f64,
    /// Probability an in-window (best-effort) update misses its read —
    /// the paper's ε_{q,p} = 0 event (congestion / drop).
    pub drop_prob: f64,
    /// Straggler model: multiplicative lognormal sigma on compute time.
    pub straggler_sigma: f64,
    /// Probability of a severe straggler event per clock.
    pub straggler_prob: f64,
    /// Severe straggler slowdown factor.
    pub straggler_factor: f64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            machines: 6,
            cores_per_machine: 16,
            latency_s: 100e-6,
            bandwidth_bps: 1.25e9,
            drop_prob: 0.05,
            straggler_sigma: 0.1,
            straggler_prob: 0.02,
            straggler_factor: 4.0,
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Engine {
    /// Native Rust backprop (`nn`).
    Native,
    /// PJRT-compiled artifact (`runtime`).
    Pjrt,
}

#[derive(Clone, Debug, PartialEq)]
pub struct TrainConfig {
    pub eta: f32,
    pub batch: usize,
    /// Minibatches per SSP clock tick.
    pub batches_per_clock: usize,
    /// Total clocks each worker runs.
    pub clocks: usize,
    pub seed: u64,
    pub engine: Engine,
    /// Artifact name in artifacts/manifest.json (Pjrt engine).
    pub artifact: Option<String>,
    /// Intra-op GEMM threads per worker (`tensor::GemmPool`). The
    /// cluster's parallelism budget is explicit: N workers × T intra-op
    /// threads. Default 1 — worker-level parallelism owns the cores
    /// unless a run raises it (CLI `--threads`, TOML
    /// `train.intra_op_threads`). Thread count never changes values
    /// (the packed backend is bitwise split-invariant).
    pub intra_op_threads: usize,
    /// GEMM microkernel selection (`tensor::dispatch`): `auto` takes
    /// the best path runtime CPU-feature detection finds; `scalar`
    /// forces the bitwise oracle; `avx2`/`avx512`/`neon` pin a SIMD
    /// path (rejected by `validate` if this host lacks the feature).
    /// TOML `train.gemm_kernel`, CLI `--gemm-kernel`.
    pub gemm_kernel: GemmKernel,
    /// bf16 pack storage / f32 compute for the GEMM pack buffers:
    /// halves pack memory traffic at one round-to-nearest-even per
    /// operand read. TOML `train.gemm_bf16`, CLI `--gemm-bf16`.
    pub gemm_bf16: bool,
}

impl TrainConfig {
    /// Resolve the configured kernel choice against this host into the
    /// concrete selection the engines run.
    pub fn gemm_selection(&self) -> Result<Selection, String> {
        Ok(Selection::new(self.gemm_kernel.resolve()?, self.gemm_bf16))
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct ExperimentConfig {
    pub name: String,
    pub model: ModelConfig,
    pub data: DataConfig,
    pub ssp: SspConfig,
    pub cluster: ClusterConfig,
    pub train: TrainConfig,
}

impl ExperimentConfig {
    /// Paper §6.1 TIMIT setting, scaled for a single host by default:
    /// 6×2048 hidden at paper scale; the scaled preset keeps 6 hidden
    /// layers (depth drives the layerwise dynamics) at width 256.
    pub fn timit_scaled() -> ExperimentConfig {
        ExperimentConfig {
            name: "timit_scaled".into(),
            model: ModelConfig {
                dims: vec![360, 256, 256, 256, 256, 256, 256, 2001],
                activation: Activation::Sigmoid,
                loss: Loss::Xent,
            },
            data: DataConfig {
                kind: DataKind::TimitLike,
                n_samples: 20_000,
                n_features: 360,
                n_classes: 2001,
                seed: 11,
            },
            ssp: SspConfig {
                policy: Policy::Ssp { staleness: 10 },
            },
            cluster: ClusterConfig::default(),
            train: TrainConfig {
                eta: 0.05,
                batch: 100,
                batches_per_clock: 4,
                clocks: 120,
                seed: 7,
                engine: Engine::Native,
                artifact: Some("timit_scaled".into()),
                intra_op_threads: 1,
                gemm_kernel: GemmKernel::Auto,
                gemm_bf16: false,
            },
        }
    }

    /// Paper §6.1 TIMIT at full scale (24M params, minibatch 100, η=0.05,
    /// staleness 10). Heavy: used with `--paper-scale`.
    pub fn timit_paper() -> ExperimentConfig {
        let mut c = ExperimentConfig::timit_scaled();
        c.name = "timit_paper".into();
        c.model.dims = vec![360, 2048, 2048, 2048, 2048, 2048, 2048, 2001];
        c.data.n_samples = 1_100_000;
        c.train.artifact = None;
        c
    }

    /// Paper §6.1 ImageNet-63K setting, scaled (features 21504→2150).
    pub fn imagenet_scaled() -> ExperimentConfig {
        ExperimentConfig {
            name: "imagenet_scaled".into(),
            model: ModelConfig {
                dims: vec![2150, 500, 300, 200, 1000],
                activation: Activation::Sigmoid,
                loss: Loss::Xent,
            },
            data: DataConfig {
                kind: DataKind::ImagenetLike,
                n_samples: 6_300,
                n_features: 2150,
                n_classes: 1000,
                seed: 13,
            },
            ssp: SspConfig {
                policy: Policy::Ssp { staleness: 10 },
            },
            cluster: ClusterConfig::default(),
            train: TrainConfig {
                eta: 1.0,
                batch: 100,
                batches_per_clock: 2,
                clocks: 100,
                seed: 17,
                engine: Engine::Native,
                artifact: Some("imagenet_scaled".into()),
                intra_op_threads: 1,
                gemm_kernel: GemmKernel::Auto,
                gemm_bf16: false,
            },
        }
    }

    /// Paper §6.1 ImageNet-63K at full scale (132M params, mb 1000, η=1).
    pub fn imagenet_paper() -> ExperimentConfig {
        let mut c = ExperimentConfig::imagenet_scaled();
        c.name = "imagenet_paper".into();
        c.model.dims = vec![21_504, 5000, 3000, 2000, 1000];
        c.data.n_samples = 63_000;
        c.data.n_features = 21_504;
        c.train.batch = 1000;
        c.train.artifact = None;
        c
    }

    /// Small config for tests/quickstart (matches the `tiny` artifact).
    pub fn tiny() -> ExperimentConfig {
        ExperimentConfig {
            name: "tiny".into(),
            model: ModelConfig {
                dims: vec![16, 32, 10],
                activation: Activation::Sigmoid,
                loss: Loss::Xent,
            },
            data: DataConfig {
                kind: DataKind::TimitLike,
                n_samples: 512,
                n_features: 16,
                n_classes: 10,
                seed: 1,
            },
            ssp: SspConfig {
                policy: Policy::Ssp { staleness: 2 },
            },
            cluster: ClusterConfig {
                machines: 3,
                ..ClusterConfig::default()
            },
            train: TrainConfig {
                eta: 0.5,
                batch: 8,
                batches_per_clock: 4,
                clocks: 40,
                seed: 3,
                engine: Engine::Native,
                artifact: Some("tiny".into()),
                intra_op_threads: 1,
                gemm_kernel: GemmKernel::Auto,
                gemm_bf16: false,
            },
        }
    }

    pub fn preset(name: &str) -> Option<ExperimentConfig> {
        match name {
            "tiny" => Some(ExperimentConfig::tiny()),
            "timit_scaled" | "timit" => Some(ExperimentConfig::timit_scaled()),
            "timit_paper" => Some(ExperimentConfig::timit_paper()),
            "imagenet_scaled" | "imagenet" => {
                Some(ExperimentConfig::imagenet_scaled())
            }
            "imagenet_paper" => Some(ExperimentConfig::imagenet_paper()),
            _ => None,
        }
    }

    /// Apply a parsed TOML-subset document on top of this config.
    pub fn apply_toml(&mut self, doc: &toml::TomlDoc) -> Result<(), String> {
        use TomlValue::*;
        for (section, key, value) in doc.entries() {
            match (section.as_str(), key.as_str(), value) {
                ("", "name", Str(s)) => self.name = s.clone(),
                ("model", "dims", IntArray(v)) => {
                    self.model.dims = v.iter().map(|&x| x as usize).collect()
                }
                ("model", "activation", Str(s)) => {
                    self.model.activation = Activation::parse(s)
                        .ok_or_else(|| format!("bad activation {s}"))?
                }
                ("model", "loss", Str(s)) => {
                    self.model.loss =
                        Loss::parse(s).ok_or_else(|| format!("bad loss {s}"))?
                }
                ("data", "kind", Str(s)) => {
                    self.data.kind = match s.as_str() {
                        "timit" => DataKind::TimitLike,
                        "imagenet" => DataKind::ImagenetLike,
                        _ => return Err(format!("bad data kind {s}")),
                    }
                }
                ("data", "n_samples", Int(n)) => self.data.n_samples = *n as usize,
                ("data", "n_features", Int(n)) => self.data.n_features = *n as usize,
                ("data", "n_classes", Int(n)) => self.data.n_classes = *n as usize,
                ("data", "seed", Int(n)) => self.data.seed = *n as u64,
                ("ssp", "staleness", Int(n)) => {
                    self.ssp.policy = Policy::Ssp {
                        staleness: *n as u64,
                    }
                }
                ("ssp", "policy", Str(s)) => {
                    self.ssp.policy = match s.as_str() {
                        "bsp" => Policy::Bsp,
                        "async" => Policy::Async,
                        "ssp" => self.ssp.policy, // staleness key sets s
                        _ => return Err(format!("bad policy {s}")),
                    }
                }
                ("cluster", "machines", Int(n)) => {
                    self.cluster.machines = *n as usize
                }
                ("cluster", "cores_per_machine", Int(n)) => {
                    self.cluster.cores_per_machine = *n as usize
                }
                ("cluster", "latency_us", v) => {
                    self.cluster.latency_s = v.as_f64().ok_or("latency_us")? * 1e-6
                }
                ("cluster", "bandwidth_gbps", v) => {
                    self.cluster.bandwidth_bps =
                        v.as_f64().ok_or("bandwidth_gbps")? * 1.25e8
                }
                ("cluster", "drop_prob", v) => {
                    self.cluster.drop_prob = v.as_f64().ok_or("drop_prob")?
                }
                ("cluster", "straggler_sigma", v) => {
                    self.cluster.straggler_sigma =
                        v.as_f64().ok_or("straggler_sigma")?
                }
                ("cluster", "straggler_prob", v) => {
                    self.cluster.straggler_prob =
                        v.as_f64().ok_or("straggler_prob")?
                }
                ("cluster", "straggler_factor", v) => {
                    self.cluster.straggler_factor =
                        v.as_f64().ok_or("straggler_factor")?
                }
                ("train", "eta", v) => {
                    self.train.eta = v.as_f64().ok_or("eta")? as f32
                }
                ("train", "batch", Int(n)) => self.train.batch = *n as usize,
                ("train", "batches_per_clock", Int(n)) => {
                    self.train.batches_per_clock = *n as usize
                }
                ("train", "clocks", Int(n)) => self.train.clocks = *n as usize,
                ("train", "seed", Int(n)) => self.train.seed = *n as u64,
                ("train", "engine", Str(s)) => {
                    self.train.engine = match s.as_str() {
                        "native" => Engine::Native,
                        "pjrt" => Engine::Pjrt,
                        _ => return Err(format!("bad engine {s}")),
                    }
                }
                ("train", "artifact", Str(s)) => {
                    self.train.artifact = Some(s.clone())
                }
                ("train", "intra_op_threads", Int(n)) => {
                    if *n < 1 {
                        return Err(format!(
                            "train.intra_op_threads must be >= 1, got {n}"
                        ));
                    }
                    self.train.intra_op_threads = *n as usize
                }
                ("train", "gemm_kernel", Str(s)) => {
                    self.train.gemm_kernel = GemmKernel::parse(s).ok_or_else(|| {
                        format!(
                            "bad train.gemm_kernel {s} \
                             (auto|scalar|avx2|avx512|neon)"
                        )
                    })?
                }
                ("train", "gemm_bf16", Bool(b)) => self.train.gemm_bf16 = *b,
                // the [sweep] table belongs to SweepConfig::apply_toml
                // (the sweep harness) and [transport] to
                // TransportConfig::apply_toml (the serve/--server
                // deployment path); skip them here so one file can
                // carry the experiment, its grid and its endpoints
                ("sweep", _, _) => {}
                ("transport", _, _) => {}
                (sec, k, _) => {
                    return Err(format!("unknown config key [{sec}] {k}"))
                }
            }
        }
        self.validate()
    }

    pub fn load_file(path: &str, base: Option<&str>) -> Result<ExperimentConfig, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read {path}: {e}"))?;
        let doc = parse_toml(&text)?;
        let mut cfg = match base {
            Some(b) => ExperimentConfig::preset(b)
                .ok_or_else(|| format!("unknown preset {b}"))?,
            None => ExperimentConfig::tiny(),
        };
        cfg.apply_toml(&doc)?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.model.dims.len() < 2 {
            return Err("model.dims needs >= 2 entries".into());
        }
        if self.model.dims[0] != self.data.n_features {
            return Err(format!(
                "model input {} != data features {}",
                self.model.dims[0], self.data.n_features
            ));
        }
        if *self.model.dims.last().unwrap() != self.data.n_classes
            && self.model.loss == Loss::Xent
        {
            return Err(format!(
                "model output {} != n_classes {}",
                self.model.dims.last().unwrap(),
                self.data.n_classes
            ));
        }
        if self.train.batch == 0 || self.train.clocks == 0 {
            return Err("batch/clocks must be positive".into());
        }
        if self.train.intra_op_threads == 0 {
            return Err("train.intra_op_threads must be >= 1".into());
        }
        if let Err(e) = self.train.gemm_kernel.resolve() {
            return Err(format!("train.gemm_kernel: {e}"));
        }
        if self.cluster.machines == 0 {
            return Err("need >= 1 machine".into());
        }
        Ok(())
    }
}

/// The sweep harness's grid (`coordinator::sweep`): every
/// `(machines × eta × policy-cell)` combination becomes one full driver
/// run, where an `"ssp"` policy entry expands to one cell per staleness
/// value. Parsed from the `[sweep]` TOML table (which
/// `ExperimentConfig::apply_toml` deliberately skips) and overridable
/// from the `sweep` subcommand's flags.
#[derive(Clone, Debug, PartialEq)]
pub struct SweepConfig {
    pub machines: Vec<usize>,
    /// Staleness bounds for `"ssp"` policy cells.
    pub staleness: Vec<u64>,
    /// Policy names: any of `"ssp"`, `"bsp"`, `"async"`.
    pub policies: Vec<String>,
    /// Learning rates; empty = sweep only the config's `train.eta`.
    pub etas: Vec<f32>,
    /// Total thread budget, shared with `train.intra_op_threads` (the
    /// harness runs `budget / intra_op_threads` cells concurrently).
    pub threads: usize,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            machines: vec![1, 2, 4, 6],
            staleness: vec![10],
            policies: vec!["ssp".into()],
            etas: Vec::new(),
            threads: 4,
        }
    }
}

impl SweepConfig {
    /// Apply a parsed TOML-subset document's `[sweep]` table.
    pub fn apply_toml(&mut self, doc: &toml::TomlDoc) -> Result<(), String> {
        use TomlValue::*;
        for (section, key, value) in doc.entries() {
            if section != "sweep" {
                continue;
            }
            // negative integers would wrap to huge unsigned values past
            // validate()'s zero checks — reject them at parse time
            let non_negative = |what: &str, xs: &[i64]| -> Result<(), String> {
                match xs.iter().find(|&&x| x < 0) {
                    Some(x) => Err(format!("sweep.{what} must be >= 0, got {x}")),
                    None => Ok(()),
                }
            };
            match (key.as_str(), value) {
                ("machines", IntArray(v)) => {
                    non_negative("machines", v)?;
                    self.machines = v.iter().map(|&x| x as usize).collect()
                }
                ("machines", Int(n)) => {
                    non_negative("machines", &[*n])?;
                    self.machines = vec![*n as usize]
                }
                ("staleness", IntArray(v)) => {
                    non_negative("staleness", v)?;
                    self.staleness = v.iter().map(|&x| x as u64).collect()
                }
                ("staleness", Int(n)) => {
                    non_negative("staleness", &[*n])?;
                    self.staleness = vec![*n as u64]
                }
                ("policies", Str(s)) => {
                    self.policies = s
                        .split(',')
                        .map(|p| p.trim().to_string())
                        .filter(|p| !p.is_empty())
                        .collect()
                }
                ("etas", v) => {
                    self.etas = v
                        .as_f64_vec()
                        .ok_or("sweep.etas must be a numeric array")?
                        .iter()
                        .map(|&x| x as f32)
                        .collect()
                }
                ("threads", Int(n)) => {
                    if *n < 1 {
                        return Err(format!(
                            "sweep.threads must be >= 1, got {n}"
                        ));
                    }
                    self.threads = *n as usize
                }
                (k, _) => {
                    return Err(format!("unknown config key [sweep] {k}"))
                }
            }
        }
        self.validate()
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.machines.is_empty() {
            return Err("sweep.machines must not be empty".into());
        }
        if self.machines.iter().any(|&m| m == 0) {
            return Err("sweep.machines entries must be >= 1".into());
        }
        if self.threads == 0 {
            return Err("sweep.threads must be >= 1".into());
        }
        if self.policies.is_empty() {
            return Err("sweep.policies must not be empty".into());
        }
        for p in &self.policies {
            match p.as_str() {
                "ssp" | "bsp" | "async" => {}
                other => {
                    return Err(format!("unknown sweep policy {other:?}"))
                }
            }
        }
        if self.policies.iter().any(|p| p == "ssp")
            && self.staleness.is_empty()
        {
            return Err("sweep.staleness must not be empty for ssp".into());
        }
        Ok(())
    }
}

/// The multi-process transport deployment (`ssp::transport`): where the
/// shard service listens and how the layer shards map onto message
/// endpoints. Parsed from the `[transport]` TOML table (which
/// `ExperimentConfig::apply_toml` deliberately skips, like `[sweep]`)
/// and overridable from the `serve`/`train --server` CLI flags.
#[derive(Clone, Debug, PartialEq)]
pub struct TransportConfig {
    /// Base listen/connect address `host:port`; shard group `g` uses
    /// `port + g` unless `group_addrs` names its endpoint explicitly.
    pub addr: String,
    /// Endpoint count (clamped to the layer count at serve time).
    pub shard_groups: usize,
    /// Version-gate delta fetches on the wire. Off: every read ships
    /// every layer (the bench's no-gate baseline).
    pub gated: bool,
    /// Pipeline commits: per-connection writer thread + bounded
    /// in-flight acknowledgement window instead of one blocking round
    /// trip per UPDATE/COMMIT frame.
    pub pipeline: bool,
    /// Max in-flight unacknowledged frames per connection when
    /// `pipeline` is on (>= 1).
    pub window: usize,
    /// Explicit per-group endpoint addresses for a multi-process server
    /// tier (one `serve --group g` process per entry, entry `g` for
    /// group `g`). Empty: derive every endpoint from `addr` by the
    /// `port + g` convention. When set, the length must equal
    /// `shard_groups`.
    pub group_addrs: Vec<String>,
    /// Bound on every TCP connect, initial and reconnect (ms).
    pub connect_timeout_ms: u64,
    /// Socket read timeout for request/response exchanges (ms); 0
    /// blocks forever. WAIT is always exempt (a barrier legitimately
    /// outlasts any bound).
    pub io_timeout_ms: u64,
    /// Reconnect attempts per supervised operation before the client
    /// declares the server tier lost. 0 disables supervision: every
    /// socket fault surfaces immediately.
    pub max_retries: u32,
    /// First reconnect backoff delay (ms); doubles per attempt, capped
    /// at 2 s.
    pub backoff_base_ms: u64,
    /// Worker lease duration granted by each heartbeat (ms); 0
    /// disables heartbeating entirely. An expired lease makes the
    /// server release barrier waits parked on the dead worker.
    pub lease_ms: u64,
    /// Heartbeat renewal interval (ms); must undercut `lease_ms` when
    /// leases are on.
    pub heartbeat_ms: u64,
    /// How long the service's shutdown path waits for its wake-up
    /// connects to the group listeners (ms).
    pub wake_timeout_ms: u64,
    /// Elastic membership: a lapsed worker lease **evicts** the worker
    /// (survivors rebalance its data shard and keep converging) instead
    /// of failing their barrier waits, and the ADMIT/LEAVE opcodes let
    /// workers leave and rejoin. Off preserves the fail-fast lease
    /// semantics exactly. Requires `lease_ms > 0` to ever trigger from
    /// silence (a LEAVE still works without leases).
    pub elastic: bool,
    /// Negotiated payload codec (`off | bf16 | f16 | topk:<frac>`).
    /// `off` keeps every payload raw f32 — bitwise wire v4. The lossy
    /// codecs quantize layer payloads (with client-side error feedback
    /// on the commit path) to cut bytes per clock; see
    /// `ssp::transport::Codec`.
    pub codec: String,
}

impl Default for TransportConfig {
    fn default() -> Self {
        TransportConfig {
            addr: "127.0.0.1:7070".into(),
            shard_groups: 1,
            gated: true,
            pipeline: true,
            window: 32,
            group_addrs: Vec::new(),
            connect_timeout_ms: 5000,
            io_timeout_ms: 30_000,
            max_retries: 5,
            backoff_base_ms: 50,
            lease_ms: 10_000,
            heartbeat_ms: 2500,
            wake_timeout_ms: 500,
            elastic: false,
            codec: "off".into(),
        }
    }
}

impl TransportConfig {
    /// Apply a parsed TOML-subset document's `[transport]` table.
    pub fn apply_toml(&mut self, doc: &toml::TomlDoc) -> Result<(), String> {
        use TomlValue::*;
        for (section, key, value) in doc.entries() {
            if section != "transport" {
                continue;
            }
            match (key.as_str(), value) {
                ("addr", Str(s)) => self.addr = s.clone(),
                ("shard_groups", Int(n)) => {
                    if *n < 1 {
                        return Err(format!(
                            "transport.shard_groups must be >= 1, got {n}"
                        ));
                    }
                    self.shard_groups = *n as usize
                }
                ("gated", Bool(b)) => self.gated = *b,
                ("pipeline", Bool(b)) => self.pipeline = *b,
                ("window", Int(n)) => {
                    if *n < 1 {
                        return Err(format!(
                            "transport.window must be >= 1, got {n}"
                        ));
                    }
                    self.window = *n as usize
                }
                ("group_addrs", StrArray(v)) => self.group_addrs = v.clone(),
                // `group_addrs = []` parses as an empty numeric array
                ("group_addrs", IntArray(v)) if v.is_empty() => {
                    self.group_addrs = Vec::new()
                }
                ("connect_timeout_ms", Int(n)) => {
                    if *n < 1 {
                        return Err(format!(
                            "transport.connect_timeout_ms must be >= 1, got {n}"
                        ));
                    }
                    self.connect_timeout_ms = *n as u64
                }
                ("io_timeout_ms", Int(n)) => {
                    if *n < 0 {
                        return Err(format!(
                            "transport.io_timeout_ms must be >= 0, got {n}"
                        ));
                    }
                    self.io_timeout_ms = *n as u64
                }
                ("max_retries", Int(n)) => {
                    if *n < 0 || *n > u32::MAX as i64 {
                        return Err(format!(
                            "transport.max_retries out of range: {n}"
                        ));
                    }
                    self.max_retries = *n as u32
                }
                ("backoff_base_ms", Int(n)) => {
                    if *n < 1 {
                        return Err(format!(
                            "transport.backoff_base_ms must be >= 1, got {n}"
                        ));
                    }
                    self.backoff_base_ms = *n as u64
                }
                ("lease_ms", Int(n)) => {
                    if *n < 0 {
                        return Err(format!(
                            "transport.lease_ms must be >= 0, got {n}"
                        ));
                    }
                    self.lease_ms = *n as u64
                }
                ("heartbeat_ms", Int(n)) => {
                    if *n < 1 {
                        return Err(format!(
                            "transport.heartbeat_ms must be >= 1, got {n}"
                        ));
                    }
                    self.heartbeat_ms = *n as u64
                }
                ("wake_timeout_ms", Int(n)) => {
                    if *n < 1 {
                        return Err(format!(
                            "transport.wake_timeout_ms must be >= 1, got {n}"
                        ));
                    }
                    self.wake_timeout_ms = *n as u64
                }
                ("elastic", Bool(b)) => self.elastic = *b,
                ("codec", Str(s)) => self.codec = s.clone(),
                (k, _) => {
                    return Err(format!("unknown config key [transport] {k}"))
                }
            }
        }
        self.validate()
    }

    /// Serialize back to the `[transport]` table — `apply_toml` of the
    /// output reproduces `self` (pinned by the round-trip test).
    pub fn to_toml(&self) -> String {
        let addrs = self
            .group_addrs
            .iter()
            .map(|a| format!("\"{a}\""))
            .collect::<Vec<_>>()
            .join(", ");
        format!(
            "[transport]\naddr = \"{}\"\nshard_groups = {}\ngated = {}\n\
             pipeline = {}\nwindow = {}\ngroup_addrs = [{addrs}]\n\
             connect_timeout_ms = {}\nio_timeout_ms = {}\n\
             max_retries = {}\nbackoff_base_ms = {}\nlease_ms = {}\n\
             heartbeat_ms = {}\nwake_timeout_ms = {}\nelastic = {}\n\
             codec = \"{}\"\n",
            self.addr,
            self.shard_groups,
            self.gated,
            self.pipeline,
            self.window,
            self.connect_timeout_ms,
            self.io_timeout_ms,
            self.max_retries,
            self.backoff_base_ms,
            self.lease_ms,
            self.heartbeat_ms,
            self.wake_timeout_ms,
            self.elastic,
            self.codec,
        )
    }

    pub fn validate(&self) -> Result<(), String> {
        // same parser the service/client use, so validation accepts
        // exactly what they can bind/dial
        crate::ssp::transport::split_addr(&self.addr)
            .map_err(|e| format!("transport.addr: {e}"))?;
        if self.shard_groups == 0 {
            return Err("transport.shard_groups must be >= 1".into());
        }
        if self.window == 0 {
            return Err("transport.window must be >= 1".into());
        }
        if !self.group_addrs.is_empty()
            && self.group_addrs.len() != self.shard_groups
        {
            return Err(format!(
                "transport.group_addrs has {} entries but shard_groups = {}",
                self.group_addrs.len(),
                self.shard_groups
            ));
        }
        for a in &self.group_addrs {
            crate::ssp::transport::split_addr(a)
                .map_err(|e| format!("transport.group_addrs: {e}"))?;
        }
        if self.connect_timeout_ms == 0 {
            return Err("transport.connect_timeout_ms must be >= 1".into());
        }
        if self.backoff_base_ms == 0 {
            return Err("transport.backoff_base_ms must be >= 1".into());
        }
        if self.lease_ms > 0 && self.heartbeat_ms >= self.lease_ms {
            return Err(format!(
                "transport.heartbeat_ms ({}) must undercut lease_ms ({})",
                self.heartbeat_ms, self.lease_ms
            ));
        }
        if self.wake_timeout_ms == 0 {
            return Err("transport.wake_timeout_ms must be >= 1".into());
        }
        self.parsed_codec()?;
        Ok(())
    }

    /// The `codec` string parsed into a transport [`Codec`] — grammar
    /// errors surface at config validation, not mid-connect.
    pub fn parsed_codec(&self) -> Result<crate::ssp::transport::Codec, String> {
        crate::ssp::transport::Codec::parse(&self.codec)
            .map_err(|e| format!("transport.codec: {e}"))
    }

    /// The client-side connection supervisor knobs, single-sourced from
    /// this table.
    pub fn fault_policy(&self) -> crate::ssp::transport::FaultPolicy {
        crate::ssp::transport::FaultPolicy {
            connect_timeout: std::time::Duration::from_millis(
                self.connect_timeout_ms,
            ),
            io_timeout: if self.io_timeout_ms == 0 {
                None
            } else {
                Some(std::time::Duration::from_millis(self.io_timeout_ms))
            },
            max_retries: self.max_retries,
            backoff_base: std::time::Duration::from_millis(
                self.backoff_base_ms,
            ),
        }
    }

    /// The server-side service knobs, single-sourced from this table.
    /// `init_digest` lets a warm-restarted `serve` advertise the
    /// config-derived digest instead of hashing its restored state.
    pub fn service_options(
        &self,
        init_digest: Option<u64>,
    ) -> crate::ssp::transport::ServiceOptions {
        crate::ssp::transport::ServiceOptions {
            wake_timeout: std::time::Duration::from_millis(
                self.wake_timeout_ms,
            ),
            init_digest,
            elastic: self.elastic,
        }
    }

    /// Group `g`'s endpoint address: the explicit `group_addrs` entry
    /// when configured, else `addr`'s host on `port + g`.
    pub fn group_addr(&self, g: usize) -> Result<String, String> {
        if !self.group_addrs.is_empty() {
            return self.group_addrs.get(g).cloned().ok_or_else(|| {
                format!("group {g} has no transport.group_addrs entry")
            });
        }
        let (host, port) = crate::ssp::transport::split_addr(&self.addr)
            .map_err(|e| format!("transport.addr: {e}"))?;
        let port = port
            .checked_add(g as u16)
            .ok_or_else(|| format!("group {g} port overflows u16"))?;
        // re-bracket IPv6 literals (split_addr strips the brackets)
        if host.contains(':') {
            Ok(format!("[{host}]:{port}"))
        } else {
            Ok(format!("{host}:{port}"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        for name in [
            "tiny",
            "timit_scaled",
            "timit_paper",
            "imagenet_scaled",
            "imagenet_paper",
        ] {
            let c = ExperimentConfig::preset(name).unwrap();
            c.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
        }
        assert!(ExperimentConfig::preset("nope").is_none());
    }

    #[test]
    fn paper_scale_param_counts() {
        // §6.1: TIMIT ~24M params, ImageNet ~132M params.
        let t = ExperimentConfig::timit_paper().model.n_params();
        assert!((23_000_000..27_000_000).contains(&t), "timit {t}");
        let i = ExperimentConfig::imagenet_paper().model.n_params();
        assert!((130_000_000..136_000_000).contains(&i), "imagenet {i}");
    }

    #[test]
    fn toml_overrides() {
        let mut c = ExperimentConfig::tiny();
        let doc = parse_toml(
            r#"
            name = "custom"
            [model]
            dims = [8, 4, 2]
            activation = "tanh"
            [data]
            n_features = 8
            n_classes = 2
            n_samples = 64
            [ssp]
            staleness = 5
            [train]
            eta = 0.25
            batch = 4
            "#,
        )
        .unwrap();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.name, "custom");
        assert_eq!(c.model.dims, vec![8, 4, 2]);
        assert_eq!(c.model.activation, Activation::Tanh);
        assert_eq!(c.ssp.policy, Policy::Ssp { staleness: 5 });
        assert_eq!(c.train.eta, 0.25);
    }

    #[test]
    fn intra_op_threads_key_and_validation() {
        let mut c = ExperimentConfig::tiny();
        assert_eq!(c.train.intra_op_threads, 1, "serial by default");
        let doc = parse_toml("[train]\nintra_op_threads = 4\n").unwrap();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.train.intra_op_threads, 4);
        let bad = parse_toml("[train]\nintra_op_threads = -1\n").unwrap();
        assert!(c.apply_toml(&bad).is_err(), "negative threads rejected");
        c.train.intra_op_threads = 0;
        assert!(c.validate().is_err(), "0 threads rejected");
    }

    #[test]
    fn unknown_key_rejected() {
        let mut c = ExperimentConfig::tiny();
        let doc = parse_toml("[train]\nbogus = 1\n").unwrap();
        assert!(c.apply_toml(&doc).is_err());
    }

    #[test]
    fn sweep_table_parses_and_is_skipped_by_experiment_config() {
        let doc = parse_toml(
            r#"
            [train]
            eta = 0.1
            [sweep]
            machines = [1, 2, 4]
            staleness = [0, 10]
            policies = "ssp, bsp"
            etas = [0.05, 0.1]
            threads = 8
            "#,
        )
        .unwrap();
        // the experiment config skips the [sweep] table entirely
        let mut c = ExperimentConfig::tiny();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.train.eta, 0.1);
        // ... while SweepConfig picks it up
        let mut s = SweepConfig::default();
        s.apply_toml(&doc).unwrap();
        assert_eq!(s.machines, vec![1, 2, 4]);
        assert_eq!(s.staleness, vec![0, 10]);
        assert_eq!(s.policies, vec!["ssp".to_string(), "bsp".to_string()]);
        assert_eq!(s.etas, vec![0.05f32, 0.1f32]);
        assert_eq!(s.threads, 8);
    }

    #[test]
    fn sweep_config_validation() {
        let mut s = SweepConfig::default();
        s.validate().unwrap();
        s.threads = 0;
        assert!(s.validate().is_err());
        s = SweepConfig {
            machines: vec![],
            ..SweepConfig::default()
        };
        assert!(s.validate().is_err());
        s = SweepConfig {
            policies: vec!["turbo".into()],
            ..SweepConfig::default()
        };
        assert!(s.validate().is_err());
        s = SweepConfig {
            staleness: vec![],
            ..SweepConfig::default()
        };
        assert!(s.validate().is_err(), "ssp needs staleness values");
        s.policies = vec!["bsp".into()];
        s.validate().unwrap();
        let bad = parse_toml("[sweep]\nbogus = 1\n").unwrap();
        assert!(SweepConfig::default().apply_toml(&bad).is_err());
        let neg = parse_toml("[sweep]\nthreads = 0\n").unwrap();
        assert!(SweepConfig::default().apply_toml(&neg).is_err());
        // negative entries must not wrap to huge unsigned values
        for doc in [
            "[sweep]\nmachines = [1, -2]\n",
            "[sweep]\nmachines = -1\n",
            "[sweep]\nstaleness = [-1]\n",
            "[sweep]\nstaleness = -3\n",
        ] {
            let d = parse_toml(doc).unwrap();
            assert!(
                SweepConfig::default().apply_toml(&d).is_err(),
                "negative value accepted: {doc}"
            );
        }
    }

    #[test]
    fn transport_table_parses_and_is_skipped_by_experiment_config() {
        // the PR-4 lesson: a new table must be explicitly skipped by
        // ExperimentConfig::apply_toml or every combined config file
        // fails with "unknown config key" — pin both halves here
        let doc = parse_toml(
            r#"
            [train]
            eta = 0.1
            [transport]
            addr = "0.0.0.0:9000"
            shard_groups = 4
            gated = false
            pipeline = false
            window = 8
            group_addrs = ["10.0.0.1:7070", "10.0.0.2:7070", "10.0.0.3:7070", "10.0.0.4:7070"]
            "#,
        )
        .unwrap();
        let mut c = ExperimentConfig::tiny();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.train.eta, 0.1);
        let mut t = TransportConfig::default();
        t.apply_toml(&doc).unwrap();
        assert_eq!(t.addr, "0.0.0.0:9000");
        assert_eq!(t.shard_groups, 4);
        assert!(!t.gated);
        assert!(!t.pipeline);
        assert_eq!(t.window, 8);
        assert_eq!(t.group_addrs.len(), 4);
        assert_eq!(t.group_addr(2).unwrap(), "10.0.0.3:7070");
    }

    #[test]
    fn transport_table_roundtrips_through_toml() {
        for t in [
            TransportConfig::default(),
            TransportConfig {
                addr: "10.1.2.3:7171".into(),
                shard_groups: 7,
                gated: false,
                pipeline: false,
                window: 1,
                group_addrs: Vec::new(),
                connect_timeout_ms: 1200,
                io_timeout_ms: 0,
                max_retries: 9,
                backoff_base_ms: 25,
                lease_ms: 0,
                heartbeat_ms: 1000,
                wake_timeout_ms: 250,
                elastic: true,
                codec: "topk:0.01".into(),
            },
            TransportConfig {
                addr: "localhost:0".into(),
                shard_groups: 1,
                gated: true,
                ..TransportConfig::default()
            },
            TransportConfig {
                shard_groups: 2,
                window: 64,
                group_addrs: vec![
                    "10.0.0.1:7070".into(),
                    "[::1]:7171".into(),
                ],
                ..TransportConfig::default()
            },
        ] {
            let doc = parse_toml(&t.to_toml()).unwrap();
            let mut back = TransportConfig::default();
            back.apply_toml(&doc).unwrap();
            assert_eq!(back, t, "round trip of {t:?}");
            // the emitted table is also skippable by the experiment
            // config (same file, both consumers)
            ExperimentConfig::tiny().apply_toml(&doc).unwrap();
        }
    }

    #[test]
    fn transport_config_validation() {
        let mut t = TransportConfig::default();
        t.validate().unwrap();
        t.shard_groups = 0;
        assert!(t.validate().is_err());
        t = TransportConfig {
            addr: "noport".into(),
            ..TransportConfig::default()
        };
        assert!(t.validate().is_err());
        t = TransportConfig {
            addr: "host:99999".into(),
            ..TransportConfig::default()
        };
        assert!(t.validate().is_err(), "port must fit u16");

        let bad = parse_toml("[transport]\nbogus = 1\n").unwrap();
        assert!(TransportConfig::default().apply_toml(&bad).is_err());
        let zero = parse_toml("[transport]\nshard_groups = 0\n").unwrap();
        assert!(TransportConfig::default().apply_toml(&zero).is_err());
        let neg = parse_toml("[transport]\nshard_groups = -2\n").unwrap();
        assert!(TransportConfig::default().apply_toml(&neg).is_err());
        // wrong value type for a known key is rejected, not ignored
        let wrong = parse_toml("[transport]\ngated = \"yes\"\n").unwrap();
        assert!(TransportConfig::default().apply_toml(&wrong).is_err());

        let zero_win = parse_toml("[transport]\nwindow = 0\n").unwrap();
        assert!(TransportConfig::default().apply_toml(&zero_win).is_err());
        // group_addrs length must match shard_groups
        let mismatched = parse_toml(
            "[transport]\nshard_groups = 3\ngroup_addrs = [\"a:1\", \"b:2\"]\n",
        )
        .unwrap();
        assert!(TransportConfig::default().apply_toml(&mismatched).is_err());
        // each entry must itself be a dialable host:port
        let badaddr = parse_toml(
            "[transport]\ngroup_addrs = [\"noport\"]\n",
        )
        .unwrap();
        assert!(TransportConfig::default().apply_toml(&badaddr).is_err());
        // codec grammar errors surface at validation
        for doc in [
            "[transport]\ncodec = \"int8\"\n",
            "[transport]\ncodec = \"topk:0\"\n",
            "[transport]\ncodec = \"topk:1.5\"\n",
        ] {
            let d = parse_toml(doc).unwrap();
            assert!(
                TransportConfig::default().apply_toml(&d).is_err(),
                "bad codec accepted: {doc}"
            );
        }
        let good = parse_toml("[transport]\ncodec = \"bf16\"\n").unwrap();
        let mut t = TransportConfig::default();
        t.apply_toml(&good).unwrap();
        assert_eq!(
            t.parsed_codec().unwrap(),
            crate::ssp::transport::Codec::Bf16
        );
        // the port + g convention re-brackets IPv6 hosts
        let v6 = TransportConfig {
            addr: "[::1]:7070".into(),
            shard_groups: 2,
            ..TransportConfig::default()
        };
        v6.validate().unwrap();
        assert_eq!(v6.group_addr(1).unwrap(), "[::1]:7071");
    }

    #[test]
    fn transport_fault_knobs_parse_validate_and_map() {
        let doc = parse_toml(
            "[transport]\nconnect_timeout_ms = 250\nio_timeout_ms = 0\n\
             max_retries = 3\nbackoff_base_ms = 10\nlease_ms = 400\n\
             heartbeat_ms = 100\nwake_timeout_ms = 50\n",
        )
        .unwrap();
        let mut t = TransportConfig::default();
        t.apply_toml(&doc).unwrap();
        assert_eq!(t.connect_timeout_ms, 250);
        assert_eq!(t.io_timeout_ms, 0);
        assert_eq!(t.max_retries, 3);
        assert_eq!(t.backoff_base_ms, 10);
        assert_eq!(t.lease_ms, 400);
        assert_eq!(t.heartbeat_ms, 100);
        assert_eq!(t.wake_timeout_ms, 50);

        // single-sourcing: the [transport] table maps onto the client
        // supervisor's FaultPolicy and the service's options
        let fp = t.fault_policy();
        assert_eq!(fp.connect_timeout.as_millis(), 250);
        assert_eq!(fp.io_timeout, None, "0 means block forever");
        assert_eq!(fp.max_retries, 3);
        assert_eq!(fp.backoff_base.as_millis(), 10);
        let t2 = TransportConfig {
            io_timeout_ms: 1500,
            ..TransportConfig::default()
        };
        assert_eq!(
            t2.fault_policy().io_timeout,
            Some(std::time::Duration::from_millis(1500))
        );
        let so = t.service_options(Some(0xDEAD));
        assert_eq!(so.wake_timeout.as_millis(), 50);
        assert_eq!(so.init_digest, Some(0xDEAD));

        // a heartbeat that cannot keep the lease alive is a config
        // error — unless leases are off entirely (lease_ms = 0)
        let stale = parse_toml(
            "[transport]\nlease_ms = 100\nheartbeat_ms = 100\n",
        )
        .unwrap();
        assert!(TransportConfig::default().apply_toml(&stale).is_err());
        let off = parse_toml(
            "[transport]\nlease_ms = 0\nheartbeat_ms = 60000\n",
        )
        .unwrap();
        TransportConfig::default().apply_toml(&off).unwrap();

        for bad in [
            "[transport]\nconnect_timeout_ms = 0\n",
            "[transport]\nbackoff_base_ms = 0\n",
            "[transport]\nheartbeat_ms = 0\n",
            "[transport]\nwake_timeout_ms = 0\n",
            "[transport]\nmax_retries = -1\n",
            "[transport]\nio_timeout_ms = -5\n",
            "[transport]\nlease_ms = -1\n",
        ] {
            let doc = parse_toml(bad).unwrap();
            assert!(
                TransportConfig::default().apply_toml(&doc).is_err(),
                "{bad:?} must be rejected"
            );
        }
    }

    #[test]
    fn validation_catches_mismatch() {
        let mut c = ExperimentConfig::tiny();
        c.model.dims = vec![5, 4, 10]; // input 5 != features 16
        assert!(c.validate().is_err());
    }
}
