//! Panel packing for the blocked GEMM backend (§Perf pass 5; aligned +
//! bf16 storage: §Perf pass 7).
//!
//! The macrokernel in `ops.rs` never reads `A`/`B` directly: each cache
//! block is first repacked into a contiguous, microkernel-ordered buffer
//! so the innermost loop streams both operands with unit stride no matter
//! how the caller's matrix is oriented. That is what makes `gemm_nt` /
//! `gemm_tn` transpose-free — a transposed operand is just a different
//! (row-stride, col-stride) pair handed to the same packing routine.
//!
//! Layouts (standard BLIS):
//!
//! * packed A block (`mc × kc`): micro-panels of `MR` rows, each stored
//!   k-major — `a_buf[panel*kc*MR + p*MR + r]`, short panels zero-padded
//!   to `MR` so the microkernel is uniform;
//! * packed B block (`kc × nc`): micro-panels of `nr` columns (8, or 16
//!   for the AVX-512 path — panel width never reorders any C element's
//!   k-accumulation, so it is value-neutral), stored k-major —
//!   `b_buf[panel*kc*nr + p*nr + c]`, zero-padded to `nr`.
//!
//! Pack storage is 64-byte aligned ([`AlignedBuf`]): every micro-panel
//! slice offset is a multiple of `nr·4` bytes (f32) or `nr·2` (bf16),
//! so from a 64-byte base the SIMD kernels may use aligned vector loads
//! throughout. Debug builds assert the alignment on every access.
//!
//! Each buffer can alternatively be packed as **bf16 storage / f32
//! compute**: values are rounded to bfloat16 (round-to-nearest-even,
//! [`f32_to_bf16`]) while packing and widened back to f32 inside the
//! microkernel (a 16-bit left shift — exact). This halves pack-buffer
//! memory traffic at a one-rounding-per-operand accuracy cost; see
//! `rust/EXPERIMENTS.md` §Perf pass 7 for the error model.
//!
//! The packing pass is also where the sparse-input skip lives: while
//! packing an A micro-panel (data already in hand), we count k-slices
//! whose `MR` values are all zero; if at least
//! `SPARSE_MIN_ZERO_NUM/SPARSE_MIN_ZERO_DEN` of the panel's slices are
//! zero — the sparse-LLC-features first layer — we record the index
//! list of nonzero slices and the microkernel walks only those. Dense
//! panels take a branch-free inner loop.

/// Microkernel tile rows. 8×8 f32 accumulators fill eight 256-bit
/// vector registers (one per tile row), leaving registers for the B
/// row vector and A broadcasts — see `rust/EXPERIMENTS.md` §Perf pass 5.
pub(crate) const MR: usize = 8;
/// Scalar/AVX2/NEON microkernel tile columns (one 8-wide f32 vector per
/// accumulator row). The AVX-512 path packs [`NR_MAX`]-wide panels.
pub(crate) const NR: usize = 8;
/// Widest B micro-panel any dispatch path packs (AVX-512: one 16-wide
/// zmm accumulator per tile row). Accumulator tiles are sized for this.
pub(crate) const NR_MAX: usize = 16;
/// k extent of a cache block: an MR×KC packed A panel (8 KiB) plus an
/// NR×KC packed B panel (8 KiB) live in L1 beside the C tile.
pub(crate) const KC: usize = 256;
/// Row extent of a packed A block (MC×KC = 64 KiB, L2-resident).
pub(crate) const MC: usize = 64;
/// Column extent of a packed B block (KC×NC = 256 KiB, L2/L3-resident).
pub(crate) const NC: usize = 256;

/// A panel qualifies for the sparse skip path when at least this
/// fraction of its k-slices are entirely zero (denominator 4 → 25%).
/// Below that, the branch-free dense kernel wins: skipping a zero slice
/// saves 2·MR·NR flops but costs an indexed load per slice.
pub(crate) const SPARSE_MIN_ZERO_NUM: usize = 1;
pub(crate) const SPARSE_MIN_ZERO_DEN: usize = 4;

// bf16 bit math is single-sourced in `util::half` (the wire codecs in
// `ssp::transport::codec` round with the same functions); re-exported
// here so the pack/microkernel paths keep their historical import site.
pub(crate) use crate::util::half::{bf16_to_f32, f32_to_bf16};

/// Strided read-only view of a matrix operand: element `(i, p)` is
/// `data[i * rs + p * cs]`. A plain row-major matrix is `(cols, 1)`;
/// its transpose is `(1, cols)` over the same storage — no transposed
/// copy is ever materialized.
#[derive(Clone, Copy, Debug)]
pub(crate) struct View<'a> {
    pub data: &'a [f32],
    pub rs: usize,
    pub cs: usize,
}

impl<'a> View<'a> {
    #[inline]
    pub fn at(&self, i: usize, p: usize) -> f32 {
        self.data[i * self.rs + p * self.cs]
    }

    /// The same view starting `rows` rows down (thread band offsets).
    #[inline]
    pub fn offset_rows(&self, rows: usize) -> View<'a> {
        View {
            data: &self.data[rows * self.rs..],
            rs: self.rs,
            cs: self.cs,
        }
    }
}

/// Per-A-micro-panel sparse metadata: `Dense`, or the range of this
/// panel's nonzero k-slice indices inside `PackBuf::idx`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum PanelSkip {
    Dense,
    Sparse { start: u32, len: u32 },
}

/// 64-byte-aligned growable buffer, viewable as f32 or as bf16 storage
/// bits over the same bytes. Alignment comes from the element type, so
/// it survives `Vec` reallocation and is asserted (debug builds) on
/// every typed access.
#[derive(Debug, Default)]
pub(crate) struct AlignedBuf {
    raw: Vec<Cacheline>,
}

/// One cache line of f32s; the `align(64)` here is what aligns the
/// whole buffer.
#[repr(C, align(64))]
#[derive(Clone, Copy, Debug)]
struct Cacheline([f32; 16]);

impl AlignedBuf {
    /// Grow to hold at least `len` f32 elements (bf16 views over the
    /// same bytes then hold `2·len` values — same byte capacity).
    fn ensure_f32(&mut self, len: usize) {
        let lines = len.div_ceil(16);
        if self.raw.len() < lines {
            self.raw.resize(lines, Cacheline([0.0; 16]));
        }
    }

    #[inline]
    fn check_align(&self) {
        debug_assert_eq!(
            self.raw.as_ptr() as usize % 64,
            0,
            "pack buffer must be 64-byte aligned"
        );
    }

    #[inline]
    pub(crate) fn f32(&self) -> &[f32] {
        self.check_align();
        // SAFETY: Cacheline is repr(C) over [f32; 16]; the cast only
        // reinterprets the same initialized f32 storage.
        unsafe { std::slice::from_raw_parts(self.raw.as_ptr().cast::<f32>(), self.raw.len() * 16) }
    }

    #[inline]
    pub(crate) fn f32_mut(&mut self) -> &mut [f32] {
        self.check_align();
        // SAFETY: as above, through a unique borrow.
        unsafe {
            std::slice::from_raw_parts_mut(self.raw.as_mut_ptr().cast::<f32>(), self.raw.len() * 16)
        }
    }

    #[inline]
    pub(crate) fn bf16(&self) -> &[u16] {
        self.check_align();
        // SAFETY: u16 has no invalid bit patterns; same bytes, half-width
        // elements, so the element count doubles.
        unsafe { std::slice::from_raw_parts(self.raw.as_ptr().cast::<u16>(), self.raw.len() * 32) }
    }

    #[inline]
    pub(crate) fn bf16_mut(&mut self) -> &mut [u16] {
        self.check_align();
        // SAFETY: as above, through a unique borrow.
        unsafe {
            std::slice::from_raw_parts_mut(self.raw.as_mut_ptr().cast::<u16>(), self.raw.len() * 32)
        }
    }
}

/// One thread's reusable packing workspace. Buffers grow to the block
/// sizes on first use and are reused for every subsequent call — the
/// GEMM hot path allocates nothing at steady state (the PR 2 contract).
#[derive(Debug, Default)]
pub struct PackBuf {
    pub(crate) a: AlignedBuf,
    pub(crate) b: AlignedBuf,
    pub(crate) panels: Vec<PanelSkip>,
    pub(crate) idx: Vec<u32>,
}

impl PackBuf {
    pub fn new() -> PackBuf {
        PackBuf::default()
    }

    fn ensure(&mut self) {
        // Worst case over every dispatch path: nr ≤ NR_MAX divides NC,
        // so a packed B block never exceeds KC·NC elements; bf16 mode
        // halves the bytes and reuses the same allocation.
        self.a.ensure_f32(MC * KC);
        self.b.ensure_f32(KC * NC);
    }
}

/// Pack the `mcb × kc` block of `a` starting at (absolute) row `i0`,
/// depth `p0` into `buf.a` as MR-row micro-panels; when `filter` is set,
/// fill `buf.panels`/`buf.idx` with the sparse skip plan (otherwise
/// every panel is marked dense). `bf16` selects bf16 pack storage
/// (values rounded with [`f32_to_bf16`]; the sparse plan is computed on
/// the packed values, so the kernels skip exactly the slices that are
/// zero *as stored*).
#[allow(clippy::too_many_arguments)]
pub(crate) fn pack_a(
    a: View,
    i0: usize,
    mcb: usize,
    p0: usize,
    kc: usize,
    buf: &mut PackBuf,
    filter: bool,
    bf16: bool,
) {
    buf.ensure();
    buf.panels.clear();
    buf.idx.clear();
    let np = mcb.div_ceil(MR);
    for pi in 0..np {
        let r0 = pi * MR;
        let mr = (mcb - r0).min(MR);
        let mut zero_slices = 0usize;
        if bf16 {
            let panel = &mut buf.a.bf16_mut()[pi * kc * MR..(pi + 1) * kc * MR];
            for p in 0..kc {
                let dst = &mut panel[p * MR..p * MR + MR];
                let mut any = false;
                for (r, d) in dst.iter_mut().enumerate().take(mr) {
                    let h = f32_to_bf16(a.at(i0 + r0 + r, p0 + p));
                    any |= bf16_to_f32(h) != 0.0;
                    *d = h;
                }
                for d in dst.iter_mut().skip(mr) {
                    *d = 0;
                }
                zero_slices += usize::from(!any);
            }
        } else {
            let panel = &mut buf.a.f32_mut()[pi * kc * MR..(pi + 1) * kc * MR];
            for p in 0..kc {
                let dst = &mut panel[p * MR..p * MR + MR];
                let mut any = false;
                for (r, d) in dst.iter_mut().enumerate().take(mr) {
                    let v = a.at(i0 + r0 + r, p0 + p);
                    any |= v != 0.0;
                    *d = v;
                }
                for d in dst.iter_mut().skip(mr) {
                    *d = 0.0;
                }
                zero_slices += usize::from(!any);
            }
        }
        let skip = if filter
            && kc > 0
            && zero_slices * SPARSE_MIN_ZERO_DEN >= kc * SPARSE_MIN_ZERO_NUM
        {
            let start = buf.idx.len() as u32;
            if bf16 {
                let panel = &buf.a.bf16()[pi * kc * MR..(pi + 1) * kc * MR];
                for p in 0..kc {
                    let slice = &panel[p * MR..p * MR + MR];
                    if slice.iter().any(|&h| bf16_to_f32(h) != 0.0) {
                        buf.idx.push(p as u32);
                    }
                }
            } else {
                let panel = &buf.a.f32()[pi * kc * MR..(pi + 1) * kc * MR];
                for p in 0..kc {
                    let slice = &panel[p * MR..p * MR + MR];
                    if slice.iter().any(|&v| v != 0.0) {
                        buf.idx.push(p as u32);
                    }
                }
            }
            PanelSkip::Sparse {
                start,
                len: buf.idx.len() as u32 - start,
            }
        } else {
            PanelSkip::Dense
        };
        buf.panels.push(skip);
    }
}

/// Pack the `kc × ncb` block of `b` at depth `p0`, (absolute) column
/// `j0` into `buf.b` as `nr`-column micro-panels (`nr` is the dispatch
/// path's panel width, ≤ [`NR_MAX`]).
#[allow(clippy::too_many_arguments)]
pub(crate) fn pack_b(
    b: View,
    p0: usize,
    kc: usize,
    j0: usize,
    ncb: usize,
    nr_w: usize,
    buf: &mut PackBuf,
    bf16: bool,
) {
    debug_assert!(nr_w == NR || nr_w == NR_MAX, "unknown panel width {nr_w}");
    buf.ensure();
    let np = ncb.div_ceil(nr_w);
    for pj in 0..np {
        let c0 = pj * nr_w;
        let nr = (ncb - c0).min(nr_w);
        if bf16 {
            let panel = &mut buf.b.bf16_mut()[pj * kc * nr_w..(pj + 1) * kc * nr_w];
            for p in 0..kc {
                let dst = &mut panel[p * nr_w..p * nr_w + nr_w];
                for (c, d) in dst.iter_mut().enumerate().take(nr) {
                    *d = f32_to_bf16(b.at(p0 + p, j0 + c0 + c));
                }
                for d in dst.iter_mut().skip(nr) {
                    *d = 0;
                }
            }
        } else {
            let panel = &mut buf.b.f32_mut()[pj * kc * nr_w..(pj + 1) * kc * nr_w];
            for p in 0..kc {
                let dst = &mut panel[p * nr_w..p * nr_w + nr_w];
                for (c, d) in dst.iter_mut().enumerate().take(nr) {
                    *d = b.at(p0 + p, j0 + c0 + c);
                }
                for d in dst.iter_mut().skip(nr) {
                    *d = 0.0;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_a_layout_and_padding() {
        // 3×4 row-major matrix, one short panel (mr = 3 < MR)
        let data: Vec<f32> = (1..=12).map(|x| x as f32).collect();
        let v = View {
            data: &data,
            rs: 4,
            cs: 1,
        };
        let mut buf = PackBuf::new();
        pack_a(v, 0, 3, 0, 4, &mut buf, false, false);
        assert_eq!(buf.panels, vec![PanelSkip::Dense]);
        for p in 0..4 {
            let s = &buf.a.f32()[p * MR..p * MR + MR];
            assert_eq!(s[0], data[p]); // row 0
            assert_eq!(s[1], data[4 + p]); // row 1
            assert_eq!(s[2], data[8 + p]); // row 2
            assert!(s[3..].iter().all(|&x| x == 0.0), "padding");
        }
    }

    #[test]
    fn pack_b_layout_matches_transposed_view() {
        // pack B' (k×n) from a row-major n×k matrix via strides
        let (n, k) = (3usize, 5usize);
        let data: Vec<f32> = (0..n * k).map(|x| x as f32).collect();
        let bt = View {
            data: &data,
            rs: 1,
            cs: k,
        }; // B'[p, j] = data[j*k + p]
        let mut buf = PackBuf::new();
        pack_b(bt, 0, k, 0, n, NR, &mut buf, false);
        for p in 0..k {
            for j in 0..n {
                assert_eq!(buf.b.f32()[p * NR + j], data[j * k + p]);
            }
        }
    }

    #[test]
    fn pack_b_wide_panels_match_narrow_values() {
        // the same block packed at nr = 8 and nr = 16 must hold the same
        // values, just in different panel geometry
        let (k, n) = (7usize, 21usize);
        let data: Vec<f32> = (0..k * n).map(|x| (x as f32).sin()).collect();
        let v = View {
            data: &data,
            rs: n,
            cs: 1,
        };
        let mut narrow = PackBuf::new();
        let mut wide = PackBuf::new();
        pack_b(v, 0, k, 0, n, NR, &mut narrow, false);
        pack_b(v, 0, k, 0, n, NR_MAX, &mut wide, false);
        for p in 0..k {
            for j in 0..n {
                let nv = narrow.b.f32()[(j / NR) * k * NR + p * NR + (j % NR)];
                let wv = wide.b.f32()[(j / NR_MAX) * k * NR_MAX + p * NR_MAX + (j % NR_MAX)];
                assert_eq!(nv, wv);
                assert_eq!(nv, data[p * n + j]);
            }
        }
        // wide padding columns are zero
        for p in 0..k {
            for j in n..NR_MAX * (n.div_ceil(NR_MAX)) {
                let wv = wide.b.f32()[(j / NR_MAX) * k * NR_MAX + p * NR_MAX + (j % NR_MAX)];
                assert_eq!(wv, 0.0, "padding at p={p} j={j}");
            }
        }
    }

    #[test]
    fn sparse_filter_records_nonzero_slices() {
        // 8×8 block with only k-slices 2 and 5 nonzero
        let mut data = vec![0.0f32; 64];
        data[2] = 1.0; // row 0, col 2
        data[8 + 5] = 2.0; // row 1, col 5
        let v = View {
            data: &data,
            rs: 8,
            cs: 1,
        };
        let mut buf = PackBuf::new();
        pack_a(v, 0, 8, 0, 8, &mut buf, true, false);
        assert_eq!(buf.panels.len(), 1);
        match buf.panels[0] {
            PanelSkip::Sparse { start, len } => {
                assert_eq!(start, 0);
                assert_eq!(len, 2);
                assert_eq!(&buf.idx[..2], &[2, 5]);
            }
            PanelSkip::Dense => panic!("expected sparse plan"),
        }
        // same block without the filter: dense
        pack_a(v, 0, 8, 0, 8, &mut buf, false, false);
        assert_eq!(buf.panels, vec![PanelSkip::Dense]);
        // bf16 pack of the same block finds the same plan
        pack_a(v, 0, 8, 0, 8, &mut buf, true, true);
        assert_eq!(
            buf.panels,
            vec![PanelSkip::Sparse { start: 0, len: 2 }],
            "bf16 sparse plan"
        );
        assert_eq!(&buf.idx[..2], &[2, 5]);
    }

    #[test]
    fn pack_buffers_are_64_byte_aligned() {
        let mut buf = PackBuf::new();
        let data = vec![1.0f32; 64];
        let v = View {
            data: &data,
            rs: 8,
            cs: 1,
        };
        pack_a(v, 0, 8, 0, 8, &mut buf, false, false);
        pack_b(v, 0, 8, 0, 8, NR, &mut buf, false);
        assert_eq!(buf.a.f32().as_ptr() as usize % 64, 0);
        assert_eq!(buf.b.f32().as_ptr() as usize % 64, 0);
        assert_eq!(buf.a.bf16().as_ptr() as usize % 64, 0);
        assert_eq!(buf.b.bf16().as_ptr() as usize % 64, 0);
    }

    // (the 12 hand-verified RNE bit vectors moved to `util::half` with
    // the conversion functions; the pack-path coverage stays here)

    #[test]
    fn bf16_pack_rounds_values() {
        let data: Vec<f32> = (0..64).map(|x| x as f32 * 0.317 + 0.001).collect();
        let v = View {
            data: &data,
            rs: 8,
            cs: 1,
        };
        let mut buf = PackBuf::new();
        pack_a(v, 0, 8, 0, 8, &mut buf, false, true);
        for p in 0..8 {
            for r in 0..8 {
                let h = buf.a.bf16()[p * MR + r];
                assert_eq!(h, f32_to_bf16(data[r * 8 + p]), "p={p} r={r}");
            }
        }
    }
}
