//! Panel packing for the blocked GEMM backend (§Perf pass 5).
//!
//! The macrokernel in `ops.rs` never reads `A`/`B` directly: each cache
//! block is first repacked into a contiguous, microkernel-ordered buffer
//! so the innermost loop streams both operands with unit stride no matter
//! how the caller's matrix is oriented. That is what makes `gemm_nt` /
//! `gemm_tn` transpose-free — a transposed operand is just a different
//! (row-stride, col-stride) pair handed to the same packing routine.
//!
//! Layouts (standard BLIS):
//!
//! * packed A block (`mc × kc`): micro-panels of `MR` rows, each stored
//!   k-major — `a_buf[panel*kc*MR + p*MR + r]`, short panels zero-padded
//!   to `MR` so the microkernel is uniform;
//! * packed B block (`kc × nc`): micro-panels of `NR` columns, stored
//!   k-major — `b_buf[panel*kc*NR + p*NR + c]`, zero-padded to `NR`.
//!
//! The packing pass is also where the sparse-input skip lives now: the
//! old kernels branched on `a == 0.0` per element *inside* the inner
//! loop, which pessimizes dense workloads. Here, while packing an A
//! micro-panel (data already in hand), we count k-slices whose `MR`
//! values are all zero; if at least [`SPARSE_MIN_ZERO_FRAC`] of the
//! panel's slices are zero — the sparse-LLC-features first layer — we
//! record the index list of nonzero slices and the microkernel walks
//! only those. Dense panels take a branch-free inner loop.

/// Microkernel tile rows. 8×8 f32 accumulators fill eight 256-bit
/// vector registers (one per tile row), leaving registers for the B
/// row vector and A broadcasts — see `rust/EXPERIMENTS.md` §Perf pass 5.
pub(crate) const MR: usize = 8;
/// Microkernel tile columns (one 8-wide f32 vector per accumulator row).
pub(crate) const NR: usize = 8;
/// k extent of a cache block: an MR×KC packed A panel (8 KiB) plus an
/// NR×KC packed B panel (8 KiB) live in L1 beside the C tile.
pub(crate) const KC: usize = 256;
/// Row extent of a packed A block (MC×KC = 64 KiB, L2-resident).
pub(crate) const MC: usize = 64;
/// Column extent of a packed B block (KC×NC = 256 KiB, L2/L3-resident).
pub(crate) const NC: usize = 256;

/// A panel qualifies for the sparse skip path when at least this
/// fraction of its k-slices are entirely zero (denominator 4 → 25%).
/// Below that, the branch-free dense kernel wins: skipping a zero slice
/// saves 2·MR·NR flops but costs an indexed load per slice.
pub(crate) const SPARSE_MIN_ZERO_NUM: usize = 1;
pub(crate) const SPARSE_MIN_ZERO_DEN: usize = 4;

/// Strided read-only view of a matrix operand: element `(i, p)` is
/// `data[i * rs + p * cs]`. A plain row-major matrix is `(cols, 1)`;
/// its transpose is `(1, cols)` over the same storage — no transposed
/// copy is ever materialized.
#[derive(Clone, Copy, Debug)]
pub(crate) struct View<'a> {
    pub data: &'a [f32],
    pub rs: usize,
    pub cs: usize,
}

impl<'a> View<'a> {
    #[inline]
    pub fn at(&self, i: usize, p: usize) -> f32 {
        self.data[i * self.rs + p * self.cs]
    }

    /// The same view starting `rows` rows down (thread band offsets).
    #[inline]
    pub fn offset_rows(&self, rows: usize) -> View<'a> {
        View {
            data: &self.data[rows * self.rs..],
            rs: self.rs,
            cs: self.cs,
        }
    }
}

/// Per-A-micro-panel sparse metadata: `Dense`, or the range of this
/// panel's nonzero k-slice indices inside `PackBuf::idx`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum PanelSkip {
    Dense,
    Sparse { start: u32, len: u32 },
}

/// One thread's reusable packing workspace. Buffers grow to the block
/// sizes on first use and are reused for every subsequent call — the
/// GEMM hot path allocates nothing at steady state (the PR 2 contract).
#[derive(Debug, Default)]
pub struct PackBuf {
    pub(crate) a: Vec<f32>,
    pub(crate) b: Vec<f32>,
    pub(crate) panels: Vec<PanelSkip>,
    pub(crate) idx: Vec<u32>,
}

impl PackBuf {
    pub fn new() -> PackBuf {
        PackBuf::default()
    }

    fn ensure(&mut self) {
        if self.a.len() < MC * KC {
            self.a.resize(MC * KC, 0.0);
        }
        if self.b.len() < KC * NC {
            self.b.resize(KC * NC, 0.0);
        }
    }
}

/// Pack the `mcb × kc` block of `a` starting at (absolute) row `i0`,
/// depth `p0` into `buf.a` as MR-row micro-panels; when `filter` is set,
/// fill `buf.panels`/`buf.idx` with the sparse skip plan (otherwise
/// every panel is marked dense).
pub(crate) fn pack_a(
    a: View,
    i0: usize,
    mcb: usize,
    p0: usize,
    kc: usize,
    buf: &mut PackBuf,
    filter: bool,
) {
    buf.ensure();
    buf.panels.clear();
    buf.idx.clear();
    let np = mcb.div_ceil(MR);
    for pi in 0..np {
        let r0 = pi * MR;
        let mr = (mcb - r0).min(MR);
        let panel = &mut buf.a[pi * kc * MR..(pi + 1) * kc * MR];
        let mut zero_slices = 0usize;
        for p in 0..kc {
            let dst = &mut panel[p * MR..p * MR + MR];
            let mut any = false;
            for (r, d) in dst.iter_mut().enumerate().take(mr) {
                let v = a.at(i0 + r0 + r, p0 + p);
                any |= v != 0.0;
                *d = v;
            }
            for d in dst.iter_mut().skip(mr) {
                *d = 0.0;
            }
            zero_slices += usize::from(!any);
        }
        let skip = if filter
            && kc > 0
            && zero_slices * SPARSE_MIN_ZERO_DEN >= kc * SPARSE_MIN_ZERO_NUM
        {
            let start = buf.idx.len() as u32;
            for p in 0..kc {
                let slice = &panel[p * MR..p * MR + MR];
                if slice.iter().any(|&v| v != 0.0) {
                    buf.idx.push(p as u32);
                }
            }
            PanelSkip::Sparse {
                start,
                len: buf.idx.len() as u32 - start,
            }
        } else {
            PanelSkip::Dense
        };
        buf.panels.push(skip);
    }
}

/// Pack the `kc × ncb` block of `b` at depth `p0`, (absolute) column
/// `j0` into `buf.b` as NR-column micro-panels.
pub(crate) fn pack_b(b: View, p0: usize, kc: usize, j0: usize, ncb: usize, buf: &mut PackBuf) {
    buf.ensure();
    let np = ncb.div_ceil(NR);
    for pj in 0..np {
        let c0 = pj * NR;
        let nr = (ncb - c0).min(NR);
        let panel = &mut buf.b[pj * kc * NR..(pj + 1) * kc * NR];
        for p in 0..kc {
            let dst = &mut panel[p * NR..p * NR + NR];
            for (c, d) in dst.iter_mut().enumerate().take(nr) {
                *d = b.at(p0 + p, j0 + c0 + c);
            }
            for d in dst.iter_mut().skip(nr) {
                *d = 0.0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_a_layout_and_padding() {
        // 3×4 row-major matrix, one short panel (mr = 3 < MR)
        let data: Vec<f32> = (1..=12).map(|x| x as f32).collect();
        let v = View {
            data: &data,
            rs: 4,
            cs: 1,
        };
        let mut buf = PackBuf::new();
        pack_a(v, 0, 3, 0, 4, &mut buf, false);
        assert_eq!(buf.panels, vec![PanelSkip::Dense]);
        for p in 0..4 {
            let s = &buf.a[p * MR..p * MR + MR];
            assert_eq!(s[0], data[p]); // row 0
            assert_eq!(s[1], data[4 + p]); // row 1
            assert_eq!(s[2], data[8 + p]); // row 2
            assert!(s[3..].iter().all(|&x| x == 0.0), "padding");
        }
    }

    #[test]
    fn pack_b_layout_matches_transposed_view() {
        // pack B' (k×n) from a row-major n×k matrix via strides
        let (n, k) = (3usize, 5usize);
        let data: Vec<f32> = (0..n * k).map(|x| x as f32).collect();
        let bt = View {
            data: &data,
            rs: 1,
            cs: k,
        }; // B'[p, j] = data[j*k + p]
        let mut buf = PackBuf::new();
        pack_b(bt, 0, k, 0, n, &mut buf);
        for p in 0..k {
            for j in 0..n {
                assert_eq!(buf.b[p * NR + j], data[j * k + p]);
            }
        }
    }

    #[test]
    fn sparse_filter_records_nonzero_slices() {
        // 8×8 block with only k-slices 2 and 5 nonzero
        let mut data = vec![0.0f32; 64];
        data[2] = 1.0; // row 0, col 2
        data[8 + 5] = 2.0; // row 1, col 5
        let v = View {
            data: &data,
            rs: 8,
            cs: 1,
        };
        let mut buf = PackBuf::new();
        pack_a(v, 0, 8, 0, 8, &mut buf, true);
        assert_eq!(buf.panels.len(), 1);
        match buf.panels[0] {
            PanelSkip::Sparse { start, len } => {
                assert_eq!(start, 0);
                assert_eq!(len, 2);
                assert_eq!(&buf.idx[..2], &[2, 5]);
            }
            PanelSkip::Dense => panic!("expected sparse plan"),
        }
        // same block without the filter: dense
        pack_a(v, 0, 8, 0, 8, &mut buf, false);
        assert_eq!(buf.panels, vec![PanelSkip::Dense]);
    }
}
