//! Row-major dense f32 matrix.

use crate::util::Pcg64;

#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Matrix { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut m = Matrix::zeros(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                m.data[r * cols + c] = f(r, c);
            }
        }
        m
    }

    /// Glorot-uniform init, matching `model.init_params` on the python side.
    pub fn glorot(rows: usize, cols: usize, rng: &mut Pcg64) -> Self {
        let limit = (6.0 / (rows + cols) as f32).sqrt();
        let mut m = Matrix::zeros(rows, cols);
        for v in &mut m.data {
            *v = rng.uniform_f32(-limit, limit);
        }
        m
    }

    pub fn randn(rows: usize, cols: usize, std: f32, rng: &mut Pcg64) -> Self {
        let mut m = Matrix::zeros(rows, cols);
        for v in &mut m.data {
            *v = rng.normal_f32(0.0, std);
        }
        m
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn fill(&mut self, v: f32) {
        self.data.fill(v);
    }

    /// self = other (same shape), reusing this matrix's allocation — the
    /// zero-copy hot path's replacement for `clone()`.
    pub fn copy_from(&mut self, other: &Matrix) {
        assert_eq!(self.rows, other.rows, "copy_from rows");
        assert_eq!(self.cols, other.cols, "copy_from cols");
        self.data.copy_from_slice(&other.data);
    }

    /// self += alpha * other (same shape).
    pub fn axpy(&mut self, alpha: f32, other: &Matrix) {
        assert_eq!(self.rows, other.rows);
        assert_eq!(self.cols, other.cols);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// self = self * alpha.
    pub fn scale(&mut self, alpha: f32) {
        for a in &mut self.data {
            *a *= alpha;
        }
    }

    /// Elementwise map in place.
    pub fn map_inplace(&mut self, f: impl Fn(f32) -> f32) {
        for a in &mut self.data {
            *a = f(*a);
        }
    }

    /// Allocating transpose — convenience only. Hot paths either go
    /// through `transpose_into` (buffer reuse) or, for GEMM operands,
    /// need no transpose at all (`gemm_nt`/`gemm_tn` pack through
    /// strided views).
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        self.transpose_into(&mut t);
        t
    }

    /// Transpose into a caller-owned `cols × rows` buffer (the
    /// allocation-free sibling of `transpose`).
    pub fn transpose_into(&self, t: &mut Matrix) {
        assert_eq!(t.rows, self.cols, "transpose_into rows");
        assert_eq!(t.cols, self.rows, "transpose_into cols");
        for r in 0..self.rows {
            for c in 0..self.cols {
                t.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
    }

    /// Frobenius norm squared.
    pub fn norm_sq(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    pub fn norm(&self) -> f64 {
        self.norm_sq().sqrt()
    }

    /// Mean squared elementwise difference to another matrix — the Fig. 6
    /// quantity (parameter convergence plot).
    pub fn mean_sq_diff(&self, other: &Matrix) -> f64 {
        assert_eq!(self.data.len(), other.data.len());
        if self.data.is_empty() {
            return 0.0;
        }
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| {
                let d = (a - b) as f64;
                d * d
            })
            .sum::<f64>()
            / self.data.len() as f64
    }

    /// Max |a-b| — used by integration tests comparing engines.
    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_indexing() {
        let m = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(m.at(0, 0), 1.0);
        assert_eq!(m.at(1, 2), 6.0);
        assert_eq!(m.row(1), &[4., 5., 6.]);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn bad_shape_panics() {
        Matrix::from_vec(2, 2, vec![1.0; 5]);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Pcg64::new(1);
        let m = Matrix::randn(5, 7, 1.0, &mut rng);
        assert_eq!(m.transpose().transpose(), m);
        assert_eq!(m.transpose().at(3, 2), m.at(2, 3));
    }

    #[test]
    fn transpose_into_matches_allocating() {
        let mut rng = Pcg64::new(3);
        let m = Matrix::randn(4, 9, 1.0, &mut rng);
        let mut t = Matrix::zeros(9, 4);
        t.fill(5.0); // stale contents must be fully overwritten
        m.transpose_into(&mut t);
        assert_eq!(t, m.transpose());
    }

    #[test]
    #[should_panic(expected = "transpose_into rows")]
    fn transpose_into_shape_checked() {
        let m = Matrix::zeros(2, 3);
        let mut t = Matrix::zeros(2, 3);
        m.transpose_into(&mut t);
    }

    #[test]
    fn axpy_and_scale() {
        let mut a = Matrix::from_vec(1, 3, vec![1., 2., 3.]);
        let b = Matrix::from_vec(1, 3, vec![10., 10., 10.]);
        a.axpy(0.5, &b);
        assert_eq!(a.data(), &[6., 7., 8.]);
        a.scale(2.0);
        assert_eq!(a.data(), &[12., 14., 16.]);
    }

    #[test]
    fn glorot_within_limit() {
        let mut rng = Pcg64::new(2);
        let m = Matrix::glorot(30, 20, &mut rng);
        let limit = (6.0f32 / 50.0).sqrt();
        assert!(m.data().iter().all(|&v| v.abs() <= limit));
        assert!(m.norm() > 0.0);
    }

    #[test]
    fn mean_sq_diff_basics() {
        let a = Matrix::from_vec(1, 2, vec![0., 0.]);
        let b = Matrix::from_vec(1, 2, vec![3., 4.]);
        assert!((a.mean_sq_diff(&b) - 12.5).abs() < 1e-12);
        assert_eq!(a.max_abs_diff(&b), 4.0);
    }
}
