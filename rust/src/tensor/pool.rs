//! Intra-op GEMM parallelism: an M-split band pool over scoped threads.
//!
//! `GemmPool` owns one [`PackBuf`] packing workspace per intra-op thread
//! (reused across calls — zero allocation at steady state) and runs each
//! GEMM by splitting the output's rows into micro-panel-aligned bands,
//! one scoped thread per band (`std::thread::scope`; no dependency on an
//! external pool crate). Row bands are disjoint row-major slices of C,
//! so the split is safe (`split_at_mut`), each thread packs its own A
//! band, and — because a band never subdivides a C element's
//! k-accumulation — the result is **bitwise identical for every thread
//! count**, which the property suite asserts per dispatch path.
//!
//! The microkernel selection (`tensor::dispatch`) is resolved **once per
//! GEMM on the calling thread** — before any band split — and handed to
//! every band worker, so scoped overrides apply to pooled calls and all
//! bands of one call run the same body.
//!
//! Costs that shaped the design (records: `rust/EXPERIMENTS.md` §Perf
//! pass 5/7): spawning a scoped thread is ~10–50 µs, so tiny GEMMs run
//! on the calling thread. The serial threshold is **per dispatch path**
//! ([`par_min_flops_for`]): SIMD kernels retire flops several times
//! faster than scalar, which moves the parallelism break-even point up
//! by the same factor — splitting a GEMM that AVX-512 finishes in 100 µs
//! across threads costs more in spawn latency than it saves. The
//! threshold is overridable ([`GemmPool::with_par_min_flops`]) so the
//! bench can sweep it. Per-band B packing is duplicated across threads
//! but is O(k·n) against O(m·k·n / T) compute, a few percent at the
//! bench shapes. `N workers × T intra-op threads` is explicit end to
//! end: the config's `train.intra_op_threads` (CLI `--threads`) reaches
//! every engine's pool through `Mlp`.

use super::dispatch::{self, KernelPath, Selection};
use super::ops::{band_ep, check_ep, gemm_band, nn_views, nt_views, tn_views, Epilogue};
use super::pack::{PackBuf, View, MR};
use super::Matrix;

/// Below this many flops (2·m·k·n) a **scalar-path** GEMM runs on the
/// calling thread: thread spawn latency would eat the win. ~4 MFLOP
/// ≈ 0.3–1 ms serial, an order of magnitude above spawn cost.
pub const PAR_MIN_FLOPS: usize = 4_000_000;

/// Serial threshold for the SIMD paths: their microkernels retire flops
/// roughly 4× faster, so the break-even GEMM is correspondingly larger.
pub const PAR_MIN_FLOPS_SIMD: usize = 16_000_000;

/// The default serial/parallel break-even for a dispatch path.
pub fn par_min_flops_for(path: KernelPath) -> usize {
    match path {
        KernelPath::Scalar => PAR_MIN_FLOPS,
        _ => PAR_MIN_FLOPS_SIMD,
    }
}

/// A configurable intra-op worker pool with per-thread pack workspaces.
#[derive(Debug)]
pub struct GemmPool {
    threads: usize,
    bufs: Vec<PackBuf>,
    kernel: Option<Selection>,
    par_min_flops: Option<usize>,
}

impl Default for GemmPool {
    fn default() -> Self {
        GemmPool::new(1)
    }
}

impl GemmPool {
    /// A pool that splits GEMMs across `threads` intra-op threads
    /// (clamped to ≥ 1; 1 = serial, the deterministic default). The
    /// microkernel follows `tensor::dispatch` per call unless pinned
    /// with [`with_kernel`](GemmPool::with_kernel).
    pub fn new(threads: usize) -> GemmPool {
        let threads = threads.max(1);
        GemmPool {
            threads,
            bufs: (0..threads).map(|_| PackBuf::new()).collect(),
            kernel: None,
            par_min_flops: None,
        }
    }

    /// Pin this pool's microkernel selection (`None` = follow
    /// `tensor::dispatch::current()` per call — the default).
    pub fn with_kernel(mut self, kernel: Option<Selection>) -> GemmPool {
        self.kernel = kernel;
        self
    }

    /// Override the serial/parallel flop threshold (`None` = the
    /// per-path default, [`par_min_flops_for`]). The bench sweeps this.
    pub fn with_par_min_flops(mut self, flops: Option<usize>) -> GemmPool {
        self.par_min_flops = flops;
        self
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The pinned selection, if any (`None` = per-call dispatch).
    pub fn kernel(&self) -> Option<Selection> {
        self.kernel
    }

    /// `C = epilogue(A · B)`; the packing-time sparse panel filter is on
    /// for `A` (the sparse-input first-layer orientation).
    pub fn gemm(&mut self, a: &Matrix, b: &Matrix, c: &mut Matrix, ep: Epilogue) {
        let (av, m, k, bv, n) = nn_views(a, b, c);
        check_ep(&ep, c);
        self.run(av, m, k, bv, n, c, &ep, true);
    }

    /// `C = epilogue(A · Bᵀ)` — transpose-free via strided packing.
    pub fn gemm_nt(&mut self, a: &Matrix, b: &Matrix, c: &mut Matrix, ep: Epilogue) {
        let (av, m, k, bv, n) = nt_views(a, b, c);
        check_ep(&ep, c);
        self.run(av, m, k, bv, n, c, &ep, false);
    }

    /// `C = epilogue(Aᵀ · B)` — transpose-free via strided packing.
    pub fn gemm_tn(&mut self, a: &Matrix, b: &Matrix, c: &mut Matrix, ep: Epilogue) {
        let (av, m, k, bv, n) = tn_views(a, b, c);
        check_ep(&ep, c);
        self.run(av, m, k, bv, n, c, &ep, false);
    }

    #[allow(clippy::too_many_arguments)]
    fn run(
        &mut self,
        a: View,
        m: usize,
        k: usize,
        b: View,
        n: usize,
        c: &mut Matrix,
        ep: &Epilogue,
        filter_a: bool,
    ) {
        // resolve once, on the entry thread (scoped overrides included),
        // so every band of this call runs the same microkernel body
        let sel = self.kernel.unwrap_or_else(dispatch::current);
        let par_min = self
            .par_min_flops
            .unwrap_or_else(|| par_min_flops_for(sel.path));
        let panels = m.div_ceil(MR);
        let t = self.threads.min(panels);
        if t <= 1 || 2 * m * k * n < par_min {
            let bep = band_ep(ep, 0, n);
            gemm_band(
                a,
                m,
                k,
                b,
                n,
                c.data_mut(),
                &bep,
                filter_a,
                &mut self.bufs[0],
                sel,
            );
            return;
        }
        // micro-panel-aligned row bands: the first (panels % t) threads
        // take one extra panel
        let base = panels / t;
        let extra = panels % t;
        std::thread::scope(|scope| {
            let mut c_rest = c.data_mut();
            let mut bufs = self.bufs.iter_mut();
            let mut row0 = 0usize;
            for ti in 0..t {
                let band_panels = base + usize::from(ti < extra);
                let band_rows = (band_panels * MR).min(m - row0);
                let (c_band, tail) = c_rest.split_at_mut(band_rows * n);
                c_rest = tail;
                let buf = bufs.next().expect("one buf per thread");
                let bep = band_ep(ep, row0, n);
                let a_band = a.offset_rows(row0);
                scope.spawn(move || {
                    gemm_band(a_band, band_rows, k, b, n, c_band, &bep, filter_a, buf, sel);
                });
                row0 += band_rows;
            }
            debug_assert_eq!(row0, m, "bands must cover all rows");
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Unary;
    use crate::util::Pcg64;

    #[test]
    fn threaded_matches_serial_bitwise() {
        let mut rng = Pcg64::new(11);
        // large enough to clear PAR_MIN_FLOPS (2·96·200·64 ≈ 2.5M… use
        // 128 cols: 2·96·200·128 ≈ 4.9M) with a non-multiple-of-MR m
        let (m, k, n) = (97usize, 200usize, 128usize);
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let b = Matrix::randn(k, n, 1.0, &mut rng);
        let mut c1 = Matrix::zeros(m, n);
        let mut c4 = Matrix::zeros(m, n);
        GemmPool::new(1).gemm(&a, &b, &mut c1, Epilogue::Overwrite);
        GemmPool::new(4).gemm(&a, &b, &mut c4, Epilogue::Overwrite);
        assert_eq!(c1, c4, "thread count must not change bits");
    }

    #[test]
    fn threaded_epilogues_match_serial_bitwise() {
        let mut rng = Pcg64::new(12);
        let (m, k, n) = (80usize, 160usize, 160usize);
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let b = Matrix::randn(k, n, 1.0, &mut rng);
        let bias: Vec<f32> = (0..n).map(|i| (i as f32) * 0.01 - 0.5).collect();
        let ep = Epilogue::BiasUnary {
            bias: &bias,
            f: Unary::Sigmoid,
        };
        let mut c1 = Matrix::zeros(m, n);
        let mut c3 = Matrix::zeros(m, n);
        GemmPool::new(1).gemm(&a, &b, &mut c1, ep);
        GemmPool::new(3).gemm(&a, &b, &mut c3, ep);
        assert_eq!(c1, c3);
    }

    #[test]
    fn more_threads_than_panels_is_fine() {
        let mut rng = Pcg64::new(13);
        let a = Matrix::randn(4, 600, 1.0, &mut rng); // 1 micro-panel
        let b = Matrix::randn(600, 700, 1.0, &mut rng);
        let mut c = Matrix::zeros(4, 700);
        let mut want = Matrix::zeros(4, 700);
        GemmPool::new(8).gemm(&a, &b, &mut c, Epilogue::Overwrite);
        GemmPool::new(1).gemm(&a, &b, &mut want, Epilogue::Overwrite);
        assert_eq!(c, want);
    }

    #[test]
    fn pool_reuse_across_shapes() {
        // one pool serving differently-shaped calls must keep matching
        let mut rng = Pcg64::new(14);
        let mut pool = GemmPool::new(2);
        for &(m, k, n) in &[(30, 40, 50), (97, 200, 128), (8, 8, 8)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            let mut c = Matrix::zeros(m, n);
            let mut want = Matrix::zeros(m, n);
            pool.gemm(&a, &b, &mut c, Epilogue::Overwrite);
            GemmPool::new(1).gemm(&a, &b, &mut want, Epilogue::Overwrite);
            assert_eq!(c, want);
        }
    }

    #[test]
    fn per_path_serial_threshold() {
        assert_eq!(par_min_flops_for(KernelPath::Scalar), PAR_MIN_FLOPS);
        for p in [KernelPath::Avx2, KernelPath::Avx512, KernelPath::Neon] {
            assert_eq!(par_min_flops_for(p), PAR_MIN_FLOPS_SIMD);
        }
    }

    #[test]
    fn pinned_kernel_and_threshold_match_dispatch() {
        // pinning the scalar kernel on the pool must equal forcing it
        // through the thread-local override, at both threshold extremes
        let mut rng = Pcg64::new(15);
        let (m, k, n) = (60usize, 120usize, 90usize);
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let b = Matrix::randn(k, n, 1.0, &mut rng);
        let sel = Selection::new(KernelPath::Scalar, false);
        let mut pinned = Matrix::zeros(m, n);
        GemmPool::new(2)
            .with_kernel(Some(sel))
            .with_par_min_flops(Some(0)) // force the banded path
            .gemm(&a, &b, &mut pinned, Epilogue::Overwrite);
        let mut forced = Matrix::zeros(m, n);
        dispatch::with_selection(sel, || {
            GemmPool::new(2)
                .with_par_min_flops(Some(usize::MAX)) // force serial
                .gemm(&a, &b, &mut forced, Epilogue::Overwrite);
        });
        assert_eq!(pinned, forced, "band split must stay value-neutral");
    }

    #[test]
    fn threaded_matches_serial_bitwise_on_every_path() {
        let mut rng = Pcg64::new(16);
        let (m, k, n) = (97usize, 200usize, 128usize);
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let b = Matrix::randn(k, n, 1.0, &mut rng);
        for &path in dispatch::available() {
            for bf16 in [false, true] {
                let sel = Selection::new(path, bf16);
                let mut c1 = Matrix::zeros(m, n);
                let mut c4 = Matrix::zeros(m, n);
                GemmPool::new(1)
                    .with_kernel(Some(sel))
                    .with_par_min_flops(Some(0))
                    .gemm(&a, &b, &mut c1, Epilogue::Overwrite);
                GemmPool::new(4)
                    .with_kernel(Some(sel))
                    .with_par_min_flops(Some(0))
                    .gemm(&a, &b, &mut c4, Epilogue::Overwrite);
                assert_eq!(c1, c4, "path {sel} must be split-invariant");
            }
        }
    }
}
